//! The repo-wide synchronization shim.
//!
//! Every concurrent module (`linalg::pool`, `coordinator::threaded`,
//! `master_actor`, `tree_threaded`, `process`) imports its primitives
//! from here instead of `std::sync` / `std::thread` — `tests/repo_lint.rs`
//! enforces that. Under a normal build this module is a zero-cost
//! re-export of `std`; under `RUSTFLAGS="--cfg loom"` it re-exports the
//! model checker's instrumented equivalents (the `loom` path dependency
//! in `rust/vendor/loom`), so `tests/loom_models.rs` can drive the
//! hand-rolled protocols — GemmPool epoch dispatch, sharded-center
//! push/pull, actor shutdown — through perturbed schedules with
//! deadlock/lost-wakeup detection. One import root, two engines.
//!
//! What deliberately stays on `std` even under `cfg(loom)`: panicking
//! (`std::panic::catch_unwind` — poison semantics are identical in both
//! engines), time, env, filesystem, and sockets (the process backend's
//! wire layer is exercised by Miri and the real-socket tests instead).

#[cfg(not(loom))]
pub use std::sync::{
    Arc, Condvar, LockResult, Mutex, MutexGuard, PoisonError, RwLock, TryLockError, TryLockResult,
};

#[cfg(loom)]
pub use loom::sync::{
    Arc, Condvar, LockResult, Mutex, MutexGuard, PoisonError, RwLock, TryLockError, TryLockResult,
};

/// `std::sync::atomic` (or loom's instrumented atomics under `cfg(loom)`).
pub mod atomic {
    #[cfg(not(loom))]
    pub use std::sync::atomic::*;

    #[cfg(loom)]
    pub use loom::sync::atomic::*;
}

/// `std::sync::mpsc` (or loom's channels under `cfg(loom)`).
pub mod mpsc {
    #[cfg(not(loom))]
    pub use std::sync::mpsc::*;

    #[cfg(loom)]
    pub use loom::sync::mpsc::*;
}

/// `std::thread` (or loom's scheduler-aware threads under `cfg(loom)`).
pub mod thread {
    #[cfg(not(loom))]
    pub use std::thread::*;

    #[cfg(loom)]
    pub use loom::thread::*;
}
