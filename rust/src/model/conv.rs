//! CIFAR-faithful convolutional stand-in model on the GEMM path.
//!
//! The thesis' Chapter-4 experiments train a 7-layer *convolutional*
//! net on CIFAR (§4.1); [`super::Mlp`] is the historical stand-in. This
//! module closes the gap while staying on the PR-4 micro-kernels:
//! every convolution is lowered to **im2col + [`gemm::sgemm`]** —
//! patches of the input image are unrolled into rows of a
//! `(batch·oh·ow) × (kh·kw·c)` panel so the convolution becomes one
//! register-blocked GEMM with the fused bias+ReLU epilogue
//! ([`gemm::sgemm_bias_act`]) applied while the accumulator tile is
//! still in registers. A 2×2/stride-2 max-pool (argmax recorded for
//! the backward routing) follows each conv block where the spatial
//! extent allows, and a small fully-connected head finishes with the
//! same softmax-CE top as the MLP.
//!
//! Layout convention: images are **HWC** row-major — the value at
//! `(y, x, ch)` lives at `(y·w + x)·c + ch` — so an im2col row is `kh`
//! contiguous `kw·c` segments and the GEMM output panel
//! `(batch·oh·ow) × out_c` IS the batch of HWC feature maps,
//! concatenated. Flattening into the FC head is therefore a straight
//! copy, and the whole batch flows through ONE GEMM per layer.
//!
//! Like [`super::Mlp`], parameters live in one flat f32 buffer
//! (conv blocks first — `W` as `(kh·kw·c) × out_c` row-major then the
//! bias — followed by the FC layers), all scratch panels are
//! pre-allocated on first use and reused, and a steady-state
//! [`ConvNet::grad_batch`] performs zero heap allocations
//! (enforced by `tests/alloc_free.rs`). Parity against a naive direct
//! convolution and against finite differences is tested below.

use super::mlp::argmax;
use crate::linalg::gemm;
use crate::rng::Rng;

/// One convolution block: `out_c` filters of `kh × kw`, given stride
/// and zero-padding, ReLU, and an optional 2×2/stride-2 max-pool.
#[derive(Clone, Copy, Debug)]
pub struct ConvSpec {
    pub out_c: usize,
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
    pub pad: usize,
    pub pool: bool,
}

impl ConvSpec {
    /// A 3×3, stride-1, pad-1 block (spatial-preserving, the CIFAR
    /// workhorse shape).
    pub fn k3(out_c: usize, pool: bool) -> ConvSpec {
        ConvSpec { out_c, kh: 3, kw: 3, stride: 1, pad: 1, pool }
    }

    /// Conv output spatial dims for an `h × w` input.
    fn out_hw(&self, h: usize, w: usize) -> (usize, usize) {
        assert!(self.stride > 0, "stride must be positive");
        assert!(
            h + 2 * self.pad >= self.kh && w + 2 * self.pad >= self.kw,
            "kernel {}x{} larger than padded input {}x{}",
            self.kh,
            self.kw,
            h + 2 * self.pad,
            w + 2 * self.pad
        );
        (
            (h + 2 * self.pad - self.kh) / self.stride + 1,
            (w + 2 * self.pad - self.kw) / self.stride + 1,
        )
    }
}

/// Factor a flat blob dimension into a near-square `(h, w)` image:
/// the largest divisor of `dim` not exceeding √dim becomes the height
/// (a prime `dim` degrades to a 1 × dim "image").
pub fn image_shape(dim: usize) -> (usize, usize) {
    assert!(dim > 0, "empty input dimension");
    let mut h = (dim as f64).sqrt().floor() as usize;
    h = h.max(1);
    while h > 1 && dim % h != 0 {
        h -= 1;
    }
    (h, dim / h)
}

/// Architecture of a [`ConvNet`]: input image shape, the conv blocks,
/// and the FC head (`hidden` ReLU widths then a linear `classes`
/// layer).
#[derive(Clone, Debug)]
pub struct ConvNetConfig {
    pub in_c: usize,
    pub in_h: usize,
    pub in_w: usize,
    pub convs: Vec<ConvSpec>,
    pub hidden: Vec<usize>,
    pub classes: usize,
    pub l2: f32,
}

impl ConvNetConfig {
    /// The conv oracle for a flat `dim`-dimensional blob input,
    /// interpreted as a 1 × h × w image (`h·w = dim`): two 3×3 conv
    /// blocks (8 then 16 channels) pooling while the spatial extent
    /// allows, then one hidden FC layer — the §4.1-shaped stand-in the
    /// `model=conv` sweeps use.
    pub fn for_blob(dim: usize, classes: usize, l2: f32) -> ConvNetConfig {
        let (h, w) = image_shape(dim);
        let (mut ch, mut cw) = (h, w);
        let mut convs = Vec::new();
        let mut c = 1usize;
        for out_c in [8usize, 16] {
            // 3×3 pad-1 stride-1 preserves the spatial dims, so the
            // pool decision only needs the incoming extent.
            let pool = ch >= 2 && cw >= 2;
            convs.push(ConvSpec::k3(out_c, pool));
            if pool {
                ch /= 2;
                cw /= 2;
            }
            c = out_c;
        }
        let flat = c * ch * cw;
        ConvNetConfig {
            in_c: 1,
            in_h: h,
            in_w: w,
            convs,
            hidden: vec![flat.max(16)],
            classes,
            l2,
        }
    }

    /// Flat input size (`c·h·w`) — what [`ConvNet::grad_batch`] expects
    /// each sample slice to hold.
    pub fn in_dim(&self) -> usize {
        self.in_c * self.in_h * self.in_w
    }

    /// Walk the conv stack: per block, the pre-pool `(c, h, w)` and
    /// post-pool `(c, h, w)` output shapes.
    fn conv_shapes(&self) -> Vec<((usize, usize, usize), (usize, usize, usize))> {
        let (mut h, mut w) = (self.in_h, self.in_w);
        let mut out = Vec::with_capacity(self.convs.len());
        for s in &self.convs {
            let (oh, ow) = s.out_hw(h, w);
            let (ph, pw) = if s.pool {
                assert!(oh >= 2 && ow >= 2, "2x2 pool needs >= 2x2 input, got {oh}x{ow}");
                (oh / 2, ow / 2)
            } else {
                (oh, ow)
            };
            out.push(((s.out_c, oh, ow), (s.out_c, ph, pw)));
            h = ph;
            w = pw;
        }
        out
    }

    /// FC layer widths: `[flat, hidden .., classes]`.
    fn fc_dims(&self) -> Vec<usize> {
        let flat = match self.conv_shapes().last() {
            Some((_, (c, h, w))) => c * h * w,
            None => self.in_dim(),
        };
        let mut dims = Vec::with_capacity(self.hidden.len() + 2);
        dims.push(flat);
        dims.extend_from_slice(&self.hidden);
        dims.push(self.classes);
        dims
    }

    pub fn n_classes(&self) -> usize {
        self.classes
    }

    /// Total flat-θ length: conv `W + b` blocks then FC `W + b` layers.
    pub fn n_params(&self) -> usize {
        let mut n = 0;
        let mut c = self.in_c;
        for s in &self.convs {
            n += s.kh * s.kw * c * s.out_c + s.out_c;
            c = s.out_c;
        }
        n + self.fc_dims().windows(2).map(|d| d[0] * d[1] + d[1]).sum::<usize>()
    }
}

/// Per-block runtime state: resolved shapes, the θ offset, and the
/// scratch panels (sized to the largest batch seen, reused forever).
struct ConvStage {
    spec: ConvSpec,
    in_c: usize,
    in_h: usize,
    in_w: usize,
    /// Conv (pre-pool) output spatial dims.
    oh: usize,
    ow: usize,
    /// Post-pool spatial dims (= `oh, ow` when `!spec.pool`).
    ph: usize,
    pw: usize,
    /// im2col width `kh·kw·in_c`.
    k: usize,
    /// θ offset of this block's `k × out_c` weight panel (bias at
    /// `off + k·out_c`).
    off: usize,
    /// im2col panel, `(n·oh·ow) × k` — kept for the weight-gradient
    /// GEMM on the way back down.
    col: Vec<f32>,
    /// Post-ReLU pre-pool activations, `(n·oh·ow) × out_c`.
    act: Vec<f32>,
    /// Pooled activations, `(n·ph·pw) × out_c` (unused when `!pool`).
    pooled: Vec<f32>,
    /// Absolute argmax index into `act` per pooled element.
    pool_idx: Vec<usize>,
    d_act: Vec<f32>,
    d_pooled: Vec<f32>,
    d_col: Vec<f32>,
}

impl ConvStage {
    /// The block's output panel (what the next layer reads).
    fn output(&self, n: usize) -> &[f32] {
        if self.spec.pool {
            &self.pooled[..n * self.ph * self.pw * self.spec.out_c]
        } else {
            &self.act[..n * self.oh * self.ow * self.spec.out_c]
        }
    }

    /// Gradient panel of the block's output (what the layer above
    /// writes).
    fn d_output_mut(&mut self, n: usize) -> &mut [f32] {
        if self.spec.pool {
            &mut self.d_pooled[..n * self.ph * self.pw * self.spec.out_c]
        } else {
            &mut self.d_act[..n * self.oh * self.ow * self.spec.out_c]
        }
    }

    /// Flat output size per sample.
    fn out_dim(&self) -> usize {
        self.ph * self.pw * self.spec.out_c
    }

    /// Unroll `src` (the previous layer's HWC batch panel, `n` samples
    /// of `in_h·in_w·in_c`) into the im2col panel: row `(i, oy, ox)`
    /// holds the `kh × kw × in_c` patch under filter position
    /// `(oy, ox)`, out-of-bounds entries zero-filled.
    fn im2col(&mut self, src: &[f32], n: usize) {
        let (kh, kw, s, pad) = (self.spec.kh, self.spec.kw, self.spec.stride, self.spec.pad);
        let (c, h, w) = (self.in_c, self.in_h, self.in_w);
        let seg = kw * c;
        for i in 0..n {
            let img = &src[i * h * w * c..(i + 1) * h * w * c];
            for oy in 0..self.oh {
                for ox in 0..self.ow {
                    let r = (i * self.oh + oy) * self.ow + ox;
                    let row = &mut self.col[r * self.k..(r + 1) * self.k];
                    for ky in 0..kh {
                        let y = (oy * s + ky) as isize - pad as isize;
                        let dst = &mut row[ky * seg..(ky + 1) * seg];
                        if y < 0 || y >= h as isize {
                            dst.fill(0.0);
                            continue;
                        }
                        let yrow = y as usize * w;
                        for kx in 0..kw {
                            let x = (ox * s + kx) as isize - pad as isize;
                            let d = &mut dst[kx * c..(kx + 1) * c];
                            if x < 0 || x >= w as isize {
                                d.fill(0.0);
                            } else {
                                let base = (yrow + x as usize) * c;
                                d.copy_from_slice(&img[base..base + c]);
                            }
                        }
                    }
                }
            }
        }
    }

    /// Mirror of [`ConvStage::im2col`]: scatter-add the im2col-shaped
    /// gradient back onto the (pre-zeroed) input-gradient panel.
    fn col2im_accum(&self, d_src: &mut [f32], n: usize) {
        let (kh, kw, s, pad) = (self.spec.kh, self.spec.kw, self.spec.stride, self.spec.pad);
        let (c, h, w) = (self.in_c, self.in_h, self.in_w);
        for i in 0..n {
            let dimg = &mut d_src[i * h * w * c..(i + 1) * h * w * c];
            for oy in 0..self.oh {
                for ox in 0..self.ow {
                    let r = (i * self.oh + oy) * self.ow + ox;
                    let row = &self.d_col[r * self.k..(r + 1) * self.k];
                    for ky in 0..kh {
                        let y = (oy * s + ky) as isize - pad as isize;
                        if y < 0 || y >= h as isize {
                            continue;
                        }
                        let yrow = y as usize * w;
                        for kx in 0..kw {
                            let x = (ox * s + kx) as isize - pad as isize;
                            if x < 0 || x >= w as isize {
                                continue;
                            }
                            let base = (yrow + x as usize) * c;
                            let seg = &row[(ky * kw + kx) * c..(ky * kw + kx + 1) * c];
                            for (dv, &sv) in dimg[base..base + c].iter_mut().zip(seg) {
                                *dv += sv;
                            }
                        }
                    }
                }
            }
        }
    }

    /// 2×2/stride-2 max-pool over the HWC `act` panel, recording the
    /// winning absolute index for the backward routing. Odd trailing
    /// rows/columns are dropped (standard floor semantics).
    fn pool_forward(&mut self, n: usize) {
        let oc = self.spec.out_c;
        for i in 0..n {
            for py in 0..self.ph {
                for px in 0..self.pw {
                    for ch in 0..oc {
                        let j0 = ((i * self.oh + py * 2) * self.ow + px * 2) * oc + ch;
                        let mut best_j = j0;
                        let mut best_v = self.act[j0];
                        for (dy, dx) in [(0usize, 1usize), (1, 0), (1, 1)] {
                            let j = ((i * self.oh + py * 2 + dy) * self.ow + px * 2 + dx) * oc
                                + ch;
                            let v = self.act[j];
                            if v > best_v {
                                best_v = v;
                                best_j = j;
                            }
                        }
                        let out = ((i * self.ph + py) * self.pw + px) * oc + ch;
                        self.pooled[out] = best_v;
                        self.pool_idx[out] = best_j;
                    }
                }
            }
        }
    }
}

/// The model: holds no parameters — they are passed as one flat slice,
/// same contract as [`super::Mlp`] — only the resolved layer shapes and
/// the batch-major scratch panels, reused across calls so the sweep hot
/// loop is allocation-free.
pub struct ConvNet {
    cfg: ConvNetConfig,
    stages: Vec<ConvStage>,
    /// FC widths `[flat, hidden .., classes]` and per-layer θ offsets.
    fc_dims: Vec<usize>,
    fc_offsets: Vec<usize>,
    /// Row capacity of every scratch panel (grows monotonically).
    cap: usize,
    /// Packed input batch, `n × in_dim` (sized by [`ConvNet::pack`]).
    input: Vec<f32>,
    /// FC activation panels; `fc_acts[0]` is the flatten copy of the
    /// last conv output.
    fc_acts: Vec<Vec<f32>>,
    fc_d: Vec<Vec<f32>>,
    labels: Vec<usize>,
}

impl ConvNet {
    pub fn new(cfg: ConvNetConfig) -> Self {
        assert!(cfg.classes >= 2, "need at least two classes");
        let shapes = cfg.conv_shapes(); // validates every block
        let mut stages = Vec::with_capacity(cfg.convs.len());
        let (mut c, mut h, mut w) = (cfg.in_c, cfg.in_h, cfg.in_w);
        let mut off = 0;
        for (spec, &((oc, oh, ow), (_, ph, pw))) in cfg.convs.iter().zip(shapes.iter()) {
            let k = spec.kh * spec.kw * c;
            stages.push(ConvStage {
                spec: *spec,
                in_c: c,
                in_h: h,
                in_w: w,
                oh,
                ow,
                ph,
                pw,
                k,
                off,
                col: Vec::new(),
                act: Vec::new(),
                pooled: Vec::new(),
                pool_idx: Vec::new(),
                d_act: Vec::new(),
                d_pooled: Vec::new(),
                d_col: Vec::new(),
            });
            off += k * oc + oc;
            c = oc;
            h = ph;
            w = pw;
        }
        let fc_dims = cfg.fc_dims();
        let mut fc_offsets = Vec::with_capacity(fc_dims.len() - 1);
        for d in fc_dims.windows(2) {
            fc_offsets.push(off);
            off += d[0] * d[1] + d[1];
        }
        debug_assert_eq!(off, cfg.n_params());
        let fc_acts = fc_dims.iter().map(|_| Vec::new()).collect();
        let fc_d = fc_dims.iter().map(|_| Vec::new()).collect();
        Self {
            cfg,
            stages,
            fc_dims,
            fc_offsets,
            cap: 0,
            input: Vec::new(),
            fc_acts,
            fc_d,
            labels: Vec::new(),
        }
    }

    pub fn config(&self) -> &ConvNetConfig {
        &self.cfg
    }

    /// He-scaled random init (fan-in = receptive field size for conv
    /// filters), zero biases — same §4.1 convention as the MLP.
    pub fn init_params(&self, rng: &mut Rng) -> Vec<f32> {
        let mut theta = vec![0.0f32; self.cfg.n_params()];
        for st in &self.stages {
            let n_w = st.k * st.spec.out_c;
            let std = (2.0 / st.k as f64).sqrt() as f32;
            rng.fill_gaussian_f32(&mut theta[st.off..st.off + n_w], std);
        }
        for (l, &off) in self.fc_offsets.iter().enumerate() {
            let (din, dout) = (self.fc_dims[l], self.fc_dims[l + 1]);
            let std = (2.0 / din as f64).sqrt() as f32;
            rng.fill_gaussian_f32(&mut theta[off..off + din * dout], std);
        }
        theta
    }

    /// `0.5·λ‖θ‖²`, computed once per θ (shared across the eval loop).
    pub fn l2_penalty(&self, theta: &[f32]) -> f32 {
        if self.cfg.l2 == 0.0 {
            return 0.0;
        }
        0.5 * self.cfg.l2 * theta.iter().map(|t| t * t).sum::<f32>()
    }

    /// Grow every scratch panel to `n` rows (amortized no-op once the
    /// largest batch has been seen).
    fn ensure_rows(&mut self, n: usize) {
        if n <= self.cap {
            return;
        }
        for st in &mut self.stages {
            let m = n * st.oh * st.ow;
            let oc = st.spec.out_c;
            st.col.resize(m * st.k, 0.0);
            st.act.resize(m * oc, 0.0);
            st.d_act.resize(m * oc, 0.0);
            st.d_col.resize(m * st.k, 0.0);
            if st.spec.pool {
                let pm = n * st.ph * st.pw * oc;
                st.pooled.resize(pm, 0.0);
                st.pool_idx.resize(pm, 0);
                st.d_pooled.resize(pm, 0.0);
            }
        }
        for (l, &dim) in self.fc_dims.iter().enumerate() {
            self.fc_acts[l].resize(n * dim, 0.0);
            self.fc_d[l].resize(n * dim, 0.0);
        }
        self.cap = n;
    }

    /// Copy the batch into the packed input panel + label buffer;
    /// returns the batch size. Allocation-free at a steady batch size.
    fn pack<'a, I: IntoIterator<Item = (&'a [f32], usize)>>(&mut self, samples: I) -> usize {
        let din = self.cfg.in_dim();
        let nc = self.cfg.classes;
        self.input.clear();
        self.labels.clear();
        for (x, y) in samples {
            assert_eq!(x.len(), din, "input dim mismatch (expect c*h*w = {din})");
            assert!(y < nc, "label {y} out of range");
            self.input.extend_from_slice(x);
            self.labels.push(y);
        }
        let n = self.labels.len();
        self.ensure_rows(n);
        n
    }

    /// Forward over the packed batch: per conv block, im2col then one
    /// fused GEMM (bias + ReLU epilogue) then the optional pool; then
    /// the FC head, logits left in the last panel.
    fn forward_packed(&mut self, theta: &[f32], n: usize) {
        for s in 0..self.stages.len() {
            let (done, rest) = self.stages.split_at_mut(s);
            let st = &mut rest[0];
            let src: &[f32] = match done.last() {
                Some(prev) => prev.output(n),
                None => &self.input[..n * st.in_c * st.in_h * st.in_w],
            };
            st.im2col(src, n);
            let m = n * st.oh * st.ow;
            let oc = st.spec.out_c;
            let w = &theta[st.off..st.off + st.k * oc];
            let bias = &theta[st.off + st.k * oc..st.off + st.k * oc + oc];
            gemm::sgemm_bias_act(
                m,
                oc,
                st.k,
                &st.col[..m * st.k],
                w,
                bias,
                true,
                &mut st.act[..m * oc],
            );
            if st.spec.pool {
                st.pool_forward(n);
            }
        }
        // Flatten: the conv output panel already is the packed
        // `n × flat` matrix — one copy into the FC input panel.
        let flat = self.fc_dims[0];
        match self.stages.last() {
            Some(st) => self.fc_acts[0][..n * flat].copy_from_slice(st.output(n)),
            None => self.fc_acts[0][..n * flat].copy_from_slice(&self.input[..n * flat]),
        }
        let n_fc = self.fc_dims.len() - 1;
        for l in 0..n_fc {
            let (din, dout) = (self.fc_dims[l], self.fc_dims[l + 1]);
            let off = self.fc_offsets[l];
            let w = &theta[off..off + din * dout];
            let bias = &theta[off + din * dout..off + din * dout + dout];
            let (lo, hi) = self.fc_acts.split_at_mut(l + 1);
            gemm::sgemm_bias_act(
                n,
                dout,
                din,
                &lo[l][..n * din],
                w,
                bias,
                l + 1 < n_fc,
                &mut hi[0][..n * dout],
            );
        }
    }

    /// Batched forward pass (labels ride along for the loss paths; pass
    /// 0 when irrelevant). Returns the batch size; logits readable via
    /// [`ConvNet::logits`].
    pub fn forward_batch<'a, I: IntoIterator<Item = (&'a [f32], usize)>>(
        &mut self,
        theta: &[f32],
        samples: I,
    ) -> usize {
        let n = self.pack(samples);
        self.forward_packed(theta, n);
        n
    }

    /// Logits panel of the last forward (`n × classes` row-major).
    pub fn logits(&self, n: usize) -> &[f32] {
        &self.fc_acts[self.fc_dims.len() - 1][..n * self.cfg.classes]
    }

    /// Backprop over the packed batch, ACCUMULATING the summed
    /// data-term gradient into `grad`; returns the summed data loss
    /// (no l2).
    fn grad_packed(&mut self, theta: &[f32], n: usize, grad: &mut [f32]) -> f32 {
        self.forward_packed(theta, n);
        let n_fc = self.fc_dims.len() - 1;
        let nc = self.cfg.classes;

        // Softmax-CE top, shared with the MLP ([`super::softmax_ce_top`]):
        // d_top row = softmax(logits) − onehot(label).
        let loss = super::softmax_ce_top(
            &self.fc_acts[n_fc][..n * nc],
            &self.labels,
            nc,
            &mut self.fc_d[n_fc][..n * nc],
        );

        // FC head backward — three GEMM-shaped products per layer.
        // Unlike the MLP we also produce d at level 0: that is the
        // flatten gradient the conv stack consumes.
        for l in (0..n_fc).rev() {
            let (din, dout) = (self.fc_dims[l], self.fc_dims[l + 1]);
            let off = self.fc_offsets[l];
            if l + 1 < n_fc {
                let act = &self.fc_acts[l + 1][..n * dout];
                let dl = &mut self.fc_d[l + 1][..n * dout];
                for (dv, &av) in dl.iter_mut().zip(act) {
                    if av <= 0.0 {
                        *dv = 0.0;
                    }
                }
            }
            gemm::sgemm(
                true,
                false,
                din,
                dout,
                n,
                &self.fc_acts[l][..n * din],
                &self.fc_d[l + 1][..n * dout],
                &mut grad[off..off + din * dout],
            );
            gemm::col_sums_accum(
                n,
                dout,
                &self.fc_d[l + 1][..n * dout],
                &mut grad[off + din * dout..off + din * dout + dout],
            );
            if l > 0 || !self.stages.is_empty() {
                let w = &theta[off..off + din * dout];
                let (dlo, dhi) = self.fc_d.split_at_mut(l + 1);
                let dl = &mut dlo[l][..n * din];
                dl.iter_mut().for_each(|v| *v = 0.0);
                gemm::sgemm(false, true, n, din, dout, &dhi[0][..n * dout], w, dl);
            }
        }

        // Hand the flatten gradient to the last conv block.
        if let Some(st) = self.stages.last_mut() {
            let flat = st.out_dim();
            st.d_output_mut(n).copy_from_slice(&self.fc_d[0][..n * flat]);
        }

        // Conv stack backward.
        for s in (0..self.stages.len()).rev() {
            let (done, rest) = self.stages.split_at_mut(s);
            let st = &mut rest[0];
            let m = n * st.oh * st.ow;
            let oc = st.spec.out_c;
            // Un-pool: route each pooled gradient to its argmax.
            if st.spec.pool {
                st.d_act[..m * oc].iter_mut().for_each(|v| *v = 0.0);
                let pm = n * st.ph * st.pw * oc;
                for j in 0..pm {
                    let tgt = st.pool_idx[j];
                    let v = st.d_pooled[j];
                    st.d_act[tgt] += v;
                }
            }
            // ReLU mask (act stores post-ReLU values: act > 0 ⇔ pre > 0).
            {
                let act = &st.act[..m * oc];
                let dl = &mut st.d_act[..m * oc];
                for (dv, &av) in dl.iter_mut().zip(act) {
                    if av <= 0.0 {
                        *dv = 0.0;
                    }
                }
            }
            // gW(k × oc) += colᵀ · dpre; gb += column sums of dpre.
            gemm::sgemm(
                true,
                false,
                st.k,
                oc,
                m,
                &st.col[..m * st.k],
                &st.d_act[..m * oc],
                &mut grad[st.off..st.off + st.k * oc],
            );
            gemm::col_sums_accum(
                m,
                oc,
                &st.d_act[..m * oc],
                &mut grad[st.off + st.k * oc..st.off + st.k * oc + oc],
            );
            // Input gradient for the block below: d_col = dpre · Wᵀ,
            // then col2im scatter-add. Skipped for block 0 (the input
            // gradient is never needed).
            if let Some(prev) = done.last_mut() {
                let w = &theta[st.off..st.off + st.k * oc];
                st.d_col[..m * st.k].iter_mut().for_each(|v| *v = 0.0);
                gemm::sgemm(
                    false,
                    true,
                    m,
                    st.k,
                    oc,
                    &st.d_act[..m * oc],
                    w,
                    &mut st.d_col[..m * st.k],
                );
                let d_prev = prev.d_output_mut(n);
                d_prev.iter_mut().for_each(|v| *v = 0.0);
                st.col2im_accum(d_prev, n);
            }
        }
        loss
    }

    /// Batched mini-batch gradient: writes the MEAN gradient
    /// (overwritten, not accumulated) with the l2 term applied once;
    /// returns the mean loss (incl. l2). Same contract as
    /// [`super::Mlp::grad_batch`] — the oracle-facing hot path.
    pub fn grad_batch<'a, I: IntoIterator<Item = (&'a [f32], usize)>>(
        &mut self,
        theta: &[f32],
        samples: I,
        grad: &mut [f32],
    ) -> f32 {
        assert_eq!(grad.len(), theta.len());
        let n = self.pack(samples);
        assert!(n > 0, "empty batch");
        grad.iter_mut().for_each(|g| *g = 0.0);
        let loss = self.grad_packed(theta, n, grad);
        let inv = 1.0 / n as f32;
        grad.iter_mut().for_each(|g| *g *= inv);
        if self.cfg.l2 > 0.0 {
            for (g, t) in grad.iter_mut().zip(theta) {
                *g += self.cfg.l2 * t;
            }
        }
        loss * inv + self.l2_penalty(theta)
    }

    /// Mini-batch gradient over owned samples (slice-of-pairs
    /// convenience over [`ConvNet::grad_batch`]).
    pub fn batch_grad(
        &mut self,
        theta: &[f32],
        xs: &[(Vec<f32>, usize)],
        grad: &mut [f32],
    ) -> f32 {
        self.grad_batch(theta, xs.iter().map(|(x, y)| (x.as_slice(), *y)), grad)
    }

    /// Summed data-term NLL and misclassification count over the batch
    /// (no l2 — add [`ConvNet::l2_penalty`] once per θ) — the eval path.
    pub fn eval_batch<'a, I: IntoIterator<Item = (&'a [f32], usize)>>(
        &mut self,
        theta: &[f32],
        samples: I,
    ) -> (f64, usize) {
        let n = self.forward_batch(theta, samples);
        let nc = self.cfg.classes;
        let logits = &self.fc_acts[self.fc_dims.len() - 1][..n * nc];
        super::batch_nll_wrong(logits, &self.labels, nc)
    }

    /// Predicted class (batch-of-one wrapper; NaN logits degrade to
    /// class 0).
    pub fn predict(&mut self, theta: &[f32], x: &[f32]) -> usize {
        let n = self.forward_batch(theta, std::iter::once((x, 0)));
        argmax(self.logits(n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Naive direct convolution + bias + ReLU over one HWC image —
    /// the reference the im2col path must match.
    #[allow(clippy::too_many_arguments)]
    fn naive_conv(
        img: &[f32],
        (c, h, w): (usize, usize, usize),
        spec: &ConvSpec,
        wgt: &[f32],
        bias: &[f32],
    ) -> Vec<f32> {
        let (oh, ow) = spec.out_hw(h, w);
        let oc = spec.out_c;
        let mut out = vec![0.0f32; oh * ow * oc];
        for oy in 0..oh {
            for ox in 0..ow {
                for f in 0..oc {
                    let mut acc = bias[f] as f64;
                    for ky in 0..spec.kh {
                        let y = (oy * spec.stride + ky) as isize - spec.pad as isize;
                        if y < 0 || y >= h as isize {
                            continue;
                        }
                        for kx in 0..spec.kw {
                            let x = (ox * spec.stride + kx) as isize - spec.pad as isize;
                            if x < 0 || x >= w as isize {
                                continue;
                            }
                            for ch in 0..c {
                                let iv = img[((y as usize) * w + x as usize) * c + ch];
                                let wv = wgt[((ky * spec.kw + kx) * c + ch) * oc + f];
                                acc += iv as f64 * wv as f64;
                            }
                        }
                    }
                    out[(oy * ow + ox) * oc + f] = (acc as f32).max(0.0);
                }
            }
        }
        out
    }

    fn fill(rng: &mut Rng, len: usize) -> Vec<f32> {
        (0..len).map(|_| rng.normal(0.0, 1.0) as f32).collect()
    }

    #[test]
    fn image_shape_factors_near_square() {
        assert_eq!(image_shape(32), (4, 8));
        assert_eq!(image_shape(36), (6, 6));
        assert_eq!(image_shape(8), (2, 4));
        assert_eq!(image_shape(7), (1, 7)); // prime degrades to a row
        assert_eq!(image_shape(1), (1, 1));
    }

    #[test]
    fn param_count_matches_layout() {
        let cfg = ConvNetConfig {
            in_c: 2,
            in_h: 6,
            in_w: 6,
            convs: vec![ConvSpec::k3(4, true)], // 6x6 -> 6x6 -> pool 3x3
            hidden: vec![10],
            classes: 5,
            l2: 0.0,
        };
        // conv: 3*3*2*4 + 4 = 76; flat = 4*3*3 = 36;
        // fc: 36*10 + 10 + 10*5 + 5 = 425.
        assert_eq!(cfg.n_params(), 76 + 360 + 10 + 50 + 5);
        let net = ConvNet::new(cfg);
        let mut rng = Rng::new(3);
        assert_eq!(net.init_params(&mut rng).len(), net.cfg.n_params());
    }

    /// The tentpole guard: im2col + sgemm convolution ≡ the naive
    /// direct convolution, over stride/pad/channel variations.
    #[test]
    fn im2col_conv_matches_naive_direct_convolution() {
        let mut rng = Rng::new(21);
        let shapes: &[((usize, usize, usize), ConvSpec)] = &[
            ((1, 5, 7), ConvSpec { out_c: 3, kh: 3, kw: 3, stride: 1, pad: 1, pool: false }),
            ((2, 6, 6), ConvSpec { out_c: 4, kh: 3, kw: 3, stride: 1, pad: 0, pool: false }),
            ((3, 8, 8), ConvSpec { out_c: 5, kh: 3, kw: 3, stride: 2, pad: 1, pool: false }),
            ((1, 4, 9), ConvSpec { out_c: 2, kh: 2, kw: 4, stride: 1, pad: 2, pool: false }),
            ((2, 7, 5), ConvSpec { out_c: 17, kh: 5, kw: 3, stride: 2, pad: 2, pool: false }),
        ];
        for &((c, h, w), spec) in shapes {
            let cfg = ConvNetConfig {
                in_c: c,
                in_h: h,
                in_w: w,
                convs: vec![spec],
                hidden: vec![],
                classes: 3,
                l2: 0.0,
            };
            let mut net = ConvNet::new(cfg);
            let theta = net.init_params(&mut rng);
            let n = 3; // a small batch so panel indexing is exercised
            let xs: Vec<Vec<f32>> = (0..n).map(|_| fill(&mut rng, c * h * w)).collect();
            net.forward_batch(&theta, xs.iter().map(|x| (x.as_slice(), 0)));
            let st = &net.stages[0];
            let oc = spec.out_c;
            let per = st.oh * st.ow * oc;
            let wgt = &theta[st.off..st.off + st.k * oc];
            let bias = &theta[st.off + st.k * oc..st.off + st.k * oc + oc];
            for (i, x) in xs.iter().enumerate() {
                let want = naive_conv(x, (c, h, w), &spec, wgt, bias);
                let got = &st.act[i * per..(i + 1) * per];
                for (j, (g, e)) in got.iter().zip(&want).enumerate() {
                    assert!(
                        (g - e).abs() < 1e-4 * (1.0 + e.abs()),
                        "shape {c}x{h}x{w} spec {spec:?} sample {i} elem {j}: {g} vs {e}"
                    );
                }
            }
        }
    }

    #[test]
    fn pool_halves_dims_and_routes_max() {
        let cfg = ConvNetConfig {
            in_c: 1,
            in_h: 4,
            in_w: 4,
            convs: vec![ConvSpec::k3(2, true)],
            hidden: vec![],
            classes: 2,
            l2: 0.0,
        };
        let mut net = ConvNet::new(cfg);
        let mut rng = Rng::new(5);
        let theta = net.init_params(&mut rng);
        let x = fill(&mut rng, 16);
        net.forward_batch(&theta, std::iter::once((x.as_slice(), 0)));
        let st = &net.stages[0];
        assert_eq!((st.ph, st.pw), (2, 2));
        // Every pooled value is the max of its 2×2 window and the
        // recorded index points at it.
        let oc = 2;
        for py in 0..2 {
            for px in 0..2 {
                for ch in 0..oc {
                    let out = ((py * st.pw) + px) * oc + ch;
                    let vals: Vec<f32> = (0..4)
                        .map(|q| {
                            let (dy, dx) = (q / 2, q % 2);
                            st.act[((py * 2 + dy) * st.ow + px * 2 + dx) * oc + ch]
                        })
                        .collect();
                    let want = vals.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                    assert_eq!(st.pooled[out], want);
                    assert_eq!(st.act[st.pool_idx[out]], want);
                }
            }
        }
    }

    /// The other tentpole guard: analytic `grad_batch` ≡ central finite
    /// differences on a tiny end-to-end net (conv → pool → conv → fc),
    /// including the l2 term.
    #[test]
    fn grad_batch_matches_finite_differences() {
        let cfg = ConvNetConfig {
            in_c: 1,
            in_h: 4,
            in_w: 4,
            convs: vec![
                ConvSpec { out_c: 3, kh: 3, kw: 3, stride: 1, pad: 1, pool: true },
                ConvSpec { out_c: 2, kh: 2, kw: 2, stride: 1, pad: 0, pool: false },
            ],
            hidden: vec![6],
            classes: 3,
            l2: 1e-3,
        };
        let mut net = ConvNet::new(cfg);
        let mut rng = Rng::new(9);
        let mut theta = net.init_params(&mut rng);
        let data: Vec<(Vec<f32>, usize)> = (0..4)
            .map(|i| (fill(&mut rng, 16), i % 3))
            .collect();
        let mut g = vec![0.0f32; theta.len()];
        net.batch_grad(&theta, &data, &mut g);

        // f(θ) = mean data NLL + l2 penalty — what grad_batch differentiates.
        let f = |net: &mut ConvNet, theta: &[f32]| -> f32 {
            let (nll, _) = net.eval_batch(theta, data.iter().map(|(x, y)| (x.as_slice(), *y)));
            nll as f32 / data.len() as f32 + net.l2_penalty(theta)
        };
        let eps = 1e-2f32;
        let mut checked = 0;
        for _ in 0..40 {
            let i = rng.below(theta.len());
            let orig = theta[i];
            theta[i] = orig + eps;
            let lp = f(&mut net, &theta);
            theta[i] = orig - eps;
            let lm = f(&mut net, &theta);
            theta[i] = orig;
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - g[i]).abs() < 5e-3 * (1.0 + fd.abs()),
                "param {i}: fd {fd} vs analytic {}",
                g[i]
            );
            checked += 1;
        }
        assert!(checked > 0);
    }

    /// Batched ≡ mean of per-sample gradients (the same parity the MLP
    /// guarantees), through every conv/pool/fc layer.
    #[test]
    fn batched_grad_is_mean_of_per_sample_grads() {
        let cfg = ConvNetConfig::for_blob(32, 4, 0.0);
        let mut net = ConvNet::new(cfg);
        let mut rng = Rng::new(13);
        let theta = net.init_params(&mut rng);
        let data: Vec<(Vec<f32>, usize)> =
            (0..5).map(|i| (fill(&mut rng, 32), i % 4)).collect();
        let mut gb = vec![0.0f32; theta.len()];
        net.batch_grad(&theta, &data, &mut gb);
        let mut acc = vec![0.0f64; theta.len()];
        let mut g1 = vec![0.0f32; theta.len()];
        for (x, y) in &data {
            net.grad_batch(&theta, std::iter::once((x.as_slice(), *y)), &mut g1);
            for (a, &g) in acc.iter_mut().zip(&g1) {
                *a += g as f64;
            }
        }
        for (i, (b, a)) in gb.iter().zip(&acc).enumerate() {
            let want = (a / data.len() as f64) as f32;
            assert!(
                (b - want).abs() < 1e-5 * (1.0 + want.abs()),
                "param {i}: batched {b} vs mean-of-singles {want}"
            );
        }
    }

    #[test]
    fn training_reduces_loss_on_separable_blobs() {
        // Two well-separated classes on a 1×4×4 "image": a few hundred
        // SGD steps must cut the loss and beat chance comfortably.
        let cfg = ConvNetConfig {
            in_c: 1,
            in_h: 4,
            in_w: 4,
            convs: vec![ConvSpec::k3(4, true)],
            hidden: vec![8],
            classes: 2,
            l2: 0.0,
        };
        let mut net = ConvNet::new(cfg);
        let mut rng = Rng::new(7);
        let mut theta = net.init_params(&mut rng);
        let data: Vec<(Vec<f32>, usize)> = (0..80)
            .map(|_| {
                let y = rng.below(2);
                let cx = if y == 0 { -1.0f32 } else { 1.0 };
                let x = (0..16)
                    .map(|_| cx + rng.normal(0.0, 0.4) as f32)
                    .collect();
                (x, y)
            })
            .collect();
        let mut g = vec![0.0f32; theta.len()];
        let l0 = net.batch_grad(&theta, &data, &mut g);
        for _ in 0..200 {
            net.batch_grad(&theta, &data, &mut g);
            crate::model::flat::sgd_step(&mut theta, &g, 0.2);
        }
        let l1 = net.batch_grad(&theta, &data, &mut g);
        assert!(l1 < l0 * 0.3, "loss {l0} -> {l1}");
        let correct = data
            .iter()
            .filter(|(x, y)| net.predict(&theta, x) == *y)
            .count();
        assert!(correct >= 72, "accuracy {correct}/80");
    }

    #[test]
    fn deterministic_given_seed_and_shrinking_batches_reuse_panels() {
        let cfg = ConvNetConfig::for_blob(32, 10, 1e-4);
        let t1 = ConvNet::new(cfg.clone()).init_params(&mut Rng::new(3));
        let t2 = ConvNet::new(cfg.clone()).init_params(&mut Rng::new(3));
        assert_eq!(t1, t2);
        // A large batch then a smaller one: panels are reused, results
        // stay consistent with a fresh model evaluating the small batch.
        let mut rng = Rng::new(4);
        let data: Vec<(Vec<f32>, usize)> = (0..16)
            .map(|i| (fill(&mut rng, 32), i % 10))
            .collect();
        let mut warm = ConvNet::new(cfg.clone());
        let theta = warm.init_params(&mut Rng::new(5));
        let mut g_warm = vec![0.0f32; theta.len()];
        warm.batch_grad(&theta, &data, &mut g_warm); // sizes panels at 16 rows
        warm.batch_grad(&theta, &data[..4], &mut g_warm);
        let mut cold = ConvNet::new(cfg);
        let mut g_cold = vec![0.0f32; theta.len()];
        cold.batch_grad(&theta, &data[..4], &mut g_cold);
        assert_eq!(g_warm, g_cold, "shrunken batch must match a cold model");
    }
}
