//! Chapter 3 figures: the closed-form MSE map and the ADMM instability.

use super::csv::Csv;
use super::FigOpts;
use crate::csv_row;
use crate::sim::{admm, moments};
use crate::error::Result;

/// Fig 3.1 — theoretical MSE of the center variable over (η, β) grids
/// for p ∈ {1, 10, 100, 1000, 10000} and t ∈ {1, 2, 10, 100, ∞}.
/// Large-noise setting: x̃₀ = x₀ⁱ = 1, h = 1, σ = 10.
pub fn fig3_1(opts: &FigOpts) -> Result<()> {
    let grid = if opts.full { 40 } else { 16 };
    let ps = [1usize, 10, 100, 1000, 10_000];
    let ts: [Option<u32>; 5] = [Some(1), Some(2), Some(10), Some(100), None];
    let mut csv = Csv::create(
        format!("{}/fig3_1.csv", opts.out_dir),
        &["p", "t", "eta", "beta", "mse"],
    )?;
    let mut shrink_ok = true;
    let mut prev_median = f64::INFINITY;
    for &p in &ps {
        let model = moments::QuadraticModel { h: 1.0, sigma: 10.0, p };
        let mut finals = Vec::new();
        for ti in &ts {
            for ei in 0..grid {
                for bi in 0..grid {
                    let eta = 10f64.powf(-3.0 + 3.0 * ei as f64 / (grid - 1) as f64);
                    let beta = 10f64.powf(-3.0 + 3.5 * bi as f64 / (grid - 1) as f64);
                    let mse = match ti {
                        Some(t) => moments::center_mse(&model, eta, beta, 1.0, *t),
                        None => {
                            let alpha = beta / p as f64;
                            let (b, _) = (0.0, 0.0);
                            let _ = b;
                            if moments::easgd_stable(eta, alpha, 1.0, p) {
                                moments::center_mse_infinite(&model, eta, beta)
                            } else {
                                f64::INFINITY
                            }
                        }
                    };
                    let t_str = ti.map(|t| t as f64).unwrap_or(f64::INFINITY);
                    csv.row_f64(&[p as f64, t_str, eta, beta, mse])?;
                    if ti.is_none() && mse.is_finite() {
                        finals.push(mse);
                    }
                }
            }
        }
        finals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = finals.get(finals.len() / 2).copied().unwrap_or(f64::NAN);
        println!("fig3.1: p={p:<6} median stationary MSE (stable region) = {median:.4e}");
        if median >= prev_median {
            shrink_ok = false;
        }
        prev_median = median;
    }
    println!(
        "fig3.1 shape: MSE decreases with p (variance reduction): {}",
        if shrink_ok { "HOLDS" } else { "VIOLATED" }
    );
    Ok(())
}

/// Fig 3.2 — sp(𝓕) of the round-robin ADMM composition over
/// (η, ρ) for p = 3 and p = 8; instability pockets at small ρ.
pub fn fig3_2(opts: &FigOpts) -> Result<()> {
    let grid = if opts.full { 64 } else { 24 };
    let mut csv = Csv::create(
        format!("{}/fig3_2.csv", opts.out_dir),
        &["p", "eta", "rho", "spectral_radius"],
    )?;
    for &p in &[3usize, 8] {
        let mut n_unstable = 0usize;
        for ei in 0..grid {
            for ri in 0..grid {
                let eta = 1e-2 * (ei as f64 + 0.5) / grid as f64;
                let rho = 10.0 * (ri as f64 + 0.5) / grid as f64;
                let sp = admm::admm_spectral_radius(p, eta, rho);
                csv.row_f64(&[p as f64, eta, rho, sp])?;
                if sp > 1.0 + 1e-9 {
                    n_unstable += 1;
                }
            }
        }
        println!(
            "fig3.2: p={p} unstable cells {n_unstable}/{} ({:.1}%)",
            grid * grid,
            100.0 * n_unstable as f64 / (grid * grid) as f64
        );
    }
    let sp_paper = admm::admm_spectral_radius(3, 0.001, 2.5);
    println!(
        "fig3.2 shape: paper's chaotic point (p=3, η=0.001, ρ=2.5) sp={sp_paper:.6} > 1: {}",
        if sp_paper > 1.0 { "HOLDS" } else { "VIOLATED" }
    );
    Ok(())
}

/// Fig 3.3 — divergent ADMM trajectory at the paper's point, plus the
/// contrasting stable EASGD round-robin run (§3.3's closed condition).
pub fn fig3_3(opts: &FigOpts) -> Result<()> {
    let rounds = if opts.full { 120_000 } else { 30_000 };
    let tr = admm::admm_trajectory(3, 0.001, 2.5, 1000.0, rounds);
    let mut csv = Csv::create(
        format!("{}/fig3_3.csv", opts.out_dir),
        &["round", "center_admm"],
    )?;
    for (i, x) in tr.iter().enumerate().step_by(10) {
        csv.row_f64(&[i as f64, *x])?;
    }
    let early: f64 = tr[..1000].iter().fold(0.0f64, |m, x| m.max(x.abs()));
    let late: f64 = tr[tr.len().saturating_sub(1000)..]
        .iter()
        .fold(0.0f64, |m, x| m.max(x.abs()));
    println!("fig3.3: ADMM |x̃| envelope early {early:.1} -> late {late:.3e}");
    println!(
        "fig3.3 shape: ADMM divergence at (η=0.001, ρ=2.5): {}",
        if late > 2.0 * early { "HOLDS" } else { "VIOLATED" }
    );

    // EASGD round-robin at the same spirit of setting stays put.
    let map = admm::easgd_round_robin_map(3, 0.5, 0.3);
    let mut s = vec![1000.0f64; 4];
    let mut csv2 = Csv::create(
        format!("{}/fig3_3_easgd.csv", opts.out_dir),
        &["round", "center_easgd"],
    )?;
    for i in 0..2000 {
        if i % 10 == 0 {
            csv2.row_f64(&[i as f64, s[3]])?;
        }
        s = map.matvec(&s);
    }
    println!(
        "fig3.3 shape: EASGD round-robin contracts (x̃ {:.2e}): {}",
        s[3],
        if s[3].abs() < 1.0 { "HOLDS" } else { "VIOLATED" }
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opts() -> FigOpts {
        FigOpts {
            out_dir: std::env::temp_dir()
                .join("et_fig_ch3")
                .to_string_lossy()
                .into_owned(),
            full: false,
            seed: 0,
            backend: crate::coordinator::Backend::Sim,
            model: crate::model::ModelKind::Mlp,
            threads: 1,
            simd: "auto".into(),
        }
    }

    #[test]
    fn fig3_2_and_3_3_run_quick() {
        fig3_2(&opts()).unwrap();
        fig3_3(&opts()).unwrap();
        let p = std::path::Path::new(&opts().out_dir).join("fig3_2.csv");
        let text = std::fs::read_to_string(p).unwrap();
        assert!(text.lines().count() > 24 * 24);
    }

    #[test]
    fn fig3_1_runs_quick() {
        fig3_1(&opts()).unwrap();
    }
}
