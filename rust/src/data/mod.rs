//! Synthetic datasets + the thesis' §4.1 parallel data-prefetch
//! pipeline.
//!
//! - [`markov`] — a next-token corpus with learnable k-gram structure
//!   (the transformer's training data in the end-to-end example).
//! - [`blobs`] — a "CIFAR-like" classification set: class-conditional
//!   gaussian clusters with controllable spread; the sweep figures'
//!   workload.
//! - [`prefetch`] — the §4.1 loader semantics: k data loaders each own
//!   a chunked "mmap file", serve consecutive chunks to whichever
//!   worker asks, cycle with a uniformly-random restart offset; workers
//!   gather k chunks, shuffle, and cut mini-batches.

pub mod blobs;
pub mod markov;
pub mod prefetch;

pub use blobs::BlobDataset;
pub use markov::MarkovCorpus;
pub use prefetch::{DataLoader, PrefetchPool, Sharding};
