//! Layer-3 coordinator: the thesis' distributed optimization methods.
//!
//! - [`oracle`] — the `GradOracle` abstraction (native MLP for sweeps,
//!   the deterministic quadratic for equivalence tests/benches; the
//!   PJRT transformer in `runtime` implements the same trait).
//! - [`method`] — every parallel method the thesis compares:
//!   EASGD / EAMSGD (Algorithms 1–2), DOWNPOUR (Alg. 3),
//!   MDOWNPOUR (Algs 4–5), ADOWNPOUR / MVADOWNPOUR, and async ADMM.
//! - [`executor`] — the `Executor` abstraction: one run contract, two
//!   backends (`SimExecutor` / `ThreadExecutor`), plus the shared
//!   config/worker/master state and `Backend` selection.
//! - [`driver`] — the virtual-time event-driven backend: per-worker
//!   virtual clocks, communication period τ, jittered compute,
//!   Table-4.4 accounting. Bitwise deterministic given the seed.
//! - [`threaded`] — the real-thread backend: one `std::thread` per
//!   worker, center variable behind a sharded lock, genuinely stale
//!   concurrent exchanges.
//! - [`sequential`] — the p = 1 baselines: SGD, MSGD, ASGD, MVASGD.
//! - [`tree`] — EASGD Tree (Alg. 6): d-ary topology, fully-async
//!   messaging, the two communication schemes of §6.1.
//! - [`gauss_seidel`] — §6.2: the Gauss–Seidel reformulation unifying
//!   EASGD and DOWNPOUR, with its stability map.

pub mod driver;
pub mod executor;
pub mod gauss_seidel;
pub mod method;
pub mod oracle;
pub mod sequential;
pub mod threaded;
pub mod tree;

pub use driver::{run_parallel, DriverConfig};
pub use executor::{
    run_with_backend, thread_supported, Backend, Executor, SimExecutor, ThreadExecutor,
};
pub use method::Method;
pub use oracle::{EvalStats, GradOracle, MlpOracle, QuadraticOracle};
pub use sequential::{run_sequential, SeqMethod};
pub use threaded::run_threaded;
pub use tree::{run_tree, TreeConfig, TreeScheme};
