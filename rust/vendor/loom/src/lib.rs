//! Vendored, dependency-free stand-in for the `loom` model checker.
//!
//! The real loom exhaustively enumerates thread interleavings; this
//! container builds offline, so this crate keeps loom's **API** while
//! backing it with instrumented `std::sync` primitives:
//!
//! - every lock acquisition and condvar notify bumps a global progress
//!   counter and may inject a randomized yield/short sleep (re-seeded
//!   per model iteration), shaking out interleavings that a quiet
//!   machine would never schedule;
//! - [`model`] runs the closure `LOOM_ITERS` times (default 32), each
//!   iteration on a fresh thread, under a watchdog that panics if no
//!   instrumented synchronization event happens for `LOOM_DEADLOCK_MS`
//!   (default 5000) — so deadlocks and **lost wakeups** fail loudly
//!   instead of hanging the test binary.
//!
//! This is a bounded stress-tester with deadlock detection, not an
//! exhaustive checker. The API is source-compatible with the subset of
//! loom this repo uses (`loom::model`, `loom::sync::{Mutex, Condvar,
//! RwLock, Arc, mpsc, atomic}`, `loom::thread`), so pointing the
//! `cfg(loom)` dependency at crates.io swaps the real engine in.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::PoisonError;
use std::time::{Duration, Instant};

/// Global count of instrumented synchronization events. The model
/// watchdog declares a deadlock when this stops advancing while the
/// model body is still running.
static EVENTS: AtomicU64 = AtomicU64::new(0);

/// Scheduling-perturbation RNG state (splitmix-style, lock-free).
static SEED: AtomicU64 = AtomicU64::new(0x9E3779B97F4A7C15);

/// One model body at a time: the watchdog reads the *global* event
/// counter, so concurrently-running models (cargo test's default
/// parallelism) would mask each other's stalls.
static MODEL_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

pub(crate) fn tick() {
    EVENTS.fetch_add(1, Ordering::Relaxed);
}

/// Maybe yield or briefly sleep, to perturb the schedule at a
/// synchronization point. Cheap (one atomic + a few ALU ops) when it
/// decides not to.
pub(crate) fn perturb() {
    let s = SEED.fetch_add(0x9E37_79B9_7F4A_7C15, Ordering::Relaxed);
    let mut x = s ^ (s >> 31);
    x = x.wrapping_mul(0xD6E8_FEB8_6659_FD93);
    x ^= x >> 32;
    match x % 61 {
        0..=3 => std::thread::yield_now(),
        4 => std::thread::sleep(Duration::from_micros((x >> 8) % 50)),
        _ => {}
    }
}

fn env_u64(key: &str, default: u64) -> u64 {
    match std::env::var(key) {
        Ok(v) => v
            .parse::<u64>()
            .unwrap_or_else(|_| panic!("{key} must be a non-negative integer, got '{v}'")),
        Err(_) => default,
    }
}

/// Run `f` repeatedly under schedule perturbation and a deadlock
/// watchdog. Panics (failing the enclosing test) if any iteration
/// panics, or if an iteration stops making synchronization progress
/// for `LOOM_DEADLOCK_MS` milliseconds — the signature of a deadlock
/// or a lost condvar wakeup.
pub fn model<F>(f: F)
where
    F: Fn() + Send + Sync + 'static,
{
    let _serial = MODEL_LOCK.lock().unwrap_or_else(PoisonError::into_inner);
    let iters = env_u64("LOOM_ITERS", 32).max(1);
    let deadlock = Duration::from_millis(env_u64("LOOM_DEADLOCK_MS", 5000).max(100));
    let f = std::sync::Arc::new(f);
    for iter in 0..iters {
        SEED.store(
            0x853C_49E6_748F_EA9B_u64.wrapping_mul(iter + 1),
            Ordering::Relaxed,
        );
        run_one(std::sync::Arc::clone(&f), iter, deadlock);
    }
}

fn run_one<F>(f: std::sync::Arc<F>, iter: u64, deadlock: Duration)
where
    F: Fn() + Send + Sync + 'static,
{
    // Each iteration gets a fresh thread so thread-local state inside
    // the model body (e.g. a per-thread pool) is rebuilt and torn down
    // every time — spawn and shutdown paths are part of the model.
    let body = std::thread::Builder::new()
        .name(format!("loom-model-{iter}"))
        .spawn(move || f())
        .expect("spawn loom model body");
    let mut last_events = EVENTS.load(Ordering::Relaxed);
    let mut last_change = Instant::now();
    while !body.is_finished() {
        std::thread::sleep(Duration::from_millis(1));
        let e = EVENTS.load(Ordering::Relaxed);
        if e != last_events {
            last_events = e;
            last_change = Instant::now();
        } else if last_change.elapsed() > deadlock {
            // The body (and whatever threads it spawned) is stuck; it
            // cannot be killed, but panicking here fails the test and
            // the harness exits the process regardless of leaked
            // threads.
            panic!(
                "loom (vendored): model iteration {iter} made no synchronization progress \
                 for {deadlock:?} — deadlock or lost wakeup"
            );
        }
    }
    if let Err(payload) = body.join() {
        std::panic::resume_unwind(payload);
    }
}

pub mod sync {
    //! Instrumented drop-ins for `std::sync`.
    //!
    //! [`Mutex`] and [`Condvar`] are thin newtype wrappers that bump the
    //! model's progress counter and inject schedule perturbation; their
    //! guards and poison semantics are exactly `std`'s (a guard dropped
    //! during unwind poisons the lock), so poison-recovery code paths
    //! behave identically under the model. Everything else re-exports
    //! `std` directly.

    use std::fmt;
    pub use std::sync::{
        Arc, LockResult, MutexGuard, PoisonError, RwLock, TryLockError, TryLockResult,
        WaitTimeoutResult,
    };

    pub mod atomic {
        pub use std::sync::atomic::*;
    }

    pub mod mpsc {
        pub use std::sync::mpsc::*;
    }

    /// `std::sync::Mutex` plus progress ticks and schedule perturbation
    /// on every acquisition. `const`-constructible (a superset of the
    /// real loom, whose `Mutex::new` is not const).
    pub struct Mutex<T: ?Sized> {
        inner: std::sync::Mutex<T>,
    }

    impl<T> Mutex<T> {
        pub const fn new(t: T) -> Self {
            Mutex {
                inner: std::sync::Mutex::new(t),
            }
        }

        pub fn into_inner(self) -> LockResult<T> {
            self.inner.into_inner()
        }
    }

    impl<T: ?Sized> Mutex<T> {
        pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
            crate::perturb();
            let r = self.inner.lock();
            crate::tick();
            r
        }

        pub fn try_lock(&self) -> TryLockResult<MutexGuard<'_, T>> {
            let r = self.inner.try_lock();
            if r.is_ok() {
                crate::tick();
            }
            r
        }

        pub fn get_mut(&mut self) -> LockResult<&mut T> {
            self.inner.get_mut()
        }
    }

    impl<T: Default> Default for Mutex<T> {
        fn default() -> Self {
            Mutex::new(T::default())
        }
    }

    impl<T: ?Sized + fmt::Debug> fmt::Debug for Mutex<T> {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            fmt::Debug::fmt(&self.inner, f)
        }
    }

    /// `std::sync::Condvar` plus progress ticks on notify (the
    /// productive side of a handoff; waits deliberately do not tick, so
    /// a waiter whose wakeup was lost reads as *no progress* to the
    /// model watchdog instead of masking the bug).
    pub struct Condvar {
        inner: std::sync::Condvar,
    }

    impl Condvar {
        pub const fn new() -> Self {
            Condvar {
                inner: std::sync::Condvar::new(),
            }
        }

        pub fn wait<'a, T>(&self, guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
            self.inner.wait(guard)
        }

        pub fn wait_timeout<'a, T>(
            &self,
            guard: MutexGuard<'a, T>,
            dur: std::time::Duration,
        ) -> LockResult<(MutexGuard<'a, T>, WaitTimeoutResult)> {
            self.inner.wait_timeout(guard, dur)
        }

        pub fn notify_one(&self) {
            crate::perturb();
            crate::tick();
            self.inner.notify_one();
        }

        pub fn notify_all(&self) {
            crate::perturb();
            crate::tick();
            self.inner.notify_all();
        }
    }

    impl Default for Condvar {
        fn default() -> Self {
            Condvar::new()
        }
    }

    impl fmt::Debug for Condvar {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            fmt::Debug::fmt(&self.inner, f)
        }
    }
}

pub mod thread {
    //! Re-export of `std::thread`: the vendored engine perturbs
    //! schedules at synchronization points rather than wrapping spawn.
    pub use std::thread::*;
}

pub mod hint {
    pub use std::hint::*;
}
