//! Property-based tests over coordinator/numeric invariants, driven by
//! the crate's own deterministic RNG (the offline crate set has no
//! proptest — DESIGN.md §2). Each property samples many random cases;
//! failures print the offending case.

use elastic_train::coordinator::gauss_seidel;
use elastic_train::data::prefetch::{PrefetchPool, Sharding};
use elastic_train::linalg::{eigenvalues, spectral_radius, Matrix};
use elastic_train::model::flat;
use elastic_train::rng::Rng;
use elastic_train::sim::{admm, moments};

const CASES: usize = 60;

fn rand_vec(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    let mut v = vec![0.0f32; n];
    rng.fill_gaussian_f32(&mut v, scale);
    v
}

/// Elastic exchange conserves x + c (up to f32 rounding) and is a
/// contraction of |x − c| for any α ∈ (0, 1).
#[test]
fn prop_elastic_exchange_conserving_contraction() {
    let mut rng = Rng::new(101);
    for case in 0..CASES {
        let n = 1 + rng.below(3000);
        let alpha = rng.uniform_in(0.01, 0.99) as f32;
        let mut x = rand_vec(&mut rng, n, 2.0);
        let mut c = rand_vec(&mut rng, n, 2.0);
        let sum_before: Vec<f32> = x.iter().zip(&c).map(|(a, b)| a + b).collect();
        let gap_before = flat::dist2(&x, &c);
        flat::elastic_exchange(&mut x, &mut c, alpha);
        let gap_after = flat::dist2(&x, &c);
        assert!(
            gap_after <= gap_before * (1.0 + 1e-6),
            "case {case}: gap grew {gap_before} -> {gap_after} (α={alpha})"
        );
        for i in 0..n {
            let s = x[i] + c[i];
            assert!(
                (s - sum_before[i]).abs() <= 2e-5 * sum_before[i].abs().max(1.0),
                "case {case}: sum drift at {i}"
            );
        }
    }
}

/// Nesterov with δ = 0 equals plain SGD for arbitrary inputs.
#[test]
fn prop_nesterov_zero_momentum_is_sgd() {
    let mut rng = Rng::new(102);
    for _ in 0..CASES {
        let n = 1 + rng.below(2000);
        let eta = rng.uniform_in(0.0, 1.0) as f32;
        let mut x1 = rand_vec(&mut rng, n, 1.0);
        let g = rand_vec(&mut rng, n, 1.0);
        let mut x2 = x1.clone();
        let mut v = vec![0.0f32; n];
        flat::sgd_step(&mut x1, &g, eta);
        flat::nesterov_step(&mut x2, &mut v, &g, eta, 0.0);
        assert_eq!(x1, x2);
    }
}

/// moving_average keeps every coordinate inside [min(c,x), max(c,x)]
/// for α ∈ [0, 1].
#[test]
fn prop_moving_average_stays_in_hull() {
    let mut rng = Rng::new(103);
    for _ in 0..CASES {
        let n = 1 + rng.below(500);
        let a = rng.uniform() as f32;
        let mut c = rand_vec(&mut rng, n, 3.0);
        let x = rand_vec(&mut rng, n, 3.0);
        let c0 = c.clone();
        flat::moving_average(&mut c, &x, a);
        for i in 0..n {
            let lo = c0[i].min(x[i]) - 1e-5;
            let hi = c0[i].max(x[i]) + 1e-5;
            assert!(c[i] >= lo && c[i] <= hi, "escaped hull at {i}");
        }
    }
}

/// Eigenvalues of random REAL SYMMETRIC matrices are real, and the sum
/// matches the trace.
#[test]
fn prop_symmetric_eigenvalues_are_real() {
    let mut rng = Rng::new(104);
    for case in 0..30 {
        let n = 2 + rng.below(9);
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let v = rng.normal(0.0, 1.0);
                m.set(i, j, v);
                m.set(j, i, v);
            }
        }
        let eigs = eigenvalues(&m);
        assert_eq!(eigs.len(), n);
        let trace: f64 = (0..n).map(|i| m.get(i, i)).sum();
        let sum: f64 = eigs.iter().map(|z| z.re).sum();
        for z in &eigs {
            assert!(z.im.abs() < 1e-6, "case {case}: complex eig {z:?}");
        }
        assert!((sum - trace).abs() < 1e-6 * (1.0 + trace.abs()));
    }
}

/// Row-stochastic matrices have spectral radius 1.
#[test]
fn prop_stochastic_matrix_spectral_radius_one() {
    let mut rng = Rng::new(105);
    for _ in 0..30 {
        let n = 2 + rng.below(8);
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            let mut row: Vec<f64> = (0..n).map(|_| rng.uniform() + 1e-3).collect();
            let s: f64 = row.iter().sum();
            for v in &mut row {
                *v /= s;
            }
            for (j, v) in row.iter().enumerate() {
                m.set(i, j, *v);
            }
        }
        let sp = spectral_radius(&m);
        assert!((sp - 1.0).abs() < 1e-7, "sp {sp}");
    }
}

/// The closed-form stability region of round-robin EASGD matches the
/// computed spectrum exactly at p = 1 for random (η, α).
#[test]
fn prop_easgd_rr_condition_exact_at_p1() {
    let mut rng = Rng::new(106);
    for _ in 0..200 {
        let eta = rng.uniform_in(0.0, 2.5);
        let alpha = rng.uniform_in(0.0, 1.2);
        let sp = spectral_radius(&admm::easgd_round_robin_map(1, eta, alpha));
        if admm::easgd_rr_stable(eta, alpha) {
            assert!(sp <= 1.0 + 1e-7, "η={eta} α={alpha}: sp={sp}");
        } else {
            assert!(sp >= 1.0 - 1e-7, "η={eta} α={alpha}: sp={sp}");
        }
    }
}

/// Lemma 3.1.1's γ, φ always satisfy the defining quadratic and the
/// ordering φ ≤ γ for random valid hyper-parameters.
#[test]
fn prop_gamma_phi_root_identity() {
    let mut rng = Rng::new(107);
    for _ in 0..300 {
        let eta = rng.uniform_in(1e-4, 1.5);
        let p = 1 + rng.below(64);
        let alpha = rng.uniform_in(1e-5, 1.0 / p as f64);
        let h = rng.uniform_in(0.1, 2.0);
        let (g, f) = moments::gamma_phi(eta, alpha, h, p);
        let a = eta * h + (p as f64 + 1.0) * alpha;
        let c2 = eta * h * p as f64 * alpha;
        for z in [g, f] {
            let r = z * z - (2.0 - a) * z + (1.0 - a + c2);
            assert!(r.abs() < 1e-9, "root residual {r}");
        }
        assert!(f <= g + 1e-12);
    }
}

/// Gauss–Seidel drift at (a, b) = (α, β) and the Jacobi drift agree in
/// the stable/unstable classification for random small rates.
#[test]
fn prop_gs_and_jacobi_agree_on_stability_at_small_rates() {
    let mut rng = Rng::new(108);
    for _ in 0..100 {
        let p = 2 + rng.below(15);
        let eta_h = rng.uniform_in(0.01, 0.4);
        let beta = rng.uniform_in(0.05, 0.5);
        let alpha = beta / p as f64;
        let gs = gauss_seidel::spectral(eta_h, alpha, beta, p);
        let jac = spectral_radius(&moments::easgd_drift_matrix(eta_h, alpha, beta, p));
        assert_eq!(
            gs < 1.0,
            jac < 1.0,
            "classification split at η_h={eta_h} β={beta} p={p}: gs={gs} jac={jac}"
        );
    }
}

/// Prefetch pipeline: for random loader/chunk/batch geometry, fetched
/// mini-batches contain only valid indices and are full-size.
#[test]
fn prop_prefetch_minibatches_well_formed() {
    let mut rng = Rng::new(109);
    for _ in 0..40 {
        let n = 64 + rng.below(2000);
        let k = 1 + rng.below(8);
        let batch = 8 + rng.below(64);
        let chunk = batch * (1 + rng.below(4));
        let mode = if rng.below(2) == 0 { Sharding::Replicated } else { Sharding::Partitioned };
        let mut pool = PrefetchPool::new(n, k, chunk, batch, mode, rng.next_u64());
        let mut prng = Rng::new(rng.next_u64());
        for _ in 0..3 {
            for mb in pool.fetch_minibatches(&mut prng) {
                assert_eq!(mb.len(), batch);
                assert!(mb.iter().all(|&i| i < n));
            }
        }
    }
}

/// Gamma sampling: mean/variance track (λ, ω) for random parameters.
#[test]
fn prop_gamma_moments_random_params() {
    let mut rng = Rng::new(110);
    for _ in 0..10 {
        let shape = rng.uniform_in(0.2, 20.0);
        let rate = rng.uniform_in(0.2, 20.0);
        let n = 60_000;
        let (mut m1, mut m2) = (0.0, 0.0);
        for _ in 0..n {
            let g = rng.gamma(shape, rate);
            m1 += g;
            m2 += g * g;
        }
        m1 /= n as f64;
        m2 = m2 / n as f64 - m1 * m1;
        let mean = shape / rate;
        let var = shape / (rate * rate);
        assert!((m1 - mean).abs() < 0.1 * mean.max(0.1), "mean {m1} vs {mean}");
        assert!((m2 - var).abs() < 0.15 * var.max(0.1), "var {m2} vs {var}");
    }
}

/// JSON parser: print → parse roundtrip over random structured values.
#[test]
fn prop_json_roundtrip_random_documents() {
    use elastic_train::config::Json;
    let mut rng = Rng::new(111);

    fn gen(rng: &mut Rng, depth: usize) -> (String, usize) {
        if depth == 0 || rng.below(3) == 0 {
            match rng.below(3) {
                0 => (format!("{}", rng.below(100000)), 0),
                1 => (format!("{:.4}", rng.uniform_in(-50.0, 50.0)), 0),
                _ => (format!("\"s{}\"", rng.below(1000)), 0),
            }
        } else if rng.below(2) == 0 {
            let n = 1 + rng.below(4);
            let items: Vec<String> = (0..n).map(|_| gen(rng, depth - 1).0).collect();
            (format!("[{}]", items.join(",")), n)
        } else {
            let n = 1 + rng.below(4);
            let items: Vec<String> = (0..n)
                .map(|i| format!("\"k{i}\":{}", gen(rng, depth - 1).0))
                .collect();
            (format!("{{{}}}", items.join(",")), n)
        }
    }

    for _ in 0..100 {
        let (doc, _) = gen(&mut rng, 3);
        let parsed = Json::parse(&doc);
        assert!(parsed.is_ok(), "failed to parse generated doc: {doc}");
    }
}
