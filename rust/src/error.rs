//! Minimal error-handling substrate (the offline crate set has no
//! `anyhow` — DESIGN.md §2): a string-backed [`Error`], a [`Result`]
//! alias, the [`Context`] extension trait, and the crate-level `err!` /
//! `bail!` macros.
//!
//! [`Error`] deliberately does NOT implement `std::error::Error`: that
//! is what lets the blanket `From` below absorb every std error type
//! through `?` without colliding with the reflexive `From<T> for T`
//! impl (the same trick `anyhow` uses).

use std::fmt;

/// A string-backed error with the context chain folded into the
/// message.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything stringly.
    pub fn msg(m: impl Into<String>) -> Error {
        Error { msg: m.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(..)` / `.with_context(..)` on any result whose error
/// displays — prepends the context to the message.
pub trait Context<T> {
    fn context(self, msg: &str) -> Result<T>;
    fn with_context<F: FnOnce() -> String>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: &str) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{msg}: {e}")))
    }

    fn with_context<F: FnOnce() -> String>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

/// Ad-hoc error constructor with `format!` syntax (anyhow's `anyhow!`).
#[macro_export]
macro_rules! err {
    ($($t:tt)*) => { $crate::error::Error::msg(format!($($t)*)) };
}

/// Early-return with an ad-hoc error (anyhow's `bail!`).
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => { return Err($crate::err!($($t)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/nonexistent/elastic_train_test")?;
        Ok(s)
    }

    #[test]
    fn question_mark_absorbs_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!format!("{e}").is_empty());
    }

    #[test]
    fn context_prepends() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.with_context(|| "doing the thing".to_string()).unwrap_err();
        assert!(format!("{e}").starts_with("doing the thing: "));
        let r2: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e2 = r2.context("ctx").unwrap_err();
        assert!(format!("{e2:#}").starts_with("ctx: "));
    }

    #[test]
    fn macros_format() {
        let e = err!("bad value {}", 42);
        assert_eq!(format!("{e}"), "bad value 42");
        fn f() -> Result<()> {
            bail!("nope: {}", "reason")
        }
        assert_eq!(format!("{}", f().unwrap_err()), "nope: reason");
    }
}
