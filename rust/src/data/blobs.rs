//! "CIFAR-like" synthetic classification data: class-conditional
//! gaussian clusters with controllable intra-class spread and a fixed
//! train/test split. Non-trivially separable (cluster overlap) so test
//! error curves behave like the thesis' CIFAR plots: fast early
//! progress, then a regime where regularization/averaging decide the
//! final error.

use crate::rng::Rng;

/// A fixed dataset of (x, label) pairs with held-out test data.
pub struct BlobDataset {
    pub dim: usize,
    pub classes: usize,
    pub train: Vec<(Vec<f32>, usize)>,
    pub test: Vec<(Vec<f32>, usize)>,
}

impl BlobDataset {
    /// `spread` ≥ ~1.0 creates heavy class overlap (irreducible error).
    pub fn generate(
        dim: usize,
        classes: usize,
        n_train: usize,
        n_test: usize,
        spread: f64,
        seed: u64,
    ) -> Self {
        let mut rng = Rng::new(seed);
        // Class centers on a loose random simplex, with an anisotropic
        // per-dimension scale (log-uniform over ~1.5 decades): natural
        // image features are strongly anisotropic, and this is what
        // makes momentum methods earn their keep on the sweeps.
        let scales: Vec<f32> = (0..dim)
            .map(|_| 10f64.powf(rng.uniform_in(-1.0, 0.5)) as f32)
            .collect();
        let centers: Vec<Vec<f32>> = (0..classes)
            .map(|_| {
                (0..dim)
                    .map(|j| rng.normal(0.0, 1.0) as f32 * scales[j])
                    .collect()
            })
            .collect();
        let mut gen = |n: usize, rng: &mut Rng| {
            (0..n)
                .map(|_| {
                    let y = rng.below(classes);
                    let x = centers[y]
                        .iter()
                        .zip(&scales)
                        .map(|(c, s)| c + rng.normal(0.0, spread) as f32 * s)
                        .collect();
                    (x, y)
                })
                .collect::<Vec<_>>()
        };
        let train = gen(n_train, &mut rng);
        let test = gen(n_test, &mut rng);
        Self { dim, classes, train, test }
    }

    /// The sweep default matching `MlpConfig::sweep_default`.
    pub fn sweep_default(seed: u64) -> Self {
        Self::generate(32, 10, 4096, 1024, 1.0, seed)
    }

    /// Random mini-batch of index references.
    pub fn sample_batch<'a>(
        &'a self,
        batch: usize,
        rng: &mut Rng,
    ) -> Vec<&'a (Vec<f32>, usize)> {
        (0..batch).map(|_| &self.train[rng.below(self.train.len())]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Mlp, MlpConfig};

    #[test]
    fn shapes_and_label_range() {
        let d = BlobDataset::generate(8, 4, 100, 50, 0.5, 1);
        assert_eq!(d.train.len(), 100);
        assert_eq!(d.test.len(), 50);
        assert!(d.train.iter().all(|(x, y)| x.len() == 8 && *y < 4));
    }

    #[test]
    fn deterministic() {
        let a = BlobDataset::generate(8, 4, 50, 10, 0.5, 3);
        let b = BlobDataset::generate(8, 4, 50, 10, 0.5, 3);
        assert_eq!(a.train[0].0, b.train[0].0);
    }

    #[test]
    fn learnable_but_not_trivial() {
        // An MLP should beat chance comfortably but not reach 100% at
        // spread 1.0 (class overlap) — the regime the sweeps need.
        let d = BlobDataset::generate(16, 4, 2000, 500, 1.0, 5);
        let cfg = MlpConfig::new(&[16, 32, 4], 0.0);
        let mut mlp = Mlp::new(cfg);
        let mut rng = Rng::new(11);
        let mut theta = mlp.init_params(&mut rng);
        let mut g = vec![0.0; theta.len()];
        for _ in 0..300 {
            let batch: Vec<(Vec<f32>, usize)> = d
                .sample_batch(32, &mut rng)
                .into_iter()
                .cloned()
                .collect();
            mlp.batch_grad(&theta, &batch, &mut g);
            crate::model::flat::sgd_step(&mut theta, &g, 0.1);
        }
        let acc = d
            .test
            .iter()
            .filter(|(x, y)| mlp.predict(&theta, x) == *y)
            .count() as f64
            / d.test.len() as f64;
        assert!(acc > 0.5, "test acc {acc} should beat chance 0.25");
        assert!(acc < 0.999, "test acc {acc} should not be trivial");
    }
}
