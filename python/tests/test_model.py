"""L2 model correctness: shapes, determinism, trainability, spec table."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.ModelConfig(vocab=64, d_model=32, n_layers=2, n_heads=2,
                    seq_len=32, batch=4, weight_decay=0.0)


def _batch(rng, cfg):
    x = rng.integers(0, cfg.vocab, size=(cfg.batch, cfg.seq_len))
    y = np.roll(x, -1, axis=1)
    return jnp.asarray(x, jnp.int32), jnp.asarray(y, jnp.int32)


def test_param_specs_deterministic_and_consistent():
    s1, s2 = M.param_specs(CFG), M.param_specs(CFG)
    assert s1 == s2
    assert len(set(n for n, _ in s1)) == len(s1)
    total = sum(int(np.prod(s)) for _, s in s1)
    assert total == M.param_count(CFG)
    flat = np.concatenate([np.asarray(p).ravel()
                           for p in M.init_params(CFG)])
    assert flat.size == total
    assert np.all(np.isfinite(flat))


def test_forward_shapes_and_loss_near_uniform_at_init():
    params = M.init_params(CFG, seed=1)
    rng = np.random.default_rng(0)
    x, y = _batch(rng, CFG)
    logits = M.forward(CFG, params, x)
    assert logits.shape == (CFG.batch, CFG.seq_len, CFG.vocab)
    loss = M.loss_fn(CFG, params, x, y)
    # At init the LM should be within ~1 nat of uniform.
    assert abs(float(loss) - np.log(CFG.vocab)) < 1.0


def test_train_step_returns_loss_and_grads_for_every_param():
    params = M.init_params(CFG, seed=2)
    rng = np.random.default_rng(1)
    x, y = _batch(rng, CFG)
    out = M.train_step(CFG, params, x, y)
    assert len(out) == 1 + len(params)
    assert out[0].shape == ()
    for g, p in zip(out[1:], params):
        assert g.shape == p.shape
        assert bool(jnp.all(jnp.isfinite(g)))


def test_gradient_matches_finite_difference():
    cfg = M.ModelConfig(vocab=16, d_model=16, n_layers=1, n_heads=2,
                        seq_len=32, batch=2, weight_decay=0.0)
    params = M.init_params(cfg, seed=3)
    rng = np.random.default_rng(2)
    x, y = _batch(rng, cfg)
    out = M.train_step(cfg, params, x, y)
    g_lnf = np.asarray(out[1 + [n for n, _ in M.param_specs(cfg)]
                           .index("lnf_scale")])
    idx, eps = 3, 1e-3
    i = [n for n, _ in M.param_specs(cfg)].index("lnf_scale")

    def loss_with(v):
        ps = list(params)
        ps[i] = ps[i].at[idx].set(v)
        return float(M.loss_fn(cfg, ps, x, y))

    v0 = float(params[i][idx])
    fd = (loss_with(v0 + eps) - loss_with(v0 - eps)) / (2 * eps)
    assert abs(fd - g_lnf[idx]) < 5e-3 * max(1.0, abs(fd))


def test_sgd_training_reduces_loss():
    """A few full-batch SGD steps on a fixed batch must reduce the loss —
    the minimal 'this model can learn' signal."""
    params = M.init_params(CFG, seed=4)
    rng = np.random.default_rng(3)
    x, y = _batch(rng, CFG)
    loss0 = float(M.loss_fn(CFG, params, x, y))
    step = jax.jit(lambda ps: M.train_step(CFG, ps, x, y))
    for _ in range(20):
        out = step(params)
        params = [p - 0.5 * g for p, g in zip(params, out[1:])]
    loss1 = float(M.loss_fn(CFG, params, x, y))
    assert loss1 < loss0 - 0.5, (loss0, loss1)


def test_eval_step_counts_correct_tokens():
    params = M.init_params(CFG, seed=5)
    rng = np.random.default_rng(4)
    x, y = _batch(rng, CFG)
    nll, correct = M.eval_step(CFG, params, x, y)
    assert 0 <= int(correct) <= CFG.batch * CFG.seq_len
    assert float(nll) > 0


def test_weight_decay_increases_loss():
    p = M.init_params(CFG, seed=6)
    rng = np.random.default_rng(5)
    x, y = _batch(rng, CFG)
    l0 = float(M.loss_fn(CFG, p, x, y))
    cfg_wd = M.ModelConfig(**{**CFG.__dict__, "weight_decay": 1e-2})
    l1 = float(M.loss_fn(cfg_wd, p, x, y))
    assert l1 > l0


@pytest.mark.parametrize("preset", sorted(M.PRESETS))
def test_presets_are_well_formed(preset):
    cfg = M.PRESETS[preset]
    assert cfg.d_model % cfg.n_heads == 0
    assert cfg.seq_len % 32 == 0  # attention kernel BQ divisibility
    assert M.param_count(cfg) > 0
