//! Real wall-clock scaling of the thread backend: worker-steps/sec vs
//! worker count p ∈ {1, 2, 4, 8} and communication period
//! τ ∈ {1, 4, 16, 64}, EASGD on the deterministic quadratic oracle
//! (gradient cost is a pure n-element stream, so the grid measures the
//! executor — thread scheduling + sharded-lock center — not the model),
//! plus a master-actor grid for the master-coupled methods (MDOWNPOUR,
//! async ADMM), where every round is a serialized channel round trip
//! through the dedicated master thread, plus a hybrid p × c grid
//! (p workers × c GEMM threads each, EASGD on the real sweep-MLP
//! oracle) measuring how the data-parallel and intra-worker
//! tensor-parallel axes compose.
//!
//!     cargo bench --bench bench_threaded            # full grid
//!     cargo bench --bench bench_threaded -- --quick # smoke (CI)
//!
//! Expected shape: steps/sec grows with p while p ≤ cores and the
//! exchange is infrequent (τ ≥ 16); at τ = 1 every step locks every
//! shard, so scaling flattens — the thesis' communication-period story
//! measured on real threads. The τ=16 column prints a monotonicity
//! verdict (5% slack; oversubscribed p > cores legitimately plateaus).
//! The master-actor rows are expected to flatten earlier: MDOWNPOUR
//! serializes one master update per worker step by construction.

use elastic_train::cluster::CostModel;
use elastic_train::coordinator::{run_threaded, DriverConfig, Method, MlpOracle, QuadraticOracle};
use elastic_train::figures::benchkit::{append_history, git_sha, unix_time};
use elastic_train::figures::ch4;
use elastic_train::linalg::pool;
use std::time::Instant;

/// Per-step gradient size: big enough that one step (~tens of µs)
/// dwarfs scheduling overhead, small enough for a quick grid.
const N_PARAMS: usize = 65_536;

fn steps_per_sec(method: Method, eta: f32, p: usize, total_steps: u64) -> f64 {
    let mut oracles = QuadraticOracle::family(N_PARAMS, 1.0, 0.0, 1.0, 0.0, p);
    let cfg = DriverConfig {
        eta,
        method,
        cost: CostModel::cifar_like(N_PARAMS), // unused by the thread backend
        horizon: 120.0,                        // real-seconds safety net
        eval_every: 1e6,                       // no mid-run snapshots
        seed: 9,
        max_steps: total_steps,
        lr_decay_gamma: 0.0,
    };
    let t0 = Instant::now();
    let r = run_threaded(&mut oracles, &cfg, 16).expect("bench run");
    assert!(!r.diverged, "{} p={p} diverged", method.name());
    assert_eq!(r.total_steps, total_steps);
    r.total_steps as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick" || a == "quick");
    let steps: u64 = if quick { 4_000 } else { 20_000 };
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    println!(
        "thread backend scaling: EASGD on quadratic(n={N_PARAMS}), {steps} steps/cell, \
         {cores} cores\n"
    );
    println!("{:>6} {:>4} {:>14} {:>10}", "tau", "p", "steps/sec", "vs p=1");

    let mut rows: Vec<String> = Vec::new();
    let mut tau16: Vec<(usize, f64)> = Vec::new();
    for &tau in &[1u32, 4, 16, 64] {
        let mut base = 0.0f64;
        for &p in &[1usize, 2, 4, 8] {
            let method = Method::easgd_default(p, tau);
            // Warm-up pass keeps first-touch page faults out of the cell.
            if p == 1 {
                let _ = steps_per_sec(method, 0.05, 1, steps / 4);
            }
            let rate = steps_per_sec(method, 0.05, p, steps);
            if p == 1 {
                base = rate;
            }
            println!("{tau:>6} {p:>4} {rate:>14.0} {:>9.2}x", rate / base);
            rows.push(format!(
                "      {{\"method\": \"easgd\", \"tau\": {tau}, \"p\": {p}, \"steps_per_sec\": {rate:.1}}}"
            ));
            if tau == 16 {
                tau16.push((p, rate));
            }
        }
        println!();
    }

    // The master-actor methods: every round is a serialized channel
    // round trip through the dedicated master thread (MDOWNPOUR pushes
    // each gradient, τ = 1 by definition; ADMM pushes its contribution
    // every τ steps).
    println!(
        "master-actor methods (serialized center), {steps} steps/cell:\n\n\
         {:>14} {:>4} {:>14} {:>10}",
        "method", "p", "steps/sec", "vs p=1"
    );
    for (name, method, eta) in [
        ("MDOWNPOUR", Method::MDownpour { delta: 0.9 }, 0.005f32),
        ("ADMM(tau=4)", Method::AdmmAsync { rho: 1.0, tau: 4 }, 0.05),
    ] {
        let mut base = 0.0f64;
        for &p in &[1usize, 2, 4, 8] {
            if p == 1 {
                let _ = steps_per_sec(method, eta, 1, steps / 4);
            }
            let rate = steps_per_sec(method, eta, p, steps);
            if p == 1 {
                base = rate;
            }
            println!("{name:>14} {p:>4} {rate:>14.0} {:>9.2}x", rate / base);
            rows.push(format!(
                "      {{\"method\": \"{name}\", \"p\": {p}, \"steps_per_sec\": {rate:.1}}}"
            ));
        }
        println!();
    }

    // ---- Hybrid p × c grid: EASGD on the real GEMM MLP oracle (the
    // quadratic's gradient is one streamed axpy — nothing for a GEMM
    // pool to split), p workers each running their local steps on c
    // GEMM threads. The per-cell clamp mirrors the train CLI: a p × c
    // product over the visible cores is pulled back with the
    // hybrid-oversubscription warning rather than thrashing.
    let hybrid_steps: u64 = if quick { 400 } else { 2_000 };
    let mlp_cfg = ch4::sweep_mlp();
    let mlp_data = ch4::sweep_data(3);
    println!(
        "hybrid grid: EASGD τ=16 on the sweep MLP (batch=128), {hybrid_steps} steps/cell, \
         p workers × c GEMM threads:\n\n{:>4} {:>8} {:>14} {:>10}",
        "p", "threads", "steps/sec", "vs c=1"
    );
    for &p in &[1usize, 2, 4, 8] {
        let mut base = 0.0f64;
        for &c in &[1usize, 2, 4] {
            let eff = pool::clamp_oversubscription(c, p);
            pool::configure_threads(eff);
            let mut oracles = MlpOracle::family(mlp_data.clone(), &mlp_cfg, 128, p);
            let cfg = DriverConfig {
                eta: 0.05,
                method: Method::easgd_default(p, 16),
                cost: CostModel::cifar_like(mlp_cfg.n_params()), // unused by the thread backend
                horizon: 120.0,
                eval_every: 1e6,
                seed: 9,
                max_steps: hybrid_steps,
                lr_decay_gamma: 0.0,
            };
            let t0 = Instant::now();
            let r = run_threaded(&mut oracles, &cfg, 16).expect("hybrid bench run");
            assert!(!r.diverged, "hybrid p={p} c={c} diverged");
            let rate = r.total_steps as f64 / t0.elapsed().as_secs_f64();
            if c == 1 {
                base = rate;
            }
            println!("{p:>4} {eff:>8} {rate:>14.0} {:>9.2}x", rate / base);
            rows.push(format!(
                "      {{\"grid\": \"hybrid\", \"model\": \"mlp\", \"p\": {p}, \"threads\": {eff}, \
                 \"steps_per_sec\": {rate:.1}}}"
            ));
        }
        println!();
    }
    pool::configure_threads(1);

    // Acceptance shape: at τ=16 steps/sec is monotone non-degrading
    // from p=1 to p=4 (5% slack for scheduler noise).
    let upto4: Vec<&(usize, f64)> = tau16.iter().filter(|(p, _)| *p <= 4).collect();
    let monotone = upto4.windows(2).all(|w| w[1].1 >= w[0].1 * 0.95);
    println!(
        "tau=16 scaling p=1->4: {} ({})",
        if monotone { "MONOTONE" } else { "NOT MONOTONE" },
        upto4
            .iter()
            .map(|(p, r)| format!("p{p}={r:.0}"))
            .collect::<Vec<_>>()
            .join(" "),
    );
    if cores < 4 {
        println!("(only {cores} cores visible — scaling beyond p={cores} plateaus by design)");
    }

    // Per-PR history, keyed by git SHA like BENCH_oracle.json.
    let entry = format!(
        "  {{\n    \"bench\": \"threaded\",\n    \"sha\": \"{}\",\n    \"unix_time\": {},\n    \
         \"quick\": {},\n    \"cores\": {},\n    \"p_grid\": [1, 2, 4, 8],\n    \
         \"threads_grid\": [1, 2, 4],\n    \"unit\": \"steps_per_sec\",\n    \
         \"results\": [\n{}\n    ]\n  }}",
        git_sha(),
        unix_time(),
        quick,
        cores,
        rows.join(",\n")
    );
    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_threaded.json");
    append_history(out, &entry);
    println!("appended history entry to {out}");
}
