//! Tiny CSV writer for the figure outputs.

use crate::error::Result;
use std::io::Write;
use std::path::Path;

/// A CSV file under construction.
pub struct Csv {
    w: std::io::BufWriter<std::fs::File>,
    cols: usize,
}

impl Csv {
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> Result<Csv> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let f = std::fs::File::create(path)?;
        let mut w = std::io::BufWriter::new(f);
        writeln!(w, "{}", header.join(","))?;
        Ok(Csv { w, cols: header.len() })
    }

    pub fn row(&mut self, fields: &[String]) -> Result<()> {
        // A real error, not a debug assert: release-built figure
        // binaries used to silently emit ragged rows on a column-count
        // mismatch, corrupting the CSV for every downstream plot.
        if fields.len() != self.cols {
            return Err(crate::err!(
                "csv row has {} fields but the header declared {} columns",
                fields.len(),
                self.cols
            ));
        }
        writeln!(self.w, "{}", fields.join(","))?;
        Ok(())
    }

    pub fn row_f64(&mut self, fields: &[f64]) -> Result<()> {
        let s: Vec<String> = fields.iter().map(|x| format!("{x}")).collect();
        self.row(&s)
    }
}

/// Format helper: stringify mixed rows tersely.
#[macro_export]
macro_rules! csv_row {
    ($csv:expr, $($v:expr),+ $(,)?) => {
        $csv.row(&[$(format!("{}", $v)),+])
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let dir = std::env::temp_dir().join("et_csv_test");
        let path = dir.join("t.csv");
        {
            let mut c = Csv::create(&path, &["a", "b"]).unwrap();
            csv_row!(c, 1, "x").unwrap();
            c.row_f64(&[2.5, 3.5]).unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,x\n2.5,3.5\n");
    }

    /// Regression: a ragged row is a hard `Err` in every build profile
    /// (it was a `debug_assert!`, so release figure binaries silently
    /// wrote corrupt CSV).
    #[test]
    fn ragged_rows_are_rejected_with_an_error() {
        let dir = std::env::temp_dir().join("et_csv_ragged_test");
        let path = dir.join("t.csv");
        let mut c = Csv::create(&path, &["a", "b", "c"]).unwrap();
        let e = c.row(&["1".into(), "2".into()]).unwrap_err();
        assert!(format!("{e}").contains("2 fields"), "{e}");
        assert!(format!("{e}").contains("3 columns"), "{e}");
        let e = csv_row!(c, 1, 2, 3, 4).unwrap_err();
        assert!(format!("{e}").contains("4 fields"), "{e}");
        // Well-formed rows still go through afterwards.
        c.row_f64(&[1.0, 2.0, 3.0]).unwrap();
    }
}
