//! Criterion-style micro-benchmark harness (the offline crate set has
//! no criterion; `cargo bench` runs our `harness = false` binaries,
//! which use this module). Reports median + MAD over timed batches and
//! prints rows `cargo bench`-style.

use std::time::Instant;

/// One benchmark measurement.
#[derive(Clone, Copy, Debug)]
pub struct Sample {
    pub median_ns: f64,
    pub mad_ns: f64,
    pub iters: u64,
}

impl Sample {
    pub fn throughput(&self, items: f64) -> f64 {
        items / (self.median_ns * 1e-9)
    }
}

/// Time `f` adaptively: calibrate iterations to ~`target_ms` per batch,
/// run `batches` batches, report median/MAD of per-iteration time.
pub fn bench<F: FnMut()>(name: &str, target_ms: f64, batches: usize, mut f: F) -> Sample {
    // Calibrate.
    let mut iters = 1u64;
    loop {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        let el = t0.elapsed().as_secs_f64() * 1e3;
        if el >= target_ms || iters >= 1 << 30 {
            break;
        }
        let scale = (target_ms / el.max(1e-6)).clamp(1.5, 100.0);
        iters = ((iters as f64) * scale).ceil() as u64;
    }
    // Measure.
    let mut per_iter: Vec<f64> = (0..batches.max(3))
        .map(|_| {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            t0.elapsed().as_nanos() as f64 / iters as f64
        })
        .collect();
    per_iter.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = per_iter[per_iter.len() / 2];
    let mut dev: Vec<f64> = per_iter.iter().map(|x| (x - median).abs()).collect();
    dev.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mad = dev[dev.len() / 2];
    let s = Sample { median_ns: median, mad_ns: mad, iters };
    println!(
        "bench {name:<44} {:>12.1} ns/iter (± {:.1}) x{}",
        s.median_ns, s.mad_ns, s.iters
    );
    s
}

/// Pretty time for summaries.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.1} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something_sane() {
        let mut acc = 0u64;
        let s = bench("noop-ish", 2.0, 3, || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert!(s.median_ns > 0.0 && s.median_ns < 1e6);
        assert!(s.iters >= 1);
    }

    #[test]
    fn fmt_ns_ranges() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert!(fmt_ns(5_000.0).ends_with("µs"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with(" s"));
    }
}
