//! Loom models of the repo's three hand-rolled synchronization
//! protocols. This file is EMPTY under a normal build (the `#![cfg]`
//! below); compile and run it with
//!
//! ```text
//! RUSTFLAGS="--cfg loom" cargo test --test loom_models
//! ```
//!
//! Under `--cfg loom` the whole crate's [`elastic_train::sync`] shim
//! re-exports the vendored loom engine (`rust/vendor/loom`): every
//! `lock` and `notify` perturbs the schedule (seeded yields and short
//! sleeps) and ticks a global progress counter, and `loom::model`
//! reruns each closure `LOOM_ITERS` times (default 32) under a
//! watchdog that fails the iteration when the body stops making
//! synchronization progress (`LOOM_DEADLOCK_MS`, default 5000).
//! Condvar *waits* deliberately do not tick, so a lost wakeup reads as
//! a stall — that is exactly how the `loom_mutate_lost_notify` CI
//! mutation (dropping the GemmPool `done` notify) is caught: the
//! dispatcher hangs in `done.wait`, the counter stops, the watchdog
//! panics.
//!
//! The three protocols under model:
//!
//! 1. **GemmPool dispatch** (`linalg/pool.rs`): epoch/Condvar job
//!    hand-off. No lost wakeup (watchdog), each helper executes each
//!    epoch exactly once (`remaining` would underflow and panic in
//!    these debug builds otherwise), and `done` never signals before
//!    every panel is complete (the threaded product would differ from
//!    the serial one).
//! 2. **Sharded center push/pull** (`coordinator/threaded.rs`): a
//!    worker dying mid-`center.step` surfaces as the named "worker N
//!    died mid-run" error while the survivors — who keep exchanging
//!    against the same shard mutexes via `lock_recover` — terminate
//!    instead of deadlocking. (The companion unit tests in
//!    `threaded.rs` poison a shard *while the lock is held*; this
//!    model drives the public `run_threaded` entry under perturbed
//!    schedules.)
//! 3. **Actor shutdown / bottom-up flush** (`master_actor.rs`,
//!    `tree_threaded.rs`): every message sent before shutdown is
//!    applied — the master's round clock equals the exact step budget,
//!    so nothing is reordered past the stop — and the tree's bottom-up
//!    flush joins without deadlock at the exact leaf-step budget.
#![cfg(loom)]

use elastic_train::cluster::CostModel;
use elastic_train::coordinator::{
    run_threaded, run_tree_threaded, DriverConfig, EvalStats, GradOracle, Method, TreeSpec,
};
use elastic_train::linalg::gemm::{sgemm, sgemm_bias_act};
use elastic_train::linalg::pool::{configure_threads, shutdown_local_pool};
use elastic_train::rng::Rng;

fn cfg(method: Method, max_steps: u64) -> DriverConfig {
    DriverConfig {
        eta: 0.05,
        method,
        cost: CostModel::cifar_like(1),
        horizon: 30.0, // real-seconds safety net; the step budget binds first
        eval_every: 1e6,
        seed: 11,
        max_steps,
        lr_decay_gamma: 0.0,
    }
}

/// Model 1 — GemmPool dispatch. Each iteration runs on a fresh model
/// thread, so the `thread_local!` pool is brand new: the spawn path,
/// the parked-helper hand-off, and the explicit shutdown/join are all
/// exercised every iteration, under perturbed lock/notify timing.
#[test]
fn gemm_pool_dispatch_has_no_lost_wakeups_and_exact_panels() {
    loom::model(|| {
        let (m, n, k) = (64usize, 32, 32);
        let a: Vec<f32> = (0..m * k).map(|i| (i % 13) as f32 * 0.25 - 1.0).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i % 7) as f32 * 0.5 - 1.5).collect();
        let bias: Vec<f32> = (0..n).map(|j| j as f32 * 0.1).collect();

        // Serial reference first (threads = 1 bypasses the pool).
        configure_threads(1);
        let mut c_serial = vec![0.0f32; m * n];
        let mut f_serial = vec![0.0f32; m * n];
        sgemm(false, false, m, n, k, &a, &b, &mut c_serial);
        sgemm_bias_act(m, n, k, &a, &b, &bias, true, &mut f_serial);

        // Threaded: several dispatches reuse the parked helpers, so
        // the epoch counter advances across jobs (the exactly-one-
        // epoch-per-helper invariant is live, not vacuous).
        configure_threads(3);
        for _ in 0..3 {
            let mut c = vec![0.0f32; m * n];
            let mut f = vec![0.0f32; m * n];
            sgemm(false, false, m, n, k, &a, &b, &mut c);
            sgemm_bias_act(m, n, k, &a, &b, &bias, true, &mut f);
            // `done` signalling before every panel completed would
            // surface here as a partially-written product.
            assert_eq!(c, c_serial, "threaded GEMM diverged from serial");
            assert_eq!(f, f_serial, "threaded fused GEMM diverged from serial");
        }
        // Join the helpers inside the model: a shutdown hang (lost
        // start-notify) is a watchdog failure, and no iteration leaks
        // parked threads.
        shutdown_local_pool();
        configure_threads(1);
    });
}

/// A tiny quadratic oracle (∇ = θ − 1) whose designated victim panics
/// on its `die_after`-th gradient call — from inside `center.step`,
/// where the worker loop's `catch_unwind` must turn it into the named
/// run error while the surviving workers keep the center usable.
struct FragileQuadratic {
    n: usize,
    calls: u64,
    die_after: u64,
}

impl FragileQuadratic {
    fn family(n: usize, p: usize, victim: usize, die_after: u64) -> Vec<FragileQuadratic> {
        (0..p)
            .map(|i| FragileQuadratic {
                n,
                calls: 0,
                die_after: if i == victim { die_after } else { u64::MAX },
            })
            .collect()
    }

    fn loss_at(&self, theta: &[f32]) -> f64 {
        theta.iter().map(|&t| 0.5 * ((t - 1.0) as f64).powi(2)).sum()
    }
}

impl GradOracle for FragileQuadratic {
    fn n_params(&self) -> usize {
        self.n
    }

    fn init_params(&self) -> Vec<f32> {
        vec![0.0; self.n]
    }

    fn grad(&mut self, theta: &[f32], _rng: &mut Rng, out: &mut [f32]) -> f32 {
        self.calls += 1;
        if self.calls > self.die_after {
            panic!("injected worker death in the sharded-center loom model");
        }
        for (o, &t) in out.iter_mut().zip(theta) {
            *o = t - 1.0;
        }
        self.loss_at(theta) as f32
    }

    fn eval(&mut self, theta: &[f32]) -> EvalStats {
        let loss = self.loss_at(theta);
        EvalStats { train_loss: loss, test_loss: loss, test_error: 0.0 }
    }
}

/// Model 2 — sharded center push/pull with a worker dying mid-run.
/// The run must return the named error (not hang, not resume the
/// unwind, not burn the full step budget) no matter how the schedule
/// interleaves the death with the survivors' exchanges.
#[test]
fn sharded_center_survives_a_worker_death_without_deadlock() {
    loom::model(|| {
        let mut oracles = FragileQuadratic::family(8, 3, 1, 3);
        let c = cfg(Method::easgd_default(3, 1), 100_000);
        let e = run_threaded(&mut oracles, &c, 4).unwrap_err();
        let msg = format!("{e}");
        assert!(msg.contains("worker 1 died mid-run"), "unexpected error: {msg}");
        assert!(msg.contains("injected worker death"), "unexpected error: {msg}");
    });
}

/// Model 3a — master-actor shutdown. MDOWNPOUR serializes one master
/// round per local step through the actor's mpsc loop; an exact round
/// count at the exact step budget means no message was dropped or
/// reordered past the stop, and returning at all means the
/// drain-until-disconnect shutdown has no deadlock.
#[test]
fn actor_master_flushes_every_message_at_shutdown() {
    loom::model(|| {
        let mut oracles = FragileQuadratic::family(16, 3, 0, u64::MAX);
        let mut c = cfg(Method::MDownpour { delta: 0.9 }, 90);
        c.eta = 0.01;
        let r = run_threaded(&mut oracles, &c, 1).unwrap();
        assert!(!r.diverged);
        assert_eq!(r.total_steps, 90, "actor run must consume the exact budget");
        assert_eq!(r.rounds, 90, "every step is one serialized master round");
    });
}

/// Model 3b — tree bottom-up flush. The threaded tree joins leaf
/// actors upward at shutdown; finishing at the exact leaf-step budget
/// under perturbed channel/lock timing means the flush ordering has no
/// deadlock and the root's final snapshot is published.
#[test]
fn tree_actors_flush_bottom_up_without_deadlock() {
    loom::model(|| {
        let mut oracles = FragileQuadratic::family(8, 4, 0, u64::MAX);
        let c = cfg(Method::easgd_default(4, 2), 120);
        let spec = TreeSpec::thesis_default();
        let r = run_tree_threaded(&mut oracles, &c, &spec).unwrap();
        assert!(!r.diverged);
        assert_eq!(r.total_steps, 120, "tree run must consume the exact leaf budget");
        assert!(!r.curve.is_empty(), "the root must publish its final snapshot");
    });
}
