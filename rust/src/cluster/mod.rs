//! The simulated cluster substrate: virtual time, communication cost,
//! and the compute/data/communication accounting of thesis Table 4.4.
//!
//! The thesis ran on a GPU cluster over InfiniBand/MPI; what its
//! experiments actually measure is how *coordination dynamics* interact
//! with relative costs (gradient-step time vs. parameter-message time
//! vs. data-load time). This module makes those costs explicit,
//! deterministic, and configurable, so the Chapter-4/6 sweeps reproduce
//! the paper's wall-clock-shaped curves on virtual time (DESIGN.md §2).

use crate::rng::Rng;

/// Per-worker cost model (all times in virtual seconds).
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// Mean time of one local gradient step (mini-batch fwd+bwd).
    pub t_grad: f64,
    /// Multiplicative log-normal-ish jitter on each step (fraction,
    /// e.g. 0.05) — this is what makes the asynchrony *real*: workers
    /// drift out of phase and staleness emerges.
    pub jitter: f64,
    /// Amortized data-loading time per local step (Table 4.4 column 2).
    pub t_data: f64,
    /// One-way message latency.
    pub latency: f64,
    /// Link bandwidth in bytes / virtual second.
    pub bandwidth: f64,
    /// Payload of one parameter (or gradient) message, in bytes.
    pub param_bytes: f64,
}

impl CostModel {
    /// Defaults shaped after Table 4.4's CIFAR column: at τ=1 the
    /// parameter communication is a large fraction of the total; at
    /// τ=10 it becomes negligible.
    pub fn cifar_like(n_params: usize) -> Self {
        // Table 4.4 left (CIFAR, per 400×128 samples): ≈11s compute,
        // ≈2s data, ≈9s comm at τ=1 ⇒ per-step 27.5/5/22.5 ms. The
        // bandwidth is set so one exchange ≈ 20 ms regardless of the
        // stand-in model's parameter count (it is the *ratio* that
        // shapes the thesis' curves).
        let param_bytes = (n_params * 4) as f64;
        CostModel {
            t_grad: 27.5e-3,
            jitter: 0.08,
            t_data: 5e-3,
            latency: 1e-3,
            bandwidth: param_bytes * 100.0, // 2·bytes/bw = 20 ms
            param_bytes,
        }
    }

    /// ImageNet column shape: model (233 MB in the thesis) dwarfs the
    /// per-batch data; parameter communication is ~66× data cost.
    pub fn imagenet_like(n_params: usize) -> Self {
        // Table 4.4 right (ImageNet, per 1024×128 samples): ≈1250s
        // compute, ≈20–60s data, ≈284s comm at p=8, τ=1 ⇒ per-step
        // 1.22 s / 0.02 s / 0.28 s.
        let param_bytes = (n_params * 4) as f64;
        CostModel {
            t_grad: 1.22,
            jitter: 0.05,
            t_data: 0.02,
            latency: 2e-3,
            bandwidth: param_bytes * 7.2, // 2·bytes/bw ≈ 0.28 s
            param_bytes,
        }
    }

    /// Duration of one local gradient step, with jitter.
    pub fn grad_time(&self, rng: &mut Rng) -> f64 {
        let j = 1.0 + self.jitter * rng.gaussian();
        self.t_grad * j.max(0.1)
    }

    /// Round-trip exchange time: request + payload both ways.
    pub fn exchange_time(&self) -> f64 {
        2.0 * self.latency + 2.0 * self.param_bytes / self.bandwidth
    }

    /// One-way message time (tree protocol, non-blocking sends).
    pub fn one_way_time(&self) -> f64 {
        self.latency + self.param_bytes / self.bandwidth
    }

    /// One-way time over a scaled link — the tree's bottom-layer
    /// (leaf ↔ leaf-parent) messages stay inside one machine in the
    /// thesis' deployment (§6.1) and take `scale` < 1.
    pub fn one_way_time_scaled(&self, scale: f64) -> f64 {
        self.one_way_time() * scale
    }

    /// Scale the local-step time by a *measured* hybrid-GEMM speedup
    /// (see `linalg::pool::measured_speedup`): with `threads = c` per
    /// worker the real backends run each gradient step ~`speedup`×
    /// faster, so the virtual-time sim must price it the same way or
    /// its τ trade-off figures stop matching the thread/process tiers.
    /// A speedup of 1.0 (the `threads = 1` default) is an exact no-op;
    /// non-finite or non-positive values are ignored.
    pub fn with_thread_speedup(mut self, speedup: f64) -> Self {
        if speedup.is_finite() && speedup > 0.0 {
            self.t_grad /= speedup;
        }
        self
    }
}

/// Table 4.4's three columns, accumulated per run, plus the process
/// backend's measured decomposition of the comm column.
#[derive(Clone, Copy, Debug, Default)]
pub struct TimeBreakdown {
    pub compute: f64,
    pub data: f64,
    pub comm: f64,
    /// Measured frame encode/decode seconds (process backend; a
    /// sub-component of `comm`, not an additional column).
    pub serialize: f64,
    /// Measured socket write/read seconds (process backend; a
    /// sub-component of `comm`, not an additional column).
    pub transfer: f64,
}

impl TimeBreakdown {
    pub fn total(&self) -> f64 {
        // serialize/transfer are "of which" sub-columns of comm.
        self.compute + self.data + self.comm
    }
}

/// A point on a training curve (the thesis' Figs 4.x/6.x axes).
#[derive(Clone, Copy, Debug)]
pub struct CurvePoint {
    /// Virtual wall-clock time.
    pub time: f64,
    /// Train loss of the center variable (on a fixed probe batch).
    pub train_loss: f64,
    /// Test loss of the center variable.
    pub test_loss: f64,
    /// Test error in [0, 1].
    pub test_error: f64,
}

/// Measured wire statistics (process backend only; `None` on the
/// single-address-space backends, whose exchanges move no bytes).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct WireStats {
    /// Frames through the master's sockets (both directions).
    pub frames: u64,
    /// Payload bytes through the master's sockets (headers excluded —
    /// the θ message size is what the thesis' cost model prices).
    pub payload_bytes: u64,
    /// Mean center-rounds of staleness a worker's exchange observed
    /// (rounds applied by other workers since its previous exchange).
    pub mean_staleness: f64,
}

/// Result of one distributed run.
#[derive(Clone, Debug, Default)]
pub struct RunResult {
    pub curve: Vec<CurvePoint>,
    pub breakdown: TimeBreakdown,
    /// Total local gradient steps summed over workers.
    pub total_steps: u64,
    /// Center-update rounds (the master clock that drives ADOWNPOUR's
    /// 1/t averaging rate). Tracked by the star backends; 0 where the
    /// backend keeps no single master clock (tree, sequential). The
    /// thread backend skips the no-op exchange at `t_local == 0`, so
    /// its count runs one lower per worker than the virtual-time
    /// driver's for the decoupled methods (the sim keeps the zeroth
    /// round as part of its deterministic event schedule).
    pub rounds: u64,
    /// Measured socket statistics (the process backend); `None` where
    /// no bytes cross a process boundary.
    pub wire: Option<WireStats>,
    pub diverged: bool,
}

impl RunResult {
    /// Earliest virtual time at which test error ≤ thr (Figs 4.14/4.15);
    /// None if never reached — a "missing bar".
    pub fn time_to_error(&self, thr: f64) -> Option<f64> {
        self.curve
            .iter()
            .find(|pt| pt.test_error <= thr)
            .map(|pt| pt.time)
    }

    /// Smallest achieved test error (the thesis' model-selection metric).
    pub fn best_test_error(&self) -> f64 {
        self.curve
            .iter()
            .map(|p| p.test_error)
            .fold(f64::INFINITY, f64::min)
    }

    pub fn final_train_loss(&self) -> f64 {
        self.curve.last().map(|p| p.train_loss).unwrap_or(f64::NAN)
    }

    /// First tracked point, `None` on an empty curve. Use this (or
    /// [`RunResult::last_point`]) instead of `curve.first().unwrap()`:
    /// a run whose horizon is shorter than its eval cadence can
    /// legitimately record nothing, and an accessor panic turns that
    /// configuration mistake into an opaque crash instead of the
    /// descriptive config-time error `DriverConfig::validate` gives.
    pub fn first_point(&self) -> Option<&CurvePoint> {
        self.curve.first()
    }

    /// Last tracked point, `None` on an empty curve.
    pub fn last_point(&self) -> Option<&CurvePoint> {
        self.curve.last()
    }

    /// Train loss of the first tracked point (NaN on an empty curve,
    /// mirroring [`RunResult::final_train_loss`]).
    pub fn first_train_loss(&self) -> f64 {
        self.curve.first().map(|p| p.train_loss).unwrap_or(f64::NAN)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exchange_time_composes_latency_and_bandwidth() {
        let cm = CostModel {
            t_grad: 1.0,
            jitter: 0.0,
            t_data: 0.0,
            latency: 0.5,
            bandwidth: 100.0,
            param_bytes: 200.0,
        };
        assert!((cm.exchange_time() - (1.0 + 4.0)).abs() < 1e-12);
        assert!((cm.one_way_time() - 2.5).abs() < 1e-12);
        assert!((cm.one_way_time_scaled(0.2) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn thread_speedup_scales_only_the_local_step() {
        let cm = CostModel::cifar_like(1000);
        let fast = cm.with_thread_speedup(2.0);
        assert!((fast.t_grad - cm.t_grad / 2.0).abs() < 1e-12);
        assert!((fast.exchange_time() - cm.exchange_time()).abs() < 1e-12);
        // Identity and garbage inputs leave the model untouched.
        assert_eq!(cm.with_thread_speedup(1.0).t_grad, cm.t_grad);
        assert_eq!(cm.with_thread_speedup(f64::NAN).t_grad, cm.t_grad);
        assert_eq!(cm.with_thread_speedup(0.0).t_grad, cm.t_grad);
    }

    #[test]
    fn grad_time_jitter_is_bounded_and_unbiased() {
        let cm = CostModel::cifar_like(1000);
        let mut rng = Rng::new(1);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| cm.grad_time(&mut rng)).sum::<f64>() / n as f64;
        assert!((mean - cm.t_grad).abs() < 0.02 * cm.t_grad, "mean {mean}");
        for _ in 0..1000 {
            assert!(cm.grad_time(&mut rng) > 0.0);
        }
    }

    #[test]
    fn table_4_4_shape_comm_dominates_at_tau_1() {
        // At τ=1 a worker pays one exchange per step; at τ=10, per 10
        // steps. The CIFAR-like model must make comm a significant
        // fraction at τ=1 and negligible at τ=10 (Table 4.4's claim).
        let cm = CostModel::cifar_like(500_000);
        let per_step = cm.t_grad + cm.t_data;
        let comm_tau1 = cm.exchange_time();
        let comm_tau10 = cm.exchange_time() / 10.0;
        assert!(comm_tau1 > 0.5 * per_step, "τ=1 comm should be large");
        assert!(comm_tau10 < 0.2 * per_step, "τ=10 comm should be small");
    }

    #[test]
    fn time_to_error_finds_first_crossing() {
        let r = RunResult {
            curve: vec![
                CurvePoint { time: 1.0, train_loss: 1.0, test_loss: 1.0, test_error: 0.5 },
                CurvePoint { time: 2.0, train_loss: 0.5, test_loss: 0.6, test_error: 0.3 },
                CurvePoint { time: 3.0, train_loss: 0.4, test_loss: 0.55, test_error: 0.2 },
            ],
            ..Default::default()
        };
        assert_eq!(r.time_to_error(0.35), Some(2.0));
        assert_eq!(r.time_to_error(0.1), None);
        assert!((r.best_test_error() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn empty_curve_accessors_do_not_panic() {
        // Regression: `curve.first().unwrap()` panicked on runs whose
        // horizon left the curve empty; every accessor must degrade.
        let r = RunResult::default();
        assert!(r.first_point().is_none());
        assert!(r.last_point().is_none());
        assert!(r.first_train_loss().is_nan());
        assert!(r.final_train_loss().is_nan());
        assert!(r.best_test_error().is_infinite());
        assert_eq!(r.time_to_error(0.5), None);
    }
}
