//! Configuration system.
//!
//! The offline crate set has no serde, so this module carries its own
//! substrates (DESIGN.md §2):
//! - [`json`] — a small recursive-descent JSON parser (reads
//!   `artifacts/manifest.json`).
//! - [`args`] — `key=value` CLI argument parsing with typed getters.
//! - [`experiment`] — the experiment config struct the `repro` binary
//!   and the examples share (model preset, cluster costs, method
//!   selection, schedule), loadable from a `key = value` file with CLI
//!   overrides.

pub mod args;
pub mod experiment;
pub mod json;

pub use args::Args;
pub use experiment::ExperimentConfig;
pub use json::Json;
