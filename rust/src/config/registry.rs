//! The knob registry: every configuration knob of the training CLI,
//! as one committed table — name, landing field, type, default, and
//! the *surfaces* it is threaded through.
//!
//! Why a registry: PRs 7 and 9 each threaded one new knob (`threads=`,
//! `simd=`) through six surfaces by hand (`ExperimentConfig`, the
//! `train` CLI, `FigOpts`, the ch4 `Sweep`, the process-worker CLI
//! forwarding list, docs), and nothing machine-checked that all six
//! stayed in sync — a silently dropped surface means a run quietly
//! ignores a knob the user set. This table is the single source of
//! truth; `tests/repo_lint.rs` (rule R5) scrapes the actual struct
//! fields and the actual worker-CLI forwarding list out of the source
//! and diffs them against it in BOTH directions, and the `train` usage
//! text in `main.rs` is generated from it ([`usage_text`]), so help,
//! structs, and forwarding cannot drift apart.
//!
//! Not every knob belongs on every surface — that's what the
//! per-surface *exemption* entries are for: each names the reason a
//! knob legitimately skips a surface (e.g. `p` never reaches a worker
//! process because a worker only knows its own `wid`). An exemption
//! without a reason, or a surface claim the scrape can't find, fails
//! the lint.

/// A place a knob must be threaded through to take effect.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Surface {
    /// A typed field of `config::ExperimentConfig` (with a `set()` arm).
    Experiment,
    /// Accepted by the `repro train` command line / config file.
    TrainCli,
    /// A field of `figures::FigOpts` (the figure harness).
    FigOpts,
    /// A field of `figures::ch4::Sweep` (the ch4 sweep harness).
    Ch4Sweep,
    /// Forwarded on the hidden `--process-worker` command line.
    WorkerCli,
}

/// One knob: where it lives and where it travels.
pub struct Knob {
    /// The key as typed on a CLI (`cost=imagenet`).
    pub name: &'static str,
    /// The struct field it lands in (differs from `name` when the CLI
    /// key and the field are spelled differently, e.g. `cost` →
    /// `cost_family`, `out-dir` → `out_dir`).
    pub field: &'static str,
    /// Human-readable type, for the generated usage text.
    pub ty: &'static str,
    /// Default value, for the generated usage text.
    pub default: &'static str,
    /// A valid NON-default value; the registry test drives it through
    /// `ExperimentConfig::set` to prove the typed arm exists (a knob
    /// whose sample lands in `extra` has silently lost its field).
    pub sample: &'static str,
    /// One-line description for the usage text.
    pub doc: &'static str,
    /// Surfaces this knob IS threaded through (scrape-verified by R5).
    pub surfaces: &'static [Surface],
    /// Surfaces this knob legitimately skips, each with the reason.
    pub exemptions: &'static [(Surface, &'static str)],
}

use Surface::{Ch4Sweep, Experiment, FigOpts, TrainCli, WorkerCli};

/// THE registry. Grouped: experiment knobs, train-only knobs, figure
/// knobs, hidden process-worker knobs.
pub const KNOBS: &[Knob] = &[
    // ---- ExperimentConfig knobs (typed fields with set() arms) ----
    Knob {
        name: "method", field: "method", ty: "name", default: "easgd", sample: "downpour",
        doc: "easgd|eamsgd|downpour|mdownpour|adownpour|mvadownpour|admm|sgd|msgd|asgd|mvasgd",
        surfaces: &[Experiment, TrainCli, WorkerCli],
        exemptions: &[
            (FigOpts, "each figure fixes the method set the thesis compares"),
            (Ch4Sweep, "the sweep's method is a run(...) argument, not a field"),
        ],
    },
    Knob {
        name: "p", field: "p", ty: "usize", default: "4", sample: "8",
        doc: "parallel workers (tree: leaf count)",
        surfaces: &[Experiment, TrainCli],
        exemptions: &[
            (FigOpts, "figures sweep p internally per thesis panel"),
            (Ch4Sweep, "p is a run(...) argument of the sweep, not a field"),
            (WorkerCli, "the master spawns p workers; a worker only knows its wid"),
        ],
    },
    Knob {
        name: "eta", field: "eta", ty: "f32", default: "0.05", sample: "0.1",
        doc: "learning rate η",
        surfaces: &[Experiment, TrainCli, WorkerCli],
        exemptions: &[
            (FigOpts, "figures use per-panel thesis learning rates"),
            (Ch4Sweep, "η is a run(...) argument of the sweep, not a field"),
        ],
    },
    Knob {
        name: "tau", field: "tau", ty: "u32", default: "10", sample: "4",
        doc: "communication period τ (local steps between exchanges)",
        surfaces: &[Experiment, TrainCli, WorkerCli],
        exemptions: &[
            (FigOpts, "figures sweep τ internally per thesis panel"),
            (Ch4Sweep, "τ is the swept variable, passed to run(...) per point"),
        ],
    },
    Knob {
        name: "beta", field: "beta", ty: "f32", default: "0.9", sample: "0.5",
        doc: "elastic rate β (α = β/p on the star, β/(d+1) on trees)",
        surfaces: &[Experiment, TrainCli],
        exemptions: &[
            (FigOpts, "figures use the thesis β = 0.9 throughout"),
            (Ch4Sweep, "the sweep uses the thesis β = 0.9 throughout"),
            (WorkerCli, "forwarded pre-resolved as alpha= (α = β/p), never as β"),
        ],
    },
    Knob {
        name: "delta", field: "delta", ty: "f32", default: "0.99", sample: "0.9",
        doc: "momentum δ (EAMSGD / MSGD / MDOWNPOUR)",
        surfaces: &[Experiment, TrainCli, WorkerCli],
        exemptions: &[
            (FigOpts, "figures use per-panel thesis momenta"),
            (Ch4Sweep, "δ rides inside the sweep's Method argument"),
        ],
    },
    Knob {
        name: "cost", field: "cost_family", ty: "name", default: "cifar", sample: "imagenet",
        doc: "cifar|imagenet virtual-time cost family (sim backend)",
        surfaces: &[Experiment, TrainCli],
        exemptions: &[
            (FigOpts, "each figure prices the family its thesis chapter uses"),
            (Ch4Sweep, "the cost family is a run(...) argument of the sweep"),
            (WorkerCli, "process workers measure real time; no cost model to price"),
        ],
    },
    Knob {
        name: "sharding", field: "sharding", ty: "name", default: "replicated", sample: "partitioned",
        doc: "replicated|partitioned §4.1 data sharding",
        surfaces: &[Experiment, TrainCli, Ch4Sweep, WorkerCli],
        exemptions: &[
            (FigOpts, "the replicated-vs-partitioned figures compare both modes internally"),
        ],
    },
    Knob {
        name: "model", field: "model", ty: "name", default: "mlp", sample: "conv",
        doc: "mlp|conv native oracle model",
        surfaces: &[Experiment, TrainCli, FigOpts, Ch4Sweep, WorkerCli],
        exemptions: &[],
    },
    Knob {
        name: "horizon", field: "horizon", ty: "f64", default: "60", sample: "30",
        doc: "wall-clock training horizon in seconds",
        surfaces: &[Experiment, TrainCli, Ch4Sweep, WorkerCli],
        exemptions: &[(FigOpts, "figures use thesis horizons, scaled by the full flag")],
    },
    Knob {
        name: "eval_every", field: "eval_every", ty: "f64", default: "2", sample: "1.5",
        doc: "evaluation cadence in seconds",
        surfaces: &[Experiment, TrainCli, Ch4Sweep],
        exemptions: &[
            (FigOpts, "figures use thesis cadences, scaled by the full flag"),
            (WorkerCli, "evaluation is master-side (center snapshots); workers never eval"),
        ],
    },
    Knob {
        name: "seed", field: "seed", ty: "u64", default: "0", sample: "7",
        doc: "root RNG seed (worker streams split deterministically)",
        surfaces: &[Experiment, TrainCli, FigOpts, Ch4Sweep, WorkerCli],
        exemptions: &[],
    },
    Knob {
        name: "batch", field: "batch", ty: "usize", default: "32", sample: "64",
        doc: "minibatch size per local step",
        surfaces: &[Experiment, TrainCli, WorkerCli],
        exemptions: &[
            (FigOpts, "figures run the thesis batch of 32"),
            (Ch4Sweep, "the sweep's oracles are built at the thesis batch of 32"),
        ],
    },
    Knob {
        name: "threads", field: "threads", ty: "usize", default: "1", sample: "2",
        doc: "GEMM helper threads per worker (hybrid parallelism)",
        surfaces: &[Experiment, TrainCli, FigOpts, Ch4Sweep, WorkerCli],
        exemptions: &[],
    },
    Knob {
        name: "simd", field: "simd", ty: "name", default: "auto", sample: "scalar",
        doc: "auto|avx2|neon|scalar kernel tier (strict availability)",
        surfaces: &[Experiment, TrainCli, FigOpts, Ch4Sweep, WorkerCli],
        exemptions: &[],
    },
    // ---- train-CLI-only knobs (read straight from Args) ----
    Knob {
        name: "backend", field: "backend", ty: "name", default: "sim", sample: "",
        doc: "sim|thread|process execution backend",
        surfaces: &[TrainCli, FigOpts, Ch4Sweep],
        exemptions: &[],
    },
    Knob {
        name: "topology", field: "", ty: "name", default: "star", sample: "",
        doc: "star|tree node wiring (thesis ch. 6)",
        surfaces: &[TrainCli], exemptions: &[],
    },
    Knob {
        name: "degree", field: "", ty: "usize", default: "4", sample: "",
        doc: "tree arity d (topology=tree)",
        surfaces: &[TrainCli], exemptions: &[],
    },
    Knob {
        name: "scheme", field: "", ty: "name", default: "multiscale", sample: "",
        doc: "multiscale|updown tree communication scheme (§6.1)",
        surfaces: &[TrainCli], exemptions: &[],
    },
    Knob {
        name: "tau1", field: "", ty: "u32", default: "10", sample: "",
        doc: "multiscale leaf period",
        surfaces: &[TrainCli], exemptions: &[],
    },
    Knob {
        name: "tau2", field: "", ty: "u32", default: "100", sample: "",
        doc: "multiscale interior period",
        surfaces: &[TrainCli], exemptions: &[],
    },
    Knob {
        name: "tau_up", field: "", ty: "u32", default: "1", sample: "",
        doc: "updown upward period",
        surfaces: &[TrainCli], exemptions: &[],
    },
    Knob {
        name: "tau_down", field: "", ty: "u32", default: "10", sample: "",
        doc: "updown downward period",
        surfaces: &[TrainCli], exemptions: &[],
    },
    Knob {
        name: "transport", field: "", ty: "name", default: "tcp", sample: "",
        doc: "tcp|unix socket transport (backend=process)",
        surfaces: &[TrainCli], exemptions: &[],
    },
    Knob {
        name: "host", field: "", ty: "str", default: "127.0.0.1", sample: "",
        doc: "master bind host (transport=tcp)",
        surfaces: &[TrainCli], exemptions: &[],
    },
    Knob {
        name: "port", field: "", ty: "u16", default: "0", sample: "",
        doc: "master bind port; 0 = ephemeral (transport=tcp)",
        surfaces: &[TrainCli], exemptions: &[],
    },
    Knob {
        name: "config", field: "", ty: "path", default: "-", sample: "",
        doc: "key=value config file applied before CLI overrides",
        surfaces: &[TrainCli], exemptions: &[],
    },
    Knob {
        name: "gamma", field: "", ty: "f64", default: "0", sample: "",
        doc: "learning-rate decay exponent (extra knob)",
        surfaces: &[TrainCli, WorkerCli], exemptions: &[],
    },
    Knob {
        name: "mva_alpha", field: "", ty: "f32", default: "0.001", sample: "",
        doc: "moving-average rate (mvadownpour/mvasgd; extra knob)",
        surfaces: &[TrainCli, WorkerCli], exemptions: &[],
    },
    Knob {
        name: "rho", field: "", ty: "f32", default: "1.0", sample: "",
        doc: "ADMM penalty ρ (extra knob)",
        surfaces: &[TrainCli], exemptions: &[],
    },
    // ---- figure-harness-only knobs ----
    Knob {
        name: "out-dir", field: "out_dir", ty: "path", default: "out", sample: "",
        doc: "figure output directory",
        surfaces: &[FigOpts], exemptions: &[],
    },
    Knob {
        name: "full", field: "full", ty: "flag", default: "-", sample: "",
        doc: "full-length thesis horizons instead of smoke-length",
        surfaces: &[FigOpts], exemptions: &[],
    },
    // ---- hidden --process-worker knobs (never user-facing) ----
    Knob {
        name: "addr", field: "", ty: "str", default: "-", sample: "",
        doc: "master wire address (tcp:host:port | unix:/path)",
        surfaces: &[WorkerCli], exemptions: &[],
    },
    Knob {
        name: "wid", field: "", ty: "usize", default: "-", sample: "",
        doc: "this worker's id",
        surfaces: &[WorkerCli], exemptions: &[],
    },
    Knob {
        name: "max_local", field: "", ty: "u64", default: "-", sample: "",
        doc: "per-worker local-step budget",
        surfaces: &[WorkerCli], exemptions: &[],
    },
    Knob {
        name: "alpha", field: "", ty: "f32", default: "-", sample: "",
        doc: "resolved elastic rate α = β/p",
        surfaces: &[WorkerCli], exemptions: &[],
    },
    Knob {
        name: "fault", field: "", ty: "name", default: "-", sample: "",
        doc: "test-only rogue-peer mode (push-before-hello)",
        surfaces: &[WorkerCli], exemptions: &[],
    },
    Knob {
        name: "oracle", field: "", ty: "name", default: "-", sample: "",
        doc: "quad|sweep oracle recipe discriminant",
        surfaces: &[WorkerCli], exemptions: &[],
    },
    Knob {
        name: "qn", field: "", ty: "usize", default: "-", sample: "",
        doc: "quadratic oracle dimension",
        surfaces: &[WorkerCli], exemptions: &[],
    },
    Knob {
        name: "qh", field: "", ty: "f32", default: "-", sample: "",
        doc: "quadratic curvature",
        surfaces: &[WorkerCli], exemptions: &[],
    },
    Knob {
        name: "qx0", field: "", ty: "f32", default: "-", sample: "",
        doc: "quadratic init point",
        surfaces: &[WorkerCli], exemptions: &[],
    },
    Knob {
        name: "qtarget", field: "", ty: "f32", default: "-", sample: "",
        doc: "quadratic optimum",
        surfaces: &[WorkerCli], exemptions: &[],
    },
    Knob {
        name: "qnoise", field: "", ty: "f32", default: "-", sample: "",
        doc: "quadratic gradient noise",
        surfaces: &[WorkerCli], exemptions: &[],
    },
    Knob {
        name: "oseed", field: "", ty: "u64", default: "-", sample: "",
        doc: "sweep-oracle data seed",
        surfaces: &[WorkerCli], exemptions: &[],
    },
];

/// Knobs carrying the given surface.
pub fn on_surface(s: Surface) -> impl Iterator<Item = &'static Knob> {
    KNOBS.iter().filter(move |k| k.surfaces.contains(&s))
}

/// Look a knob up by CLI name.
pub fn find(name: &str) -> Option<&'static Knob> {
    KNOBS.iter().find(|k| k.name == name)
}

/// The `repro` usage text, generated from the registry so the help and
/// the actual knob set cannot drift (pinned by the registry tests and
/// lint R5).
pub fn usage_text() -> String {
    let mut s = String::from(
        "usage: repro <figure|train|train-pjrt|inspect> [key=value ...]\n\
         \n\
         repro figure <id|all|list> [out-dir=out] [--full] [seed=N]\n\
         repro train [key=value ...]   one distributed run on the sweep workload\n\
         repro train-pjrt [p=2] [steps=200] [eta=0.3] [tau=4]\n\
         repro inspect                 print the artifacts manifest summary\n\
         \n\
         train knobs (from config/registry.rs):\n",
    );
    for k in on_surface(Surface::TrainCli) {
        s.push_str(&format!(
            "  {:<24} {}  [{}, default {}]\n",
            format!("{}={}", k.name, k.default),
            k.doc,
            k.ty,
            k.default,
        ));
    }
    s.push_str(
        "\ntree runs: topology=tree degree=4 scheme=multiscale tau1=10 tau2=100\n\
         \x20          topology=tree degree=4 scheme=updown tau_up=1 tau_down=10\n",
    );
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;

    #[test]
    fn knob_names_are_unique() {
        for (i, a) in KNOBS.iter().enumerate() {
            assert!(!a.name.is_empty());
            for b in &KNOBS[i + 1..] {
                assert_ne!(a.name, b.name, "duplicate knob {}", a.name);
            }
        }
    }

    #[test]
    fn surfaces_and_exemptions_are_disjoint_and_reasoned() {
        for k in KNOBS {
            for (s, reason) in k.exemptions {
                assert!(
                    !k.surfaces.contains(s),
                    "{}: surface {s:?} both claimed and exempted",
                    k.name
                );
                assert!(
                    reason.len() > 10,
                    "{}: exemption for {s:?} needs a real reason",
                    k.name
                );
            }
        }
    }

    /// The R5 coverage contract at the registry level: every
    /// ExperimentConfig knob is either threaded through or explicitly
    /// exempted from EACH downstream surface — no silent gaps.
    #[test]
    fn experiment_knobs_account_for_every_downstream_surface() {
        for k in on_surface(Surface::Experiment) {
            for s in [Surface::FigOpts, Surface::Ch4Sweep, Surface::WorkerCli] {
                assert!(
                    k.surfaces.contains(&s) || k.exemptions.iter().any(|(e, _)| *e == s),
                    "{}: surface {s:?} neither threaded nor exempted — thread the knob \
                     through or document why it skips that surface",
                    k.name
                );
            }
        }
        assert!(
            on_surface(Surface::Experiment).count() >= 15,
            "the ExperimentConfig knob set shrank — update the registry deliberately"
        );
    }

    /// Drift pin: every Experiment knob's sample value must flow
    /// through `ExperimentConfig::set` into a TYPED field. A sample
    /// landing in `extra` means the field was renamed/removed without
    /// updating the registry (or vice versa).
    #[test]
    fn experiment_knobs_have_live_set_arms() {
        for k in on_surface(Surface::Experiment) {
            let mut cfg = ExperimentConfig::default();
            cfg.set(k.name, k.sample)
                .unwrap_or_else(|e| panic!("{}={} rejected: {e}", k.name, k.sample));
            assert!(
                cfg.extra.is_empty(),
                "{}={} fell through to `extra` — the typed set() arm is gone",
                k.name,
                k.sample
            );
        }
    }

    /// Drift pin for the generated help: every train-facing knob
    /// appears in the usage text exactly as `name=`.
    #[test]
    fn usage_text_covers_every_train_knob() {
        let text = usage_text();
        assert!(text.starts_with("usage: repro"));
        for k in on_surface(Surface::TrainCli) {
            assert!(
                text.contains(&format!("{}=", k.name)),
                "usage text lost the {} knob",
                k.name
            );
        }
        // Hidden worker knobs stay hidden.
        assert!(!text.contains("max_local="), "worker-only knobs must not leak into help");
    }

    #[test]
    fn find_resolves_names() {
        assert_eq!(find("simd").map(|k| k.field), Some("simd"));
        assert_eq!(find("cost").map(|k| k.field), Some("cost_family"));
        assert!(find("bogus").is_none());
    }
}
