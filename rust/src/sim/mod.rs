//! The thesis' analysis chapters (3 and 5) as executable models.
//!
//! - [`moments`] — closed-form MSE of the center variable (Lemma 3.1.1 /
//!   Corollary 3.1.1) and every moment/drift matrix whose spectral
//!   radius the thesis plots (Eqs 5.6, 5.12, 5.18, 5.19, 5.20, 5.30,
//!   5.34), plus the optimal-rate formulas (δ_h = (√η_h−1)²,
//!   α* = −(√β−√η_h)², η_p = ω/(λ+1/p), α = 1−√λ).
//! - [`quadratic`] — discrete-time simulators for the additive-noise
//!   model (SGD / MSGD / EASGD / EAMSGD on the 1-d quadratic).
//! - [`multiplicative`] — the §5.2 Gamma multiplicative-noise model.
//! - [`admm`] — the §3.3 round-robin ADMM and EASGD linear maps and
//!   their (in)stability.
//! - [`nonconvex`] — the §5.3 double-well saddle analysis.

pub mod admm;
pub mod moments;
pub mod multiplicative;
pub mod nonconvex;
pub mod quadratic;
