//! Benchmarks for the analysis substrate that regenerates the Chapter
//! 3/5 stability figures: eigenvalue solves and full figure-grid sweeps
//! (one row per thesis figure family).

use elastic_train::figures::benchkit::{bench, fmt_ns};
use elastic_train::linalg::{spectral_radius, Matrix};
use elastic_train::rng::Rng;
use elastic_train::sim::{admm, moments};

fn main() {
    // Raw eigen-solve cost at the sizes the figures use.
    let mut rng = Rng::new(7);
    for n in [3usize, 5, 9, 17] {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                m.set(i, j, rng.normal(0.0, 1.0));
            }
        }
        bench(&format!("linalg/spectral_radius/{n}x{n}"), 20.0, 7, || {
            std::hint::black_box(spectral_radius(&m));
        });
    }

    // Fig 5.6-family cell: build + solve the EASGD drift matrix.
    bench("fig5.6/easgd_drift_cell", 20.0, 7, || {
        let m = moments::easgd_drift_matrix(0.7, 0.1, 0.9, 2);
        std::hint::black_box(spectral_radius(&m));
    });

    // Fig 5.15-family cell: the 4x4 multiplicative moment matrix.
    bench("fig5.15/easgd_mult_cell", 20.0, 7, || {
        let m = moments::easgd_mult_moment_matrix(0.4, 0.1, 0.9, 0.5, 0.5, 16);
        std::hint::black_box(spectral_radius(&m));
    });

    // Fig 3.2 cell: compose and solve the 2p+1 ADMM round-robin map.
    for p in [3usize, 8] {
        let s = bench(&format!("fig3.2/admm_cell/p{p}"), 30.0, 5, || {
            std::hint::black_box(admm::admm_spectral_radius(p, 0.001, 2.5));
        });
        let grid = 64 * 64;
        println!(
            "  -> full {grid}-cell Fig 3.2 grid at p={p} ≈ {}",
            fmt_ns(s.median_ns * grid as f64)
        );
    }

    // Fig 3.1 cell: closed-form MSE evaluation.
    let model = moments::QuadraticModel { h: 1.0, sigma: 10.0, p: 100 };
    bench("fig3.1/closed_form_mse_cell", 10.0, 7, || {
        std::hint::black_box(moments::center_mse(&model, 0.1, 0.5, 1.0, 100));
    });
}
