//! The shared experiment configuration: what the `repro` binary, the
//! examples, and the figure harness all consume. Loadable from a
//! `key = value` file (comments with `#`) with CLI overrides on top.

use super::args::Args;
use crate::cluster::CostModel;
use crate::coordinator::{Method, SeqMethod};
use crate::error::Result;
use std::collections::BTreeMap;
use std::str::FromStr;

/// Top-level experiment configuration.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Parallel workers.
    pub p: usize,
    pub eta: f32,
    pub tau: u32,
    pub beta: f32,
    pub delta: f32,
    pub method: String,
    /// "cifar" | "imagenet" cost-model family.
    pub cost_family: String,
    /// §4.1 prefetch sharding: "replicated" (CIFAR mode) or
    /// "partitioned" (ImageNet mode).
    pub sharding: String,
    /// Native gradient model: "mlp" (historical stand-in) or "conv"
    /// (§4.1-faithful im2col conv net).
    pub model: String,
    pub horizon: f64,
    pub eval_every: f64,
    pub seed: u64,
    pub batch: usize,
    /// Hybrid-parallelism knob: GEMM threads *per worker* (p workers ×
    /// `threads` helper threads). 1 (the default) is byte-for-byte the
    /// single-threaded compute path.
    pub threads: usize,
    /// Kernel-tier knob: `auto` (default; runtime detection), `avx2`,
    /// `neon`, or `scalar`. Names are validated at parse time;
    /// *availability* (feature gate, architecture, CPU) is checked at
    /// run start by `linalg::simd::configure`, which errors loudly
    /// instead of silently degrading.
    pub simd: String,
    /// Extra free-form keys (forwarded to specific figures).
    pub extra: BTreeMap<String, String>,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            p: 4,
            eta: 0.05,
            tau: 10,
            beta: 0.9,
            delta: 0.99,
            method: "easgd".into(),
            cost_family: "cifar".into(),
            sharding: "replicated".into(),
            model: "mlp".into(),
            horizon: 60.0,
            eval_every: 2.0,
            seed: 0,
            batch: 32,
            threads: 1,
            simd: "auto".into(),
            extra: BTreeMap::new(),
        }
    }
}

/// Strict parse of one typed config value: the error names the key and
/// the offending value (the seed's `unwrap_or(default)` silently ran
/// experiments at the default — `tau=0.5` became τ=10).
fn parse_kv<T: FromStr>(k: &str, v: &str, ty: &str) -> Result<T> {
    v.parse()
        .map_err(|_| crate::err!("invalid value for {k}: '{v}' (expected {ty})"))
}

impl ExperimentConfig {
    /// Parse a `key = value` file (unknown keys land in `extra`).
    /// Malformed typed values are errors carrying the line number.
    pub fn from_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| crate::err!("cannot read config file {path}: {e}"))?;
        let mut cfg = ExperimentConfig::default();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap().trim();
            if line.is_empty() {
                continue;
            }
            if let Some((k, v)) = line.split_once('=') {
                cfg.set(k.trim(), v.trim())
                    .map_err(|e| crate::err!("{path}:{}: {e}", lineno + 1))?;
            }
        }
        Ok(cfg)
    }

    /// Apply CLI overrides; malformed values are errors, not silently
    /// ignored defaults.
    pub fn apply_args(&mut self, args: &Args) -> Result<()> {
        for (k, v) in &args.kv {
            self.set(k, v)?;
        }
        Ok(())
    }

    pub fn set(&mut self, k: &str, v: &str) -> Result<()> {
        match k {
            "p" => self.p = parse_kv(k, v, "a positive integer")?,
            "eta" => self.eta = parse_kv(k, v, "a number")?,
            "tau" => self.tau = parse_kv(k, v, "a positive integer")?,
            "beta" => self.beta = parse_kv(k, v, "a number")?,
            "delta" => self.delta = parse_kv(k, v, "a number")?,
            "method" => self.method = v.to_string(),
            "cost" => self.cost_family = v.to_string(),
            "sharding" => self.sharding = v.to_string(),
            "model" => self.model = v.to_string(),
            "horizon" => self.horizon = parse_kv(k, v, "a number of seconds")?,
            "eval_every" => self.eval_every = parse_kv(k, v, "a number of seconds")?,
            "seed" => self.seed = parse_kv(k, v, "a non-negative integer")?,
            "batch" => self.batch = parse_kv(k, v, "a positive integer")?,
            "threads" => self.threads = parse_kv(k, v, "a positive integer")?,
            "simd" => {
                if !crate::linalg::simd::is_known_request(v) {
                    crate::bail!("invalid value for simd: '{v}' (expected auto|avx2|neon|scalar)");
                }
                self.simd = v.to_string();
            }
            _ => {
                self.extra.insert(k.to_string(), v.to_string());
            }
        }
        Ok(())
    }

    /// Strictly-parsed `extra` key (mva_alpha, rho, gamma, …): absent ⇒
    /// default, malformed ⇒ an error naming the key.
    pub fn extra_f32(&self, k: &str, default: f32) -> Result<f32> {
        match self.extra.get(k) {
            None => Ok(default),
            Some(v) => parse_kv(k, v, "a number"),
        }
    }

    /// Config-time sanity checks on the time axis and grid shape —
    /// catches the degenerate configurations that used to surface as
    /// panics deep in a run (an empty curve from `horizon <= 0`,
    /// a zero-period exchange from `tau = 0`).
    pub fn validate(&self) -> Result<()> {
        if self.p == 0 {
            crate::bail!("p must be >= 1 (got 0)");
        }
        if self.batch == 0 {
            crate::bail!("batch must be >= 1 (got 0)");
        }
        if self.threads == 0 {
            crate::bail!("threads must be >= 1 (got 0): 1 means no intra-worker parallelism");
        }
        if self.tau == 0 {
            crate::bail!("tau must be >= 1 (got 0): a zero communication period is undefined");
        }
        if !self.horizon.is_finite() || self.horizon <= 0.0 {
            crate::bail!("horizon must be a positive number of seconds (got {})", self.horizon);
        }
        if !self.eval_every.is_finite() || self.eval_every <= 0.0 {
            crate::bail!(
                "eval_every must be a positive number of seconds (got {})",
                self.eval_every
            );
        }
        if !self.eta.is_finite() || self.eta <= 0.0 {
            crate::bail!("eta must be a positive number (got {})", self.eta);
        }
        Ok(())
    }

    /// Resolve the parallel method named in `method`: `Ok(None)` when
    /// the name is not a parallel method, `Err` when one of its
    /// hyper-parameter keys is malformed.
    pub fn parallel_method(&self) -> Result<Option<Method>> {
        let alpha = self.beta / self.p as f32;
        Ok(Some(match self.method.as_str() {
            "easgd" => Method::Easgd { alpha, tau: self.tau },
            "eamsgd" => Method::Eamsgd { alpha, tau: self.tau, delta: self.delta },
            "downpour" => Method::Downpour { tau: self.tau },
            "mdownpour" => Method::MDownpour { delta: self.delta },
            "adownpour" => Method::ADownpour { tau: self.tau },
            "mvadownpour" => Method::MvaDownpour {
                tau: self.tau,
                alpha: self.extra_f32("mva_alpha", 0.001)?,
            },
            "admm" => Method::AdmmAsync {
                rho: self.extra_f32("rho", 1.0)?,
                tau: self.tau,
            },
            _ => return Ok(None),
        }))
    }

    /// Resolve a sequential method name (same contract as
    /// [`ExperimentConfig::parallel_method`]).
    pub fn sequential_method(&self) -> Result<Option<SeqMethod>> {
        Ok(Some(match self.method.as_str() {
            "sgd" => SeqMethod::Sgd,
            "msgd" => SeqMethod::Msgd { delta: self.delta },
            "asgd" => SeqMethod::Asgd,
            "mvasgd" => SeqMethod::Mvasgd {
                alpha: self.extra_f32("mva_alpha", 0.001)?,
            },
            _ => return Ok(None),
        }))
    }

    /// Cost model for the chosen family at a given parameter count.
    pub fn cost_model(&self, n_params: usize) -> CostModel {
        match self.cost_family.as_str() {
            "imagenet" => CostModel::imagenet_like(n_params),
            _ => CostModel::cifar_like(n_params),
        }
    }

    /// Resolve the §4.1 prefetch sharding mode; None on an unknown
    /// value (callers report the CLI error).
    pub fn sharding_mode(&self) -> Option<crate::data::Sharding> {
        crate::data::Sharding::parse(&self.sharding)
    }

    /// Resolve the `model=mlp|conv` knob; None on an unknown value
    /// (callers report the CLI error).
    pub fn model_kind(&self) -> Option<crate::model::ModelKind> {
        crate::model::ModelKind::parse(&self.model)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_then_file_then_cli_priority() {
        let dir = std::env::temp_dir().join("et_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("exp.cfg");
        std::fs::write(&path, "p = 8\neta = 0.1 # comment\nmethod = downpour\n").unwrap();
        let mut cfg = ExperimentConfig::from_file(path.to_str().unwrap()).unwrap();
        assert_eq!(cfg.p, 8);
        assert!((cfg.eta - 0.1).abs() < 1e-7);
        assert_eq!(cfg.method, "downpour");
        let args = Args::parse(["p=16".to_string(), "rho=2.5".to_string()]);
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.p, 16);
        assert_eq!(cfg.extra.get("rho").map(|s| s.as_str()), Some("2.5"));
    }

    #[test]
    fn malformed_typed_values_are_rejected() {
        // Regression: these used to be silently swallowed by
        // `unwrap_or(default)` — `tau=0.5` ran at τ=10.
        let mut cfg = ExperimentConfig::default();
        for (k, v) in [("p", "abc"), ("tau", "0.5"), ("eta", "fast"), ("horizon", "1h")] {
            let e = cfg.set(k, v).unwrap_err();
            let msg = format!("{e}");
            assert!(msg.contains(k) && msg.contains(v), "{msg}");
        }
        // The config is untouched by the failed sets.
        assert_eq!(cfg.p, 4);
        assert_eq!(cfg.tau, 10);
    }

    #[test]
    fn from_file_reports_the_offending_line() {
        let dir = std::env::temp_dir().join("et_cfg_badfile");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.cfg");
        std::fs::write(&path, "p = 8\ntau = 0.5\n").unwrap();
        let e = ExperimentConfig::from_file(path.to_str().unwrap()).unwrap_err();
        let msg = format!("{e}");
        assert!(msg.contains(":2:") && msg.contains("tau") && msg.contains("0.5"), "{msg}");
    }

    #[test]
    fn apply_args_rejects_malformed_overrides() {
        let mut cfg = ExperimentConfig::default();
        let args = Args::parse(["batch=many".to_string()]);
        let e = cfg.apply_args(&args).unwrap_err();
        assert!(format!("{e}").contains("batch"), "{e}");
    }

    #[test]
    fn malformed_extra_hyperparams_are_rejected() {
        let mut cfg = ExperimentConfig::default();
        cfg.method = "admm".into();
        cfg.extra.insert("rho".into(), "heavy".into());
        let e = cfg.parallel_method().unwrap_err();
        assert!(format!("{e}").contains("rho"), "{e}");
        cfg.method = "mvasgd".into();
        cfg.extra.insert("mva_alpha".into(), "x".into());
        assert!(cfg.sequential_method().is_err());
    }

    #[test]
    fn validate_catches_degenerate_time_axes() {
        let mut cfg = ExperimentConfig::default();
        cfg.validate().unwrap();
        cfg.horizon = 0.0;
        assert!(format!("{}", cfg.validate().unwrap_err()).contains("horizon"));
        cfg.horizon = 60.0;
        cfg.eval_every = f64::NAN;
        assert!(format!("{}", cfg.validate().unwrap_err()).contains("eval_every"));
        cfg.eval_every = 2.0;
        cfg.tau = 0;
        assert!(format!("{}", cfg.validate().unwrap_err()).contains("tau"));
        cfg.tau = 1;
        cfg.p = 0;
        assert!(cfg.validate().is_err());
    }

    /// R7 pin (tests/repo_lint.rs): every error construction site in
    /// this file has its message fragment asserted verbatim here (the
    /// one exemption — the `{path}:{line}` wrapper — is documented in
    /// the lint table).
    #[test]
    fn error_messages_are_pinned_verbatim() {
        let mut cfg = ExperimentConfig::default();
        let msg = |e: crate::error::Error| format!("{e}");
        assert!(msg(cfg.set("p", "x").unwrap_err()).contains("invalid value for"));
        assert!(msg(cfg.set("simd", "sse9").unwrap_err())
            .contains("expected auto|avx2|neon|scalar"));
        assert!(msg(ExperimentConfig::from_file("/definitely/not/here.cfg").unwrap_err())
            .contains("cannot read config file"));

        let check = |mutate: &dyn Fn(&mut ExperimentConfig), fragment: &str| {
            let mut cfg = ExperimentConfig::default();
            mutate(&mut cfg);
            let m = msg(cfg.validate().unwrap_err());
            assert!(m.contains(fragment), "expected '{fragment}' in: {m}");
        };
        check(&|c| c.p = 0, "p must be >= 1");
        check(&|c| c.batch = 0, "batch must be >= 1");
        check(&|c| c.threads = 0, "threads must be >= 1");
        check(&|c| c.tau = 0, "tau must be >= 1");
        check(&|c| c.horizon = -1.0, "horizon must be a positive number of seconds");
        check(&|c| c.eval_every = 0.0, "eval_every must be a positive number of seconds");
        check(&|c| c.eta = 0.0, "eta must be a positive number");
    }

    #[test]
    fn method_resolution() {
        let mut cfg = ExperimentConfig { p: 8, ..Default::default() };
        cfg.method = "easgd".into();
        match cfg.parallel_method().unwrap().unwrap() {
            Method::Easgd { alpha, tau } => {
                assert!((alpha - 0.9 / 8.0).abs() < 1e-7);
                assert_eq!(tau, 10);
            }
            _ => unreachable!(),
        }
        cfg.method = "msgd".into();
        assert!(cfg.parallel_method().unwrap().is_none());
        assert!(matches!(
            cfg.sequential_method().unwrap(),
            Some(SeqMethod::Msgd { .. })
        ));
        cfg.method = "bogus".into();
        assert!(cfg.sequential_method().unwrap().is_none());
    }

    #[test]
    fn threads_knob_is_strict() {
        let mut cfg = ExperimentConfig::default();
        assert_eq!(cfg.threads, 1, "default must be the serial path");
        cfg.set("threads", "4").unwrap();
        assert_eq!(cfg.threads, 4);
        let e = cfg.set("threads", "two").unwrap_err();
        assert!(format!("{e}").contains("threads"), "{e}");
        cfg.set("threads", "0").unwrap();
        assert!(format!("{}", cfg.validate().unwrap_err()).contains("threads"));
    }

    #[test]
    fn simd_knob_is_strict_on_names_but_lazy_on_availability() {
        let mut cfg = ExperimentConfig::default();
        assert_eq!(cfg.simd, "auto", "default must be runtime detection");
        // A tier this build may not even compile still *parses*: the
        // availability check belongs to run start, not config load.
        for good in ["avx2", "neon", "scalar", "auto"] {
            cfg.set("simd", good).unwrap();
            assert_eq!(cfg.simd, good);
        }
        let e = cfg.set("simd", "sse42").unwrap_err();
        let msg = format!("{e}");
        assert!(msg.contains("simd") && msg.contains("sse42"), "{msg}");
        assert_eq!(cfg.simd, "auto", "failed set must leave the config untouched");
    }

    #[test]
    fn sharding_resolution() {
        let mut cfg = ExperimentConfig::default();
        assert_eq!(cfg.sharding_mode(), Some(crate::data::Sharding::Replicated));
        cfg.set("sharding", "partitioned").unwrap();
        assert_eq!(cfg.sharding_mode(), Some(crate::data::Sharding::Partitioned));
        cfg.set("sharding", "bogus").unwrap();
        assert_eq!(cfg.sharding_mode(), None);
    }

    #[test]
    fn model_resolution() {
        let mut cfg = ExperimentConfig::default();
        assert_eq!(cfg.model_kind(), Some(crate::model::ModelKind::Mlp));
        cfg.set("model", "conv").unwrap();
        assert_eq!(cfg.model_kind(), Some(crate::model::ModelKind::Conv));
        cfg.set("model", "bogus").unwrap();
        assert_eq!(cfg.model_kind(), None);
    }

    #[test]
    fn cost_family_switch() {
        let mut cfg = ExperimentConfig::default();
        let c = cfg.cost_model(1000);
        assert!(c.t_grad < 0.1);
        cfg.cost_family = "imagenet".into();
        let i = cfg.cost_model(1000);
        assert!(i.t_grad > 1.0);
    }
}
