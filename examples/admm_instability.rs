//! §3.3 demo: round-robin ADMM goes chaotic where round-robin EASGD's
//! symmetric elastic maps stay stable.
//!
//!     cargo run --release --example admm_instability -- [p=3] [eta=0.001] [rho=2.5]

use elastic_train::config::Args;
use elastic_train::sim::admm;

fn main() -> elastic_train::error::Result<()> {
    let args = Args::from_env();
    let p = args.get_usize("p", 3)?;
    let eta = args.get_f64("eta", 0.001)?;
    let rho = args.get_f64("rho", 2.5)?;

    let sp = admm::admm_spectral_radius(p, eta, rho);
    println!("ADMM round-robin p={p}, η={eta}, ρ={rho}: sp(𝓕) = {sp:.6}");
    for i in 0..p {
        let (f1, f2, f3) = admm::admm_maps(i, p, eta, rho);
        let m = f3.matmul(&f2).matmul(&f1);
        println!(
            "  factor F³F²F¹ for worker {i}: sp = {:.6} (individually stable)",
            elastic_train::linalg::spectral_radius(&m)
        );
    }

    println!("\ntrajectory from x̃₀ = xⁱ₀ = 1000, λⁱ₀ = 0 (thesis Fig 3.3):");
    let tr = admm::admm_trajectory(p, eta, rho, 1000.0, 50_000);
    for (i, x) in tr.iter().enumerate().step_by(5000) {
        println!("  round {i:>6}: x̃ = {x:.4e}");
    }

    println!("\nEASGD round-robin (η=0.5, α=0.3, same p) for contrast:");
    let map = admm::easgd_round_robin_map(p, 0.5, 0.3);
    let mut s = vec![1000.0; p + 1];
    for i in 0..=40 {
        if i % 8 == 0 {
            println!("  round {i:>6}: x̃ = {:.4e}", s[p]);
        }
        s = map.matvec(&s);
    }
    println!(
        "\nstability condition for EASGD round robin (§3.3): 0≤η≤2 and α ≤ (4−2η)/(4−η); \
         (0.5, 0.3) satisfies it: {}",
        admm::easgd_rr_stable(0.5, 0.3)
    );
    Ok(())
}
