//! The parallel methods of Chapter 4, as data.
//!
//! Hyper-parameter defaults follow §4.2: EASGD family uses β = 0.9 and
//! α = β/p; momentum methods use δ = 0.99; MVADOWNPOUR's moving rate is
//! 0.001.

/// A parallel distributed optimization method (p ≥ 1 workers + master).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Method {
    /// Asynchronous EASGD (Alg. 1): elastic exchange every τ local steps.
    Easgd { alpha: f32, tau: u32 },
    /// Asynchronous EAMSGD (Alg. 2): Nesterov local dynamics + elastic.
    Eamsgd { alpha: f32, tau: u32, delta: f32 },
    /// DOWNPOUR (Alg. 3): push accumulated gradients, pull fresh center.
    Downpour { tau: u32 },
    /// Momentum DOWNPOUR (Algs 4–5): τ = 1, Nesterov on the master.
    MDownpour { delta: f32 },
    /// DOWNPOUR + time-average of the center (α_t = 1/t).
    ADownpour { tau: u32 },
    /// DOWNPOUR + constant-rate moving average of the center.
    MvaDownpour { tau: u32, alpha: f32 },
    /// Asynchronous ADMM comparator (§4 footnote: performance close to
    /// EASGD; momentum variant unstable at large τ).
    AdmmAsync { rho: f32, tau: u32 },
}

impl Method {
    /// Thesis-default EASGD at p workers: β = 0.9, α = β/p.
    pub fn easgd_default(p: usize, tau: u32) -> Method {
        Method::Easgd { alpha: 0.9 / p as f32, tau }
    }

    /// Thesis-default EAMSGD: δ = 0.99.
    pub fn eamsgd_default(p: usize, tau: u32) -> Method {
        Method::Eamsgd { alpha: 0.9 / p as f32, tau, delta: 0.99 }
    }

    pub fn tau(&self) -> u32 {
        match *self {
            Method::Easgd { tau, .. }
            | Method::Eamsgd { tau, .. }
            | Method::Downpour { tau }
            | Method::ADownpour { tau }
            | Method::MvaDownpour { tau, .. }
            | Method::AdmmAsync { tau, .. } => tau,
            Method::MDownpour { .. } => 1,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Easgd { .. } => "EASGD",
            Method::Eamsgd { .. } => "EAMSGD",
            Method::Downpour { .. } => "DOWNPOUR",
            Method::MDownpour { .. } => "MDOWNPOUR",
            Method::ADownpour { .. } => "ADOWNPOUR",
            Method::MvaDownpour { .. } => "MVADOWNPOUR",
            Method::AdmmAsync { .. } => "ADMM",
        }
    }

    /// Does the local worker keep its own parameter between rounds?
    /// (EASGD family: yes — exploration; DOWNPOUR family: no — workers
    /// restart from the fresh center each round.)
    pub fn keeps_local_state(&self) -> bool {
        matches!(
            self,
            Method::Easgd { .. } | Method::Eamsgd { .. } | Method::AdmmAsync { .. }
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_follow_the_thesis() {
        match Method::easgd_default(8, 10) {
            Method::Easgd { alpha, tau } => {
                assert!((alpha - 0.9 / 8.0).abs() < 1e-7);
                assert_eq!(tau, 10);
            }
            _ => unreachable!(),
        }
        match Method::eamsgd_default(4, 10) {
            Method::Eamsgd { delta, .. } => assert!((delta - 0.99).abs() < 1e-7),
            _ => unreachable!(),
        }
    }

    #[test]
    fn mdownpour_always_tau_1() {
        assert_eq!(Method::MDownpour { delta: 0.99 }.tau(), 1);
    }

    #[test]
    fn state_retention_split() {
        assert!(Method::easgd_default(4, 1).keeps_local_state());
        assert!(!Method::Downpour { tau: 1 }.keeps_local_state());
    }
}
