//! §6.2 — unifying EASGD and DOWNPOUR via the Gauss–Seidel form.
//!
//! The synchronous Gauss–Seidel update (workers first, center second,
//! using the *updated* workers):
//!
//!   xⁱ_{t+1} = xⁱ_t − η ∇F(xⁱ_t) − a (xⁱ_t − x̃_t)
//!   x̃_{t+1} = (1 − b) x̃_t + b · mean_i xⁱ_{t+1}
//!
//! * (a, b) = (α, β)  → Gauss–Seidel EASGD (the Jacobi form of Ch. 2
//!   differs only in using xⁱ_t in the center update);
//! * (a, b) = (1, p)  → exactly synchronous DOWNPOUR with τ = 1:
//!   workers restart from the center (a = 1) and the center absorbs the
//!   SUM of their updates (b = p) — a *singular* moving rate that sits
//!   far outside EASGD's 0 < b ≤ 1 region when p is large, which is the
//!   thesis' explanation of DOWNPOUR's instability.
//!
//! `drift_matrix` gives the 1-d quadratic (∇F(x) = h·x) dynamics;
//! `stability_map` sweeps (a, b).

use crate::linalg::{spectral_radius, Matrix};

/// Drift matrix of the Gauss–Seidel form on F(x) = h x² / 2 over the
/// state (x¹, …, xᵖ, x̃).
pub fn drift_matrix(eta_h: f64, a: f64, b: f64, p: usize) -> Matrix {
    let n = p + 1;
    let mut m = Matrix::zeros(n, n);
    let q = 1.0 - eta_h - a; // worker self-coefficient
    for i in 0..p {
        m.set(i, i, q);
        m.set(i, p, a);
    }
    // x̃_{t+1} = (1−b) x̃ + (b/p) Σ_j (q xʲ + a x̃)
    for j in 0..p {
        m.set(p, j, b / p as f64 * q);
    }
    m.set(p, p, (1.0 - b) + b * a);
    m
}

/// sp of the Gauss–Seidel drift — the §6.2 stability map.
pub fn spectral(eta_h: f64, a: f64, b: f64, p: usize) -> f64 {
    spectral_radius(&drift_matrix(eta_h, a, b, p))
}

/// The DOWNPOUR point in the unified (a, b) plane.
pub fn downpour_rates(p: usize) -> (f64, f64) {
    (1.0, p as f64)
}

/// The EASGD point (thesis defaults β = 0.9, α = β/p).
pub fn easgd_rates(p: usize) -> (f64, f64) {
    (0.9 / p as f64, 0.9)
}

/// One synchronous Gauss–Seidel step on concrete state (test support &
/// the fig6 GS simulation): returns updated (workers, center).
pub fn gs_step(
    workers: &mut [Vec<f32>],
    center: &mut [f32],
    grads: &[Vec<f32>],
    eta: f32,
    a: f32,
    b: f32,
) {
    let p = workers.len();
    let n = center.len();
    for (w, g) in workers.iter_mut().zip(grads) {
        for j in 0..n {
            w[j] = w[j] - eta * g[j] - a * (w[j] - center[j]);
        }
    }
    for j in 0..n {
        let mean: f32 = workers.iter().map(|w| w[j]).sum::<f32>() / p as f32;
        center[j] = (1.0 - b) * center[j] + b * mean;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn easgd_gs_is_stable_at_thesis_defaults() {
        for p in [4usize, 16, 64] {
            let (a, b) = easgd_rates(p);
            let sp = spectral(0.1, a, b, p);
            assert!(sp < 1.0, "p={p}: sp={sp}");
        }
    }

    #[test]
    fn downpour_rates_grow_singular_with_p() {
        // In the unified plane DOWNPOUR's b = p leaves the EASGD region
        // (b ≤ 1); its stability then demands an O(1/p)-small ηh.
        let p = 16;
        let (a, b) = downpour_rates(p);
        // Stable only for tiny ηh:
        assert!(spectral(0.01, a, b, p) < 1.0 + 1e-9);
        // ...but already unstable at a moderate ηh where EASGD is fine:
        let eta_h = 1.5;
        assert!(spectral(eta_h, a, b, p) > 1.0);
        let (ae, be) = easgd_rates(p);
        assert!(spectral(eta_h, ae, be, p) < 1.0);
    }

    #[test]
    fn downpour_gs_form_matches_direct_downpour_sync() {
        // With (a,b) = (1,p) the GS step must equal synchronous
        // DOWNPOUR τ=1: x̃' = x̃ − η Σ gᵢ and workers restart at x̃'...
        // (restart happens at the NEXT round's a=1 pull; here we check
        // the center.)
        let p = 3;
        let n = 4;
        let mut workers: Vec<Vec<f32>> = vec![vec![2.0; n]; p];
        let mut center = vec![2.0f32; n];
        let grads: Vec<Vec<f32>> = (0..p)
            .map(|i| vec![0.1 * (i as f32 + 1.0); n])
            .collect();
        let eta = 0.5;
        gs_step(&mut workers, &mut center, &grads, eta, 1.0, p as f32);
        let gsum: f32 = (0..p).map(|i| 0.1 * (i as f32 + 1.0)).sum();
        for j in 0..n {
            assert!((center[j] - (2.0 - eta * gsum)).abs() < 1e-5,
                    "center {} vs {}", center[j], 2.0 - eta * gsum);
        }
    }

    #[test]
    fn jacobi_and_gs_easgd_agree_to_first_order() {
        // For small rates the two forms differ at O(αβ); check the
        // drift spectra are close.
        let p = 8;
        let (a, b) = (0.01, 0.08);
        let gs = spectral(0.05, a, b, p);
        let jac = spectral_radius(&crate::sim::moments::easgd_drift_matrix(
            0.05, a, b, p,
        ));
        assert!((gs - jac).abs() < 0.02, "gs {gs} vs jacobi {jac}");
    }

    #[test]
    fn gs_consensus_on_quadratic() {
        // Run the concrete GS dynamics on F = x²/2: everyone → 0.
        let p = 4;
        let n = 8;
        let mut workers: Vec<Vec<f32>> = vec![vec![5.0; n]; p];
        let mut center = vec![5.0f32; n];
        let (a, b) = easgd_rates(p);
        for _ in 0..3000 {
            let grads: Vec<Vec<f32>> = workers.iter().map(|w| w.clone()).collect();
            gs_step(&mut workers, &mut center, &grads, 0.1, a as f32, b as f32);
        }
        assert!(center.iter().all(|c| c.abs() < 1e-2), "{center:?}");
    }
}
