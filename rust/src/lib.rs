//! # elastic-train
//!
//! A Rust + JAX + Pallas reproduction of *Distributed stochastic
//! optimization for deep learning* (Sixin Zhang, NYU thesis, 2016) — the
//! Elastic Averaging SGD (EASGD) thesis.
//!
//! Layer 3 of the three-layer stack: the distributed-training
//! coordinator. The JAX/Pallas layers (L2 model, L1 kernels) are
//! AOT-lowered to HLO text at build time (`make artifacts`) and executed
//! here through the PJRT C API (the `xla` crate, behind the off-by-default
//! `pjrt` feature); Python is never on the training path.
//!
//! Module map (see DESIGN.md for the full inventory):
//! - [`error`] — string-backed error substrate (`Result`, `err!`,
//!   `bail!`, `Context`; the offline crate set has no anyhow).
//! - [`rng`], [`linalg`] — numeric substrates (deterministic RNG;
//!   dense eigenvalues for the stability figures; the
//!   [`linalg::gemm`] register-blocked f32 micro-kernels under the
//!   batched MLP oracle, with an explicit AVX2+FMA / NEON kernel tier
//!   in [`linalg::simd`] behind the off-by-default `simd` feature and
//!   the `simd=` knob, threaded across per-worker [`linalg::pool`]
//!   MR-row — or, for short-m × wide-n shapes, NR-column — panels
//!   when `threads= > 1`).
//! - [`sim`] — the thesis' analysis chapters as executable models
//!   (closed-form MSE, moment matrices, ADMM round-robin maps,
//!   the non-convex double well).
//! - [`cluster`] — virtual-time simulated cluster (latency/bandwidth
//!   links, compute/data/comm accounting, Table 4.4 semantics).
//! - [`model`], [`data`] — flat parameter buffers + fused native update
//!   ops; the batch-major GEMM-backed gradient models behind the
//!   [`model::BatchModel`] trait — the MLP stand-in and the
//!   §4.1-faithful im2col conv net (`model::conv`), both
//!   allocation-free at steady state and selected by the
//!   `model=mlp|conv` knob; synthetic corpora and the §4.1 prefetch
//!   pipeline (mini-batches served strictly in pool cut order).
//! - [`coordinator`] — EASGD/EAMSGD, DOWNPOUR and friends behind the
//!   [`coordinator::Executor`] abstraction: two backends (virtual-time
//!   [`coordinator::SimExecutor`], real-thread
//!   [`coordinator::ThreadExecutor`]) × two
//!   [`coordinator::Topology`]s (flat star, method-complete on both
//!   backends — sharded-lock center for the decoupled methods, the
//!   `coordinator::master_actor` serialized master thread for
//!   MDOWNPOUR / async ADMM; the Chapter-6 EASGD **Tree** —
//!   `coordinator::tree` in virtual time,
//!   `coordinator::tree_threaded` as one actor thread per node over
//!   `mpsc` channels), with a checked method/backend/topology
//!   support matrix ([`coordinator::check_supported`]); sequential
//!   baselines and round-robin ADMM ride along. The process backend's
//!   frame protocol is data: [`coordinator::protocol`] holds both
//!   sides' typed transition tables and the `ProtocolState` checker
//!   every process send/recv drives through (fuzzed by the `fuzz_wire`
//!   binary against [`coordinator::wire`]).
//! - [`runtime`] — PJRT artifact loading (always) and execution
//!   (`pjrt` feature; the in-tree `vendor/xla` stub keeps it compiling
//!   offline).
//! - [`config`] — the key=value config system, including the knob
//!   registry ([`config::registry`]: every CLI knob with its surfaces,
//!   diffed against the real structs/forwarding by lint R5, and the
//!   generator of the `train` usage text); [`figures`] — one
//!   generator per thesis table/figure, backend-selectable via
//!   `backend=sim|thread`.
//! - [`sync`] — the synchronization shim every concurrent module
//!   imports instead of `std::sync`/`std::thread` (enforced by
//!   `tests/repo_lint.rs`): `std` re-exports normally, loom's
//!   instrumented equivalents under `RUSTFLAGS="--cfg loom"` so
//!   `tests/loom_models.rs` can model-check the hand-rolled protocols.

pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod figures;
pub mod linalg;
pub mod model;
pub mod rng;
pub mod runtime;
pub mod sim;
pub mod sync;
