//! Flat-parameter update ops — the L3 hot path.
//!
//! Semantics are identical to the L1 Pallas kernels in
//! `python/compile/kernels/easgd_update.py` (which lower to the
//! `sgd_step` / `elastic` / `fused_step` HLO artifacts); the rust
//! versions exist so the coordinator can update million-element buffers
//! without a PJRT round-trip. `runtime::tests` cross-checks the two
//! paths numerically; `bench_update_hot_path` races them.
//!
//! All loops are written to auto-vectorize: slice iterators, no bounds
//! checks in the hot loop, fused multiply-adds where the compiler finds
//! them.

/// v' = delta·v − eta·g ; x' = x + v'. With `delta == 0` this is plain
/// SGD (thesis Alg. 1 inner update). The gradient is assumed evaluated
/// at the Nesterov lookahead point by the caller (thesis Alg. 2).
pub fn nesterov_step(x: &mut [f32], v: &mut [f32], g: &[f32], eta: f32, delta: f32) {
    assert_eq!(x.len(), v.len());
    assert_eq!(x.len(), g.len());
    for ((xi, vi), gi) in x.iter_mut().zip(v.iter_mut()).zip(g) {
        let vn = delta * *vi - eta * *gi;
        *vi = vn;
        *xi += vn;
    }
}

/// Plain SGD step x' = x − eta·g.
pub fn sgd_step(x: &mut [f32], g: &[f32], eta: f32) {
    assert_eq!(x.len(), g.len());
    for (xi, gi) in x.iter_mut().zip(g) {
        *xi -= eta * gi;
    }
}

/// The symmetric elastic exchange (thesis Alg. 1 steps a/b):
/// d = alpha·(x − c); x ← x − d; c ← c + d. Returns nothing; both
/// buffers move toward each other — x + c is exactly conserved.
pub fn elastic_exchange(x: &mut [f32], c: &mut [f32], alpha: f32) {
    assert_eq!(x.len(), c.len());
    for (xi, ci) in x.iter_mut().zip(c.iter_mut()) {
        let d = alpha * (*xi - *ci);
        *xi -= d;
        *ci += d;
    }
}

/// One-sided elastic pull: x ← x − alpha·(x − c), with the opposite
/// force accumulated into `delta_out` for a deferred master update
/// (the non-blocking Jacobi protocol of §2.2).
pub fn elastic_pull(x: &mut [f32], c: &[f32], delta_out: &mut [f32], alpha: f32) {
    assert_eq!(x.len(), c.len());
    assert_eq!(x.len(), delta_out.len());
    for ((xi, ci), di) in x.iter_mut().zip(c).zip(delta_out.iter_mut()) {
        let d = alpha * (*xi - *ci);
        *xi -= d;
        *di = d;
    }
}

/// Accumulate: c ← c + d (the master's half of the deferred exchange;
/// also DOWNPOUR's gradient push).
pub fn accumulate(c: &mut [f32], d: &[f32]) {
    assert_eq!(c.len(), d.len());
    for (ci, di) in c.iter_mut().zip(d) {
        *ci += di;
    }
}

/// Moving average c ← (1−a)·c + a·x (ADOWNPOUR / MVADOWNPOUR / ASGD /
/// MVASGD center updates, and the EASGD-Tree Gauss-Seidel arrival rule).
pub fn moving_average(c: &mut [f32], x: &[f32], a: f32) {
    assert_eq!(c.len(), x.len());
    for (ci, xi) in c.iter_mut().zip(x) {
        *ci += a * (xi - *ci);
    }
}

/// Squared L2 distance between two buffers (consensus diagnostics).
pub fn dist2(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| {
            let d = (*x - *y) as f64;
            d * d
        })
        .sum()
}

/// Euclidean norm (divergence detection in sweeps).
pub fn norm2(a: &[f32]) -> f64 {
    a.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; n];
        rng.fill_gaussian_f32(&mut v, 1.0);
        v
    }

    #[test]
    fn nesterov_matches_scalar_reference() {
        let mut rng = Rng::new(1);
        let n = 1537;
        let (mut x, mut v, g) = (rand_vec(&mut rng, n), rand_vec(&mut rng, n), rand_vec(&mut rng, n));
        let (x0, v0) = (x.clone(), v.clone());
        nesterov_step(&mut x, &mut v, &g, 0.1, 0.9);
        for i in 0..n {
            let vn = 0.9 * v0[i] - 0.1 * g[i];
            assert!((v[i] - vn).abs() < 1e-7);
            assert!((x[i] - (x0[i] + vn)).abs() < 1e-6);
        }
    }

    #[test]
    fn sgd_is_nesterov_with_zero_momentum() {
        let mut rng = Rng::new(2);
        let n = 999;
        let (mut x1, g) = (rand_vec(&mut rng, n), rand_vec(&mut rng, n));
        let mut x2 = x1.clone();
        let mut v = vec![0.0f32; n];
        sgd_step(&mut x1, &g, 0.05);
        nesterov_step(&mut x2, &mut v, &g, 0.05, 0.0);
        assert_eq!(x1, x2);
    }

    #[test]
    fn elastic_exchange_conserves_sum_exactly() {
        let mut rng = Rng::new(3);
        let n = 2048;
        let (mut x, mut c) = (rand_vec(&mut rng, n), rand_vec(&mut rng, n));
        let sums: Vec<f32> = x.iter().zip(&c).map(|(a, b)| a + b).collect();
        elastic_exchange(&mut x, &mut c, 0.37);
        for i in 0..n {
            // The force is computed once and applied with ±; only f32
            // rounding of the two additions can differ.
            assert!((x[i] + c[i] - sums[i]).abs() <= 1e-5 * sums[i].abs().max(1.0));
        }
    }

    #[test]
    fn elastic_pull_plus_accumulate_equals_exchange() {
        let mut rng = Rng::new(4);
        let n = 512;
        let (mut x1, mut c1) = (rand_vec(&mut rng, n), rand_vec(&mut rng, n));
        let (mut x2, mut c2) = (x1.clone(), c1.clone());
        elastic_exchange(&mut x1, &mut c1, 0.2);
        let mut d = vec![0.0f32; n];
        elastic_pull(&mut x2, &c2.clone(), &mut d, 0.2);
        accumulate(&mut c2, &d);
        assert_eq!(x1, x2);
        assert_eq!(c1, c2);
    }

    #[test]
    fn moving_average_endpoints() {
        let mut c = vec![1.0f32, 2.0, 3.0];
        let x = vec![5.0f32, 5.0, 5.0];
        let c0 = c.clone();
        moving_average(&mut c, &x, 0.0);
        assert_eq!(c, c0);
        moving_average(&mut c, &x, 1.0);
        assert_eq!(c, x);
    }

    #[test]
    fn repeated_exchange_converges_to_midpoint() {
        let mut x = vec![0.0f32; 16];
        let mut c = vec![10.0f32; 16];
        for _ in 0..200 {
            elastic_exchange(&mut x, &mut c, 0.2);
        }
        for i in 0..16 {
            assert!((x[i] - 5.0).abs() < 1e-3);
            assert!((c[i] - 5.0).abs() < 1e-3);
        }
    }

    #[test]
    fn dist2_and_norm2() {
        let a = vec![3.0f32, 0.0];
        let b = vec![0.0f32, 4.0];
        assert!((dist2(&a, &b) - 25.0).abs() < 1e-12);
        assert!((norm2(&a) - 3.0).abs() < 1e-12);
    }
}
