//! Hybrid parallelism: the per-worker GEMM thread pool.
//!
//! Today's executors parallelize across workers (p threads or
//! processes); this module parallelizes *inside* each worker's
//! gradient step, so p workers × c threads compose — the hybrid
//! data-parallel × tensor-parallel layout. [`super::gemm::sgemm`] and
//! [`super::gemm::sgemm_bias_act`] split their output into contiguous
//! panels — row panels along M aligned to the [`super::gemm::MR`]
//! register-tile boundary by default, or column panels along N aligned
//! to [`super::gemm::NR`] when M is too short to feed every helper and
//! N is wide ([`plan_for`] picks the axis) — and hand panels 1.. to
//! parked helper threads while the calling thread computes panel 0.
//! Every output element is produced whole, by exactly one thread, with
//! the serial kernels' inner-loop order — so the threaded result is
//! **bitwise identical** to the single-thread one *within a kernel
//! tier* (see [`super::simd`]), and `threads=1` (the default) bypasses
//! this module entirely.
//!
//! Design constraints, in order:
//!
//! - **No new deps, no work stealing.** One `Mutex<Ctrl>` + two
//!   `Condvar`s (job start, job done) park the helpers; a job is a
//!   `Copy` descriptor of raw panel pointers. On Linux both primitives
//!   are futex-backed, so a steady-state dispatch performs **zero heap
//!   allocations** (`tests/alloc_free.rs` enforces this after pool
//!   warm-up).
//! - **One pool per OS thread** (`thread_local!`): the thread backend's
//!   p workers each own their helpers, which is exactly the
//!   "threads-per-worker" meaning of the `threads=` knob. Helpers spawn
//!   lazily on first threaded dispatch ("spawn-once") and are joined
//!   when the owning worker thread exits.
//! - **Process-global target** ([`configure_threads`], seeded from the
//!   `ELASTIC_TRAIN_THREADS` environment variable when unset): models
//!   call the free `gemm` functions with no handle to thread a count
//!   through, and a freshly spawned worker thread must inherit the
//!   run's setting without plumbing.
//!
//! The per-thread scratch of this decomposition is each helper's
//! MR×NR accumulator tile — panels write disjoint C elements, so no
//! reduction buffer exists to race on.

use super::gemm::{exec_span, Job, MR, NR};
use super::simd;
use crate::sync::atomic::{AtomicUsize, Ordering};
use crate::sync::{thread, Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::cell::RefCell;
use std::time::Instant;

/// Hard cap on threads-per-worker (a sanity bound, not a tuning
/// target; the oversubscription clamp keeps real runs far below it).
pub const MAX_THREADS: usize = 64;

/// Minimum `m·n·max(k,1)` below which a GEMM runs serially even at
/// `threads > 1`: a dispatch round-trip costs ~µs, which only pays for
/// itself on panels of tens of thousands of multiply-adds. Either path
/// yields bitwise-identical output, so this is purely a latency knob.
const PAR_MIN_WORK: usize = 32 * 1024;

/// Configured threads-per-worker. 0 = not yet configured: the first
/// reader seeds it from `ELASTIC_TRAIN_THREADS` (default 1).
static TARGET: AtomicUsize = AtomicUsize::new(0);

/// Detected core count, cached (reading `/proc` on every GEMM dispatch
/// would both cost time and allocate).
static CORES: AtomicUsize = AtomicUsize::new(0);

/// Cached `((thread_count, kernel_tier), speedup)` of the last
/// calibration run. Keyed by tier as well as threads: SIMD kernels
/// shift the compute/synchronization balance, so the same thread count
/// calibrates differently per tier.
static SPEEDUP: Mutex<Option<((usize, simd::Tier), f64)>> = Mutex::new(None);

/// The axis a GEMM's output is partitioned along when dispatched on
/// the pool. Rows is the default (whole cache-friendly C rows per
/// panel); Cols is the wide-n fallback for short M, where row tiles
/// would leave helpers idle.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub(crate) enum Split {
    /// MR-aligned row panels of `[0, m)`.
    Rows,
    /// NR-aligned column panels of `[0, n)`.
    Cols,
}

/// Detected available cores (cached after the first call).
pub fn available_cores() -> usize {
    let c = CORES.load(Ordering::Relaxed);
    if c != 0 {
        return c;
    }
    let n = thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    CORES.store(n, Ordering::Relaxed);
    n
}

fn clamp_threads(req: usize) -> usize {
    req.clamp(1, MAX_THREADS)
}

/// Set the process-global threads-per-worker target; returns the
/// effective (clamped to `1..=MAX_THREADS`) value. `1` restores the
/// byte-for-byte serial path.
pub fn configure_threads(req: usize) -> usize {
    let t = clamp_threads(req);
    TARGET.store(t, Ordering::Relaxed);
    t
}

/// Current threads-per-worker target. On the very first call of the
/// process (nothing configured yet) this reads `ELASTIC_TRAIN_THREADS`;
/// a malformed value is a loud panic, not a silent default — the same
/// no-silent-fallback contract as the config parser.
pub fn configured_threads() -> usize {
    match TARGET.load(Ordering::Relaxed) {
        0 => {
            let t = match std::env::var("ELASTIC_TRAIN_THREADS") {
                Ok(v) => match v.parse::<usize>() {
                    Ok(n) if n >= 1 => clamp_threads(n),
                    _ => panic!("ELASTIC_TRAIN_THREADS must be a positive integer, got '{v}'"),
                },
                Err(_) => 1,
            };
            TARGET.store(t, Ordering::Relaxed);
            t
        }
        t => t,
    }
}

/// Clamp a threads-per-worker request against the visible cores for a
/// run with `workers` concurrently-computing workers, printing the loud
/// `hybrid-oversubscription` warning when it lowers the request.
/// `workers` alone exceeding the cores is not this knob's concern (the
/// thesis deliberately oversubscribes p at times); only the *product*
/// p × c is clamped.
pub fn clamp_oversubscription(threads: usize, workers: usize) -> usize {
    let threads = clamp_threads(threads);
    let workers = workers.max(1);
    let cores = available_cores();
    if threads.saturating_mul(workers) <= cores {
        return threads;
    }
    let clamped = (cores / workers).max(1);
    if clamped < threads {
        eprintln!(
            "warning[hybrid-oversubscription]: {workers} workers × threads={threads} would \
             oversubscribe the {cores} visible cores; clamping to threads={clamped} per worker"
        );
    }
    clamped
}

/// Plan a GEMM of shape `m × n × k` at the configured target: the
/// effective thread count plus the split axis. Serial (`(1, Rows)`)
/// below the [`PAR_MIN_WORK`] threshold; rows when M has tiles enough,
/// columns when M is short but N is wide.
pub(crate) fn plan_for(m: usize, n: usize, k: usize) -> (usize, Split) {
    plan_with(configured_threads(), m, n, k)
}

/// Pure planning core, factored out so tests pin the policy without
/// touching the process-global thread target.
fn plan_with(t: usize, m: usize, n: usize, k: usize) -> (usize, Split) {
    if t <= 1 || m.saturating_mul(n).saturating_mul(k.max(1)) < PAR_MIN_WORK {
        return (1, Split::Rows);
    }
    let row_tiles = tiles(m);
    let col_tiles = n.div_ceil(NR);
    // Rows win ties: whole output rows stream B and C contiguously per
    // thread. Columns only when rows would leave helpers starved AND
    // the column axis actually offers more panels.
    let (split, avail) = if row_tiles >= t || row_tiles >= col_tiles {
        (Split::Rows, row_tiles)
    } else {
        (Split::Cols, col_tiles)
    };
    let t_eff = t.min(avail);
    if t_eff <= 1 {
        (1, Split::Rows)
    } else {
        (t_eff, split)
    }
}

fn tiles(m: usize) -> usize {
    m.div_ceil(MR)
}

/// Tile-aligned contiguous partition of `[0, len)` into `t` ranges:
/// range `idx`'s start sits on a `gran` boundary (or at `len`), and
/// the last non-empty range absorbs the sub-tile tail so the serial
/// kernels' tail loop runs exactly where it would single-threaded.
fn split_range(len: usize, gran: usize, t: usize, idx: usize) -> (usize, usize) {
    let tiles = len.div_ceil(gran);
    let (q, r) = (tiles / t, tiles % t);
    let t0 = idx * q + idx.min(r);
    let t1 = t0 + q + usize::from(idx < r);
    ((t0 * gran).min(len), (t1 * gran).min(len))
}

/// Row range `[i0, i1)` of C owned by `idx` (0 = the dispatching
/// thread) when `m` rows are split over `t` threads — MR-aligned.
pub(crate) fn range_for(m: usize, t: usize, idx: usize) -> (usize, usize) {
    split_range(m, MR, t, idx)
}

/// Column range `[j0, j1)` of C owned by `idx` when `n` columns are
/// split over `t` threads — NR-aligned.
pub(crate) fn col_range_for(n: usize, t: usize, idx: usize) -> (usize, usize) {
    split_range(n, NR, t, idx)
}

/// The span of `job` owned by participant `idx`, on whichever axis the
/// job is split along.
pub(crate) fn span_for(job: &Job, t: usize, idx: usize) -> (usize, usize) {
    match job.split() {
        Split::Rows => range_for(job.rows(), t, idx),
        Split::Cols => col_range_for(job.cols(), t, idx),
    }
}

/// Panels available along `job`'s split axis (what caps `t_eff`), and
/// the full span length (what a serial fallback must cover).
fn split_extent(job: &Job) -> (usize, usize) {
    match job.split() {
        Split::Rows => (tiles(job.rows()), job.rows()),
        Split::Cols => (job.cols().div_ceil(NR), job.cols()),
    }
}

struct Ctrl {
    /// Bumped once per dispatched job; helpers wake on a change.
    epoch: u64,
    /// The active job (valid while `remaining > 0`).
    job: Option<Job>,
    /// Threads participating in the active job (incl. the dispatcher).
    t_eff: usize,
    /// Helpers that have not yet finished the active job.
    remaining: usize,
    shutdown: bool,
}

struct Shared {
    ctrl: Mutex<Ctrl>,
    start: Condvar,
    done: Condvar,
}

fn lock_ctrl(shared: &Shared) -> MutexGuard<'_, Ctrl> {
    shared.ctrl.lock().unwrap_or_else(PoisonError::into_inner)
}

/// A spawn-once helper-thread pool owned by one dispatching thread.
/// Helpers park on a condvar between jobs; a job hands each
/// participant one tile-aligned panel of the output.
pub struct GemmPool {
    shared: Arc<Shared>,
    helpers: Vec<thread::JoinHandle<()>>,
}

impl Default for GemmPool {
    fn default() -> Self {
        Self::new()
    }
}

impl GemmPool {
    pub fn new() -> Self {
        GemmPool {
            shared: Arc::new(Shared {
                ctrl: Mutex::new(Ctrl {
                    epoch: 0,
                    job: None,
                    t_eff: 1,
                    remaining: 0,
                    shutdown: false,
                }),
                start: Condvar::new(),
                done: Condvar::new(),
            }),
            helpers: Vec::new(),
        }
    }

    /// Grow to at least `want` helpers (spawn-once: existing helpers
    /// are reused across jobs and across thread-count changes).
    fn ensure_helpers(&mut self, want: usize) {
        while self.helpers.len() < want {
            // Helper slots are 1-based: slot 0 is the dispatcher.
            let slot = self.helpers.len() + 1;
            let shared = Arc::clone(&self.shared);
            // A helper spawned between jobs must not treat the *current*
            // epoch as new work: seed its last-seen epoch under the lock.
            let seen = lock_ctrl(&shared).epoch;
            let handle = thread::Builder::new()
                .name(format!("gemm-pool-{slot}"))
                .spawn(move || helper_loop(shared, slot, seen))
                .expect("spawn gemm pool helper");
            self.helpers.push(handle);
        }
    }

    /// Run `job` across `t` threads (the caller plus `t − 1` helpers).
    /// The caller computes panel 0 in place of parking.
    ///
    /// Correctness rests on two invariants: `span_for` hands each
    /// participant a disjoint span, and this method does not return
    /// until every helper has finished — so the raw panel pointers
    /// inside `job` never outlive the caller's borrows.
    pub(crate) fn run(&mut self, job: &Job, t: usize) {
        let (avail, full) = split_extent(job);
        let t_eff = t.min(avail).max(1);
        if t_eff <= 1 {
            exec_span(job, 0, full);
            return;
        }
        self.ensure_helpers(t_eff - 1);
        {
            let mut c = lock_ctrl(&self.shared);
            c.job = Some(*job);
            c.t_eff = t_eff;
            c.remaining = t_eff - 1;
            c.epoch = c.epoch.wrapping_add(1);
            self.shared.start.notify_all();
        }
        let (s0, s1) = span_for(job, t_eff, 0);
        exec_span(job, s0, s1);
        let mut c = lock_ctrl(&self.shared);
        while c.remaining > 0 {
            c = self
                .shared
                .done
                .wait(c)
                .unwrap_or_else(PoisonError::into_inner);
        }
        c.job = None;
    }
}

impl Drop for GemmPool {
    fn drop(&mut self) {
        {
            let mut c = lock_ctrl(&self.shared);
            c.shutdown = true;
            self.shared.start.notify_all();
        }
        for h in self.helpers.drain(..) {
            let _ = h.join();
        }
    }
}

fn helper_loop(shared: Arc<Shared>, slot: usize, mut seen: u64) {
    loop {
        let (job, t_eff);
        {
            let mut c = lock_ctrl(&shared);
            loop {
                if c.shutdown {
                    return;
                }
                if c.epoch != seen {
                    break;
                }
                c = shared.start.wait(c).unwrap_or_else(PoisonError::into_inner);
            }
            seen = c.epoch;
            if slot >= c.t_eff {
                // Not a participant this job (the pool once grew larger
                // than the current thread count); park again.
                continue;
            }
            job = c.job.expect("an active epoch always carries a job");
            t_eff = c.t_eff;
        }
        let (s0, s1) = span_for(&job, t_eff, slot);
        exec_span(&job, s0, s1);
        {
            let mut c = lock_ctrl(&shared);
            // Underflow here would mean a helper executed the same
            // epoch twice; debug builds (all test lanes) panic on it.
            c.remaining -= 1;
            if c.remaining == 0 {
                // The last finisher wakes the dispatcher. Dropping this
                // notify is the canonical lost-wakeup bug; CI compiles
                // with `--cfg loom_mutate_lost_notify` to prove the
                // loom GemmPool model catches it (the dispatcher hangs
                // in `done.wait` and the model watchdog fires).
                #[cfg(not(loom_mutate_lost_notify))]
                shared.done.notify_one();
            }
        }
    }
}

thread_local! {
    /// This thread's pool. Each executor worker thread lazily owns its
    /// own helpers; the `thread_local!` destructor drops the pool (and
    /// so joins the helpers — see [`GemmPool::drop`]) when the owning
    /// thread exits. Between jobs helpers *park* on the `start` condvar
    /// (futex wait — zero CPU), never spin. The one gap is the process'
    /// main thread, whose TLS destructors are not guaranteed to run at
    /// exit: call [`shutdown_local_pool`] there (tests and sanitizer
    /// lanes do) instead of relying on process teardown.
    static POOL: RefCell<GemmPool> = RefCell::new(GemmPool::new());
}

/// Dispatch `job` on the calling thread's pool at `t` threads.
pub(crate) fn run(job: &Job, t: usize) {
    POOL.with(|p| p.borrow_mut().run(job, t));
}

/// Join the calling thread's helper threads now, instead of at thread
/// exit. The pool is reset to an empty one, so later threaded dispatch
/// from this thread transparently respawns helpers; the call is cheap
/// when no helpers were ever spawned. TSan/loom/Miri lanes call this so
/// a test never ends with detached helpers still parked.
pub fn shutdown_local_pool() {
    POOL.with(|p| {
        // Swap first, drop outside the borrow: the old pool's Drop
        // joins helpers, and a helper could (in principle) re-enter
        // POOL via a nested dispatch.
        let old = std::mem::take(&mut *p.borrow_mut());
        drop(old);
    });
}

/// Measured speedup of the threaded GEMM at the *configured* thread
/// count and the *active* kernel tier, from a quick (~tens of ms, once
/// per process per setting) calibration on a representative fused
/// forward panel. 1.0 at `threads = 1` without measuring. The sim
/// backend divides the cost model's local-step time by this, so
/// virtual-time sweeps price the c-thread local step the way the real
/// backends experience it — including how much less a SIMD tier gains
/// from extra threads.
pub fn measured_speedup() -> f64 {
    let t = configured_threads();
    if t <= 1 {
        return 1.0;
    }
    let tier = simd::active_tier();
    let mut cache = SPEEDUP.lock().unwrap_or_else(PoisonError::into_inner);
    if let Some((key, s)) = *cache {
        if key == (t, tier) {
            return s;
        }
    }
    let s = calibrate(t);
    *cache = Some(((t, tier), s));
    s
}

fn calibrate(t: usize) -> f64 {
    // A mid-size fused forward panel: comfortably above PAR_MIN_WORK,
    // small enough that best-of-5 × 4 reps × 2 settings stays in the
    // tens of milliseconds.
    let (m, n, k) = (256usize, 64, 128);
    let a = vec![0.5f32; m * k];
    let b = vec![0.25f32; k * n];
    let bias = vec![0.1f32; n];
    let mut c = vec![0.0f32; m * n];
    let mut best_of = |c: &mut [f32]| {
        let mut best = f64::INFINITY;
        for _ in 0..5 {
            let t0 = Instant::now();
            for _ in 0..4 {
                super::gemm::sgemm_bias_act(m, n, k, &a, &b, &bias, true, c);
            }
            best = best.min(t0.elapsed().as_secs_f64() / 4.0);
        }
        best
    };
    // Both paths produce bitwise-identical output, so briefly flipping
    // the global target only changes *speed* for any concurrent
    // dispatcher, never results.
    TARGET.store(1, Ordering::Relaxed);
    let serial = best_of(&mut c);
    TARGET.store(t, Ordering::Relaxed);
    let threaded = best_of(&mut c);
    std::hint::black_box(&c);
    let s = serial / threaded.max(1e-12);
    if s.is_finite() && s > 0.0 {
        s
    } else {
        1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranges_partition_all_rows_mr_aligned() {
        for &m in &[0usize, 1, 3, 4, 5, 8, 9, 31, 64, 67, 129] {
            for &t in &[1usize, 2, 3, 4, 7] {
                let mut next = 0;
                for idx in 0..t {
                    let (i0, i1) = range_for(m, t, idx);
                    assert_eq!(i0, next, "m={m} t={t} idx={idx}: ranges must be contiguous");
                    assert!(
                        i0 % MR == 0 || i0 == m,
                        "m={m} t={t} idx={idx}: panel start {i0} breaks an MR tile"
                    );
                    assert!(i0 <= i1 && i1 <= m);
                    next = i1;
                }
                assert_eq!(next, m, "m={m} t={t}: ranges must cover every row");
            }
        }
    }

    #[test]
    fn col_ranges_partition_all_columns_nr_aligned() {
        for &n in &[0usize, 1, 15, 16, 17, 64, 100, 1024, 4096] {
            for &t in &[1usize, 2, 3, 4, 7] {
                let mut next = 0;
                for idx in 0..t {
                    let (j0, j1) = col_range_for(n, t, idx);
                    assert_eq!(j0, next, "n={n} t={t} idx={idx}: ranges must be contiguous");
                    assert!(
                        j0 % NR == 0 || j0 == n,
                        "n={n} t={t} idx={idx}: panel start {j0} breaks an NR tile"
                    );
                    assert!(j0 <= j1 && j1 <= n);
                    next = j1;
                }
                assert_eq!(next, n, "n={n} t={t}: ranges must cover every column");
            }
        }
    }

    #[test]
    fn small_m_gives_fewer_threads_than_requested() {
        // 2 tiles can feed at most 2 threads; the rest get empty ranges.
        let m = 5; // tiles = 2
        let (a0, a1) = range_for(m, 4, 0);
        let (b0, b1) = range_for(m, 4, 1);
        let (c0, c1) = range_for(m, 4, 2);
        assert_eq!((a0, a1), (0, 4));
        assert_eq!((b0, b1), (4, 5));
        assert_eq!((c0, c1), (5, 5), "surplus threads own empty panels");
    }

    #[test]
    fn plan_prefers_rows_and_falls_back_to_columns_when_rows_starve() {
        // Plenty of row tiles: rows at full t.
        assert_eq!(plan_with(4, 256, 64, 64), (4, Split::Rows));
        // One row tile but a wide n: the column split keeps all 4
        // threads fed (ROADMAP item 4's named remaining upside).
        assert_eq!(plan_with(4, 4, 4096, 32), (4, Split::Cols));
        // Short m AND narrow n: rows win the tie, clamped to the tiles.
        assert_eq!(plan_with(4, 8, 32, 512), (2, Split::Rows));
        // Below the work threshold: serial, regardless of shape.
        assert_eq!(plan_with(4, 4, 4096, 0), (1, Split::Rows));
        assert_eq!(plan_with(4, 16, 16, 16), (1, Split::Rows));
        // threads=1 never plans a split.
        assert_eq!(plan_with(1, 256, 4096, 64), (1, Split::Rows));
        // Degenerate: an empty output is serial.
        assert_eq!(plan_with(4, 0, 4096, 64), (1, Split::Rows));
    }

    #[test]
    fn wide_n_column_split_is_bitwise_identical_to_serial() {
        // The satellite shape: 4 × 4096 output (one MR tile, 256 NR
        // tiles) — the row split would run this serially at t=4; the
        // column split must keep helpers busy AND stay bitwise equal.
        // Under Miri the shape shrinks to the smallest one that still
        // clears PAR_MIN_WORK with a single row tile (so the column
        // split still engages) — the interpreter is ~10⁴× slower.
        let before = configured_threads();
        let (m, n, k) = if cfg!(miri) { (4usize, 512, 16) } else { (4usize, 4096, 32) };
        let a: Vec<f32> = (0..m * k).map(|i| (i % 97) as f32 * 0.0625 - 3.0).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i % 89) as f32 * 0.03125 - 1.0).collect();
        let bias: Vec<f32> = (0..n).map(|j| (j % 13) as f32 * 0.25 - 1.5).collect();
        configure_threads(1);
        let mut serial = vec![0.5f32; m * n];
        crate::linalg::gemm::sgemm(false, false, m, n, k, &a, &b, &mut serial);
        let mut serial_fused = vec![0.0f32; m * n];
        crate::linalg::gemm::sgemm_bias_act(m, n, k, &a, &b, &bias, true, &mut serial_fused);
        configure_threads(4);
        let mut threaded = vec![0.5f32; m * n];
        crate::linalg::gemm::sgemm(false, false, m, n, k, &a, &b, &mut threaded);
        let mut threaded_fused = vec![0.0f32; m * n];
        crate::linalg::gemm::sgemm_bias_act(m, n, k, &a, &b, &bias, true, &mut threaded_fused);
        assert!(serial == threaded, "column-split sgemm != serial bitwise");
        assert!(serial_fused == threaded_fused, "column-split fused != serial bitwise");
        configure_threads(before.max(1));
    }

    #[test]
    fn configure_threads_clamps_and_reports() {
        assert_eq!(configure_threads(0), 1);
        assert_eq!(configure_threads(MAX_THREADS + 100), MAX_THREADS);
        assert_eq!(configure_threads(3), 3);
        configure_threads(1);
    }

    #[test]
    fn oversubscription_clamps_the_product_not_p() {
        let cores = available_cores();
        assert!(cores >= 1);
        // p alone exceeding the cores is untouched at threads=1.
        assert_eq!(clamp_oversubscription(1, cores * 8), 1);
        // A huge product is pulled back under the core count (or to 1).
        let c = clamp_oversubscription(MAX_THREADS, 2);
        assert!(c == 1 || c * 2 <= cores.max(2), "clamped to {c} on {cores} cores");
    }

    #[test]
    fn shutdown_local_pool_joins_and_respawns_cleanly() {
        let before = configured_threads();
        configure_threads(3);
        let (m, n, k) = (64usize, 32, 32);
        let a = vec![1.0f32; m * k];
        let b = vec![0.5f32; k * n];
        let mut c1 = vec![0.0f32; m * n];
        let mut c2 = vec![0.0f32; m * n];
        crate::linalg::gemm::sgemm(false, false, m, n, k, &a, &b, &mut c1);
        // Helpers are parked now; shutting down must join them and a
        // later dispatch must respawn a working pool.
        shutdown_local_pool();
        crate::linalg::gemm::sgemm(false, false, m, n, k, &a, &b, &mut c2);
        assert_eq!(c1, c2, "pool must survive a shutdown/respawn cycle");
        shutdown_local_pool();
        // Idempotent on an empty pool.
        shutdown_local_pool();
        configure_threads(before);
    }

    #[test]
    #[cfg_attr(miri, ignore = "timing calibration is meaningless and slow under Miri")]
    fn measured_speedup_is_identity_at_one_thread_and_finite_above() {
        configure_threads(1);
        assert_eq!(measured_speedup(), 1.0);
        configure_threads(2);
        let s = measured_speedup();
        assert!(s.is_finite() && s > 0.0, "speedup {s}");
        configure_threads(1);
    }
}
