//! Dense linear-algebra substrate. Two tiers with different jobs:
//!
//! - **Eigen tier** ([`Matrix`], [`eigenvalues`], [`spectral_radius`];
//!   f64): every stability figure in the thesis (Figs 3.2, 5.1–5.19)
//!   is the spectral radius of a small dense, generally
//!   *non-symmetric* matrix — the drift/moment matrices of the
//!   optimization dynamics and the composed round-robin ADMM maps. We
//!   therefore need a general real eigenvalue solver: Householder
//!   Hessenberg reduction followed by complex Wilkinson-shifted QR
//!   with deflation — compact, robust for the ≤ 20×20 matrices the
//!   figures sweep over millions of times.
//! - **Throughput tier** ([`gemm`]; f32): register-blocked GEMM
//!   micro-kernels ([`gemm::sgemm`] with transpose flags, the fused
//!   [`gemm::sgemm_bias_act`] bias+ReLU epilogue) under the batched
//!   MLP oracle's forward/backward — the wall clock of every
//!   Chapter-4/6 sweep and both real-thread backends. Two orthogonal
//!   accelerators compose under it: the [`simd`] module selects a
//!   kernel *tier* (scalar / AVX2+FMA / NEON, behind the off-by-default
//!   `simd` cargo feature and the `simd=` knob), and the [`pool`]
//!   module parallelizes whichever tier is active across a per-worker
//!   helper thread pool (MR-aligned row panels, or NR-aligned column
//!   panels for short-m × wide-n shapes; bitwise-identical to serial
//!   within a tier) behind the `threads=` knob — the hybrid p workers
//!   × c threads layout.

mod complex;
mod eig;
pub mod gemm;
mod matrix;
pub mod pool;
pub mod simd;

pub use complex::Complex;
pub use eig::{eigenvalues, spectral_radius};
pub use matrix::Matrix;

#[cfg(test)]
mod tests {
    use super::*;

    fn sorted_abs(mut v: Vec<Complex>) -> Vec<f64> {
        let mut a: Vec<f64> = v.drain(..).map(|z| z.abs()).collect();
        a.sort_by(|x, y| x.partial_cmp(y).unwrap());
        a
    }

    #[test]
    fn diagonal_matrix_eigenvalues() {
        let m = Matrix::diag(&[3.0, -1.0, 0.5, 7.0]);
        let got = sorted_abs(eigenvalues(&m));
        let want = [0.5, 1.0, 3.0, 7.0];
        for (g, w) in got.iter().zip(want) {
            assert!((g - w).abs() < 1e-10, "{g} vs {w}");
        }
    }

    #[test]
    fn rotation_matrix_has_unit_complex_pair() {
        // [[cos, -sin], [sin, cos]] has eigenvalues e^{±iθ}.
        let th = 0.7f64;
        let m = Matrix::from_rows(&[
            &[th.cos(), -th.sin()],
            &[th.sin(), th.cos()],
        ]);
        let eig = eigenvalues(&m);
        assert_eq!(eig.len(), 2);
        for z in &eig {
            assert!((z.abs() - 1.0).abs() < 1e-10);
            assert!((z.re - th.cos()).abs() < 1e-10);
        }
        assert!((spectral_radius(&m) - 1.0).abs() < 1e-10);
    }

    #[test]
    fn companion_matrix_roots() {
        // p(x) = x^3 - 6x^2 + 11x - 6 = (x-1)(x-2)(x-3).
        let m = Matrix::from_rows(&[
            &[6.0, -11.0, 6.0],
            &[1.0, 0.0, 0.0],
            &[0.0, 1.0, 0.0],
        ]);
        let got = sorted_abs(eigenvalues(&m));
        for (g, w) in got.iter().zip([1.0, 2.0, 3.0]) {
            assert!((g - w).abs() < 1e-8, "{g} vs {w}");
        }
    }

    #[test]
    fn trace_and_det_consistency_random() {
        let mut rng = crate::rng::Rng::new(314);
        for n in [2usize, 3, 5, 8, 13] {
            let mut m = Matrix::zeros(n, n);
            for i in 0..n {
                for j in 0..n {
                    m.set(i, j, rng.normal(0.0, 1.0));
                }
            }
            let eig = eigenvalues(&m);
            assert_eq!(eig.len(), n);
            let sum: Complex = eig.iter().fold(Complex::ZERO, |a, &b| a + b);
            let trace: f64 = (0..n).map(|i| m.get(i, i)).sum();
            assert!((sum.re - trace).abs() < 1e-7 * (1.0 + trace.abs()),
                    "n={n} trace {} vs {}", sum.re, trace);
            assert!(sum.im.abs() < 1e-7, "imag parts must cancel");
        }
    }

    #[test]
    fn defective_jordan_block_converges() {
        // Jordan block: repeated eigenvalue 2 with no full eigenbasis.
        let m = Matrix::from_rows(&[
            &[2.0, 1.0, 0.0],
            &[0.0, 2.0, 1.0],
            &[0.0, 0.0, 2.0],
        ]);
        for z in eigenvalues(&m) {
            assert!((z.re - 2.0).abs() < 1e-4 && z.im.abs() < 1e-4);
        }
    }

    #[test]
    fn matmul_identity_and_associativity() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let i = Matrix::identity(2);
        assert_eq!(a.matmul(&i).as_slice(), a.as_slice());
        let b = Matrix::from_rows(&[&[0.0, 1.0], &[1.0, 0.0]]);
        let c = Matrix::from_rows(&[&[2.0, 0.0], &[0.0, 2.0]]);
        let lhs = a.matmul(&b).matmul(&c);
        let rhs = a.matmul(&b.matmul(&c));
        for (x, y) in lhs.as_slice().iter().zip(rhs.as_slice()) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn spectral_radius_of_contraction_below_one() {
        // The EASGD round-robin 2x2 block from §3.3 at a stable setting.
        let (eta, alpha) = (0.5, 0.3);
        let m = Matrix::from_rows(&[
            &[1.0 - eta - alpha, alpha],
            &[alpha, 1.0 - alpha],
        ]);
        assert!(spectral_radius(&m) < 1.0);
    }
}
