//! The multi-process star backend (`backend=process`): a parameter
//! server owning the center variable, with workers as separate OS
//! processes exchanging flat-θ frames over real sockets
//! ([`super::wire`]).
//!
//! This is the tier every single-address-space backend only models: a
//! "round trip" here is a serialize → socket write → master update →
//! socket read → deserialize chain, so the communication period τ, the
//! message size, and the staleness a worker sees are MEASURED physical
//! quantities (the thesis ran EASGD/DOWNPOUR on a real cluster; the
//! Elastic Consistency framework of 2001.05918 bounds exactly these).
//!
//! Topology of one run:
//! * [`run_process`] (the master) binds a TCP or Unix-domain listener,
//!   spawns `p` copies of its own executable with the hidden
//!   `--process-worker` subcommand, and serves one handler thread per
//!   worker connection. Handlers share the center state behind a
//!   poison-recovering mutex and apply each arriving exchange
//!   atomically (whole-vector — the 1-shard regime of the thread
//!   backend's sharded lock).
//! * The worker ([`process_worker_main`]) rebuilds its oracle and RNG
//!   stream deterministically from CLI arguments (an [`OracleSpec`] is
//!   the serializable recipe — live oracles cannot cross a process
//!   boundary), dials the master, and runs the standard decoupled
//!   local-step loop, exchanging every τ steps.
//!
//! Protocol (all frames [`super::wire::Frame`]):
//! `Hello(wid)` → `Init(θ₀)` · then per round `Push(payload)` →
//! `Center(reply)` (or `Stop(reply)` once the master's horizon is
//! reached) · finally `Done(steps, [compute_s, comm_s, serialize_s,
//! transfer_s])`, or `Diverged` on a non-finite local loss.
//!
//! Failure semantics are deliberately loud: a worker process dying
//! mid-run surfaces as a descriptive `Err` (its socket closes before
//! `Done`) and stops the remaining workers promptly, a worker that
//! never dials trips the accept timeout, and a nonzero worker exit
//! status fails the run even when its socket lifecycle looked clean.
//!
//! Method support: the master-DEcoupled methods (EASGD / EAMSGD,
//! DOWNPOUR / ADOWNPOUR / MVADOWNPOUR) on the star topology —
//! [`super::executor::check_supported`] gates the rest with
//! descriptive errors.

use super::executor::{eval_point, DriverConfig, WorkerState};
use super::method::Method;
use super::oracle::GradOracle;
use super::protocol::ProtocolState;
use super::threaded::lock_recover;
use super::wire::{
    send_frame, Frame, FrameKind, WireAddr, WireClock, WireListener, WireStream,
};
use crate::cluster::{RunResult, TimeBreakdown, WireStats};
use crate::config::Args;
use crate::error::Result;
use crate::model::flat;
use crate::rng::Rng;
use crate::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use crate::sync::{thread, Mutex};
use std::path::PathBuf;
use std::time::{Duration, Instant};

/// How the master reaches its workers.
#[derive(Clone, Debug)]
pub struct ProcessOpts {
    /// Listener address. TCP port 0 binds an ephemeral port; the
    /// actual address is passed to the spawned workers.
    pub addr: WireAddr,
    /// Worker executable; defaults to `std::env::current_exe()` (the
    /// self-exec contract). Tests and benches override it with
    /// `env!("CARGO_BIN_EXE_repro")`.
    pub exe: Option<PathBuf>,
    /// GEMM threads per spawned worker process (forwarded on each
    /// worker's command line; the caller is expected to have clamped
    /// p × threads against the visible cores already).
    pub threads: usize,
    /// Kernel-tier knob (`simd=auto|avx2|neon|scalar`), forwarded on
    /// each worker's command line so every process in a run computes
    /// on the same tier; an unavailable tier fails the worker loudly
    /// at startup.
    pub simd: String,
    /// Test-only fault injection: `(wid, mode)` forwards `fault=mode`
    /// to that one worker so integration tests can drive a rogue peer
    /// against the master's protocol checker over a real socket.
    /// Modes: `push-before-hello`. Never set on production paths.
    pub fault: Option<(usize, String)>,
}

impl Default for ProcessOpts {
    fn default() -> Self {
        ProcessOpts {
            addr: WireAddr::Tcp("127.0.0.1:0".into()),
            exe: None,
            threads: 1,
            simd: "auto".into(),
            fault: None,
        }
    }
}

static SOCK_COUNTER: AtomicU64 = AtomicU64::new(0);

impl ProcessOpts {
    /// Parse the `transport=tcp|unix`, `host=`, `port=` knobs.
    pub fn from_args(args: &Args) -> Result<ProcessOpts> {
        let addr = match args.get_str("transport", "tcp") {
            "tcp" => {
                let host = args.get_str("host", "127.0.0.1");
                let port = args.get_u16("port", 0)?;
                WireAddr::Tcp(format!("{host}:{port}"))
            }
            "unix" => Self::unix_addr()?,
            other => crate::bail!("unknown transport '{other}' (tcp|unix)"),
        };
        let simd = args.get_str("simd", "auto");
        if !crate::linalg::simd::is_known_request(simd) {
            crate::bail!("unknown simd tier '{simd}' (auto|avx2|neon|scalar)");
        }
        Ok(ProcessOpts { addr, exe: None, threads: 1, simd: simd.to_string(), fault: None })
    }

    /// A fresh Unix-domain socket path in the temp dir (pid + counter,
    /// so concurrent runs in one process don't collide).
    pub fn unix_addr() -> Result<WireAddr> {
        #[cfg(unix)]
        {
            let k = SOCK_COUNTER.fetch_add(1, Ordering::Relaxed);
            Ok(WireAddr::Unix(std::env::temp_dir().join(format!(
                "elastic_train_{}_{k}.sock",
                std::process::id()
            ))))
        }
        #[cfg(not(unix))]
        {
            Err(crate::err!("unix-domain sockets are not available on this platform"))
        }
    }
}

/// A serializable oracle recipe: what a worker process needs to
/// rebuild its [`GradOracle`] bit-identically to the master's
/// evaluator. (Live oracles hold data pools and scratch panels; only
/// the recipe crosses the process boundary.)
#[derive(Clone, Debug, PartialEq)]
pub enum OracleSpec {
    /// The deterministic quadratic (equivalence tests, bench grids).
    Quadratic { n: usize, h: f32, x0: f32, target: f32, noise: f32 },
    /// The ch4 sweep workload: blob dataset + MLP/conv model through
    /// the §4.1 prefetch pipeline. `seed` is the sweep seed — data is
    /// `sweep_data(seed + 1)`, worker i's pool seed is `40_000 + i`
    /// (the `family_sharded` layout of [`super::oracle::NativeOracle`]).
    Sweep {
        model: crate::model::ModelKind,
        sharding: crate::data::Sharding,
        batch: usize,
        seed: u64,
    },
}

impl OracleSpec {
    /// Build worker `wid`'s oracle (wid 0 doubles as the evaluator).
    pub fn build(&self, wid: usize) -> Box<dyn GradOracle + Send> {
        match *self {
            OracleSpec::Quadratic { n, h, x0, target, noise } => {
                Box::new(super::oracle::QuadraticOracle::new(n, h, x0, target, noise))
            }
            OracleSpec::Sweep { model, sharding, batch, seed } => {
                // The canonical sweep constructors live in the figure
                // harness; reusing them here is what guarantees a
                // worker process rebuilds the exact master-side
                // workload from the seed alone.
                let data = crate::figures::ch4::sweep_data(seed + 1);
                let pool_seed = 40_000 + wid as u64;
                match model {
                    crate::model::ModelKind::Mlp => Box::new(super::oracle::MlpOracle::new_sharded(
                        data,
                        crate::figures::ch4::sweep_mlp(),
                        batch,
                        pool_seed,
                        sharding,
                    )),
                    crate::model::ModelKind::Conv => {
                        Box::new(super::oracle::ConvOracle::new_sharded(
                            data,
                            crate::figures::ch4::sweep_conv(),
                            batch,
                            pool_seed,
                            sharding,
                        ))
                    }
                }
            }
        }
    }

    fn to_args(&self) -> Vec<String> {
        match self {
            OracleSpec::Quadratic { n, h, x0, target, noise } => vec![
                "oracle=quad".into(),
                format!("qn={n}"),
                format!("qh={h}"),
                format!("qx0={x0}"),
                format!("qtarget={target}"),
                format!("qnoise={noise}"),
            ],
            OracleSpec::Sweep { model, sharding, batch, seed } => vec![
                "oracle=sweep".into(),
                format!("model={}", model.name()),
                format!("sharding={}", sharding.name()),
                format!("batch={batch}"),
                format!("oseed={seed}"),
            ],
        }
    }

    fn from_args(args: &Args) -> Result<OracleSpec> {
        match args.get_str("oracle", "") {
            "quad" => Ok(OracleSpec::Quadratic {
                n: args.get_usize("qn", 0)?,
                h: args.get_f32("qh", 1.0)?,
                x0: args.get_f32("qx0", 0.0)?,
                target: args.get_f32("qtarget", 0.0)?,
                noise: args.get_f32("qnoise", 0.0)?,
            }),
            "sweep" => {
                let ms = args.get_str("model", "mlp");
                let model = crate::model::ModelKind::parse(ms)
                    .ok_or_else(|| crate::err!("unknown model '{ms}' (mlp|conv)"))?;
                let ss = args.get_str("sharding", "replicated");
                let sharding = crate::data::Sharding::parse(ss)
                    .ok_or_else(|| crate::err!("unknown sharding '{ss}'"))?;
                Ok(OracleSpec::Sweep {
                    model,
                    sharding,
                    batch: args.get_usize("batch", 32)?,
                    seed: args.get_u64("oseed", 0)?,
                })
            }
            other => Err(crate::err!("unknown oracle spec '{other}' (quad|sweep)")),
        }
    }
}

/// Method → worker CLI arguments (the process-gated subset of methods).
fn method_to_args(m: Method) -> Result<Vec<String>> {
    Ok(match m {
        Method::Easgd { alpha, tau } => {
            vec!["method=easgd".into(), format!("alpha={alpha}"), format!("tau={tau}")]
        }
        Method::Eamsgd { alpha, tau, delta } => vec![
            "method=eamsgd".into(),
            format!("alpha={alpha}"),
            format!("tau={tau}"),
            format!("delta={delta}"),
        ],
        Method::Downpour { tau } => vec!["method=downpour".into(), format!("tau={tau}")],
        Method::ADownpour { tau } => vec!["method=adownpour".into(), format!("tau={tau}")],
        Method::MvaDownpour { tau, alpha } => vec![
            "method=mvadownpour".into(),
            format!("tau={tau}"),
            format!("mva_alpha={alpha}"),
        ],
        Method::MDownpour { .. } | Method::AdmmAsync { .. } => {
            return Err(crate::err!(
                "{} is master-coupled and not implemented on backend=process; \
                 use backend=thread (master actor) or backend=sim",
                m.name()
            ))
        }
    })
}

fn method_from_args(args: &Args) -> Result<Method> {
    let tau = args.get_u32("tau", 1)?;
    let alpha = args.get_f32("alpha", 0.0)?;
    Ok(match args.get_str("method", "") {
        "easgd" => Method::Easgd { alpha, tau },
        "eamsgd" => Method::Eamsgd { alpha, tau, delta: args.get_f32("delta", 0.99)? },
        "downpour" => Method::Downpour { tau },
        "adownpour" => Method::ADownpour { tau },
        "mvadownpour" => Method::MvaDownpour { tau, alpha: args.get_f32("mva_alpha", 0.001)? },
        other => return Err(crate::err!("unknown process-worker method '{other}'")),
    })
}

/// Master-side center state, shared by the handler threads behind one
/// poison-recovering mutex (whole-vector atomic exchanges).
struct CenterState {
    center: Vec<f32>,
    /// Averaged center (ADOWNPOUR / MVADOWNPOUR).
    z: Option<Vec<f32>>,
    /// Master clock: center-update rounds applied.
    clock: u64,
    /// Master clock at each worker's previous exchange (staleness).
    last_round: Vec<u64>,
    stale_sum: u64,
    stale_rounds: u64,
}

impl CenterState {
    /// Apply one worker push and build the reply payload.
    fn apply(&mut self, method: Method, wid: usize, payload: &[f32]) -> Result<Vec<f32>> {
        if payload.len() != self.center.len() {
            return Err(crate::err!(
                "worker {wid} pushed {} f32s, center has {} — mismatched oracle specs?",
                payload.len(),
                self.center.len()
            ));
        }
        let reply = match method {
            Method::Easgd { alpha, .. } | Method::Eamsgd { alpha, .. } => {
                // Elastic exchange against the atomic whole-vector
                // center: θ' = θ − α(θ − c), c += α(θ − c).
                let mut reply = payload.to_vec();
                flat::elastic_exchange(&mut reply, &mut self.center, alpha);
                reply
            }
            Method::Downpour { .. } | Method::ADownpour { .. } | Method::MvaDownpour { .. } => {
                // Alg. 3: absorb the accumulated update, reply with
                // the fresh center.
                flat::accumulate(&mut self.center, payload);
                self.center.clone()
            }
            Method::MDownpour { .. } | Method::AdmmAsync { .. } => {
                return Err(crate::err!(
                    "master-coupled method on the process master — check_supported should \
                     have refused this run"
                ))
            }
        };
        self.clock += 1;
        match method {
            Method::ADownpour { .. } => {
                let a = 1.0 / (self.clock as f32);
                match self.z.as_mut() {
                    Some(z) => flat::moving_average(z, &self.center, a),
                    None => return Err(missing_z(method)),
                }
            }
            Method::MvaDownpour { alpha, .. } => {
                match self.z.as_mut() {
                    Some(z) => flat::moving_average(z, &self.center, alpha),
                    None => return Err(missing_z(method)),
                }
            }
            _ => {}
        }
        // Staleness: center rounds applied by OTHER workers since this
        // worker's previous exchange (its own just-applied round is
        // excluded by measuring against the pre-update clock).
        let st = (self.clock - 1).saturating_sub(self.last_round[wid]);
        self.stale_sum += st;
        self.stale_rounds += 1;
        self.last_round[wid] = self.clock;
        Ok(reply)
    }

    fn snapshot(&self) -> Vec<f32> {
        self.z.as_ref().unwrap_or(&self.center).clone()
    }
}

/// The averaged-center buffer `z` is allocated at init iff the method
/// is averaged; reaching an averaged update without it is an init bug
/// in [`run_process`], surfaced as a typed error rather than a panic.
fn missing_z(method: Method) -> crate::error::Error {
    crate::err!("{} master has no averaged center z — init/method mismatch", method.name())
}

/// What one handler thread learned from its worker's `Done` frame.
struct WorkerReport {
    steps: u64,
    compute_s: f64,
    comm_s: f64,
    serialize_s: f64,
    transfer_s: f64,
    /// Master-side wire accounting for this connection.
    wire: WireClock,
}

/// Serve one worker connection: handshake (the `Hello` names the
/// worker — accept order is racy), then rounds until `Done`. Every
/// frame is driven through a [`ProtocolState`] checker, so a worker
/// process dying (socket error) AND a peer sending out-of-order frames
/// (protocol violation) both surface as loud, descriptive failures
/// that stop the surviving workers promptly.
fn serve_worker(
    conn: WireStream,
    method: Method,
    init: &[f32],
    state: &Mutex<CenterState>,
    stop: &AtomicBool,
    diverged: &AtomicBool,
) -> Result<WorkerReport> {
    let r = serve_worker_loop(conn, method, init, state, stop, diverged);
    if r.is_err() {
        // The loudest failure in the protocol: a worker died or broke
        // the frame protocol. Stop the rest so the error surfaces now,
        // not after the surviving workers burn the whole budget.
        stop.store(true, Ordering::Relaxed);
    }
    r
}

fn serve_worker_loop(
    mut conn: WireStream,
    method: Method,
    init: &[f32],
    state: &Mutex<CenterState>,
    stop: &AtomicBool,
    diverged: &AtomicBool,
) -> Result<WorkerReport> {
    let mut ck = WireClock::default();
    let mut proto = ProtocolState::master();
    // The checker subsumes the old manual kind check: anything but a
    // Hello in the AwaitHello state is a typed protocol violation
    // naming the state and the offending frame.
    let hello = proto
        .recv(&mut conn, &mut ck)
        .map_err(|e| crate::err!("a worker connected but sent no valid Hello frame: {e}"))?;
    let wid = hello.wid as usize;
    proto.send(&mut conn, &Frame::new(FrameKind::Init, 0, 0, init.to_vec()), &mut ck)?;
    loop {
        let frame = proto.recv(&mut conn, &mut ck).map_err(|e| {
            crate::err!("worker {wid} died or broke protocol before its Done frame: {e}")
        })?;
        match frame.kind {
            FrameKind::Push => {
                let reply = {
                    let mut st = lock_recover(state);
                    st.apply(method, wid, &frame.payload)?
                };
                let kind =
                    if stop.load(Ordering::Relaxed) { FrameKind::Stop } else { FrameKind::Center };
                proto.send(&mut conn, &Frame::new(kind, 0, frame.clock, reply), &mut ck)?;
            }
            FrameKind::Diverged => {
                diverged.store(true, Ordering::Relaxed);
                stop.store(true, Ordering::Relaxed);
            }
            FrameKind::Done => {
                let p = &frame.payload;
                if p.len() != 4 {
                    return Err(crate::err!(
                        "worker {wid}: malformed Done stats (got {} fields, expected 4)",
                        p.len()
                    ));
                }
                return Ok(WorkerReport {
                    steps: frame.clock,
                    compute_s: p[0] as f64,
                    comm_s: p[1] as f64,
                    serialize_s: p[2] as f64,
                    transfer_s: p[3] as f64,
                    wire: ck,
                });
            }
            // Unreachable once proto.recv succeeded (the Serve state
            // admits only Push/Diverged/Done), kept as defense in
            // depth against a table edit outrunning this match.
            other => return Err(crate::err!("worker {wid}: unexpected {other:?} frame mid-run")),
        }
    }
}

/// Run one distributed experiment with workers as separate OS
/// processes over real sockets (the star topology's `backend=process`).
///
/// `spec` must describe the same oracle family on both sides; the
/// master builds `spec.build(0)` as the post-run evaluator, worker `i`
/// rebuilds `spec.build(i)` after self-exec. Timing semantics match
/// the thread backend (real seconds, measured columns), with
/// `breakdown.serialize` / `breakdown.transfer` additionally reporting
/// the measured wire costs and [`RunResult::wire`] the frame / byte /
/// staleness counters.
pub fn run_process(
    spec: &OracleSpec,
    p: usize,
    cfg: &DriverConfig,
    opts: &ProcessOpts,
) -> Result<RunResult> {
    if p == 0 {
        crate::bail!("p must be >= 1");
    }
    cfg.validate()?;
    super::executor::check_supported(
        cfg.method,
        super::executor::Backend::Process,
        &super::topology::Topology::Star,
    )?;

    let mut eval_oracle = spec.build(0);
    let init = eval_oracle.init_params();
    let (listener, actual) = WireListener::bind(&opts.addr)?;

    let exe = match &opts.exe {
        Some(e) => e.clone(),
        None => std::env::current_exe()
            .map_err(|e| crate::err!("cannot resolve current executable for self-exec: {e}"))?,
    };
    // Per-worker budget: the thread backend's global atomic budget has
    // no cross-process analogue, so the cap is split evenly.
    let max_local = (cfg.max_steps / p as u64).max(1);

    let mut children = Vec::with_capacity(p);
    for wid in 0..p {
        let mut cmd = std::process::Command::new(&exe);
        cmd.arg("--process-worker")
            .arg(format!("addr={}", actual.to_arg()))
            .arg(format!("wid={wid}"))
            .arg(format!("eta={}", cfg.eta))
            .arg(format!("gamma={}", cfg.lr_decay_gamma))
            .arg(format!("seed={}", cfg.seed))
            .arg(format!("max_local={max_local}"))
            .arg(format!("horizon={}", cfg.horizon))
            .arg(format!("threads={}", opts.threads))
            .arg(format!("simd={}", opts.simd))
            .args(method_to_args(cfg.method)?)
            .args(spec.to_args());
        if let Some((fault_wid, mode)) = &opts.fault {
            if *fault_wid == wid {
                cmd.arg(format!("fault={mode}"));
            }
        }
        cmd
            .stdin(std::process::Stdio::null())
            .stdout(std::process::Stdio::inherit())
            .stderr(std::process::Stdio::inherit());
        match cmd.spawn() {
            Ok(child) => children.push(child),
            Err(e) => {
                kill_children(&mut children);
                return Err(crate::err!("cannot spawn worker {wid} ({}): {e}", exe.display()));
            }
        }
    }

    let averaged = matches!(cfg.method, Method::ADownpour { .. } | Method::MvaDownpour { .. });
    let state = Mutex::new(CenterState {
        center: init.clone(),
        z: if averaged { Some(init.clone()) } else { None },
        clock: 0,
        last_round: vec![0; p],
        stale_sum: 0,
        stale_rounds: 0,
    });
    let stop = AtomicBool::new(false);
    let diverged = AtomicBool::new(false);

    // Accept every worker BEFORE serving any: the Init replies then go
    // out together, so workers start their clocks roughly in step.
    let mut conns = Vec::with_capacity(p);
    for _ in 0..p {
        match listener.accept_timeout(Duration::from_secs(60)) {
            Ok(conn) => conns.push(conn),
            Err(e) => {
                kill_children(&mut children);
                return Err(e);
            }
        }
    }

    let mut snaps: Vec<(f64, Vec<f32>)> = Vec::new();
    let mut reports: Vec<Result<WorkerReport>> = Vec::new();
    let t0 = Instant::now();
    thread::scope(|s| {
        let handles: Vec<_> = conns
            .into_iter()
            .map(|conn| {
                let (state, stop, diverged, init) = (&state, &stop, &diverged, &init);
                s.spawn(move || serve_worker(conn, cfg.method, init, state, stop, diverged))
            })
            .collect();
        let cadence = cfg.eval_every.max(1e-3);
        let mut next_eval = 0.0f64;
        loop {
            let el = t0.elapsed().as_secs_f64();
            if el >= next_eval {
                snaps.push((el, lock_recover(&state).snapshot()));
                next_eval += cadence;
            }
            if el > cfg.horizon {
                stop.store(true, Ordering::Relaxed);
            }
            if handles.iter().all(|h| h.is_finished()) {
                break;
            }
            thread::sleep(Duration::from_micros(200));
        }
        for h in handles {
            reports.push(
                h.join().unwrap_or_else(|_| Err(crate::err!("a master handler thread panicked"))),
            );
        }
    });
    snaps.push((t0.elapsed().as_secs_f64(), lock_recover(&state).snapshot()));

    // Reap the children; a nonzero exit is a loud failure even when
    // the socket lifecycle looked clean.
    let mut exit_err: Option<crate::error::Error> = None;
    for (wid, child) in children.iter_mut().enumerate() {
        match child.wait() {
            Ok(status) if !status.success() && exit_err.is_none() => {
                exit_err = Some(crate::err!("worker {wid} exited with {status}"));
            }
            Err(e) if exit_err.is_none() => {
                exit_err = Some(crate::err!("cannot reap worker {wid}: {e}"));
            }
            _ => {}
        }
    }
    cleanup_unix_socket(&actual);

    let mut ok_reports = Vec::with_capacity(p);
    for r in reports {
        ok_reports.push(r?);
    }
    if let Some(e) = exit_err {
        return Err(e);
    }

    let mut result = RunResult::default();
    let mut div = diverged.load(Ordering::Relaxed);
    for (t, theta) in &snaps {
        if !eval_point(&mut eval_oracle, theta, *t, &mut result.curve) {
            div = true;
        }
    }
    let st = lock_recover(&state);
    result.total_steps = ok_reports.iter().map(|r| r.steps).sum();
    result.rounds = st.clock;
    result.wire = Some(WireStats {
        frames: ok_reports.iter().map(|r| r.wire.frames).sum(),
        payload_bytes: ok_reports.iter().map(|r| r.wire.payload_bytes).sum(),
        mean_staleness: if st.stale_rounds == 0 {
            0.0
        } else {
            st.stale_sum as f64 / st.stale_rounds as f64
        },
    });
    result.breakdown = TimeBreakdown {
        compute: ok_reports.iter().map(|r| r.compute_s).sum(),
        data: 0.0,
        comm: ok_reports.iter().map(|r| r.comm_s).sum(),
        serialize: ok_reports.iter().map(|r| r.serialize_s).sum(),
        transfer: ok_reports.iter().map(|r| r.transfer_s).sum(),
    };
    result.diverged = div;
    Ok(result)
}

fn kill_children(children: &mut [std::process::Child]) {
    for c in children.iter_mut() {
        let _ = c.kill();
        let _ = c.wait();
    }
}

fn cleanup_unix_socket(addr: &WireAddr) {
    #[cfg(unix)]
    if let WireAddr::Unix(p) = addr {
        let _ = std::fs::remove_file(p);
    }
    #[cfg(not(unix))]
    let _ = addr;
}

/// The hidden `--process-worker` entry point: rebuild the oracle and
/// RNG stream from CLI args, dial the master, run the decoupled local
/// loop, exchange every τ steps, report measured stats in `Done`.
pub fn process_worker_main(args: &Args) -> Result<()> {
    let addr = WireAddr::parse(args.get_str("addr", ""))?;
    let wid = args.get_usize("wid", 0)?;
    let method = method_from_args(args)?;
    let spec = OracleSpec::from_args(args)?;
    let seed = args.get_u64("seed", 0)?;
    let max_local = args.get_u64("max_local", u64::MAX / 2)?;
    let horizon = args.get_f64("horizon", f64::INFINITY)?;
    // Hybrid parallelism: this process IS one worker, so the forwarded
    // `threads=` is its whole GEMM pool budget (the master clamped the
    // p × threads product before spawning).
    crate::linalg::pool::configure_threads(args.get_usize("threads", 1)?);
    // Kernel tier: resolved here, once, before any GEMM dispatch — an
    // unavailable tier kills the worker with a named reason instead of
    // letting processes in one run silently compute on different tiers.
    crate::linalg::simd::configure(args.get_str("simd", "auto"))?;
    let cfg = DriverConfig {
        eta: args.get_f32("eta", 0.05)?,
        method,
        cost: crate::cluster::CostModel::cifar_like(1),
        horizon,
        eval_every: horizon,
        seed,
        max_steps: max_local,
        lr_decay_gamma: args.get_f64("gamma", 0.0)?,
    };

    let mut oracle = spec.build(wid);

    let mut conn = WireStream::connect(&addr)?;
    let mut ck = WireClock::default();
    // Test-only fault injection (forwarded by `ProcessOpts::fault`):
    // play a rogue peer to exercise the master's conformance checker
    // over a real socket. Raw `send_frame` on purpose — the checked
    // path would refuse to put an out-of-order frame on the wire.
    match args.get_str("fault", "") {
        "" => {}
        "push-before-hello" => {
            send_frame(
                &mut conn,
                &Frame::new(FrameKind::Push, wid as u32, 0, vec![0.0]),
                &mut ck,
            )?;
            return Ok(());
        }
        other => crate::bail!("unknown worker fault '{other}' (push-before-hello)"),
    }
    let mut proto = ProtocolState::worker();
    proto.send(&mut conn, &Frame::new(FrameKind::Hello, wid as u32, 0, vec![]), &mut ck)?;
    // The checker subsumes the old manual Init kind check.
    let init_frame = proto
        .recv(&mut conn, &mut ck)
        .map_err(|e| crate::err!("worker {wid}: master sent no valid Init: {e}"))?;
    if init_frame.payload.len() != oracle.n_params() {
        crate::bail!(
            "worker {wid}: Init carries {} params, local oracle has {} — mismatched specs",
            init_frame.payload.len(),
            oracle.n_params()
        );
    }

    // Reproduce worker `wid`'s RNG stream exactly as
    // `WorkerState::family` mints it: `Rng::split` advances the root,
    // so the splits must be replayed in worker order.
    let mut root = Rng::new(seed);
    let mut workers = WorkerState::family(&init_frame.payload, wid + 1, &mut root);
    let mut w = workers.pop().expect("family(wid+1) has wid+1 entries");

    let tau = method.tau().max(1) as u64;
    let mut compute_ns = 0u64;
    let mut comm_ns = 0u64;
    let t_start = Instant::now();

    loop {
        if w.t_local >= max_local || t_start.elapsed().as_secs_f64() > horizon {
            break;
        }
        // No round at t_local == 0, matching the thread backend.
        if w.t_local > 0 && w.t_local % tau == 0 {
            // One communication round: the whole serialize → transfer
            // → master-update → transfer → deserialize chain is comm
            // time; `ck` attributes the serialize/transfer shares.
            let tc = Instant::now();
            let payload = match method {
                Method::Easgd { .. } | Method::Eamsgd { .. } => w.theta.clone(),
                _ => w.aux.clone(),
            };
            proto.send(
                &mut conn,
                &Frame::new(FrameKind::Push, wid as u32, w.t_local, payload),
                &mut ck,
            )?;
            let reply = proto
                .recv(&mut conn, &mut ck)
                .map_err(|e| crate::err!("worker {wid}: master vanished mid-round: {e}"))?;
            let stop = match reply.kind {
                FrameKind::Center | FrameKind::Stop => {
                    w.theta = reply.payload;
                    if !matches!(method, Method::Easgd { .. } | Method::Eamsgd { .. }) {
                        w.aux.iter_mut().for_each(|a| *a = 0.0);
                    }
                    reply.kind == FrameKind::Stop
                }
                // Unreachable once proto.recv succeeded (AwaitReply
                // admits only Center/Stop); defense in depth.
                other => crate::bail!("worker {wid}: unexpected {other:?} reply"),
            };
            comm_ns += tc.elapsed().as_nanos() as u64;
            if stop {
                break;
            }
        }
        let t0 = Instant::now();
        let loss = super::executor::local_step_decoupled(&cfg, &mut w, &mut oracle);
        compute_ns += t0.elapsed().as_nanos() as u64;
        if !loss.is_finite() || flat::norm2(&w.theta) > 1e8 {
            proto.send(
                &mut conn,
                &Frame::new(FrameKind::Diverged, wid as u32, w.t_local, vec![]),
                &mut ck,
            )?;
            break;
        }
    }

    let stats = vec![
        (compute_ns as f64 * 1e-9) as f32,
        (comm_ns as f64 * 1e-9) as f32,
        ck.serialize_s() as f32,
        ck.transfer_s() as f32,
    ];
    proto.send(&mut conn, &Frame::new(FrameKind::Done, wid as u32, w.t_local, stats), &mut ck)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_spec_roundtrips_through_args() {
        let specs = [
            OracleSpec::Quadratic { n: 512, h: 1.0, x0: 0.0, target: 1.0, noise: 0.25 },
            OracleSpec::Sweep {
                model: crate::model::ModelKind::Conv,
                sharding: crate::data::Sharding::Partitioned,
                batch: 64,
                seed: 9,
            },
        ];
        for spec in specs {
            let args = Args::parse(spec.to_args());
            assert_eq!(OracleSpec::from_args(&args).unwrap(), spec);
        }
    }

    #[test]
    fn method_roundtrips_through_args() {
        let methods = [
            Method::Easgd { alpha: 0.225, tau: 4 },
            Method::Eamsgd { alpha: 0.1, tau: 8, delta: 0.9 },
            Method::Downpour { tau: 2 },
            Method::ADownpour { tau: 3 },
            Method::MvaDownpour { tau: 5, alpha: 0.01 },
        ];
        for m in methods {
            let args = Args::parse(method_to_args(m).unwrap());
            assert_eq!(method_from_args(&args).unwrap(), m);
        }
    }

    #[test]
    fn master_coupled_methods_refuse_process_serialization() {
        let e = method_to_args(Method::MDownpour { delta: 0.9 }).unwrap_err();
        assert!(format!("{e}").contains("master-coupled"), "{e}");
        assert!(method_to_args(Method::AdmmAsync { rho: 1.0, tau: 4 }).is_err());
    }

    #[test]
    fn quadratic_spec_builds_identical_oracles_across_wids() {
        let spec = OracleSpec::Quadratic { n: 8, h: 2.0, x0: 0.5, target: 1.0, noise: 0.0 };
        let a = spec.build(0);
        let b = spec.build(3);
        assert_eq!(a.init_params(), b.init_params());
        assert_eq!(a.n_params(), 8);
    }

    #[test]
    fn center_apply_matches_single_shard_elastic_semantics() {
        let mut st = CenterState {
            center: vec![0.0; 4],
            z: None,
            clock: 0,
            last_round: vec![0; 2],
            stale_sum: 0,
            stale_rounds: 0,
        };
        let m = Method::Easgd { alpha: 0.5, tau: 1 };
        let reply = st.apply(m, 0, &[2.0, 2.0, 2.0, 2.0]).unwrap();
        // θ' = 2 − 0.5·2 = 1 ; c = 0 + 0.5·2 = 1.
        assert_eq!(reply, vec![1.0; 4]);
        assert_eq!(st.center, vec![1.0; 4]);
        assert_eq!(st.clock, 1);
        // The second worker's first push sees one stale round (worker
        // 0's) applied since its baseline.
        let _ = st.apply(m, 1, &[1.0, 1.0, 1.0, 1.0]).unwrap();
        assert_eq!(st.stale_sum, 1);
        assert_eq!(st.last_round, vec![1, 2]);
    }

    #[test]
    fn center_apply_rejects_length_mismatch() {
        let mut st = CenterState {
            center: vec![0.0; 4],
            z: None,
            clock: 0,
            last_round: vec![0],
            stale_sum: 0,
            stale_rounds: 0,
        };
        let e = st.apply(Method::Downpour { tau: 1 }, 0, &[1.0]).unwrap_err();
        assert!(format!("{e}").contains("mismatched"), "{e}");
    }
}
