//! The master-actor [`CenterBackend`]: real-thread execution of the
//! master-COUPLED methods (MDOWNPOUR, async ADMM) on the star
//! topology.
//!
//! These methods fold a master update into every local step —
//! MDOWNPOUR's Nesterov master (Algs 4–5) applies each arriving
//! gradient to the center momentum, async ADMM's consensus step
//! recomputes the center mean from the stored worker contributions.
//! Neither update can race shard-by-shard on a lock-striped center:
//! the momentum recursion and the consensus mean are whole-vector
//! recurrences whose terms must be applied one arrival at a time.
//!
//! So the center gets an owner: a dedicated master thread
//! ([`ActorMaster::serve`]) absorbs worker messages over `mpsc`
//! channels and applies them **serialized, in arrival order** — the
//! Gauss–Seidel rule of §6.2, and the same actor pattern
//! [`super::tree_threaded`] uses for interior tree nodes. One
//! serialized-absorb rule now implements tree interior nodes,
//! MDOWNPOUR's master, and async ADMM's consensus step.
//!
//! Per-method protocol (one round trip per message; replies carry the
//! worker's next read of the master, so a worker is stale by exactly
//! the other workers' arrivals since its own last message — genuine
//! asynchrony, serialized application):
//!
//! * **MDOWNPOUR** (τ = 1): the stateless worker evaluates its
//!   gradient at the lookahead x̃ + δv it last received, pushes
//!   `(η_t, g)`; the master applies v ← δv − η_t·g, x̃ ← x̃ + v, and
//!   replies with the fresh lookahead.
//! * **async ADMM** (every τ steps): the worker runs the dual ascent
//!   λⁱ ← λⁱ − (xⁱ − x̃) against its cached center, pushes the
//!   contribution xⁱ − λⁱ; the master stores it, recomputes the
//!   center as the contribution mean (in full, like the sim driver, so
//!   both backends share one rounding story), and replies with the
//!   fresh center, which the worker caches for its next τ linearized
//!   prox steps (Eq 3.53).
//!
//! Timing semantics match [`super::threaded`]: real seconds, measured
//! compute/comm columns, no bit-determinism. ADMM skips the no-op
//! exchange at `t_local == 0` like the sharded backend; MDOWNPOUR's
//! first-step round is NOT skipped — it already carries a real
//! gradient, so every one of its local steps is one master round.

use super::executor::{DriverConfig, WorkerState};
use super::method::Method;
use super::oracle::GradOracle;
use super::threaded::{lock_recover, CenterBackend, Shared};
use crate::sync::atomic::Ordering;
use crate::sync::mpsc::{channel, Receiver, Sender};
use crate::sync::Mutex;
use std::time::Instant;

/// A worker message to the master actor.
enum ToMaster {
    /// MDOWNPOUR gradient push (Alg. 5): apply Nesterov on the master,
    /// reply with the fresh lookahead x̃ + δv.
    Grad { wid: usize, eta: f32, grad: Vec<f32> },
    /// Async ADMM consensus push: replace worker `wid`'s stored
    /// contribution (xⁱ − λⁱ), recompute the center mean, reply with
    /// the fresh center.
    Contrib { wid: usize, contrib: Vec<f32> },
}

/// One worker's channel endpoints, moved into its thread.
pub(crate) struct ActorPort {
    wid: usize,
    tx: Sender<ToMaster>,
    reply: Receiver<Vec<f32>>,
}

/// The master thread's state: touched only by [`ActorMaster::serve`]
/// (one message at a time) and the main thread's snapshot path.
struct ActorState {
    method: Method,
    center: Vec<f32>,
    /// Master momentum (MDOWNPOUR).
    mv: Option<Vec<f32>>,
    /// ADMM: last (xⁱ − λⁱ) contribution per worker.
    contrib: Option<Vec<Vec<f32>>>,
    /// Master clock (# center updates).
    clock: u64,
    reply_tx: Vec<Sender<Vec<f32>>>,
}

impl ActorState {
    /// Apply one absorbed message — THE serialized Gauss–Seidel step —
    /// and reply to its sender.
    fn apply(&mut self, msg: ToMaster) {
        match msg {
            ToMaster::Grad { wid, eta, grad } => {
                let delta = match self.method {
                    Method::MDownpour { delta } => delta,
                    _ => unreachable!("Grad messages are MDOWNPOUR-only"),
                };
                let mv = self.mv.as_mut().expect("MDOWNPOUR allocates mv at init");
                // Alg. 5: v ← δv − η_t g ; x̃ ← x̃ + v.
                for (c, (v, g)) in self.center.iter_mut().zip(mv.iter_mut().zip(&grad)) {
                    *v = delta * *v - eta * g;
                    *c += *v;
                }
                self.clock += 1;
                // Alg. 4: the worker's next read is the lookahead.
                let look: Vec<f32> = self
                    .center
                    .iter()
                    .zip(mv.iter())
                    .map(|(c, v)| c + delta * v)
                    .collect();
                let _ = self.reply_tx[wid].send(look);
            }
            ToMaster::Contrib { wid, contrib } => {
                let contribs = self.contrib.as_mut().expect("ADMM allocates contrib at init");
                contribs[wid] = contrib;
                // Consensus step: center = mean of stored contributions,
                // recomputed in full like the sim driver.
                let inv = 1.0 / contribs.len() as f32;
                for (j, c) in self.center.iter_mut().enumerate() {
                    let mut s = 0.0f32;
                    for w in contribs.iter() {
                        s += w[j];
                    }
                    *c = s * inv;
                }
                self.clock += 1;
                let _ = self.reply_tx[wid].send(self.center.clone());
            }
        }
    }
}

/// The dedicated-master-thread [`CenterBackend`] for master-coupled
/// methods. Construct with [`ActorMaster::new`], hand to
/// [`super::threaded::run_with_center`].
pub(crate) struct ActorMaster {
    rx: Mutex<Receiver<ToMaster>>,
    state: Mutex<ActorState>,
    ports: Mutex<Option<Vec<ActorPort>>>,
}

impl ActorMaster {
    pub(crate) fn new(method: Method, init: &[f32], p: usize) -> ActorMaster {
        let n = init.len();
        let (tx, rx) = channel();
        let mut ports = Vec::with_capacity(p);
        let mut reply_tx = Vec::with_capacity(p);
        for wid in 0..p {
            let (rtx, rrx) = channel();
            reply_tx.push(rtx);
            ports.push(ActorPort { wid, tx: tx.clone(), reply: rrx });
        }
        // Only worker ports hold senders now: when the last worker
        // exits, `serve`'s receive loop disconnects and returns.
        drop(tx);
        let state = ActorState {
            method,
            center: init.to_vec(),
            mv: match method {
                Method::MDownpour { .. } => Some(vec![0.0; n]),
                _ => None,
            },
            contrib: match method {
                Method::AdmmAsync { .. } => Some(vec![init.to_vec(); p]),
                _ => None,
            },
            clock: 0,
            reply_tx,
        };
        ActorMaster {
            rx: Mutex::new(rx),
            state: Mutex::new(state),
            ports: Mutex::new(Some(ports)),
        }
    }
}

impl CenterBackend for ActorMaster {
    type Port = ActorPort;

    fn take_ports(&mut self, p: usize) -> Vec<ActorPort> {
        let ports = lock_recover(&self.ports).take().expect("ports already taken");
        assert_eq!(ports.len(), p);
        ports
    }

    fn snapshot(&self) -> Vec<f32> {
        lock_recover(&self.state).center.clone()
    }

    fn rounds(&self) -> u64 {
        lock_recover(&self.state).clock
    }

    /// The master thread: wake on each arrival, then drain the inbox
    /// under one lock hold, applying every message in arrival order —
    /// the serialized Gauss–Seidel absorb. Returns when every worker
    /// port has been dropped.
    fn serve(&self) {
        let rx = lock_recover(&self.rx);
        while let Ok(msg) = rx.recv() {
            let mut st = lock_recover(&self.state);
            st.apply(msg);
            while let Ok(m) = rx.try_recv() {
                st.apply(m);
            }
        }
    }

    fn step<O: GradOracle>(
        &self,
        cfg: &DriverConfig,
        port: &mut ActorPort,
        w: &mut WorkerState,
        oracle: &mut O,
        sh: &Shared,
    ) -> f32 {
        match cfg.method {
            Method::MDownpour { .. } => {
                // Gradient at the lookahead from the last reply (the
                // shared init before the first one), Alg. 4.
                let eta_t = cfg.eta_at(w.t_local);
                let t0 = Instant::now();
                let loss = oracle.grad(&w.theta, &mut w.rng, &mut w.grad);
                sh.compute_ns
                    .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                w.t_local += 1;
                let tc = Instant::now();
                let _ = port.tx.send(ToMaster::Grad {
                    wid: port.wid,
                    eta: eta_t,
                    grad: w.grad.clone(),
                });
                if let Ok(look) = port.reply.recv() {
                    w.theta = look;
                }
                sh.comm_ns
                    .fetch_add(tc.elapsed().as_nanos() as u64, Ordering::Relaxed);
                loss
            }
            Method::AdmmAsync { rho, .. } => {
                let n = w.theta.len();
                if w.t_local == 0 {
                    // The worker-side center cache (w.scratch) starts at
                    // the shared init — exactly theta before any step.
                    w.scratch.copy_from_slice(&w.theta);
                }
                let tau = cfg.method.tau().max(1) as u64;
                // No round at t_local == 0 (see super::threaded docs).
                if w.t_local > 0 && w.t_local % tau == 0 {
                    let tc = Instant::now();
                    // Dual ascent against the cached center:
                    // λⁱ ← λⁱ − (xⁱ − x̃). λ lives in w.aux.
                    for j in 0..n {
                        w.aux[j] -= w.theta[j] - w.scratch[j];
                    }
                    let contrib: Vec<f32> =
                        w.theta.iter().zip(&w.aux).map(|(t, l)| t - l).collect();
                    let _ = port.tx.send(ToMaster::Contrib { wid: port.wid, contrib });
                    if let Ok(center) = port.reply.recv() {
                        w.scratch = center;
                    }
                    sh.comm_ns
                        .fetch_add(tc.elapsed().as_nanos() as u64, Ordering::Relaxed);
                }
                let eta_t = cfg.eta_at(w.t_local);
                let t0 = Instant::now();
                let loss = oracle.grad(&w.theta, &mut w.rng, &mut w.grad);
                // Linearized prox step (Eq 3.53) toward the cached center.
                let d = 1.0 + eta_t * rho;
                for j in 0..n {
                    w.theta[j] = (w.theta[j] - eta_t * w.grad[j]
                        + eta_t * rho * (w.aux[j] + w.scratch[j]))
                        / d;
                }
                w.t_local += 1;
                sh.compute_ns
                    .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                loss
            }
            _ => unreachable!("decoupled methods use the sharded-lock center"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn actor_state_allocates_per_method() {
        let init = vec![1.0f32; 8];
        let m = ActorMaster::new(Method::MDownpour { delta: 0.9 }, &init, 3);
        {
            let st = lock_recover(&m.state);
            assert!(st.mv.is_some() && st.contrib.is_none());
            assert_eq!(st.reply_tx.len(), 3);
        }
        assert_eq!(m.snapshot(), init);
        assert_eq!(m.rounds(), 0);
        let m = ActorMaster::new(Method::AdmmAsync { rho: 1.0, tau: 4 }, &init, 4);
        let st = lock_recover(&m.state);
        assert!(st.mv.is_none());
        assert_eq!(st.contrib.as_ref().unwrap().len(), 4);
    }

    #[test]
    fn mdownpour_apply_is_nesterov_and_replies_lookahead() {
        let init = vec![0.0f32; 4];
        let mut m = ActorMaster::new(Method::MDownpour { delta: 0.5 }, &init, 1);
        let ports = m.take_ports(1);
        {
            let mut st = lock_recover(&m.state);
            st.apply(ToMaster::Grad { wid: 0, eta: 0.1, grad: vec![1.0; 4] });
            // v = 0.5·0 − 0.1·1 = −0.1 ; x̃ = −0.1.
            assert!(st.center.iter().all(|c| (c + 0.1).abs() < 1e-7));
            assert_eq!(st.clock, 1);
        }
        // Reply = x̃ + δv = −0.1 + 0.5·(−0.1) = −0.15.
        let look = ports[0].reply.recv().unwrap();
        assert!(look.iter().all(|l| (l + 0.15).abs() < 1e-7));
    }

    #[test]
    fn admm_apply_recomputes_the_consensus_mean() {
        let init = vec![0.0f32; 2];
        let mut m = ActorMaster::new(Method::AdmmAsync { rho: 1.0, tau: 1 }, &init, 2);
        let ports = m.take_ports(2);
        {
            let mut st = lock_recover(&m.state);
            st.apply(ToMaster::Contrib { wid: 1, contrib: vec![2.0, 4.0] });
        }
        // Worker 0's stored contribution is still the init (0,0):
        // center = mean{(0,0), (2,4)} = (1,2).
        let c = ports[1].reply.recv().unwrap();
        assert_eq!(c, vec![1.0, 2.0]);
        assert_eq!(m.snapshot(), vec![1.0, 2.0]);
        assert_eq!(m.rounds(), 1);
    }
}
