//! EASGD Tree at scale (thesis Chapter 6): d-ary tree of workers with
//! fully-asynchronous parameter messaging, comparing the two §6.1
//! communication schemes on the synthetic CIFAR-like task.
//!
//!     cargo run --release --example tree_scale -- [leaves=64] [degree=8] \
//!         [eta=0.15] [delta=0] [horizon=25]
//!
//! Thesis scale is leaves=256 degree=16 (use those for the full run).

use elastic_train::cluster::CostModel;
use elastic_train::config::Args;
use elastic_train::coordinator::{run_tree, MlpOracle, TreeConfig, TreeScheme};
use elastic_train::data::BlobDataset;
use elastic_train::model::MlpConfig;
use std::sync::Arc;

fn main() {
    let args = Args::from_env();
    let leaves = args.get_usize("leaves", 64);
    let degree = args.get_usize("degree", 8);
    let eta = args.get_f32("eta", 0.15);
    let delta = args.get_f32("delta", 0.0);
    let horizon = args.get_f64("horizon", 25.0);

    let data = Arc::new(BlobDataset::generate(32, 10, 4096, 512, 2.2, 1));
    let mcfg = MlpConfig::new(&[32, 64, 32, 10], 1e-4);
    let cost = CostModel::cifar_like(mcfg.n_params());

    for (name, scheme) in [
        ("scheme-1 multi-scale (τ1=1, τ2=10)", TreeScheme::MultiScale { tau1: 1, tau2: 10 }),
        ("scheme-2 up/down    (τu=1, τd=10)", TreeScheme::UpDown { tau_up: 1, tau_down: 10 }),
    ] {
        let mut oracles = MlpOracle::family(data.clone(), &mcfg, 16, leaves);
        let cfg = TreeConfig {
            degree,
            leaves,
            scheme,
            alpha: 0.9 / (degree as f32 + 1.0),
            eta,
            delta,
            cost,
            interior_activity: 0.25,
        intra_discount: 0.2,
            horizon,
            eval_every: horizon / 10.0,
            seed: args.get_u64("seed", 0),
            max_events: 200_000_000,
        };
        let t0 = std::time::Instant::now();
        let r = run_tree(&mut oracles, &cfg);
        println!("== {name}: p={leaves}, d={degree}, α=0.9/(d+1), η={eta}, δ={delta}");
        println!("  vt[s]   train_loss  test_err");
        for pt in &r.curve {
            println!("  {:<6.1}  {:<10.4}  {:.3}", pt.time, pt.train_loss, pt.test_error);
        }
        println!(
            "  {} leaf steps, {:.1}s wall, best test err {:.3}{}\n",
            r.total_steps,
            t0.elapsed().as_secs_f64(),
            r.best_test_error(),
            if r.diverged { "  [DIVERGED]" } else { "" }
        );
    }
}
