//! End-to-end validation (DESIGN.md §6): train the AOT-lowered JAX
//! transformer for a few hundred steps with asynchronous EAMSGD over p
//! workers, entirely from rust — gradients come from the
//! `train_step.hlo.txt` artifact through PJRT; the elastic exchange and
//! Nesterov updates run on the native hot path. Python is not involved.
//!
//!     make artifacts               # once (python, build time)
//!     cargo run --release --example train_transformer -- \
//!         [p=4] [steps=300] [eta=0.3] [tau=4] [delta=0.9] [out=out/e2e_loss.csv]
//!
//! The center variable's loss curve is printed and written to CSV; the
//! run recorded in EXPERIMENTS.md used the defaults.

use elastic_train::cluster::CostModel;
use elastic_train::config::Args;
use elastic_train::coordinator::{run_parallel, DriverConfig, Method};
use elastic_train::runtime::{PjrtModel, PjrtOracle};
use std::io::Write;
use std::rc::Rc;

fn main() -> elastic_train::error::Result<()> {
    let args = Args::from_env();
    let p = args.get_usize("p", 4)?;
    let steps = args.get_u64("steps", 300)?;
    let eta = args.get_f32("eta", 0.3)?;
    let tau = args.get_u32("tau", 4)?;
    let delta = args.get_f32("delta", 0.9)?;
    let out = args.get_str("out", "out/e2e_loss.csv").to_string();
    let dir = std::path::PathBuf::from(args.get_str("artifacts", "artifacts"));

    let t0 = std::time::Instant::now();
    let model = Rc::new(PjrtModel::load(&dir)?);
    println!(
        "loaded artifacts: preset={} params={} ({:.1} MB) in {:.1}s",
        model.artifacts.preset,
        model.n_params(),
        model.n_params() as f64 * 4e-6,
        t0.elapsed().as_secs_f64()
    );

    let mut oracles = PjrtOracle::family(model.clone(), 0.05, 4, 42, p);
    let method = if delta > 0.0 {
        Method::Eamsgd { alpha: 0.9 / p as f32, tau, delta }
    } else {
        Method::Easgd { alpha: 0.9 / p as f32, tau }
    };
    println!(
        "running {} p={p} τ={tau} η={eta} δ={delta} for ~{steps} total worker steps",
        method.name()
    );

    let cost = CostModel {
        t_grad: 1e-3,
        jitter: 0.05,
        t_data: 1e-4,
        latency: 1e-4,
        bandwidth: 1e9,
        param_bytes: (model.n_params() * 4) as f64,
    };
    let horizon = steps as f64 * 2.4e-3 / p as f64;
    let cfg = DriverConfig {
        eta,
        method,
        cost,
        horizon,
        eval_every: horizon / 15.0,
        seed: args.get_u64("seed", 0)?,
        max_steps: steps,
        lr_decay_gamma: 0.0,
    };
    let wall0 = std::time::Instant::now();
    let r = run_parallel(&mut oracles, &cfg);
    let wall = wall0.elapsed().as_secs_f64();

    if let Some(parent) = std::path::Path::new(&out).parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::File::create(&out)?;
    writeln!(f, "virtual_time,train_loss,test_loss,test_err")?;
    println!("  vt[s]   train_loss  test_loss  token_err");
    for pt in &r.curve {
        writeln!(f, "{},{},{},{}", pt.time, pt.train_loss, pt.test_loss, pt.test_error)?;
        println!(
            "  {:<6.3}  {:<10.4}  {:<9.4}  {:.3}",
            pt.time, pt.train_loss, pt.test_loss, pt.test_error
        );
    }
    let first = r.curve.first().unwrap();
    let last = r.curve.last().unwrap();
    println!(
        "\n{} steps in {wall:.1}s wall ({:.1} steps/s through PJRT); \
         train {:.3}→{:.3}, test {:.3}→{:.3}; curve → {out}",
        r.total_steps,
        r.total_steps as f64 / wall,
        first.train_loss,
        last.train_loss,
        first.test_loss,
        last.test_loss
    );
    assert!(!r.diverged, "e2e run diverged");
    assert!(
        last.test_loss < first.test_loss,
        "e2e run must reduce test loss"
    );
    Ok(())
}
