//! §5.3: the double-well non-convex case — when does the elastic
//! coupling break and leave workers straddling a saddle?
//!
//! Objective for p = 2 workers (Eq 5.35):
//!   (1/4)(1−x²)² + (1/4)(1−y²)² + (ρ/2)(x−z)² + (ρ/2)(y−z)²
//! with the *EASGD-introduced* critical point x = √(1−ρ), y = −√(1−ρ),
//! z = 0 that is a stable local optimum for ρ ∈ (0, 2/3) (Fig 5.20).

use crate::linalg::{eigenvalues, Matrix};
use crate::rng::Rng;

/// The coupled objective value (Eq 5.35).
pub fn objective(x: f64, y: f64, z: f64, rho: f64) -> f64 {
    0.25 * (1.0 - x * x).powi(2)
        + 0.25 * (1.0 - y * y).powi(2)
        + 0.5 * rho * (x - z).powi(2)
        + 0.5 * rho * (y - z).powi(2)
}

/// Gradient (Eq 5.36).
pub fn gradient(x: f64, y: f64, z: f64, rho: f64) -> (f64, f64, f64) {
    (
        (x * x - 1.0) * x + rho * (x - z),
        (y * y - 1.0) * y + rho * (y - z),
        rho * (z - x) + rho * (z - y),
    )
}

/// Hessian at (x, y, z) (Eq 5.38).
pub fn hessian(x: f64, y: f64, rho: f64) -> Matrix {
    Matrix::from_rows(&[
        &[3.0 * x * x - 1.0 + rho, 0.0, -rho],
        &[0.0, 3.0 * y * y - 1.0 + rho, -rho],
        &[-rho, -rho, 2.0 * rho],
    ])
}

/// The saddle-straddling critical point (±√(1−ρ), 0) for ρ < 1.
pub fn straddle_point(rho: f64) -> Option<(f64, f64, f64)> {
    if rho < 1.0 {
        let s = (1.0 - rho).sqrt();
        Some((s, -s, 0.0))
    } else {
        None
    }
}

/// Smallest Hessian eigenvalue at the straddle point — Fig 5.20's curve.
pub fn straddle_min_eig(rho: f64) -> Option<f64> {
    let (x, y, _) = straddle_point(rho)?;
    let h = hessian(x, y, rho);
    let min = eigenvalues(&h)
        .iter()
        .map(|z| z.re)
        .fold(f64::INFINITY, f64::min);
    Some(min)
}

/// All real critical points (thesis: x = y or x = −y families):
/// (1,1,1), (−1,−1,−1), (0,0,0), and ±(√(1−ρ), −√(1−ρ), 0) for ρ < 1.
pub fn critical_points(rho: f64) -> Vec<(f64, f64, f64)> {
    let mut pts = vec![(1.0, 1.0, 1.0), (-1.0, -1.0, -1.0), (0.0, 0.0, 0.0)];
    if let Some((x, y, z)) = straddle_point(rho) {
        pts.push((x, y, z));
        pts.push((-x, -y, z));
    }
    pts
}

/// Simulate noisy gradient descent on the coupled objective from a
/// straddling initialization; returns final (x, y, z). Demonstrates
/// trapping for small ρ and escape (consensus) for ρ > 2/3.
pub fn descend_from_straddle(
    rho: f64,
    eta: f64,
    noise: f64,
    steps: usize,
    rng: &mut Rng,
) -> (f64, f64, f64) {
    let (mut x, mut y, mut z) = (0.9, -0.9, 0.0);
    for _ in 0..steps {
        let (gx, gy, gz) = gradient(x, y, z, rho);
        x -= eta * (gx + rng.normal(0.0, noise));
        y -= eta * (gy + rng.normal(0.0, noise));
        z -= eta * gz;
    }
    (x, y, z)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gradient_vanishes_at_critical_points() {
        for rho in [0.1, 0.3, 0.6, 0.9] {
            for (x, y, z) in critical_points(rho) {
                let (gx, gy, gz) = gradient(x, y, z, rho);
                assert!(gx.abs() < 1e-12 && gy.abs() < 1e-12 && gz.abs() < 1e-12,
                        "ρ={rho} pt=({x},{y},{z}) grad=({gx},{gy},{gz})");
            }
        }
    }

    #[test]
    fn gradient_is_derivative_of_objective() {
        let (x, y, z, rho) = (0.4, -0.7, 0.2, 0.35);
        let eps = 1e-6;
        let (gx, gy, gz) = gradient(x, y, z, rho);
        let fd_x = (objective(x + eps, y, z, rho) - objective(x - eps, y, z, rho)) / (2.0 * eps);
        let fd_y = (objective(x, y + eps, z, rho) - objective(x, y - eps, z, rho)) / (2.0 * eps);
        let fd_z = (objective(x, y, z + eps, rho) - objective(x, y, z - eps, rho)) / (2.0 * eps);
        assert!((gx - fd_x).abs() < 1e-6);
        assert!((gy - fd_y).abs() < 1e-6);
        assert!((gz - fd_z).abs() < 1e-6);
    }

    #[test]
    fn straddle_stable_below_two_thirds_unstable_above() {
        // Fig 5.20: min-eig > 0 on ρ ∈ (0, 2/3); ≤ 0 beyond.
        for rho in [0.05, 0.2, 0.4, 0.6] {
            let e = straddle_min_eig(rho).unwrap();
            assert!(e > 0.0, "ρ={rho}: min eig {e}");
        }
        for rho in [0.7, 0.9, 0.99] {
            let e = straddle_min_eig(rho).unwrap();
            assert!(e <= 1e-10, "ρ={rho}: min eig {e}");
        }
    }

    #[test]
    fn descent_traps_at_small_rho_escapes_at_large() {
        let mut rng = crate::rng::Rng::new(42);
        // Small ρ: workers stay on opposite wells (broken elasticity).
        let (x, y, _) = descend_from_straddle(0.2, 0.05, 0.05, 20_000, &mut rng);
        assert!(x > 0.3 && y < -0.3, "expected straddle, got ({x},{y})");
        // Large ρ: coupling forces consensus in one well.
        let (x2, y2, _) = descend_from_straddle(0.9, 0.05, 0.05, 20_000, &mut rng);
        assert!((x2 - y2).abs() < 0.3, "expected consensus, got ({x2},{y2})");
    }

    #[test]
    fn global_minima_are_stable_for_all_rho() {
        for rho in [0.1, 0.5, 1.0, 2.0] {
            let h = hessian(1.0, 1.0, rho);
            let min = eigenvalues(&h).iter().map(|z| z.re).fold(f64::INFINITY, f64::min);
            // (1,1,1) Hessian has a ρ-scaled zero mode only at ρ=0.
            assert!(min > -1e-10, "ρ={rho} min {min}");
        }
    }
}
