//! End-to-end Chapter-4 benchmark: time to regenerate one full
//! Fig 4.x-style run per method (the unit of the τ/p sweep figures),
//! and the relative *virtual-time* speedups the figures report —
//! EXPERIMENTS.md cites these rows against Figs 4.5–4.7/4.14.

use elastic_train::config::Args;
use elastic_train::coordinator::{Method, SeqMethod};
use elastic_train::figures::ch4::Sweep;
use elastic_train::figures::FigOpts;
use std::time::Instant;

fn main() {
    // Accepts the same key=value args as `repro figure` (backend=, seed=).
    let mut opts = FigOpts::from_args(&Args::from_env()).unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(2);
    });
    opts.out_dir = "out".into();
    opts.full = false;
    let mut sw = Sweep::new(&opts);
    sw.horizon = 30.0;
    sw.eval_every = 3.0;

    println!("one sweep-unit run per method (horizon 30 vs, p=8):");
    let mut results = Vec::new();
    for (name, method, eta) in [
        ("EASGD τ=10", Method::easgd_default(8, 10), 0.08f32),
        ("EAMSGD τ=10", Method::Eamsgd { alpha: 0.9 / 8.0, tau: 10, delta: 0.9 }, 0.016),
        ("DOWNPOUR τ=1", Method::Downpour { tau: 1 }, 0.05),
        ("MDOWNPOUR", Method::MDownpour { delta: 0.9 }, 0.002),
    ] {
        let t0 = Instant::now();
        let r = sw.run(8, method, eta, "cifar").unwrap_or_else(|e| {
            eprintln!("error: {e}");
            std::process::exit(2);
        });
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "bench ch4/{name:<14} {wall:>7.2} s/run   best_err={:.3} steps={}",
            r.best_test_error(),
            r.total_steps
        );
        results.push((name, r));
    }
    let t0 = Instant::now();
    let r = sw.run_seq(SeqMethod::Msgd { delta: 0.9 }, 0.01, "cifar");
    println!(
        "bench ch4/{:<14} {:>7.2} s/run   best_err={:.3} steps={}",
        "MSGD p=1",
        t0.elapsed().as_secs_f64(),
        r.best_test_error(),
        r.total_steps
    );
    results.push(("MSGD p=1", r));

    // The Fig 4.14-style punchline: virtual time to the common threshold.
    let best = results
        .iter()
        .map(|(_, r)| r.best_test_error())
        .fold(f64::INFINITY, f64::min);
    let thr = best * 1.15;
    println!("\nvirtual time to test error ≤ {thr:.3} (Fig 4.14 shape):");
    for (name, r) in &results {
        match r.time_to_error(thr) {
            Some(t) => println!("  {name:<14} {t:>8.1} vs"),
            None => println!("  {name:<14}   never"),
        }
    }
}
