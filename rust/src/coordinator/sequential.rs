//! The p = 1 baselines of §4.3.1: SGD, Nesterov momentum SGD (MSGD),
//! and the Polyak–Ruppert averaging variants ASGD (α_t = 1/t) and
//! MVASGD (constant moving rate).

use super::oracle::GradOracle;
use crate::cluster::{CostModel, CurvePoint, RunResult, TimeBreakdown};
use crate::model::flat;
use crate::rng::Rng;

/// Sequential method selector.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SeqMethod {
    Sgd,
    /// Nesterov momentum with rate δ.
    Msgd { delta: f32 },
    /// Averaged SGD, α_t = 1/(t+1).
    Asgd,
    /// Moving-average SGD with constant α.
    Mvasgd { alpha: f32 },
}

impl SeqMethod {
    pub fn name(&self) -> &'static str {
        match self {
            SeqMethod::Sgd => "SGD",
            SeqMethod::Msgd { .. } => "MSGD",
            SeqMethod::Asgd => "ASGD",
            SeqMethod::Mvasgd { .. } => "MVASGD",
        }
    }
}

/// Run a sequential baseline under the same cost model / eval protocol
/// as the parallel driver (comm cost is zero: there is no master).
pub fn run_sequential<O: GradOracle>(
    oracle: &mut O,
    method: SeqMethod,
    eta: f32,
    cost: &CostModel,
    horizon: f64,
    eval_every: f64,
    seed: u64,
) -> RunResult {
    let n = oracle.n_params();
    let mut theta = oracle.init_params();
    let mut v = vec![0.0f32; n];
    let mut g = vec![0.0f32; n];
    let mut scratch = vec![0.0f32; n];
    let mut z = theta.clone(); // averaging variants
    let mut rng = Rng::new(seed);
    let mut time_rng = Rng::new(seed ^ 0xFEED);

    let mut now = 0.0f64;
    let mut next_eval = 0.0f64;
    let mut t = 0u64;
    let mut result = RunResult::default();
    let mut breakdown = TimeBreakdown::default();
    let mut diverged = false;

    let eval_target = |m: SeqMethod, theta: &Vec<f32>, z: &Vec<f32>| match m {
        SeqMethod::Asgd | SeqMethod::Mvasgd { .. } => z.clone(),
        _ => theta.clone(),
    };

    while now <= horizon && !diverged {
        while now >= next_eval {
            let te = eval_target(method, &theta, &z);
            let st = oracle.eval(&te);
            result.curve.push(CurvePoint {
                time: next_eval,
                train_loss: st.train_loss,
                test_loss: st.test_loss,
                test_error: st.test_error,
            });
            if !st.train_loss.is_finite() {
                diverged = true;
            }
            next_eval += eval_every;
        }
        match method {
            SeqMethod::Msgd { delta } => {
                for (s, (ti, vi)) in scratch.iter_mut().zip(theta.iter().zip(&v)) {
                    *s = ti + delta * vi;
                }
                oracle.grad(&scratch, &mut rng, &mut g);
                flat::nesterov_step(&mut theta, &mut v, &g, eta, delta);
            }
            _ => {
                oracle.grad(&theta, &mut rng, &mut g);
                flat::sgd_step(&mut theta, &g, eta);
            }
        }
        t += 1;
        match method {
            SeqMethod::Asgd => {
                flat::moving_average(&mut z, &theta, 1.0 / (t as f32 + 1.0));
            }
            SeqMethod::Mvasgd { alpha } => {
                flat::moving_average(&mut z, &theta, alpha);
            }
            _ => {}
        }
        if flat::norm2(&theta) > 1e8 {
            diverged = true;
        }
        let dt = cost.grad_time(&mut time_rng) + cost.t_data;
        breakdown.compute += dt - cost.t_data;
        breakdown.data += cost.t_data;
        now += dt;
    }

    let te = eval_target(method, &theta, &z);
    let st = oracle.eval(&te);
    result.curve.push(CurvePoint {
        time: horizon,
        train_loss: st.train_loss,
        test_loss: st.test_loss,
        test_error: st.test_error,
    });
    result.breakdown = breakdown;
    result.total_steps = t;
    result.diverged = diverged || !st.train_loss.is_finite();
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::oracle::MlpOracle;
    use crate::data::BlobDataset;
    use crate::model::MlpConfig;
    use std::sync::Arc;

    fn oracle() -> MlpOracle {
        let data = Arc::new(BlobDataset::generate(8, 4, 1024, 256, 0.8, 1));
        MlpOracle::new(data, MlpConfig::new(&[8, 16, 4], 1e-4), 32, 3)
    }

    fn cost() -> CostModel {
        CostModel {
            t_grad: 1e-3,
            jitter: 0.05,
            t_data: 1e-4,
            latency: 0.0,
            bandwidth: 1.0,
            param_bytes: 0.0,
        }
    }

    #[test]
    fn all_sequential_methods_learn() {
        for m in [
            SeqMethod::Sgd,
            SeqMethod::Msgd { delta: 0.9 },
            SeqMethod::Asgd,
            SeqMethod::Mvasgd { alpha: 0.01 },
        ] {
            let mut o = oracle();
            let eta = if matches!(m, SeqMethod::Msgd { .. }) { 0.02 } else { 0.1 };
            let r = run_sequential(&mut o, m, eta, &cost(), 0.8, 0.2, 5);
            assert!(!r.diverged, "{}", m.name());
            let first = r.curve.first().unwrap().train_loss;
            let last = r.curve.last().unwrap().train_loss;
            assert!(last < first, "{}: {first} -> {last}", m.name());
        }
    }

    #[test]
    fn asgd_average_lags_raw_iterate_early() {
        // ASGD's averaged z moves slower than θ from the start — the
        // thesis starts averaging late on ImageNet for exactly this
        // reason.
        let mut o1 = oracle();
        let r_sgd = run_sequential(&mut o1, SeqMethod::Sgd, 0.1, &cost(), 0.1, 0.05, 5);
        let mut o2 = oracle();
        let r_asgd = run_sequential(&mut o2, SeqMethod::Asgd, 0.1, &cost(), 0.1, 0.05, 5);
        let s = r_sgd.curve.last().unwrap().train_loss;
        let a = r_asgd.curve.last().unwrap().train_loss;
        assert!(a >= s - 0.05, "averaged {a} vs raw {s}");
    }

    #[test]
    fn msgd_with_large_eta_diverges_smaller_is_fine() {
        let mut o = oracle();
        let bad = run_sequential(&mut o, SeqMethod::Msgd { delta: 0.99 }, 1.5,
                                 &cost(), 0.6, 0.2, 5);
        let mut o2 = oracle();
        let good = run_sequential(&mut o2, SeqMethod::Msgd { delta: 0.99 }, 0.005,
                                  &cost(), 0.6, 0.2, 5);
        assert!(!good.diverged);
        let bl = bad.curve.last().unwrap().train_loss;
        let gl = good.curve.last().unwrap().train_loss;
        assert!(bad.diverged || bl > gl, "bad {bl} vs good {gl}");
    }
}
