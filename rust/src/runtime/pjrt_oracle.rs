//! `PjrtOracle`: the AOT transformer as a `GradOracle`, so the same
//! coordinator drivers (EASGD, EAMSGD, DOWNPOUR, Tree, …) run the real
//! three-layer stack end-to-end. Each worker gets its own corpus stream
//! (thesis §1.2: every worker samples the whole distribution); a shared
//! `PjrtModel` (behind `Rc`) provides the compiled executables.

use super::session::PjrtModel;
use crate::coordinator::oracle::{EvalStats, GradOracle};
use crate::data::MarkovCorpus;
use crate::rng::Rng;
use std::rc::Rc;

/// GradOracle over the PJRT transformer.
pub struct PjrtOracle {
    model: Rc<PjrtModel>,
    corpus: MarkovCorpus,
    /// Fixed held-out batches for evaluation.
    eval_batches: Rc<Vec<(Vec<i32>, Vec<i32>)>>,
    /// Fixed probe batch for train loss.
    probe: Rc<(Vec<i32>, Vec<i32>)>,
}

impl PjrtOracle {
    /// Build a family of p oracles sharing the compiled model, eval
    /// set, and probe batch; per-worker corpora use distinct streams of
    /// the SAME language (same Markov chain seed, different sampling).
    pub fn family(
        model: Rc<PjrtModel>,
        concentration: f64,
        n_eval_batches: usize,
        seed: u64,
        p: usize,
    ) -> Vec<PjrtOracle> {
        let d = model.artifacts.dims;
        // Learnability at few-hundred-step scale: the chain runs over an
        // ACTIVE subset of the vocabulary (≤64 tokens ⇒ ≤4096 bigram
        // contexts, dozens of visits each within one run) while logits
        // still span the full vocab — so the loss has a long way to fall
        // from ln(vocab) and the curve is meaningful quickly.
        let active = d.vocab.min(64);
        let mut eval_corpus = MarkovCorpus::new(active, concentration, seed);
        let eval_batches: Rc<Vec<_>> = Rc::new(
            (0..n_eval_batches)
                .map(|_| eval_corpus.batch(d.batch, d.seq_len))
                .collect(),
        );
        let probe = Rc::new(eval_corpus.batch(d.batch, d.seq_len));
        (0..p)
            .map(|i| PjrtOracle {
                model: model.clone(),
                // Same chain (seed) ⇒ same language; sampling streams
                // diverge via the worker index mixed into the corpus rng.
                corpus: MarkovCorpus::new(active, concentration, seed)
                    .reseeded(seed ^ (0x9E37 + i as u64 * 0x1000)),
                eval_batches: eval_batches.clone(),
                probe: probe.clone(),
            })
            .collect()
    }
}

impl GradOracle for PjrtOracle {
    fn n_params(&self) -> usize {
        self.model.n_params()
    }

    fn init_params(&self) -> Vec<f32> {
        self.model
            .artifacts
            .init_params()
            .expect("init_params.bin readable")
    }

    fn grad(&mut self, theta: &[f32], _rng: &mut Rng, out: &mut [f32]) -> f32 {
        let d = self.model.artifacts.dims;
        let (x, y) = self.corpus.batch(d.batch, d.seq_len);
        self.model
            .train_step(theta, &x, &y, out)
            .expect("train_step")
    }

    fn eval(&mut self, theta: &[f32]) -> EvalStats {
        let d = self.model.artifacts.dims;
        let mut g_scratch; // train probe via eval_step (no grads needed)
        let probe_out = self
            .model
            .eval_step(theta, &self.probe.0, &self.probe.1)
            .expect("probe eval");
        g_scratch = probe_out.loss as f64;
        let mut test_loss = 0.0f64;
        let mut correct = 0i64;
        for (x, y) in self.eval_batches.iter() {
            let o = self.model.eval_step(theta, x, y).expect("eval_step");
            test_loss += o.loss as f64;
            correct += o.n_correct as i64;
        }
        let n_batches = self.eval_batches.len().max(1);
        let n_tokens = (n_batches * d.batch * d.seq_len) as f64;
        if !g_scratch.is_finite() {
            g_scratch = f64::INFINITY;
        }
        EvalStats {
            train_loss: g_scratch,
            test_loss: test_loss / n_batches as f64,
            test_error: 1.0 - correct as f64 / n_tokens,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::CostModel;
    use crate::coordinator::{run_parallel, DriverConfig, Method};
    use std::path::Path;

    fn model() -> Option<Rc<PjrtModel>> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        Some(Rc::new(PjrtModel::load(&dir).unwrap()))
    }

    #[test]
    fn easgd_over_pjrt_reduces_loss() {
        // The end-to-end composition test: async EASGD, p=2 workers,
        // gradients from the AOT transformer, elastic exchange in rust.
        let Some(m) = model() else { return };
        let mut oracles = PjrtOracle::family(m.clone(), 0.05, 2, 42, 2);
        let cost = CostModel {
            t_grad: 1e-3,
            jitter: 0.05,
            t_data: 1e-4,
            latency: 1e-4,
            bandwidth: 1e9,
            param_bytes: (m.n_params() * 4) as f64,
        };
        let cfg = DriverConfig {
            eta: 0.3,
            method: Method::easgd_default(2, 4),
            cost,
            horizon: 0.09, // ~80 worker steps total
            eval_every: 0.04,
            seed: 1,
            max_steps: 200,
            lr_decay_gamma: 0.0,
        };
        let r = run_parallel(&mut oracles, &cfg);
        assert!(!r.diverged);
        let first = r.curve.first().unwrap().train_loss;
        let last = r.curve.last().unwrap().train_loss;
        assert!(last < first, "{first} -> {last}");
    }

    #[test]
    fn family_shares_language_but_not_stream() {
        let Some(m) = model() else { return };
        let mut fam = PjrtOracle::family(m, 0.05, 1, 7, 2);
        let d = fam[0].model.artifacts.dims;
        let b0 = fam[0].corpus.batch(d.batch, d.seq_len);
        let b1 = fam[1].corpus.batch(d.batch, d.seq_len);
        assert_ne!(b0.0, b1.0, "workers must draw different batches");
    }
}
