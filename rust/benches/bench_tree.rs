//! EASGD Tree benchmark (Chapter 6), sim backend: host-time cost of
//! the fully-async virtual-time tree at increasing scale, and the two
//! communication schemes' relative convergence (Figs 6.3–6.10 shape).
//! The real-thread twin is `bench_tree_threaded`.

use elastic_train::cluster::CostModel;
use elastic_train::coordinator::{
    run_tree_sim, DriverConfig, Method, MlpOracle, TreeScheme, TreeSpec,
};
use elastic_train::data::BlobDataset;
use elastic_train::model::MlpConfig;
use std::sync::Arc;
use std::time::Instant;

fn main() {
    let data = Arc::new(BlobDataset::generate(32, 10, 2048, 256, 2.2, 1));
    let mcfg = MlpConfig::new(&[32, 64, 32, 10], 1e-4);
    let cost = CostModel::cifar_like(mcfg.n_params());

    for (degree, leaves) in [(4usize, 16usize), (8, 64), (16, 256)] {
        for (name, scheme) in [
            ("scheme1", TreeScheme::MultiScale { tau1: 1, tau2: 10 }),
            ("scheme2", TreeScheme::UpDown { tau_up: 1, tau_down: 10 }),
        ] {
            let mut oracles = MlpOracle::family(data.clone(), &mcfg, 16, leaves);
            let spec = TreeSpec::new(degree, scheme);
            let cfg = DriverConfig {
                eta: 0.15,
                method: Method::Easgd { alpha: 0.9 / (degree as f32 + 1.0), tau: 1 },
                cost,
                horizon: 8.0,
                eval_every: 4.0,
                seed: 5,
                max_steps: u64::MAX / 2,
                lr_decay_gamma: 0.0,
            };
            let t0 = Instant::now();
            let r = run_tree_sim(&mut oracles, &cfg, &spec).expect("supported combination");
            let wall = t0.elapsed().as_secs_f64();
            println!(
                "bench tree/{name}/p{leaves}d{degree}  {wall:>7.2} s/run  \
                 {:.0} leaf-steps/s  final_train={:.3}{}",
                r.total_steps as f64 / wall,
                r.final_train_loss(),
                if r.diverged { " [DIVERGED]" } else { "" }
            );
        }
    }
}
