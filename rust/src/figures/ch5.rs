//! Chapter 5: the speedup-limit analysis — spectral-radius maps of the
//! moment/drift matrices plus the validating simulations.

use super::csv::Csv;
use super::FigOpts;
use crate::csv_row;
use crate::rng::Rng;
use crate::sim::{moments, multiplicative, quadratic};
use crate::error::Result;

fn grid(opts: &FigOpts) -> usize {
    if opts.full { 120 } else { 48 }
}

/// Fig 5.1 — sp(M) of Eq 5.6 over η ∈ (0,2) × δ ∈ (−1,1), h = 1.
pub fn fig5_1(opts: &FigOpts) -> Result<()> {
    let g = grid(opts);
    let mut csv = Csv::create(
        format!("{}/fig5_1.csv", opts.out_dir),
        &["eta", "delta", "sp"],
    )?;
    for ei in 0..g {
        for di in 0..g {
            let eta = 2.0 * (ei as f64 + 0.5) / g as f64;
            let delta = -1.0 + 2.0 * (di as f64 + 0.5) / g as f64;
            csv.row_f64(&[eta, delta, moments::sp(&moments::msgd_moment_matrix(eta, delta))])?;
        }
    }
    // Shape: at η_h > 1 the optimal δ is negative.
    let eta = 1.5;
    let mut best = (f64::INFINITY, 0.0);
    for di in 0..200 {
        let delta = -0.99 + 1.98 * di as f64 / 199.0;
        let s = moments::sp(&moments::msgd_moment_matrix(eta, delta));
        if s < best.0 {
            best = (s, delta);
        }
    }
    println!(
        "fig5.1: at η_h=1.5 optimal δ = {:.3} (negative: {})",
        best.1,
        if best.1 < 0.0 { "HOLDS" } else { "VIOLATED" }
    );
    Ok(())
}

/// Fig 5.2 — sp(M) of the EASGD reduced moment matrix (Eq 5.12) over
/// η × α, β = 0.9: optimal α is negative.
pub fn fig5_2(opts: &FigOpts) -> Result<()> {
    let g = grid(opts);
    let beta = 0.9;
    let mut csv = Csv::create(
        format!("{}/fig5_2.csv", opts.out_dir),
        &["eta", "alpha", "sp"],
    )?;
    for ei in 0..g {
        for ai in 0..g {
            let eta = 2.0 * (ei as f64 + 0.5) / g as f64;
            let alpha = -1.0 + 2.0 * (ai as f64 + 0.5) / g as f64;
            csv.row_f64(&[
                eta,
                alpha,
                moments::sp(&moments::easgd_reduced_moment_matrix(eta, alpha, beta)),
            ])?;
        }
    }
    let eta = 0.5;
    let pred = moments::easgd_optimal_alpha_reduced(eta, beta);
    let mut best = (f64::INFINITY, 0.0);
    for ai in 0..400 {
        let alpha = -0.99 + 1.98 * ai as f64 / 399.0;
        let s = moments::sp(&moments::easgd_reduced_moment_matrix(eta, alpha, beta));
        if s < best.0 {
            best = (s, alpha);
        }
    }
    println!(
        "fig5.2: η=0.5 β=0.9 optimal α={:.3} (Eq 5.17 predicts {:.3}): {}",
        best.1,
        pred,
        if (best.1 - pred).abs() < 0.05 { "HOLDS" } else { "VIOLATED" }
    );
    Ok(())
}

/// Figs 5.3 / 5.7 — three independent EASGD simulations with α = β/p vs
/// the 'optimal' α of Eq 5.17, at η = 0.1 (reduced-system trap) and
/// η = 1.5 (genuine win).
pub fn fig5_3_7(opts: &FigOpts, eta: f64, label: &str) -> Result<()> {
    let (h, sigma, p, beta) = (1.0, 1e-2, 4usize, 0.9);
    let m = quadratic::Quadratic { h, sigma };
    let a_opt = moments::easgd_optimal_alpha_reduced(eta * h, beta);
    let a_elastic = beta / p as f64;
    let t = if opts.full { 2000 } else { 600 };
    let mut csv = Csv::create(
        format!("{}/{label}.csv", opts.out_dir),
        &["run", "alpha_kind", "t", "center_sq"],
    )?;
    let mut final_opt = Vec::new();
    let mut final_ela = Vec::new();
    for run in 0..3u64 {
        for (kind, alpha) in [("elastic", a_elastic), ("optimal", a_opt)] {
            let mut rng = Rng::new(opts.seed + 100 + run);
            let tr = quadratic::easgd_trajectory(m, eta, alpha, beta, p, 1.0, t, &mut rng);
            for (i, x) in tr.iter().enumerate().step_by(5) {
                csv.row_f64(&[run as f64, if kind == "elastic" { 0.0 } else { 1.0 }, i as f64, x * x])?;
            }
            let last = tr.last().unwrap();
            if kind == "optimal" {
                final_opt.push(last * last);
            } else {
                final_ela.push(last * last);
            }
        }
    }
    let med = |v: &mut Vec<f64>| {
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v[v.len() / 2]
    };
    let (mo, me) = (med(&mut final_opt), med(&mut final_ela));
    println!("{label}: η={eta} final x̃² — optimal-α {mo:.3e}, elastic-α {me:.3e}");
    if eta < 1.0 {
        println!(
            "{label} shape: reduced-system 'optimal' α diverges at small η: {}",
            if mo > 1e3 || !mo.is_finite() { "HOLDS" } else { "VIOLATED" }
        );
    } else {
        println!(
            "{label} shape: optimal α beats elastic at large η: {}",
            if mo < me { "HOLDS" } else { "VIOLATED" }
        );
    }
    Ok(())
}

/// Figs 5.4–5.5 — |z₁|, |z₂|, |z₃| of Eq 5.19 as functions of α at
/// η_h ∈ {0.1, 1.5}, β = 0.9.
pub fn fig5_4_5(opts: &FigOpts) -> Result<()> {
    let mut csv = Csv::create(
        format!("{}/fig5_4_5.csv", opts.out_dir),
        &["eta_h", "alpha", "z1", "z2", "z3"],
    )?;
    for &eta_h in &[0.1f64, 1.5] {
        for ai in 0..400 {
            let alpha = -1.0 + 2.0 * ai as f64 / 399.0;
            let (z1, z2, z3) = moments::easgd_drift_eigs(eta_h, alpha, 0.9);
            csv.row_f64(&[eta_h, alpha, z1.abs(), z2.abs(), z3.abs()])?;
        }
        let opt = moments::easgd_optimal_alpha_original(eta_h, 0.9);
        println!("fig5.4-5.5: η_h={eta_h} → optimal α = {opt:.4}");
    }
    println!(
        "fig5.4-5.5 shape: β>η_h ⇒ α*=0; β<η_h ⇒ α*<0: {}",
        if moments::easgd_optimal_alpha_original(0.1, 0.9) == 0.0
            && moments::easgd_optimal_alpha_original(1.5, 0.9) < 0.0
        {
            "HOLDS"
        } else {
            "VIOLATED"
        }
    );
    Ok(())
}

/// Fig 5.6 — sp(M_p) of Eq 5.18 over η × α (p-independent for p > 1).
pub fn fig5_6(opts: &FigOpts) -> Result<()> {
    let g = grid(opts);
    let mut csv = Csv::create(
        format!("{}/fig5_6.csv", opts.out_dir),
        &["eta", "alpha", "sp"],
    )?;
    for ei in 0..g {
        for ai in 0..g {
            let eta = 2.0 * (ei as f64 + 0.5) / g as f64;
            let alpha = -1.0 + 2.0 * (ai as f64 + 0.5) / g as f64;
            csv.row_f64(&[
                eta,
                alpha,
                moments::sp(&moments::easgd_drift_matrix(eta, alpha, 0.9, 2)),
            ])?;
        }
    }
    let a = moments::sp(&moments::easgd_drift_matrix(0.7, 0.3, 0.9, 2));
    let b = moments::sp(&moments::easgd_drift_matrix(0.7, 0.3, 0.9, 16));
    println!(
        "fig5.6 shape: sp independent of p for p>1 ({a:.6} vs {b:.6}): {}",
        if (a - b).abs() < 1e-9 { "HOLDS" } else { "VIOLATED" }
    );
    Ok(())
}

/// Fig 5.8 — sp(M_p) of the EAMSGD drift (Eq 5.20) over η × α at
/// β = 0.9, δ = 0.99: the optimal α grows as η shrinks (and can be > 0).
pub fn fig5_8(opts: &FigOpts) -> Result<()> {
    let g = grid(opts);
    let mut csv = Csv::create(
        format!("{}/fig5_8.csv", opts.out_dir),
        &["eta", "alpha", "sp"],
    )?;
    for ei in 0..g {
        for ai in 0..g {
            let eta = 2.0 * (ei as f64 + 0.5) / g as f64;
            let alpha = -1.0 + 2.0 * (ai as f64 + 0.5) / g as f64;
            csv.row_f64(&[
                eta,
                alpha,
                moments::sp(&moments::eamsgd_drift_matrix(eta, alpha, 0.9, 0.99, 2)),
            ])?;
        }
    }
    let best_alpha = |eta: f64| -> f64 {
        let mut best = (f64::INFINITY, 0.0);
        for ai in 0..300 {
            let alpha = -0.99 + 1.98 * ai as f64 / 299.0;
            let s = moments::sp(&moments::eamsgd_drift_matrix(eta, alpha, 0.9, 0.99, 2));
            if s < best.0 {
                best = (s, alpha);
            }
        }
        best.1
    };
    let (a_small, a_large) = (best_alpha(0.1), best_alpha(1.5));
    println!(
        "fig5.8: optimal α at η=0.1 is {a_small:.3}, at η=1.5 is {a_large:.3} — grows as η ↓: {}",
        if a_small > a_large { "HOLDS" } else { "VIOLATED" }
    );
    Ok(())
}

/// Fig 5.9 — Γ(λ, ω) pdfs incl. mini-batch concentration Γ(pλ, pω).
pub fn fig5_9(opts: &FigOpts) -> Result<()> {
    let mut csv = Csv::create(
        format!("{}/fig5_9.csv", opts.out_dir),
        &["p", "x", "pdf"],
    )?;
    for &p in &[1usize, 2, 4] {
        let (l, w) = (0.5 * p as f64, 0.5 * p as f64);
        for i in 0..400 {
            let x = 10f64.powf(-3.0 + 5.0 * i as f64 / 399.0);
            csv.row_f64(&[p as f64, x, moments::gamma_pdf(x, l, w)])?;
        }
    }
    let pole = moments::gamma_pdf(1e-3, 0.5, 0.5) > moments::gamma_pdf(0.1, 0.5, 0.5);
    let conc = moments::gamma_pdf(1.0, 2.0, 2.0) > moments::gamma_pdf(1.0, 0.5, 0.5);
    println!(
        "fig5.9 shape: λ<1 pole at 0: {} | mini-batch concentrates at mean: {}",
        if pole { "HOLDS" } else { "VIOLATED" },
        if conc { "HOLDS" } else { "VIOLATED" }
    );
    Ok(())
}

/// Figs 5.10–5.12 — sp(M) of Eq 5.30 over η × δ for
/// (λ, ω) ∈ {(0.5,0.5), (1,1), (2,2)} (the mini-batch sequence).
pub fn fig5_10_12(opts: &FigOpts) -> Result<()> {
    let g = grid(opts);
    let mut csv = Csv::create(
        format!("{}/fig5_10_12.csv", opts.out_dir),
        &["lambda", "omega", "eta", "delta", "sp"],
    )?;
    for &(l, w) in &[(0.5f64, 0.5f64), (1.0, 1.0), (2.0, 2.0)] {
        for ei in 0..g {
            for di in 0..g {
                let eta = (ei as f64 + 0.5) / g as f64;
                let delta = -1.0 + 2.0 * (di as f64 + 0.5) / g as f64;
                csv.row_f64(&[
                    l,
                    w,
                    eta,
                    delta,
                    moments::sp(&moments::msgd_mult_moment_matrix(eta, delta, l, w)),
                ])?;
            }
        }
    }
    println!("fig5.10-5.12 written (see fig5.13 for the δ=0 optimality check)");
    Ok(())
}

/// Fig 5.13 — sp(M) vs δ at the optimal η = λ/(ω+1): minimum at δ = 0,
/// i.e. momentum slows the optimal multiplicative-noise rate.
pub fn fig5_13(opts: &FigOpts) -> Result<()> {
    let mut csv = Csv::create(
        format!("{}/fig5_13.csv", opts.out_dir),
        &["lambda", "omega", "delta", "sp"],
    )?;
    let mut holds = true;
    for &(l, w) in &[(0.5f64, 0.5f64), (1.0, 1.0), (2.0, 2.0)] {
        let eta = l / (w + 1.0); // = ω/(λ+1) when λ=ω (thesis notation)
        let mut best = (f64::INFINITY, 0.0);
        for di in 0..401 {
            let delta = -0.9 + 1.8 * di as f64 / 400.0;
            let s = moments::sp(&moments::msgd_mult_moment_matrix(eta, delta, l, w));
            csv.row_f64(&[l, w, delta, s])?;
            if s < best.0 {
                best = (s, delta);
            }
        }
        println!("fig5.13: (λ,ω)=({l},{w}) sp minimized at δ={:.3}", best.1);
        if best.1.abs() > 0.05 {
            holds = false;
        }
    }
    println!(
        "fig5.13 shape: optimal δ = 0 at optimal η: {}",
        if holds { "HOLDS" } else { "VIOLATED" }
    );
    Ok(())
}

/// Fig 5.14 — sp(M) over (λ, ω) grids for (η, δ) ∈ {(1,0), (0.1,0),
/// (0.1,0.9)}: momentum helps only for small spread slope λ/ω.
pub fn fig5_14(opts: &FigOpts) -> Result<()> {
    let g = grid(opts);
    let mut csv = Csv::create(
        format!("{}/fig5_14.csv", opts.out_dir),
        &["eta", "delta", "lambda", "omega", "sp"],
    )?;
    for &(eta, delta) in &[(1.0f64, 0.0f64), (0.1, 0.0), (0.1, 0.9)] {
        for li in 0..g {
            for wi in 0..g {
                let l = 100.0 * (li as f64 + 0.5) / g as f64;
                let w = 100.0 * (wi as f64 + 0.5) / g as f64;
                csv.row_f64(&[
                    eta,
                    delta,
                    l,
                    w,
                    moments::sp(&moments::msgd_mult_moment_matrix(eta, delta, l, w)),
                ])?;
            }
        }
    }
    // Momentum accelerates at sub-optimal η for small λ/ω:
    let (l, w) = (1.0, 40.0); // slope 0.025, optimal η ≈ 20 ≫ 0.1
    let s0 = moments::sp(&moments::msgd_mult_moment_matrix(0.1, 0.0, l, w));
    let s9 = moments::sp(&moments::msgd_mult_moment_matrix(0.1, 0.9, l, w));
    println!(
        "fig5.14 shape: at small λ/ω and sub-optimal η momentum helps ({s9:.4} < {s0:.4}): {}",
        if s9 < s0 { "HOLDS" } else { "VIOLATED" }
    );
    Ok(())
}

/// Figs 5.15–5.18 — sp(M) of Eq 5.34 over η × p (α = β/p):
/// an optimal FINITE p exists (contrast with mini-batch SGD).
pub fn fig5_15_18(opts: &FigOpts) -> Result<()> {
    let g = grid(opts);
    let mut csv = Csv::create(
        format!("{}/fig5_15_18.csv", opts.out_dir),
        &["lambda", "omega", "eta", "p", "sp"],
    )?;
    for &(l, w, eta_max) in &[(0.5f64, 0.5f64, 1.0), (1.0, 1.0, 1.0), (2.0, 2.0, 1.0), (10.0, 10.0, 2.0)] {
        let mut best = (f64::INFINITY, 0usize, 0.0f64);
        for p in 1..=64usize {
            for ei in 0..g {
                let eta = eta_max * (ei as f64 + 0.5) / g as f64;
                let s = moments::sp(&moments::easgd_mult_moment_matrix(
                    eta,
                    0.9 / p as f64,
                    0.9,
                    l,
                    w,
                    p,
                ));
                csv.row_f64(&[l, w, eta, p as f64, s])?;
                if s < best.0 {
                    best = (s, p, eta);
                }
            }
        }
        println!(
            "fig5.15-18: (λ,ω)=({l},{w}) min sp={:.4} at p={} η={:.4}",
            best.0, best.1, best.2
        );
        if (l - 10.0).abs() < 1e-9 {
            println!(
                "fig5.18 shape: thesis reports min sp=0.0868 at p=29, η=0.8929 — ours p={} (finite, interior): {}",
                best.1,
                if best.1 > 2 && best.1 < 64 { "HOLDS" } else { "VIOLATED" }
            );
        }
    }
    Ok(())
}

/// Fig 5.19 — sp(M) of Eq 5.34 over η × α at p = 100, λ = ω = 0.5:
/// optimal α is POSITIVE (≈ 1 − √λ) and stability extends to η < ω/√λ.
pub fn fig5_19(opts: &FigOpts) -> Result<()> {
    let g = grid(opts);
    let (l, w, p) = (0.5, 0.5, 100usize);
    let mut csv = Csv::create(
        format!("{}/fig5_19.csv", opts.out_dir),
        &["eta", "alpha", "sp"],
    )?;
    let mut best = (f64::INFINITY, 0.0, 0.0);
    for ei in 0..g {
        for ai in 0..g {
            let eta = (ei as f64 + 0.5) / g as f64;
            let alpha = -1.0 + 2.0 * (ai as f64 + 0.5) / g as f64;
            let s = moments::sp(&moments::easgd_mult_moment_matrix(eta, alpha, 0.9, l, w, p));
            csv.row_f64(&[eta, alpha, s])?;
            if s < best.0 {
                best = (s, eta, alpha);
            }
        }
    }
    println!(
        "fig5.19: min sp={:.4} at η={:.3}, α={:.3} (thesis: 0.5024 at 0.4343, 0.2525)",
        best.0, best.1, best.2
    );
    println!(
        "fig5.19 shape: optimal α positive ≈ 1−√λ = {:.3}: {}",
        moments::easgd_mult_optimal_alpha(l),
        if best.2 > 0.0 { "HOLDS" } else { "VIOLATED" }
    );
    Ok(())
}

/// Fig 5.20 — smallest Hessian eigenvalue at the saddle-straddling
/// critical point vs ρ: positive on (0, 2/3).
pub fn fig5_20(opts: &FigOpts) -> Result<()> {
    let mut csv = Csv::create(
        format!("{}/fig5_20.csv", opts.out_dir),
        &["rho", "min_eig"],
    )?;
    let mut sign_flip = None;
    let mut prev_pos = true;
    for i in 1..400 {
        let rho = i as f64 / 400.0;
        if let Some(e) = crate::sim::nonconvex::straddle_min_eig(rho) {
            csv.row_f64(&[rho, e])?;
            let pos = e > 0.0;
            if prev_pos && !pos && sign_flip.is_none() {
                sign_flip = Some(rho);
            }
            prev_pos = pos;
        }
    }
    let flip = sign_flip.unwrap_or(f64::NAN);
    println!(
        "fig5.20: min-eig sign flips at ρ ≈ {flip:.3} (thesis: 2/3): {}",
        if (flip - 2.0 / 3.0).abs() < 0.02 { "HOLDS" } else { "VIOLATED" }
    );
    Ok(())
}

/// Extra (quick empirical cross-check used by tests): the multiplicative
/// EASGD simulation contracts where Eq 5.34's sp < 1.
#[allow(dead_code)]
pub fn mult_crosscheck(seed: u64) -> bool {
    let m = multiplicative::Multiplicative { lambda: 1.0, omega: 1.0 };
    let mut rng = Rng::new(seed);
    let tr = multiplicative::easgd_trajectory(m, 0.4, 0.9 / 8.0, 0.9, 8, 1.0, 400, &mut rng);
    *tr.last().unwrap() < 0.1
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opts() -> FigOpts {
        FigOpts {
            out_dir: std::env::temp_dir()
                .join("et_fig_ch5")
                .to_string_lossy()
                .into_owned(),
            full: false,
            seed: 0,
            backend: crate::coordinator::Backend::Sim,
            model: crate::model::ModelKind::Mlp,
            threads: 1,
            simd: "auto".into(),
        }
    }

    #[test]
    fn spectral_figures_run_and_hold_shapes() {
        fig5_1(&opts()).unwrap();
        fig5_2(&opts()).unwrap();
        fig5_4_5(&opts()).unwrap();
        fig5_6(&opts()).unwrap();
        fig5_13(&opts()).unwrap();
        fig5_20(&opts()).unwrap();
    }

    #[test]
    fn simulation_figures_run() {
        fig5_3_7(&opts(), 0.1, "fig5.3").unwrap();
        fig5_3_7(&opts(), 1.5, "fig5.7").unwrap();
        assert!(mult_crosscheck(3));
    }
}
