//! Criterion-style micro-benchmark harness (the offline crate set has
//! no criterion; `cargo bench` runs our `harness = false` binaries,
//! which use this module). Reports median + MAD over timed batches and
//! prints rows `cargo bench`-style.

use std::time::Instant;

/// One benchmark measurement.
#[derive(Clone, Copy, Debug)]
pub struct Sample {
    pub median_ns: f64,
    pub mad_ns: f64,
    pub iters: u64,
}

impl Sample {
    pub fn throughput(&self, items: f64) -> f64 {
        items / (self.median_ns * 1e-9)
    }
}

/// Time `f` adaptively: calibrate iterations to ~`target_ms` per batch,
/// run `batches` batches, report median/MAD of per-iteration time.
pub fn bench<F: FnMut()>(name: &str, target_ms: f64, batches: usize, mut f: F) -> Sample {
    // Calibrate.
    let mut iters = 1u64;
    loop {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        let el = t0.elapsed().as_secs_f64() * 1e3;
        if el >= target_ms || iters >= 1 << 30 {
            break;
        }
        let scale = (target_ms / el.max(1e-6)).clamp(1.5, 100.0);
        iters = ((iters as f64) * scale).ceil() as u64;
    }
    // Measure.
    let mut per_iter: Vec<f64> = (0..batches.max(3))
        .map(|_| {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            t0.elapsed().as_nanos() as f64 / iters as f64
        })
        .collect();
    per_iter.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = per_iter[per_iter.len() / 2];
    let mut dev: Vec<f64> = per_iter.iter().map(|x| (x - median).abs()).collect();
    dev.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mad = dev[dev.len() / 2];
    let s = Sample { median_ns: median, mad_ns: mad, iters };
    println!(
        "bench {name:<44} {:>12.1} ns/iter (± {:.1}) x{}",
        s.median_ns, s.mad_ns, s.iters
    );
    s
}

/// Short git SHA of HEAD (the bench-history key); "unknown" outside a
/// git checkout.
pub fn git_sha() -> String {
    std::process::Command::new("git")
        .args(["rev-parse", "--short=12", "HEAD"])
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

/// Seconds since the Unix epoch (history-entry timestamp).
pub fn unix_time() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

/// Split a JSON history file into its top-level object entries,
/// validating the structure on the way: the text must be a JSON array
/// whose every element is a balanced `{…}` object (braces counted
/// outside string literals, escapes honored). Returns `None` on any
/// violation — the old "starts with `[`, ends with `]`" check happily
/// appended after a malformed head forever.
fn split_json_array(text: &str) -> Option<Vec<String>> {
    let trimmed = text.trim();
    if trimmed.is_empty() {
        return Some(Vec::new());
    }
    let inner = trimmed.strip_prefix('[')?.strip_suffix(']')?;
    let mut entries = Vec::new();
    let mut depth = 0usize;
    let mut start = None;
    let mut in_str = false;
    let mut escaped = false;
    let mut expect_elem = true;
    for (i, ch) in inner.char_indices() {
        if in_str {
            if escaped {
                escaped = false;
            } else if ch == '\\' {
                escaped = true;
            } else if ch == '"' {
                in_str = false;
            }
            continue;
        }
        match ch {
            '"' if depth > 0 => in_str = true,
            '{' => {
                if depth == 0 {
                    if !expect_elem {
                        return None; // two objects with no comma
                    }
                    start = Some(i);
                }
                depth += 1;
            }
            '}' => {
                depth = depth.checked_sub(1)?;
                if depth == 0 {
                    entries.push(inner[start?..=i].to_string());
                    start = None;
                    expect_elem = false;
                }
            }
            ',' if depth == 0 => {
                if expect_elem {
                    return None; // leading/double comma
                }
                expect_elem = true;
            }
            c if depth == 0 && !c.is_whitespace() => return None, // junk between entries
            _ => {}
        }
    }
    if depth != 0 || in_str {
        return None; // truncated object or unterminated string
    }
    if expect_elem && !entries.is_empty() {
        return None; // trailing comma
    }
    Some(entries)
}

/// Marker the committed placeholder heads carry (PRs 4–6 had no cargo
/// in the authoring container, so real measurements could not seed the
/// histories; real entries never contain it).
const PLACEHOLDER: &str = "\"sha\": \"placeholder\"";

/// Append `entry` (one JSON object, pre-indented) to the history array
/// at `path`. The existing file is *validated*, not pattern-matched:
/// a malformed head (legacy single-object format, truncated write,
/// hand-edit gone wrong) starts a fresh array with a loud note instead
/// of splicing new entries after garbage, and committed "placeholder"
/// heads are replaced by the first real measurement.
pub fn append_history(path: &str, entry: &str) {
    let existing = std::fs::read_to_string(path).unwrap_or_default();
    let mut entries = match split_json_array(&existing) {
        Some(e) => e,
        None => {
            eprintln!("benchkit: {path} is not a valid JSON history array; starting fresh");
            Vec::new()
        }
    };
    let placeholders = entries.iter().filter(|e| e.contains(PLACEHOLDER)).count();
    if placeholders > 0 {
        eprintln!("benchkit: {path}: replacing {placeholders} placeholder head(s) with this run");
        entries.retain(|e| !e.contains(PLACEHOLDER));
    }
    entries.push(entry.trim_end().trim_start_matches('\n').to_string());
    let body = format!("[\n{}\n]\n", entries.join(",\n"));
    std::fs::write(path, body).unwrap_or_else(|e| panic!("write {path}: {e}"));
}

/// Pretty time for summaries.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.1} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something_sane() {
        let mut acc = 0u64;
        let s = bench("noop-ish", 2.0, 3, || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert!(s.median_ns > 0.0 && s.median_ns < 1e6);
        assert!(s.iters >= 1);
    }

    #[test]
    fn append_history_grows_an_array_and_recovers_from_junk() {
        let path = std::env::temp_dir().join(format!("et_hist_{}.json", std::process::id()));
        let p = path.to_str().unwrap();
        let _ = std::fs::remove_file(p);
        append_history(p, "  {\"a\": 1}");
        append_history(p, "  {\"b\": 2}");
        let s = std::fs::read_to_string(p).unwrap();
        assert!(s.trim_start().starts_with('['), "{s}");
        assert!(s.contains("\"a\"") && s.contains("\"b\""), "{s}");
        std::fs::write(p, "not json").unwrap();
        append_history(p, "  {\"c\": 3}");
        let s = std::fs::read_to_string(p).unwrap();
        assert!(s.contains("\"c\"") && !s.contains("not json"), "{s}");
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn append_history_replaces_placeholder_heads() {
        let path = std::env::temp_dir().join(format!("et_hist_ph_{}.json", std::process::id()));
        let p = path.to_str().unwrap();
        std::fs::write(
            p,
            "[\n{\n  \"sha\": \"placeholder\",\n  \"note\": \"no cargo in container\"\n}\n]\n",
        )
        .unwrap();
        append_history(p, "{\"sha\": \"abc123\", \"results\": []}");
        let s = std::fs::read_to_string(p).unwrap();
        assert!(!s.contains("placeholder"), "placeholder head must be replaced: {s}");
        assert!(s.contains("abc123"), "{s}");
        // A real head is kept on subsequent appends.
        append_history(p, "{\"sha\": \"def456\", \"results\": []}");
        let s = std::fs::read_to_string(p).unwrap();
        assert!(s.contains("abc123") && s.contains("def456"), "{s}");
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn append_history_starts_fresh_on_a_malformed_array() {
        // The old check only looked at the first and last byte, so
        // junk *inside* the array was preserved and appended after.
        let path = std::env::temp_dir().join(format!("et_hist_bad_{}.json", std::process::id()));
        let p = path.to_str().unwrap();
        for bad in [
            "[{\"a\": 1}, oops]",
            "[{\"a\": 1},]",
            "[{\"a\": 1}",
            "[{\"a\": \"unterminated]",
            "{\"legacy\": \"single object\"}",
        ] {
            std::fs::write(p, bad).unwrap();
            append_history(p, "{\"fresh\": true}");
            let s = std::fs::read_to_string(p).unwrap();
            assert!(s.contains("\"fresh\""), "head {bad:?}: {s}");
            assert!(
                !s.contains("oops") && !s.contains("legacy"),
                "head {bad:?} must not survive: {s}"
            );
            assert!(split_json_array(&s).is_some(), "rewritten file must validate: {s}");
        }
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn split_json_array_validates_structure() {
        assert_eq!(split_json_array("").unwrap().len(), 0);
        assert_eq!(split_json_array("[]").unwrap().len(), 0);
        let two = split_json_array("[\n{\"a\": \"x,{}\"},\n{\"b\": 2}\n]").unwrap();
        assert_eq!(two.len(), 2);
        assert!(two[0].contains("x,{}"), "strings with braces/commas survive: {two:?}");
        for bad in ["[1, 2]", "[{\"a\":1} {\"b\":2}]", "[,{\"a\":1}]", "not json", "[\"str\"]"] {
            assert!(split_json_array(bad).is_none(), "{bad}");
        }
    }

    #[test]
    fn fmt_ns_ranges() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert!(fmt_ns(5_000.0).ends_with("µs"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with(" s"));
    }
}
