"""L1 Pallas kernels for the EASGD / EAMSGD parameter update hot path.

The thesis' per-step computation (Algorithms 1 and 2) over the flat
parameter vector, expressed as tiled Pallas kernels:

  * ``sgd_nesterov_step``  — v' = delta*v - eta*g ; x' = x + v'
  * ``elastic_exchange``   — d = alpha*(x - c) ; x' = x - d ; c' = c + d
  * ``easgd_fused_step``   — exchange (masked) + Nesterov step in one pass

Hardware adaptation (DESIGN.md §3): the flat parameter vector is tiled
into BLOCK-element chunks; each grid step streams one tile HBM→VMEM,
does the element-wise VPU work, and writes back. BLOCK=65536 keeps the
working set (≤5 tiles live = 1.3 MiB f32) far under VMEM while remaining
lane-aligned (8x128). On this image kernels lower with interpret=True
(plain HLO the CPU PJRT plugin runs); the BlockSpec schedule is what a
real TPU lowering would pipeline.

Scalars (eta/alpha/delta/do_exchange) are passed as f32[1] operands so a
single AOT artifact serves every hyper-parameter setting — the rust
coordinator feeds them per call.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile size over the flat parameter vector. 8 * 128 lane alignment.
BLOCK = 65536


def _pad_to_block(n: int) -> int:
    return (n + BLOCK - 1) // BLOCK * BLOCK


def _scalar_spec():
    # Scalars are replicated to every grid step: index_map pins block 0.
    return pl.BlockSpec((1,), lambda i: (0,))


def _vec_spec():
    return pl.BlockSpec((BLOCK,), lambda i: (i,))


def _sgd_nesterov_kernel(eta_ref, delta_ref, x_ref, v_ref, g_ref,
                         x_out_ref, v_out_ref):
    eta = eta_ref[0]
    delta = delta_ref[0]
    v_new = delta * v_ref[...] - eta * g_ref[...]
    v_out_ref[...] = v_new
    x_out_ref[...] = x_ref[...] + v_new


def sgd_nesterov_step(x, v, g, eta, delta):
    """Fused (momentum) SGD step over a flat f32[n] parameter vector.

    ``eta`` and ``delta`` are f32[1] arrays. Returns (x', v').
    delta == 0 recovers plain SGD (thesis Alg. 1); the gradient ``g`` is
    assumed evaluated at the Nesterov lookahead point by the caller.
    """
    n = x.shape[0]
    grid = (_pad_to_block(n) // BLOCK,)
    out_shape = [jax.ShapeDtypeStruct(x.shape, x.dtype)] * 2
    return tuple(
        pl.pallas_call(
            _sgd_nesterov_kernel,
            grid=grid,
            in_specs=[_scalar_spec(), _scalar_spec(),
                      _vec_spec(), _vec_spec(), _vec_spec()],
            out_specs=[_vec_spec(), _vec_spec()],
            out_shape=out_shape,
            interpret=True,
        )(eta, delta, x, v, g)
    )


def _elastic_kernel(alpha_ref, x_ref, c_ref, x_out_ref, c_out_ref):
    alpha = alpha_ref[0]
    d = alpha * (x_ref[...] - c_ref[...])
    x_out_ref[...] = x_ref[...] - d
    c_out_ref[...] = c_ref[...] + d


def elastic_exchange(x, center, alpha):
    """Symmetric elastic exchange (thesis Alg. 1 steps a/b) over flat
    f32[n] vectors. ``alpha`` is f32[1]. Returns (x', center')."""
    n = x.shape[0]
    grid = (_pad_to_block(n) // BLOCK,)
    out_shape = [jax.ShapeDtypeStruct(x.shape, x.dtype)] * 2
    return tuple(
        pl.pallas_call(
            _elastic_kernel,
            grid=grid,
            in_specs=[_scalar_spec(), _vec_spec(), _vec_spec()],
            out_specs=[_vec_spec(), _vec_spec()],
            out_shape=out_shape,
            interpret=True,
        )(alpha, x, center)
    )


def _fused_kernel(eta_ref, alpha_ref, delta_ref, do_ref,
                  x_ref, v_ref, g_ref, c_ref,
                  x_out_ref, v_out_ref, d_out_ref):
    eta = eta_ref[0]
    alpha = alpha_ref[0]
    delta = delta_ref[0]
    do = do_ref[0]
    d = do * alpha * (x_ref[...] - c_ref[...])
    x1 = x_ref[...] - d
    v_new = delta * v_ref[...] - eta * g_ref[...]
    x_out_ref[...] = x1 + v_new
    v_out_ref[...] = v_new
    d_out_ref[...] = d


def easgd_fused_step(x, v, g, center, eta, alpha, delta, do_exchange):
    """One whole asynchronous-EASGD/EAMSGD worker step in a single pass:
    masked elastic exchange followed by the (momentum) gradient step.

    Returns (x', v', center_delta); the master adds center_delta to the
    center variable (the symmetric half of the elastic force). All four
    scalars are f32[1]; ``do_exchange`` is 1.0 on steps where tau divides
    the local clock, else 0.0 — so one compiled artifact serves every
    communication period.
    """
    n = x.shape[0]
    grid = (_pad_to_block(n) // BLOCK,)
    out_shape = [jax.ShapeDtypeStruct(x.shape, x.dtype)] * 3
    return tuple(
        pl.pallas_call(
            _fused_kernel,
            grid=grid,
            in_specs=[_scalar_spec()] * 4 + [_vec_spec()] * 4,
            out_specs=[_vec_spec()] * 3,
            out_shape=out_shape,
            interpret=True,
        )(eta, alpha, delta, do_exchange, x, v, g, center)
    )
