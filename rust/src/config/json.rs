//! Minimal recursive-descent JSON parser — just enough for
//! `artifacts/manifest.json` (objects, arrays, strings, numbers, bools,
//! null; no \u escapes beyond BMP passthrough).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Path access: `j.at(&["kernels", "flat_len"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in path {
            cur = cur.get(k)?;
        }
        Some(cur)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.into() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    out.push(match e {
                        b'n' => '\n',
                        b't' => '\t',
                        b'r' => '\r',
                        b'"' => '"',
                        b'\\' => '\\',
                        b'/' => '/',
                        b'b' => '\u{8}',
                        b'f' => '\u{c}',
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u"))?;
                            self.i += 4;
                            char::from_u32(cp).unwrap_or('\u{fffd}')
                        }
                        _ => return Err(self.err("unknown escape")),
                    });
                }
                Some(c) => {
                    // Copy raw UTF-8 bytes through.
                    let len = utf8_len(c);
                    let chunk = &self.b[self.i..self.i + len];
                    out.push_str(
                        std::str::from_utf8(chunk).map_err(|_| self.err("bad utf8"))?,
                    );
                    self.i += len;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let src = r#"{
          "preset": "tiny",
          "preset_params": 435456,
          "config": {"vocab": 256, "weight_decay": 1e-4},
          "params": [
            {"name": "tok_embed", "shape": [256, 128], "offset": 0, "size": 32768}
          ],
          "ok": true, "nothing": null
        }"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.get("preset").unwrap().as_str(), Some("tiny"));
        assert_eq!(j.get("preset_params").unwrap().as_usize(), Some(435456));
        assert_eq!(j.at(&["config", "vocab"]).unwrap().as_usize(), Some(256));
        let p0 = &j.get("params").unwrap().as_arr().unwrap()[0];
        assert_eq!(p0.get("name").unwrap().as_str(), Some("tok_embed"));
        assert_eq!(
            p0.get("shape").unwrap().as_arr().unwrap()[1].as_usize(),
            Some(128)
        );
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(j.get("nothing"), Some(&Json::Null));
    }

    #[test]
    fn parses_scientific_and_negative_numbers() {
        let j = Json::parse("[-1.5, 2e3, 1e-4, 0]").unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(-1.5));
        assert_eq!(a[1].as_f64(), Some(2000.0));
        assert_eq!(a[2].as_f64(), Some(1e-4));
    }

    #[test]
    fn string_escapes() {
        let j = Json::parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(j.as_str(), Some("a\nb\t\"q\" A"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("hello").is_err());
        assert!(Json::parse("{\"a\": 1} x").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }
}
