//! Process-backend equivalence suite: workers as separate OS processes
//! exchanging flat-θ frames over real sockets must land where the
//! virtual-time simulator and the thread backend land on the same
//! deterministic objective — and must report real, nonzero wire costs.
//!
//! These tests self-exec the `repro` binary (Cargo builds it for
//! integration tests and exports its path via `CARGO_BIN_EXE_repro`),
//! so the hidden `--process-worker` entry point is exercised end to
//! end: spawn → Hello/Init → Push/Center rounds → Done.

use elastic_train::cluster::CostModel;
use elastic_train::coordinator::{
    run_process, run_threaded, DriverConfig, Executor, Method, OracleSpec, ProcessOpts,
    QuadraticOracle, SimExecutor,
};

fn fast_cost(n_params: usize) -> CostModel {
    CostModel {
        t_grad: 1e-3,
        jitter: 0.0,
        t_data: 0.0,
        latency: 1e-5,
        bandwidth: 1e12,
        param_bytes: (n_params * 4) as f64,
    }
}

fn repro_exe() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_BIN_EXE_repro"))
}

fn quad_spec(n: usize) -> OracleSpec {
    OracleSpec::Quadratic { n, h: 1.0, x0: 0.0, target: 1.0, noise: 0.0 }
}

fn cfg(n: usize, method: Method, eta: f32, steps: u64) -> DriverConfig {
    DriverConfig {
        eta,
        method,
        cost: fast_cost(n),
        horizon: 60.0, // REAL seconds safety net; steps bound first
        eval_every: 1e6,
        seed: 11,
        max_steps: steps,
        lr_decay_gamma: 0.0,
    }
}

/// EASGD on the deterministic quadratic: sim, thread, and process all
/// contract to the same fixed point (workers = center = target). The
/// process run must also report nonzero serialize/transfer time and
/// wire statistics — the whole point of measuring on real sockets.
#[test]
fn process_matches_thread_and_sim_on_quadratic_easgd() {
    let (n, p, steps) = (512usize, 4usize, 8_000u64);
    let method = Method::easgd_default(p, 4);

    let sim_cfg = DriverConfig { horizon: 1e6, ..cfg(n, method, 0.1, steps) };
    let mut sim_oracles = QuadraticOracle::family(n, 1.0, 0.0, 1.0, 0.0, p);
    let sim = SimExecutor.run(&mut sim_oracles, &sim_cfg).unwrap();

    let thr_cfg = cfg(n, method, 0.1, steps);
    let mut thr_oracles = QuadraticOracle::family(n, 1.0, 0.0, 1.0, 0.0, p);
    let thr = run_threaded(&mut thr_oracles, &thr_cfg, 16).unwrap();

    let opts = ProcessOpts { exe: Some(repro_exe()), ..ProcessOpts::default() };
    let prc = run_process(&quad_spec(n), p, &thr_cfg, &opts).unwrap();

    assert!(!sim.diverged && !thr.diverged && !prc.diverged);
    let ls = sim.curve.last().unwrap().train_loss;
    let lt = thr.curve.last().unwrap().train_loss;
    let lp = prc.curve.last().unwrap().train_loss;
    // All three at the optimum (loss 0 for ½(θ−1)² from θ=0)...
    assert!(ls < 1e-6, "sim final loss {ls}");
    assert!(lt < 1e-6, "thread final loss {lt}");
    assert!(lp < 1e-6, "process final loss {lp}");
    // ...and within the required tolerance of each other.
    assert!((lp - ls).abs() < 1e-4, "process {lp} vs sim {ls}");
    assert!((lp - lt).abs() < 1e-4, "process {lp} vs thread {lt}");

    // The run crossed a real socket: frames flowed, bytes moved, and
    // the measured serialize/transfer shares are nonzero.
    assert!(prc.total_steps > 0);
    assert!(prc.rounds > 0, "no communication rounds over the socket");
    let wire = prc.wire.expect("process runs report wire stats");
    assert!(wire.frames > 0);
    assert!(wire.payload_bytes >= wire.frames * 4, "payload bytes {}", wire.payload_bytes);
    assert!(prc.breakdown.serialize > 0.0, "serialize time not measured");
    assert!(prc.breakdown.transfer > 0.0, "transfer time not measured");
    // Sim and thread runs don't fabricate wire stats.
    assert!(sim.wire.is_none() && thr.wire.is_none());
}

/// DOWNPOUR over sockets: accumulated-update pushes instead of elastic
/// θ exchanges. Same quadratic, same fixed point across backends.
#[test]
fn process_matches_thread_and_sim_on_quadratic_downpour() {
    let (n, p, steps) = (256usize, 4usize, 8_000u64);
    let method = Method::Downpour { tau: 2 };

    let sim_cfg = DriverConfig { horizon: 1e6, ..cfg(n, method, 0.05, steps) };
    let mut sim_oracles = QuadraticOracle::family(n, 1.0, 0.0, 1.0, 0.0, p);
    let sim = SimExecutor.run(&mut sim_oracles, &sim_cfg).unwrap();

    let thr_cfg = cfg(n, method, 0.05, steps);
    let mut thr_oracles = QuadraticOracle::family(n, 1.0, 0.0, 1.0, 0.0, p);
    let thr = run_threaded(&mut thr_oracles, &thr_cfg, 16).unwrap();

    let opts = ProcessOpts { exe: Some(repro_exe()), ..ProcessOpts::default() };
    let prc = run_process(&quad_spec(n), p, &thr_cfg, &opts).unwrap();

    assert!(!sim.diverged && !thr.diverged && !prc.diverged);
    let ls = sim.curve.last().unwrap().train_loss;
    let lt = thr.curve.last().unwrap().train_loss;
    let lp = prc.curve.last().unwrap().train_loss;
    assert!(ls < 1e-6, "sim final loss {ls}");
    assert!(lt < 1e-6, "thread final loss {lt}");
    assert!(lp < 1e-6, "process final loss {lp}");
    assert!((lp - ls).abs() < 1e-4, "process {lp} vs sim {ls}");
    assert!((lp - lt).abs() < 1e-4, "process {lp} vs thread {lt}");
}

/// Unix-domain transport carries the same run as TCP (the default).
#[cfg(unix)]
#[test]
fn process_backend_runs_over_unix_sockets() {
    let (n, p, steps) = (128usize, 2usize, 2_000u64);
    let method = Method::easgd_default(p, 4);
    let opts = ProcessOpts {
        addr: ProcessOpts::unix_addr().unwrap(),
        exe: Some(repro_exe()),
        ..ProcessOpts::default()
    };
    let r = run_process(&quad_spec(n), p, &cfg(n, method, 0.1, steps), &opts).unwrap();
    assert!(!r.diverged);
    assert!(r.curve.last().unwrap().train_loss < 1e-5);
    assert!(r.wire.unwrap().frames > 0);
}

/// The support matrix gates master-coupled methods off the process
/// backend with a descriptive error — no half-run, no panic.
#[test]
fn process_backend_refuses_master_coupled_methods() {
    let n = 32usize;
    let method = Method::MDownpour { delta: 0.9 };
    let opts = ProcessOpts { exe: Some(repro_exe()), ..ProcessOpts::default() };
    let e = run_process(&quad_spec(n), 2, &cfg(n, method, 0.01, 100), &opts).unwrap_err();
    assert!(format!("{e}").contains("master-coupled"), "{e}");
}

/// A rogue peer that opens a socket and sends Push before Hello (wire-
/// valid bytes, protocol-invalid order) must fail the run with an error
/// naming the protocol state and the offending frame — and the failure
/// must stop the surviving worker promptly, long before the horizon.
#[test]
fn rogue_push_before_hello_fails_naming_state_and_frame() {
    let (n, p) = (64usize, 2usize);
    let method = Method::easgd_default(p, 4);
    let opts = ProcessOpts {
        exe: Some(repro_exe()),
        fault: Some((1, "push-before-hello".to_string())),
        ..ProcessOpts::default()
    };
    // Unbounded steps: only the 60 s horizon or the rogue's violation
    // can end this run. Finishing fast proves the stop flag worked.
    let t0 = std::time::Instant::now();
    let e = run_process(&quad_spec(n), p, &cfg(n, method, 0.1, u64::MAX), &opts).unwrap_err();
    let msg = format!("{e}");
    assert!(msg.contains("protocol violation"), "not a protocol error: {msg}");
    assert!(
        msg.contains("AwaitHello") && msg.contains("Push"),
        "violation must name the state and the frame: {msg}"
    );
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(30),
        "survivors did not stop promptly after the protocol violation ({:?})",
        t0.elapsed()
    );
}

/// Config validation fires before any process is spawned: a
/// non-finite horizon is a named config error, not a hung run.
#[test]
fn process_backend_validates_config_before_spawning() {
    let n = 32usize;
    let method = Method::easgd_default(2, 1);
    let mut bad = cfg(n, method, 0.1, 100);
    bad.horizon = f64::INFINITY;
    let opts = ProcessOpts { exe: Some(repro_exe()), ..ProcessOpts::default() };
    let e = run_process(&quad_spec(n), 2, &bad, &opts).unwrap_err();
    assert!(format!("{e}").contains("horizon"), "{e}");
}
