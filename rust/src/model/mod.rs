//! Model-side substrates for the L3 coordinator.
//!
//! - [`flat`] — flat f32 parameter buffers and the *fused native update
//!   ops* (SGD / Nesterov / elastic exchange). They mirror the L1
//!   Pallas kernels bit-for-bit in semantics and are the coordinator's
//!   hot path when gradients come back from PJRT; `bench_update_hot_path`
//!   compares them against the PJRT-executed kernel variant.
//! - [`mlp`] — a small native MLP classifier with hand-written backprop:
//!   the cheap gradient oracle the Chapter-4/6 figure sweeps use at
//!   p up to 256 workers, where running the PJRT transformer per
//!   worker-step would be wall-clock prohibitive (DESIGN.md §2).
//!   Compute is batch-major: whole mini-batches flow through the
//!   register-blocked [`crate::linalg::gemm`] micro-kernels
//!   (`grad_batch` / `eval_batch`, zero steady-state allocations),
//!   with per-sample `grad`/`loss`/`predict` kept as thin wrappers;
//!   `bench_oracle` tracks the samples/sec trajectory.
//! - [`conv`] — the CIFAR-faithful convolutional stand-in (thesis §4.1
//!   trains conv nets): im2col + `sgemm` convolution blocks with the
//!   fused bias+ReLU epilogue, 2×2 max-pool, and an FC head — same
//!   flat-θ batch contract, same micro-kernels, same allocation-free
//!   steady state.
//!
//! Both gradient models implement [`BatchModel`], the small trait the
//! generic native oracle (`coordinator::NativeOracle`) is written
//! against; [`ModelKind`] is the `model=mlp|conv` CLI/config selector.

pub mod conv;
pub mod flat;
pub mod mlp;

pub use conv::{image_shape, ConvNet, ConvNetConfig, ConvSpec};
pub use flat::{elastic_exchange, nesterov_step, sgd_step};
pub use mlp::{Mlp, MlpConfig};

use crate::rng::Rng;

/// Softmax + cross-entropy top over a batch logits panel (`n × nc`
/// row-major, `n = labels.len()`): writes each `dtop` row as
/// `softmax(logits) − onehot(label)` and returns the summed data loss.
/// The SHARED backward top of both gradient models — a numerical
/// change here (max-shift, NaN behavior) applies to `model=mlp` and
/// `model=conv` alike.
pub(crate) fn softmax_ce_top(
    logits: &[f32],
    labels: &[usize],
    nc: usize,
    dtop: &mut [f32],
) -> f32 {
    let mut loss = 0.0f32;
    for (r, &label) in labels.iter().enumerate() {
        let z = &logits[r * nc..(r + 1) * nc];
        let dz = &mut dtop[r * nc..(r + 1) * nc];
        let m = z.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
        let mut sum = 0.0f32;
        for (e, &v) in dz.iter_mut().zip(z) {
            *e = (v - m).exp();
            sum += *e;
        }
        loss += sum.ln() + m - z[label];
        let inv = 1.0 / sum;
        for e in dz.iter_mut() {
            *e *= inv;
        }
        dz[label] -= 1.0;
    }
    loss
}

/// Summed data-term NLL + misclassification count over a batch logits
/// panel — the shared eval top (log-sum-exp + the NaN-hardened
/// total-order argmax) of both gradient models.
pub(crate) fn batch_nll_wrong(logits: &[f32], labels: &[usize], nc: usize) -> (f64, usize) {
    let mut nll = 0.0f64;
    let mut wrong = 0usize;
    for (r, &label) in labels.iter().enumerate() {
        let z = &logits[r * nc..(r + 1) * nc];
        let m = z.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
        let lse = m + z.iter().map(|v| (v - m).exp()).sum::<f32>().ln();
        nll += (lse - z[label]) as f64;
        if mlp::argmax(z) != label {
            wrong += 1;
        }
    }
    (nll, wrong)
}

/// The batch-major gradient-model contract shared by [`Mlp`] and
/// [`ConvNet`]: parameters live in ONE flat f32 slice, whole
/// mini-batches flow through `grad_batch` / `eval_batch`, and a
/// steady-state `grad_batch` call is allocation-free. The generic
/// native oracle (`coordinator::NativeOracle`) is written against this
/// trait, so every distributed method runs unchanged on either model.
pub trait BatchModel {
    /// Flat-θ length.
    fn n_params(&self) -> usize;
    /// Flat input dimension each sample slice must hold.
    fn in_dim(&self) -> usize;
    fn n_classes(&self) -> usize;
    /// Fresh He-scaled random θ.
    fn init_params(&self, rng: &mut Rng) -> Vec<f32>;
    /// `0.5·λ‖θ‖²`, computed once per θ.
    fn l2_penalty(&self, theta: &[f32]) -> f32;
    /// Mean mini-batch gradient into `grad` (overwritten), l2 applied
    /// once; returns the mean loss incl. l2.
    fn grad_batch<'a, I: IntoIterator<Item = (&'a [f32], usize)>>(
        &mut self,
        theta: &[f32],
        samples: I,
        grad: &mut [f32],
    ) -> f32;
    /// Summed data-term NLL + misclassification count (no l2).
    fn eval_batch<'a, I: IntoIterator<Item = (&'a [f32], usize)>>(
        &mut self,
        theta: &[f32],
        samples: I,
    ) -> (f64, usize);
}

impl BatchModel for Mlp {
    fn n_params(&self) -> usize {
        self.config().n_params()
    }

    fn in_dim(&self) -> usize {
        self.config().dims[0]
    }

    fn n_classes(&self) -> usize {
        self.config().n_classes()
    }

    fn init_params(&self, rng: &mut Rng) -> Vec<f32> {
        Mlp::init_params(self, rng)
    }

    fn l2_penalty(&self, theta: &[f32]) -> f32 {
        Mlp::l2_penalty(self, theta)
    }

    fn grad_batch<'a, I: IntoIterator<Item = (&'a [f32], usize)>>(
        &mut self,
        theta: &[f32],
        samples: I,
        grad: &mut [f32],
    ) -> f32 {
        Mlp::grad_batch(self, theta, samples, grad)
    }

    fn eval_batch<'a, I: IntoIterator<Item = (&'a [f32], usize)>>(
        &mut self,
        theta: &[f32],
        samples: I,
    ) -> (f64, usize) {
        Mlp::eval_batch(self, theta, samples)
    }
}

impl BatchModel for ConvNet {
    fn n_params(&self) -> usize {
        self.config().n_params()
    }

    fn in_dim(&self) -> usize {
        self.config().in_dim()
    }

    fn n_classes(&self) -> usize {
        self.config().n_classes()
    }

    fn init_params(&self, rng: &mut Rng) -> Vec<f32> {
        ConvNet::init_params(self, rng)
    }

    fn l2_penalty(&self, theta: &[f32]) -> f32 {
        ConvNet::l2_penalty(self, theta)
    }

    fn grad_batch<'a, I: IntoIterator<Item = (&'a [f32], usize)>>(
        &mut self,
        theta: &[f32],
        samples: I,
        grad: &mut [f32],
    ) -> f32 {
        ConvNet::grad_batch(self, theta, samples, grad)
    }

    fn eval_batch<'a, I: IntoIterator<Item = (&'a [f32], usize)>>(
        &mut self,
        theta: &[f32],
        samples: I,
    ) -> (f64, usize) {
        ConvNet::eval_batch(self, theta, samples)
    }
}

/// The `model=mlp|conv` selector plumbed through the config system,
/// the `train` CLI, the ch4 sweeps, and `bench_oracle`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKind {
    /// The historical MLP stand-in ([`Mlp`], `MlpConfig::sweep_default`).
    Mlp,
    /// The §4.1-faithful conv stand-in ([`ConvNet`],
    /// `ConvNetConfig::for_blob` over the same blob input reshaped to
    /// a 1×h×w image).
    Conv,
}

impl ModelKind {
    pub fn parse(s: &str) -> Option<ModelKind> {
        match s {
            "mlp" => Some(ModelKind::Mlp),
            "conv" | "convnet" | "cnn" => Some(ModelKind::Conv),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ModelKind::Mlp => "mlp",
            ModelKind::Conv => "conv",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_kind_parse_roundtrip() {
        assert_eq!(ModelKind::parse("mlp"), Some(ModelKind::Mlp));
        assert_eq!(ModelKind::parse("conv"), Some(ModelKind::Conv));
        assert_eq!(ModelKind::parse("cnn"), Some(ModelKind::Conv));
        assert_eq!(ModelKind::parse("transformer"), None);
        assert_eq!(ModelKind::Conv.name(), "conv");
    }

    #[test]
    fn both_models_satisfy_the_batch_contract() {
        fn check<M: BatchModel>(mut m: M) {
            let mut rng = Rng::new(2);
            let theta = m.init_params(&mut rng);
            assert_eq!(theta.len(), m.n_params());
            let din = m.in_dim();
            let batch: Vec<(Vec<f32>, usize)> = (0..6)
                .map(|i| {
                    let x: Vec<f32> =
                        (0..din).map(|_| rng.normal(0.0, 1.0) as f32).collect();
                    (x, i % m.n_classes())
                })
                .collect();
            let mut g = vec![0.0f32; theta.len()];
            let loss =
                m.grad_batch(&theta, batch.iter().map(|(x, y)| (x.as_slice(), *y)), &mut g);
            assert!(loss.is_finite());
            assert!(g.iter().any(|v| *v != 0.0), "gradient must be non-trivial");
            let (nll, wrong) =
                m.eval_batch(&theta, batch.iter().map(|(x, y)| (x.as_slice(), *y)));
            assert!(nll.is_finite() && wrong <= batch.len());
        }
        check(Mlp::new(MlpConfig::new(&[12, 8, 3], 1e-4)));
        check(ConvNet::new(ConvNetConfig::for_blob(12, 3, 1e-4)));
    }
}
