//! Model-side substrates for the L3 coordinator.
//!
//! - [`flat`] — flat f32 parameter buffers and the *fused native update
//!   ops* (SGD / Nesterov / elastic exchange). They mirror the L1
//!   Pallas kernels bit-for-bit in semantics and are the coordinator's
//!   hot path when gradients come back from PJRT; `bench_update_hot_path`
//!   compares them against the PJRT-executed kernel variant.
//! - [`mlp`] — a small native MLP classifier with hand-written backprop:
//!   the cheap gradient oracle the Chapter-4/6 figure sweeps use at
//!   p up to 256 workers, where running the PJRT transformer per
//!   worker-step would be wall-clock prohibitive (DESIGN.md §2).
//!   Compute is batch-major: whole mini-batches flow through the
//!   register-blocked [`crate::linalg::gemm`] micro-kernels
//!   (`grad_batch` / `eval_batch`, zero steady-state allocations),
//!   with per-sample `grad`/`loss`/`predict` kept as thin wrappers;
//!   `bench_oracle` tracks the samples/sec trajectory.

pub mod flat;
pub mod mlp;

pub use flat::{elastic_exchange, nesterov_step, sgd_step};
pub use mlp::{Mlp, MlpConfig};
