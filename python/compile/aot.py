"""AOT export: lower the L2/L1 jax functions ONCE to HLO *text* plus a
manifest the rust runtime consumes. Python never runs on the train path.

Interchange is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published ``xla`` 0.1.6 crate links) rejects
(``proto.id() <= INT_MAX``). The text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts written to --out-dir (default ../artifacts):

  train_step.hlo.txt    (params..., tokens i32[B,T], targets i32[B,T])
                        -> (loss f32[], grads...)
  eval_step.hlo.txt     (params..., tokens, targets) -> (loss, n_correct)
  sgd_step.hlo.txt      (eta f32[1], delta f32[1], x f32[N], v, g)
                        -> (x', v')          [L1 pallas kernel]
  elastic.hlo.txt       (alpha f32[1], x f32[N], c f32[N]) -> (x', c')
  fused_step.hlo.txt    (eta, alpha, delta, do, x, v, g, c)
                        -> (x', v', center_delta)
  init_params.bin       flat little-endian f32[N], the shared random init
                        (thesis §4.1: same init for master and workers)
  manifest.json         model config, param (name, shape, offset) table,
                        artifact signatures
"""
from __future__ import annotations

import argparse
import json
import os
from typing import List

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import easgd_update as KU


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_model(cfg: M.ModelConfig, out_dir: str, seed: int) -> dict:
    specs = M.param_specs(cfg)
    param_structs = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in specs]
    tok = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.int32)

    lowered = jax.jit(
        lambda *a: M.train_step(cfg, list(a[:-2]), a[-2], a[-1])
    ).lower(*param_structs, tok, tok)
    with open(os.path.join(out_dir, "train_step.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))

    lowered = jax.jit(
        lambda *a: M.eval_step(cfg, list(a[:-2]), a[-2], a[-1])
    ).lower(*param_structs, tok, tok)
    with open(os.path.join(out_dir, "eval_step.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))

    # Shared random init (same parameter for master and every worker —
    # thesis §4.1 notes different seeds trap symmetry breaking).
    params = M.init_params(cfg, seed)
    flat = np.concatenate([np.asarray(p, np.float32).ravel() for p in params])
    flat.tofile(os.path.join(out_dir, "init_params.bin"))

    offsets, off = [], 0
    table = []
    for name, shape in specs:
        size = int(np.prod(shape))
        table.append({"name": name, "shape": list(shape),
                      "offset": off, "size": size})
        off += size
    return {
        "preset_params": off,
        "config": {
            "vocab": cfg.vocab, "d_model": cfg.d_model,
            "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
            "seq_len": cfg.seq_len, "batch": cfg.batch,
            "weight_decay": cfg.weight_decay,
        },
        "params": table,
        "seed": seed,
    }


def export_update_kernels(n: int, out_dir: str) -> dict:
    """Lower the L1 update kernels for flat length n (= total params)."""
    vec = jax.ShapeDtypeStruct((n,), jnp.float32)
    sc = jax.ShapeDtypeStruct((1,), jnp.float32)

    lowered = jax.jit(KU.sgd_nesterov_step).lower(vec, vec, vec, sc, sc)
    with open(os.path.join(out_dir, "sgd_step.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))

    lowered = jax.jit(KU.elastic_exchange).lower(vec, vec, sc)
    with open(os.path.join(out_dir, "elastic.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))

    lowered = jax.jit(KU.easgd_fused_step).lower(
        vec, vec, vec, vec, sc, sc, sc, sc)
    with open(os.path.join(out_dir, "fused_step.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))

    return {"flat_len": n, "block": KU.BLOCK}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--preset", default=os.environ.get("ET_PRESET", "tiny"),
                    choices=sorted(M.PRESETS))
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    cfg = M.PRESETS[args.preset]
    manifest = {"preset": args.preset}
    manifest.update(export_model(cfg, args.out_dir, args.seed))
    manifest["kernels"] = export_update_kernels(
        manifest["preset_params"], args.out_dir)
    manifest["artifacts"] = {
        "train_step": "train_step.hlo.txt",
        "eval_step": "eval_step.hlo.txt",
        "sgd_step": "sgd_step.hlo.txt",
        "elastic": "elastic.hlo.txt",
        "fused_step": "fused_step.hlo.txt",
        "init_params": "init_params.bin",
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    n = manifest["preset_params"]
    print(f"AOT export done: preset={args.preset} params={n} "
          f"({n * 4 / 1e6:.1f} MB) -> {args.out_dir}")


if __name__ == "__main__":
    main()
