//! Native MLP classifier with hand-written backprop — the cheap,
//! allocation-conscious gradient oracle behind the Chapter-4/6 figure
//! sweeps (a stand-in for the thesis' CIFAR conv nets; see DESIGN.md §2:
//! the distributed-optimizer dynamics under study are model-agnostic,
//! and at p = 256 simulated workers the PJRT transformer would be
//! wall-clock prohibitive).
//!
//! Architecture: input → [hidden ReLU]× → linear → softmax + CE, with
//! optional l2 regularization (thesis §4.1). Parameters live in ONE
//! flat f32 buffer so the coordinator's elastic/momentum ops
//! ([`super::flat`]) apply directly.
//!
//! Compute path: **batch-major**. Activations are `n_batch × dim`
//! row-major panels and every layer product runs on the
//! [`crate::linalg::gemm`] micro-kernels — fused bias+ReLU on the way
//! up ([`gemm::sgemm_bias_act`]), `Aᵀ·B` / `A·Bᵀ` accumulating GEMMs
//! on the way down — with the softmax-CE top vectorized over the
//! batch. All scratch is pre-allocated on first use and reused, so a
//! steady-state [`Mlp::grad_batch`] call performs zero heap
//! allocations (enforced by `tests/alloc_free.rs`). Thin per-sample
//! wrappers ([`Mlp::grad`], [`Mlp::loss`], [`Mlp::predict`]) keep the
//! single-sample callers and the PJRT oracle untouched.

use crate::linalg::gemm;
use crate::rng::Rng;

/// Layer sizes: `dims = [in, h1, ..., out]`.
#[derive(Clone, Debug)]
pub struct MlpConfig {
    pub dims: Vec<usize>,
    pub l2: f32,
}

impl MlpConfig {
    pub fn new(dims: &[usize], l2: f32) -> Self {
        assert!(dims.len() >= 2);
        Self { dims: dims.to_vec(), l2 }
    }

    /// The sweep default: a 3-layer net small enough for 256 workers.
    pub fn sweep_default() -> Self {
        Self::new(&[32, 64, 32, 10], 1e-4)
    }

    pub fn n_params(&self) -> usize {
        self.dims
            .windows(2)
            .map(|w| w[0] * w[1] + w[1]) // W + b per layer
            .sum()
    }

    pub fn n_classes(&self) -> usize {
        *self.dims.last().unwrap()
    }
}

/// Total-order argmax: the first strict maximum wins; NaN entries never
/// win (an all-NaN row degrades to class 0 instead of panicking).
/// Shared with the conv model ([`super::conv`]).
#[inline]
pub(crate) fn argmax(z: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in z.iter().enumerate() {
        if v > bv {
            best = i;
            bv = v;
        }
    }
    best
}

/// The model: holds no parameters itself — they are passed as flat
/// slices — only the batch-major scratch panels for fwd/bwd, re-used
/// across calls so the sweep hot loop is allocation-free.
pub struct Mlp {
    cfg: MlpConfig,
    /// θ offset of layer l's weight block (its bias follows at
    /// `offsets[l] + din·dout`).
    offsets: Vec<usize>,
    /// Row capacity of the scratch panels below (grows monotonically).
    cap: usize,
    /// Post-activation panels, `cap × dims[l]` row-major; `acts[0]` is
    /// the packed input batch and is sized by [`Mlp::pack`] itself.
    acts: Vec<Vec<f32>>,
    /// Activation-gradient panels, same shapes; `d[0]` stays empty
    /// (the input gradient is never needed).
    d: Vec<Vec<f32>>,
    /// Labels of the packed batch.
    labels: Vec<usize>,
}

impl Mlp {
    pub fn new(cfg: MlpConfig) -> Self {
        let mut offsets = Vec::with_capacity(cfg.dims.len() - 1);
        let mut off = 0;
        for w in cfg.dims.windows(2) {
            offsets.push(off);
            off += w[0] * w[1] + w[1];
        }
        let acts = cfg.dims.iter().map(|_| Vec::new()).collect();
        let d = cfg.dims.iter().map(|_| Vec::new()).collect();
        Self { cfg, offsets, cap: 0, acts, d, labels: Vec::new() }
    }

    pub fn config(&self) -> &MlpConfig {
        &self.cfg
    }

    /// He-scaled random init into a fresh flat buffer.
    pub fn init_params(&self, rng: &mut Rng) -> Vec<f32> {
        let mut theta = vec![0.0f32; self.cfg.n_params()];
        let mut off = 0;
        for w in self.cfg.dims.windows(2) {
            let (fan_in, fan_out) = (w[0], w[1]);
            let std = (2.0 / fan_in as f64).sqrt() as f32;
            rng.fill_gaussian_f32(&mut theta[off..off + fan_in * fan_out], std);
            off += fan_in * fan_out;
            // biases zero (thesis §4.1 CIFAR init).
            off += fan_out;
        }
        theta
    }

    /// Grow the hidden/output scratch panels to `n` rows (amortized:
    /// a no-op once the largest batch size has been seen).
    fn ensure_rows(&mut self, n: usize) {
        if n <= self.cap {
            return;
        }
        for l in 1..self.cfg.dims.len() {
            let dim = self.cfg.dims[l];
            self.acts[l].resize(n * dim, 0.0);
            self.d[l].resize(n * dim, 0.0);
        }
        self.cap = n;
    }

    /// Copy the batch into the packed input panel + label buffer;
    /// returns the batch size. Reuses capacity — allocation-free at a
    /// steady batch size.
    fn pack<'a, I: IntoIterator<Item = (&'a [f32], usize)>>(&mut self, samples: I) -> usize {
        let din = self.cfg.dims[0];
        let nc = self.cfg.n_classes();
        self.acts[0].clear();
        self.labels.clear();
        for (x, y) in samples {
            assert_eq!(x.len(), din, "input dim mismatch");
            assert!(y < nc, "label {y} out of range");
            self.acts[0].extend_from_slice(x);
            self.labels.push(y);
        }
        let n = self.labels.len();
        self.ensure_rows(n);
        n
    }

    /// Forward over the packed batch: one fused GEMM (bias broadcast +
    /// ReLU epilogue) per layer, logits left in the last panel.
    fn forward_packed(&mut self, theta: &[f32], n: usize) {
        let n_layers = self.cfg.dims.len() - 1;
        for l in 0..n_layers {
            let (din, dout) = (self.cfg.dims[l], self.cfg.dims[l + 1]);
            let off = self.offsets[l];
            let w = &theta[off..off + din * dout];
            let bias = &theta[off + din * dout..off + din * dout + dout];
            let (lo, hi) = self.acts.split_at_mut(l + 1);
            let inp = &lo[l][..n * din];
            let out = &mut hi[0][..n * dout];
            gemm::sgemm_bias_act(n, dout, din, inp, w, bias, l + 1 < n_layers, out);
        }
    }

    /// Batched forward pass; packs the samples (labels ride along for
    /// the loss paths; pass 0 when irrelevant) and leaves the logits in
    /// the internal panel read by [`Mlp::logits`]. Returns the batch
    /// size.
    pub fn forward_batch<'a, I: IntoIterator<Item = (&'a [f32], usize)>>(
        &mut self,
        theta: &[f32],
        samples: I,
    ) -> usize {
        let n = self.pack(samples);
        self.forward_packed(theta, n);
        n
    }

    /// Logits panel of the last [`Mlp::forward_batch`] (`n × classes`
    /// row-major).
    pub fn logits(&self, n: usize) -> &[f32] {
        let nc = self.cfg.n_classes();
        &self.acts[self.cfg.dims.len() - 1][..n * nc]
    }

    /// `0.5·λ‖θ‖²` — computed ONCE per θ; the eval loop shares it
    /// across every sample instead of rescanning `n_params` each time.
    pub fn l2_penalty(&self, theta: &[f32]) -> f32 {
        if self.cfg.l2 == 0.0 {
            return 0.0;
        }
        0.5 * self.cfg.l2 * theta.iter().map(|t| t * t).sum::<f32>()
    }

    /// Backprop over the packed batch, ACCUMULATING the summed (not
    /// averaged) data-term gradient into `grad`; returns the summed
    /// data loss (no l2). Shared core of [`Mlp::grad`] and
    /// [`Mlp::grad_batch`].
    fn grad_packed(&mut self, theta: &[f32], n: usize, grad: &mut [f32]) -> f32 {
        self.forward_packed(theta, n);
        let n_layers = self.cfg.dims.len() - 1;
        let nc = self.cfg.n_classes();

        // Softmax-CE top, vectorized over the batch: d_top row =
        // softmax(logits) − onehot(label), written in place (shared
        // with the conv model — [`super::softmax_ce_top`]).
        let loss = super::softmax_ce_top(
            &self.acts[n_layers][..n * nc],
            &self.labels,
            nc,
            &mut self.d[n_layers][..n * nc],
        );

        // Backward through layers, three GEMM-shaped products each.
        for l in (0..n_layers).rev() {
            let (din, dout) = (self.cfg.dims[l], self.cfg.dims[l + 1]);
            let off = self.offsets[l];
            // dpre = dact ⊙ relu' for hidden layers (act > 0 ⇔ pre > 0;
            // the last layer is linear), applied in place.
            if l + 1 < n_layers {
                let act = &self.acts[l + 1][..n * dout];
                let dl = &mut self.d[l + 1][..n * dout];
                for (dv, &av) in dl.iter_mut().zip(act) {
                    if av <= 0.0 {
                        *dv = 0.0;
                    }
                }
            }
            // gW(din×dout) += actsᵀ(l) · dpre — the batch sum is the
            // GEMM's k-reduction.
            gemm::sgemm(
                true,
                false,
                din,
                dout,
                n,
                &self.acts[l][..n * din],
                &self.d[l + 1][..n * dout],
                &mut grad[off..off + din * dout],
            );
            // gb += column sums of dpre.
            gemm::col_sums_accum(
                n,
                dout,
                &self.d[l + 1][..n * dout],
                &mut grad[off + din * dout..off + din * dout + dout],
            );
            // dact(l) = dpre · Wᵀ for the next level down.
            if l > 0 {
                let w = &theta[off..off + din * dout];
                let (dlo, dhi) = self.d.split_at_mut(l + 1);
                let dl = &mut dlo[l][..n * din];
                dl.iter_mut().for_each(|v| *v = 0.0);
                gemm::sgemm(false, true, n, din, dout, &dhi[0][..n * dout], w, dl);
            }
        }
        loss
    }

    /// Batched mini-batch gradient: the MEAN gradient over the batch is
    /// written into `grad` (overwritten, not accumulated) with the l2
    /// term applied once. Returns the mean loss (incl. l2) — the
    /// oracle-facing hot path.
    pub fn grad_batch<'a, I: IntoIterator<Item = (&'a [f32], usize)>>(
        &mut self,
        theta: &[f32],
        samples: I,
        grad: &mut [f32],
    ) -> f32 {
        assert_eq!(grad.len(), theta.len());
        let n = self.pack(samples);
        assert!(n > 0, "empty batch");
        grad.iter_mut().for_each(|g| *g = 0.0);
        let loss = self.grad_packed(theta, n, grad);
        let inv = 1.0 / n as f32;
        grad.iter_mut().for_each(|g| *g *= inv);
        if self.cfg.l2 > 0.0 {
            for (g, t) in grad.iter_mut().zip(theta) {
                *g += self.cfg.l2 * t;
            }
        }
        loss * inv + self.l2_penalty(theta)
    }

    /// Mini-batch gradient over owned samples: mean over the batch.
    /// Returns mean loss. (Slice-of-pairs convenience over
    /// [`Mlp::grad_batch`].)
    pub fn batch_grad(
        &mut self,
        theta: &[f32],
        xs: &[(Vec<f32>, usize)],
        grad: &mut [f32],
    ) -> f32 {
        self.grad_batch(theta, xs.iter().map(|(x, y)| (x.as_slice(), *y)), grad)
    }

    /// Accumulate ∂loss/∂θ for one sample into `grad` (caller zeroes or
    /// scales; the l2 term is added per call). Returns the sample loss.
    /// Thin batch-of-one wrapper — the sweeps should prefer
    /// [`Mlp::grad_batch`].
    pub fn grad(&mut self, theta: &[f32], x: &[f32], label: usize, grad: &mut [f32]) -> f32 {
        assert_eq!(grad.len(), theta.len());
        let n = self.pack(std::iter::once((x, label)));
        let loss = self.grad_packed(theta, n, grad);
        if self.cfg.l2 > 0.0 {
            for (g, t) in grad.iter_mut().zip(theta) {
                *g += self.cfg.l2 * t;
            }
        }
        loss + self.l2_penalty(theta)
    }

    /// Summed data-term NLL and misclassification count over the batch
    /// (no l2 — add [`Mlp::l2_penalty`] once per θ) — the eval path.
    pub fn eval_batch<'a, I: IntoIterator<Item = (&'a [f32], usize)>>(
        &mut self,
        theta: &[f32],
        samples: I,
    ) -> (f64, usize) {
        let n = self.forward_batch(theta, samples);
        let nc = self.cfg.n_classes();
        let logits = &self.acts[self.cfg.dims.len() - 1][..n * nc];
        super::batch_nll_wrong(logits, &self.labels, nc)
    }

    /// Loss only (evaluation path; batch-of-one wrapper).
    pub fn loss(&mut self, theta: &[f32], x: &[f32], label: usize) -> f32 {
        let (nll, _) = self.eval_batch(theta, std::iter::once((x, label)));
        nll as f32 + self.l2_penalty(theta)
    }

    /// Predicted class (evaluation path; batch-of-one wrapper). NaN
    /// logits degrade to class 0 instead of panicking.
    pub fn predict(&mut self, theta: &[f32], x: &[f32]) -> usize {
        let n = self.forward_batch(theta, std::iter::once((x, 0)));
        argmax(self.logits(n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> (Mlp, Vec<f32>) {
        let cfg = MlpConfig::new(&[4, 6, 3], 0.0);
        let mlp = Mlp::new(cfg);
        let mut rng = Rng::new(5);
        let theta = mlp.init_params(&mut rng);
        (mlp, theta)
    }

    #[test]
    fn param_count_matches_layout() {
        let cfg = MlpConfig::new(&[4, 6, 3], 0.0);
        assert_eq!(cfg.n_params(), 4 * 6 + 6 + 6 * 3 + 3);
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let (mut mlp, mut theta) = tiny();
        let x = vec![0.3, -0.5, 1.2, 0.1];
        let label = 2;
        let mut g = vec![0.0; theta.len()];
        mlp.grad(&theta, &x, label, &mut g);
        let eps = 1e-3f32;
        let mut rng = Rng::new(8);
        for _ in 0..25 {
            let i = rng.below(theta.len());
            let orig = theta[i];
            theta[i] = orig + eps;
            let lp = mlp.loss(&theta, &x, label);
            theta[i] = orig - eps;
            let lm = mlp.loss(&theta, &x, label);
            theta[i] = orig;
            let fd = (lp - lm) / (2.0 * eps);
            assert!((fd - g[i]).abs() < 2e-3 * (1.0 + fd.abs()),
                    "param {i}: fd {fd} vs analytic {}", g[i]);
        }
    }

    #[test]
    fn gradient_with_l2_matches_finite_differences() {
        let cfg = MlpConfig::new(&[3, 5, 2], 1e-2);
        let mut mlp = Mlp::new(cfg);
        let mut rng = Rng::new(6);
        let mut theta = mlp.init_params(&mut rng);
        let x = vec![1.0, -1.0, 0.5];
        let mut g = vec![0.0; theta.len()];
        mlp.grad(&theta, &x, 1, &mut g);
        let eps = 1e-3f32;
        for i in [0usize, 7, 14, 20] {
            let orig = theta[i];
            theta[i] = orig + eps;
            let lp = mlp.loss(&theta, &x, 1);
            theta[i] = orig - eps;
            let lm = mlp.loss(&theta, &x, 1);
            theta[i] = orig;
            let fd = (lp - lm) / (2.0 * eps);
            assert!((fd - g[i]).abs() < 3e-3 * (1.0 + fd.abs()));
        }
    }

    #[test]
    fn training_reduces_loss_and_fits_separable_data() {
        let cfg = MlpConfig::new(&[2, 16, 2], 0.0);
        let mut mlp = Mlp::new(cfg);
        let mut rng = Rng::new(7);
        let mut theta = mlp.init_params(&mut rng);
        // Two gaussian blobs.
        let mut data = Vec::new();
        for _ in 0..100 {
            let y = rng.below(2);
            let cx = if y == 0 { -1.0 } else { 1.0 };
            data.push((
                vec![rng.normal(cx, 0.3) as f32, rng.normal(-cx, 0.3) as f32],
                y,
            ));
        }
        let mut g = vec![0.0; theta.len()];
        let l0 = mlp.batch_grad(&theta, &data, &mut g);
        for _ in 0..200 {
            mlp.batch_grad(&theta, &data, &mut g);
            crate::model::flat::sgd_step(&mut theta, &g, 0.5);
        }
        let l1 = mlp.batch_grad(&theta, &data, &mut g);
        assert!(l1 < l0 * 0.2, "loss {l0} -> {l1}");
        let correct = data
            .iter()
            .filter(|(x, y)| mlp.predict(&theta, x) == *y)
            .count();
        assert!(correct >= 95, "accuracy {correct}/100");
    }

    #[test]
    fn batch_grad_is_mean_of_sample_grads() {
        let (mut mlp, theta) = tiny();
        let data = vec![
            (vec![0.1, 0.2, 0.3, 0.4], 0usize),
            (vec![-0.5, 0.5, -0.5, 0.5], 1usize),
        ];
        let mut gb = vec![0.0; theta.len()];
        mlp.batch_grad(&theta, &data, &mut gb);
        let mut g1 = vec![0.0; theta.len()];
        let mut g2 = vec![0.0; theta.len()];
        mlp.grad(&theta, &data[0].0, 0, &mut g1);
        mlp.grad(&theta, &data[1].0, 1, &mut g2);
        for i in 0..theta.len() {
            assert!((gb[i] - 0.5 * (g1[i] + g2[i])).abs() < 1e-6);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = MlpConfig::sweep_default();
        let m1 = Mlp::new(cfg.clone()).init_params(&mut Rng::new(3));
        let m2 = Mlp::new(cfg).init_params(&mut Rng::new(3));
        assert_eq!(m1, m2);
    }

    #[test]
    fn predict_survives_nan_logits() {
        // NaN parameters poison every logit; the argmax must degrade to
        // class 0 instead of panicking (seed code unwrap()ed a
        // partial_cmp here).
        let (mut mlp, theta) = tiny();
        let bad = vec![f32::NAN; theta.len()];
        let x = vec![0.5, -0.25, 1.0, 0.0];
        assert_eq!(mlp.predict(&bad, &x), 0);
        // Sane logits still pick the true maximum afterwards.
        let p = mlp.predict(&theta, &x);
        assert!(p < 3);
        let n = mlp.forward_batch(&theta, std::iter::once((x.as_slice(), 0)));
        let logits = mlp.logits(n).to_vec();
        let want = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap()
            .0;
        assert_eq!(p, want);
    }

    #[test]
    fn argmax_total_order_edge_cases() {
        assert_eq!(argmax(&[1.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[f32::NAN, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[1.0, f32::NAN, 2.0]), 2);
        assert_eq!(argmax(&[f32::NAN, f32::NAN]), 0);
        assert_eq!(argmax(&[f32::NEG_INFINITY, f32::NEG_INFINITY]), 0);
        assert_eq!(argmax(&[2.0, 2.0]), 0, "first strict max wins ties");
    }

}
