//! The reproduction harness: one generator per thesis table/figure.
//!
//! `repro figure <id>` (or `figure all`) regenerates the figure's data
//! as CSV under `--out-dir` and prints the paper-shaped summary rows.
//! Exact numbers differ from the thesis (our substrate is a simulator,
//! not the authors' GPU cluster — DESIGN.md §2); the *shape* claims are
//! asserted in each generator and recorded in EXPERIMENTS.md.
//!
//! `--full` switches from the quick default grids/horizons to
//! thesis-scale ones.

pub mod benchkit;
pub mod ch3;
pub mod ch4;
pub mod ch5;
pub mod ch6;
pub mod csv;

use crate::bail;
use crate::config::Args;
use crate::coordinator::Backend;
use crate::error::Result;
use crate::model::ModelKind;

/// Global options every figure generator receives.
#[derive(Clone, Debug)]
pub struct FigOpts {
    pub out_dir: String,
    /// Thesis-scale grids/horizons instead of the quick defaults.
    pub full: bool,
    pub seed: u64,
    /// Executor backend for the parallel-run figures (`backend=sim`
    /// keeps virtual time; `backend=thread` runs real workers, with
    /// horizons read as wall-clock seconds).
    pub backend: Backend,
    /// Gradient model for the native-oracle sweeps (`model=mlp` is the
    /// historical stand-in; `model=conv` is the §4.1-faithful im2col
    /// conv net over the same blob data read as a 1×h×w image).
    pub model: ModelKind,
    /// Hybrid-parallelism knob: GEMM threads per worker for the
    /// native-oracle figures. 1 (the default) keeps every figure
    /// byte-for-byte on the historical serial compute path.
    pub threads: usize,
    /// Kernel-tier knob (`simd=auto|avx2|neon|scalar`); resolved by
    /// `linalg::simd::configure` at figure start — an unavailable tier
    /// is a clean CLI error, never a silent fallback.
    pub simd: String,
}

impl FigOpts {
    /// Errors on an unknown `backend=`/`model=` value — a figure
    /// silently run on the wrong executor or model is worse than a
    /// refused invocation, and a `panic!` is worse than a clean CLI
    /// error.
    pub fn from_args(args: &Args) -> Result<FigOpts> {
        let backend_str = args.get_str("backend", "sim");
        let backend = match Backend::parse(backend_str) {
            Some(b) => b,
            None => bail!("unknown backend '{backend_str}' (sim|thread)"),
        };
        let model_str = args.get_str("model", "mlp");
        let model = match ModelKind::parse(model_str) {
            Some(m) => m,
            None => bail!("unknown model '{model_str}' (mlp|conv)"),
        };
        let threads = args.get_usize("threads", 1)?;
        if threads == 0 {
            bail!("threads must be >= 1 (got 0): 1 means no intra-worker parallelism");
        }
        let simd = args.get_str("simd", "auto");
        if !crate::linalg::simd::is_known_request(simd) {
            bail!("unknown simd tier '{simd}' (auto|avx2|neon|scalar)");
        }
        Ok(FigOpts {
            out_dir: args.get_str("out-dir", "out").to_string(),
            full: args.get_bool("full", false)?,
            seed: args.get_u64("seed", 0)?,
            backend,
            model,
            threads,
            simd: simd.to_string(),
        })
    }
}

/// All known figure ids in thesis order.
pub const ALL_FIGURES: &[&str] = &[
    "fig3.1", "fig3.2", "fig3.3", "tab4.1", "fig4.1-4.4", "fig4.5-4.7",
    "fig4.8-4.9", "fig4.10-4.11", "fig4.12", "fig4.13", "fig4.14-4.15",
    "tab4.4", "fig5.1", "fig5.2", "fig5.3", "fig5.4-5.5", "fig5.6",
    "fig5.7", "fig5.8", "fig5.9", "fig5.10-5.12", "fig5.13", "fig5.14",
    "fig5.15-5.18", "fig5.19", "fig5.20", "fig6.3-6.10", "fig6.11-6.12",
    "fig6.13gs",
];

/// Dispatch a figure id.
pub fn run(id: &str, opts: &FigOpts) -> Result<()> {
    std::fs::create_dir_all(&opts.out_dir)?;
    crate::linalg::pool::configure_threads(opts.threads);
    crate::linalg::simd::configure(&opts.simd)?;
    match id {
        "all" => {
            for f in ALL_FIGURES {
                println!("==== {f} ====");
                run(f, opts)?;
            }
            Ok(())
        }
        "fig3.1" => ch3::fig3_1(opts),
        "fig3.2" => ch3::fig3_2(opts),
        "fig3.3" => ch3::fig3_3(opts),
        "tab4.1" => ch4::tab4_1(opts),
        "fig4.1-4.4" => ch4::fig4_tau_sweep(opts),
        "fig4.5-4.7" => ch4::fig4_p_sweep(opts),
        "fig4.8-4.9" => ch4::fig4_imagenet(opts),
        "fig4.10-4.11" => ch4::fig4_sequential(opts),
        "fig4.12" => ch4::fig4_12_eta(opts),
        "fig4.13" => ch4::fig4_13_tau_decay(opts),
        "fig4.14-4.15" => ch4::fig4_speedup(opts),
        "tab4.4" => ch4::tab4_4(opts),
        "fig5.1" => ch5::fig5_1(opts),
        "fig5.2" => ch5::fig5_2(opts),
        "fig5.3" => ch5::fig5_3_7(opts, 0.1, "fig5.3"),
        "fig5.7" => ch5::fig5_3_7(opts, 1.5, "fig5.7"),
        "fig5.4-5.5" => ch5::fig5_4_5(opts),
        "fig5.6" => ch5::fig5_6(opts),
        "fig5.8" => ch5::fig5_8(opts),
        "fig5.9" => ch5::fig5_9(opts),
        "fig5.10-5.12" => ch5::fig5_10_12(opts),
        "fig5.13" => ch5::fig5_13(opts),
        "fig5.14" => ch5::fig5_14(opts),
        "fig5.15-5.18" => ch5::fig5_15_18(opts),
        "fig5.19" => ch5::fig5_19(opts),
        "fig5.20" => ch5::fig5_20(opts),
        "fig6.3-6.10" => ch6::fig6_tree(opts),
        "fig6.11-6.12" => ch6::fig6_best(opts),
        "fig6.13gs" => ch6::fig6_gs(opts),
        other => bail!("unknown figure id '{other}' (see `repro figure list`)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_listed_figure_dispatches() {
        // Cheap figures run outright; expensive ones are covered by the
        // bench/figure integration — here we at least verify dispatch
        // does not hit the unknown-id arm.
        let opts = FigOpts {
            out_dir: std::env::temp_dir()
                .join("et_figtest")
                .to_string_lossy()
                .into_owned(),
            full: false,
            seed: 0,
            backend: Backend::Sim,
            model: ModelKind::Mlp,
            // "auto" resolves to the ambient detected tier, so running
            // this figure does not flip the process-global tier under
            // concurrently-running bitwise kernel tests.
            simd: "auto".into(),
            threads: 1,
        };
        // A fast, pure-math subset end-to-end:
        for id in ["fig5.9", "fig5.20", "fig5.13"] {
            run(id, &opts).unwrap();
        }
        assert!(run("nope", &opts).is_err());
    }

    #[test]
    fn from_args_rejects_unknown_backend_with_an_error() {
        let args = Args::parse(["backend=gpu".to_string()]);
        let e = FigOpts::from_args(&args).unwrap_err();
        assert!(format!("{e}").contains("unknown backend"), "{e}");
        let args = Args::parse(["backend=thread".to_string()]);
        assert_eq!(FigOpts::from_args(&args).unwrap().backend, Backend::Thread);
    }

    #[test]
    fn from_args_parses_the_threads_knob() {
        let args = Args::parse(["threads=4".to_string()]);
        assert_eq!(FigOpts::from_args(&args).unwrap().threads, 4);
        let args = Args::parse(Vec::<String>::new());
        assert_eq!(FigOpts::from_args(&args).unwrap().threads, 1);
        let args = Args::parse(["threads=0".to_string()]);
        assert!(FigOpts::from_args(&args).is_err());
    }

    #[test]
    fn from_args_parses_the_simd_knob() {
        let args = Args::parse(Vec::<String>::new());
        assert_eq!(FigOpts::from_args(&args).unwrap().simd, "auto");
        let args = Args::parse(["simd=scalar".to_string()]);
        assert_eq!(FigOpts::from_args(&args).unwrap().simd, "scalar");
        let args = Args::parse(["simd=sse42".to_string()]);
        let e = FigOpts::from_args(&args).unwrap_err();
        assert!(format!("{e}").contains("simd"), "{e}");
    }

    #[test]
    fn from_args_parses_the_model_knob() {
        let args = Args::parse(["model=conv".to_string()]);
        assert_eq!(FigOpts::from_args(&args).unwrap().model, ModelKind::Conv);
        let args = Args::parse(Vec::<String>::new());
        assert_eq!(FigOpts::from_args(&args).unwrap().model, ModelKind::Mlp);
        let args = Args::parse(["model=resnet".to_string()]);
        let e = FigOpts::from_args(&args).unwrap_err();
        assert!(format!("{e}").contains("unknown model"), "{e}");
    }
}
