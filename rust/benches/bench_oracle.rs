//! Samples/sec of the native gradient oracles.
//!
//! MLP grid — three compute paths:
//!
//! - **seed**: a verbatim replica of the pre-GEMM per-sample
//!   algorithm (strided matvec loops, `exps`/`dpre`/`offsets` heap
//!   allocations per sample) — the fixed baseline every PR is
//!   measured against;
//! - **per-sample**: today's `Mlp::grad` wrapper looped over the
//!   batch (batch-of-one through the GEMM kernels);
//! - **batched**: `Mlp::batch_grad`, one fused forward/backward over
//!   the whole `n × dim` panel.
//!
//! Conv grid — the im2col `ConvNet` (`model=conv`): per-sample
//! (batch-of-one `grad_batch` looped) vs batched, on the sweep blob
//! read as a 1×4×8 image and a wider 1×8×8 one.
//!
//! Grid: batch ∈ {32, 128} per model. This is the perf trajectory for
//! every Chapter-4/6 figure sweep and both real-thread backends, whose
//! wall clock is exactly this gradient step.
//!
//! Hybrid-parallelism grid — the batched path at GEMM threads ∈
//! {1, 2, 4} (sweep MLP + wide conv, batch=128), gated on the threaded
//! gradient being bitwise-identical to single-thread; the conv-wide
//! panel is expected to reach ≥ 1.6× at threads=4.
//!
//! Kernel-tier grid — the batched path at scalar vs the detected SIMD
//! tier (`linalg::simd`), threads ∈ {1, 4}, same two panels at
//! batch=128. Compiled without `--features simd` (or on a host with no
//! SIMD tier) the grid degenerates to scalar-only and says so; with a
//! tier available, SIMD at threads=1 is expected to reach ≥ 1.5× the
//! scalar kernels on both panels. The entry records the resolved tier
//! and the detected CPU features so historical rows stay comparable
//! across machines.
//!
//!     cargo bench --bench bench_oracle            # full grid
//!     cargo bench --bench bench_oracle -- --quick # smoke (CI)
//!
//! APPENDS one history entry — keyed by the current git SHA — to
//! `BENCH_oracle.json` at the repository root (anchored via
//! `CARGO_MANIFEST_DIR`, independent of the invocation directory), so
//! the conv-vs-MLP samples/sec trajectory stays visible across PRs
//! instead of each run erasing the last. A legacy single-object file
//! is replaced by a fresh one-entry history.
//! Acceptance shape: batched ≥ 3× the seed path at
//! batch=128 on `MlpConfig::sweep_default` — the GEMM micro-kernels
//! amortize weight-panel traffic over the batch, which
//! one-sample-at-a-time matvecs cannot.

use elastic_train::data::BlobDataset;
use elastic_train::figures::benchkit;
use elastic_train::model::{ConvNet, ConvNetConfig, Mlp, MlpConfig};
use elastic_train::rng::Rng;
use std::hint::black_box;

/// The seed's per-sample forward/backward, reproduced verbatim (minus
/// the l2 term, identical across paths): scalar strided loops and the
/// per-sample `exps`/`dpre`/`offsets` allocations the GEMM refactor
/// removed. Kept here as the frozen baseline.
struct SeedMlp {
    dims: Vec<usize>,
    acts: Vec<Vec<f32>>,
    pre: Vec<Vec<f32>>,
    grads_a: Vec<Vec<f32>>,
}

impl SeedMlp {
    fn new(dims: &[usize]) -> Self {
        Self {
            dims: dims.to_vec(),
            acts: dims.iter().map(|&d| vec![0.0; d]).collect(),
            pre: dims[1..].iter().map(|&d| vec![0.0; d]).collect(),
            grads_a: dims.iter().map(|&d| vec![0.0; d]).collect(),
        }
    }

    fn grad(&mut self, theta: &[f32], x: &[f32], label: usize, grad: &mut [f32]) -> f32 {
        self.acts[0].copy_from_slice(x);
        let n_layers = self.dims.len() - 1;
        let mut off = 0;
        for l in 0..n_layers {
            let (din, dout) = (self.dims[l], self.dims[l + 1]);
            let w = &theta[off..off + din * dout];
            let b = &theta[off + din * dout..off + din * dout + dout];
            off += din * dout + dout;
            let (inp, pre) = {
                let (a, b2) = (&self.acts[l], &mut self.pre[l]);
                (a.as_slice(), b2)
            };
            for (j, (pj, bj)) in pre.iter_mut().zip(b).enumerate() {
                let mut s = *bj;
                for (i, xi) in inp.iter().enumerate() {
                    s += xi * w[i * dout + j];
                }
                *pj = s;
            }
            let last = l == n_layers - 1;
            let (acts, pre) = (&mut self.acts, &self.pre);
            for (aj, pj) in acts[l + 1].iter_mut().zip(&pre[l]) {
                *aj = if last { *pj } else { pj.max(0.0) };
            }
        }
        let logits = self.acts.last().unwrap();
        let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let exps: Vec<f32> = logits.iter().map(|z| (z - m).exp()).collect();
        let sum: f32 = exps.iter().sum();
        let loss = sum.ln() + m - logits[label];
        {
            let top = self.grads_a.last_mut().unwrap();
            for (g, e) in top.iter_mut().zip(&exps) {
                *g = e / sum;
            }
            top[label] -= 1.0;
        }
        let mut offsets = Vec::with_capacity(n_layers);
        let mut off = 0;
        for w in self.dims.windows(2) {
            offsets.push(off);
            off += w[0] * w[1] + w[1];
        }
        for l in (0..n_layers).rev() {
            let (din, dout) = (self.dims[l], self.dims[l + 1]);
            let woff = offsets[l];
            let last = l == n_layers - 1;
            let dpre: Vec<f32> = self.grads_a[l + 1]
                .iter()
                .zip(&self.pre[l])
                .map(|(g, p)| if last || *p > 0.0 { *g } else { 0.0 })
                .collect();
            {
                let inp = &self.acts[l];
                let gw = &mut grad[woff..woff + din * dout];
                for (i, xi) in inp.iter().enumerate() {
                    if *xi == 0.0 {
                        continue;
                    }
                    let row = &mut gw[i * dout..(i + 1) * dout];
                    for (gj, dj) in row.iter_mut().zip(&dpre) {
                        *gj += xi * dj;
                    }
                }
                let gb = &mut grad[woff + din * dout..woff + din * dout + dout];
                for (g, d) in gb.iter_mut().zip(&dpre) {
                    *g += d;
                }
            }
            if l > 0 {
                let w = &theta[woff..woff + din * dout];
                let ga = &mut self.grads_a[l];
                for (i, gi) in ga.iter_mut().enumerate() {
                    let row = &w[i * dout..(i + 1) * dout];
                    *gi = row.iter().zip(&dpre).map(|(wj, dj)| wj * dj).sum();
                }
            }
        }
        loss
    }
}

struct Cell {
    model: &'static str,
    dims: Vec<usize>,
    batch: usize,
    seed_sps: f64,
    per_sample_sps: f64,
    batched_sps: f64,
}

fn bench_model(
    name: &'static str,
    cfg: &MlpConfig,
    data: &BlobDataset,
    batch: usize,
    target_ms: f64,
    batches: usize,
) -> Cell {
    let mut mlp = Mlp::new(cfg.clone());
    let mut seed = SeedMlp::new(&cfg.dims);
    let mut rng = Rng::new(1234);
    let theta = mlp.init_params(&mut rng);
    let mut grad = vec![0.0f32; theta.len()];
    // Fixed deterministic mini-batch: the first `batch` training rows.
    let samples: Vec<(Vec<f32>, usize)> = data.train[..batch].to_vec();
    let mut sink = 0.0f32;

    // Seed path: the pre-refactor loop shape — zero, accumulate one
    // sample at a time through the scalar kernels, scale to the mean.
    let sd = benchkit::bench(&format!("{name}/b{batch}/seed"), target_ms, batches, || {
        grad.iter_mut().for_each(|g| *g = 0.0);
        let mut loss = 0.0f32;
        for (x, y) in &samples {
            loss += seed.grad(black_box(&theta), x, *y, &mut grad);
        }
        let inv = 1.0 / samples.len() as f32;
        grad.iter_mut().for_each(|g| *g *= inv);
        sink += loss * inv;
    });

    // Per-sample wrapper: batch-of-one through the GEMM kernels.
    let per = benchkit::bench(&format!("{name}/b{batch}/per-sample"), target_ms, batches, || {
        grad.iter_mut().for_each(|g| *g = 0.0);
        let mut loss = 0.0f32;
        for (x, y) in &samples {
            loss += mlp.grad(black_box(&theta), x, *y, &mut grad);
        }
        let inv = 1.0 / samples.len() as f32;
        grad.iter_mut().for_each(|g| *g *= inv);
        sink += loss * inv;
    });

    // Batched path: one fused forward/backward over the whole panel.
    let bat = benchkit::bench(&format!("{name}/b{batch}/batched"), target_ms, batches, || {
        sink += mlp.batch_grad(black_box(&theta), &samples, &mut grad);
    });
    black_box(sink);

    Cell {
        model: name,
        dims: cfg.dims.clone(),
        batch,
        seed_sps: sd.throughput(batch as f64),
        per_sample_sps: per.throughput(batch as f64),
        batched_sps: bat.throughput(batch as f64),
    }
}

fn json_row(c: &Cell) -> String {
    let dims = c
        .dims
        .iter()
        .map(|d| d.to_string())
        .collect::<Vec<_>>()
        .join(",");
    format!(
        "      {{\"model\": \"{}\", \"dims\": [{}], \"batch\": {}, \"seed_sps\": {:.1}, \
         \"per_sample_sps\": {:.1}, \"batched_sps\": {:.1}, \"speedup_vs_seed\": {:.2}}}",
        c.model,
        dims,
        c.batch,
        c.seed_sps,
        c.per_sample_sps,
        c.batched_sps,
        c.batched_sps / c.seed_sps
    )
}

/// One conv grid cell: the im2col `ConvNet` has no pre-GEMM "seed"
/// replica (it never existed before the GEMM path), so the baseline is
/// the batch-of-one loop through the same kernels.
struct ConvCell {
    model: &'static str,
    shape: (usize, usize, usize),
    batch: usize,
    per_sample_sps: f64,
    batched_sps: f64,
}

fn bench_conv(
    name: &'static str,
    cfg: &ConvNetConfig,
    data: &BlobDataset,
    batch: usize,
    target_ms: f64,
    batches: usize,
) -> ConvCell {
    let mut net = ConvNet::new(cfg.clone());
    let mut rng = Rng::new(1234);
    let theta = net.init_params(&mut rng);
    let mut grad = vec![0.0f32; theta.len()];
    let mut gtmp = vec![0.0f32; theta.len()];
    let samples: Vec<(Vec<f32>, usize)> = data.train[..batch].to_vec();
    let mut sink = 0.0f32;

    // Per-sample: batch-of-one through the im2col + GEMM path,
    // accumulated to the mean like the seed algorithm would.
    let per = benchkit::bench(&format!("{name}/b{batch}/per-sample"), target_ms, batches, || {
        grad.iter_mut().for_each(|g| *g = 0.0);
        let mut loss = 0.0f32;
        for (x, y) in &samples {
            let one = std::iter::once((x.as_slice(), *y));
            loss += net.grad_batch(black_box(&theta), one, &mut gtmp);
            for (g, &t) in grad.iter_mut().zip(&gtmp) {
                *g += t;
            }
        }
        let inv = 1.0 / samples.len() as f32;
        grad.iter_mut().for_each(|g| *g *= inv);
        sink += loss * inv;
    });

    // Batched: one fused im2col + GEMM forward/backward per layer over
    // the whole panel.
    let bat = benchkit::bench(&format!("{name}/b{batch}/batched"), target_ms, batches, || {
        sink += net.batch_grad(black_box(&theta), &samples, &mut grad);
    });
    black_box(sink);

    ConvCell {
        model: name,
        shape: (cfg.in_c, cfg.in_h, cfg.in_w),
        batch,
        per_sample_sps: per.throughput(batch as f64),
        batched_sps: bat.throughput(batch as f64),
    }
}

fn conv_json_row(c: &ConvCell) -> String {
    format!(
        "      {{\"model\": \"{}\", \"shape\": \"{}x{}x{}\", \"batch\": {}, \
         \"per_sample_sps\": {:.1}, \"batched_sps\": {:.1}, \"speedup_batched_vs_per_sample\": {:.2}}}",
        c.model,
        c.shape.0,
        c.shape.1,
        c.shape.2,
        c.batch,
        c.per_sample_sps,
        c.batched_sps,
        c.batched_sps / c.per_sample_sps
    )
}

use elastic_train::figures::benchkit::{append_history, git_sha, unix_time};
use elastic_train::linalg::{pool, simd};

/// One hybrid-parallelism grid cell: the batched path at a given GEMM
/// thread count (same fixed minibatch as the main grids).
struct ThreadCell {
    model: &'static str,
    threads: usize,
    batch: usize,
    batched_sps: f64,
}

fn thread_json_row(c: &ThreadCell) -> String {
    format!(
        "      {{\"model\": \"{}\", \"grid\": \"threads\", \"threads\": {}, \"batch\": {}, \
         \"batched_sps\": {:.1}}}",
        c.model, c.threads, c.batch, c.batched_sps
    )
}

/// One kernel-tier grid cell: the batched path on a given SIMD tier at
/// a given GEMM thread count.
struct TierCell {
    model: &'static str,
    tier: &'static str,
    threads: usize,
    batch: usize,
    batched_sps: f64,
}

fn tier_json_row(c: &TierCell) -> String {
    format!(
        "      {{\"model\": \"{}\", \"grid\": \"simd\", \"simd\": \"{}\", \"threads\": {}, \
         \"batch\": {}, \"batched_sps\": {:.1}}}",
        c.model, c.tier, c.threads, c.batch, c.batched_sps
    )
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick" || a == "quick");
    let (target_ms, batches) = if quick { (8.0, 3) } else { (50.0, 7) };
    // Respect an inherited ELASTIC_TRAIN_THREADS (CI runs this bench at
    // threads=2) for the main grids; the threads grid below sets its
    // own count per cell and restores this afterwards.
    let base_threads = pool::configured_threads();

    // The sweep-default model every figure uses, plus a wider net where
    // the GEMM panels are large enough for the register tiles to run
    // full blocks.
    let sweep_cfg = MlpConfig::sweep_default();
    let sweep_data = BlobDataset::sweep_default(3);
    let wide_cfg = MlpConfig::new(&[64, 256, 128, 10], 1e-4);
    let wide_data = BlobDataset::generate(64, 10, 2048, 256, 1.0, 3);

    println!("oracle gradient throughput (samples/sec): seed vs per-sample vs batched GEMM\n");
    let mut cells = Vec::new();
    for (name, cfg, data) in [
        ("sweep", &sweep_cfg, &sweep_data),
        ("wide", &wide_cfg, &wide_data),
    ] {
        for batch in [32usize, 128] {
            let c = bench_model(name, cfg, data, batch, target_ms, batches);
            println!(
                "  {name:>5} batch={batch:<4} seed {:>11.0}  per-sample {:>11.0}  batched {:>11.0} sps  ({:.2}x vs seed)",
                c.seed_sps,
                c.per_sample_sps,
                c.batched_sps,
                c.batched_sps / c.seed_sps
            );
            cells.push(c);
        }
        println!();
    }

    // The conv grid (`model=conv`): the sweep blob read as a 1×4×8
    // image plus a wider 1×8×8 one, same batch axis as the MLP grid.
    let conv_sweep_cfg = ConvNetConfig::for_blob(32, 10, 1e-4);
    let conv_wide_cfg = ConvNetConfig::for_blob(64, 10, 1e-4);
    let mut conv_cells = Vec::new();
    for (name, cfg, data) in [
        ("conv-sweep", &conv_sweep_cfg, &sweep_data),
        ("conv-wide", &conv_wide_cfg, &wide_data),
    ] {
        for batch in [32usize, 128] {
            let c = bench_conv(name, cfg, data, batch, target_ms, batches);
            println!(
                "  {name:>10} batch={batch:<4} per-sample {:>11.0}  batched {:>11.0} sps  ({:.2}x batched)",
                c.per_sample_sps,
                c.batched_sps,
                c.batched_sps / c.per_sample_sps
            );
            conv_cells.push(c);
        }
        println!();
    }

    // Acceptance shape: ≥ 3× over the seed path at batch=128 on the
    // sweep-default net.
    let key = cells
        .iter()
        .find(|c| c.model == "sweep" && c.batch == 128)
        .unwrap();
    let speedup = key.batched_sps / key.seed_sps;
    println!(
        "sweep batch=128 batched/seed: {speedup:.2}x ({})",
        if speedup >= 3.0 { "OK, >= 3x" } else { "BELOW 3x target" }
    );

    // ---- Hybrid-parallelism grid: the batched path at threads ∈
    // {1, 2, 4} on the two panels the thread pool targets (sweep MLP
    // and the wide conv net, both at batch=128). Gate first: the
    // threaded gradient must be BITWISE equal to the single-thread one
    // before any speedup is worth reporting.
    {
        let mut mlp = Mlp::new(sweep_cfg.clone());
        let mut rng = Rng::new(1234);
        let theta = mlp.init_params(&mut rng);
        let samples: Vec<(Vec<f32>, usize)> = sweep_data.train[..128].to_vec();
        let mut g1 = vec![0.0f32; theta.len()];
        let mut g4 = vec![0.0f32; theta.len()];
        pool::configure_threads(1);
        let l1 = mlp.batch_grad(&theta, &samples, &mut g1);
        pool::configure_threads(4);
        let l4 = mlp.batch_grad(&theta, &samples, &mut g4);
        assert!(
            g1 == g4 && l1 == l4,
            "threaded MLP batch_grad is not bitwise-identical to single-thread"
        );

        let mut net = ConvNet::new(conv_wide_cfg.clone());
        let ctheta = net.init_params(&mut rng);
        let csamples: Vec<(Vec<f32>, usize)> = wide_data.train[..128].to_vec();
        let mut cg1 = vec![0.0f32; ctheta.len()];
        let mut cg4 = vec![0.0f32; ctheta.len()];
        pool::configure_threads(1);
        let cl1 = net.batch_grad(&ctheta, &csamples, &mut cg1);
        pool::configure_threads(4);
        let cl4 = net.batch_grad(&ctheta, &csamples, &mut cg4);
        assert!(
            cg1 == cg4 && cl1 == cl4,
            "threaded conv batch_grad is not bitwise-identical to single-thread"
        );
        println!("threaded gradients bitwise-identical to single-thread: OK\n");
    }

    println!("hybrid-parallelism grid (batched samples/sec vs GEMM threads, batch=128):");
    let mut thread_cells = Vec::new();
    for t in [1usize, 2, 4] {
        pool::configure_threads(t);
        {
            let mut mlp = Mlp::new(sweep_cfg.clone());
            let mut rng = Rng::new(1234);
            let theta = mlp.init_params(&mut rng);
            let mut grad = vec![0.0f32; theta.len()];
            let samples: Vec<(Vec<f32>, usize)> = sweep_data.train[..128].to_vec();
            let mut sink = 0.0f32;
            let s = benchkit::bench(&format!("sweep/b128/t{t}/batched"), target_ms, batches, || {
                sink += mlp.batch_grad(black_box(&theta), &samples, &mut grad);
            });
            black_box(sink);
            thread_cells.push(ThreadCell {
                model: "sweep",
                threads: t,
                batch: 128,
                batched_sps: s.throughput(128.0),
            });
        }
        {
            let mut net = ConvNet::new(conv_wide_cfg.clone());
            let mut rng = Rng::new(1234);
            let theta = net.init_params(&mut rng);
            let mut grad = vec![0.0f32; theta.len()];
            let samples: Vec<(Vec<f32>, usize)> = wide_data.train[..128].to_vec();
            let mut sink = 0.0f32;
            let s =
                benchkit::bench(&format!("conv-wide/b128/t{t}/batched"), target_ms, batches, || {
                    sink += net.batch_grad(black_box(&theta), &samples, &mut grad);
                });
            black_box(sink);
            thread_cells.push(ThreadCell {
                model: "conv-wide",
                threads: t,
                batch: 128,
                batched_sps: s.throughput(128.0),
            });
        }
    }
    pool::configure_threads(base_threads);
    let sps_at = |model: &str, t: usize| {
        thread_cells
            .iter()
            .find(|c| c.model == model && c.threads == t)
            .map(|c| c.batched_sps)
            .unwrap()
    };
    let conv_scaling = sps_at("conv-wide", 4) / sps_at("conv-wide", 1);
    let mlp_scaling = sps_at("sweep", 4) / sps_at("sweep", 1);
    println!(
        "  threads=4 vs threads=1: conv-wide {conv_scaling:.2}x, sweep {mlp_scaling:.2}x ({})\n",
        if conv_scaling >= 1.6 { "OK, >= 1.6x" } else { "BELOW 1.6x target" }
    );

    // ---- Kernel-tier grid: scalar vs the detected SIMD tier at
    // threads ∈ {1, 4} on the same two panels. `detect_best()` is
    // Scalar when the crate is built without `--features simd` or the
    // host CPU has neither AVX2+FMA nor NEON, so the grid is always
    // well-defined; it just collapses to one tier.
    let best = simd::detect_best();
    let tiers: Vec<&'static str> = if best == simd::Tier::Scalar {
        println!("kernel-tier grid: no SIMD tier (cpu: {}) — scalar only", simd::cpu_features());
        vec!["scalar"]
    } else {
        println!(
            "kernel-tier grid (batched samples/sec, batch=128, cpu: {}):",
            simd::cpu_features()
        );
        vec!["scalar", best.name()]
    };
    let mut tier_cells = Vec::new();
    for &tier in &tiers {
        simd::configure(tier).expect("grid tiers come from detect_best, always available");
        for t in [1usize, 4] {
            pool::configure_threads(t);
            {
                let mut mlp = Mlp::new(sweep_cfg.clone());
                let mut rng = Rng::new(1234);
                let theta = mlp.init_params(&mut rng);
                let mut grad = vec![0.0f32; theta.len()];
                let samples: Vec<(Vec<f32>, usize)> = sweep_data.train[..128].to_vec();
                let mut sink = 0.0f32;
                let s =
                    benchkit::bench(&format!("sweep/b128/{tier}/t{t}"), target_ms, batches, || {
                        sink += mlp.batch_grad(black_box(&theta), &samples, &mut grad);
                    });
                black_box(sink);
                tier_cells.push(TierCell {
                    model: "sweep",
                    tier,
                    threads: t,
                    batch: 128,
                    batched_sps: s.throughput(128.0),
                });
            }
            {
                let mut net = ConvNet::new(conv_wide_cfg.clone());
                let mut rng = Rng::new(1234);
                let theta = net.init_params(&mut rng);
                let mut grad = vec![0.0f32; theta.len()];
                let samples: Vec<(Vec<f32>, usize)> = wide_data.train[..128].to_vec();
                let mut sink = 0.0f32;
                let s = benchkit::bench(
                    &format!("conv-wide/b128/{tier}/t{t}"),
                    target_ms,
                    batches,
                    || {
                        sink += net.batch_grad(black_box(&theta), &samples, &mut grad);
                    },
                );
                black_box(sink);
                tier_cells.push(TierCell {
                    model: "conv-wide",
                    tier,
                    threads: t,
                    batch: 128,
                    batched_sps: s.throughput(128.0),
                });
            }
        }
    }
    pool::configure_threads(base_threads);
    simd::configure("auto").expect("auto is always available");
    let resolved_tier = simd::active_tier().name();
    if tiers.len() > 1 {
        let tier_sps = |model: &str, tier: &str, t: usize| {
            tier_cells
                .iter()
                .find(|c| c.model == model && c.tier == tier && c.threads == t)
                .map(|c| c.batched_sps)
                .unwrap()
        };
        let best_name = best.name();
        let mlp_gain = tier_sps("sweep", best_name, 1) / tier_sps("sweep", "scalar", 1);
        let conv_gain = tier_sps("conv-wide", best_name, 1) / tier_sps("conv-wide", "scalar", 1);
        println!(
            "  {best_name} vs scalar at threads=1: sweep {mlp_gain:.2}x, conv-wide \
             {conv_gain:.2}x ({})\n",
            if mlp_gain >= 1.5 && conv_gain >= 1.5 { "OK, >= 1.5x" } else { "BELOW 1.5x target" }
        );
    }

    let mut rows: Vec<String> = cells.iter().map(json_row).collect();
    rows.extend(conv_cells.iter().map(conv_json_row));
    rows.extend(thread_cells.iter().map(thread_json_row));
    rows.extend(tier_cells.iter().map(tier_json_row));
    let entry = format!(
        "  {{\n    \"bench\": \"oracle\",\n    \"sha\": \"{}\",\n    \"unix_time\": {},\n    \
         \"quick\": {},\n    \"cores\": {},\n    \"p\": 1,\n    \"threads\": {},\n    \
         \"threads_grid\": [1, 2, 4],\n    \"simd\": \"{}\",\n    \"cpu_features\": \"{}\",\n    \
         \"unit\": \"samples_per_sec\",\n    \"results\": [\n{}\n    ]\n  }}",
        git_sha(),
        unix_time(),
        quick,
        pool::available_cores(),
        base_threads,
        resolved_tier,
        simd::cpu_features(),
        rows.join(",\n")
    );
    // Anchor at the repository root (cargo runs benches with cwd at the
    // package root, rust/), so the tracked trajectory copy is the one
    // that accumulates the per-PR history.
    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_oracle.json");
    append_history(out, &entry);
    println!("appended history entry to {out}");
}
