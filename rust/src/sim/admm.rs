//! §3.3: stability of round-robin ADMM vs round-robin EASGD on the
//! one-dimensional quadratic F(x) = x²/2.
//!
//! ADMM's round-robin update composes p *non-symmetric* linear maps
//! F₃ⁱ∘F₂ⁱ∘F₁ⁱ over the state s = (λ¹, x¹, …, λᵖ, xᵖ, x̃) ∈ ℝ^{2p+1};
//! each factor is individually stable yet the composition can leave the
//! unit disk — the thesis' Fig 3.2/3.3 chaos. EASGD's maps are symmetric
//! (the elastic force), so composition stays stable under the simple
//! closed-form condition reproduced in [`easgd_rr_stable`].

use crate::linalg::{spectral_radius, Matrix};

/// Build the three ADMM linear maps for worker `i` (0-based), state
/// dimension 2p+1, learning rate η, penalty ρ (Eqs 3.52–3.54).
pub fn admm_maps(i: usize, p: usize, eta: f64, rho: f64) -> (Matrix, Matrix, Matrix) {
    let n = 2 * p + 1;
    let li = 2 * i; // λ^i index
    let xi = 2 * i + 1; // x^i index
    let xc = n - 1; // x̃ index

    // F1: λ^i ← λ^i − (x^i − x̃).
    let mut f1 = Matrix::identity(n);
    f1.set(li, xi, -1.0);
    f1.set(li, xc, 1.0);

    // F2: x^i ← (x^i − η∇F(x^i) + ηρ(λ^i + x̃)) / (1 + ηρ), ∇F(x) = x.
    let mut f2 = Matrix::identity(n);
    let d = 1.0 + eta * rho;
    f2.set(xi, xi, (1.0 - eta) / d);
    f2.set(xi, li, eta * rho / d);
    f2.set(xi, xc, eta * rho / d);

    // F3: x̃ ← (1/p) Σ_j (x^j − λ^j).
    let mut f3 = Matrix::identity(n);
    for j in 0..n {
        f3.set(xc, j, 0.0);
    }
    for j in 0..p {
        f3.set(xc, 2 * j + 1, 1.0 / p as f64);
        f3.set(xc, 2 * j, -1.0 / p as f64);
    }
    (f1, f2, f3)
}

/// The full round-robin composition 𝓕 = ∏_{i=p..1} F₃ⁱ F₂ⁱ F₁ⁱ.
pub fn admm_round_robin_map(p: usize, eta: f64, rho: f64) -> Matrix {
    let n = 2 * p + 1;
    let mut acc = Matrix::identity(n);
    for i in 0..p {
        let (f1, f2, f3) = admm_maps(i, p, eta, rho);
        acc = f3.matmul(&f2).matmul(&f1).matmul(&acc);
    }
    acc
}

/// sp(𝓕) — the Fig 3.2 quantity.
pub fn admm_spectral_radius(p: usize, eta: f64, rho: f64) -> f64 {
    spectral_radius(&admm_round_robin_map(p, eta, rho))
}

/// Iterate the ADMM round-robin dynamics from the thesis' Fig 3.3
/// initial state (λ₀ⁱ = 0, x₀ⁱ = x̃₀ = x0); returns the x̃ trajectory
/// sampled once per full round.
pub fn admm_trajectory(p: usize, eta: f64, rho: f64, x0: f64, rounds: usize) -> Vec<f64> {
    let n = 2 * p + 1;
    let map = admm_round_robin_map(p, eta, rho);
    let mut s = vec![0.0; n];
    for i in 0..p {
        s[2 * i + 1] = x0;
    }
    s[n - 1] = x0;
    let mut out = Vec::with_capacity(rounds + 1);
    out.push(s[n - 1]);
    for _ in 0..rounds {
        s = map.matvec(&s);
        out.push(s[n - 1]);
        if !s[n - 1].is_finite() {
            break;
        }
    }
    out
}

/// Round-robin EASGD single-worker map Fⁱ (Eqs 3.55–3.56) over
/// (x¹, …, xᵖ, x̃), ∇F(x) = x.
pub fn easgd_rr_map(i: usize, p: usize, eta: f64, alpha: f64) -> Matrix {
    let n = p + 1;
    let mut f = Matrix::identity(n);
    f.set(i, i, 1.0 - eta - alpha);
    f.set(i, n - 1, alpha);
    f.set(n - 1, i, alpha);
    f.set(n - 1, n - 1, 1.0 - alpha);
    f
}

/// Composed EASGD round-robin map.
pub fn easgd_round_robin_map(p: usize, eta: f64, alpha: f64) -> Matrix {
    let mut acc = Matrix::identity(p + 1);
    for i in 0..p {
        acc = easgd_rr_map(i, p, eta, alpha).matmul(&acc);
    }
    acc
}

/// The closed-form §3.3 stability condition for round-robin EASGD:
/// 0 ≤ η ≤ 2 and 0 ≤ α ≤ (4 − 2η)/(4 − η). p-independent because each
/// Fⁱ is symmetric.
pub fn easgd_rr_stable(eta: f64, alpha: f64) -> bool {
    (0.0..=2.0).contains(&eta) && alpha >= 0.0 && alpha <= (4.0 - 2.0 * eta) / (4.0 - eta)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admm_unstable_at_the_papers_chaotic_point() {
        // Fig 3.2/3.3: p=3, η=0.001, ρ=2.5 diverges.
        let sp = admm_spectral_radius(3, 0.001, 2.5);
        assert!(sp > 1.0, "sp={sp} should exceed 1");
        // sp is only slightly above 1, so divergence is slow (the thesis'
        // Fig 3.3 shows growing oscillations): compare the trajectory
        // envelope early vs late over a long horizon.
        let tr = admm_trajectory(3, 0.001, 2.5, 1000.0, 60_000);
        let early: f64 = tr[..1000].iter().fold(0.0f64, |m, x| m.max(x.abs()));
        let late: f64 = tr[tr.len() - 1000..]
            .iter()
            .fold(0.0f64, |m, x| m.max(x.abs()));
        assert!(
            late > 2.0 * early || tr.iter().any(|x| !x.is_finite()),
            "expected growing envelope, early={early} late={late}"
        );
    }

    #[test]
    fn admm_stable_at_large_rho() {
        // Larger quadratic penalty stabilizes the dual ascent.
        let sp = admm_spectral_radius(3, 0.001, 9.0);
        assert!(sp <= 1.0 + 1e-9, "sp={sp}");
    }

    #[test]
    fn admm_factors_individually_stable_composition_not() {
        let (p, eta, rho) = (3, 0.001, 2.5);
        for i in 0..p {
            let (f1, f2, f3) = admm_maps(i, p, eta, rho);
            let m = f3.matmul(&f2).matmul(&f1);
            assert!(spectral_radius(&m) <= 1.0 + 1e-9, "factor {i} unstable");
        }
        assert!(admm_spectral_radius(p, eta, rho) > 1.0);
    }

    #[test]
    fn easgd_rr_stability_condition_is_sufficient_for_all_p() {
        // §3.3: each Fⁱ is symmetric; when its 2×2 elastic block is a
        // contraction (the closed-form condition) the composition is
        // stable for EVERY p. (The condition is sufficient, not
        // necessary, for the composed map at p > 1 — interleaved idle
        // coordinates can damp a factor that is itself expansive.)
        for p in [1usize, 2, 3, 5] {
            for ei in 0..8 {
                for ai in 0..8 {
                    let eta = 0.25 + ei as f64 * 0.22;
                    let alpha = 0.05 + ai as f64 * 0.12;
                    if easgd_rr_stable(eta, alpha) {
                        let sp = spectral_radius(&easgd_round_robin_map(p, eta, alpha));
                        assert!(sp <= 1.0 + 1e-7,
                                "p={p} η={eta} α={alpha}: sp={sp} though stable");
                    }
                }
            }
        }
    }

    #[test]
    fn easgd_rr_condition_is_exact_at_p_equals_1() {
        // At p = 1 the composite IS the 2×2 block, so the closed-form
        // condition is necessary too.
        for ei in 0..10 {
            for ai in 0..10 {
                let eta = 0.1 + ei as f64 * 0.2;
                let alpha = 0.05 + ai as f64 * 0.11;
                let sp = spectral_radius(&easgd_round_robin_map(1, eta, alpha));
                if easgd_rr_stable(eta, alpha) {
                    assert!(sp <= 1.0 + 1e-7, "η={eta} α={alpha}: sp={sp}");
                } else {
                    assert!(sp >= 1.0 - 1e-7, "η={eta} α={alpha}: sp={sp}");
                }
            }
        }
    }

    #[test]
    fn easgd_rr_trajectory_contracts_where_admm_diverges() {
        // Same (η≈paper) regime: EASGD round robin from x0=1000 decays.
        let p = 3;
        let map = easgd_round_robin_map(p, 0.5, 0.3);
        let mut s = vec![1000.0; p + 1];
        for _ in 0..200 {
            s = map.matvec(&s);
        }
        assert!(s.iter().all(|x| x.abs() < 1.0), "{s:?}");
    }

    #[test]
    fn admm_fixed_point_is_origin() {
        // Where stable, the dynamics solve min x²/2 ⇒ x̃ → 0.
        let tr = admm_trajectory(3, 0.5, 5.0, 10.0, 4000);
        assert!(tr.last().unwrap().abs() < 1e-2, "{:?}", tr.last());
    }
}
