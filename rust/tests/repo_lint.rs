//! Hand-rolled repo-invariant lint: a tier-1 `#[test]` (no new
//! dependencies, plain `std::fs`) that walks `rust/src` and enforces
//! the concurrency-correctness conventions the `crate::sync` shim and
//! the loom/Miri/TSan lanes rely on:
//!
//! | rule | invariant |
//! |------|-----------|
//! | R1 | no `std::sync` / `std::thread` outside `sync/mod.rs` — all concurrent code imports through the shim, so `--cfg loom` instruments every lock, notify, and spawn |
//! | R2 | no `unsafe` outside the committed allowlist (`linalg/gemm.rs`, whose Job aliasing invariants are documented at the type, and `linalg/simd.rs`, the intrinsic kernel tier) |
//! | R3 | any file using `catch_unwind` also uses `lock_recover` — catching a panic without recovering poisoned locks deadlocks the survivors |
//! | R4 | `.unwrap()` / `.expect(` in `coordinator/*` non-test code stays at or below the committed per-file ceiling — the count can only shrink |
//! | R5 | the knob registry (`config/registry.rs`) matches reality in BOTH directions: every claimed surface is found by scraping the actual structs / CLI forwarding, and every scraped field/key is a registered knob |
//! | R6 | no bare `as` narrowing casts in the wire/protocol/config path outside the documented allowlist — a silent truncation on the wire is a protocol bug |
//! | R7 | every `crate::error::Error` construction site in the wire/protocol/config path has a test asserting its message fragment, or a documented exemption |
//!
//! Scope: non-test code only. Each source file's `#[cfg(test)] mod`
//! sits at the bottom (repo convention), so the lint truncates the
//! stripped source at the first `#[cfg(test)]`. Comments and string
//! literals are stripped first, so prose mentioning `std::thread` or
//! an error message quoting `unsafe` never trips a rule. (R5 and R7
//! additionally scrape a strings-KEPT variant, because CLI keys and
//! error messages live inside string literals.) The vendored crates
//! (`rust/vendor/*`) are outside `src/` and deliberately exempt
//! (the loom stub IS an instrumented `std::sync`).

use std::fs;
use std::path::{Path, PathBuf};

/// Files allowed to name `std::sync` / `std::thread` directly: the
/// shim itself (its whole job is re-exporting them).
const SYNC_IMPORT_ALLOWLIST: &[&str] = &["sync/mod.rs"];

/// The entire committed `unsafe` surface, per file. Growing a count
/// here must come with the same scrutiny as `gemm.rs`'s Job aliasing
/// invariants; everything not listed is `unsafe`-free.
const UNSAFE_ALLOWLIST: &[(&str, usize)] = &[
    // 1 `unsafe impl Send for Job` + 3 slice reconstructions in
    // `exec_span` + the `COut::row` &mut materialization, each
    // annotated with the invariant it leans on.
    ("linalg/gemm.rs", 5),
    // 8 dispatch-wrapper call sites (4 kernels × {avx2, neon}) + 8 AVX2
    // + 7 NEON `#[target_feature]` kernel fns; see the module doc for
    // why each is sound. All cfg-gated behind `--features simd`, but
    // the lint is textual so they count unconditionally.
    ("linalg/simd.rs", 23),
    // The fuzzer's counting `GlobalAlloc` (1 `unsafe impl` + 2
    // `unsafe fn`): pure bookkeeping over `System`, needed to prove
    // decode allocation stays bounded under hostile length prefixes.
    ("bin/fuzz_wire.rs", 3),
];

/// Per-file ceilings on `.unwrap()` + `.expect(` in non-test
/// `coordinator/*` code. Every remaining site is a documented
/// structural invariant (e.g. "averaged methods allocate z at init")
/// or an infallible conversion (wire.rs's fixed-width `try_into`s);
/// anything fallible returns a typed `crate::error::Error` instead.
/// Lower a ceiling when you remove a site; never raise one without a
/// matching invariant comment at the call site.
const UNWRAP_CEILINGS: &[(&str, usize)] = &[
    ("coordinator/driver.rs", 5),
    ("coordinator/master_actor.rs", 3),
    ("coordinator/process.rs", 1),
    ("coordinator/threaded.rs", 2),
    ("coordinator/topology.rs", 3),
    ("coordinator/tree_threaded.rs", 1),
    ("coordinator/wire.rs", 6),
];

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    let entries = fs::read_dir(dir).unwrap_or_else(|e| panic!("read_dir {dir:?}: {e}"));
    for entry in entries {
        let path = entry.expect("readable directory entry").path();
        if path.is_dir() {
            collect_rs(&path, out);
        } else if path.extension() == Some(std::ffi::OsStr::new("rs")) {
            out.push(path);
        }
    }
}

/// Strip comments and string literals (newlines preserved so reported
/// line numbers stay true), then truncate at the first `#[cfg(test)]`
/// — the bottom-of-file tests module, per repo convention.
fn lintable_source(raw: &str) -> String {
    let bytes = raw.as_bytes();
    let mut out = String::with_capacity(raw.len());
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'/' if bytes.get(i + 1) == Some(&b'/') => {
                while i < bytes.len() && bytes[i] != b'\n' {
                    i += 1;
                }
            }
            b'/' if bytes.get(i + 1) == Some(&b'*') => {
                let mut depth = 1usize;
                i += 2;
                while i < bytes.len() && depth > 0 {
                    if bytes[i] == b'/' && bytes.get(i + 1) == Some(&b'*') {
                        depth += 1;
                        i += 2;
                    } else if bytes[i] == b'*' && bytes.get(i + 1) == Some(&b'/') {
                        depth -= 1;
                        i += 2;
                    } else {
                        if bytes[i] == b'\n' {
                            out.push('\n');
                        }
                        i += 1;
                    }
                }
            }
            b'"' => {
                i += 1;
                while i < bytes.len() {
                    match bytes[i] {
                        b'\\' => i += 2,
                        b'"' => {
                            i += 1;
                            break;
                        }
                        b'\n' => {
                            out.push('\n');
                            i += 1;
                        }
                        _ => i += 1,
                    }
                }
            }
            c => {
                out.push(c as char);
                i += 1;
            }
        }
    }
    if let Some(pos) = out.find("#[cfg(test)]") {
        out.truncate(pos);
    }
    out
}

/// Load every `src/**/*.rs` as `(path relative to src/, stripped
/// non-test source)`.
fn sources() -> Vec<(String, String)> {
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let mut files = Vec::new();
    collect_rs(&src, &mut files);
    files.sort();
    assert!(files.len() >= 20, "walked only {} files — wrong root?", files.len());
    files
        .into_iter()
        .map(|p| {
            let rel = p
                .strip_prefix(&src)
                .expect("collected under src/")
                .to_string_lossy()
                .replace('\\', "/");
            let raw = fs::read_to_string(&p).unwrap_or_else(|e| panic!("read {p:?}: {e}"));
            (rel, lintable_source(&raw))
        })
        .collect()
}

/// 1-based line number of byte offset `pos`.
fn line_of(text: &str, pos: usize) -> usize {
    text[..pos].bytes().filter(|&b| b == b'\n').count() + 1
}

/// Occurrences of `needle` with identifier boundaries on both sides
/// (so `unsafe` never matches inside a longer word).
fn count_word(text: &str, needle: &str) -> usize {
    let is_ident = |b: u8| b == b'_' || b.is_ascii_alphanumeric();
    let mut n = 0;
    let mut from = 0;
    while let Some(off) = text[from..].find(needle) {
        let start = from + off;
        let end = start + needle.len();
        let left_ok = start == 0 || !is_ident(text.as_bytes()[start - 1]);
        let right_ok = end >= text.len() || !is_ident(text.as_bytes()[end]);
        if left_ok && right_ok {
            n += 1;
        }
        from = start + 1;
    }
    n
}

fn count_substr(text: &str, needle: &str) -> usize {
    let mut n = 0;
    let mut from = 0;
    while let Some(off) = text[from..].find(needle) {
        n += 1;
        from += off + 1;
    }
    n
}

#[test]
fn r1_no_std_sync_or_thread_outside_the_shim() {
    let mut violations = Vec::new();
    for (rel, text) in sources() {
        if SYNC_IMPORT_ALLOWLIST.contains(&rel.as_str()) {
            continue;
        }
        for needle in ["std::sync", "std::thread"] {
            let mut from = 0;
            while let Some(off) = text[from..].find(needle) {
                let pos = from + off;
                violations.push(format!(
                    "{rel}:{}: `{needle}` outside sync/mod.rs — import through \
                     `crate::sync` so `--cfg loom` instruments it",
                    line_of(&text, pos)
                ));
                from = pos + 1;
            }
        }
    }
    assert!(violations.is_empty(), "R1 violations:\n{}", violations.join("\n"));
}

#[test]
fn r2_unsafe_stays_inside_the_allowlist() {
    let mut violations = Vec::new();
    for (rel, text) in sources() {
        let n = count_word(&text, "unsafe");
        let cap = UNSAFE_ALLOWLIST
            .iter()
            .find(|(f, _)| *f == rel)
            .map_or(0, |(_, c)| *c);
        if n > cap {
            violations.push(format!(
                "{rel}: {n} `unsafe` occurrence(s), allowlist permits {cap} — document \
                 the aliasing invariants and extend UNSAFE_ALLOWLIST deliberately"
            ));
        }
    }
    assert!(violations.is_empty(), "R2 violations:\n{}", violations.join("\n"));
}

#[test]
fn r3_catch_unwind_is_paired_with_lock_recover() {
    let mut violations = Vec::new();
    for (rel, text) in sources() {
        if text.contains("catch_unwind") && !text.contains("lock_recover") {
            violations.push(format!(
                "{rel}: uses `catch_unwind` without `lock_recover` — a caught panic \
                 leaves poisoned locks that every surviving thread must recover"
            ));
        }
    }
    assert!(violations.is_empty(), "R3 violations:\n{}", violations.join("\n"));
}

#[test]
fn r4_coordinator_unwrap_count_only_shrinks() {
    let mut violations = Vec::new();
    for (rel, text) in sources() {
        if !rel.starts_with("coordinator/") {
            continue;
        }
        let n = count_substr(&text, ".unwrap()") + count_substr(&text, ".expect(");
        let cap = UNWRAP_CEILINGS
            .iter()
            .find(|(f, _)| *f == rel)
            .map_or(0, |(_, c)| *c);
        if n > cap {
            violations.push(format!(
                "{rel}: {n} `.unwrap()`/`.expect(` site(s) in non-test code, ceiling is \
                 {cap} — return a typed `crate::error::Error` instead (or, for a true \
                 structural invariant, document it at the call site and raise the \
                 ceiling in the same change)"
            ));
        }
    }
    assert!(violations.is_empty(), "R4 violations:\n{}", violations.join("\n"));
}

// ---------------------------------------------------------------------------
// R5–R7: knob-registry conformance, narrowing casts, error-message pins
// ---------------------------------------------------------------------------

use elastic_train::config::registry::{Surface, KNOBS};

/// Per-file allowlist of bare narrowing `as` casts on the
/// wire/protocol/config path. Every entry documents why the cast is
/// lossless; everything else must use `try_from` with a typed error
/// (wire.rs's length-field overflow is the canonical example).
const NARROWING_CAST_ALLOWLIST: &[(&str, usize)] = &[
    // `frame.kind as u8`: `FrameKind` is `#[repr(u8)]` with unit
    // variants 0..=6 — the cast is the identity on the discriminant.
    ("coordinator/wire.rs", 1),
    // `self.p as f32` (α = β/p): worker counts are tiny integers,
    // exactly representable in f32.
    ("config/experiment.rs", 1),
];

/// R7 table: for each file on the wire/protocol/config path, the
/// message fragment of every `err!`/`bail!`/`Error::msg` site. A
/// `tested` fragment must appear verbatim BOTH at a construction site
/// (strings-kept source) and in the test corpus (an assertion). An
/// `exempt` entry documents why the site cannot be reasonably driven
/// by a tier-1 test; the fragment must still exist in the source so a
/// reworded or deleted site invalidates its row loudly.
type R7Row = (&'static str, &'static [&'static str], &'static [(&'static str, &'static str)]);
const R7_MESSAGE_PINS: &[R7Row] = &[
    (
        "coordinator/wire.rs",
        &[
            "unknown wire frame kind",
            "bad frame magic",
            "wire version mismatch",
            "cap — corrupt stream",
            "reading frame header",
            "payload at byte",
            "socket write failed",
            "socket flush failed",
            "invalid wire address",
            "cannot bind tcp listener",
            "cannot bind unix listener",
            "no worker connected within",
        ],
        &[
            ("frame payload of ", "triggering it needs a payload over u32::MAX f32s (16 GiB)"),
            ("cannot connect to master", "only fires after a 10 s retry deadline — too slow for tier-1"),
            ("unix-domain sockets are not available", "compiled only on non-unix platforms"),
            ("cannot resolve bound tcp address", "local_addr() on a live listener cannot be made to fail portably"),
            ("accept failed", "needs OS-level fault injection on the listening socket"),
            ("set_nonblocking(", "needs OS-level fault injection on the socket fd"),
        ],
    ),
    ("coordinator/protocol.rs", &["protocol violation"], &[]),
    ("config/args.rs", &["invalid value for", "expected true|false|1|0|yes|no"], &[]),
    (
        "config/experiment.rs",
        &[
            "invalid value for",
            "cannot read config file",
            "expected auto|avx2|neon|scalar",
            "p must be >= 1",
            "batch must be >= 1",
            "threads must be >= 1",
            "tau must be >= 1",
            "horizon must be a positive number of seconds",
            "eval_every must be a positive number of seconds",
            "eta must be a positive number",
        ],
        &[(
            "{path}:{}: {e}",
            "pure interpolation wrapping an already-pinned set() error with the config line number",
        )],
    ),
    // json.rs reports through its own `JsonError` (std::error::Error),
    // args-free files carry no sites — zero rows keep the scope total.
    ("config/json.rs", &[], &[]),
    ("config/registry.rs", &[], &[]),
    ("config/mod.rs", &[], &[]),
];

/// Like [`lintable_source`] but KEEPS string literals — needed when the
/// thing being linted lives inside a string (forwarded CLI keys, error
/// messages). Byte-accurate so non-ASCII message text (em-dashes)
/// survives for verbatim fragment matching.
fn lintable_source_keep_strings(raw: &str) -> String {
    let bytes = raw.as_bytes();
    let mut out: Vec<u8> = Vec::with_capacity(raw.len());
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'/' if bytes.get(i + 1) == Some(&b'/') => {
                while i < bytes.len() && bytes[i] != b'\n' {
                    i += 1;
                }
            }
            b'/' if bytes.get(i + 1) == Some(&b'*') => {
                let mut depth = 1usize;
                i += 2;
                while i < bytes.len() && depth > 0 {
                    if bytes[i] == b'/' && bytes.get(i + 1) == Some(&b'*') {
                        depth += 1;
                        i += 2;
                    } else if bytes[i] == b'*' && bytes.get(i + 1) == Some(&b'/') {
                        depth -= 1;
                        i += 2;
                    } else {
                        if bytes[i] == b'\n' {
                            out.push(b'\n');
                        }
                        i += 1;
                    }
                }
            }
            b'"' => {
                out.push(b'"');
                i += 1;
                while i < bytes.len() {
                    match bytes[i] {
                        b'\\' => {
                            let end = (i + 2).min(bytes.len());
                            out.extend_from_slice(&bytes[i..end]);
                            i = end;
                        }
                        b'"' => {
                            out.push(b'"');
                            i += 1;
                            break;
                        }
                        b => {
                            out.push(b);
                            i += 1;
                        }
                    }
                }
            }
            b => {
                out.push(b);
                i += 1;
            }
        }
    }
    let mut s = String::from_utf8(out).expect("stripping only ASCII delimiters preserves UTF-8");
    if let Some(pos) = s.find("#[cfg(test)]") {
        s.truncate(pos);
    }
    s
}

fn read_src(rel: &str) -> String {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("src").join(rel);
    fs::read_to_string(&p).unwrap_or_else(|e| panic!("read {p:?}: {e}"))
}

/// Field names of `pub struct <name>` in stripped source: every
/// `pub ident:` inside the struct's brace block.
fn struct_fields(text: &str, name: &str) -> Vec<String> {
    let decl = format!("pub struct {name}");
    let start = text.find(&decl).unwrap_or_else(|| panic!("no `{decl}` found"));
    let open = start + text[start..].find('{').unwrap_or_else(|| panic!("{decl}: no body"));
    let mut depth = 0usize;
    let mut end = open;
    for (i, b) in text[open..].bytes().enumerate() {
        if b == b'{' {
            depth += 1;
        } else if b == b'}' {
            depth -= 1;
            if depth == 0 {
                end = open + i;
                break;
            }
        }
    }
    let body = &text[open..end];
    let mut fields = Vec::new();
    let mut from = 0;
    while let Some(off) = body[from..].find("pub ") {
        let at = from + off + 4;
        let ident: String = body[at..]
            .chars()
            .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
            .collect();
        if !ident.is_empty() && body[at + ident.len()..].trim_start().starts_with(':') {
            fields.push(ident);
        }
        from = at;
    }
    fields
}

/// CLI keys the master literally forwards: every `"key=` occurrence in
/// strings-kept source (quote-anchored, so prose mentioning `a=b`
/// mid-sentence never matches).
fn forwarded_keys(text: &str) -> Vec<String> {
    let bytes = text.as_bytes();
    let mut keys: Vec<String> = Vec::new();
    for (i, &b) in bytes.iter().enumerate() {
        if b != b'"' {
            continue;
        }
        let start = i + 1;
        let mut j = start;
        while j < bytes.len()
            && (bytes[j] == b'_' || bytes[j].is_ascii_lowercase() || bytes[j].is_ascii_digit())
        {
            j += 1;
        }
        if j > start && bytes.get(j) == Some(&b'=') {
            let k = String::from_utf8_lossy(&bytes[start..j]).to_string();
            if !keys.contains(&k) {
                keys.push(k);
            }
        }
    }
    keys
}

/// Everything tests can assert against: the integration tests raw,
/// plus each src file's `#[cfg(test)]` tail.
fn test_corpus() -> String {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let mut out = String::new();
    let mut test_files = Vec::new();
    collect_rs(&root.join("tests"), &mut test_files);
    for p in &test_files {
        out.push_str(&fs::read_to_string(p).unwrap_or_else(|e| panic!("read {p:?}: {e}")));
        out.push('\n');
    }
    let mut src_files = Vec::new();
    collect_rs(&root.join("src"), &mut src_files);
    for p in &src_files {
        let raw = fs::read_to_string(p).unwrap_or_else(|e| panic!("read {p:?}: {e}"));
        if let Some(pos) = raw.find("#[cfg(test)]") {
            out.push_str(&raw[pos..]);
            out.push('\n');
        }
    }
    out
}

/// Registry knobs on a surface, as the identifiers the scrape will
/// find: struct surfaces match on the landing `field`, CLI surfaces on
/// the typed `name`.
fn registry_idents(surface: Surface, by_field: bool) -> Vec<&'static str> {
    KNOBS
        .iter()
        .filter(|k| k.surfaces.contains(&surface))
        .map(|k| if by_field && !k.field.is_empty() { k.field } else { k.name })
        .collect()
}

#[test]
fn r5_knob_registry_matches_structs_and_forwarding_both_ways() {
    let mut violations = Vec::new();

    // Struct surfaces: registry ⊆ scraped fields and scraped ⊆ registry
    // (minus the documented non-knob fields).
    let struct_cases: [(Surface, &str, &str, &[&str]); 3] = [
        // `extra`: free-form passthrough map, not a knob.
        (Surface::Experiment, "config/experiment.rs", "ExperimentConfig", &["extra"]),
        (Surface::FigOpts, "figures/mod.rs", "FigOpts", &[]),
        // `data`/`mcfg`/`ccfg`: built artifacts of the sweep, not knobs.
        (Surface::Ch4Sweep, "figures/ch4.rs", "Sweep", &["data", "mcfg", "ccfg"]),
    ];
    for (surface, file, sname, non_knob) in struct_cases {
        let fields = struct_fields(&lintable_source(&read_src(file)), sname);
        let claimed = registry_idents(surface, true);
        for c in &claimed {
            if !fields.iter().any(|f| f == c) {
                violations.push(format!(
                    "registry claims `{c}` is threaded through {sname} ({file}) but the \
                     struct has no such field"
                ));
            }
        }
        for f in &fields {
            if non_knob.contains(&f.as_str()) {
                continue;
            }
            if !claimed.iter().any(|c| c == f) {
                violations.push(format!(
                    "{sname}.{f} ({file}) is not in the knob registry for {surface:?} — \
                     register the knob (or list the field as a non-knob here)"
                ));
            }
        }
    }

    // WorkerCli: registry names ⇄ the keys run_process literally
    // forwards on the hidden --process-worker command line.
    let fwd = forwarded_keys(&lintable_source_keep_strings(&read_src("coordinator/process.rs")));
    let claimed = registry_idents(Surface::WorkerCli, false);
    for c in &claimed {
        if !fwd.iter().any(|k| k == c) {
            violations.push(format!(
                "registry claims `{c}=` is forwarded to process workers but no such key \
                 appears in coordinator/process.rs — the knob is silently dropped"
            ));
        }
    }
    for k in &fwd {
        if !claimed.iter().any(|c| c == k) {
            violations.push(format!(
                "coordinator/process.rs forwards `{k}=` but the registry does not list it \
                 on WorkerCli — register it so usage/docs/lints see it"
            ));
        }
    }

    // TrainCli: every user-facing train knob must be READ somewhere on
    // the train path (a set() arm or a typed Args getter) — a knob in
    // the registry nothing reads is dead help text.
    let train_path: String = ["main.rs", "config/experiment.rs", "coordinator/process.rs"]
        .iter()
        .map(|f| lintable_source_keep_strings(&read_src(f)))
        .collect();
    for name in registry_idents(Surface::TrainCli, false) {
        if !train_path.contains(&format!("\"{name}\"")) {
            violations.push(format!(
                "train knob `{name}` is in the registry but never read on the train path \
                 (main.rs / config/experiment.rs / coordinator/process.rs)"
            ));
        }
    }

    assert!(violations.is_empty(), "R5 violations:\n{}", violations.join("\n"));
}

#[test]
fn r6_no_bare_narrowing_casts_on_the_wire_or_config_path() {
    const NARROW: &[&str] = &["as u8", "as u16", "as u32", "as i8", "as i16", "as i32", "as f32"];
    let mut violations = Vec::new();
    for (rel, text) in sources() {
        let scoped = rel == "coordinator/wire.rs"
            || rel == "coordinator/protocol.rs"
            || rel.starts_with("config/");
        if !scoped {
            continue;
        }
        let n: usize = NARROW.iter().map(|c| count_word(&text, c)).sum();
        let cap = NARROWING_CAST_ALLOWLIST
            .iter()
            .find(|(f, _)| *f == rel)
            .map_or(0, |(_, c)| *c);
        if n > cap {
            violations.push(format!(
                "{rel}: {n} bare narrowing `as` cast(s), allowlist permits {cap} — use \
                 `try_from` with a typed error (a silent truncation on the wire is a \
                 protocol bug), or document losslessness and extend the allowlist"
            ));
        }
    }
    assert!(violations.is_empty(), "R6 violations:\n{}", violations.join("\n"));
}

#[test]
fn r7_every_error_site_is_message_tested_or_exempt() {
    let corpus = test_corpus();
    let mut violations = Vec::new();
    for (rel, tested, exempt) in R7_MESSAGE_PINS {
        let raw = read_src(rel);
        let stripped = lintable_source(&raw);
        let with_strings = lintable_source_keep_strings(&raw);
        let sites = count_substr(&stripped, "err!(")
            + count_substr(&stripped, "bail!(")
            + count_substr(&stripped, "Error::msg(");
        if sites != tested.len() + exempt.len() {
            violations.push(format!(
                "{rel}: {sites} error construction site(s) but the R7 table pins {} — \
                 every new site needs a tested message fragment (or a reasoned exemption)",
                tested.len() + exempt.len()
            ));
        }
        for frag in *tested {
            if !with_strings.contains(frag) {
                violations.push(format!(
                    "{rel}: pinned fragment '{frag}' no longer appears at any construction \
                     site — the message was reworded without updating the pin"
                ));
            }
            if !corpus.contains(frag) {
                violations.push(format!(
                    "{rel}: fragment '{frag}' is pinned as tested but no test asserts it"
                ));
            }
        }
        for (frag, why) in *exempt {
            if !with_strings.contains(frag) {
                violations.push(format!(
                    "{rel}: exempt fragment '{frag}' no longer appears — stale exemption"
                ));
            }
            assert!(why.len() > 10, "{rel}: exemption '{frag}' needs a real reason");
        }
    }
    // Scope completeness: a new file on the config path joins the table
    // explicitly (possibly with empty rows), never silently.
    for (rel, _) in sources() {
        let scoped = rel == "coordinator/wire.rs"
            || rel == "coordinator/protocol.rs"
            || rel.starts_with("config/");
        if scoped && !R7_MESSAGE_PINS.iter().any(|(f, _, _)| *f == rel) {
            violations.push(format!("{rel}: in R7 scope but missing from R7_MESSAGE_PINS"));
        }
    }
    assert!(violations.is_empty(), "R7 violations:\n{}", violations.join("\n"));
}

/// The ceilings themselves must stay honest: a stale entry (file
/// removed or renamed) would silently allowlist a future file of the
/// same name.
#[test]
fn lint_tables_reference_existing_files() {
    let files: Vec<String> = sources().into_iter().map(|(rel, _)| rel).collect();
    for (f, _) in UNSAFE_ALLOWLIST
        .iter()
        .chain(UNWRAP_CEILINGS)
        .chain(NARROWING_CAST_ALLOWLIST)
    {
        assert!(files.iter().any(|r| r == f), "lint table references missing file {f}");
    }
    for f in SYNC_IMPORT_ALLOWLIST {
        assert!(files.iter().any(|r| r == f), "lint table references missing file {f}");
    }
    for (f, _, _) in R7_MESSAGE_PINS {
        assert!(files.iter().any(|r| r == f), "R7 table references missing file {f}");
    }
}
