"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here; pytest
(+ hypothesis) asserts allclose between kernel and oracle across shapes
and dtypes. The attention oracle also provides the backward pass for the
kernel's custom_vjp (interpret-mode Pallas AD limitation, see DESIGN.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sgd_nesterov_step_ref(x, v, g, eta, delta):
    """One fused local step (thesis Alg. 2 inner update, after the gradient
    has been evaluated at the lookahead point x + delta*v):

        v' = delta * v - eta * g
        x' = x + v'

    With delta == 0 this is plain SGD (thesis Alg. 1 inner update).
    """
    v_new = delta * v - eta * g
    return x + v_new, v_new


def elastic_exchange_ref(x, center, alpha):
    """The elastic symmetric exchange (thesis Alg. 1 steps a/b):

        d       = alpha * (x - center)
        x'      = x - d
        center' = center + d

    The symmetry (equal and opposite force) is the stability mechanism
    vs. ADMM (thesis §3.3).
    """
    d = alpha * (x - center)
    return x - d, center + d


def easgd_fused_step_ref(x, v, g, center, eta, alpha, delta, do_exchange):
    """Fully fused worker step: elastic exchange (masked by do_exchange,
    0.0 or 1.0) followed by the Nesterov SGD step. Returns
    (x', v', center_delta) where center_delta is what the master must add
    to the center variable (alpha * (x - center) when exchanging, else 0).
    """
    d = do_exchange * alpha * (x - center)
    x1 = x - d
    v_new = delta * v - eta * g
    return x1 + v_new, v_new, d


def attention_ref(q, k, v, scale):
    """Causal softmax attention oracle. q,k,v: [B, H, T, Dh]."""
    t = q.shape[-2]
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    s = jnp.where(mask[None, None], s, jnp.asarray(-1e30, s.dtype))
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)
