//! Coordinator/cluster throughput: virtual-time event-loop overhead per
//! local step, for the methods the Chapter-4 figures sweep. The metric
//! that matters is steps/second of *simulated cluster time* — this
//! bounds how big a sweep `figure all --full` can afford.

use elastic_train::cluster::CostModel;
use elastic_train::coordinator::{
    run_parallel, run_threaded, DriverConfig, Method, MlpOracle,
};
use elastic_train::data::BlobDataset;
use elastic_train::figures::benchkit::bench;
use elastic_train::model::MlpConfig;
use std::sync::Arc;
use std::time::Instant;

fn main() {
    let data = Arc::new(BlobDataset::generate(32, 10, 2048, 256, 2.2, 1));
    let mcfg = MlpConfig::new(&[32, 64, 32, 10], 1e-4);
    let cost = CostModel {
        t_grad: 1e-3,
        jitter: 0.08,
        t_data: 1e-4,
        latency: 1e-4,
        bandwidth: 1e9,
        param_bytes: (mcfg.n_params() * 4) as f64,
    };
    for (name, method) in [
        ("easgd_tau10", Method::easgd_default(8, 10)),
        ("eamsgd_tau10", Method::eamsgd_default(8, 10)),
        ("downpour_tau1", Method::Downpour { tau: 1 }),
        ("admm_tau10", Method::AdmmAsync { rho: 1.0, tau: 10 }),
    ] {
        let mut total_steps = 0u64;
        let s = bench(&format!("driver/{name}/p8"), 150.0, 5, || {
            let mut oracles = MlpOracle::family(data.clone(), &mcfg, 32, 8);
            let cfg = DriverConfig {
                eta: 0.05,
                method,
                cost,
                horizon: 0.5,
                eval_every: 10.0, // effectively no evals: pure step cost
                seed: 3,
                max_steps: u64::MAX / 2,
                lr_decay_gamma: 0.0,
            };
            let r = run_parallel(&mut oracles, &cfg);
            total_steps = r.total_steps;
        });
        println!(
            "  -> {name}: {:.0} worker-steps/s of host time ({} steps per 0.5 vs run)",
            total_steps as f64 / (s.median_ns * 1e-9),
            total_steps
        );
    }

    // Same workload through the real-thread backend: steps/sec of REAL
    // time, 8 workers, sharded-lock center (bench_threaded has the full
    // p × τ scaling grid).
    for (name, method) in [
        ("easgd_tau10", Method::easgd_default(8, 10)),
        ("downpour_tau1", Method::Downpour { tau: 1 }),
    ] {
        let mut oracles = MlpOracle::family(data.clone(), &mcfg, 32, 8);
        let cfg = DriverConfig {
            eta: 0.05,
            method,
            cost,
            horizon: 60.0, // real-seconds safety net; steps bound first
            eval_every: 1e6,
            seed: 3,
            max_steps: 20_000,
            lr_decay_gamma: 0.0,
        };
        let t0 = Instant::now();
        let r = run_threaded(&mut oracles, &cfg, 16).expect("bench run");
        let el = t0.elapsed().as_secs_f64();
        println!(
            "  -> thread/{name}/p8: {:.0} worker-steps/s real time ({} steps in {el:.2}s)",
            r.total_steps as f64 / el,
            r.total_steps
        );
    }
}
