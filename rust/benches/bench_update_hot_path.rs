//! Hot-path benchmark: the fused parameter-update ops (the per-step
//! cost every worker pays), native rust vs the PJRT-executed L1 Pallas
//! kernels — quantifying what keeping the update on the native path
//! buys (EXPERIMENTS.md §Perf).

use elastic_train::figures::benchkit::{bench, fmt_ns};
use elastic_train::model::flat;
use elastic_train::rng::Rng;

fn main() {
    let mut rng = Rng::new(1);
    for n in [4_096usize, 65_536, 1_048_576] {
        let mut mk = || {
            let mut v = vec![0.0f32; n];
            rng.fill_gaussian_f32(&mut v, 0.5);
            v
        };
        let (mut x, mut v, g, mut c) = (mk(), mk(), mk(), mk());

        let s1 = bench(&format!("native/nesterov_step/{n}"), 40.0, 7, || {
            flat::nesterov_step(&mut x, &mut v, &g, 1e-4, 0.9);
        });
        let s2 = bench(&format!("native/elastic_exchange/{n}"), 40.0, 7, || {
            flat::elastic_exchange(&mut x, &mut c, 1e-3);
        });
        let s3 = bench(&format!("native/sgd_step/{n}"), 40.0, 7, || {
            flat::sgd_step(&mut x, &g, 1e-4);
        });
        println!(
            "  -> {n} params: nesterov {} | elastic {} | sgd {} ({:.1} GB/s streamed)",
            fmt_ns(s1.median_ns),
            fmt_ns(s2.median_ns),
            fmt_ns(s3.median_ns),
            (n * 4 * 3) as f64 / s1.median_ns // 3 streams r/w
        );
    }

    pjrt_comparison();
}

/// PJRT comparison at the artifact's size (requires `--features pjrt`
/// with the real xla crate, plus `make artifacts`).
#[cfg(not(feature = "pjrt"))]
fn pjrt_comparison() {
    println!("(built without the pjrt feature — native rows only)");
}

#[cfg(feature = "pjrt")]
fn pjrt_comparison() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        let m = elastic_train::runtime::PjrtModel::load(&dir).unwrap();
        let n = m.n_params();
        let mut rng = Rng::new(2);
        let mut mk = || {
            let mut v = vec![0.0f32; n];
            rng.fill_gaussian_f32(&mut v, 0.5);
            v
        };
        let (mut x, mut v, g, c) = (mk(), mk(), mk(), mk());
        let sk = bench(&format!("pjrt/fused_step_kernel/{n}"), 60.0, 5, || {
            let _ = m
                .fused_step_kernel(&mut x, &mut v, &g, &c, 1e-4, 1e-3, 0.9, true)
                .unwrap();
        });
        let (mut xn, mut vn, mut dn) = (mk(), mk(), vec![0.0f32; n]);
        let sn = bench(&format!("native/fused_equivalent/{n}"), 40.0, 7, || {
            flat::elastic_pull(&mut xn, &c, &mut dn, 1e-3);
            flat::nesterov_step(&mut xn, &mut vn, &g, 1e-4, 0.9);
        });
        println!(
            "  -> fused update at n={n}: native {} vs PJRT {} ({:.1}x) — why the \
             coordinator keeps updates native and PJRT for gradients",
            fmt_ns(sn.median_ns),
            fmt_ns(sk.median_ns),
            sk.median_ns / sn.median_ns
        );
    } else {
        println!("(artifacts missing — run `make artifacts` for the PJRT comparison)");
    }
}
