//! Chapter 6: EASGD Tree at scale + the Gauss–Seidel unification map.

use super::ch4::Sweep;
use super::csv::Csv;
use super::FigOpts;
use crate::cluster::RunResult;
use crate::coordinator::{
    gauss_seidel, run_with_backend_topology, Backend, ConvOracle, DriverConfig, Method,
    MlpOracle, Topology, TreeScheme, TreeSpec,
};
use crate::csv_row;
use crate::error::Result;
use crate::model::ModelKind;

fn tree_dims(opts: &FigOpts) -> (usize, usize) {
    if opts.full {
        (16, 256) // thesis scale: d = 16, p = 256
    } else {
        (8, 64)
    }
}

/// (horizon, eval cadence): virtual seconds under `backend=sim`
/// (matching the ch4 sweeps), REAL wall-clock seconds under
/// `backend=thread` — kept short, since real compute replaces the cost
/// model there.
fn tree_time(opts: &FigOpts) -> (f64, f64) {
    match opts.backend {
        Backend::Sim => {
            if opts.full {
                (240.0, 10.0)
            } else {
                (45.0, 2.5)
            }
        }
        // Trees don't run on the process backend (star only); if a
        // caller tries anyway, `check_supported` refuses downstream —
        // use the wall-clock horizons so the refusal is immediate.
        Backend::Thread | Backend::Process => {
            if opts.full {
                (60.0, 2.5)
            } else {
                (8.0, 0.5)
            }
        }
    }
}

fn tree_run(
    opts: &FigOpts,
    sw: &Sweep,
    scheme: TreeScheme,
    eta: f32,
    delta: f32,
    seed: u64,
) -> Result<RunResult> {
    let (degree, leaves) = tree_dims(opts);
    // Thesis rate: α = 0.9/(d+1) — each node has at most d+1 neighbors.
    let alpha = 0.9 / (degree as f32 + 1.0);
    let method = if delta > 0.0 {
        Method::Eamsgd { alpha, tau: 1, delta }
    } else {
        Method::Easgd { alpha, tau: 1 }
    };
    let (horizon, eval_every) = tree_time(opts);
    let cfg = DriverConfig {
        eta,
        method,
        cost: sw.cost("cifar"),
        horizon,
        eval_every,
        seed,
        max_steps: u64::MAX / 2,
        lr_decay_gamma: 0.0,
    };
    let topo = Topology::Tree(TreeSpec::new(degree, scheme));
    // Honor the sweep's `model=` knob like the ch4 cells do — the cost
    // model above already scales with the selected model's n_params,
    // and the fig6.11-6.12 comparators run the same model.
    match sw.model {
        ModelKind::Mlp => {
            let mut oracles = MlpOracle::family(sw.data.clone(), &sw.mcfg, 16, leaves);
            run_with_backend_topology(opts.backend, &mut oracles, &cfg, &topo)
        }
        ModelKind::Conv => {
            let mut oracles = ConvOracle::family_sharded(
                sw.data.clone(),
                &sw.ccfg,
                16,
                leaves,
                crate::data::Sharding::Replicated,
            );
            run_with_backend_topology(opts.backend, &mut oracles, &cfg, &topo)
        }
    }
}

/// Figs 6.3–6.10 — both schemes × momentum settings × repeated seeds
/// (the thesis runs each six times; quick mode uses three).
pub fn fig6_tree(opts: &FigOpts) -> Result<()> {
    let sw = Sweep::new(opts);
    let reps: u64 = if opts.full { 6 } else { 3 };
    let mut csv = Csv::create(
        format!("{}/fig6_3_6_10.csv", opts.out_dir),
        &["fig", "scheme", "eta", "delta", "run", "time", "train_loss", "test_loss", "test_error"],
    )?;
    // (figure id, scheme, η, δ) — mirroring the thesis' grid, with η
    // scaled to this oracle (thesis: 5e-2 / 5e-3 / 5e-4 on CIFAR-lowrank).
    let cases: Vec<(&str, TreeScheme, f32, f32)> = vec![
        ("6.3", TreeScheme::MultiScale { tau1: 10, tau2: 100 }, 0.08, 0.0),
        ("6.4", TreeScheme::UpDown { tau_up: 8, tau_down: 80 }, 0.08, 0.0),
        ("6.5", TreeScheme::MultiScale { tau1: 1, tau2: 10 }, 0.20, 0.0),
        ("6.6", TreeScheme::MultiScale { tau1: 1, tau2: 10 }, 0.02, 0.9),
        ("6.7", TreeScheme::MultiScale { tau1: 1, tau2: 10 }, 0.002, 0.99),
        ("6.8", TreeScheme::UpDown { tau_up: 1, tau_down: 10 }, 0.20, 0.0),
        ("6.9", TreeScheme::UpDown { tau_up: 1, tau_down: 10 }, 0.02, 0.9),
        ("6.10", TreeScheme::UpDown { tau_up: 1, tau_down: 10 }, 0.002, 0.99),
    ];
    let mut summary: Vec<(String, usize, f64, f64)> = Vec::new();
    for (fig, scheme, eta, delta) in cases {
        let mut diverged = 0usize;
        let mut best = f64::INFINITY;
        let mut final_train = Vec::new();
        for run in 0..reps {
            let r = tree_run(opts, &sw, scheme, eta, delta, opts.seed + 600 + run)?;
            for pt in &r.curve {
                csv_row!(csv, fig, format!("{scheme:?}").replace(',', ";"), eta, delta, run,
                         pt.time, pt.train_loss, pt.test_loss, pt.test_error)?;
            }
            if r.diverged {
                diverged += 1;
            } else {
                best = best.min(r.best_test_error());
                final_train.push(r.final_train_loss());
            }
        }
        let mean_train = if final_train.is_empty() {
            f64::NAN
        } else {
            final_train.iter().sum::<f64>() / final_train.len() as f64
        };
        println!(
            "fig{fig}: η={eta} δ={delta} diverged {diverged}/{reps}, best test err {best:.3}, mean final train {mean_train:.3}"
        );
        summary.push((fig.to_string(), diverged, best, mean_train));
    }
    // Shapes at the thesis' headline settings (Figs 6.3 vs 6.4):
    // scheme 1 trains faster; scheme 2 reaches better test accuracy;
    // momentum δ=0.9 with reduced η stabilizes (6.6/6.9 no divergence).
    let get = |f: &str| summary.iter().find(|(s, ..)| s == f).unwrap().clone();
    let (_, _, b63, t63) = get("6.3");
    let (_, _, b64, t64) = get("6.4");
    let (_, d66, ..) = get("6.6");
    let (_, d69, ..) = get("6.9");
    println!(
        "fig6 shape: scheme1 faster training ({t63:.3} ≤ {t64:.3}): {} | \
         scheme2 better test ({b64:.3} ≤ {b63:.3}): {} | \
         momentum stabilizes (div {d66}+{d69}=0): {}",
        if t63 <= t64 + 0.02 { "HOLDS" } else { "VIOLATED" },
        if b64 <= b63 + 0.02 { "HOLDS" } else { "VIOLATED" },
        if d66 + d69 == 0 { "HOLDS" } else { "VIOLATED" }
    );
    Ok(())
}

/// Figs 6.11–6.12 — best-of comparison: EASGD Tree (p=256) vs flat
/// DOWNPOUR / EASGD at p=16, no momentum.
pub fn fig6_best(opts: &FigOpts) -> Result<()> {
    let mut sw = Sweep::new(opts);
    // The flat-star comparators must share the tree's time base —
    // under backend=thread the tree horizon is short real seconds, and
    // a best-of comparison across different compute budgets is bogus.
    let (horizon, eval_every) = tree_time(opts);
    sw.horizon = horizon;
    sw.eval_every = eval_every;
    let mut csv = Csv::create(
        format!("{}/fig6_11_6_12.csv", opts.out_dir),
        &["method", "time", "train_loss", "test_loss", "test_error"],
    )?;
    let tree = tree_run(
        opts,
        &sw,
        TreeScheme::UpDown { tau_up: 1, tau_down: 10 },
        0.08,
        0.0,
        opts.seed + 990,
    )?;
    let easgd = sw.run(16, Method::easgd_default(16, 10), 0.08, "cifar")?;
    let downpour = sw.run(16, Method::Downpour { tau: 1 }, 0.05, "cifar")?;
    for (name, r) in [("TREE", &tree), ("EASGD16", &easgd), ("DOWNPOUR16", &downpour)] {
        for pt in &r.curve {
            csv_row!(csv, name, pt.time, pt.train_loss, pt.test_loss, pt.test_error)?;
        }
        println!(
            "fig6.12 {name:<11} best test err {:.3}{}",
            r.best_test_error(),
            if r.diverged { " [DIVERGED]" } else { "" }
        );
    }
    let vs_downpour = tree.best_test_error() <= downpour.best_test_error() + 0.02;
    let vs_easgd = tree.best_test_error() <= easgd.best_test_error() + 0.02;
    if opts.full {
        println!(
            "fig6.11-6.12 shape: tree (p={}) ≤ flat-p16 best: {}",
            tree_dims(opts).1,
            if vs_downpour && vs_easgd { "HOLDS" } else { "VIOLATED" }
        );
    } else {
        println!(
            "fig6.11-6.12 shape (quick, p={} tree): tree ≤ DOWNPOUR16: {} \
             (vs EASGD16 needs the thesis-scale p=256 run: use --full)",
            tree_dims(opts).1,
            if vs_downpour { "HOLDS" } else { "VIOLATED" }
        );
    }
    Ok(())
}

/// §6.2 — the Gauss–Seidel stability map over the moving-rate plane
/// (a, b), with the DOWNPOUR point (1, p) and EASGD point (β/p, β).
pub fn fig6_gs(opts: &FigOpts) -> Result<()> {
    let g = if opts.full { 96 } else { 40 };
    let p = 16usize;
    let mut csv = Csv::create(
        format!("{}/fig6_13gs.csv", opts.out_dir),
        &["eta_h", "a", "b", "sp"],
    )?;
    for &eta_h in &[0.1f64, 1.0] {
        for ai in 0..g {
            for bi in 0..g {
                let a = (ai as f64 + 0.5) / g as f64 * 1.2;
                let b = (bi as f64 + 0.5) / g as f64 * (p as f64 * 1.2);
                csv.row_f64(&[eta_h, a, b, gauss_seidel::spectral(eta_h, a, b, p)])?;
            }
        }
    }
    let (ad, bd) = gauss_seidel::downpour_rates(p);
    let (ae, be) = gauss_seidel::easgd_rates(p);
    let sp_d = gauss_seidel::spectral(1.0, ad, bd, p);
    let sp_e = gauss_seidel::spectral(1.0, ae, be, p);
    println!(
        "fig6.13gs: at η_h=1.0, DOWNPOUR point (1,{p}) sp={sp_d:.3}; EASGD point ({ae:.3},{be}) sp={sp_e:.3}"
    );
    println!(
        "fig6.13gs shape: DOWNPOUR's singular rates unstable where EASGD stable: {}",
        if sp_d > 1.0 && sp_e < 1.0 { "HOLDS" } else { "VIOLATED" }
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gs_map_runs_quick() {
        let opts = FigOpts {
            out_dir: std::env::temp_dir()
                .join("et_fig_ch6")
                .to_string_lossy()
                .into_owned(),
            full: false,
            seed: 0,
            backend: crate::coordinator::Backend::Sim,
            model: crate::model::ModelKind::Mlp,
            threads: 1,
            simd: "auto".into(),
        };
        fig6_gs(&opts).unwrap();
    }
}
