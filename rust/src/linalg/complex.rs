//! Minimal complex arithmetic for the QR eigenvalue iteration.

use std::ops::{Add, AddAssign, Div, Mul, Neg, Sub};

/// A complex number (f64 re/im). Only what the eig solver needs.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Complex {
    pub re: f64,
    pub im: f64,
}

impl Complex {
    pub const ZERO: Complex = Complex { re: 0.0, im: 0.0 };
    pub const ONE: Complex = Complex { re: 1.0, im: 0.0 };

    #[inline]
    pub fn new(re: f64, im: f64) -> Self {
        Self { re, im }
    }

    #[inline]
    pub fn real(re: f64) -> Self {
        Self { re, im: 0.0 }
    }

    #[inline]
    pub fn conj(self) -> Self {
        Self { re: self.re, im: -self.im }
    }

    /// |z| with overflow-safe hypot.
    #[inline]
    pub fn abs(self) -> f64 {
        self.re.hypot(self.im)
    }

    #[inline]
    pub fn norm_sqr(self) -> f64 {
        self.re * self.re + self.im * self.im
    }

    /// Principal square root.
    pub fn sqrt(self) -> Self {
        let r = self.abs();
        if r == 0.0 {
            return Complex::ZERO;
        }
        let re = ((r + self.re) / 2.0).sqrt();
        let im_mag = ((r - self.re) / 2.0).sqrt();
        Complex::new(re, if self.im >= 0.0 { im_mag } else { -im_mag })
    }
}

impl Add for Complex {
    type Output = Complex;
    #[inline]
    fn add(self, o: Complex) -> Complex {
        Complex::new(self.re + o.re, self.im + o.im)
    }
}

impl AddAssign for Complex {
    #[inline]
    fn add_assign(&mut self, o: Complex) {
        self.re += o.re;
        self.im += o.im;
    }
}

impl Sub for Complex {
    type Output = Complex;
    #[inline]
    fn sub(self, o: Complex) -> Complex {
        Complex::new(self.re - o.re, self.im - o.im)
    }
}

impl Mul for Complex {
    type Output = Complex;
    #[inline]
    fn mul(self, o: Complex) -> Complex {
        Complex::new(
            self.re * o.re - self.im * o.im,
            self.re * o.im + self.im * o.re,
        )
    }
}

impl Mul<f64> for Complex {
    type Output = Complex;
    #[inline]
    fn mul(self, s: f64) -> Complex {
        Complex::new(self.re * s, self.im * s)
    }
}

impl Div for Complex {
    type Output = Complex;
    /// Smith's algorithm (overflow-resistant complex division).
    fn div(self, o: Complex) -> Complex {
        if o.re.abs() >= o.im.abs() {
            let r = o.im / o.re;
            let d = o.re + o.im * r;
            Complex::new((self.re + self.im * r) / d, (self.im - self.re * r) / d)
        } else {
            let r = o.re / o.im;
            let d = o.re * r + o.im;
            Complex::new((self.re * r + self.im) / d, (self.im * r - self.re) / d)
        }
    }
}

impl Neg for Complex {
    type Output = Complex;
    #[inline]
    fn neg(self) -> Complex {
        Complex::new(-self.re, -self.im)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn field_ops() {
        let a = Complex::new(1.0, 2.0);
        let b = Complex::new(3.0, -1.0);
        let p = a * b;
        assert!((p.re - 5.0).abs() < 1e-14 && (p.im - 5.0).abs() < 1e-14);
        let q = p / b;
        assert!((q.re - a.re).abs() < 1e-12 && (q.im - a.im).abs() < 1e-12);
    }

    #[test]
    fn sqrt_roundtrip() {
        for &(re, im) in &[(4.0, 0.0), (-4.0, 0.0), (3.0, 4.0), (-3.0, -4.0)] {
            let z = Complex::new(re, im);
            let s = z.sqrt();
            let back = s * s;
            assert!((back.re - re).abs() < 1e-10 && (back.im - im).abs() < 1e-10);
        }
    }

    #[test]
    fn sqrt_of_negative_real_is_imaginary() {
        let s = Complex::real(-9.0).sqrt();
        assert!(s.re.abs() < 1e-12 && (s.im - 3.0).abs() < 1e-12);
    }
}
