//! Explicit SIMD kernel tier for the GEMM substrate.
//!
//! [`super::gemm`]'s four micro-kernels (`Broadcast`, `Dot`, `BothT`,
//! and the fused `BiasAct` epilogue) auto-vectorize well, but an
//! explicit `core::arch` tier buys FMA contraction and wider effective
//! issue on the batch × dim panels every sweep spends its wall clock
//! in. This module owns the **kernel-tier dispatch table**: each
//! `exec_span` call routes through [`broadcast`] / [`dot`] /
//! [`both_t`] / [`bias_act`] below, which select the process-wide
//! [`active_tier`] once per span (a relaxed atomic load) and jump to
//! the matching implementation.
//!
//! Tier selection, strictest first:
//!
//! 1. `simd=` config/CLI knob → [`configure`] (same plumbing as
//!    `threads=`);
//! 2. `ELASTIC_SIMD=auto|avx2|neon|scalar` environment variable, read
//!    on the first dispatch when nothing was configured — a malformed
//!    or unsupported value is a loud panic, never a silent fallback
//!    (the `ELASTIC_TRAIN_THREADS` contract);
//! 3. `auto` (the default): runtime feature detection picks the best
//!    supported tier — AVX2+FMA on x86_64, NEON on aarch64, scalar
//!    otherwise.
//!
//! Guarantees, matching the repo's layered-equivalence story:
//!
//! - **`simd` feature off (the default): byte-identical behavior.**
//!   The arch modules are not compiled, every request other than
//!   `auto`/`scalar` is a typed error, and dispatch collapses to the
//!   scalar kernels.
//! - **Threaded ≡ serial stays bitwise *within* a tier**: the pool
//!   hands out MR-row / NR-column panels and each output element is
//!   produced by one thread in the tier's serial loop order.
//! - **SIMD vs scalar is tolerance-level parity, not bitwise**: FMA
//!   contracts the multiply-add rounding step, legitimately changing
//!   low-order bits (`tests/simd_parity.rs` pins ≤ 1e-5 relative).
//! - **Miri always runs the scalar tier** (`cfg(miri)` short-circuits
//!   detection and rejects explicit SIMD requests): intrinsics are not
//!   interpretable, and the aliasing story Miri vets is tier-agnostic.
//!
//! The `unsafe` surface here is exactly the `#[target_feature]` kernel
//! bodies plus their call sites in the dispatch wrappers; the whole
//! file is capped by the `tests/repo_lint.rs` R2 allowlist.

use super::gemm::{self, COut};
use crate::error::Result;
use crate::sync::atomic::{AtomicUsize, Ordering};

/// A kernel tier. `Scalar` is the auto-vectorized baseline the repo
/// shipped with; the SIMD tiers exist only under the off-by-default
/// `simd` cargo feature and on their own architecture.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Tier {
    /// The portable register-blocked kernels in [`super::gemm`].
    Scalar,
    /// AVX2 + FMA (`core::arch::x86_64`), 2×8 f32 lanes per NR block.
    Avx2,
    /// NEON (`core::arch::aarch64`), 4×4 f32 lanes per NR block.
    Neon,
}

impl Tier {
    /// The knob spelling of this tier (`simd=<name>`).
    pub fn name(&self) -> &'static str {
        match self {
            Tier::Scalar => "scalar",
            Tier::Avx2 => "avx2",
            Tier::Neon => "neon",
        }
    }
}

/// Selected tier + 1; 0 = not yet selected (first dispatch seeds from
/// `ELASTIC_SIMD`, defaulting to `auto` detection).
static ACTIVE: AtomicUsize = AtomicUsize::new(0);

fn tier_from_code(code: usize) -> Tier {
    match code {
        0 => Tier::Scalar,
        1 => Tier::Avx2,
        _ => Tier::Neon,
    }
}

fn tier_code(t: Tier) -> usize {
    match t {
        Tier::Scalar => 0,
        Tier::Avx2 => 1,
        Tier::Neon => 2,
    }
}

/// The process-wide active kernel tier. First call seeds it from the
/// `ELASTIC_SIMD` environment variable (absent = `auto`); a value that
/// is malformed, or names a tier this build/CPU cannot run, panics
/// loudly — the same no-silent-fallback contract as the config parser
/// and `ELASTIC_TRAIN_THREADS`.
pub fn active_tier() -> Tier {
    match ACTIVE.load(Ordering::Relaxed) {
        0 => {
            let t = match std::env::var("ELASTIC_SIMD") {
                Ok(v) => match resolve(&v) {
                    Ok(t) => t,
                    Err(e) => panic!("ELASTIC_SIMD='{v}' rejected: {e}"),
                },
                Err(_) => detect_best(),
            };
            ACTIVE.store(tier_code(t) + 1, Ordering::Relaxed);
            t
        }
        code => tier_from_code(code - 1),
    }
}

/// Select the kernel tier for this process from a knob value
/// (`auto|avx2|neon|scalar`); returns the resolved tier. Requests the
/// build or CPU cannot honor are typed errors naming the reason —
/// callers surface them instead of silently degrading.
pub fn configure(request: &str) -> Result<Tier> {
    let t = resolve(request)?;
    ACTIVE.store(tier_code(t) + 1, Ordering::Relaxed);
    Ok(t)
}

/// Whether `s` is a syntactically valid `simd=` knob value. Config
/// parsing validates the *name* eagerly (strict-parse contract) but
/// defers availability to [`configure`] at run start, so a config file
/// naming `avx2` parses on any machine and fails loudly only when the
/// run actually asks for it.
pub fn is_known_request(s: &str) -> bool {
    matches!(s, "auto" | "avx2" | "neon" | "scalar")
}

/// Best tier this build + CPU supports: AVX2+FMA, else NEON, else
/// scalar. Always scalar under Miri (intrinsics are not interpreted)
/// and in builds without the `simd` cargo feature.
pub fn detect_best() -> Tier {
    if avx2_supported() {
        return Tier::Avx2;
    }
    if neon_supported() {
        return Tier::Neon;
    }
    Tier::Scalar
}

/// CPU capability string recorded in bench history entries, so a
/// throughput regression can be traced to the host it ran on.
pub fn cpu_features() -> &'static str {
    if avx2_supported() {
        return "avx2+fma";
    }
    if neon_supported() {
        return "neon";
    }
    "none-detected"
}

fn avx2_supported() -> bool {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        return !cfg!(miri)
            && std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma");
    }
    #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
    false
}

fn neon_supported() -> bool {
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    {
        return !cfg!(miri) && std::arch::is_aarch64_feature_detected!("neon");
    }
    #[cfg(not(all(feature = "simd", target_arch = "aarch64")))]
    false
}

/// Why an explicit tier request cannot be honored, most-specific last:
/// feature gate, then Miri, then architecture, then the CPU itself.
fn unavailable_reason(tier: &str) -> &'static str {
    if !cfg!(feature = "simd") {
        return "this build has the `simd` cargo feature disabled (rebuild with --features simd)";
    }
    if cfg!(miri) {
        return "SIMD intrinsics are not interpreted under Miri; use simd=scalar";
    }
    if tier == "avx2" && !cfg!(target_arch = "x86_64") {
        return "avx2 requires an x86_64 target";
    }
    if tier == "neon" && !cfg!(target_arch = "aarch64") {
        return "neon requires an aarch64 target";
    }
    "the CPU does not report the required features (avx2+fma / neon)"
}

fn resolve(request: &str) -> Result<Tier> {
    match request {
        "auto" => Ok(detect_best()),
        "scalar" => Ok(Tier::Scalar),
        "avx2" if avx2_supported() => Ok(Tier::Avx2),
        "neon" if neon_supported() => Ok(Tier::Neon),
        "avx2" | "neon" => {
            crate::bail!("simd={request} unavailable: {}", unavailable_reason(request))
        }
        other => crate::bail!("unknown simd tier '{other}' (expected auto|avx2|neon|scalar)"),
    }
}

// ---------------------------------------------------------------------------
// Dispatch wrappers — the one place kernels are selected. Each wrapper
// is called once per dispatched span (serial: once per product), so
// the tier load is a relaxed atomic read amortized over an entire
// panel's worth of multiply-adds.
// ---------------------------------------------------------------------------

/// `C += op(A)·B` over rows `[i0, i1)` × columns `[j0, j1)` in the
/// active tier (see [`gemm::kernel_broadcast`] for the contract).
#[allow(clippy::too_many_arguments)]
pub(crate) fn broadcast(
    i0: usize,
    i1: usize,
    j0: usize,
    j1: usize,
    n: usize,
    k: usize,
    strides: [usize; 2],
    a: &[f32],
    b: &[f32],
    c: &mut COut,
) {
    match active_tier() {
        Tier::Scalar => gemm::kernel_broadcast(i0, i1, j0, j1, n, k, strides, a, b, c),
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        // SAFETY: Tier::Avx2 is only ever stored after avx2_supported()
        // confirmed avx2+fma on this CPU (resolve/detect_best).
        Tier::Avx2 => unsafe { avx2::broadcast(i0, i1, j0, j1, n, k, strides, a, b, c) },
        #[cfg(all(feature = "simd", target_arch = "aarch64"))]
        // SAFETY: Tier::Neon is only ever stored after neon_supported()
        // confirmed NEON on this CPU (resolve/detect_best).
        Tier::Neon => unsafe { neon::broadcast(i0, i1, j0, j1, n, k, strides, a, b, c) },
        #[allow(unreachable_patterns)] // covers the cfg'd-out tiers
        _ => gemm::kernel_broadcast(i0, i1, j0, j1, n, k, strides, a, b, c),
    }
}

/// `C += A·Bᵀ` over rows `[i0, i1)` × columns `[j0, j1)` in the active
/// tier (see [`gemm::kernel_dot`] for the contract).
#[allow(clippy::too_many_arguments)]
pub(crate) fn dot(
    i0: usize,
    i1: usize,
    j0: usize,
    j1: usize,
    k: usize,
    a: &[f32],
    b: &[f32],
    c: &mut COut,
) {
    match active_tier() {
        Tier::Scalar => gemm::kernel_dot(i0, i1, j0, j1, k, a, b, c),
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        // SAFETY: Tier::Avx2 implies avx2+fma was detected (see above).
        Tier::Avx2 => unsafe { avx2::dot(i0, i1, j0, j1, k, a, b, c) },
        #[cfg(all(feature = "simd", target_arch = "aarch64"))]
        // SAFETY: Tier::Neon implies NEON was detected (see above).
        Tier::Neon => unsafe { neon::dot(i0, i1, j0, j1, k, a, b, c) },
        #[allow(unreachable_patterns)] // covers the cfg'd-out tiers
        _ => gemm::kernel_dot(i0, i1, j0, j1, k, a, b, c),
    }
}

/// `C += Aᵀ·Bᵀ` over rows `[i0, i1)` × columns `[j0, j1)` in the
/// active tier (see [`gemm::kernel_both_t`] for the contract).
#[allow(clippy::too_many_arguments)]
pub(crate) fn both_t(
    i0: usize,
    i1: usize,
    j0: usize,
    j1: usize,
    m: usize,
    k: usize,
    a: &[f32],
    b: &[f32],
    c: &mut COut,
) {
    match active_tier() {
        Tier::Scalar => gemm::kernel_both_t(i0, i1, j0, j1, m, k, a, b, c),
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        // SAFETY: Tier::Avx2 implies avx2+fma was detected (see above).
        Tier::Avx2 => unsafe { avx2::both_t(i0, i1, j0, j1, m, k, a, b, c) },
        #[cfg(all(feature = "simd", target_arch = "aarch64"))]
        // SAFETY: Tier::Neon implies NEON was detected (see above).
        Tier::Neon => unsafe { neon::both_t(i0, i1, j0, j1, m, k, a, b, c) },
        #[allow(unreachable_patterns)] // covers the cfg'd-out tiers
        _ => gemm::kernel_both_t(i0, i1, j0, j1, m, k, a, b, c),
    }
}

/// Fused `C = act(A·B + bias)` over rows `[i0, i1)` × columns
/// `[j0, j1)` in the active tier (see [`gemm::kernel_bias_act`]).
#[allow(clippy::too_many_arguments)]
pub(crate) fn bias_act(
    i0: usize,
    i1: usize,
    j0: usize,
    j1: usize,
    n: usize,
    k: usize,
    a: &[f32],
    b: &[f32],
    bias: &[f32],
    relu: bool,
    c: &mut COut,
) {
    match active_tier() {
        Tier::Scalar => gemm::kernel_bias_act(i0, i1, j0, j1, n, k, a, b, bias, relu, c),
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        // SAFETY: Tier::Avx2 implies avx2+fma was detected (see above).
        Tier::Avx2 => unsafe { avx2::bias_act(i0, i1, j0, j1, n, k, a, b, bias, relu, c) },
        #[cfg(all(feature = "simd", target_arch = "aarch64"))]
        // SAFETY: Tier::Neon implies NEON was detected (see above).
        Tier::Neon => unsafe { neon::bias_act(i0, i1, j0, j1, n, k, a, b, bias, relu, c) },
        #[allow(unreachable_patterns)] // covers the cfg'd-out tiers
        _ => gemm::kernel_bias_act(i0, i1, j0, j1, n, k, a, b, bias, relu, c),
    }
}

/// AVX2 + FMA kernels. Same loop *structure* as the scalar kernels
/// (MR-row blocks × NR-column blocks, column tail, then row tail) so
/// the panel-boundary reasoning carries over verbatim; the NR block is
/// two 8-lane registers per row and the k-loop contracts with
/// `_mm256_fmadd_ps`. Every fn is `#[target_feature(enable = "avx2",
/// enable = "fma")] unsafe`: callers (the dispatch wrappers above)
/// guarantee the CPU reports both features before any call exists.
/// Indexing stays within the same `a.len() == m·k` / `b.len() == k·n`
/// bounds the scalar kernels assert via slice indexing; here the hot
/// loops use unchecked loads, justified by the entry-point size
/// asserts in `gemm.rs` (Job invariant 2).
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod avx2 {
    use crate::linalg::gemm::{COut, MR, NR};
    use core::arch::x86_64::*;

    /// Broadcast-form `C += op(A)·B`; see `gemm::kernel_broadcast`.
    ///
    /// # Safety
    /// CPU must support avx2+fma; slice lengths per Job invariant 2.
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn broadcast(
        i0: usize,
        i1: usize,
        j0: usize,
        j1: usize,
        n: usize,
        k: usize,
        strides: [usize; 2],
        a: &[f32],
        b: &[f32],
        c: &mut COut,
    ) {
        let [ars, acs] = strides;
        let bp = b.as_ptr();
        let mut i = i0;
        while i + MR <= i1 {
            let mut j = j0;
            while j + NR <= j1 {
                let mut acc = [[_mm256_setzero_ps(); 2]; MR];
                for p in 0..k {
                    let b0 = _mm256_loadu_ps(bp.add(p * n + j));
                    let b1 = _mm256_loadu_ps(bp.add(p * n + j + 8));
                    for (r, accr) in acc.iter_mut().enumerate() {
                        let arp = _mm256_set1_ps(*a.get_unchecked((i + r) * ars + p * acs));
                        accr[0] = _mm256_fmadd_ps(arp, b0, accr[0]);
                        accr[1] = _mm256_fmadd_ps(arp, b1, accr[1]);
                    }
                }
                for (r, accr) in acc.iter().enumerate() {
                    let cp = c.row(i + r, j, j + NR).as_mut_ptr();
                    _mm256_storeu_ps(cp, _mm256_add_ps(_mm256_loadu_ps(cp), accr[0]));
                    let cp8 = cp.add(8);
                    _mm256_storeu_ps(cp8, _mm256_add_ps(_mm256_loadu_ps(cp8), accr[1]));
                }
                j += NR;
            }
            if j < j1 {
                for r in 0..MR {
                    row_accum(i + r, j, j1, n, k, ars, acs, a, b, c);
                }
            }
            i += MR;
        }
        while i < i1 {
            row_accum(i, j0, j1, n, k, ars, acs, a, b, c);
            i += 1;
        }
    }

    /// One output row of the broadcast form, columns `[j0, j1)`:
    /// 8-lane blocks then a scalar tail. Shared by the column tail of
    /// the MR block and the sub-MR row tail.
    ///
    /// # Safety
    /// Same contract as [`broadcast`].
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn row_accum(
        i: usize,
        j0: usize,
        j1: usize,
        n: usize,
        k: usize,
        ars: usize,
        acs: usize,
        a: &[f32],
        b: &[f32],
        c: &mut COut,
    ) {
        let bp = b.as_ptr();
        let crow = c.row(i, j0, j1);
        let w = j1 - j0;
        let mut x = 0;
        while x + 8 <= w {
            let mut acc = _mm256_setzero_ps();
            for p in 0..k {
                let arp = _mm256_set1_ps(*a.get_unchecked(i * ars + p * acs));
                acc = _mm256_fmadd_ps(arp, _mm256_loadu_ps(bp.add(p * n + j0 + x)), acc);
            }
            let cx = crow.as_mut_ptr().add(x);
            _mm256_storeu_ps(cx, _mm256_add_ps(_mm256_loadu_ps(cx), acc));
            x += 8;
        }
        while x < w {
            let mut s = 0.0f32;
            for p in 0..k {
                s += *a.get_unchecked(i * ars + p * acs) * *b.get_unchecked(p * n + j0 + x);
            }
            *crow.get_unchecked_mut(x) += s;
            x += 1;
        }
    }

    /// Fused `C = act(A·B + bias)`; see `gemm::kernel_bias_act`.
    ///
    /// # Safety
    /// Same contract as [`broadcast`]; `bias.len() == n`.
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn bias_act(
        i0: usize,
        i1: usize,
        j0: usize,
        j1: usize,
        n: usize,
        k: usize,
        a: &[f32],
        b: &[f32],
        bias: &[f32],
        relu: bool,
        c: &mut COut,
    ) {
        let bp = b.as_ptr();
        let mut i = i0;
        while i + MR <= i1 {
            let mut j = j0;
            while j + NR <= j1 {
                let bias0 = _mm256_loadu_ps(bias.as_ptr().add(j));
                let bias1 = _mm256_loadu_ps(bias.as_ptr().add(j + 8));
                let mut acc = [[bias0, bias1]; MR];
                for p in 0..k {
                    let b0 = _mm256_loadu_ps(bp.add(p * n + j));
                    let b1 = _mm256_loadu_ps(bp.add(p * n + j + 8));
                    for (r, accr) in acc.iter_mut().enumerate() {
                        let arp = _mm256_set1_ps(*a.get_unchecked((i + r) * k + p));
                        accr[0] = _mm256_fmadd_ps(arp, b0, accr[0]);
                        accr[1] = _mm256_fmadd_ps(arp, b1, accr[1]);
                    }
                }
                let zero = _mm256_setzero_ps();
                for (r, accr) in acc.iter().enumerate() {
                    let (mut v0, mut v1) = (accr[0], accr[1]);
                    if relu {
                        v0 = _mm256_max_ps(v0, zero);
                        v1 = _mm256_max_ps(v1, zero);
                    }
                    let cp = c.row(i + r, j, j + NR).as_mut_ptr();
                    _mm256_storeu_ps(cp, v0);
                    _mm256_storeu_ps(cp.add(8), v1);
                }
                j += NR;
            }
            if j < j1 {
                for r in 0..MR {
                    row_bias_act(i + r, j, j1, n, k, a, b, bias, relu, c);
                }
            }
            i += MR;
        }
        while i < i1 {
            row_bias_act(i, j0, j1, n, k, a, b, bias, relu, c);
            i += 1;
        }
    }

    /// One output row of the fused form, columns `[j0, j1)`.
    ///
    /// # Safety
    /// Same contract as [`bias_act`].
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn row_bias_act(
        i: usize,
        j0: usize,
        j1: usize,
        n: usize,
        k: usize,
        a: &[f32],
        b: &[f32],
        bias: &[f32],
        relu: bool,
        c: &mut COut,
    ) {
        let bp = b.as_ptr();
        let crow = c.row(i, j0, j1);
        let w = j1 - j0;
        let mut x = 0;
        while x + 8 <= w {
            let mut acc = _mm256_loadu_ps(bias.as_ptr().add(j0 + x));
            for p in 0..k {
                let arp = _mm256_set1_ps(*a.get_unchecked(i * k + p));
                acc = _mm256_fmadd_ps(arp, _mm256_loadu_ps(bp.add(p * n + j0 + x)), acc);
            }
            if relu {
                acc = _mm256_max_ps(acc, _mm256_setzero_ps());
            }
            _mm256_storeu_ps(crow.as_mut_ptr().add(x), acc);
            x += 8;
        }
        while x < w {
            let mut s = *bias.get_unchecked(j0 + x);
            for p in 0..k {
                s += *a.get_unchecked(i * k + p) * *b.get_unchecked(p * n + j0 + x);
            }
            *crow.get_unchecked_mut(x) = if relu { s.max(0.0) } else { s };
            x += 1;
        }
    }

    /// Dot-form `C += A·Bᵀ`; see `gemm::kernel_dot`.
    ///
    /// # Safety
    /// Same contract as [`broadcast`] with `b.len() == n·k`.
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn dot(
        i0: usize,
        i1: usize,
        j0: usize,
        j1: usize,
        k: usize,
        a: &[f32],
        b: &[f32],
        c: &mut COut,
    ) {
        for i in i0..i1 {
            let ap = a.as_ptr().add(i * k);
            let crow = c.row(i, j0, j1);
            for (j, cv) in (j0..j1).zip(crow.iter_mut()) {
                *cv += dot1(ap, b.as_ptr().add(j * k), k);
            }
        }
    }

    /// `C += Aᵀ·Bᵀ`; see `gemm::kernel_both_t`. The strided `Aᵀ`
    /// column is staged through a fixed stack buffer (64 elements — no
    /// allocation) so the k-loop becomes contiguous [`dot1`] calls.
    ///
    /// # Safety
    /// Same contract as [`broadcast`] with `a.len() == k·m`,
    /// `b.len() == n·k`.
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn both_t(
        i0: usize,
        i1: usize,
        j0: usize,
        j1: usize,
        m: usize,
        k: usize,
        a: &[f32],
        b: &[f32],
        c: &mut COut,
    ) {
        let mut buf = [0.0f32; 64];
        for i in i0..i1 {
            let crow = c.row(i, j0, j1);
            let mut p0 = 0;
            while p0 < k {
                let pc = (k - p0).min(buf.len());
                for (t, slot) in buf[..pc].iter_mut().enumerate() {
                    *slot = *a.get_unchecked((p0 + t) * m + i);
                }
                for (j, cv) in (j0..j1).zip(crow.iter_mut()) {
                    *cv += dot1(buf.as_ptr(), b.as_ptr().add(j * k + p0), pc);
                }
                p0 += pc;
            }
        }
    }

    /// Two-accumulator FMA dot product of length `k` at raw pointers.
    ///
    /// # Safety
    /// `x` and `y` must be readable for `k` f32s; avx2+fma required.
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn dot1(x: *const f32, y: *const f32, k: usize) -> f32 {
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut p = 0;
        while p + 16 <= k {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(x.add(p)), _mm256_loadu_ps(y.add(p)), acc0);
            acc1 = _mm256_fmadd_ps(
                _mm256_loadu_ps(x.add(p + 8)),
                _mm256_loadu_ps(y.add(p + 8)),
                acc1,
            );
            p += 16;
        }
        if p + 8 <= k {
            acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(x.add(p)), _mm256_loadu_ps(y.add(p)), acc0);
            p += 8;
        }
        let mut s = hsum8(_mm256_add_ps(acc0, acc1));
        while p < k {
            s += *x.add(p) * *y.add(p);
            p += 1;
        }
        s
    }

    /// Horizontal sum of 8 f32 lanes.
    ///
    /// # Safety
    /// avx2 required.
    #[target_feature(enable = "avx2")]
    unsafe fn hsum8(v: __m256) -> f32 {
        let q = _mm_add_ps(_mm256_castps256_ps128(v), _mm256_extractf128_ps::<1>(v));
        let h = _mm_add_ps(q, _mm_movehl_ps(q, q));
        let s = _mm_add_ss(h, _mm_shuffle_ps::<0x55>(h, h));
        _mm_cvtss_f32(s)
    }
}

/// NEON kernels (aarch64). Mirrors the AVX2 module with 4-lane
/// `float32x4_t` registers — an NR block is four of them per row —
/// and `vfmaq_f32` contraction. NEON is baseline on aarch64, but the
/// fns stay `#[target_feature]`-gated and runtime-detected for
/// uniformity with the x86 path.
#[cfg(all(feature = "simd", target_arch = "aarch64"))]
mod neon {
    use crate::linalg::gemm::{COut, MR, NR};
    use core::arch::aarch64::*;

    /// Broadcast-form `C += op(A)·B`; see `gemm::kernel_broadcast`.
    ///
    /// # Safety
    /// CPU must support neon; slice lengths per Job invariant 2.
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn broadcast(
        i0: usize,
        i1: usize,
        j0: usize,
        j1: usize,
        n: usize,
        k: usize,
        strides: [usize; 2],
        a: &[f32],
        b: &[f32],
        c: &mut COut,
    ) {
        let [ars, acs] = strides;
        let bp = b.as_ptr();
        let mut i = i0;
        while i + MR <= i1 {
            let mut j = j0;
            while j + NR <= j1 {
                let mut acc = [[vdupq_n_f32(0.0); 4]; MR];
                for p in 0..k {
                    let bv = [
                        vld1q_f32(bp.add(p * n + j)),
                        vld1q_f32(bp.add(p * n + j + 4)),
                        vld1q_f32(bp.add(p * n + j + 8)),
                        vld1q_f32(bp.add(p * n + j + 12)),
                    ];
                    for (r, accr) in acc.iter_mut().enumerate() {
                        let arp = vdupq_n_f32(*a.get_unchecked((i + r) * ars + p * acs));
                        for (av, &b4) in accr.iter_mut().zip(&bv) {
                            *av = vfmaq_f32(*av, arp, b4);
                        }
                    }
                }
                for (r, accr) in acc.iter().enumerate() {
                    let cp = c.row(i + r, j, j + NR).as_mut_ptr();
                    for (q, &av) in accr.iter().enumerate() {
                        let cq = cp.add(q * 4);
                        vst1q_f32(cq, vaddq_f32(vld1q_f32(cq), av));
                    }
                }
                j += NR;
            }
            if j < j1 {
                for r in 0..MR {
                    row_accum(i + r, j, j1, n, k, ars, acs, a, b, c);
                }
            }
            i += MR;
        }
        while i < i1 {
            row_accum(i, j0, j1, n, k, ars, acs, a, b, c);
            i += 1;
        }
    }

    /// One output row of the broadcast form, columns `[j0, j1)`.
    ///
    /// # Safety
    /// Same contract as [`broadcast`].
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "neon")]
    unsafe fn row_accum(
        i: usize,
        j0: usize,
        j1: usize,
        n: usize,
        k: usize,
        ars: usize,
        acs: usize,
        a: &[f32],
        b: &[f32],
        c: &mut COut,
    ) {
        let bp = b.as_ptr();
        let crow = c.row(i, j0, j1);
        let w = j1 - j0;
        let mut x = 0;
        while x + 4 <= w {
            let mut acc = vdupq_n_f32(0.0);
            for p in 0..k {
                let arp = vdupq_n_f32(*a.get_unchecked(i * ars + p * acs));
                acc = vfmaq_f32(acc, arp, vld1q_f32(bp.add(p * n + j0 + x)));
            }
            let cx = crow.as_mut_ptr().add(x);
            vst1q_f32(cx, vaddq_f32(vld1q_f32(cx), acc));
            x += 4;
        }
        while x < w {
            let mut s = 0.0f32;
            for p in 0..k {
                s += *a.get_unchecked(i * ars + p * acs) * *b.get_unchecked(p * n + j0 + x);
            }
            *crow.get_unchecked_mut(x) += s;
            x += 1;
        }
    }

    /// Fused `C = act(A·B + bias)`; see `gemm::kernel_bias_act`.
    ///
    /// # Safety
    /// Same contract as [`broadcast`]; `bias.len() == n`.
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn bias_act(
        i0: usize,
        i1: usize,
        j0: usize,
        j1: usize,
        n: usize,
        k: usize,
        a: &[f32],
        b: &[f32],
        bias: &[f32],
        relu: bool,
        c: &mut COut,
    ) {
        let bp = b.as_ptr();
        let mut i = i0;
        while i + MR <= i1 {
            let mut j = j0;
            while j + NR <= j1 {
                let binit = [
                    vld1q_f32(bias.as_ptr().add(j)),
                    vld1q_f32(bias.as_ptr().add(j + 4)),
                    vld1q_f32(bias.as_ptr().add(j + 8)),
                    vld1q_f32(bias.as_ptr().add(j + 12)),
                ];
                let mut acc = [binit; MR];
                for p in 0..k {
                    let bv = [
                        vld1q_f32(bp.add(p * n + j)),
                        vld1q_f32(bp.add(p * n + j + 4)),
                        vld1q_f32(bp.add(p * n + j + 8)),
                        vld1q_f32(bp.add(p * n + j + 12)),
                    ];
                    for (r, accr) in acc.iter_mut().enumerate() {
                        let arp = vdupq_n_f32(*a.get_unchecked((i + r) * k + p));
                        for (av, &b4) in accr.iter_mut().zip(&bv) {
                            *av = vfmaq_f32(*av, arp, b4);
                        }
                    }
                }
                let zero = vdupq_n_f32(0.0);
                for (r, accr) in acc.iter().enumerate() {
                    let cp = c.row(i + r, j, j + NR).as_mut_ptr();
                    for (q, &av) in accr.iter().enumerate() {
                        let v = if relu { vmaxq_f32(av, zero) } else { av };
                        vst1q_f32(cp.add(q * 4), v);
                    }
                }
                j += NR;
            }
            if j < j1 {
                for r in 0..MR {
                    row_bias_act(i + r, j, j1, n, k, a, b, bias, relu, c);
                }
            }
            i += MR;
        }
        while i < i1 {
            row_bias_act(i, j0, j1, n, k, a, b, bias, relu, c);
            i += 1;
        }
    }

    /// One output row of the fused form, columns `[j0, j1)`.
    ///
    /// # Safety
    /// Same contract as [`bias_act`].
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "neon")]
    unsafe fn row_bias_act(
        i: usize,
        j0: usize,
        j1: usize,
        n: usize,
        k: usize,
        a: &[f32],
        b: &[f32],
        bias: &[f32],
        relu: bool,
        c: &mut COut,
    ) {
        let bp = b.as_ptr();
        let crow = c.row(i, j0, j1);
        let w = j1 - j0;
        let mut x = 0;
        while x + 4 <= w {
            let mut acc = vld1q_f32(bias.as_ptr().add(j0 + x));
            for p in 0..k {
                let arp = vdupq_n_f32(*a.get_unchecked(i * k + p));
                acc = vfmaq_f32(acc, arp, vld1q_f32(bp.add(p * n + j0 + x)));
            }
            if relu {
                acc = vmaxq_f32(acc, vdupq_n_f32(0.0));
            }
            vst1q_f32(crow.as_mut_ptr().add(x), acc);
            x += 4;
        }
        while x < w {
            let mut s = *bias.get_unchecked(j0 + x);
            for p in 0..k {
                s += *a.get_unchecked(i * k + p) * *b.get_unchecked(p * n + j0 + x);
            }
            *crow.get_unchecked_mut(x) = if relu { s.max(0.0) } else { s };
            x += 1;
        }
    }

    /// Dot-form `C += A·Bᵀ`; see `gemm::kernel_dot`.
    ///
    /// # Safety
    /// Same contract as [`broadcast`] with `b.len() == n·k`.
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn dot(
        i0: usize,
        i1: usize,
        j0: usize,
        j1: usize,
        k: usize,
        a: &[f32],
        b: &[f32],
        c: &mut COut,
    ) {
        for i in i0..i1 {
            let ap = a.as_ptr().add(i * k);
            let crow = c.row(i, j0, j1);
            for (j, cv) in (j0..j1).zip(crow.iter_mut()) {
                *cv += dot1(ap, b.as_ptr().add(j * k), k);
            }
        }
    }

    /// `C += Aᵀ·Bᵀ`; see `gemm::kernel_both_t` and the AVX2 twin for
    /// the stack-staging rationale.
    ///
    /// # Safety
    /// Same contract as [`broadcast`] with `a.len() == k·m`,
    /// `b.len() == n·k`.
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn both_t(
        i0: usize,
        i1: usize,
        j0: usize,
        j1: usize,
        m: usize,
        k: usize,
        a: &[f32],
        b: &[f32],
        c: &mut COut,
    ) {
        let mut buf = [0.0f32; 64];
        for i in i0..i1 {
            let crow = c.row(i, j0, j1);
            let mut p0 = 0;
            while p0 < k {
                let pc = (k - p0).min(buf.len());
                for (t, slot) in buf[..pc].iter_mut().enumerate() {
                    *slot = *a.get_unchecked((p0 + t) * m + i);
                }
                for (j, cv) in (j0..j1).zip(crow.iter_mut()) {
                    *cv += dot1(buf.as_ptr(), b.as_ptr().add(j * k + p0), pc);
                }
                p0 += pc;
            }
        }
    }

    /// Two-accumulator FMA dot product of length `k` at raw pointers.
    ///
    /// # Safety
    /// `x` and `y` must be readable for `k` f32s; neon required.
    #[target_feature(enable = "neon")]
    unsafe fn dot1(x: *const f32, y: *const f32, k: usize) -> f32 {
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        let mut p = 0;
        while p + 8 <= k {
            acc0 = vfmaq_f32(acc0, vld1q_f32(x.add(p)), vld1q_f32(y.add(p)));
            acc1 = vfmaq_f32(acc1, vld1q_f32(x.add(p + 4)), vld1q_f32(y.add(p + 4)));
            p += 8;
        }
        if p + 4 <= k {
            acc0 = vfmaq_f32(acc0, vld1q_f32(x.add(p)), vld1q_f32(y.add(p)));
            p += 4;
        }
        let mut s = vaddvq_f32(vaddq_f32(acc0, acc1));
        while p < k {
            s += *x.add(p) * *y.add(p);
            p += 1;
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_names_are_validated() {
        for good in ["auto", "avx2", "neon", "scalar"] {
            assert!(is_known_request(good), "{good} must parse");
        }
        for bad in ["", "AVX2", "sse", "auto ", "simd"] {
            assert!(!is_known_request(bad), "'{bad}' must be rejected");
        }
    }

    #[test]
    fn resolve_rejects_unknown_and_accepts_scalar() {
        assert!(resolve("bogus").is_err());
        let msg = format!("{}", resolve("bogus").unwrap_err());
        assert!(msg.contains("bogus"), "error must name the value: {msg}");
        assert_eq!(resolve("scalar").unwrap(), Tier::Scalar);
        // `auto` always resolves — to the best available tier.
        let best = resolve("auto").unwrap();
        assert_eq!(best, detect_best());
    }

    #[test]
    fn unavailable_tiers_error_with_a_reason() {
        // Whichever of avx2/neon this build+host lacks must produce a
        // typed error naming why (feature gate, arch, Miri, or CPU).
        for tier in ["avx2", "neon"] {
            match resolve(tier) {
                Ok(t) => assert_eq!(t.name(), tier, "resolve must be faithful"),
                Err(e) => {
                    let msg = format!("{e}");
                    assert!(msg.contains(tier), "error must name the tier: {msg}");
                }
            }
        }
    }

    #[test]
    fn tier_names_round_trip() {
        for t in [Tier::Scalar, Tier::Avx2, Tier::Neon] {
            assert_eq!(tier_from_code(tier_code(t)), t);
            assert!(is_known_request(t.name()));
        }
        assert!(!cpu_features().is_empty());
    }

    #[test]
    fn detection_is_scalar_when_the_feature_is_off() {
        if !cfg!(feature = "simd") || cfg!(miri) {
            assert_eq!(detect_best(), Tier::Scalar);
            assert!(resolve("avx2").is_err() && resolve("neon").is_err());
        }
    }
}
