//! The master⇄worker frame protocol as *data*: explicit typed
//! transition tables for both sides of the `backend=process` exchange,
//! checked at every `send_frame`/`recv_frame` call site.
//!
//! PR 6 implemented the `Hello→Init→Push/Center…→Stop→Done` sequencing
//! implicitly, spread across the two handler loops in
//! [`super::process`]. That made "which message orderings are
//! admissible" — exactly the property Elastic Consistency
//! (arXiv 2001.05918) says these methods' correctness hinges on — a
//! reading-comprehension exercise over two long loops. Here the
//! admissible set is one committed table, [`TRANSITIONS`]; everything
//! not in the table is a *named* rejection ([`ProtocolState::advance`]
//! errors carry the current state and the offending frame), and the
//! exhaustive enumeration test at the bottom proves every
//! (state × direction × [`FrameKind`]) pair is one or the other — no
//! implicit behavior.
//!
//! The two state machines (master side is per worker connection):
//!
//! ```text
//!  master handler                      worker
//!  ==============                      ======
//!  AwaitHello --recv Hello--> SendInit Start --send Hello--> AwaitInit
//!  SendInit --send Init--> Serve       AwaitInit --recv Init--> Local
//!  Serve --recv Push--> Reply          Local --send Push--> AwaitReply
//!  Serve --recv Diverged--> Serve      Local --send Diverged--> Finish
//!  Serve --recv Done--> Closed         Local --send Done--> Done
//!  Reply --send Center--> Serve        AwaitReply --recv Center--> Local
//!  Reply --send Stop--> Serve          AwaitReply --recv Stop--> Finish
//!                                      Finish --send Done--> Done
//!  Closed: terminal                    Done: terminal
//! ```
//!
//! [`super::process`] drives every frame through
//! [`ProtocolState::send`] / [`ProtocolState::recv`], so an
//! out-of-order or unexpected frame — from a buggy refactor or a rogue
//! peer on the socket — is a typed error at the exact exchange that
//! violated the table, not a hang or a silent mis-application.

use super::wire::{recv_frame, send_frame, Frame, FrameKind, WireClock};
use crate::error::Result;
use std::io::{Read, Write};

/// Which endpoint of the exchange this checker guards.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Side {
    /// The parameter-server master (one checker per worker connection).
    Master,
    /// A worker process.
    Worker,
}

/// Whether a frame is being written to or read from the socket.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dir {
    Send,
    Recv,
}

/// Every protocol state of both sides (the sides are disjoint subsets;
/// a checker never crosses between them because every transition's
/// target stays on its side — asserted by the enumeration test).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProtoState {
    // Master side, per worker connection.
    /// Waiting for the worker to announce itself.
    AwaitHello,
    /// Hello absorbed; the init θ must go out before anything else.
    SendInit,
    /// Steady state: waiting for the worker's next frame.
    Serve,
    /// A Push was absorbed; exactly one reply (Center or Stop) is owed.
    Reply,
    /// Done absorbed — terminal; the connection is spent.
    Closed,

    // Worker side.
    /// Nothing sent yet; the Hello announcement must go first.
    Start,
    /// Hello sent; only the master's Init may arrive.
    AwaitInit,
    /// Local-step loop: may Push (exchange), Diverged, or Done (budget
    /// or horizon reached before the next exchange).
    Local,
    /// Push sent; exactly one reply (Center or Stop) may arrive.
    AwaitReply,
    /// Stop received or Diverged sent: the final stats frame is owed.
    Finish,
    /// Done sent — terminal; nothing further may cross the socket.
    Done,
}

impl ProtoState {
    /// Every state, for exhaustive enumeration (tests, fuzzing).
    pub const ALL: [ProtoState; 11] = [
        ProtoState::AwaitHello,
        ProtoState::SendInit,
        ProtoState::Serve,
        ProtoState::Reply,
        ProtoState::Closed,
        ProtoState::Start,
        ProtoState::AwaitInit,
        ProtoState::Local,
        ProtoState::AwaitReply,
        ProtoState::Finish,
        ProtoState::Done,
    ];

    /// Terminal states accept no transition in either direction.
    pub fn is_terminal(self) -> bool {
        matches!(self, ProtoState::Closed | ProtoState::Done)
    }

    fn side(self) -> Side {
        match self {
            ProtoState::AwaitHello
            | ProtoState::SendInit
            | ProtoState::Serve
            | ProtoState::Reply
            | ProtoState::Closed => Side::Master,
            ProtoState::Start
            | ProtoState::AwaitInit
            | ProtoState::Local
            | ProtoState::AwaitReply
            | ProtoState::Finish
            | ProtoState::Done => Side::Worker,
        }
    }
}

/// THE protocol: the complete set of admissible
/// (state, direction, frame) → state transitions. Anything not listed
/// here is a typed [`ProtocolState::advance`] error; the enumeration
/// test pins that the table is exactly this set and that every absent
/// combination is a named rejection.
pub const TRANSITIONS: &[(ProtoState, Dir, FrameKind, ProtoState)] = &[
    // Master side (per connection).
    (ProtoState::AwaitHello, Dir::Recv, FrameKind::Hello, ProtoState::SendInit),
    (ProtoState::SendInit, Dir::Send, FrameKind::Init, ProtoState::Serve),
    (ProtoState::Serve, Dir::Recv, FrameKind::Push, ProtoState::Reply),
    (ProtoState::Serve, Dir::Recv, FrameKind::Diverged, ProtoState::Serve),
    (ProtoState::Serve, Dir::Recv, FrameKind::Done, ProtoState::Closed),
    (ProtoState::Reply, Dir::Send, FrameKind::Center, ProtoState::Serve),
    (ProtoState::Reply, Dir::Send, FrameKind::Stop, ProtoState::Serve),
    // Worker side.
    (ProtoState::Start, Dir::Send, FrameKind::Hello, ProtoState::AwaitInit),
    (ProtoState::AwaitInit, Dir::Recv, FrameKind::Init, ProtoState::Local),
    (ProtoState::Local, Dir::Send, FrameKind::Push, ProtoState::AwaitReply),
    (ProtoState::Local, Dir::Send, FrameKind::Diverged, ProtoState::Finish),
    (ProtoState::Local, Dir::Send, FrameKind::Done, ProtoState::Done),
    (ProtoState::AwaitReply, Dir::Recv, FrameKind::Center, ProtoState::Local),
    (ProtoState::AwaitReply, Dir::Recv, FrameKind::Stop, ProtoState::Finish),
    (ProtoState::Finish, Dir::Send, FrameKind::Done, ProtoState::Done),
];

/// A live conformance checker: owns the current state of one endpoint
/// and refuses — with an error naming the state and the frame — any
/// exchange the table does not admit.
#[derive(Clone, Debug)]
pub struct ProtocolState {
    side: Side,
    state: ProtoState,
}

impl ProtocolState {
    /// A master-side checker for one freshly accepted connection.
    pub fn master() -> ProtocolState {
        ProtocolState { side: Side::Master, state: ProtoState::AwaitHello }
    }

    /// A worker-side checker for one freshly dialed connection.
    pub fn worker() -> ProtocolState {
        ProtocolState { side: Side::Worker, state: ProtoState::Start }
    }

    pub fn state(&self) -> ProtoState {
        self.state
    }

    pub fn side(&self) -> Side {
        self.side
    }

    /// The exchange is complete (Done crossed the socket).
    pub fn is_terminal(&self) -> bool {
        self.state.is_terminal()
    }

    /// Render the admissible exchanges out of `state` ("recv Push,
    /// recv Diverged, recv Done", or "nothing (terminal state)") for
    /// rejection messages.
    pub fn expected_from(state: ProtoState) -> String {
        let mut parts = Vec::new();
        for &(s, d, k, _) in TRANSITIONS {
            if s == state {
                parts.push(format!(
                    "{} {k:?}",
                    match d {
                        Dir::Send => "send",
                        Dir::Recv => "recv",
                    }
                ));
            }
        }
        if parts.is_empty() {
            "nothing (terminal state)".to_string()
        } else {
            parts.join(", ")
        }
    }

    /// Drive one exchange through the table: `Ok` advances the state,
    /// anything else is a typed rejection naming the current state,
    /// the direction, the offending frame kind, and what the table
    /// would have admitted. Rejections do NOT advance the state — the
    /// checker stays honest for error-path reporting.
    pub fn advance(&mut self, dir: Dir, kind: FrameKind) -> Result<()> {
        for &(s, d, k, next) in TRANSITIONS {
            if s == self.state && d == dir && k == kind {
                self.state = next;
                return Ok(());
            }
        }
        Err(crate::err!(
            "protocol violation ({:?} side): cannot {} {kind:?} in state {:?} — admissible: {}",
            self.side,
            match dir {
                Dir::Send => "send",
                Dir::Recv => "recv",
            },
            self.state,
            Self::expected_from(self.state)
        ))
    }

    /// Checked send: the frame is validated against the table BEFORE
    /// any bytes go out, so this endpoint can never put an
    /// out-of-order frame on the wire.
    pub fn send<W: Write>(&mut self, w: &mut W, frame: &Frame, ck: &mut WireClock) -> Result<()> {
        self.advance(Dir::Send, frame.kind)?;
        send_frame(w, frame, ck)
    }

    /// Checked receive: the frame is decoded (all of `recv_frame`'s
    /// wire-level validation applies), then validated against the
    /// table — an unexpected kind from a conforming-wire but
    /// nonconforming-protocol peer is a typed error here.
    pub fn recv<R: Read>(&mut self, r: &mut R, ck: &mut WireClock) -> Result<Frame> {
        let frame = recv_frame(r, ck)?;
        self.advance(Dir::Recv, frame.kind)?;
        Ok(frame)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DIRS: [Dir; 2] = [Dir::Send, Dir::Recv];

    /// THE conformance test the tentpole asks for: every
    /// (state × direction × FrameKind) triple — 11 × 2 × 7 = 154 of
    /// them — is either an admitted transition (advancing to the
    /// table's target) or a rejection whose message names the state
    /// and the frame. Nothing is implicit.
    #[test]
    fn every_state_frame_pair_is_admitted_or_named_rejected() {
        let mut admitted = 0;
        let mut rejected = 0;
        for &state in &ProtoState::ALL {
            for &dir in &DIRS {
                for &kind in &FrameKind::ALL {
                    let hit = TRANSITIONS
                        .iter()
                        .find(|&&(s, d, k, _)| s == state && d == dir && k == kind);
                    let mut p = ProtocolState { side: state.side(), state };
                    match hit {
                        Some(&(_, _, _, next)) => {
                            p.advance(dir, kind).unwrap_or_else(|e| {
                                panic!("table admits {state:?}/{dir:?}/{kind:?} but advance refused: {e}")
                            });
                            assert_eq!(p.state(), next, "{state:?}/{dir:?}/{kind:?}");
                            admitted += 1;
                        }
                        None => {
                            let e = p.advance(dir, kind).expect_err(&format!(
                                "{state:?}/{dir:?}/{kind:?} is not in the table but was admitted"
                            ));
                            let msg = format!("{e}");
                            assert!(
                                msg.contains(&format!("{state:?}")),
                                "rejection must name the state: {msg}"
                            );
                            assert!(
                                msg.contains(&format!("{kind:?}")),
                                "rejection must name the frame: {msg}"
                            );
                            assert_eq!(p.state(), state, "a rejection must not advance the state");
                            rejected += 1;
                        }
                    }
                }
            }
        }
        assert_eq!(admitted, TRANSITIONS.len(), "duplicate or dead table rows");
        assert_eq!(admitted + rejected, ProtoState::ALL.len() * 2 * FrameKind::ALL.len());
    }

    /// The table is well-formed data: no duplicate (state, dir, kind)
    /// keys (the first match wins in `advance`, so a duplicate would
    /// be dead — or worse, a divergent — row), and every transition
    /// stays on its own side of the socket.
    #[test]
    fn table_has_unique_keys_and_never_crosses_sides() {
        for (i, &(s1, d1, k1, n1)) in TRANSITIONS.iter().enumerate() {
            assert_eq!(s1.side(), n1.side(), "{s1:?} -> {n1:?} crosses sides");
            for &(s2, d2, k2, _) in &TRANSITIONS[i + 1..] {
                assert!(
                    !(s1 == s2 && d1 == d2 && k1 == k2),
                    "duplicate table key {s1:?}/{d1:?}/{k1:?}"
                );
            }
        }
    }

    /// Terminal states admit nothing, and both sides can actually
    /// reach their terminal state through the table.
    #[test]
    fn terminal_states_are_terminal_and_reachable() {
        for &state in &ProtoState::ALL {
            if state.is_terminal() {
                assert!(
                    !TRANSITIONS.iter().any(|&(s, _, _, _)| s == state),
                    "{state:?} is terminal but has outgoing transitions"
                );
                assert!(
                    TRANSITIONS.iter().any(|&(_, _, _, n)| n == state),
                    "{state:?} is terminal but unreachable"
                );
            }
        }
    }

    /// A conforming happy-path session on both sides, frame by frame.
    #[test]
    fn happy_path_sessions_conform() {
        // Master: Hello, Init, (Push, Center) ×2, Push, Stop, Done.
        let mut m = ProtocolState::master();
        m.advance(Dir::Recv, FrameKind::Hello).unwrap();
        m.advance(Dir::Send, FrameKind::Init).unwrap();
        for _ in 0..2 {
            m.advance(Dir::Recv, FrameKind::Push).unwrap();
            m.advance(Dir::Send, FrameKind::Center).unwrap();
        }
        m.advance(Dir::Recv, FrameKind::Push).unwrap();
        m.advance(Dir::Send, FrameKind::Stop).unwrap();
        m.advance(Dir::Recv, FrameKind::Done).unwrap();
        assert!(m.is_terminal());

        // Worker mirror image, with a divergence instead of a Stop.
        let mut w = ProtocolState::worker();
        w.advance(Dir::Send, FrameKind::Hello).unwrap();
        w.advance(Dir::Recv, FrameKind::Init).unwrap();
        w.advance(Dir::Send, FrameKind::Push).unwrap();
        w.advance(Dir::Recv, FrameKind::Center).unwrap();
        w.advance(Dir::Send, FrameKind::Diverged).unwrap();
        w.advance(Dir::Send, FrameKind::Done).unwrap();
        assert!(w.is_terminal());
    }

    /// The rogue-peer case the integration test drives over a real
    /// socket: Push before Hello is a rejection naming both.
    #[test]
    fn push_before_hello_is_rejected_by_name() {
        let mut m = ProtocolState::master();
        let e = m.advance(Dir::Recv, FrameKind::Push).unwrap_err();
        let msg = format!("{e}");
        assert!(msg.contains("AwaitHello") && msg.contains("Push"), "{msg}");
        assert!(msg.contains("Hello"), "should say what was admissible: {msg}");
    }

    /// Checked send refuses BEFORE bytes hit the wire: the buffer
    /// stays empty on a table violation.
    #[test]
    fn checked_send_refuses_before_writing() {
        let mut buf = Vec::new();
        let mut ck = WireClock::default();
        let mut m = ProtocolState::master();
        let f = Frame::new(FrameKind::Center, 0, 0, vec![1.0]);
        let e = m.send(&mut buf, &f, &mut ck).unwrap_err();
        assert!(format!("{e}").contains("AwaitHello"), "{e}");
        assert!(buf.is_empty(), "no bytes may leave on a protocol violation");
        assert_eq!(ck.frames, 0);
    }

    /// Checked recv decodes then validates: a wire-valid but
    /// protocol-invalid frame is a protocol error, not a wire error.
    #[test]
    fn checked_recv_rejects_wire_valid_but_out_of_order_frames() {
        let mut buf = Vec::new();
        let mut ck = WireClock::default();
        send_frame(&mut buf, &Frame::new(FrameKind::Push, 1, 5, vec![0.5]), &mut ck).unwrap();
        let mut m = ProtocolState::master();
        let e = m.recv(&mut buf.as_slice(), &mut ck).unwrap_err();
        let msg = format!("{e}");
        assert!(msg.contains("protocol violation"), "{msg}");
        assert!(msg.contains("Push") && msg.contains("AwaitHello"), "{msg}");
    }
}
