//! `key=value` CLI argument parsing (the offline crate set has no clap).
//!
//! Grammar: positional words first, then any number of `key=value`
//! pairs; `--key=value` and `--flag` are also accepted.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub kv: BTreeMap<String, String>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(it: I) -> Args {
        let mut a = Args::default();
        for raw in it {
            let s = raw.trim_start_matches("--");
            if let Some(eq) = s.find('=') {
                a.kv.insert(s[..eq].to_string(), s[eq + 1..].to_string());
            } else if raw.starts_with("--") {
                a.kv.insert(s.to_string(), "true".to_string());
            } else {
                a.positional.push(raw);
            }
        }
        a
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.kv.get(key).map(|s| s.as_str())
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_f32(&self, key: &str, default: f32) -> f32 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_u32(&self, key: &str, default: u32) -> u32 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_bool(&self, key: &str, default: bool) -> bool {
        self.get(key)
            .map(|s| matches!(s, "true" | "1" | "yes"))
            .unwrap_or(default)
    }

    pub fn get_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(words: &[&str]) -> Args {
        Args::parse(words.iter().map(|s| s.to_string()))
    }

    #[test]
    fn positional_and_kv() {
        let a = parse(&["figure", "fig3.1", "p=16", "--eta=0.05", "--quick"]);
        assert_eq!(a.positional, vec!["figure", "fig3.1"]);
        assert_eq!(a.get_usize("p", 1), 16);
        assert!((a.get_f64("eta", 0.0) - 0.05).abs() < 1e-12);
        assert!(a.get_bool("quick", false));
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]);
        assert_eq!(a.get_usize("p", 4), 4);
        assert_eq!(a.get_str("method", "easgd"), "easgd");
        assert!(!a.get_bool("quick", false));
    }

    #[test]
    fn malformed_values_fall_back() {
        let a = parse(&["p=abc"]);
        assert_eq!(a.get_usize("p", 7), 7);
    }
}
