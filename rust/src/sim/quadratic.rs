//! Discrete-time simulators for the additive-noise quadratic model
//! (§3.1.1 / §5.1): noisy gradient g(x) = h·x − ξ, ξ ~ N(0, σ²).
//!
//! These are the empirical counterparts of the closed forms in
//! [`super::moments`]; Figs 5.3 and 5.7 are direct plots of
//! [`easgd_trajectory`], and the tests cross-validate simulator moments
//! against Lemma 3.1.1 / Eq 5.7.

use crate::rng::Rng;

/// Model constants shared by every simulator in this module.
#[derive(Clone, Copy, Debug)]
pub struct Quadratic {
    pub h: f64,
    pub sigma: f64,
}

impl Quadratic {
    #[inline]
    fn noisy_grad(&self, x: f64, rng: &mut Rng) -> f64 {
        self.h * x - rng.normal(0.0, self.sigma)
    }
}

/// Plain SGD from x0 for t steps; returns the trajectory x_0..x_t.
pub fn sgd_trajectory(m: Quadratic, eta: f64, x0: f64, t: usize, rng: &mut Rng) -> Vec<f64> {
    let mut xs = Vec::with_capacity(t + 1);
    let mut x = x0;
    xs.push(x);
    for _ in 0..t {
        x -= eta * m.noisy_grad(x, rng);
        xs.push(x);
    }
    xs
}

/// Mini-batch SGD: the batch of size p averages p independent noises.
pub fn minibatch_sgd_trajectory(
    m: Quadratic,
    eta: f64,
    p: usize,
    x0: f64,
    t: usize,
    rng: &mut Rng,
) -> Vec<f64> {
    let eff = Quadratic { h: m.h, sigma: m.sigma / (p as f64).sqrt() };
    sgd_trajectory(eff, eta, x0, t, rng)
}

/// Nesterov momentum SGD (Eq 5.4): v' = δv − η(h(x+δv) − ξ); x' = x + v'.
pub fn msgd_trajectory(
    m: Quadratic,
    eta: f64,
    delta: f64,
    x0: f64,
    t: usize,
    rng: &mut Rng,
) -> Vec<f64> {
    let mut xs = Vec::with_capacity(t + 1);
    let (mut x, mut v) = (x0, 0.0);
    xs.push(x);
    for _ in 0..t {
        v = delta * v - eta * m.noisy_grad(x + delta * v, rng);
        x += v;
        xs.push(x);
    }
    xs
}

/// State of a synchronous EASGD run (Eq 5.9).
#[derive(Clone, Debug)]
pub struct EasgdState {
    pub workers: Vec<f64>,
    pub center: f64,
}

/// Synchronous EASGD (Eq 5.9): every step each worker does a noisy
/// gradient step plus the elastic pull; the center moves by
/// β · (spatial mean − center). Returns the center trajectory x̃_0..x̃_t.
pub fn easgd_trajectory(
    m: Quadratic,
    eta: f64,
    alpha: f64,
    beta: f64,
    p: usize,
    x0: f64,
    t: usize,
    rng: &mut Rng,
) -> Vec<f64> {
    let mut st = EasgdState { workers: vec![x0; p], center: x0 };
    let mut out = Vec::with_capacity(t + 1);
    out.push(st.center);
    for _ in 0..t {
        let mean: f64 = st.workers.iter().sum::<f64>() / p as f64;
        for w in &mut st.workers {
            let g = m.noisy_grad(*w, rng);
            *w = *w - eta * g - alpha * (*w - st.center);
        }
        st.center += beta * (mean - st.center);
        out.push(st.center);
    }
    out
}

/// Synchronous EAMSGD: Nesterov local steps + elastic coupling (§2.3).
pub fn eamsgd_trajectory(
    m: Quadratic,
    eta: f64,
    alpha: f64,
    beta: f64,
    delta: f64,
    p: usize,
    x0: f64,
    t: usize,
    rng: &mut Rng,
) -> Vec<f64> {
    let mut xs = vec![x0; p];
    let mut vs = vec![0.0; p];
    let mut center = x0;
    let mut out = Vec::with_capacity(t + 1);
    out.push(center);
    for _ in 0..t {
        let mean: f64 = xs.iter().sum::<f64>() / p as f64;
        for i in 0..p {
            let g = m.noisy_grad(xs[i] + delta * vs[i], rng);
            vs[i] = delta * vs[i] - eta * g;
            xs[i] = xs[i] + vs[i] - alpha * (xs[i] - center);
        }
        center += beta * (mean - center);
        out.push(center);
    }
    out
}

/// Time-averaged (Polyak–Ruppert style) double averaging sequence
/// z_{t+1} = mean of x̃_0..x̃_t (Eq 3.13), whose weak limit is
/// N(0, σ²/(p h²)) by Lemma 3.1.2.
pub fn double_average(center_traj: &[f64]) -> Vec<f64> {
    let mut out = Vec::with_capacity(center_traj.len());
    let mut acc = 0.0;
    for (k, &x) in center_traj.iter().enumerate() {
        acc += x;
        out.push(acc / (k + 1) as f64);
    }
    out
}

/// Empirical second moment of the trajectory tail (last `tail` points
/// across `reps` independent runs) — used to validate asymptotics.
pub fn empirical_second_moment<F>(mut run: F, reps: usize, tail: usize) -> f64
where
    F: FnMut(usize) -> Vec<f64>,
{
    let mut acc = 0.0;
    let mut n = 0usize;
    for r in 0..reps {
        let tr = run(r);
        for &x in tr.iter().rev().take(tail) {
            acc += x * x;
            n += 1;
        }
    }
    acc / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::moments;

    const M: Quadratic = Quadratic { h: 1.0, sigma: 0.1 };

    #[test]
    fn sgd_converges_to_noise_ball() {
        let mut rng = Rng::new(1);
        let tr = sgd_trajectory(M, 0.1, 5.0, 2000, &mut rng);
        let tail: f64 = tr.iter().rev().take(100).map(|x| x * x).sum::<f64>() / 100.0;
        // Asymptotic variance η²σ²/(1−(1−ηh)²) ≈ 5.26e-4.
        assert!(tail < 5e-3, "tail second moment {tail}");
        assert!(tr[0] == 5.0 && tr.last().unwrap().abs() < 1.0);
    }

    #[test]
    fn sgd_asymptotic_variance_matches_closed_form() {
        let eta = 0.2;
        let want = eta * eta * M.sigma * M.sigma / (1.0 - (1.0 - eta * M.h).powi(2));
        let got = empirical_second_moment(
            |r| sgd_trajectory(M, eta, 0.0, 4000, &mut Rng::new(100 + r as u64)),
            40,
            500,
        );
        assert!((got - want).abs() / want < 0.15, "{got} vs {want}");
    }

    #[test]
    fn minibatch_reduces_variance_by_p() {
        let eta = 0.2;
        let v1 = empirical_second_moment(
            |r| minibatch_sgd_trajectory(M, eta, 1, 0.0, 3000, &mut Rng::new(r as u64)),
            30,
            400,
        );
        let v8 = empirical_second_moment(
            |r| minibatch_sgd_trajectory(M, eta, 8, 0.0, 3000, &mut Rng::new(r as u64)),
            30,
            400,
        );
        let ratio = v1 / v8;
        assert!((ratio - 8.0).abs() < 2.0, "variance ratio {ratio}");
    }

    #[test]
    fn msgd_asymptotic_variance_matches_eq_5_7() {
        let (eta, delta) = (0.2, 0.5);
        let (_, _, x2_units) = moments::msgd_asymptotic(eta * M.h, delta);
        let want = x2_units * eta * eta * M.sigma * M.sigma;
        let got = empirical_second_moment(
            |r| msgd_trajectory(M, eta, delta, 0.0, 4000, &mut Rng::new(7 + r as u64)),
            40,
            500,
        );
        assert!((got - want).abs() / want < 0.2, "{got} vs {want}");
    }

    #[test]
    fn easgd_center_variance_matches_lemma_3_1_1() {
        let (eta, beta, p) = (0.1, 0.5, 4usize);
        let alpha = beta / p as f64;
        let model = moments::QuadraticModel { h: M.h, sigma: M.sigma, p };
        let want = moments::center_mse_infinite(&model, eta, beta);
        let got = empirical_second_moment(
            |r| easgd_trajectory(M, eta, alpha, beta, p, 0.0, 4000, &mut Rng::new(31 + r as u64)),
            40,
            500,
        );
        assert!((got - want).abs() / want < 0.25, "{got} vs {want}");
    }

    #[test]
    fn easgd_center_less_noisy_than_single_sgd() {
        let (eta, beta, p) = (0.1, 0.5, 16usize);
        let v_center = empirical_second_moment(
            |r| easgd_trajectory(M, eta, beta / p as f64, beta, p, 0.0, 3000,
                                 &mut Rng::new(r as u64)),
            20,
            400,
        );
        let v_sgd = empirical_second_moment(
            |r| sgd_trajectory(M, eta, 0.0, 3000, &mut Rng::new(r as u64)),
            20,
            400,
        );
        assert!(v_center < v_sgd / 3.0, "{v_center} vs {v_sgd}");
    }

    #[test]
    fn fig_5_3_reduced_optimal_alpha_diverges_at_small_eta() {
        // The thesis' cautionary tale: the 'optimal' α from the reduced
        // system (Eq 5.17) ignores the extra eigenvalue 1−α−η_h and the
        // simulation blows up at η=0.1 while α=β/p stays stable.
        let (eta, beta, p) = (0.1, 0.9, 4usize);
        let a_opt = moments::easgd_optimal_alpha_reduced(eta * M.h, beta);
        let mut rng = Rng::new(5);
        let tr = easgd_trajectory(M, eta, a_opt, beta, p, 1.0, 400, &mut rng);
        let last = tr.last().unwrap().abs();
        assert!(last > 1e3 || last.is_nan(), "expected divergence, got {last}");
        let tr2 = easgd_trajectory(M, eta, beta / p as f64, beta, p, 1.0, 400,
                                   &mut Rng::new(5));
        assert!(tr2.last().unwrap().abs() < 1.0);
    }

    #[test]
    fn fig_5_7_optimal_alpha_wins_at_large_eta() {
        // At η=1.5 (β < η_h) the negative optimal α is genuinely better:
        // both runs are stable and optimal-α contracts faster.
        let (eta, beta, p) = (1.5, 0.9, 4usize);
        let a_opt = moments::easgd_optimal_alpha_original(eta * M.h, beta);
        assert!(a_opt < 0.0);
        let m2 = |alpha: f64| {
            empirical_second_moment(
                |r| easgd_trajectory(M, eta, alpha, beta, p, 1.0, 60, &mut Rng::new(r as u64)),
                50,
                1,
            )
        };
        // Distance to optimum after 60 steps: optimal α should be ahead.
        let d_opt = m2(a_opt);
        let d_elastic = m2(beta / p as f64);
        assert!(d_opt < d_elastic, "{d_opt} vs {d_elastic}");
    }

    #[test]
    fn double_average_approaches_fisher_bound() {
        let (eta, beta, p) = (0.1, 0.5, 4usize);
        let t = 20_000;
        let mut acc = 0.0;
        let reps = 30;
        for r in 0..reps {
            let tr = easgd_trajectory(M, eta, beta / p as f64, beta, p, 0.0, t,
                                      &mut Rng::new(900 + r));
            let z = double_average(&tr);
            let zt = *z.last().unwrap();
            acc += (t as f64) * zt * zt;
        }
        let got = acc / reps as f64;
        let want = M.sigma * M.sigma / (p as f64 * M.h * M.h); // Lemma 3.1.2
        assert!((got - want).abs() / want < 0.5, "{got} vs {want}");
    }

    #[test]
    fn eamsgd_stable_at_paper_settings() {
        let mut rng = Rng::new(17);
        let tr = eamsgd_trajectory(M, 0.05, 0.9 / 4.0, 0.9, 0.99, 4, 1.0, 3000, &mut rng);
        assert!(tr.last().unwrap().abs() < 1.0);
        assert!(tr.iter().all(|x| x.is_finite()));
    }
}
