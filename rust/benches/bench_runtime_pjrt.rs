//! PJRT runtime benchmark: the per-step cost of the three-layer stack —
//! train_step (fwd+bwd through the AOT transformer), eval_step, literal
//! packing, and the update kernels. These rows bound the end-to-end
//! example's throughput and feed EXPERIMENTS.md §Perf (L2/L3).

#[cfg(feature = "pjrt")]
use elastic_train::figures::benchkit::{bench, fmt_ns};
#[cfg(feature = "pjrt")]
use elastic_train::model::flat;
#[cfg(feature = "pjrt")]
use elastic_train::rng::Rng;

#[cfg(not(feature = "pjrt"))]
fn main() {
    println!("built without the pjrt feature — rebuild with --features pjrt; skipping");
}

#[cfg(feature = "pjrt")]
fn main() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("artifacts missing — run `make artifacts` first; skipping");
        return;
    }
    let m = elastic_train::runtime::PjrtModel::load(&dir).unwrap();
    let n = m.n_params();
    let d = m.artifacts.dims;
    println!(
        "preset={} params={} batch={} seq={}",
        m.artifacts.preset, n, d.batch, d.seq_len
    );

    let theta = m.artifacts.init_params().unwrap();
    let mut corpus = elastic_train::data::MarkovCorpus::new(d.vocab, 0.05, 1);
    let (x, y) = corpus.batch(d.batch, d.seq_len);
    let mut g = vec![0.0f32; n];

    let ts = bench("pjrt/train_step(fwd+bwd)", 300.0, 5, || {
        std::hint::black_box(m.train_step(&theta, &x, &y, &mut g).unwrap());
    });
    let tokens = (d.batch * d.seq_len) as f64;
    // ~6·N FLOPs per token for fwd+bwd of an N-param transformer.
    let flops = 6.0 * n as f64 * tokens;
    println!(
        "  -> {} / step  |  {:.1} ktok/s  |  ~{:.2} GFLOP/s effective",
        fmt_ns(ts.median_ns),
        tokens / (ts.median_ns * 1e-9) / 1e3,
        flops / ts.median_ns
    );

    let es = bench("pjrt/eval_step(fwd)", 200.0, 5, || {
        std::hint::black_box(m.eval_step(&theta, &x, &y).unwrap());
    });
    println!("  -> fwd:bwd ratio {:.2}", ts.median_ns / es.median_ns);

    let mut rng = Rng::new(2);
    let mut mk = || {
        let mut v = vec![0.0f32; n];
        rng.fill_gaussian_f32(&mut v, 0.5);
        v
    };
    let (mut xv, mut vv, gv, cv) = (mk(), mk(), mk(), mk());
    let ks = bench("pjrt/fused_update_kernel", 100.0, 5, || {
        let _ = m
            .fused_step_kernel(&mut xv, &mut vv, &gv, &cv, 1e-4, 1e-3, 0.9, true)
            .unwrap();
    });
    let (mut xn, mut vn, mut dn) = (mk(), mk(), vec![0.0f32; n]);
    let ns = bench("native/fused_update", 50.0, 7, || {
        flat::elastic_pull(&mut xn, &cv, &mut dn, 1e-3);
        flat::nesterov_step(&mut xn, &mut vn, &gv, 1e-4, 0.9);
    });
    println!(
        "  -> update is {:.3}% of train_step natively ({}), {:.1}% via PJRT ({})",
        100.0 * ns.median_ns / ts.median_ns,
        fmt_ns(ns.median_ns),
        100.0 * ks.median_ns / ts.median_ns,
        fmt_ns(ks.median_ns),
    );
}
