//! Loud-failure suite: the failure modes that used to be silent (a
//! panicking worker poisoning the center locks while the survivors
//! burned the step budget, `unwrap_or(default)` config parsing, empty
//! curves panicking in accessors) must now surface as prompt,
//! descriptive errors.

use elastic_train::cluster::{CostModel, RunResult};
use elastic_train::config::{Args, ExperimentConfig};
use elastic_train::coordinator::{
    run_threaded, run_with_backend_topology, Backend, DriverConfig, EvalStats, GradOracle,
    Method, QuadraticOracle, Topology,
};
use elastic_train::rng::Rng;
use std::time::Instant;

/// A quadratic-like oracle that panics after `panic_after` gradient
/// calls (None = never) — the synthetic stand-in for a worker dying
/// mid-run (OOM, a bug in the model code, a poisoned batch).
struct PanickingOracle {
    n: usize,
    calls: u64,
    panic_after: Option<u64>,
}

impl PanickingOracle {
    fn family(n: usize, p: usize, victim: usize, after: u64) -> Vec<PanickingOracle> {
        (0..p)
            .map(|i| PanickingOracle {
                n,
                calls: 0,
                panic_after: (i == victim).then_some(after),
            })
            .collect()
    }
}

impl GradOracle for PanickingOracle {
    fn n_params(&self) -> usize {
        self.n
    }

    fn init_params(&self) -> Vec<f32> {
        vec![0.0; self.n]
    }

    fn grad(&mut self, theta: &[f32], _rng: &mut Rng, out: &mut [f32]) -> f32 {
        self.calls += 1;
        if let Some(k) = self.panic_after {
            if self.calls > k {
                panic!("synthetic oracle failure after {k} calls");
            }
        }
        let mut loss = 0.0f32;
        for (o, t) in out.iter_mut().zip(theta) {
            let d = t - 1.0;
            *o = d;
            loss += 0.5 * d * d;
        }
        loss / self.n as f32
    }

    fn eval(&mut self, theta: &[f32]) -> EvalStats {
        let loss = theta.iter().map(|t| 0.5 * (t - 1.0) as f64 * (t - 1.0) as f64).sum::<f64>()
            / self.n as f64;
        EvalStats { train_loss: loss, test_loss: loss, test_error: 0.0 }
    }
}

fn cfg(method: Method, max_steps: u64) -> DriverConfig {
    DriverConfig {
        eta: 0.05,
        method,
        cost: CostModel::cifar_like(64),
        horizon: 30.0, // the pre-fix failure mode ran to THIS wall
        eval_every: 1e6,
        seed: 7,
        max_steps,
        lr_decay_gamma: 0.0,
    }
}

/// A worker panicking on the sharded-lock backend (EASGD) surfaces as
/// a descriptive error naming the worker and the panic message — and
/// returns promptly, instead of letting the survivors burn the whole
/// step budget against poisoned center locks.
#[test]
fn panicking_worker_on_sharded_center_is_a_prompt_named_error() {
    let mut oracles = PanickingOracle::family(64, 3, 1, 10);
    let t0 = Instant::now();
    let e = run_threaded(&mut oracles, &cfg(Method::easgd_default(3, 2), u64::MAX / 2), 4)
        .unwrap_err();
    let elapsed = t0.elapsed().as_secs_f64();
    let msg = format!("{e}");
    assert!(msg.contains("worker 1 died mid-run"), "{msg}");
    assert!(msg.contains("synthetic oracle failure"), "{msg}");
    // Prompt: nowhere near the 30 s horizon the survivors used to burn.
    assert!(elapsed < 15.0, "took {elapsed:.1}s to report a dead worker");
}

/// Same contract on the master-actor backend (MDOWNPOUR): the panic is
/// caught in the worker, the actor's receive loop drains cleanly, and
/// the run reports the worker death instead of hanging or resuming the
/// unwind.
#[test]
fn panicking_worker_on_master_actor_is_a_prompt_named_error() {
    let mut oracles = PanickingOracle::family(64, 3, 2, 10);
    let mut c = cfg(Method::MDownpour { delta: 0.9 }, u64::MAX / 2);
    c.eta = 0.01;
    let t0 = Instant::now();
    let e = run_threaded(&mut oracles, &c, 4).unwrap_err();
    let elapsed = t0.elapsed().as_secs_f64();
    let msg = format!("{e}");
    assert!(msg.contains("worker 2 died mid-run"), "{msg}");
    assert!(msg.contains("synthetic oracle failure"), "{msg}");
    assert!(elapsed < 15.0, "took {elapsed:.1}s to report a dead worker");
}

/// A run where NO worker panics still succeeds through the same
/// machinery (the catch_unwind wrapper is transparent on the happy
/// path).
#[test]
fn non_panicking_run_is_unaffected_by_the_panic_guard() {
    let mut oracles = PanickingOracle::family(64, 3, 0, u64::MAX);
    let r = run_threaded(&mut oracles, &cfg(Method::easgd_default(3, 2), 600), 4).unwrap();
    assert!(!r.diverged);
    assert_eq!(r.total_steps, 600);
    assert!(r.last_point().unwrap().train_loss < r.first_point().unwrap().train_loss);
}

/// Strict config parsing end to end: a malformed CLI override is a
/// named error at both the `Args` getter and `ExperimentConfig` layers
/// (it used to be silently replaced by the default).
#[test]
fn malformed_cli_values_are_named_errors_not_silent_defaults() {
    let args = Args::parse(["tau=0.5".to_string(), "p=abc".to_string()]);
    assert!(args.get_u32("tau", 1).is_err());
    assert!(args.get_usize("p", 4).is_err());

    let mut cfg = ExperimentConfig::default();
    let e = cfg.apply_args(&args).unwrap_err();
    let msg = format!("{e}");
    // BTreeMap order: "p" applies (and fails) before "tau".
    assert!(msg.contains('p') && msg.contains("abc"), "{msg}");
    // The failed overrides left the config untouched.
    assert_eq!(cfg.tau, 10);
    assert_eq!(cfg.p, 4);
}

/// Degenerate time axes are config-time errors naming the field — on
/// every backend path through `run_with_backend_topology` — instead of
/// empty-curve panics deep in a run.
#[test]
fn degenerate_driver_configs_are_validated_before_running() {
    for backend in [Backend::Sim, Backend::Thread] {
        let mut bad = cfg(Method::easgd_default(2, 1), 100);
        bad.horizon = f64::INFINITY;
        let mut oracles = QuadraticOracle::family(16, 1.0, 0.0, 1.0, 0.0, 2);
        let e = run_with_backend_topology(backend, &mut oracles, &bad, &Topology::Star)
            .unwrap_err();
        assert!(format!("{e}").contains("horizon"), "{backend:?}: {e}");

        let mut bad = cfg(Method::easgd_default(2, 1), 100);
        bad.eval_every = 0.0;
        let mut oracles = QuadraticOracle::family(16, 1.0, 0.0, 1.0, 0.0, 2);
        let e = run_with_backend_topology(backend, &mut oracles, &bad, &Topology::Star)
            .unwrap_err();
        assert!(format!("{e}").contains("eval_every"), "{backend:?}: {e}");
    }
}

/// Empty-curve regression: every `RunResult` accessor is total — the
/// figure harness used to `curve.first().unwrap()` and crash on runs
/// whose horizon produced no snapshots.
#[test]
fn empty_curve_accessors_are_total() {
    let r = RunResult::default();
    assert!(r.first_point().is_none());
    assert!(r.last_point().is_none());
    assert!(r.first_train_loss().is_nan());
    assert!(r.final_train_loss().is_nan());
    assert!(r.best_test_error().is_infinite());
}
