//! Cross-module integration tests: theory ↔ simulator ↔ coordinator.
//! These are the "does the system reproduce the thesis' claims when all
//! the layers compose" checks, one notch above the per-module units.

use elastic_train::cluster::CostModel;
use elastic_train::coordinator::oracle::GradOracle;
use elastic_train::coordinator::{
    run_parallel, run_sequential, DriverConfig, Method, MlpOracle, SeqMethod,
};
use elastic_train::data::BlobDataset;
use elastic_train::model::MlpConfig;
use elastic_train::rng::Rng;
use elastic_train::sim::{moments, quadratic};
use std::sync::Arc;

fn fast_cost(n_params: usize) -> CostModel {
    CostModel {
        t_grad: 1e-3,
        jitter: 0.08,
        t_data: 1e-4,
        latency: 1e-4,
        bandwidth: 1e9,
        param_bytes: (n_params * 4) as f64,
    }
}

fn hard_task(p: usize) -> Vec<MlpOracle> {
    let data = Arc::new(BlobDataset::generate(32, 10, 2048, 512, 2.2, 1));
    let mcfg = MlpConfig::new(&[32, 64, 32, 10], 1e-4);
    MlpOracle::family(data, &mcfg, 32, p)
}

fn run(p: usize, method: Method, eta: f32, horizon: f64) -> elastic_train::cluster::RunResult {
    let mut oracles = hard_task(p);
    let n = oracles[0].n_params();
    let cfg = DriverConfig {
        eta,
        method,
        cost: fast_cost(n),
        horizon,
        eval_every: horizon / 40.0,
        seed: 7,
        max_steps: u64::MAX / 2,
        lr_decay_gamma: 0.0,
    };
    run_parallel(&mut oracles, &cfg)
}

/// Thesis Figs 4.1–4.4, end to end through the coordinator: DOWNPOUR's
/// best τ is small, EASGD tolerates τ = 64.
#[test]
fn downpour_large_tau_collapses_easgd_does_not() {
    let e64 = run(4, Method::easgd_default(4, 64), 0.08, 3.0);
    let d64 = run(4, Method::Downpour { tau: 64 }, 0.05, 3.0);
    let d1 = run(4, Method::Downpour { tau: 1 }, 0.05, 3.0);
    assert!(!e64.diverged);
    let e = e64.best_test_error();
    let d_bad = if d64.diverged { 1.0 } else { d64.best_test_error() };
    let d_good = d1.best_test_error();
    assert!(e < d_bad - 0.05, "EASGD {e} should beat DOWNPOUR@64 {d_bad}");
    assert!(d_good < d_bad - 0.05, "DOWNPOUR degrades with τ: {d_good} vs {d_bad}");
}

/// Thesis Figs 4.5–4.7 shape: EAMSGD reaches a fixed error level faster
/// (virtual time) than sequential MSGD.
#[test]
fn eamsgd_beats_sequential_msgd_to_threshold() {
    let par = run(8, Method::eamsgd_default(8, 10), 0.01, 1.5);
    let mut seq_oracle = hard_task(1).pop().unwrap();
    let n = seq_oracle.n_params();
    let seq = run_sequential(
        &mut seq_oracle,
        SeqMethod::Msgd { delta: 0.99 },
        0.005,
        &fast_cost(n),
        1.5,
        1.5 / 40.0,
        7,
    );
    // A *hard* threshold near EAMSGD's floor — that is where Figs
    // 4.5–4.7 compare (loose early thresholds favor whoever skips
    // the initial exchange overhead).
    let thr = par.best_test_error() * 1.05;
    let tp = par.time_to_error(thr);
    let ts = seq.time_to_error(thr);
    let a = tp.expect("EAMSGD reaches its own threshold");
    match ts {
        Some(b) => assert!(a < b, "EAMSGD {a} vs MSGD {b}"),
        None => {} // MSGD never gets there — the thesis' missing bar
    }
}

/// Corollary 3.1.1 through the synchronous simulator at several
/// settings: stationary center MSE matches the closed form.
#[test]
fn lemma_3_1_1_matches_simulation_across_settings() {
    for &(eta, beta, p) in &[(0.05f64, 0.3f64, 2usize), (0.1, 0.5, 8), (0.2, 0.8, 4)] {
        let m = quadratic::Quadratic { h: 1.0, sigma: 0.2 };
        let model = moments::QuadraticModel { h: 1.0, sigma: 0.2, p };
        let want = moments::center_mse_infinite(&model, eta, beta);
        let got = quadratic::empirical_second_moment(
            |r| {
                quadratic::easgd_trajectory(
                    m,
                    eta,
                    beta / p as f64,
                    beta,
                    p,
                    0.0,
                    4000,
                    &mut Rng::new(1000 + r as u64),
                )
            },
            30,
            400,
        );
        assert!(
            (got - want).abs() / want < 0.3,
            "(η={eta}, β={beta}, p={p}): {got} vs {want}"
        );
    }
}

/// Table 4.4 through the driver: raising τ from 1 to 10 cuts the comm
/// column by ~10× while compute stays put.
#[test]
fn tau_controls_comm_share_like_table_4_4() {
    let cost = CostModel::cifar_like(4_000);
    let mk = |tau: u32| {
        let mut oracles = hard_task(4);
        let cfg = DriverConfig {
            eta: 0.05,
            method: Method::easgd_default(4, tau),
            cost,
            horizon: 20.0,
            eval_every: 20.0,
            seed: 3,
            max_steps: u64::MAX / 2,
            lr_decay_gamma: 0.0,
        };
        run_parallel(&mut oracles, &cfg)
    };
    let r1 = mk(1);
    let r10 = mk(10);
    let per_step_comm_1 = r1.breakdown.comm / r1.total_steps as f64;
    let per_step_comm_10 = r10.breakdown.comm / r10.total_steps as f64;
    let ratio = per_step_comm_1 / per_step_comm_10;
    assert!((ratio - 10.0).abs() < 3.0, "comm ratio {ratio} ≈ 10 expected");
    let per_step_compute_1 = r1.breakdown.compute / r1.total_steps as f64;
    let per_step_compute_10 = r10.breakdown.compute / r10.total_steps as f64;
    assert!((per_step_compute_1 / per_step_compute_10 - 1.0).abs() < 0.1);
}

/// §5.2.3 Case I integrated: the multiplicative-noise EASGD moment
/// matrix has an interior optimal p, and the simulator agrees the
/// optimum beats p = 1.
#[test]
fn optimal_worker_count_is_interior_under_multiplicative_noise() {
    let (l, w, beta) = (1.0, 1.0, 0.9);
    let best_for = |p: usize| {
        let mut best = f64::INFINITY;
        for ei in 1..60 {
            let eta = ei as f64 / 60.0;
            let s = moments::sp(&moments::easgd_mult_moment_matrix(
                eta,
                beta / p as f64,
                beta,
                l,
                w,
                p,
            ));
            best = best.min(s);
        }
        best
    };
    let b1 = best_for(1);
    let b7 = best_for(7);
    let b64 = best_for(64);
    assert!(b7 < b1, "p=7 {b7} should beat p=1 {b1}");
    assert!(b7 < b64, "p=7 {b7} should beat p=64 {b64} (interior optimum)");
}

/// The averaging variants track their base method: ADOWNPOUR's averaged
/// center lags early but ends comparable (Fig 4.10 flavor).
#[test]
fn averaged_center_lags_early() {
    let base = run(4, Method::Downpour { tau: 1 }, 0.05, 0.6);
    let avg = run(4, Method::ADownpour { tau: 1 }, 0.05, 0.6);
    let b_first = base.curve[1].train_loss;
    let a_first = avg.curve[1].train_loss;
    assert!(
        a_first >= b_first - 0.05,
        "averaged center should not lead early: {a_first} vs {b_first}"
    );
}

/// Determinism across the whole stack: same seed ⇒ identical curve;
/// different seed ⇒ different trajectory.
#[test]
fn full_stack_determinism() {
    let a = run(4, Method::easgd_default(4, 10), 0.08, 1.0);
    let b = run(4, Method::easgd_default(4, 10), 0.08, 1.0);
    assert_eq!(a.total_steps, b.total_steps);
    let la: Vec<f64> = a.curve.iter().map(|p| p.train_loss).collect();
    let lb: Vec<f64> = b.curve.iter().map(|p| p.train_loss).collect();
    assert_eq!(la, lb);
}

/// Round-robin EASGD (§3.3) embedded in the non-convex double well:
/// large ρ forces consensus, small ρ leaves a straddle — through the
/// actual gradient dynamics, not just the Hessian test.
#[test]
fn double_well_consensus_depends_on_rho() {
    use elastic_train::sim::nonconvex;
    let mut rng = Rng::new(11);
    let (x, y, _) = nonconvex::descend_from_straddle(0.1, 0.05, 0.02, 30_000, &mut rng);
    assert!(x > 0.2 && y < -0.2, "ρ=0.1 should straddle: ({x},{y})");
    let (x2, y2, _) = nonconvex::descend_from_straddle(0.8, 0.05, 0.02, 30_000, &mut rng);
    assert!((x2 - y2).abs() < 0.4, "ρ=0.8 should reach consensus: ({x2},{y2})");
}
