//! Layer-3 coordinator: the thesis' distributed optimization methods.
//!
//! - [`oracle`] — the `GradOracle` abstraction (native MLP for sweeps,
//!   the deterministic quadratic for equivalence tests/benches; the
//!   PJRT transformer in `runtime` implements the same trait).
//! - [`method`] — every parallel method the thesis compares:
//!   EASGD / EAMSGD (Algorithms 1–2), DOWNPOUR (Alg. 3),
//!   MDOWNPOUR (Algs 4–5), ADOWNPOUR / MVADOWNPOUR, and async ADMM.
//! - [`executor`] — the `Executor` abstraction: one run contract, two
//!   backends (`SimExecutor` / `ThreadExecutor`) × two topologies,
//!   plus the shared config/worker/master state, `Backend` selection,
//!   and the `check_supported` method/backend/topology matrix.
//! - [`topology`] — how nodes are wired: the flat `Star`, the d-ary
//!   `Tree` (spec, layout, §6.1 communication schemes, per-node τ
//!   table) — shared by both tree backends.
//! - [`driver`] — the virtual-time event-driven star backend:
//!   per-worker virtual clocks, communication period τ, jittered
//!   compute, Table-4.4 accounting. Bitwise deterministic given the
//!   seed.
//! - [`threaded`] — the real-thread star backend: one `std::thread`
//!   per worker, the center variable behind a per-method
//!   `CenterBackend` — the sharded lock (genuinely stale concurrent
//!   exchanges) for the master-decoupled methods.
//! - [`master_actor`] — the other `CenterBackend`: a dedicated master
//!   thread absorbing worker messages over `mpsc` channels with
//!   serialized Gauss–Seidel application, running the master-coupled
//!   methods (MDOWNPOUR, async ADMM) on real threads.
//! - [`wire`] — the process backend's wire format: length-prefixed
//!   flat-θ frames over TCP/Unix sockets, with measured
//!   serialize/transfer accounting. No serde, no new dependencies.
//! - [`protocol`] — the master⇄worker frame protocol as data: typed
//!   transition tables for both sides, a `ProtocolState` checker that
//!   every `process` send/recv is driven through, exhaustive
//!   (state × kind) enumeration tests, fuzzed by `fuzz_wire`.
//! - [`process`] — the multi-process star backend: a parameter-server
//!   master, workers as self-exec'd OS processes exchanging frames
//!   over real sockets (`backend=process`).
//! - [`sequential`] — the p = 1 baselines: SGD, MSGD, ASGD, MVASGD.
//! - [`tree`] — EASGD Tree (Alg. 6), virtual-time backend: fully-async
//!   messaging on the shared worker/step machinery.
//! - [`tree_threaded`] — EASGD Tree, real-thread backend: one actor
//!   thread per node, parameter snapshots over `mpsc` channels.
//! - [`gauss_seidel`] — §6.2: the Gauss–Seidel reformulation unifying
//!   EASGD and DOWNPOUR, with its stability map.

pub mod driver;
pub mod executor;
pub mod gauss_seidel;
pub mod master_actor;
pub mod method;
pub mod oracle;
pub mod process;
pub mod protocol;
pub mod sequential;
pub mod threaded;
pub mod topology;
pub mod tree;
pub mod tree_threaded;
pub mod wire;

pub use driver::{run_parallel, DriverConfig};
pub use executor::{
    check_supported, master_coupled, run_with_backend, run_with_backend_topology,
    tree_supported, Backend, Executor, SimExecutor, ThreadExecutor,
};
pub use method::Method;
pub use oracle::{ConvOracle, EvalStats, GradOracle, MlpOracle, NativeOracle, QuadraticOracle};
pub use process::{process_worker_main, run_process, OracleSpec, ProcessOpts};
pub use protocol::{Dir, ProtoState, ProtocolState, Side, TRANSITIONS};
pub use sequential::{run_sequential, SeqMethod};
pub use threaded::run_threaded;
pub use topology::{node_taus, Topology, TreeLayout, TreeScheme, TreeSpec};
pub use tree::run_tree_sim;
pub use tree_threaded::run_tree_threaded;
