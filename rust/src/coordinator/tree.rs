//! EASGD **Tree** (thesis Chapter 6, Algorithm 6): scaling elastic
//! averaging to hundreds of workers with a d-ary tree of nodes and a
//! *fully asynchronous* message protocol.
//!
//! * Leaf nodes run local SGD (optionally Nesterov momentum, as in the
//!   thesis' mini-batch experiments) and push their parameter up every
//!   τ_up steps.
//! * Interior nodes do NO gradient work (the thesis' final design):
//!   they absorb arriving child/parent parameters with the
//!   Gauss–Seidel moving-average rule x ← x + α(x_arrived − x), and
//!   push their own parameter up (τ_up) and down (τ_down).
//! * Two communication schemes (§6.1, Fig 6.2):
//!     Scheme 1 (multi-scale): fast period τ₁ at the bottom layer,
//!       slow τ₂ above.
//!     Scheme 2 (fast-up/slow-down): every node uses τ_u up, τ_d down.
//!
//! Messages carry full parameter snapshots with a one-way delivery
//! delay from the cost model; arrival processing happens at the
//! receiving node's next activation — exactly the "apply just-in-time,
//! never during a gradient update" rule of §6.1.

use super::oracle::GradOracle;
use crate::cluster::{CostModel, CurvePoint, RunResult, TimeBreakdown};
use crate::model::flat;
use crate::rng::Rng;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// The two §6.1 communication schemes.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TreeScheme {
    /// τ₁ between leaves and their parents, τ₂ between interior nodes.
    MultiScale { tau1: u32, tau2: u32 },
    /// τ_up / τ_down at every node.
    UpDown { tau_up: u32, tau_down: u32 },
}

/// Tree run configuration.
#[derive(Clone, Debug)]
pub struct TreeConfig {
    /// Fan-out d of the d-ary tree.
    pub degree: usize,
    /// Number of leaf workers (must be a power of `degree` for a full
    /// tree; other values produce a ragged last level).
    pub leaves: usize,
    pub scheme: TreeScheme,
    /// Moving rate at every node (thesis: 0.9/(d+1)).
    pub alpha: f32,
    pub eta: f32,
    /// Leaf Nesterov momentum δ (0 disables).
    pub delta: f32,
    pub cost: CostModel,
    /// Interior nodes activate this often (fraction of t_grad).
    pub interior_activity: f64,
    /// Cost discount for bottom-layer (leaf ↔ leaf-parent) messages —
    /// they stay inside one machine in the thesis' deployment (§6.1),
    /// which is exactly what communication scheme 1 exploits.
    pub intra_discount: f64,
    pub horizon: f64,
    pub eval_every: f64,
    pub seed: u64,
    pub max_events: u64,
}

impl TreeConfig {
    /// Thesis §6.1.2 defaults: d = 16, p = 256, α = 0.9/(d+1).
    pub fn thesis_default(cost: CostModel) -> Self {
        TreeConfig {
            degree: 16,
            leaves: 256,
            scheme: TreeScheme::MultiScale { tau1: 10, tau2: 100 },
            alpha: 0.9 / 17.0,
            eta: 5e-3,
            delta: 0.0,
            cost,
            interior_activity: 0.25,
            intra_discount: 0.2,
            horizon: 10.0,
            eval_every: 1.0,
            seed: 0,
            max_events: 50_000_000,
        }
    }
}

/// Static tree topology: node 0 is the root; nodes are laid out level
/// by level. Leaves are the last `leaves` nodes.
pub struct Topology {
    pub parent: Vec<Option<usize>>,
    pub children: Vec<Vec<usize>>,
    pub n_nodes: usize,
    pub first_leaf: usize,
}

impl Topology {
    /// Build the minimal d-ary tree with `leaves` leaf nodes: levels of
    /// size ⌈leaves/d^k⌉ from root down.
    pub fn dary(degree: usize, leaves: usize) -> Topology {
        assert!(degree >= 2 && leaves >= 1);
        // Level sizes from the leaf level up.
        let mut sizes = vec![leaves];
        while *sizes.last().unwrap() > 1 {
            let s = sizes.last().unwrap().div_ceil(degree);
            sizes.push(s);
        }
        sizes.reverse(); // root first
        let n_nodes: usize = sizes.iter().sum();
        let mut parent = vec![None; n_nodes];
        let mut children = vec![Vec::new(); n_nodes];
        // Offsets of each level.
        let mut offs = vec![0usize];
        for s in &sizes {
            offs.push(offs.last().unwrap() + s);
        }
        for lvl in 1..sizes.len() {
            for j in 0..sizes[lvl] {
                let node = offs[lvl] + j;
                let par = offs[lvl - 1] + j / degree;
                parent[node] = Some(par);
                children[par].push(node);
            }
        }
        let first_leaf = n_nodes - leaves;
        Topology { parent, children, n_nodes, first_leaf }
    }

    pub fn is_leaf(&self, i: usize) -> bool {
        i >= self.first_leaf
    }

    /// Is this node a parent of leaves (the "bottom layer" of scheme 1)?
    pub fn is_leaf_parent(&self, i: usize) -> bool {
        self.children[i].iter().any(|&c| self.is_leaf(c))
    }
}

#[derive(PartialEq)]
enum EvKind {
    Activate(usize),
    Deliver { to: usize, payload_idx: usize },
}

#[derive(PartialEq)]
struct Ev(f64, u64, EvKind);
impl Eq for Ev {}
impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Ev {
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .0
            .partial_cmp(&self.0)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.1.cmp(&self.1))
    }
}

/// Run an EASGD Tree experiment. `oracles[k]` serves leaf k (k-th leaf,
/// i.e. node `first_leaf + k`); `oracles[0]` evaluates the ROOT node —
/// the thesis' tracked variable.
pub fn run_tree<O: GradOracle>(oracles: &mut [O], cfg: &TreeConfig) -> RunResult {
    let topo = Topology::dary(cfg.degree, cfg.leaves);
    assert_eq!(oracles.len(), cfg.leaves);
    let n = oracles[0].n_params();
    let init = oracles[0].init_params();

    // Per-node τ_up / τ_down per the scheme.
    let taus: Vec<(u64, u64)> = (0..topo.n_nodes)
        .map(|i| match cfg.scheme {
            TreeScheme::MultiScale { tau1, tau2 } => {
                if topo.is_leaf(i) {
                    (tau1 as u64, u64::MAX)
                } else if topo.is_leaf_parent(i) {
                    (tau2 as u64, tau1 as u64)
                } else if topo.parent[i].is_none() {
                    (u64::MAX, tau2 as u64)
                } else {
                    (tau2 as u64, tau2 as u64)
                }
            }
            TreeScheme::UpDown { tau_up, tau_down } => {
                let up = if topo.parent[i].is_none() { u64::MAX } else { tau_up as u64 };
                let down = if topo.is_leaf(i) { u64::MAX } else { tau_down as u64 };
                (up, down)
            }
        })
        .collect();

    let mut params: Vec<Vec<f32>> = vec![init.clone(); topo.n_nodes];
    let mut vels: Vec<Vec<f32>> =
        (0..cfg.leaves).map(|_| vec![0.0f32; n]).collect();
    let mut grads: Vec<Vec<f32>> =
        (0..cfg.leaves).map(|_| vec![0.0f32; n]).collect();
    let mut clocks = vec![0u64; topo.n_nodes];
    let mut inbox: Vec<Vec<usize>> = vec![Vec::new(); topo.n_nodes];
    let mut payloads: Vec<Vec<f32>> = Vec::new();
    let mut free_payloads: Vec<usize> = Vec::new();

    let mut root_rng = Rng::new(cfg.seed);
    let mut worker_rngs: Vec<Rng> =
        (0..cfg.leaves).map(|k| root_rng.split(k as u64)).collect();
    let mut time_rng = root_rng.split(0xABCD);
    let mut scratch = vec![0.0f32; n];

    let mut heap: BinaryHeap<Ev> = BinaryHeap::new();
    let mut seq = 0u64;
    for i in 0..topo.n_nodes {
        heap.push(Ev(time_rng.uniform() * cfg.cost.t_grad, seq, EvKind::Activate(i)));
        seq += 1;
    }

    let mut result = RunResult::default();
    let mut breakdown = TimeBreakdown::default();
    let mut next_eval = 0.0f64;
    let mut total_steps = 0u64;
    let mut events = 0u64;
    let mut diverged = false;

    while let Some(Ev(now, _, kind)) = heap.pop() {
        if now > cfg.horizon || events >= cfg.max_events || diverged {
            break;
        }
        events += 1;
        while now >= next_eval {
            let st = oracles[0].eval(&params[0]); // root node
            result.curve.push(CurvePoint {
                time: next_eval,
                train_loss: st.train_loss,
                test_loss: st.test_loss,
                test_error: st.test_error,
            });
            if !st.train_loss.is_finite() {
                diverged = true;
            }
            next_eval += cfg.eval_every;
        }

        match kind {
            EvKind::Deliver { to, payload_idx } => {
                inbox[to].push(payload_idx);
            }
            EvKind::Activate(i) => {
                // 1) absorb arrivals (Gauss–Seidel moving average).
                let pending = std::mem::take(&mut inbox[i]);
                for pidx in pending {
                    flat::moving_average(&mut params[i], &payloads[pidx], cfg.alpha);
                    free_payloads.push(pidx);
                }
                // 2) leaf gradient step (interior nodes do no gradient
                //    work — thesis' final design).
                let mut dt;
                if topo.is_leaf(i) {
                    let k = i - topo.first_leaf;
                    if cfg.delta > 0.0 {
                        // Nesterov: g at lookahead θ + δv.
                        for (s, (t, vv)) in scratch
                            .iter_mut()
                            .zip(params[i].iter().zip(vels[k].iter()))
                        {
                            *s = t + cfg.delta * vv;
                        }
                        oracles[k].grad(&scratch, &mut worker_rngs[k], &mut grads[k]);
                        flat::nesterov_step(
                            &mut params[i],
                            &mut vels[k],
                            &grads[k],
                            cfg.eta,
                            cfg.delta,
                        );
                    } else {
                        let theta_now = &params[i];
                        oracles[k].grad(theta_now, &mut worker_rngs[k], &mut grads[k]);
                        flat::sgd_step(&mut params[i], &grads[k], cfg.eta);
                    }
                    dt = cfg.cost.grad_time(&mut time_rng) + cfg.cost.t_data;
                    breakdown.compute += dt - cfg.cost.t_data;
                    breakdown.data += cfg.cost.t_data;
                    total_steps += 1;
                } else {
                    dt = cfg.cost.t_grad * cfg.interior_activity;
                }
                clocks[i] += 1;
                let t = clocks[i];
                // 3) sends (non-blocking Isend).
                let (tau_up, tau_down) = taus[i];
                let mut send_to: Vec<usize> = Vec::new();
                if tau_up != u64::MAX && t % tau_up == 0 {
                    if let Some(par) = topo.parent[i] {
                        send_to.push(par);
                    }
                }
                if tau_down != u64::MAX && t % tau_down == 0 {
                    send_to.extend(topo.children[i].iter().copied());
                }
                for dest in send_to {
                    // Intra-machine (bottom-layer) links are cheap.
                    let discount = if topo.is_leaf(dest)
                        || topo.is_leaf(i)
                    {
                        cfg.intra_discount
                    } else {
                        1.0
                    };
                    let pidx = match free_payloads.pop() {
                        Some(idx) => {
                            payloads[idx].copy_from_slice(&params[i]);
                            idx
                        }
                        None => {
                            payloads.push(params[i].clone());
                            payloads.len() - 1
                        }
                    };
                    let delay = cfg.cost.one_way_time() * discount;
                    breakdown.comm += delay;
                    heap.push(Ev(now + delay, seq, EvKind::Deliver { to: dest, payload_idx: pidx }));
                    seq += 1;
                    // Non-blocking: no dt added to the sender.
                }
                if flat::norm2(&params[i]) > 1e8 {
                    diverged = true;
                }
                if dt <= 0.0 {
                    dt = 1e-9;
                }
                heap.push(Ev(now + dt, seq, EvKind::Activate(i)));
                seq += 1;
            }
        }
    }

    let st = oracles[0].eval(&params[0]);
    result.curve.push(CurvePoint {
        time: cfg.horizon.min(next_eval),
        train_loss: st.train_loss,
        test_loss: st.test_loss,
        test_error: st.test_error,
    });
    result.breakdown = breakdown;
    result.total_steps = total_steps;
    result.diverged = diverged || !st.train_loss.is_finite();
    result
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dary_topology_shapes() {
        let t = Topology::dary(16, 256);
        // 256 leaves, 16 parents, 1 root.
        assert_eq!(t.n_nodes, 256 + 16 + 1);
        assert_eq!(t.first_leaf, 17);
        assert!(t.parent[0].is_none());
        assert_eq!(t.children[0].len(), 16);
        for i in 17..t.n_nodes {
            assert!(t.is_leaf(i));
            assert!(t.children[i].is_empty());
        }
        for i in 1..17 {
            assert_eq!(t.children[i].len(), 16);
            assert_eq!(t.parent[i], Some(0));
            assert!(t.is_leaf_parent(i));
        }
    }

    #[test]
    fn tree_trains_on_blobs_with_both_schemes() {
        use crate::coordinator::oracle::MlpOracle;
        use crate::data::BlobDataset;
        use crate::model::MlpConfig;
        use std::sync::Arc;

        let data = Arc::new(BlobDataset::generate(8, 4, 1024, 256, 0.8, 1));
        let mcfg = MlpConfig::new(&[8, 16, 4], 1e-4);
        for scheme in [
            TreeScheme::MultiScale { tau1: 2, tau2: 8 },
            TreeScheme::UpDown { tau_up: 2, tau_down: 8 },
        ] {
            let mut oracles = MlpOracle::family(data.clone(), &mcfg, 32, 16);
            let cost = CostModel {
                t_grad: 1e-3,
                jitter: 0.1,
                t_data: 1e-4,
                latency: 1e-4,
                bandwidth: 1e9,
                param_bytes: 1000.0,
            };
            let cfg = TreeConfig {
                degree: 4,
                leaves: 16,
                scheme,
                alpha: 0.9 / 5.0,
                eta: 0.1,
                delta: 0.0,
                cost,
                interior_activity: 0.25,
                intra_discount: 0.2,
                horizon: 0.5,
                eval_every: 0.1,
                seed: 11,
                max_events: 5_000_000,
            };
            let r = run_tree(&mut oracles, &cfg);
            assert!(!r.diverged, "{scheme:?} diverged");
            assert!(r.total_steps > 1000, "{scheme:?}: {} steps", r.total_steps);
            let first = r.curve.first().unwrap().train_loss;
            let last = r.curve.last().unwrap().train_loss;
            assert!(last < first - 0.1, "{scheme:?}: {first} -> {last}");
        }
    }

    #[test]
    fn tree_with_momentum_is_stable_at_reduced_eta() {
        use crate::coordinator::oracle::MlpOracle;
        use crate::data::BlobDataset;
        use crate::model::MlpConfig;
        use std::sync::Arc;

        let data = Arc::new(BlobDataset::generate(8, 4, 512, 128, 0.8, 2));
        let mcfg = MlpConfig::new(&[8, 16, 4], 1e-4);
        let mut oracles = MlpOracle::family(data, &mcfg, 32, 16);
        let cost = CostModel {
            t_grad: 1e-3,
            jitter: 0.1,
            t_data: 1e-4,
            latency: 1e-4,
            bandwidth: 1e9,
            param_bytes: 1000.0,
        };
        let cfg = TreeConfig {
            degree: 4,
            leaves: 16,
            scheme: TreeScheme::MultiScale { tau1: 1, tau2: 10 },
            alpha: 0.9 / 5.0,
            eta: 0.01, // thesis: momentum δ=0.9 ⇒ reduce η ×10
            delta: 0.9,
            cost,
            interior_activity: 0.25,
            intra_discount: 0.2,
            horizon: 0.5,
            eval_every: 0.25,
            seed: 13,
            max_events: 5_000_000,
        };
        let r = run_tree(&mut oracles, &cfg);
        assert!(!r.diverged);
        let first = r.curve.first().unwrap().train_loss;
        let last = r.curve.last().unwrap().train_loss;
        assert!(last < first, "{first} -> {last}");
    }

    #[test]
    fn ragged_tree_still_connects_everyone() {
        let t = Topology::dary(4, 10); // levels: 10, 3, 1
        assert_eq!(t.n_nodes, 14);
        for i in 1..t.n_nodes {
            assert!(t.parent[i].is_some());
        }
        let total_children: usize = t.children.iter().map(|c| c.len()).sum();
        assert_eq!(total_children, t.n_nodes - 1);
    }
}
