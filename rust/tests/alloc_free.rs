//! The acceptance gate for the batched compute path's memory behavior:
//! a steady-state `grad_batch` call performs ZERO heap allocations —
//! all activation/gradient panels (and, for the conv model, the
//! im2col/pool panels) are pre-allocated on first use and reused.
//! Enforced with a counting global allocator; this file must hold
//! exactly one test (the counter is process-wide and the default test
//! harness runs a binary's tests in parallel).

use elastic_train::model::{ConvNet, ConvNetConfig, Mlp, MlpConfig};
use elastic_train::rng::Rng;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn grad_batch_steady_state_does_not_allocate() {
    let cfg = MlpConfig::sweep_default();
    let mut mlp = Mlp::new(cfg);
    let mut rng = Rng::new(17);
    let theta = mlp.init_params(&mut rng);
    let mut grad = vec![0.0f32; theta.len()];
    let batch: Vec<(Vec<f32>, usize)> = (0..128)
        .map(|_| {
            let x = (0..32).map(|_| rng.normal(0.0, 1.0) as f32).collect();
            (x, rng.below(10))
        })
        .collect();

    // Warm up: first calls size the scratch panels.
    for _ in 0..3 {
        mlp.batch_grad(&theta, &batch, &mut grad);
    }

    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    let mut sink = 0.0f32;
    for _ in 0..10 {
        sink += mlp.batch_grad(&theta, &batch, &mut grad);
    }
    let after = ALLOC_CALLS.load(Ordering::SeqCst);
    assert!(sink.is_finite());
    assert_eq!(
        after - before,
        0,
        "grad_batch allocated {} times across 10 steady-state calls",
        after - before
    );

    // A smaller batch reuses the larger panels — still allocation-free,
    // including through the iterator-based entry point.
    let small = &batch[..32];
    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    for _ in 0..5 {
        sink += mlp.grad_batch(&theta, small.iter().map(|(x, y)| (x.as_slice(), *y)), &mut grad);
    }
    let after = ALLOC_CALLS.load(Ordering::SeqCst);
    assert!(sink.is_finite());
    assert_eq!(after - before, 0, "smaller batches must reuse the panels");

    // The conv model holds the same contract: after warm-up, the
    // im2col/activation/pool/backward panels are all reused — a
    // steady-state `ConvNet::grad_batch` never touches the allocator.
    let cfg = ConvNetConfig::for_blob(32, 10, 1e-4);
    let mut conv = ConvNet::new(cfg);
    let ctheta = conv.init_params(&mut rng);
    let mut cgrad = vec![0.0f32; ctheta.len()];
    for _ in 0..3 {
        conv.batch_grad(&ctheta, &batch, &mut cgrad);
    }
    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    for _ in 0..10 {
        sink += conv.batch_grad(&ctheta, &batch, &mut cgrad);
    }
    let after = ALLOC_CALLS.load(Ordering::SeqCst);
    assert!(sink.is_finite());
    assert_eq!(
        after - before,
        0,
        "ConvNet::grad_batch allocated {} times across 10 steady-state calls",
        after - before
    );

    // Shrunken conv batches reuse the larger panels too.
    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    for _ in 0..5 {
        let it = small.iter().map(|(x, y)| (x.as_slice(), *y));
        sink += conv.grad_batch(&ctheta, it, &mut cgrad);
    }
    let after = ALLOC_CALLS.load(Ordering::SeqCst);
    assert!(sink.is_finite());
    assert_eq!(after - before, 0, "smaller conv batches must reuse the panels");

    // Hybrid parallelism holds the same contract: with `threads=2`
    // GEMM helpers the dispatch path is a stack-copied job descriptor
    // plus futex-backed Condvar signaling — once the pool's helper
    // threads exist (warm-up below is allowed to spawn them and seed
    // the thread-local registry), a steady-state parallel grad_batch
    // never touches the allocator either.
    elastic_train::linalg::pool::configure_threads(2);
    for _ in 0..3 {
        mlp.batch_grad(&theta, &batch, &mut grad);
        conv.batch_grad(&ctheta, &batch, &mut cgrad);
    }
    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    for _ in 0..10 {
        sink += mlp.batch_grad(&theta, &batch, &mut grad);
        sink += conv.batch_grad(&ctheta, &batch, &mut cgrad);
    }
    let after = ALLOC_CALLS.load(Ordering::SeqCst);
    assert!(sink.is_finite());
    assert_eq!(
        after - before,
        0,
        "threaded grad_batch allocated {} times across 10 steady-state calls",
        after - before
    );
    elastic_train::linalg::pool::configure_threads(1);

    // The SIMD dispatch path holds the same contract: tier selection is
    // a relaxed atomic load per span and the intrinsic kernels stage
    // everything in registers or fixed stack buffers. (Compiled only
    // with `--features simd`; runs on whatever tier the host detects —
    // on a scalar-only host this re-checks the scalar path, which is
    // still the dispatch-table code shape being gated here.)
    #[cfg(feature = "simd")]
    {
        let tier = elastic_train::linalg::simd::detect_best();
        elastic_train::linalg::simd::configure(tier.name()).unwrap();
        for _ in 0..3 {
            mlp.batch_grad(&theta, &batch, &mut grad);
            conv.batch_grad(&ctheta, &batch, &mut cgrad);
        }
        let before = ALLOC_CALLS.load(Ordering::SeqCst);
        for _ in 0..10 {
            sink += mlp.batch_grad(&theta, &batch, &mut grad);
            sink += conv.batch_grad(&ctheta, &batch, &mut cgrad);
        }
        let after = ALLOC_CALLS.load(Ordering::SeqCst);
        assert!(sink.is_finite());
        assert_eq!(
            after - before,
            0,
            "SIMD-tier ({}) grad_batch allocated {} times across 10 steady-state calls",
            tier.name(),
            after - before
        );
    }
}
