//! Tier-1 replay of the committed wire fuzz corpus
//! (`tests/corpus/wire/*.bin`).
//!
//! Every file runs through `recv_frame` + the master-side
//! `ProtocolState` checker — exactly the `fuzz_wire` binary's corpus
//! phase, but in-process so plain `cargo test` keeps the regression
//! corpus honest without the fuzz lane. Contract: `ok_*` streams
//! replay cleanly, `err_*` streams produce a typed error (never a
//! panic, never an attacker-sized allocation), and the classes we have
//! been burned by before pin their exact error fragments.

use elastic_train::coordinator::protocol::{Dir, ProtoState, ProtocolState};
use elastic_train::coordinator::wire::{recv_frame, FrameKind, WireClock};
use elastic_train::error::Result;

fn corpus_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/corpus/wire")
}

/// Decode a whole stream frame-by-frame through the master-side
/// checker, simulating the master's own Init/Center turns (same
/// contract as `fuzz_wire`'s corpus phase).
fn replay(bytes: &[u8]) -> Result<usize> {
    let mut slice = bytes;
    let mut ck = WireClock::default();
    let mut proto = ProtocolState::master();
    let mut frames = 0usize;
    while !slice.is_empty() {
        let f = recv_frame(&mut slice, &mut ck)?;
        proto.advance(Dir::Recv, f.kind)?;
        frames += 1;
        match proto.state() {
            ProtoState::SendInit => proto.advance(Dir::Send, FrameKind::Init)?,
            ProtoState::Reply => proto.advance(Dir::Send, FrameKind::Center)?,
            _ => {}
        }
    }
    Ok(frames)
}

fn read(name: &str) -> Vec<u8> {
    let path = corpus_dir().join(name);
    std::fs::read(&path).unwrap_or_else(|e| panic!("cannot read {}: {e}", path.display()))
}

#[test]
fn every_corpus_file_replays_per_its_name() {
    let dir = corpus_dir();
    let mut names: Vec<String> = std::fs::read_dir(&dir)
        .unwrap_or_else(|e| panic!("corpus dir {} missing: {e}", dir.display()))
        .filter_map(|e| e.ok())
        .map(|e| e.file_name().to_string_lossy().to_string())
        .filter(|n| n.ends_with(".bin"))
        .collect();
    names.sort();
    assert!(
        names.len() >= 10,
        "regression corpus shrank to {} files — did a move lose tests/corpus/wire?",
        names.len()
    );
    for name in &names {
        let outcome = replay(&read(name));
        match outcome {
            Ok(frames) if name.starts_with("err_") => {
                panic!("{name}: expected a typed error, decoded {frames} frames cleanly")
            }
            Err(e) if name.starts_with("ok_") => {
                panic!("{name}: expected a clean replay, got: {e}")
            }
            _ => {}
        }
        assert!(
            name.starts_with("ok_") || name.starts_with("err_"),
            "{name}: corpus files must be ok_*.bin or err_*.bin so intent is explicit"
        );
    }
}

#[test]
fn known_error_classes_pin_their_fragments() {
    // Each pair: corpus file → fragment its error must carry. These are
    // the classes that must never regress to a panic or a vague  error.
    let pins = [
        ("err_bad_magic.bin", "bad frame magic"),
        ("err_bad_version.bin", "wire version mismatch"),
        ("err_unknown_kind.bin", "unknown wire frame kind"),
        ("err_cap_exceeded.bin", "cap"),
        ("err_cap_edge.bin", "payload at byte"),
        ("err_truncated_header.bin", "reading frame header"),
        ("err_truncated_payload.bin", "payload at byte"),
        ("err_len_lie.bin", "payload at byte"),
        ("err_out_of_order.bin", "protocol violation"),
        ("err_after_done.bin", "protocol violation"),
    ];
    for (name, fragment) in pins {
        let e = replay(&read(name)).expect_err(name);
        let msg = format!("{e}");
        assert!(msg.contains(fragment), "{name}: expected '{fragment}' in: {msg}");
    }
}

#[test]
fn out_of_order_corpus_names_state_and_frame() {
    let e = replay(&read("err_out_of_order.bin")).expect_err("push before hello");
    let msg = format!("{e}");
    assert!(
        msg.contains("AwaitHello") && msg.contains("Push"),
        "violation must name the state and the offending frame: {msg}"
    );
}

#[test]
fn clean_session_decodes_expected_frame_count() {
    assert_eq!(replay(&read("ok_session.bin")).expect("ok_session"), 4);
    assert_eq!(replay(&read("ok_diverged.bin")).expect("ok_diverged"), 4);
    assert_eq!(replay(&read("ok_hello.bin")).expect("ok_hello"), 1);
    assert_eq!(replay(&read("ok_empty.bin")).expect("ok_empty"), 0);
}
