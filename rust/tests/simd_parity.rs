//! SIMD kernel-tier gates (compiled only with `--features simd`):
//!
//! 1. **Parity**: every kernel (all four `sgemm` flag combinations plus
//!    the fused bias+act epilogue) agrees with the scalar tier to
//!    ≤ 1e-5 relative over a shape grid covering the blocked body, the
//!    MR/NR tails, single-tile and empty panels, and the wide-n
//!    column-split shape. Bitwise equality is deliberately NOT required
//!    across tiers — FMA contracts the multiply-add rounding step.
//! 2. **Bitwise within the tier**: threaded SIMD ≡ serial SIMD, the
//!    same invariant the scalar tier pins in `linalg::gemm`'s tests.
//! 3. **Strict knobs**: unknown and unavailable tier requests are typed
//!    errors, never silent fallbacks.
//!
//! Everything runs inside ONE `#[test]`: `simd::configure` flips a
//! process-global tier, so concurrently running tests would race on the
//! numeric results. Keep any future additions inside this function, in
//! sequence.

use elastic_train::linalg::gemm::{sgemm, sgemm_bias_act};
use elastic_train::linalg::{pool, simd};

/// Deterministic value spread over ±2 with varied low-order bits; no
/// RNG so failures reproduce from the (shape, index) alone.
fn fill(len: usize, salt: u32) -> Vec<f32> {
    (0..len)
        .map(|i| {
            let x = (i as u32).wrapping_mul(2654435761).wrapping_add(salt);
            (x % 4093) as f32 / 1023.0 - 2.0
        })
        .collect()
}

fn assert_close(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let tol = 1e-5 * (1.0 + w.abs());
        assert!(
            (g - w).abs() <= tol,
            "{what}: elem {i}: simd {g} vs scalar {w} (tol {tol})"
        );
    }
}

#[test]
fn simd_tier_parity_and_bitwise_gates() {
    let tier = simd::detect_best();
    if tier == simd::Tier::Scalar {
        // Feature is on but the host offers no SIMD tier (e.g. an
        // x86_64 CI runner without AVX2). The gates below would only
        // compare scalar with scalar; skip loudly instead.
        eprintln!(
            "simd_parity: skipping — no SIMD tier on this host (cpu: {})",
            simd::cpu_features()
        );
        return;
    }
    eprintln!("simd_parity: testing tier {} (cpu: {})", tier.name(), simd::cpu_features());

    // --- strict knobs -----------------------------------------------------
    let e = simd::configure("sse42").unwrap_err();
    assert!(format!("{e}").contains("sse42"), "unknown tier must be named: {e}");
    // Exactly one of avx2/neon can be available on one architecture;
    // the other must refuse with a reason, not degrade.
    let other = if tier == simd::Tier::Avx2 { "neon" } else { "avx2" };
    let e = simd::configure(other).unwrap_err();
    assert!(format!("{e}").contains(other), "unavailable tier must be named: {e}");
    assert_eq!(simd::configure("auto").unwrap(), tier, "auto must pick the detected tier");

    // Shape grid: blocked body, NR tail (n % 16), MR tail (m % 4),
    // both tails, single row/column, k = 0, empty output, and the
    // 4×4096 wide-n column-split satellite shape.
    let shapes: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (3, 5, 7),
        (4, 16, 8),
        (9, 33, 17),
        (128, 10, 32),
        (2, 64, 1),
        (67, 129, 40),
        (64, 64, 64),
        (2, 3, 0),
        (0, 16, 8),
        (4, 4096, 32),
    ];

    for &(m, n, k) in shapes {
        let a = fill(m * k, 1);
        let b = fill(k * n, 2);
        let at = fill(k * m, 3); // k×m storage for the ta=true legs
        let bt = fill(n * k, 4); // n×k storage for the tb=true legs
        let bias = fill(n, 5);
        let seed = fill(m * n, 6);

        pool::configure_threads(1);
        for (ta, tb) in [(false, false), (true, false), (false, true), (true, true)] {
            let (aa, bb) = (if ta { &at } else { &a }, if tb { &bt } else { &b });
            simd::configure("scalar").unwrap();
            let mut scalar = seed.clone();
            sgemm(ta, tb, m, n, k, aa, bb, &mut scalar);
            simd::configure(tier.name()).unwrap();
            let mut vectored = seed.clone();
            sgemm(ta, tb, m, n, k, aa, bb, &mut vectored);
            assert_close(&vectored, &scalar, &format!("sgemm ta={ta} tb={tb} {m}x{n}x{k}"));
        }
        for relu in [false, true] {
            simd::configure("scalar").unwrap();
            let mut scalar = vec![-1.0f32; m * n];
            sgemm_bias_act(m, n, k, &a, &b, &bias, relu, &mut scalar);
            simd::configure(tier.name()).unwrap();
            let mut vectored = vec![-1.0f32; m * n];
            sgemm_bias_act(m, n, k, &a, &b, &bias, relu, &mut vectored);
            assert_close(&vectored, &scalar, &format!("bias_act relu={relu} {m}x{n}x{k}"));
        }
    }

    // --- threaded SIMD ≡ serial SIMD, bitwise -----------------------------
    // Row-split (67 rows) and column-split (4×4096) shapes; panel
    // starts sit on MR/NR boundaries, so every element runs the same
    // SIMD code path it would serially.
    simd::configure(tier.name()).unwrap();
    for &(m, n, k) in &[(67usize, 129, 40), (4, 4096, 32), (128, 10, 32)] {
        let a = fill(m * k, 7);
        let b = fill(k * n, 8);
        let bias = fill(n, 9);
        let seed = fill(m * n, 10);

        pool::configure_threads(1);
        let mut serial = seed.clone();
        sgemm(false, false, m, n, k, &a, &b, &mut serial);
        let mut serial_fused = vec![0.0f32; m * n];
        sgemm_bias_act(m, n, k, &a, &b, &bias, true, &mut serial_fused);

        pool::configure_threads(4);
        let mut threaded = seed.clone();
        sgemm(false, false, m, n, k, &a, &b, &mut threaded);
        let mut threaded_fused = vec![0.0f32; m * n];
        sgemm_bias_act(m, n, k, &a, &b, &bias, true, &mut threaded_fused);

        assert!(serial == threaded, "{m}x{n}x{k}: threaded SIMD != serial SIMD bitwise");
        assert!(
            serial_fused == threaded_fused,
            "{m}x{n}x{k}: threaded fused SIMD != serial fused SIMD bitwise"
        );
    }

    // Leave the process in the detected default state.
    pool::configure_threads(1);
    pool::shutdown_local_pool();
    simd::configure("auto").unwrap();
}
