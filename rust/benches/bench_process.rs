//! Real-socket throughput of the PROCESS backend: worker-steps/sec and
//! measured wire costs vs worker count p ∈ {1, 2, 4} and communication
//! period τ ∈ {4, 16, 64}, EASGD on the deterministic quadratic oracle
//! — each cell spawns p OS processes that exchange flat-θ frames with
//! the parameter-server master over TCP, so the grid measures the
//! executor (fork/exec + serialize + socket round trips), not the
//! model.
//!
//!     cargo bench --bench bench_process            # full grid
//!     cargo bench --bench bench_process -- --quick # smoke (CI)
//!
//! Expected shape: per-round wire cost is roughly constant (one
//! n-element frame each way), so steps/sec rises with τ — the thesis'
//! communication-period story measured on a real transport. The
//! serialize and transfer columns are the measured per-cell totals that
//! single-address-space backends can only model.

use elastic_train::cluster::CostModel;
use elastic_train::coordinator::{run_process, DriverConfig, Method, OracleSpec, ProcessOpts};
use elastic_train::figures::benchkit::{append_history, git_sha, unix_time};
use std::time::Instant;

/// Per-step gradient size: big enough that a frame is a real message
/// (256 KiB of f32), small enough for a quick grid.
const N_PARAMS: usize = 65_536;

struct Cell {
    tau: u32,
    p: usize,
    steps_per_sec: f64,
    serialize_s: f64,
    transfer_s: f64,
    frames: u64,
    payload_mb: f64,
}

fn run_cell(tau: u32, p: usize, total_steps: u64) -> Cell {
    let spec = OracleSpec::Quadratic { n: N_PARAMS, h: 1.0, x0: 0.0, target: 1.0, noise: 0.0 };
    let cfg = DriverConfig {
        eta: 0.05,
        method: Method::easgd_default(p, tau),
        cost: CostModel::cifar_like(N_PARAMS), // unused by the process backend
        horizon: 120.0,                        // real-seconds safety net
        eval_every: 1e6,                       // no mid-run snapshots
        seed: 9,
        max_steps: total_steps,
        lr_decay_gamma: 0.0,
    };
    let opts = ProcessOpts {
        exe: Some(std::path::PathBuf::from(env!("CARGO_BIN_EXE_repro"))),
        ..ProcessOpts::default()
    };
    let t0 = Instant::now();
    let r = run_process(&spec, p, &cfg, &opts).expect("bench run");
    let elapsed = t0.elapsed().as_secs_f64();
    assert!(!r.diverged, "easgd tau={tau} p={p} diverged");
    let wire = r.wire.expect("process runs report wire stats");
    Cell {
        tau,
        p,
        steps_per_sec: r.total_steps as f64 / elapsed,
        serialize_s: r.breakdown.serialize,
        transfer_s: r.breakdown.transfer,
        frames: wire.frames,
        payload_mb: wire.payload_bytes as f64 * 1e-6,
    }
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick" || a == "quick");
    let steps: u64 = if quick { 2_000 } else { 12_000 };
    println!(
        "process backend: EASGD on quadratic(n={N_PARAMS}) over TCP, {steps} steps/cell, \
         workers as OS processes\n"
    );
    println!(
        "{:>5} {:>3} {:>12} {:>12} {:>12} {:>8} {:>10}",
        "tau", "p", "steps/sec", "serialize_s", "transfer_s", "frames", "wire_MB"
    );

    let taus: &[u32] = if quick { &[4, 64] } else { &[4, 16, 64] };
    let ps: &[usize] = if quick { &[1, 2] } else { &[1, 2, 4] };
    let mut cells: Vec<Cell> = Vec::new();
    for &tau in taus {
        for &p in ps {
            let c = run_cell(tau, p, steps);
            println!(
                "{:>5} {:>3} {:>12.0} {:>12.4} {:>12.4} {:>8} {:>10.2}",
                c.tau, c.p, c.steps_per_sec, c.serialize_s, c.transfer_s, c.frames, c.payload_mb
            );
            cells.push(c);
        }
        println!();
    }

    // Acceptance shape: at any fixed p, fewer rounds (larger τ) must
    // not slow the run down (20% slack — fork/exec noise is real).
    for &p in ps {
        let col: Vec<&Cell> = cells.iter().filter(|c| c.p == p).collect();
        let monotone = col.windows(2).all(|w| w[1].steps_per_sec >= w[0].steps_per_sec * 0.8);
        println!(
            "p={p} steps/sec vs tau: {} ({})",
            if monotone { "NON-DEGRADING" } else { "DEGRADING" },
            col.iter()
                .map(|c| format!("tau{}={:.0}", c.tau, c.steps_per_sec))
                .collect::<Vec<_>>()
                .join(" "),
        );
    }

    // Per-PR history, keyed by git SHA like BENCH_oracle.json.
    let rows: Vec<String> = cells
        .iter()
        .map(|c| {
            format!(
                "      {{\"tau\": {}, \"p\": {}, \"steps_per_sec\": {:.1}, \
                 \"serialize_s\": {:.6}, \"transfer_s\": {:.6}, \"frames\": {}, \
                 \"payload_mb\": {:.3}}}",
                c.tau, c.p, c.steps_per_sec, c.serialize_s, c.transfer_s, c.frames, c.payload_mb
            )
        })
        .collect();
    let entry = format!(
        "  {{\n    \"bench\": \"process\",\n    \"sha\": \"{}\",\n    \"unix_time\": {},\n    \
         \"quick\": {},\n    \"unit\": \"steps_per_sec\",\n    \"results\": [\n{}\n    ]\n  }}",
        git_sha(),
        unix_time(),
        quick,
        rows.join(",\n")
    );
    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_process.json");
    append_history(out, &entry);
    println!("appended history entry to {out}");
}
