//! Tree-equivalence suite: the virtual-time tree simulator and the
//! real-thread tree backend are different machines running the SAME
//! protocol (Alg. 6) — on a deterministic objective they must land in
//! the same place, the simulator must stay bitwise reproducible, the
//! tree's elastic fixed point must sit at the conserved mean, and the
//! method/backend/topology gate must refuse what the tree does not
//! define.

use elastic_train::cluster::CostModel;
use elastic_train::coordinator::{
    run_tree_sim, run_tree_threaded, run_with_backend_topology, Backend, DriverConfig, Method,
    MlpOracle, QuadraticOracle, TreeLayout, Topology, TreeScheme, TreeSpec,
};
use elastic_train::data::BlobDataset;
use elastic_train::model::MlpConfig;
use elastic_train::rng::Rng;
use std::sync::Arc;

fn fast_cost(n_params: usize) -> CostModel {
    CostModel {
        t_grad: 1e-3,
        jitter: 0.0, // synchronous: no compute jitter
        t_data: 0.0,
        latency: 1e-5,
        bandwidth: 1e12,
        param_bytes: (n_params * 4) as f64,
    }
}

/// (a) τ = 1 / zero jitter on the deterministic quadratic: both tree
/// backends contract every node to the target (the unique fixed point
/// of elastic absorption + vanishing gradient), so the root losses
/// agree within 1e-4. The tolerance absorbs f32 rounding along the two
/// different interleavings.
#[test]
fn thread_tree_matches_sim_tree_on_quadratic() {
    let (n, leaves, steps) = (512usize, 4usize, 20_000u64);
    let spec = TreeSpec::new(2, TreeScheme::UpDown { tau_up: 1, tau_down: 1 });
    let method = Method::Easgd { alpha: 0.3, tau: 1 };

    let mut sim_oracles = QuadraticOracle::family(n, 1.0, 0.0, 1.0, 0.0, leaves);
    let sim_cfg = DriverConfig {
        eta: 0.1,
        method,
        cost: fast_cost(n),
        horizon: 1e6, // steps bound first
        eval_every: 1e6,
        seed: 11,
        max_steps: steps,
        lr_decay_gamma: 0.0,
    };
    let sim = run_tree_sim(&mut sim_oracles, &sim_cfg, &spec).unwrap();

    let mut thr_oracles = QuadraticOracle::family(n, 1.0, 0.0, 1.0, 0.0, leaves);
    let thr_cfg = DriverConfig {
        horizon: 60.0, // REAL seconds safety net; steps bound first
        ..sim_cfg.clone()
    };
    let thr = run_tree_threaded(&mut thr_oracles, &thr_cfg, &spec).unwrap();

    assert!(!sim.diverged && !thr.diverged);
    assert_eq!(sim.total_steps, steps);
    assert_eq!(thr.total_steps, steps);
    let ls = sim.curve.last().unwrap().train_loss;
    let lt = thr.curve.last().unwrap().train_loss;
    // Both roots at the optimum (loss 0 for ½(θ−1)² from θ=0)...
    assert!(ls < 1e-5, "sim-tree final root loss {ls}");
    assert!(lt < 1e-5, "thread-tree final root loss {lt}");
    // ...and within the required tolerance of each other.
    assert!((ls - lt).abs() < 1e-4, "sim {ls} vs thread {lt}");
}

/// (b) The tree simulator is bitwise deterministic: two runs with the
/// same seed produce identical step counts and identical curves (every
/// field, exact float equality) — jittered costs and all.
#[test]
fn sim_tree_is_bitwise_deterministic() {
    let run = || {
        let data = Arc::new(BlobDataset::generate(8, 4, 1024, 256, 0.8, 1));
        let mcfg = MlpConfig::new(&[8, 16, 4], 1e-4);
        let mut oracles = MlpOracle::family(data, &mcfg, 32, 16);
        let spec = TreeSpec::new(4, TreeScheme::MultiScale { tau1: 2, tau2: 8 });
        let cfg = DriverConfig {
            eta: 0.1,
            method: Method::Easgd { alpha: 0.9 / 5.0, tau: 1 },
            cost: CostModel {
                t_grad: 1e-3,
                jitter: 0.1,
                t_data: 1e-4,
                latency: 1e-4,
                bandwidth: 1e9,
                param_bytes: 1000.0,
            },
            horizon: 0.4,
            eval_every: 0.1,
            seed: 23,
            max_steps: 1_000_000,
            lr_decay_gamma: 0.0,
        };
        run_tree_sim(&mut oracles, &cfg, &spec).unwrap()
    };
    let (a, b) = (run(), run());
    assert_eq!(a.total_steps, b.total_steps);
    assert_eq!(a.curve.len(), b.curve.len());
    for (pa, pb) in a.curve.iter().zip(&b.curve) {
        assert_eq!(pa.time, pb.time);
        assert_eq!(pa.train_loss, pb.train_loss);
        assert_eq!(pa.test_loss, pb.test_loss);
        assert_eq!(pa.test_error, pb.test_error);
    }
}

/// (c) With zero gradient and synchronized symmetric exchanges along
/// every tree edge (each endpoint moves α toward the other's
/// pre-round snapshot), the per-coordinate mean over ALL nodes is
/// conserved exactly, and the dynamics contract to consensus at that
/// conserved mean — the tree analog of the star's
/// elastic-fixed-point-is-worker-average invariant.
#[test]
fn tree_elastic_fixed_point_preserves_conserved_mean() {
    let (n, alpha) = (32usize, 0.1f32);
    let layout = TreeLayout::dary(4, 16); // 21 nodes, max degree 5
    let mut rng = Rng::new(41);
    let mut params: Vec<Vec<f32>> = (0..layout.n_nodes)
        .map(|_| {
            let mut v = vec![0.0f32; n];
            rng.fill_gaussian_f32(&mut v, 2.0);
            v
        })
        .collect();

    // Conserved quantity: per-coordinate mean over all nodes.
    let conserved: Vec<f64> = (0..n)
        .map(|j| {
            params.iter().map(|p| p[j] as f64).sum::<f64>() / layout.n_nodes as f64
        })
        .collect();

    for _ in 0..3000 {
        // Jacobi round: all deltas from the pre-round snapshot, so the
        // ±α(x_child − x_parent) pairs cancel exactly edge by edge.
        let snap = params.clone();
        for (child, parent) in layout
            .parent
            .iter()
            .enumerate()
            .filter_map(|(c, p)| p.map(|p| (c, p)))
        {
            for j in 0..n {
                let d = alpha * (snap[child][j] - snap[parent][j]);
                params[parent][j] += d;
                params[child][j] -= d;
            }
        }
    }

    for j in 0..n {
        let mean_now =
            params.iter().map(|p| p[j] as f64).sum::<f64>() / layout.n_nodes as f64;
        // The mean never moved...
        assert!(
            (mean_now - conserved[j]).abs() < 1e-4,
            "coord {j}: mean drifted {} -> {mean_now}",
            conserved[j]
        );
        // ...and every node contracted onto it.
        for (i, p) in params.iter().enumerate() {
            assert!(
                (p[j] as f64 - conserved[j]).abs() < 1e-3,
                "node {i} coord {j}: {} vs conserved mean {}",
                p[j],
                conserved[j]
            );
        }
    }
}

/// (d) The public dispatch refuses unsupported method/topology/backend
/// combinations with a descriptive error instead of silently falling
/// back to another executor — and the star matrix is complete: every
/// method runs there on both backends.
#[test]
fn dispatch_gates_unsupported_combinations() {
    let tree = Topology::Tree(TreeSpec::new(2, TreeScheme::UpDown { tau_up: 1, tau_down: 4 }));
    let cfg = |method: Method| DriverConfig {
        eta: 0.05,
        method,
        cost: fast_cost(64),
        horizon: 0.01,
        eval_every: 1.0,
        seed: 1,
        max_steps: 10,
        lr_decay_gamma: 0.0,
    };

    // DOWNPOUR has no tree form — on either backend.
    for backend in [Backend::Sim, Backend::Thread] {
        let mut oracles = QuadraticOracle::family(64, 1.0, 0.0, 1.0, 0.0, 2);
        let e = run_with_backend_topology(
            backend,
            &mut oracles,
            &cfg(Method::Downpour { tau: 1 }),
            &tree,
        )
        .unwrap_err();
        assert!(format!("{e}").contains("no tree form"), "{backend:?}: {e}");
    }

    // Master-coupled methods run on the star under BOTH backends (the
    // thread backend serializes them through the master actor).
    for backend in [Backend::Sim, Backend::Thread] {
        let mut oracles = QuadraticOracle::family(64, 1.0, 0.0, 1.0, 0.0, 2);
        let r = run_with_backend_topology(
            backend,
            &mut oracles,
            &cfg(Method::MDownpour { delta: 0.9 }),
            &Topology::Star,
        )
        .unwrap();
        assert!(!r.curve.is_empty(), "{backend:?}");
    }
}

/// (e) Tree and star agree on the degenerate single-worker case: with
/// one leaf/worker and no communication partners, both topologies are
/// plain local SGD and reach the same quadratic optimum.
#[test]
fn single_worker_tree_matches_single_worker_star() {
    let mk = || QuadraticOracle::family(32, 2.0, 0.0, 1.0, 0.0, 1);
    let cfg = DriverConfig {
        eta: 0.1,
        method: Method::Easgd { alpha: 0.3, tau: 1 },
        cost: fast_cost(32),
        horizon: 1e6,
        eval_every: 1e6,
        seed: 3,
        max_steps: 600,
        lr_decay_gamma: 0.0,
    };
    let tree = Topology::Tree(TreeSpec::new(2, TreeScheme::UpDown { tau_up: 1, tau_down: 1 }));
    let t = run_with_backend_topology(Backend::Sim, &mut mk(), &cfg, &tree).unwrap();
    let s = run_with_backend_topology(Backend::Sim, &mut mk(), &cfg, &Topology::Star).unwrap();
    assert!(!t.diverged && !s.diverged);
    let (lt, ls) = (
        t.curve.last().unwrap().train_loss,
        s.curve.last().unwrap().train_loss,
    );
    assert!(lt < 1e-6 && ls < 1e-6, "tree {lt} star {ls}");
}
