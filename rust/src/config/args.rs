//! `key=value` CLI argument parsing (the offline crate set has no clap).
//!
//! Grammar: positional words first, then any number of `key=value`
//! pairs; `--key=value` and `--flag` are also accepted.
//!
//! Typed getters are STRICT: an absent key yields the default, but a
//! present-and-malformed value is a real error naming the key and the
//! offending value. (They used to `unwrap_or(default)`, so `p=abc` ran
//! the sweep at the default p and corrupted figure comparisons.)

use crate::error::Result;
use std::collections::BTreeMap;
use std::str::FromStr;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub kv: BTreeMap<String, String>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(it: I) -> Args {
        let mut a = Args::default();
        for raw in it {
            let s = raw.trim_start_matches("--");
            if let Some(eq) = s.find('=') {
                a.kv.insert(s[..eq].to_string(), s[eq + 1..].to_string());
            } else if raw.starts_with("--") {
                a.kv.insert(s.to_string(), "true".to_string());
            } else {
                a.positional.push(raw);
            }
        }
        a
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.kv.get(key).map(|s| s.as_str())
    }

    /// Absent key ⇒ `Ok(default)`; malformed value ⇒ an error naming
    /// the key and the offending value.
    fn get_parsed<T: FromStr>(&self, key: &str, default: T, ty: &str) -> Result<T> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| crate::err!("invalid value for {key}: '{v}' (expected {ty})")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        self.get_parsed(key, default, "a number")
    }

    pub fn get_f32(&self, key: &str, default: f32) -> Result<f32> {
        self.get_parsed(key, default, "a number")
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        self.get_parsed(key, default, "a non-negative integer")
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        self.get_parsed(key, default, "a non-negative integer")
    }

    pub fn get_u32(&self, key: &str, default: u32) -> Result<u32> {
        self.get_parsed(key, default, "a non-negative integer")
    }

    pub fn get_u16(&self, key: &str, default: u16) -> Result<u16> {
        self.get_parsed(key, default, "a port number (0-65535)")
    }

    pub fn get_bool(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => Err(crate::err!(
                "invalid value for {key}: '{v}' (expected true|false|1|0|yes|no)"
            )),
        }
    }

    pub fn get_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(words: &[&str]) -> Args {
        Args::parse(words.iter().map(|s| s.to_string()))
    }

    #[test]
    fn positional_and_kv() {
        let a = parse(&["figure", "fig3.1", "p=16", "--eta=0.05", "--quick"]);
        assert_eq!(a.positional, vec!["figure", "fig3.1"]);
        assert_eq!(a.get_usize("p", 1).unwrap(), 16);
        assert!((a.get_f64("eta", 0.0).unwrap() - 0.05).abs() < 1e-12);
        assert!(a.get_bool("quick", false).unwrap());
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]);
        assert_eq!(a.get_usize("p", 4).unwrap(), 4);
        assert_eq!(a.get_str("method", "easgd"), "easgd");
        assert!(!a.get_bool("quick", false).unwrap());
    }

    #[test]
    fn malformed_values_are_rejected_naming_key_and_value() {
        // The seed silently fell back to the default here — `p=abc`
        // ran the sweep at the default p. Now it is a descriptive error.
        let a = parse(&["p=abc", "eta=fast", "tau=0.5", "verbose=maybe"]);
        let e = a.get_usize("p", 7).unwrap_err();
        let msg = format!("{e}");
        // R7 pin (tests/repo_lint.rs): both err sites' fragments verbatim.
        assert!(msg.contains("invalid value for"), "{msg}");
        assert!(msg.contains("p") && msg.contains("abc"), "{msg}");
        assert!(format!("{}", a.get_f32("eta", 0.1).unwrap_err()).contains("fast"));
        assert!(format!("{}", a.get_u32("tau", 1).unwrap_err()).contains("0.5"));
        let bool_msg = format!("{}", a.get_bool("verbose", false).unwrap_err());
        assert!(bool_msg.contains("maybe"), "{bool_msg}");
        assert!(bool_msg.contains("expected true|false|1|0|yes|no"), "{bool_msg}");
    }

    #[test]
    fn explicit_false_bools_parse() {
        let a = parse(&["quick=false", "full=no", "deep=0"]);
        assert!(!a.get_bool("quick", true).unwrap());
        assert!(!a.get_bool("full", true).unwrap());
        assert!(!a.get_bool("deep", true).unwrap());
    }
}
