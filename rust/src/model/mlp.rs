//! Native MLP classifier with hand-written backprop — the cheap,
//! allocation-conscious gradient oracle behind the Chapter-4/6 figure
//! sweeps (a stand-in for the thesis' CIFAR conv nets; see DESIGN.md §2:
//! the distributed-optimizer dynamics under study are model-agnostic,
//! and at p = 256 simulated workers the PJRT transformer would be
//! wall-clock prohibitive).
//!
//! Architecture: input → [hidden ReLU]× → linear → softmax + CE, with
//! optional l2 regularization (thesis §4.1). Parameters live in ONE
//! flat f32 buffer so the coordinator's elastic/momentum ops
//! ([`super::flat`]) apply directly.

use crate::rng::Rng;

/// Layer sizes: `dims = [in, h1, ..., out]`.
#[derive(Clone, Debug)]
pub struct MlpConfig {
    pub dims: Vec<usize>,
    pub l2: f32,
}

impl MlpConfig {
    pub fn new(dims: &[usize], l2: f32) -> Self {
        assert!(dims.len() >= 2);
        Self { dims: dims.to_vec(), l2 }
    }

    /// The sweep default: a 3-layer net small enough for 256 workers.
    pub fn sweep_default() -> Self {
        Self::new(&[32, 64, 32, 10], 1e-4)
    }

    pub fn n_params(&self) -> usize {
        self.dims
            .windows(2)
            .map(|w| w[0] * w[1] + w[1]) // W + b per layer
            .sum()
    }

    pub fn n_classes(&self) -> usize {
        *self.dims.last().unwrap()
    }
}

/// The model: holds no parameters itself — they are passed as flat
/// slices — only scratch buffers for fwd/bwd (re-used across calls so
/// the sweep hot loop is allocation-free).
pub struct Mlp {
    cfg: MlpConfig,
    acts: Vec<Vec<f32>>,  // post-activation per layer (incl. input copy)
    pre: Vec<Vec<f32>>,   // pre-activation per layer
    grads_a: Vec<Vec<f32>>, // activation gradients
}

impl Mlp {
    pub fn new(cfg: MlpConfig) -> Self {
        let acts = cfg.dims.iter().map(|&d| vec![0.0; d]).collect();
        let pre = cfg.dims[1..].iter().map(|&d| vec![0.0; d]).collect();
        let grads_a = cfg.dims.iter().map(|&d| vec![0.0; d]).collect();
        Self { cfg, acts, pre, grads_a }
    }

    pub fn config(&self) -> &MlpConfig {
        &self.cfg
    }

    /// He-scaled random init into a fresh flat buffer.
    pub fn init_params(&self, rng: &mut Rng) -> Vec<f32> {
        let mut theta = vec![0.0f32; self.cfg.n_params()];
        let mut off = 0;
        for w in self.cfg.dims.windows(2) {
            let (fan_in, fan_out) = (w[0], w[1]);
            let std = (2.0 / fan_in as f64).sqrt() as f32;
            rng.fill_gaussian_f32(&mut theta[off..off + fan_in * fan_out], std);
            off += fan_in * fan_out;
            // biases zero (thesis §4.1 CIFAR init).
            off += fan_out;
        }
        theta
    }

    /// Forward pass; returns the loss for (x, label). Logits stay in the
    /// last activation buffer.
    fn forward(&mut self, theta: &[f32], x: &[f32]) {
        assert_eq!(x.len(), self.cfg.dims[0]);
        self.acts[0].copy_from_slice(x);
        let mut off = 0;
        let n_layers = self.cfg.dims.len() - 1;
        for l in 0..n_layers {
            let (din, dout) = (self.cfg.dims[l], self.cfg.dims[l + 1]);
            let w = &theta[off..off + din * dout];
            let b = &theta[off + din * dout..off + din * dout + dout];
            off += din * dout + dout;
            // Split borrows: acts[l] is input, pre[l] is output.
            let (inp, pre) = {
                let (a, b2) = (&self.acts[l], &mut self.pre[l]);
                (a.as_slice(), b2)
            };
            for (j, (pj, bj)) in pre.iter_mut().zip(b).enumerate() {
                // column-major access: w[i * dout + j]
                let mut s = *bj;
                for (i, xi) in inp.iter().enumerate() {
                    s += xi * w[i * dout + j];
                }
                *pj = s;
                let _ = j;
            }
            let last = l == n_layers - 1;
            // acts and pre are distinct fields: disjoint borrows.
            let (acts, pre) = (&mut self.acts, &self.pre);
            for (aj, pj) in acts[l + 1].iter_mut().zip(&pre[l]) {
                *aj = if last { *pj } else { pj.max(0.0) };
            }
        }
    }

    /// Loss only (evaluation path).
    pub fn loss(&mut self, theta: &[f32], x: &[f32], label: usize) -> f32 {
        self.forward(theta, x);
        let logits = self.acts.last().unwrap();
        let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let lse = m + logits.iter().map(|z| (z - m).exp()).sum::<f32>().ln();
        let nll = lse - logits[label];
        let l2: f32 = if self.cfg.l2 > 0.0 {
            0.5 * self.cfg.l2 * theta.iter().map(|t| t * t).sum::<f32>()
        } else {
            0.0
        };
        nll + l2
    }

    /// Predicted class (evaluation path).
    pub fn predict(&mut self, theta: &[f32], x: &[f32]) -> usize {
        self.forward(theta, x);
        let logits = self.acts.last().unwrap();
        logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0
    }

    /// Accumulate ∂loss/∂θ for one sample into `grad` (caller zeroes or
    /// scales). Returns the sample loss. This is THE inner loop of every
    /// Chapter-4/6 sweep.
    pub fn grad(&mut self, theta: &[f32], x: &[f32], label: usize, grad: &mut [f32]) -> f32 {
        assert_eq!(grad.len(), theta.len());
        self.forward(theta, x);
        let n_layers = self.cfg.dims.len() - 1;

        // Softmax CE gradient at the top.
        let logits = self.acts.last().unwrap();
        let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let exps: Vec<f32> = logits.iter().map(|z| (z - m).exp()).collect();
        let sum: f32 = exps.iter().sum();
        let loss = sum.ln() + m - logits[label];
        {
            let top = self.grads_a.last_mut().unwrap();
            for (g, e) in top.iter_mut().zip(&exps) {
                *g = e / sum;
            }
            top[label] -= 1.0;
        }

        // Backward through layers.
        let mut offsets = Vec::with_capacity(n_layers);
        let mut off = 0;
        for w in self.cfg.dims.windows(2) {
            offsets.push(off);
            off += w[0] * w[1] + w[1];
        }
        for l in (0..n_layers).rev() {
            let (din, dout) = (self.cfg.dims[l], self.cfg.dims[l + 1]);
            let woff = offsets[l];
            // dpre = dact ⊙ relu' (last layer is linear).
            let last = l == n_layers - 1;
            let dpre: Vec<f32> = self.grads_a[l + 1]
                .iter()
                .zip(&self.pre[l])
                .map(|(g, p)| if last || *p > 0.0 { *g } else { 0.0 })
                .collect();
            // Weight and bias grads.
            {
                let inp = &self.acts[l];
                let gw = &mut grad[woff..woff + din * dout];
                for (i, xi) in inp.iter().enumerate() {
                    if *xi == 0.0 {
                        continue;
                    }
                    let row = &mut gw[i * dout..(i + 1) * dout];
                    for (gj, dj) in row.iter_mut().zip(&dpre) {
                        *gj += xi * dj;
                    }
                }
                let gb = &mut grad[woff + din * dout..woff + din * dout + dout];
                for (g, d) in gb.iter_mut().zip(&dpre) {
                    *g += d;
                }
            }
            // Input gradient for the next level down.
            if l > 0 {
                let w = &theta[woff..woff + din * dout];
                let ga = &mut self.grads_a[l];
                for (i, gi) in ga.iter_mut().enumerate() {
                    let row = &w[i * dout..(i + 1) * dout];
                    *gi = row.iter().zip(&dpre).map(|(wj, dj)| wj * dj).sum();
                }
            }
        }

        // l2 term.
        if self.cfg.l2 > 0.0 {
            for (g, t) in grad.iter_mut().zip(theta) {
                *g += self.cfg.l2 * t;
            }
        }
        loss + if self.cfg.l2 > 0.0 {
            0.5 * self.cfg.l2 * theta.iter().map(|t| t * t).sum::<f32>()
        } else {
            0.0
        }
    }

    /// Mini-batch gradient: mean over the batch. Returns mean loss.
    pub fn batch_grad(
        &mut self,
        theta: &[f32],
        xs: &[(Vec<f32>, usize)],
        grad: &mut [f32],
    ) -> f32 {
        grad.iter_mut().for_each(|g| *g = 0.0);
        let mut loss = 0.0;
        for (x, y) in xs {
            loss += self.grad(theta, x, *y, grad);
        }
        let inv = 1.0 / xs.len() as f32;
        grad.iter_mut().for_each(|g| *g *= inv);
        // l2 was added per-sample; keep its mean (same value each time).
        loss * inv
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> (Mlp, Vec<f32>) {
        let cfg = MlpConfig::new(&[4, 6, 3], 0.0);
        let mlp = Mlp::new(cfg);
        let mut rng = Rng::new(5);
        let theta = mlp.init_params(&mut rng);
        (mlp, theta)
    }

    #[test]
    fn param_count_matches_layout() {
        let cfg = MlpConfig::new(&[4, 6, 3], 0.0);
        assert_eq!(cfg.n_params(), 4 * 6 + 6 + 6 * 3 + 3);
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let (mut mlp, mut theta) = tiny();
        let x = vec![0.3, -0.5, 1.2, 0.1];
        let label = 2;
        let mut g = vec![0.0; theta.len()];
        mlp.grad(&theta, &x, label, &mut g);
        let eps = 1e-3f32;
        let mut rng = Rng::new(8);
        for _ in 0..25 {
            let i = rng.below(theta.len());
            let orig = theta[i];
            theta[i] = orig + eps;
            let lp = mlp.loss(&theta, &x, label);
            theta[i] = orig - eps;
            let lm = mlp.loss(&theta, &x, label);
            theta[i] = orig;
            let fd = (lp - lm) / (2.0 * eps);
            assert!((fd - g[i]).abs() < 2e-3 * (1.0 + fd.abs()),
                    "param {i}: fd {fd} vs analytic {}", g[i]);
        }
    }

    #[test]
    fn gradient_with_l2_matches_finite_differences() {
        let cfg = MlpConfig::new(&[3, 5, 2], 1e-2);
        let mut mlp = Mlp::new(cfg);
        let mut rng = Rng::new(6);
        let mut theta = mlp.init_params(&mut rng);
        let x = vec![1.0, -1.0, 0.5];
        let mut g = vec![0.0; theta.len()];
        mlp.grad(&theta, &x, 1, &mut g);
        let eps = 1e-3f32;
        for i in [0usize, 7, 14, 20] {
            let orig = theta[i];
            theta[i] = orig + eps;
            let lp = mlp.loss(&theta, &x, 1);
            theta[i] = orig - eps;
            let lm = mlp.loss(&theta, &x, 1);
            theta[i] = orig;
            let fd = (lp - lm) / (2.0 * eps);
            assert!((fd - g[i]).abs() < 3e-3 * (1.0 + fd.abs()));
        }
    }

    #[test]
    fn training_reduces_loss_and_fits_separable_data() {
        let cfg = MlpConfig::new(&[2, 16, 2], 0.0);
        let mut mlp = Mlp::new(cfg);
        let mut rng = Rng::new(7);
        let mut theta = mlp.init_params(&mut rng);
        // Two gaussian blobs.
        let mut data = Vec::new();
        for _ in 0..100 {
            let y = rng.below(2);
            let cx = if y == 0 { -1.0 } else { 1.0 };
            data.push((
                vec![rng.normal(cx, 0.3) as f32, rng.normal(-cx, 0.3) as f32],
                y,
            ));
        }
        let mut g = vec![0.0; theta.len()];
        let l0 = mlp.batch_grad(&theta, &data, &mut g);
        for _ in 0..200 {
            mlp.batch_grad(&theta, &data, &mut g);
            crate::model::flat::sgd_step(&mut theta, &g, 0.5);
        }
        let l1 = mlp.batch_grad(&theta, &data, &mut g);
        assert!(l1 < l0 * 0.2, "loss {l0} -> {l1}");
        let correct = data
            .iter()
            .filter(|(x, y)| mlp.predict(&theta, x) == *y)
            .count();
        assert!(correct >= 95, "accuracy {correct}/100");
    }

    #[test]
    fn batch_grad_is_mean_of_sample_grads() {
        let (mut mlp, theta) = tiny();
        let data = vec![
            (vec![0.1, 0.2, 0.3, 0.4], 0usize),
            (vec![-0.5, 0.5, -0.5, 0.5], 1usize),
        ];
        let mut gb = vec![0.0; theta.len()];
        mlp.batch_grad(&theta, &data, &mut gb);
        let mut g1 = vec![0.0; theta.len()];
        let mut g2 = vec![0.0; theta.len()];
        mlp.grad(&theta, &data[0].0, 0, &mut g1);
        mlp.grad(&theta, &data[1].0, 1, &mut g2);
        for i in 0..theta.len() {
            assert!((gb[i] - 0.5 * (g1[i] + g2[i])).abs() < 1e-6);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = MlpConfig::sweep_default();
        let m1 = Mlp::new(cfg.clone()).init_params(&mut Rng::new(3));
        let m2 = Mlp::new(cfg).init_params(&mut Rng::new(3));
        assert_eq!(m1, m2);
    }
}
