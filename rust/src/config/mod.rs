//! Configuration system.
//!
//! The offline crate set has no serde, so this module carries its own
//! substrates (DESIGN.md §2):
//! - [`json`] — a small recursive-descent JSON parser (reads
//!   `artifacts/manifest.json`).
//! - [`args`] — `key=value` CLI argument parsing with typed getters.
//! - [`experiment`] — the experiment config struct the `repro` binary
//!   and the examples share (model preset, cluster costs, method
//!   selection, schedule), loadable from a `key = value` file with CLI
//!   overrides.
//! - [`registry`] — the knob registry: every CLI/config knob with its
//!   type, default, and the surfaces it is threaded through; the
//!   `train` usage text is generated from it and lint R5 diffs it
//!   against the actual structs and forwarding lists.

pub mod args;
pub mod experiment;
pub mod json;
pub mod registry;

pub use args::Args;
pub use experiment::ExperimentConfig;
pub use json::Json;
pub use registry::{usage_text, Knob, Surface, KNOBS};
