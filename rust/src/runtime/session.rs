//! `PjrtModel`: compiled executables + flat-buffer ⇄ literal packing.
//!
//! One instance per process (the PJRT CPU client is shared); every
//! worker's state stays in flat f32 buffers owned by the coordinator,
//! and is packed into shaped literals only at execution time.

use super::artifacts::Artifacts;
use crate::err;
use crate::error::Result;
use std::path::Path;

/// Compiled model + kernels.
pub struct PjrtModel {
    pub artifacts: Artifacts,
    client: xla::PjRtClient,
    train_step: xla::PjRtLoadedExecutable,
    eval_step: xla::PjRtLoadedExecutable,
    sgd_step: xla::PjRtLoadedExecutable,
    elastic: xla::PjRtLoadedExecutable,
    fused_step: xla::PjRtLoadedExecutable,
}

/// Result of one eval_step call.
#[derive(Clone, Copy, Debug)]
pub struct EvalOut {
    pub loss: f32,
    pub n_correct: i32,
}

impl PjrtModel {
    pub fn load(dir: &Path) -> Result<PjrtModel> {
        let artifacts = Artifacts::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| err!("pjrt cpu: {e:?}"))?;
        let train_step = artifacts.compile(&client, "train_step")?;
        let eval_step = artifacts.compile(&client, "eval_step")?;
        let sgd_step = artifacts.compile(&client, "sgd_step")?;
        let elastic = artifacts.compile(&client, "elastic")?;
        let fused_step = artifacts.compile(&client, "fused_step")?;
        Ok(PjrtModel { artifacts, client, train_step, eval_step, sgd_step, elastic, fused_step })
    }

    pub fn n_params(&self) -> usize {
        self.artifacts.n_params
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Pack a flat parameter buffer into per-tensor literals following
    /// the manifest table.
    fn pack_params(&self, theta: &[f32]) -> Result<Vec<xla::Literal>> {
        assert_eq!(theta.len(), self.artifacts.n_params);
        self.artifacts
            .params
            .iter()
            .map(|p| {
                let sl = &theta[p.offset..p.offset + p.size];
                let dims: Vec<i64> = p.shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(sl)
                    .reshape(&dims)
                    .map_err(|e| err!("reshape {}: {e:?}", p.name))
            })
            .collect()
    }

    fn tokens_literal(&self, toks: &[i32]) -> Result<xla::Literal> {
        let d = &self.artifacts.dims;
        assert_eq!(toks.len(), d.batch * d.seq_len);
        xla::Literal::vec1(toks)
            .reshape(&[d.batch as i64, d.seq_len as i64])
            .map_err(|e| err!("token reshape: {e:?}"))
    }

    /// Execute train_step: writes the mean-batch gradient into
    /// `grad_out` (flat) and returns the loss.
    pub fn train_step(
        &self,
        theta: &[f32],
        tokens: &[i32],
        targets: &[i32],
        grad_out: &mut [f32],
    ) -> Result<f32> {
        assert_eq!(grad_out.len(), self.artifacts.n_params);
        let mut inputs = self.pack_params(theta)?;
        inputs.push(self.tokens_literal(tokens)?);
        inputs.push(self.tokens_literal(targets)?);
        let result = self
            .train_step
            .execute::<xla::Literal>(&inputs)
            .map_err(|e| err!("train_step exec: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| err!("to_literal: {e:?}"))?;
        let parts = result.to_tuple().map_err(|e| err!("tuple: {e:?}"))?;
        if parts.len() != 1 + self.artifacts.params.len() {
            return Err(err!(
                "train_step returned {} parts, expected {}",
                parts.len(),
                1 + self.artifacts.params.len()
            ));
        }
        let loss = parts[0]
            .get_first_element::<f32>()
            .map_err(|e| err!("loss: {e:?}"))?;
        for (p, lit) in self.artifacts.params.iter().zip(&parts[1..]) {
            let v = lit
                .to_vec::<f32>()
                .map_err(|e| err!("grad {}: {e:?}", p.name))?;
            grad_out[p.offset..p.offset + p.size].copy_from_slice(&v);
        }
        Ok(loss)
    }

    /// Execute eval_step on one batch.
    pub fn eval_step(&self, theta: &[f32], tokens: &[i32], targets: &[i32]) -> Result<EvalOut> {
        let mut inputs = self.pack_params(theta)?;
        inputs.push(self.tokens_literal(tokens)?);
        inputs.push(self.tokens_literal(targets)?);
        let result = self
            .eval_step
            .execute::<xla::Literal>(&inputs)
            .map_err(|e| err!("eval_step exec: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| err!("to_literal: {e:?}"))?;
        let (loss_l, correct_l) = result
            .to_tuple2()
            .map_err(|e| err!("tuple2: {e:?}"))?;
        Ok(EvalOut {
            loss: loss_l
                .get_first_element::<f32>()
                .map_err(|e| err!("loss: {e:?}"))?,
            n_correct: correct_l
                .get_first_element::<i32>()
                .map_err(|e| err!("correct: {e:?}"))?,
        })
    }

    fn flat_vec_literal(&self, v: &[f32]) -> xla::Literal {
        xla::Literal::vec1(v)
    }

    fn scalar1(&self, x: f32) -> xla::Literal {
        xla::Literal::vec1(&[x])
    }

    /// The PJRT-executed L1 Pallas kernel: (x, v) ← sgd_nesterov(x, v, g).
    /// Exists to cross-validate and benchmark against the native
    /// `model::flat` ops (same semantics).
    pub fn sgd_step_kernel(
        &self,
        x: &mut [f32],
        v: &mut [f32],
        g: &[f32],
        eta: f32,
        delta: f32,
    ) -> Result<()> {
        let inputs = [
            self.flat_vec_literal(x),
            self.flat_vec_literal(v),
            self.flat_vec_literal(g),
            self.scalar1(eta),
            self.scalar1(delta),
        ];
        let result = self
            .sgd_step
            .execute::<xla::Literal>(&inputs)
            .map_err(|e| err!("sgd_step exec: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| err!("to_literal: {e:?}"))?;
        let (xl, vl) = result.to_tuple2().map_err(|e| err!("tuple2: {e:?}"))?;
        x.copy_from_slice(&xl.to_vec::<f32>().map_err(|e| err!("{e:?}"))?);
        v.copy_from_slice(&vl.to_vec::<f32>().map_err(|e| err!("{e:?}"))?);
        Ok(())
    }

    /// The PJRT-executed elastic exchange kernel.
    pub fn elastic_kernel(&self, x: &mut [f32], c: &mut [f32], alpha: f32) -> Result<()> {
        let inputs = [
            self.flat_vec_literal(x),
            self.flat_vec_literal(c),
            self.scalar1(alpha),
        ];
        let result = self
            .elastic
            .execute::<xla::Literal>(&inputs)
            .map_err(|e| err!("elastic exec: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| err!("to_literal: {e:?}"))?;
        let (xl, cl) = result.to_tuple2().map_err(|e| err!("tuple2: {e:?}"))?;
        x.copy_from_slice(&xl.to_vec::<f32>().map_err(|e| err!("{e:?}"))?);
        c.copy_from_slice(&cl.to_vec::<f32>().map_err(|e| err!("{e:?}"))?);
        Ok(())
    }

    /// The fully fused worker step kernel (exchange mask + Nesterov).
    /// Returns the center delta the master must accumulate.
    pub fn fused_step_kernel(
        &self,
        x: &mut [f32],
        v: &mut [f32],
        g: &[f32],
        center: &[f32],
        eta: f32,
        alpha: f32,
        delta: f32,
        do_exchange: bool,
    ) -> Result<Vec<f32>> {
        let inputs = [
            self.flat_vec_literal(x),
            self.flat_vec_literal(v),
            self.flat_vec_literal(g),
            self.flat_vec_literal(center),
            self.scalar1(eta),
            self.scalar1(alpha),
            self.scalar1(delta),
            self.scalar1(if do_exchange { 1.0 } else { 0.0 }),
        ];
        let result = self
            .fused_step
            .execute::<xla::Literal>(&inputs)
            .map_err(|e| err!("fused exec: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| err!("to_literal: {e:?}"))?;
        let (xl, vl, dl) = result.to_tuple3().map_err(|e| err!("tuple3: {e:?}"))?;
        x.copy_from_slice(&xl.to_vec::<f32>().map_err(|e| err!("{e:?}"))?);
        v.copy_from_slice(&vl.to_vec::<f32>().map_err(|e| err!("{e:?}"))?);
        dl.to_vec::<f32>().map_err(|e| err!("{e:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::flat;
    use crate::rng::Rng;

    fn load_model() -> Option<PjrtModel> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        Some(PjrtModel::load(&dir).expect("artifacts present but failed to load"))
    }

    #[test]
    fn train_step_produces_finite_loss_and_grads() {
        let Some(m) = load_model() else { return };
        let theta = m.artifacts.init_params().unwrap();
        let d = m.artifacts.dims;
        let mut corpus = crate::data::MarkovCorpus::new(d.vocab, 0.1, 1);
        let (x, y) = corpus.batch(d.batch, d.seq_len);
        let mut g = vec![0.0f32; m.n_params()];
        let loss = m.train_step(&theta, &x, &y, &mut g).unwrap();
        assert!(loss.is_finite() && loss > 0.0, "loss {loss}");
        // Near-uniform at init (+ l2 term).
        assert!(loss < (d.vocab as f32).ln() + 2.0);
        assert!(g.iter().all(|x| x.is_finite()));
        assert!(flat::norm2(&g) > 0.0);
    }

    #[test]
    fn eval_step_counts_and_losses() {
        let Some(m) = load_model() else { return };
        let theta = m.artifacts.init_params().unwrap();
        let d = m.artifacts.dims;
        let mut corpus = crate::data::MarkovCorpus::new(d.vocab, 0.1, 2);
        let (x, y) = corpus.batch(d.batch, d.seq_len);
        let out = m.eval_step(&theta, &x, &y).unwrap();
        assert!(out.loss.is_finite());
        assert!(out.n_correct >= 0 && out.n_correct <= (d.batch * d.seq_len) as i32);
    }

    #[test]
    fn pjrt_kernels_match_native_flat_ops() {
        // The L1 Pallas kernels (through PJRT) and the native rust hot
        // path must agree bit-for-bit up to f32 rounding.
        let Some(m) = load_model() else { return };
        let n = m.n_params();
        let mut rng = Rng::new(3);
        let mut mk = |_: usize| {
            let mut v = vec![0.0f32; n];
            rng.fill_gaussian_f32(&mut v, 0.5);
            v
        };
        let (x0, v0, g, c0) = (mk(0), mk(1), mk(2), mk(3));

        // sgd_step kernel vs native.
        let (mut xk, mut vk) = (x0.clone(), v0.clone());
        m.sgd_step_kernel(&mut xk, &mut vk, &g, 0.1, 0.9).unwrap();
        let (mut xn, mut vn) = (x0.clone(), v0.clone());
        flat::nesterov_step(&mut xn, &mut vn, &g, 0.1, 0.9);
        for i in 0..n {
            assert!((xk[i] - xn[i]).abs() <= 1e-5 * (1.0 + xn[i].abs()), "x at {i}");
            assert!((vk[i] - vn[i]).abs() <= 1e-5 * (1.0 + vn[i].abs()), "v at {i}");
        }

        // elastic kernel vs native.
        let (mut xk, mut ck) = (x0.clone(), c0.clone());
        m.elastic_kernel(&mut xk, &mut ck, 0.3).unwrap();
        let (mut xn, mut cn) = (x0.clone(), c0.clone());
        flat::elastic_exchange(&mut xn, &mut cn, 0.3);
        for i in 0..n {
            assert!((xk[i] - xn[i]).abs() <= 1e-5 * (1.0 + xn[i].abs()));
            assert!((ck[i] - cn[i]).abs() <= 1e-5 * (1.0 + cn[i].abs()));
        }

        // fused kernel vs native composition.
        let (mut xk, mut vk) = (x0.clone(), v0.clone());
        let dk = m
            .fused_step_kernel(&mut xk, &mut vk, &g, &c0, 0.05, 0.2, 0.9, true)
            .unwrap();
        let (mut xn, mut vn) = (x0.clone(), v0.clone());
        let mut dn = vec![0.0f32; n];
        flat::elastic_pull(&mut xn, &c0, &mut dn, 0.2);
        flat::nesterov_step(&mut xn, &mut vn, &g, 0.05, 0.9);
        for i in 0..n {
            assert!((xk[i] - xn[i]).abs() <= 1e-4 * (1.0 + xn[i].abs()), "fused x {i}");
            assert!((dk[i] - dn[i]).abs() <= 1e-5 * (1.0 + dn[i].abs()), "fused d {i}");
        }
    }

    #[test]
    fn training_loop_reduces_loss_through_pjrt() {
        // A short end-to-end smoke: 30 SGD steps on a fixed batch must
        // cut the loss — the whole three-layer stack composing.
        let Some(m) = load_model() else { return };
        let mut theta = m.artifacts.init_params().unwrap();
        let d = m.artifacts.dims;
        let mut corpus = crate::data::MarkovCorpus::new(d.vocab, 0.1, 7);
        let (x, y) = corpus.batch(d.batch, d.seq_len);
        let mut g = vec![0.0f32; m.n_params()];
        let l0 = m.train_step(&theta, &x, &y, &mut g).unwrap();
        for _ in 0..30 {
            m.train_step(&theta, &x, &y, &mut g).unwrap();
            flat::sgd_step(&mut theta, &g, 0.5);
        }
        let l1 = m.train_step(&theta, &x, &y, &mut g).unwrap();
        assert!(l1 < l0 - 0.3, "loss {l0} -> {l1}");
    }
}
