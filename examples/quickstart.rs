//! Quickstart: asynchronous EASGD with 4 workers on the synthetic
//! CIFAR-like task, via the public API.
//!
//!     cargo run --release --example quickstart
//!
//! What happens: 4 workers each run local SGD on their own data stream;
//! every τ = 10 local steps a worker performs the symmetric elastic
//! exchange with the center variable; the center's loss/error curve is
//! printed against virtual wall-clock time.

use elastic_train::cluster::CostModel;
use elastic_train::coordinator::{run_parallel, DriverConfig, Method, MlpOracle};
use elastic_train::data::BlobDataset;
use elastic_train::model::MlpConfig;
use std::sync::Arc;

fn main() {
    let p = 4;
    let data = Arc::new(BlobDataset::generate(32, 10, 4096, 512, 2.2, 1));
    let mcfg = MlpConfig::new(&[32, 64, 32, 10], 1e-4);
    let mut oracles = MlpOracle::family(data, &mcfg, 32, p);

    let cfg = DriverConfig {
        eta: 0.08,
        method: Method::easgd_default(p, 10), // β = 0.9, α = β/p, τ = 10
        cost: CostModel::cifar_like(mcfg.n_params()),
        horizon: 30.0,
        eval_every: 2.0,
        seed: 0,
        max_steps: u64::MAX / 2,
        lr_decay_gamma: 0.0,
    };
    let r = run_parallel(&mut oracles, &cfg);

    println!("  t[s]    train_loss  test_loss  test_err");
    for pt in &r.curve {
        println!(
            "  {:<6.1}  {:<10.4}  {:<9.4}  {:.3}",
            pt.time, pt.train_loss, pt.test_loss, pt.test_error
        );
    }
    println!(
        "\n{} local steps across {p} workers; best test error {:.3}",
        r.total_steps,
        r.best_test_error()
    );
    println!(
        "time breakdown (Table 4.4 columns): compute {:.1}s data {:.1}s comm {:.1}s",
        r.breakdown.compute, r.breakdown.data, r.breakdown.comm
    );
    assert!(!r.diverged, "quickstart should not diverge");
}
