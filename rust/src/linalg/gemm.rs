//! Blocked f32 GEMM micro-kernels — the compute substrate of the
//! batched MLP oracle.
//!
//! The Chapter-4/6 sweeps and both real-thread backends spend their
//! wall clock inside `Mlp::grad_batch`; every matrix product there
//! lands on [`sgemm`] (accumulating `C += op(A)·op(B)` with transpose
//! flags) or on the fused [`sgemm_bias_act`] forward epilogue (bias
//! broadcast + optional ReLU applied while the accumulator tile is
//! still in registers). The kernels are register-blocked — an
//! [`MR`]×[`NR`] accumulator tile per iteration, streaming
//! contiguously along the output row so the inner loops
//! auto-vectorize — and never allocate: callers own every buffer.
//!
//! Layout convention: everything is row-major and contiguous (leading
//! dimension = column count), which is both how the model stores its
//! batch-major activation matrices and how a flat `theta` stores each
//! layer's `din × dout` weight block. Three storage-aware paths cover
//! the MLP's products without packing scratch:
//!
//! - `A·B` (forward): broadcast kernel, B streamed along rows;
//! - `Aᵀ·B` (weight gradient, sum over the batch): same broadcast
//!   kernel with swapped A strides — the broadcast load is scalar, so
//!   the strided access costs nothing in the vector lanes;
//! - `A·Bᵀ` (input gradient): both operands are walked along their
//!   contiguous k-axis, so each output is one vectorized dot product.
//!
//! Not to be confused with [`super::Matrix`], the f64 substrate of the
//! eigenvalue solver: that one optimizes for robustness on ≤ 20×20
//! stability matrices, this one for throughput on batch × dim panels.

/// Register-tile rows of the broadcast kernels.
pub const MR: usize = 4;
/// Register-tile columns (f32 lanes) of the broadcast kernels.
pub const NR: usize = 16;

/// `C(m×n) += op(A)·op(B)`, accumulating into `C`.
///
/// `op(A)` is `m×k` (stored `k×m` row-major when `ta`), `op(B)` is
/// `k×n` (stored `n×k` row-major when `tb`). All slices must be
/// exactly the implied size.
#[allow(clippy::too_many_arguments)]
pub fn sgemm(
    ta: bool,
    tb: bool,
    m: usize,
    n: usize,
    k: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
) {
    assert_eq!(a.len(), m * k, "A size");
    assert_eq!(b.len(), k * n, "B size");
    assert_eq!(c.len(), m * n, "C size");
    match (ta, tb) {
        // op(A)[i][p] = a[i*ars + p*acs]; broadcast loads are scalar,
        // so runtime strides cost nothing in the vector lanes.
        (false, false) => kernel_broadcast(m, n, k, [k, 1], a, b, c),
        (true, false) => kernel_broadcast(m, n, k, [1, m], a, b, c),
        (false, true) => kernel_dot(m, n, k, a, b, c),
        (true, true) => kernel_both_t(m, n, k, a, b, c),
    }
}

/// Fused forward step: `C(m×n) = act(A(m×k)·B(k×n) + bias)`,
/// overwriting `C`. `bias` (length `n`) is broadcast over rows; the
/// activation is ReLU when `relu`, identity otherwise — applied in the
/// epilogue, before the accumulator tile is stored.
#[allow(clippy::too_many_arguments)]
pub fn sgemm_bias_act(
    m: usize,
    n: usize,
    k: usize,
    a: &[f32],
    b: &[f32],
    bias: &[f32],
    relu: bool,
    c: &mut [f32],
) {
    assert_eq!(a.len(), m * k, "A size");
    assert_eq!(b.len(), k * n, "B size");
    assert_eq!(bias.len(), n, "bias size");
    assert_eq!(c.len(), m * n, "C size");
    let mut i = 0;
    while i + MR <= m {
        let mut j = 0;
        while j + NR <= n {
            let mut acc = [[0.0f32; NR]; MR];
            for accr in acc.iter_mut() {
                accr.copy_from_slice(&bias[j..j + NR]);
            }
            for p in 0..k {
                let brow = &b[p * n + j..p * n + j + NR];
                for (r, accr) in acc.iter_mut().enumerate() {
                    let arp = a[(i + r) * k + p];
                    for (av, &bv) in accr.iter_mut().zip(brow) {
                        *av += arp * bv;
                    }
                }
            }
            for (r, accr) in acc.iter().enumerate() {
                let crow = &mut c[(i + r) * n + j..(i + r) * n + j + NR];
                for (cv, &av) in crow.iter_mut().zip(accr) {
                    *cv = if relu { av.max(0.0) } else { av };
                }
            }
            j += NR;
        }
        if j < n {
            for r in 0..MR {
                let row = i + r;
                let crow = &mut c[row * n + j..(row + 1) * n];
                crow.copy_from_slice(&bias[j..]);
                for p in 0..k {
                    let arp = a[row * k + p];
                    let brow = &b[p * n + j..(p + 1) * n];
                    for (cv, &bv) in crow.iter_mut().zip(brow) {
                        *cv += arp * bv;
                    }
                }
                if relu {
                    for cv in crow.iter_mut() {
                        *cv = cv.max(0.0);
                    }
                }
            }
        }
        i += MR;
    }
    while i < m {
        let crow = &mut c[i * n..(i + 1) * n];
        crow.copy_from_slice(bias);
        for p in 0..k {
            let aip = a[i * k + p];
            let brow = &b[p * n..(p + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += aip * bv;
            }
        }
        if relu {
            for cv in crow.iter_mut() {
                *cv = cv.max(0.0);
            }
        }
        i += 1;
    }
}

/// `out[j] += Σ_i a[i][j]` over an `m×n` row-major panel — the bias
/// gradient's column reduction, batched.
pub fn col_sums_accum(m: usize, n: usize, a: &[f32], out: &mut [f32]) {
    assert_eq!(a.len(), m * n, "A size");
    assert_eq!(out.len(), n, "out size");
    for i in 0..m {
        let row = &a[i * n..(i + 1) * n];
        for (ov, &av) in out.iter_mut().zip(row) {
            *ov += av;
        }
    }
}

/// Lane-blocked dot product (8 independent partial sums so the
/// reduction auto-vectorizes).
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let head = x.len() / 8 * 8;
    let mut lanes = [0.0f32; 8];
    for (xc, yc) in x[..head].chunks_exact(8).zip(y[..head].chunks_exact(8)) {
        for (l, lane) in lanes.iter_mut().enumerate() {
            *lane += xc[l] * yc[l];
        }
    }
    let mut s: f32 = lanes.iter().sum();
    for (&xv, &yv) in x[head..].iter().zip(&y[head..]) {
        s += xv * yv;
    }
    s
}

/// Broadcast-form kernel: `C += op(A)·B` with `op(A)[i][p] =
/// a[i*strides[0] + p*strides[1]]` and `B` stored `k×n` row-major.
/// Covers the no-transpose and A-transposed cases; the inner loop
/// streams `B` and `C` rows while `op(A)` supplies scalar broadcasts.
fn kernel_broadcast(
    m: usize,
    n: usize,
    k: usize,
    strides: [usize; 2],
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
) {
    let [ars, acs] = strides;
    let mut i = 0;
    while i + MR <= m {
        let mut j = 0;
        while j + NR <= n {
            let mut acc = [[0.0f32; NR]; MR];
            for p in 0..k {
                let brow = &b[p * n + j..p * n + j + NR];
                for (r, accr) in acc.iter_mut().enumerate() {
                    let arp = a[(i + r) * ars + p * acs];
                    for (av, &bv) in accr.iter_mut().zip(brow) {
                        *av += arp * bv;
                    }
                }
            }
            for (r, accr) in acc.iter().enumerate() {
                let crow = &mut c[(i + r) * n + j..(i + r) * n + j + NR];
                for (cv, &av) in crow.iter_mut().zip(accr) {
                    *cv += av;
                }
            }
            j += NR;
        }
        if j < n {
            for p in 0..k {
                let brow = &b[p * n + j..(p + 1) * n];
                for r in 0..MR {
                    let arp = a[(i + r) * ars + p * acs];
                    let crow = &mut c[(i + r) * n + j..(i + r + 1) * n];
                    for (cv, &bv) in crow.iter_mut().zip(brow) {
                        *cv += arp * bv;
                    }
                }
            }
        }
        i += MR;
    }
    while i < m {
        for p in 0..k {
            let aip = a[i * ars + p * acs];
            let brow = &b[p * n..(p + 1) * n];
            let crow = &mut c[i * n..(i + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += aip * bv;
            }
        }
        i += 1;
    }
}

/// Dot-form kernel: `C += A·Bᵀ` with `A` stored `m×k` and `B` stored
/// `n×k` — both operands contiguous along `k`, so every output element
/// is one vectorized [`dot`].
fn kernel_dot(m: usize, n: usize, k: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (j, cv) in crow.iter_mut().enumerate() {
            *cv += dot(arow, &b[j * k..(j + 1) * k]);
        }
    }
}

/// `C += Aᵀ·Bᵀ` — not on any hot path (kept for completeness of the
/// flag matrix); plain triple loop.
fn kernel_both_t(m: usize, n: usize, k: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    for i in 0..m {
        for j in 0..n {
            let mut s = 0.0f32;
            for p in 0..k {
                s += a[p * m + i] * b[j * k + p];
            }
            c[i * n + j] += s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn fill(rng: &mut Rng, len: usize) -> Vec<f32> {
        (0..len).map(|_| rng.normal(0.0, 1.0) as f32).collect()
    }

    fn naive(ta: bool, tb: bool, m: usize, n: usize, k: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0f64;
                for p in 0..k {
                    let av = if ta { a[p * m + i] } else { a[i * k + p] };
                    let bv = if tb { b[j * k + p] } else { b[p * n + j] };
                    s += av as f64 * bv as f64;
                }
                c[i * n + j] = s as f32;
            }
        }
        c
    }

    fn close(got: &[f32], want: &[f32]) {
        assert_eq!(got.len(), want.len());
        for (i, (g, w)) in got.iter().zip(want).enumerate() {
            assert!((g - w).abs() < 1e-3 * (1.0 + w.abs()), "elem {i}: {g} vs {w}");
        }
    }

    #[test]
    fn all_transpose_flags_match_naive_reference() {
        // Sizes chosen to hit the blocked body, the n-tail, the m-tail,
        // and the degenerate single-row/column cases.
        let shapes = [(1, 1, 1), (3, 5, 7), (4, 16, 8), (9, 33, 17), (128, 10, 32), (2, 64, 1)];
        let mut rng = Rng::new(42);
        for &(m, n, k) in &shapes {
            let a = fill(&mut rng, m * k);
            let b = fill(&mut rng, k * n);
            for ta in [false, true] {
                for tb in [false, true] {
                    let mut c = vec![0.0f32; m * n];
                    sgemm(ta, tb, m, n, k, &a, &b, &mut c);
                    close(&c, &naive(ta, tb, m, n, k, &a, &b));
                }
            }
        }
    }

    #[test]
    fn sgemm_accumulates_into_c() {
        let mut rng = Rng::new(7);
        let (m, n, k) = (5, 18, 6);
        let a = fill(&mut rng, m * k);
        let b = fill(&mut rng, k * n);
        let seed = fill(&mut rng, m * n);
        let mut c = seed.clone();
        sgemm(false, false, m, n, k, &a, &b, &mut c);
        let prod = naive(false, false, m, n, k, &a, &b);
        let want: Vec<f32> = seed.iter().zip(&prod).map(|(s, p)| s + p).collect();
        close(&c, &want);
    }

    #[test]
    fn fused_bias_act_matches_unfused() {
        let mut rng = Rng::new(9);
        for &(m, n, k) in &[(1, 10, 32), (6, 16, 4), (7, 33, 13), (128, 10, 64)] {
            let a = fill(&mut rng, m * k);
            let b = fill(&mut rng, k * n);
            let bias = fill(&mut rng, n);
            for relu in [false, true] {
                let mut c = vec![-1.0f32; m * n]; // overwritten, not accumulated
                sgemm_bias_act(m, n, k, &a, &b, &bias, relu, &mut c);
                let prod = naive(false, false, m, n, k, &a, &b);
                let want: Vec<f32> = prod
                    .iter()
                    .enumerate()
                    .map(|(idx, p)| {
                        let v = p + bias[idx % n];
                        if relu {
                            v.max(0.0)
                        } else {
                            v
                        }
                    })
                    .collect();
                close(&c, &want);
            }
        }
    }

    #[test]
    fn col_sums_accumulate() {
        let a = [1.0f32, 2.0, 3.0, 10.0, 20.0, 30.0];
        let mut out = vec![1.0f32; 3];
        col_sums_accum(2, 3, &a, &mut out);
        assert_eq!(out, vec![12.0, 23.0, 34.0]);
    }

    #[test]
    fn dot_handles_tails() {
        for len in [0usize, 1, 7, 8, 9, 17, 64] {
            let x: Vec<f32> = (0..len).map(|i| i as f32).collect();
            let y: Vec<f32> = (0..len).map(|i| (i as f32) * 0.5).collect();
            let want: f32 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
            assert!((dot(&x, &y) - want).abs() < 1e-3 * (1.0 + want.abs()), "len {len}");
        }
    }

    #[test]
    fn zero_sized_dims_are_noops() {
        let mut c = vec![5.0f32; 6];
        sgemm(false, false, 2, 3, 0, &[], &[], &mut c);
        assert_eq!(c, vec![5.0; 6]);
        let mut empty: Vec<f32> = Vec::new();
        sgemm(false, false, 0, 3, 2, &[], &[0.0; 6], &mut empty);
    }
}
