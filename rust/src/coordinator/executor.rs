//! The `Executor` abstraction: one distributed-run contract, two
//! backends, two topologies.
//!
//! A backend takes a family of [`GradOracle`]s (one per worker/leaf,
//! index 0 doubling as the evaluator), a [`DriverConfig`], and a
//! [`Topology`] (flat star or d-ary tree), and produces a [`RunResult`]
//! with the tracked-variable curve:
//!
//! * [`SimExecutor`] — the virtual-time event simulator
//!   ([`super::driver::run_parallel`] for the star,
//!   [`super::tree::run_tree_sim`] for the tree): a min-heap
//!   interleaves nodes by next-event time, communication/data costs
//!   come from the [`crate::cluster::CostModel`], and runs are bitwise
//!   deterministic given the seed. This is the figure-sweep substrate.
//! * [`ThreadExecutor`] — real `std::thread` workers
//!   ([`super::threaded::run_threaded`] for the star, with the center
//!   variable behind one of two `CenterBackend`s — a sharded lock for
//!   the master-decoupled methods, the serialized master actor of
//!   [`super::master_actor`] for MDOWNPOUR / async ADMM;
//!   [`super::tree_threaded::run_tree_threaded`] for the tree: one
//!   actor thread per node, snapshots over `mpsc` channels).
//!   Time-valued config fields are *real* seconds here; runs are not
//!   bit-deterministic (the interleaving is the OS scheduler's), but
//!   the optimization-level outcomes match the simulator (see
//!   `tests/executor_equivalence.rs` and `tests/tree_equivalence.rs`).
//!
//! Which method runs where is a checked matrix ([`check_supported`]):
//! unsupported method/backend/topology combinations get a descriptive
//! error, never a silent fallback.
//!
//! This module also owns the state shared by every backend: the
//! [`DriverConfig`], the per-worker [`WorkerState`], the virtual-time
//! master's [`MasterState`], the master-decoupled local gradient step,
//! and the evaluation-point recorder.

use super::method::Method;
use super::oracle::GradOracle;
use super::topology::Topology;
use crate::cluster::{CostModel, CurvePoint, RunResult};
use crate::error::Result;
use crate::model::flat;
use crate::rng::Rng;

/// Driver configuration for one distributed run, shared by every
/// backend. `horizon` / `eval_every` are *virtual* seconds under
/// [`SimExecutor`] and *real* (wall-clock) seconds under
/// [`ThreadExecutor`]; `cost` is only consulted by the simulator.
#[derive(Clone, Debug)]
pub struct DriverConfig {
    pub eta: f32,
    pub method: Method,
    pub cost: CostModel,
    /// Time horizon (virtual seconds for Sim, real seconds for Thread).
    pub horizon: f64,
    /// Evaluation cadence (same time base as `horizon`).
    pub eval_every: f64,
    pub seed: u64,
    /// Safety cap on total local steps across workers.
    pub max_steps: u64,
    /// Learning-rate decay γ: η_t = η / (1 + γ·t_local)^0.5, driven by
    /// each worker's own clock (thesis Fig 4.13). 0 disables.
    pub lr_decay_gamma: f64,
}

impl DriverConfig {
    /// Reject degenerate time axes and hyperparameters at config time,
    /// with errors naming the field — the alternative is an empty
    /// curve, a zero-division, or a thread backend spinning its whole
    /// step budget before anything notices.
    pub fn validate(&self) -> Result<()> {
        if !self.eta.is_finite() || self.eta <= 0.0 {
            crate::bail!("eta must be a finite positive number, got {}", self.eta);
        }
        if !self.horizon.is_finite() || self.horizon <= 0.0 {
            crate::bail!("horizon must be a finite positive number, got {}", self.horizon);
        }
        if !self.eval_every.is_finite() || self.eval_every <= 0.0 {
            crate::bail!("eval_every must be a finite positive number, got {}", self.eval_every);
        }
        if self.max_steps == 0 {
            crate::bail!("max_steps must be >= 1");
        }
        if !self.lr_decay_gamma.is_finite() || self.lr_decay_gamma < 0.0 {
            crate::bail!("gamma (lr decay) must be finite and >= 0, got {}", self.lr_decay_gamma);
        }
        Ok(())
    }

    #[inline]
    pub(crate) fn eta_at(&self, t_local: u64) -> f32 {
        if self.lr_decay_gamma == 0.0 {
            self.eta
        } else {
            (self.eta as f64 / (1.0 + self.lr_decay_gamma * t_local as f64).sqrt()) as f32
        }
    }
}

/// Per-worker mutable state, identical across backends.
pub(crate) struct WorkerState {
    pub theta: Vec<f32>,
    pub v: Vec<f32>,
    pub grad: Vec<f32>,
    /// EAMSGD lookahead buffer; on the thread backend, async ADMM's
    /// cached copy of the center between exchanges.
    pub scratch: Vec<f32>,
    /// DOWNPOUR accumulated update; ADMM λ.
    pub aux: Vec<f32>,
    pub t_local: u64,
    pub rng: Rng,
}

impl WorkerState {
    /// Build the p-worker family: shared init (thesis §4.1), RNG
    /// streams split off `root` in worker order.
    pub fn family(init: &[f32], p: usize, root: &mut Rng) -> Vec<WorkerState> {
        let n = init.len();
        (0..p)
            .map(|i| WorkerState {
                theta: init.to_vec(),
                v: vec![0.0; n],
                grad: vec![0.0; n],
                scratch: vec![0.0; n],
                aux: vec![0.0; n],
                t_local: 0,
                rng: root.split(i as u64),
            })
            .collect()
    }
}

/// Master-side state of the virtual-time driver (center variable,
/// averaging sequences, master momentum, ADMM contributions). The
/// threaded backend keeps the equivalent state sharded behind locks
/// (`super::threaded::ShardedMaster`) for the decoupled methods, or
/// owned by the master-actor thread
/// (`super::master_actor::ActorMaster`) for the master-coupled ones.
pub(crate) struct MasterState {
    pub center: Vec<f32>,
    /// Averaged center (ADOWNPOUR / MVADOWNPOUR).
    pub z: Option<Vec<f32>>,
    /// Master momentum (MDOWNPOUR).
    pub mv: Option<Vec<f32>>,
    /// ADMM: last (xⁱ − λⁱ) contribution per worker.
    pub contrib: Option<Vec<Vec<f32>>>,
    /// Master clock (# center updates) for the 1/t averaging rate.
    pub clock: u64,
}

impl MasterState {
    pub fn new(method: Method, init: &[f32], p: usize) -> MasterState {
        let n = init.len();
        MasterState {
            center: init.to_vec(),
            z: match method {
                Method::ADownpour { .. } | Method::MvaDownpour { .. } => Some(init.to_vec()),
                _ => None,
            },
            mv: match method {
                Method::MDownpour { .. } => Some(vec![0.0; n]),
                _ => None,
            },
            contrib: match method {
                Method::AdmmAsync { .. } => Some(vec![init.to_vec(); p]),
                _ => None,
            },
            clock: 0,
        }
    }

    /// The variable the thesis tracks: the averaged center when the
    /// method defines one, otherwise the center itself.
    pub fn eval_target(&self) -> &Vec<f32> {
        self.z.as_ref().unwrap_or(&self.center)
    }
}

/// One local gradient step for the master-decoupled methods (EASGD /
/// EAMSGD local dynamics, and the DOWNPOUR pull-push family's local
/// accumulation). Returns the batch loss and advances `t_local`.
///
/// MDOWNPOUR and async ADMM touch master state *inside* the local step
/// (master momentum push / prox toward the center) and therefore never
/// route through here: the virtual-time driver inlines their steps and
/// the thread backend serializes them through the master actor
/// ([`super::master_actor`]); see [`master_coupled`].
pub(crate) fn local_step_decoupled<O: GradOracle>(
    cfg: &DriverConfig,
    w: &mut WorkerState,
    oracle: &mut O,
) -> f32 {
    let eta_t = cfg.eta_at(w.t_local);
    let loss = match cfg.method {
        Method::Eamsgd { delta, .. } => {
            // g at lookahead x + δv (Alg. 2), then v ← δv − ηg ; x ← x + v.
            for (s, (t, v)) in w.scratch.iter_mut().zip(w.theta.iter().zip(&w.v)) {
                *s = t + delta * v;
            }
            let loss = oracle.grad(&w.scratch, &mut w.rng, &mut w.grad);
            flat::nesterov_step(&mut w.theta, &mut w.v, &w.grad, eta_t, delta);
            loss
        }
        Method::MDownpour { .. } | Method::AdmmAsync { .. } => {
            unreachable!("master-coupled methods take the driver's inline step")
        }
        _ => {
            let loss = oracle.grad(&w.theta, &mut w.rng, &mut w.grad);
            flat::sgd_step(&mut w.theta, &w.grad, eta_t);
            if matches!(
                cfg.method,
                Method::Downpour { .. } | Method::ADownpour { .. } | Method::MvaDownpour { .. }
            ) {
                // Accumulate −ηg for the next push.
                for (a, g) in w.aux.iter_mut().zip(&w.grad) {
                    *a -= eta_t * g;
                }
            }
            loss
        }
    };
    w.t_local += 1;
    loss
}

/// Evaluate `theta` and append a curve point at `time`; returns false
/// when the train loss is non-finite (divergence).
pub(crate) fn eval_point<O: GradOracle>(
    oracle: &mut O,
    theta: &[f32],
    time: f64,
    curve: &mut Vec<CurvePoint>,
) -> bool {
    let st = oracle.eval(theta);
    curve.push(CurvePoint {
        time,
        train_loss: st.train_loss,
        test_loss: st.test_loss,
        test_error: st.test_error,
    });
    st.train_loss.is_finite()
}

/// Is this method's master update coupled into every local step
/// (MDOWNPOUR's Nesterov master, Algs 4–5; async ADMM's consensus
/// step)? Master-coupled methods cannot race on a lock-sharded center:
/// the star thread backend serializes them through the dedicated
/// master-actor thread ([`super::master_actor`]); decoupled methods
/// keep the sharded-lock center. Every method runs on both star
/// backends either way — this only selects the center backend.
pub fn master_coupled(method: Method) -> bool {
    matches!(method, Method::MDownpour { .. } | Method::AdmmAsync { .. })
}

/// Does the tree topology define this method? The EASGD tree (Alg. 6)
/// has elastic leaf dynamics only — plain (EASGD) or Nesterov (EAMSGD);
/// the DOWNPOUR/ADMM families have no tree form. Holds for BOTH
/// backends: the tree's method matrix is backend-independent.
pub fn tree_supported(method: Method) -> bool {
    matches!(method, Method::Easgd { .. } | Method::Eamsgd { .. })
}

/// The per-arrival Gauss–Seidel moving rate α the tree backends use
/// (the method's elastic rate), with a descriptive error for methods
/// the tree does not define.
pub(crate) fn tree_alpha(method: Method) -> Result<f32> {
    match method {
        Method::Easgd { alpha, .. } | Method::Eamsgd { alpha, .. } => Ok(alpha),
        other => Err(crate::err!(
            "{} has no tree form: the EASGD tree (Alg. 6) defines elastic leaf \
             dynamics only — use method=easgd or method=eamsgd with topology=tree",
            other.name()
        )),
    }
}

/// The full method × backend × topology support matrix. Returns `Ok`
/// when the combination is implemented, and a descriptive error —
/// never a silent fallback — when it is not.
pub fn check_supported(method: Method, backend: Backend, topo: &Topology) -> Result<()> {
    match topo {
        // Every method runs on the star under the sim and thread
        // backends: the sim driver inlines master-coupled updates, and
        // the thread backend picks its center backend per method
        // (sharded lock for the decoupled methods, the master actor
        // for MDOWNPOUR / async ADMM) — see [`master_coupled`]. The
        // process backend serves the master-DEcoupled methods only:
        // its parameter server applies whole-vector exchanges, and the
        // master-coupled updates would need a per-local-step round
        // trip nothing in the thesis' protocol asks for.
        Topology::Star => match backend {
            Backend::Sim | Backend::Thread => Ok(()),
            Backend::Process if !master_coupled(method) => Ok(()),
            Backend::Process => Err(crate::err!(
                "{} is master-coupled (its master update belongs to every local step) and \
                 is not implemented on backend=process — use backend=thread (master actor) \
                 or backend=sim",
                method.name()
            )),
        },
        Topology::Tree(spec) => {
            spec.validate()?;
            if backend == Backend::Process {
                return Err(crate::err!(
                    "backend=process implements the star topology only (one parameter \
                     server, p socket workers) — use backend=sim or backend=thread for \
                     topology=tree"
                ));
            }
            // Sim and thread both implement the tree for the elastic
            // methods.
            tree_alpha(method).map(|_| ())
        }
    }
}

/// A distributed-run backend.
///
/// The `Send` bound on the oracle is what real parallelism needs; the
/// simulator does not require it when called directly
/// ([`super::driver::run_parallel`] stays bound-free for the non-`Send`
/// PJRT oracle).
pub trait Executor {
    fn name(&self) -> &'static str;

    /// Run on the flat star topology. Method gating happens in
    /// [`check_supported`] / [`run_with_backend`]; the `Result` here
    /// carries RUN failures — a worker thread dying mid-run surfaces
    /// as a descriptive error naming the worker, never a panic that
    /// poisons the center and hangs the survivors.
    fn run<O: GradOracle + Send>(&self, oracles: &mut [O], cfg: &DriverConfig)
        -> Result<RunResult>;

    /// Run on an explicit topology, gating unsupported
    /// method/backend/topology combinations with a descriptive error.
    fn run_topology<O: GradOracle + Send>(
        &self,
        oracles: &mut [O],
        cfg: &DriverConfig,
        topo: &Topology,
    ) -> Result<RunResult>;
}

/// Virtual-time event-driven backend (deterministic; the figure-sweep
/// substrate).
#[derive(Clone, Copy, Debug, Default)]
pub struct SimExecutor;

impl Executor for SimExecutor {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn run<O: GradOracle + Send>(
        &self,
        oracles: &mut [O],
        cfg: &DriverConfig,
    ) -> Result<RunResult> {
        Ok(super::driver::run_parallel(oracles, cfg))
    }

    fn run_topology<O: GradOracle + Send>(
        &self,
        oracles: &mut [O],
        cfg: &DriverConfig,
        topo: &Topology,
    ) -> Result<RunResult> {
        check_supported(cfg.method, Backend::Sim, topo)?;
        cfg.validate()?;
        match topo {
            Topology::Star => Ok(super::driver::run_parallel(oracles, cfg)),
            Topology::Tree(spec) => super::tree::run_tree_sim(oracles, cfg, spec),
        }
    }
}

/// Real-thread backend: one `std::thread` per worker; the center lives
/// behind a sharded lock (decoupled methods) or a dedicated
/// master-actor thread (master-coupled methods) — see
/// [`master_coupled`].
#[derive(Clone, Copy, Debug)]
pub struct ThreadExecutor {
    /// Number of center shards (lock granularity) for the sharded-lock
    /// center backend. More shards ⇒ finer interleaving and less
    /// contention at small τ. Ignored by the master actor, whose whole
    /// point is one serialized center.
    pub shards: usize,
}

impl Default for ThreadExecutor {
    fn default() -> Self {
        ThreadExecutor { shards: 16 }
    }
}

impl Executor for ThreadExecutor {
    fn name(&self) -> &'static str {
        "thread"
    }

    fn run<O: GradOracle + Send>(
        &self,
        oracles: &mut [O],
        cfg: &DriverConfig,
    ) -> Result<RunResult> {
        super::threaded::run_threaded(oracles, cfg, self.shards)
    }

    fn run_topology<O: GradOracle + Send>(
        &self,
        oracles: &mut [O],
        cfg: &DriverConfig,
        topo: &Topology,
    ) -> Result<RunResult> {
        check_supported(cfg.method, Backend::Thread, topo)?;
        cfg.validate()?;
        match topo {
            Topology::Star => super::threaded::run_threaded(oracles, cfg, self.shards),
            Topology::Tree(spec) => super::tree_threaded::run_tree_threaded(oracles, cfg, spec),
        }
    }
}

/// Backend selector for CLI / figure plumbing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    Sim,
    Thread,
    /// Workers as separate OS processes over real sockets
    /// ([`super::process::run_process`]). Selected here for gating and
    /// CLI plumbing; dispatching a run needs a serializable
    /// [`super::process::OracleSpec`] rather than live oracles, so
    /// [`run_with_backend_topology`] refuses it with directions.
    Process,
}

impl Backend {
    pub fn parse(s: &str) -> Option<Backend> {
        match s {
            "sim" | "virtual" => Some(Backend::Sim),
            "thread" | "threads" | "threaded" => Some(Backend::Thread),
            "process" | "proc" | "processes" => Some(Backend::Process),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Sim => "sim",
            Backend::Thread => "thread",
            Backend::Process => "process",
        }
    }
}

/// Dispatch a star-topology run to the selected backend. Methods the
/// backend does not implement yield a descriptive error — NOT a silent
/// sim fallback: the two backends' curves live on different time bases
/// (virtual vs. wall-clock seconds), so quietly swapping executors
/// would corrupt any sweep plotted on one axis.
pub fn run_with_backend<O: GradOracle + Send>(
    backend: Backend,
    oracles: &mut [O],
    cfg: &DriverConfig,
) -> Result<RunResult> {
    run_with_backend_topology(backend, oracles, cfg, &Topology::Star)
}

/// Dispatch a run on an explicit topology to the selected backend,
/// with the same no-silent-fallback contract as [`run_with_backend`].
pub fn run_with_backend_topology<O: GradOracle + Send>(
    backend: Backend,
    oracles: &mut [O],
    cfg: &DriverConfig,
    topo: &Topology,
) -> Result<RunResult> {
    match backend {
        Backend::Sim => SimExecutor.run_topology(oracles, cfg, topo),
        Backend::Thread => ThreadExecutor::default().run_topology(oracles, cfg, topo),
        // Live oracles cannot cross a process boundary; the process
        // tier runs from a serializable oracle recipe instead. Callers
        // that can build one (the `train` CLI, the ch4 sweeps, the
        // process bench) dispatch there before reaching this generic
        // entry point.
        Backend::Process => Err(crate::err!(
            "backend=process cannot run from live oracles — call \
             coordinator::process::run_process with an OracleSpec (a serializable oracle \
             recipe the self-exec'd workers rebuild)"
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_parse_roundtrip() {
        assert_eq!(Backend::parse("sim"), Some(Backend::Sim));
        assert_eq!(Backend::parse("thread"), Some(Backend::Thread));
        assert_eq!(Backend::parse("threaded"), Some(Backend::Thread));
        assert_eq!(Backend::parse("process"), Some(Backend::Process));
        assert_eq!(Backend::parse("proc"), Some(Backend::Process));
        assert_eq!(Backend::parse("gpu"), None);
        assert_eq!(Backend::Sim.name(), "sim");
        assert_eq!(Backend::Thread.name(), "thread");
        assert_eq!(Backend::Process.name(), "process");
    }

    #[test]
    fn master_coupling_split() {
        assert!(!master_coupled(Method::easgd_default(4, 4)));
        assert!(!master_coupled(Method::eamsgd_default(4, 4)));
        assert!(!master_coupled(Method::Downpour { tau: 1 }));
        assert!(!master_coupled(Method::ADownpour { tau: 1 }));
        assert!(!master_coupled(Method::MvaDownpour { tau: 1, alpha: 0.001 }));
        assert!(master_coupled(Method::MDownpour { delta: 0.9 }));
        assert!(master_coupled(Method::AdmmAsync { rho: 1.0, tau: 4 }));
    }

    #[test]
    fn tree_support_matrix() {
        assert!(tree_supported(Method::easgd_default(4, 4)));
        assert!(tree_supported(Method::eamsgd_default(4, 4)));
        for m in [
            Method::Downpour { tau: 1 },
            Method::MDownpour { delta: 0.9 },
            Method::ADownpour { tau: 1 },
            Method::MvaDownpour { tau: 1, alpha: 0.001 },
            Method::AdmmAsync { rho: 1.0, tau: 4 },
        ] {
            assert!(!tree_supported(m), "{}", m.name());
            assert!(tree_alpha(m).is_err(), "{}", m.name());
        }
        let a = tree_alpha(Method::Easgd { alpha: 0.25, tau: 1 }).unwrap();
        assert!((a - 0.25).abs() < 1e-7);
    }

    #[test]
    fn check_supported_matrix_is_descriptive() {
        use crate::coordinator::topology::{TreeScheme, TreeSpec};
        let tree = Topology::Tree(TreeSpec::new(4, TreeScheme::UpDown { tau_up: 1, tau_down: 4 }));
        // Star: EVERY method runs on BOTH backends (the thread backend
        // routes master-coupled methods through the master actor).
        for m in [
            Method::easgd_default(4, 4),
            Method::eamsgd_default(4, 4),
            Method::Downpour { tau: 1 },
            Method::MDownpour { delta: 0.9 },
            Method::ADownpour { tau: 1 },
            Method::MvaDownpour { tau: 1, alpha: 0.001 },
            Method::AdmmAsync { rho: 1.0, tau: 4 },
        ] {
            for b in [Backend::Sim, Backend::Thread] {
                assert!(
                    check_supported(m, b, &Topology::Star).is_ok(),
                    "{} on {}",
                    m.name(),
                    b.name()
                );
            }
        }
        // Tree (either backend): elastic methods only.
        for b in [Backend::Sim, Backend::Thread] {
            assert!(check_supported(Method::easgd_default(4, 4), b, &tree).is_ok());
            assert!(check_supported(Method::eamsgd_default(4, 4), b, &tree).is_ok());
            let e = check_supported(Method::Downpour { tau: 1 }, b, &tree).unwrap_err();
            assert!(format!("{e}").contains("no tree form"), "{e}");
        }
        // Degenerate fan-out refused.
        let skinny = Topology::Tree(TreeSpec::new(1, TreeScheme::UpDown { tau_up: 1, tau_down: 1 }));
        let e = check_supported(Method::easgd_default(4, 4), Backend::Sim, &skinny).unwrap_err();
        assert!(format!("{e}").contains("fan-out"), "{e}");
        // Process: decoupled star methods only.
        for m in [
            Method::easgd_default(4, 4),
            Method::eamsgd_default(4, 4),
            Method::Downpour { tau: 1 },
            Method::ADownpour { tau: 1 },
            Method::MvaDownpour { tau: 1, alpha: 0.001 },
        ] {
            assert!(
                check_supported(m, Backend::Process, &Topology::Star).is_ok(),
                "{} on process",
                m.name()
            );
        }
        for m in [Method::MDownpour { delta: 0.9 }, Method::AdmmAsync { rho: 1.0, tau: 4 }] {
            let e = check_supported(m, Backend::Process, &Topology::Star).unwrap_err();
            assert!(format!("{e}").contains("master-coupled"), "{e}");
        }
        let e =
            check_supported(Method::easgd_default(4, 4), Backend::Process, &tree).unwrap_err();
        assert!(format!("{e}").contains("star topology only"), "{e}");
    }

    #[test]
    fn validate_names_the_offending_field() {
        let good = DriverConfig {
            eta: 0.1,
            method: Method::easgd_default(4, 4),
            cost: CostModel::cifar_like(100),
            horizon: 1.0,
            eval_every: 0.5,
            seed: 0,
            max_steps: 100,
            lr_decay_gamma: 0.0,
        };
        assert!(good.validate().is_ok());
        for (field, mutate) in [
            ("eta", Box::new(|c: &mut DriverConfig| c.eta = f32::NAN)
                as Box<dyn Fn(&mut DriverConfig)>),
            ("eta", Box::new(|c: &mut DriverConfig| c.eta = -0.1)),
            ("horizon", Box::new(|c: &mut DriverConfig| c.horizon = 0.0)),
            ("horizon", Box::new(|c: &mut DriverConfig| c.horizon = f64::INFINITY)),
            ("eval_every", Box::new(|c: &mut DriverConfig| c.eval_every = -1.0)),
            ("max_steps", Box::new(|c: &mut DriverConfig| c.max_steps = 0)),
            ("gamma", Box::new(|c: &mut DriverConfig| c.lr_decay_gamma = f64::NAN)),
        ] {
            let mut bad = good.clone();
            mutate(&mut bad);
            let e = bad.validate().unwrap_err();
            assert!(format!("{e}").contains(field), "expected '{field}' in: {e}");
        }
    }

    #[test]
    fn eta_decay_schedule() {
        let cfg = DriverConfig {
            eta: 0.1,
            method: Method::easgd_default(4, 4),
            cost: CostModel::cifar_like(100),
            horizon: 1.0,
            eval_every: 1.0,
            seed: 0,
            max_steps: 100,
            lr_decay_gamma: 1.0,
        };
        assert!((cfg.eta_at(0) - 0.1).abs() < 1e-9);
        assert!((cfg.eta_at(3) - 0.05).abs() < 1e-9); // 0.1/√4
    }

    #[test]
    fn master_state_allocates_per_method() {
        let init = vec![1.0f32; 8];
        let m = MasterState::new(Method::easgd_default(4, 4), &init, 4);
        assert!(m.z.is_none() && m.mv.is_none() && m.contrib.is_none());
        assert_eq!(m.eval_target(), &init);
        let m = MasterState::new(Method::ADownpour { tau: 1 }, &init, 4);
        assert!(m.z.is_some());
        let m = MasterState::new(Method::MDownpour { delta: 0.9 }, &init, 4);
        assert!(m.mv.is_some());
        let m = MasterState::new(Method::AdmmAsync { rho: 1.0, tau: 4 }, &init, 4);
        assert_eq!(m.contrib.as_ref().unwrap().len(), 4);
    }
}
