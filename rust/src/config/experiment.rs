//! The shared experiment configuration: what the `repro` binary, the
//! examples, and the figure harness all consume. Loadable from a
//! `key = value` file (comments with `#`) with CLI overrides on top.

use super::args::Args;
use crate::cluster::CostModel;
use crate::coordinator::{Method, SeqMethod};
use std::collections::BTreeMap;

/// Top-level experiment configuration.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Parallel workers.
    pub p: usize,
    pub eta: f32,
    pub tau: u32,
    pub beta: f32,
    pub delta: f32,
    pub method: String,
    /// "cifar" | "imagenet" cost-model family.
    pub cost_family: String,
    /// §4.1 prefetch sharding: "replicated" (CIFAR mode) or
    /// "partitioned" (ImageNet mode).
    pub sharding: String,
    /// Native gradient model: "mlp" (historical stand-in) or "conv"
    /// (§4.1-faithful im2col conv net).
    pub model: String,
    pub horizon: f64,
    pub eval_every: f64,
    pub seed: u64,
    pub batch: usize,
    /// Extra free-form keys (forwarded to specific figures).
    pub extra: BTreeMap<String, String>,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            p: 4,
            eta: 0.05,
            tau: 10,
            beta: 0.9,
            delta: 0.99,
            method: "easgd".into(),
            cost_family: "cifar".into(),
            sharding: "replicated".into(),
            model: "mlp".into(),
            horizon: 60.0,
            eval_every: 2.0,
            seed: 0,
            batch: 32,
            extra: BTreeMap::new(),
        }
    }
}

impl ExperimentConfig {
    /// Parse a `key = value` file (unknown keys land in `extra`).
    pub fn from_file(path: &str) -> std::io::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let mut cfg = ExperimentConfig::default();
        for line in text.lines() {
            let line = line.split('#').next().unwrap().trim();
            if line.is_empty() {
                continue;
            }
            if let Some((k, v)) = line.split_once('=') {
                cfg.set(k.trim(), v.trim());
            }
        }
        Ok(cfg)
    }

    /// Apply CLI overrides.
    pub fn apply_args(&mut self, args: &Args) {
        for (k, v) in &args.kv {
            self.set(k, v);
        }
    }

    fn set(&mut self, k: &str, v: &str) {
        match k {
            "p" => self.p = v.parse().unwrap_or(self.p),
            "eta" => self.eta = v.parse().unwrap_or(self.eta),
            "tau" => self.tau = v.parse().unwrap_or(self.tau),
            "beta" => self.beta = v.parse().unwrap_or(self.beta),
            "delta" => self.delta = v.parse().unwrap_or(self.delta),
            "method" => self.method = v.to_string(),
            "cost" => self.cost_family = v.to_string(),
            "sharding" => self.sharding = v.to_string(),
            "model" => self.model = v.to_string(),
            "horizon" => self.horizon = v.parse().unwrap_or(self.horizon),
            "eval_every" => self.eval_every = v.parse().unwrap_or(self.eval_every),
            "seed" => self.seed = v.parse().unwrap_or(self.seed),
            "batch" => self.batch = v.parse().unwrap_or(self.batch),
            _ => {
                self.extra.insert(k.to_string(), v.to_string());
            }
        }
    }

    /// Resolve the parallel method named in `method`.
    pub fn parallel_method(&self) -> Option<Method> {
        let alpha = self.beta / self.p as f32;
        Some(match self.method.as_str() {
            "easgd" => Method::Easgd { alpha, tau: self.tau },
            "eamsgd" => Method::Eamsgd { alpha, tau: self.tau, delta: self.delta },
            "downpour" => Method::Downpour { tau: self.tau },
            "mdownpour" => Method::MDownpour { delta: self.delta },
            "adownpour" => Method::ADownpour { tau: self.tau },
            "mvadownpour" => Method::MvaDownpour {
                tau: self.tau,
                alpha: self
                    .extra
                    .get("mva_alpha")
                    .and_then(|s| s.parse().ok())
                    .unwrap_or(0.001),
            },
            "admm" => Method::AdmmAsync {
                rho: self
                    .extra
                    .get("rho")
                    .and_then(|s| s.parse().ok())
                    .unwrap_or(1.0),
                tau: self.tau,
            },
            _ => return None,
        })
    }

    /// Resolve a sequential method name.
    pub fn sequential_method(&self) -> Option<SeqMethod> {
        Some(match self.method.as_str() {
            "sgd" => SeqMethod::Sgd,
            "msgd" => SeqMethod::Msgd { delta: self.delta },
            "asgd" => SeqMethod::Asgd,
            "mvasgd" => SeqMethod::Mvasgd {
                alpha: self
                    .extra
                    .get("mva_alpha")
                    .and_then(|s| s.parse().ok())
                    .unwrap_or(0.001),
            },
            _ => return None,
        })
    }

    /// Cost model for the chosen family at a given parameter count.
    pub fn cost_model(&self, n_params: usize) -> CostModel {
        match self.cost_family.as_str() {
            "imagenet" => CostModel::imagenet_like(n_params),
            _ => CostModel::cifar_like(n_params),
        }
    }

    /// Resolve the §4.1 prefetch sharding mode; None on an unknown
    /// value (callers report the CLI error).
    pub fn sharding_mode(&self) -> Option<crate::data::Sharding> {
        crate::data::Sharding::parse(&self.sharding)
    }

    /// Resolve the `model=mlp|conv` knob; None on an unknown value
    /// (callers report the CLI error).
    pub fn model_kind(&self) -> Option<crate::model::ModelKind> {
        crate::model::ModelKind::parse(&self.model)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_then_file_then_cli_priority() {
        let dir = std::env::temp_dir().join("et_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("exp.cfg");
        std::fs::write(&path, "p = 8\neta = 0.1 # comment\nmethod = downpour\n").unwrap();
        let mut cfg = ExperimentConfig::from_file(path.to_str().unwrap()).unwrap();
        assert_eq!(cfg.p, 8);
        assert!((cfg.eta - 0.1).abs() < 1e-7);
        assert_eq!(cfg.method, "downpour");
        let args = Args::parse(["p=16".to_string(), "rho=2.5".to_string()]);
        cfg.apply_args(&args);
        assert_eq!(cfg.p, 16);
        assert_eq!(cfg.extra.get("rho").map(|s| s.as_str()), Some("2.5"));
    }

    #[test]
    fn method_resolution() {
        let mut cfg = ExperimentConfig { p: 8, ..Default::default() };
        cfg.method = "easgd".into();
        match cfg.parallel_method().unwrap() {
            Method::Easgd { alpha, tau } => {
                assert!((alpha - 0.9 / 8.0).abs() < 1e-7);
                assert_eq!(tau, 10);
            }
            _ => unreachable!(),
        }
        cfg.method = "msgd".into();
        assert!(cfg.parallel_method().is_none());
        assert!(matches!(cfg.sequential_method(), Some(SeqMethod::Msgd { .. })));
        cfg.method = "bogus".into();
        assert!(cfg.sequential_method().is_none());
    }

    #[test]
    fn sharding_resolution() {
        let mut cfg = ExperimentConfig::default();
        assert_eq!(cfg.sharding_mode(), Some(crate::data::Sharding::Replicated));
        cfg.set("sharding", "partitioned");
        assert_eq!(cfg.sharding_mode(), Some(crate::data::Sharding::Partitioned));
        cfg.set("sharding", "bogus");
        assert_eq!(cfg.sharding_mode(), None);
    }

    #[test]
    fn model_resolution() {
        let mut cfg = ExperimentConfig::default();
        assert_eq!(cfg.model_kind(), Some(crate::model::ModelKind::Mlp));
        cfg.set("model", "conv");
        assert_eq!(cfg.model_kind(), Some(crate::model::ModelKind::Conv));
        cfg.set("model", "bogus");
        assert_eq!(cfg.model_kind(), None);
    }

    #[test]
    fn cost_family_switch() {
        let mut cfg = ExperimentConfig::default();
        let c = cfg.cost_model(1000);
        assert!(c.t_grad < 0.1);
        cfg.cost_family = "imagenet".into();
        let i = cfg.cost_model(1000);
        assert!(i.t_grad > 1.0);
    }
}
