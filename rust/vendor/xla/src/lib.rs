//! Offline stub of the `xla` crate (xla_extension / PJRT bindings):
//! exactly the API surface `elastic_train`'s `pjrt` feature consumes,
//! with every operation returning an error at runtime.
//!
//! Why this exists: the tier-1 build must work with no network and no
//! XLA shared library, yet `--features pjrt` should still *compile* so
//! the runtime layer cannot rot. To actually execute the AOT artifacts,
//! replace the `xla = { path = "vendor/xla" }` dependency in
//! `rust/Cargo.toml` with the real crate (see rust/README.md).

use std::fmt;

/// Stub error: carries the operation name that was attempted.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "xla stub: '{}' requires the real xla crate (see rust/README.md)",
            self.0
        )
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(op: &str) -> Result<T> {
    Err(Error(op.to_string()))
}

/// A device literal (shaped host buffer).
#[derive(Debug, Clone, Default)]
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    pub fn to_tuple2(self) -> Result<(Literal, Literal)> {
        unavailable("Literal::to_tuple2")
    }

    pub fn to_tuple3(self) -> Result<(Literal, Literal, Literal)> {
        unavailable("Literal::to_tuple3")
    }

    pub fn get_first_element<T>(&self) -> Result<T> {
        unavailable("Literal::get_first_element")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

/// A buffer resident on a PJRT device.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// The PJRT client (CPU plugin in the real crate).
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// A compiled executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// An HLO module proto parsed from text.
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_operation_reports_stub() {
        assert!(PjRtClient::cpu().is_err());
        assert!(Literal::vec1(&[1.0f32]).reshape(&[1]).is_err());
        let msg = format!("{}", Error("op".into()));
        assert!(msg.contains("real xla crate"));
    }
}
