//! The §5.2 multiplicative-noise model: min_x E[(x·u)²] with u² ~ Γ(λ, ω)
//! — the initial-phase model where the *spread* of the input data
//! distribution governs attainable speedup.

use crate::rng::Rng;

/// Model parameters: u² ~ Γ(lambda, omega) (rate parameterization).
#[derive(Clone, Copy, Debug)]
pub struct Multiplicative {
    pub lambda: f64,
    pub omega: f64,
}

impl Multiplicative {
    /// One draw of ξ = mini-batch mean of p i.i.d. u² — itself Γ(pλ, pω).
    #[inline]
    pub fn xi(&self, p: usize, rng: &mut Rng) -> f64 {
        rng.gamma(self.lambda * p as f64, self.omega * p as f64)
    }
}

/// Mini-batch SGD (Eq 5.24): x' = x − η ξ x. Returns |x_t| trajectory
/// (geometric decay — log-scale is the meaningful view).
pub fn minibatch_sgd_trajectory(
    m: Multiplicative,
    eta: f64,
    p: usize,
    x0: f64,
    t: usize,
    rng: &mut Rng,
) -> Vec<f64> {
    let mut x = x0;
    let mut out = Vec::with_capacity(t + 1);
    out.push(x.abs());
    for _ in 0..t {
        x -= eta * m.xi(p, rng) * x;
        out.push(x.abs());
    }
    out
}

/// Momentum SGD under multiplicative noise (Eq 5.28).
pub fn msgd_trajectory(
    m: Multiplicative,
    eta: f64,
    delta: f64,
    x0: f64,
    t: usize,
    rng: &mut Rng,
) -> Vec<f64> {
    let (mut x, mut v) = (x0, 0.0);
    let mut out = Vec::with_capacity(t + 1);
    out.push(x.abs());
    for _ in 0..t {
        let xi = m.xi(1, rng);
        v = delta * v - eta * xi * (x + delta * v);
        x += v;
        out.push(x.abs());
    }
    out
}

/// EASGD under multiplicative noise (Eq 5.31): per-worker ξᵗᵢ.
pub fn easgd_trajectory(
    m: Multiplicative,
    eta: f64,
    alpha: f64,
    beta: f64,
    p: usize,
    x0: f64,
    t: usize,
    rng: &mut Rng,
) -> Vec<f64> {
    let mut xs = vec![x0; p];
    let mut center = x0;
    let mut out = Vec::with_capacity(t + 1);
    out.push(center.abs());
    for _ in 0..t {
        let mean: f64 = xs.iter().sum::<f64>() / p as f64;
        for x in &mut xs {
            let xi = m.xi(1, rng);
            *x = *x - eta * xi * *x - alpha * (*x - center);
        }
        center += beta * (mean - center);
        out.push(center.abs());
    }
    out
}

/// Empirical contraction rate of the second moment over a horizon:
/// (E x_t² / x_0²)^(1/t) averaged over reps — compares against
/// [`super::moments::minibatch_sgd_rate`].
pub fn empirical_rate<F>(mut run: F, reps: usize, t: usize) -> f64
where
    F: FnMut(u64) -> Vec<f64>,
{
    let mut acc = 0.0;
    for r in 0..reps {
        let tr = run(r as u64);
        let x0 = tr[0].max(1e-300);
        let xt = tr[t].max(1e-300);
        acc += (xt * xt / (x0 * x0)).powf(1.0 / t as f64);
    }
    acc / reps as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::moments;

    const M: Multiplicative = Multiplicative { lambda: 1.0, omega: 1.0 };

    #[test]
    fn sgd_contracts_at_the_closed_form_rate() {
        let eta = 0.3;
        let want = moments::minibatch_sgd_rate(eta, M.lambda, M.omega, 1);
        // Second-moment contraction: average x_t²/x_0² over many runs,
        // then take the per-step ratio.
        let t = 40;
        let reps = 8000;
        let mut acc = 0.0;
        for r in 0..reps {
            let tr = minibatch_sgd_trajectory(M, eta, 1, 1.0, t, &mut Rng::new(r));
            acc += tr[t] * tr[t];
        }
        let got = (acc / reps as f64).powf(1.0 / t as f64);
        assert!((got - want).abs() < 0.05, "{got} vs {want}");
    }

    #[test]
    fn minibatch_improves_contraction_at_optimal_eta() {
        // §5.2.1: for spread-out inputs (λ=0.5) bigger p lets a bigger
        // optimal η contract faster.
        let m = Multiplicative { lambda: 0.5, omega: 0.5 };
        let rate = |p: usize| {
            let eta = moments::minibatch_optimal_eta(m.lambda, m.omega, p);
            moments::minibatch_sgd_rate(eta, m.lambda, m.omega, p)
        };
        assert!(rate(4) < rate(1));
        assert!(rate(16) < rate(4));
    }

    #[test]
    fn heavy_tail_draws_can_exceed_mean_wildly() {
        // λ < 1 ⇒ pdf pole at 0 and heavy tail: witness spread.
        let m = Multiplicative { lambda: 0.5, omega: 0.5 };
        let mut rng = Rng::new(3);
        let mut max = 0.0f64;
        for _ in 0..10_000 {
            max = max.max(m.xi(1, &mut rng));
        }
        assert!(max > 5.0, "max draw {max} should dwarf mean 1.0");
    }

    #[test]
    fn easgd_center_tracks_and_contracts() {
        let mut rng = Rng::new(9);
        let tr = easgd_trajectory(M, 0.3, 0.9 / 8.0, 0.9, 8, 1.0, 300, &mut rng);
        assert!(tr.last().unwrap() < &1e-2, "center {:?}", tr.last());
    }

    #[test]
    fn easgd_survives_eta_beyond_sgd_edge_when_alpha_tuned() {
        // §5.2.3 Case II: with α = 1−√λ and large p, EASGD's second
        // moment is stable up to η < ω/√λ, beyond the single-worker SGD
        // edge 2ω/(λ+1). (Individual SGD *paths* still converge a.s. —
        // geometric Brownian motion — so the right check is the moment
        // matrices, not path divergence.)
        let (l, w) = (0.5, 0.5);
        let alpha = moments::easgd_mult_optimal_alpha(l); // ≈ 0.293
        let edge_sgd = 2.0 * w / (l + 1.0); // ≈ 0.667 (p=1)
        let eta = 0.68; // beyond the SGD edge, inside ω/√λ ≈ 0.707
        assert!(eta > edge_sgd);
        // SGD second moment diverges:
        assert!(moments::minibatch_sgd_rate(eta, l, w, 1) > 1.0);
        // EASGD (p large, tuned α) second moment contracts:
        let m = moments::easgd_mult_moment_matrix(eta, alpha, 0.9, l, w, 400);
        let sp = moments::sp(&m);
        assert!(sp < 1.0, "sp={sp}");
        // And the simulated center indeed contracts.
        let model = Multiplicative { lambda: l, omega: w };
        let tr = easgd_trajectory(model, eta, alpha, 0.9, 100, 1.0, 1500,
                                  &mut Rng::new(4));
        assert!(*tr.last().unwrap() < 0.5, "center {:?}", tr.last());
    }
}
