//! The §4.1 parallel data-prefetch pipeline, faithfully reimplemented:
//!
//! * k data loaders, each owning a chunked "mmap file" (here: an index
//!   range over a dataset) holding either the whole set (CIFAR mode) or
//!   a distinct 1/k shard (ImageNet mode);
//! * each loader serves *consecutive* chunks of c samples to whichever
//!   worker requests next, cycling through its file;
//! * on wrap-around the loader restarts from a uniformly random offset
//!   in [0, s], s = (file size mod mini-batch size);
//! * a worker gathers one chunk from each of the k loaders, shuffles
//!   the union, and cuts mini-batches of size 128 (here: `batch`).

use crate::rng::Rng;

/// One data loader cycling through its chunk file.
pub struct DataLoader {
    /// The sample indices this loader owns (its "mmap file").
    file: Vec<usize>,
    /// Chunk size in samples.
    chunk: usize,
    /// Current read position.
    pos: usize,
    /// Mini-batch size (for the random wrap offset rule).
    batch: usize,
    rng: Rng,
}

impl DataLoader {
    pub fn new(file: Vec<usize>, chunk: usize, batch: usize, seed: u64) -> Self {
        assert!(!file.is_empty() && chunk > 0);
        Self { file, chunk, pos: 0, batch, rng: Rng::new(seed) }
    }

    /// Serve the next chunk (consecutive samples, cycling).
    pub fn next_chunk(&mut self) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.chunk);
        for _ in 0..self.chunk {
            if self.pos >= self.file.len() {
                // Wrap: restart from a random offset in [0, s],
                // s = len mod batch (the thesis' rule). When the file
                // is SMALLER than a mini-batch (tiny partitioned
                // shards), len mod batch = len, and an offset of len
                // would read one past the end — clamp the offset range
                // to [0, len − 1] so the restart stays in bounds.
                let s = (self.file.len() % self.batch).min(self.file.len() - 1);
                self.pos = if s == 0 { 0 } else { self.rng.below(s + 1) };
            }
            out.push(self.file[self.pos]);
            self.pos += 1;
        }
        out
    }
}

/// The pool of k loaders a worker draws from.
pub struct PrefetchPool {
    loaders: Vec<DataLoader>,
    batch: usize,
    /// Trailing partial mini-batch carried into the next fetch — the
    /// pool never drops fetched samples when k × chunk is not a
    /// multiple of `batch`.
    carry: Vec<usize>,
}

/// Sharding mode for constructing the pool (thesis §4.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Sharding {
    /// Every loader's file is the whole dataset (CIFAR mode).
    Replicated,
    /// Loader j owns the j-th 1/k fraction (ImageNet mode).
    Partitioned,
}

impl Sharding {
    /// CLI/config selector (`sharding=replicated|partitioned`; the
    /// thesis' dataset names are accepted as aliases).
    pub fn parse(s: &str) -> Option<Sharding> {
        match s {
            "replicated" | "cifar" => Some(Sharding::Replicated),
            "partitioned" | "imagenet" => Some(Sharding::Partitioned),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Sharding::Replicated => "replicated",
            Sharding::Partitioned => "partitioned",
        }
    }
}

impl PrefetchPool {
    pub fn new(
        n_samples: usize,
        k: usize,
        chunk: usize,
        batch: usize,
        mode: Sharding,
        seed: u64,
    ) -> Self {
        assert!(n_samples > 0, "prefetch pool over an empty dataset");
        // Partitioned mode hands loader j the j-th 1/k fraction; with
        // n_samples < k some fractions are EMPTY, and an empty "mmap
        // file" trips the `DataLoader::new` assert — a panic reachable
        // straight from the `sharding=` CLI knob on small datasets.
        // Clamp the loader count so every loader owns ≥ 1 sample.
        let k = k.min(n_samples).max(1);
        let loaders = (0..k)
            .map(|j| {
                let file: Vec<usize> = match mode {
                    Sharding::Replicated => (0..n_samples).collect(),
                    Sharding::Partitioned => {
                        let lo = j * n_samples / k;
                        let hi = (j + 1) * n_samples / k;
                        (lo..hi).collect()
                    }
                };
                DataLoader::new(file, chunk, batch, seed.wrapping_add(j as u64))
            })
            .collect();
        Self { loaders, batch, carry: Vec::new() }
    }

    /// One worker fetch: the previous fetch's trailing remainder plus
    /// k chunks (one per loader), shuffled, cut into mini-batches of
    /// `batch` sample indices. The trailing partial mini-batch is
    /// carried over into the next fetch, never dropped.
    pub fn fetch_minibatches(&mut self, rng: &mut Rng) -> Vec<Vec<usize>> {
        let mut pool: Vec<usize> = std::mem::take(&mut self.carry);
        for l in &mut self.loaders {
            pool.extend(l.next_chunk());
        }
        rng.shuffle(&mut pool);
        let full = pool.len() / self.batch * self.batch;
        self.carry = pool.split_off(full);
        pool.chunks(self.batch).map(|c| c.to_vec()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_are_consecutive_and_cycle() {
        let mut l = DataLoader::new((0..10).collect(), 4, 4, 1);
        assert_eq!(l.next_chunk(), vec![0, 1, 2, 3]);
        assert_eq!(l.next_chunk(), vec![4, 5, 6, 7]);
        let third = l.next_chunk();
        assert_eq!(&third[..2], &[8, 9]);
        // After wrap, restart offset ∈ [0, 10 mod 4] = [0, 2].
        assert!(third[2] <= 2, "wrap offset {:?}", &third[2..]);
        assert_eq!(third[3], third[2] + 1);
    }

    /// Regression: a file SMALLER than the mini-batch size (tiny
    /// partitioned shards) used to make the wrap rule draw an offset of
    /// `len` itself (len mod batch = len) and index one past the end.
    /// The offset range is now clamped to [0, len − 1].
    #[test]
    fn files_smaller_than_batch_cycle_without_out_of_bounds() {
        for len in [1usize, 2, 3, 5] {
            let mut l = DataLoader::new((0..len).collect(), 4, 8, 9);
            // Many wraps: every draw of the restart offset must stay
            // in bounds (the old rule panicked with probability
            // ~1/(len+1) per wrap).
            for _ in 0..200 {
                for idx in l.next_chunk() {
                    assert!(idx < len);
                }
            }
        }
    }

    #[test]
    fn partitioned_loaders_cover_disjoint_shards() {
        let pool = PrefetchPool::new(100, 4, 8, 8, Sharding::Partitioned, 2);
        for (j, l) in pool.loaders.iter().enumerate() {
            assert_eq!(l.file.first(), Some(&(j * 25)));
            assert_eq!(l.file.len(), 25);
        }
    }

    #[test]
    fn replicated_loaders_each_own_everything() {
        let pool = PrefetchPool::new(50, 3, 8, 8, Sharding::Replicated, 2);
        for l in &pool.loaders {
            assert_eq!(l.file.len(), 50);
        }
    }

    #[test]
    fn fetch_produces_full_minibatches_of_valid_indices() {
        let mut pool = PrefetchPool::new(512, 8, 64, 128, Sharding::Replicated, 3);
        let mut rng = Rng::new(4);
        let mbs = pool.fetch_minibatches(&mut rng);
        // 8 loaders × 64 = 512 samples = 4 mini-batches of 128.
        assert_eq!(mbs.len(), 4);
        for mb in &mbs {
            assert_eq!(mb.len(), 128);
            assert!(mb.iter().all(|&i| i < 512));
        }
    }

    #[test]
    fn trailing_partial_minibatch_carries_over() {
        // 3 loaders × 40 = 120 samples per fetch, batch 32:
        // 120 = 3×32 + 24, so each fetch leaves a remainder.
        let mut pool = PrefetchPool::new(240, 3, 40, 32, Sharding::Replicated, 7);
        let mut rng = Rng::new(8);
        let first = pool.fetch_minibatches(&mut rng);
        assert_eq!(first.len(), 3); // 96 served, 24 carried
        assert_eq!(pool.carry.len(), 24);
        // Second fetch sees 24 + 120 = 144 = 4×32 + 16.
        let second = pool.fetch_minibatches(&mut rng);
        assert_eq!(second.len(), 4);
        assert_eq!(pool.carry.len(), 16);
        // Over many fetches nothing is ever dropped: served + carry
        // always accounts for every fetched sample.
        let mut served = (first.len() + second.len()) * 32;
        for _ in 0..10 {
            served += pool.fetch_minibatches(&mut rng).len() * 32;
        }
        let fetched = 12 * 120;
        assert!(
            fetched - served < 32,
            "served {served} of {fetched}; the rest must sit in carry"
        );
        assert_eq!(served + pool.carry.len(), fetched);
    }

    /// Regression for the `n_samples < k` panic: `Partitioned` used to
    /// build empty loader files (e.g. 3 samples across 4 loaders ⇒ one
    /// loader owns nothing) and trip the `DataLoader::new` assert —
    /// reachable from the `sharding=` CLI knob on small datasets. The
    /// loader count is now clamped to `min(k, n_samples)`.
    #[test]
    fn tiny_dataset_clamps_loader_count_instead_of_panicking() {
        for mode in [Sharding::Partitioned, Sharding::Replicated] {
            let mut pool = PrefetchPool::new(3, 4, 8, 4, mode, 1);
            assert_eq!(pool.loaders.len(), 3, "{mode:?}: one loader per sample");
            // The clamped pool still serves valid full mini-batches.
            let mut rng = Rng::new(2);
            let mut served = 0;
            for _ in 0..8 {
                for mb in pool.fetch_minibatches(&mut rng) {
                    assert_eq!(mb.len(), 4);
                    assert!(mb.iter().all(|&i| i < 3));
                    served += 1;
                }
            }
            assert!(served > 0, "{mode:?}: clamped pool must still serve batches");
        }
        // Partitioned coverage: the 3 clamped loaders own disjoint
        // singleton shards that union to the whole set.
        let pool = PrefetchPool::new(3, 4, 8, 4, Sharding::Partitioned, 1);
        let mut all: Vec<usize> = pool.loaders.iter().flat_map(|l| l.file.clone()).collect();
        all.sort_unstable();
        assert_eq!(all, vec![0, 1, 2]);
    }

    #[test]
    fn sharding_parse_roundtrip() {
        assert_eq!(Sharding::parse("replicated"), Some(Sharding::Replicated));
        assert_eq!(Sharding::parse("partitioned"), Some(Sharding::Partitioned));
        assert_eq!(Sharding::parse("imagenet"), Some(Sharding::Partitioned));
        assert_eq!(Sharding::parse("bogus"), None);
        assert_eq!(Sharding::Partitioned.name(), "partitioned");
    }

    #[test]
    fn coverage_is_near_uniform_over_many_fetches() {
        // Cycling loaders must visit every sample at similar frequency.
        let n = 256;
        let mut pool = PrefetchPool::new(n, 4, 32, 32, Sharding::Partitioned, 5);
        let mut rng = Rng::new(6);
        let mut counts = vec![0usize; n];
        for _ in 0..64 {
            for mb in pool.fetch_minibatches(&mut rng) {
                for i in mb {
                    counts[i] += 1;
                }
            }
        }
        let min = *counts.iter().min().unwrap();
        let max = *counts.iter().max().unwrap();
        assert!(min > 0, "every sample visited");
        assert!(max <= 3 * min.max(1), "near-uniform: min {min} max {max}");
    }
}
