"""L2: the JAX transformer language model (fwd/bwd), calling the L1
Pallas kernels.

The thesis trains deep conv nets on CIFAR/ImageNet; this repo's
end-to-end deep model is a decoder-only transformer LM on a synthetic
Markov corpus (DESIGN.md §2 substitution table). The distributed
optimizer dynamics under study are model-agnostic; what matters is a
real multi-layer non-convex model with a meaningful loss curve.

Parameters live in a flat, deterministically-ordered list (see
``param_specs``) so the rust coordinator can treat the model as a single
flat f32 vector (the thesis' "x") while the HLO entry points take the
individual tensors.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels.attention import attention


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    vocab: int = 256
    d_model: int = 128
    n_layers: int = 2
    n_heads: int = 4
    seq_len: int = 64
    batch: int = 8
    weight_decay: float = 1e-4  # thesis §4.1 l2 regularization

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def d_ff(self) -> int:
        return 4 * self.d_model


PRESETS: Dict[str, ModelConfig] = {
    "tiny": ModelConfig(),
    "small": ModelConfig(vocab=512, d_model=256, n_layers=4, n_heads=8,
                         seq_len=64, batch=8),
    "base": ModelConfig(vocab=1024, d_model=512, n_layers=8, n_heads=8,
                        seq_len=128, batch=8),
}


def param_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Deterministic (name, shape) list — the contract with the rust side.

    The rust runtime reads the same list from artifacts/manifest.json and
    slices its flat parameter buffer accordingly. Order is load-bearing.
    """
    d, v, t, f = cfg.d_model, cfg.vocab, cfg.seq_len, cfg.d_ff
    specs: List[Tuple[str, Tuple[int, ...]]] = [
        ("tok_embed", (v, d)),
        ("pos_embed", (t, d)),
    ]
    for i in range(cfg.n_layers):
        specs += [
            (f"l{i}.ln1_scale", (d,)),
            (f"l{i}.ln1_bias", (d,)),
            (f"l{i}.w_qkv", (d, 3 * d)),
            (f"l{i}.w_out", (d, d)),
            (f"l{i}.ln2_scale", (d,)),
            (f"l{i}.ln2_bias", (d,)),
            (f"l{i}.w_ff1", (d, f)),
            (f"l{i}.w_ff2", (f, d)),
        ]
    specs += [("lnf_scale", (d,)), ("lnf_bias", (d,))]
    return specs


def param_count(cfg: ModelConfig) -> int:
    return sum(int(jnp.prod(jnp.array(s))) for _, s in param_specs(cfg))


def init_params(cfg: ModelConfig, seed: int = 0) -> List[jax.Array]:
    """Scaled-gaussian init; scales/biases to 1/0 (thesis: biases zeroed
    for CIFAR)."""
    key = jax.random.PRNGKey(seed)
    out = []
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith("_scale"):
            out.append(jnp.ones(shape, jnp.float32))
        elif name.endswith("_bias"):
            out.append(jnp.zeros(shape, jnp.float32))
        elif name == "pos_embed":
            out.append(0.01 * jax.random.normal(sub, shape, jnp.float32))
        else:
            fan_in = shape[0]
            out.append(jax.random.normal(sub, shape, jnp.float32)
                       / jnp.sqrt(jnp.float32(fan_in)))
    return out


def _layer_norm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * scale + bias


def forward(cfg: ModelConfig, params: List[jax.Array], tokens: jax.Array):
    """Logits for next-token prediction. tokens: i32[B, T]."""
    p = dict(zip([n for n, _ in param_specs(cfg)], params))
    b, t = tokens.shape
    h = p["tok_embed"][tokens] + p["pos_embed"][None, :t]
    scale = 1.0 / (cfg.d_head ** 0.5)
    for i in range(cfg.n_layers):
        x = _layer_norm(h, p[f"l{i}.ln1_scale"], p[f"l{i}.ln1_bias"])
        qkv = x @ p[f"l{i}.w_qkv"]                      # (B, T, 3D)
        q, k, v = jnp.split(qkv, 3, axis=-1)

        def heads(z):
            return z.reshape(b, t, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)

        o = attention(heads(q), heads(k), heads(v), scale)  # L1 kernel
        o = o.transpose(0, 2, 1, 3).reshape(b, t, cfg.d_model)
        h = h + o @ p[f"l{i}.w_out"]
        x = _layer_norm(h, p[f"l{i}.ln2_scale"], p[f"l{i}.ln2_bias"])
        h = h + jax.nn.gelu(x @ p[f"l{i}.w_ff1"]) @ p[f"l{i}.w_ff2"]
    h = _layer_norm(h, p["lnf_scale"], p["lnf_bias"])
    return h @ p["tok_embed"].T                          # tied head


def loss_fn(cfg: ModelConfig, params: List[jax.Array],
            tokens: jax.Array, targets: jax.Array) -> jax.Array:
    """Mean next-token cross-entropy + l2 regularization (thesis §4.1)."""
    logits = forward(cfg, params, tokens)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1).mean()
    if cfg.weight_decay > 0.0:
        l2 = sum(jnp.sum(w * w) for w in params)
        nll = nll + 0.5 * cfg.weight_decay * l2
    return nll


def train_step(cfg: ModelConfig, params: List[jax.Array],
               tokens: jax.Array, targets: jax.Array):
    """(loss, grads...) — the artifact the rust workers execute per step."""
    loss, grads = jax.value_and_grad(
        lambda ps: loss_fn(cfg, ps, tokens, targets))(params)
    return (loss, *grads)


def eval_step(cfg: ModelConfig, params: List[jax.Array],
              tokens: jax.Array, targets: jax.Array):
    """(loss, n_correct) for test-curve reporting on the center variable."""
    logits = forward(cfg, params, tokens)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1).mean()
    correct = jnp.sum((jnp.argmax(logits, axis=-1) == targets)
                      .astype(jnp.int32))
    return (nll, correct)
