//! Deterministic random-number substrate.
//!
//! Every stochastic experiment in the repo (quadratic simulations,
//! synthetic corpora, cluster jitter, Gamma multiplicative noise) draws
//! from this module so runs are reproducible from a single `u64` seed.
//!
//! Generator: PCG64 (O'Neill's pcg64_xsl_rr_128_64). Gaussians via
//! Box–Muller with caching; Gamma via Marsaglia–Tsang squeeze (with the
//! shape-boost trick for `shape < 1`), which the thesis' §5.2
//! multiplicative-noise model needs for `Γ(λ, ω)` input data.

mod pcg;

pub use pcg::Pcg64;

/// Streamed distributions over a [`Pcg64`].
#[derive(Clone, Debug)]
pub struct Rng {
    pcg: Pcg64,
    gauss_cache: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self { pcg: Pcg64::new(seed), gauss_cache: None }
    }

    /// Derive an independent stream (for per-worker seeding).
    pub fn split(&mut self, stream: u64) -> Rng {
        let s = self.next_u64() ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Rng::new(s)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.pcg.next_u64()
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift; bias is negligible for n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(z) = self.gauss_cache.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.gauss_cache = Some(r * s);
            return r * c;
        }
    }

    /// Normal with the given mean and standard deviation.
    #[inline]
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gaussian()
    }

    /// Gamma(shape, rate) — thesis parameterization Γ(λ, ω) with mean
    /// λ/ω and variance λ/ω². Marsaglia–Tsang; `shape < 1` handled by
    /// the boost `Γ(a) = Γ(a+1) · U^{1/a}`.
    pub fn gamma(&mut self, shape: f64, rate: f64) -> f64 {
        assert!(shape > 0.0 && rate > 0.0, "gamma needs positive params");
        if shape < 1.0 {
            let boost = self.gamma(shape + 1.0, 1.0);
            let u: f64 = loop {
                let u = self.uniform();
                if u > 0.0 {
                    break u;
                }
            };
            return boost * u.powf(1.0 / shape) / rate;
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.gaussian();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.uniform();
            let x2 = x * x;
            if u < 1.0 - 0.0331 * x2 * x2
                || u.ln() < 0.5 * x2 + d * (1.0 - v3 + v3.ln())
            {
                return d * v3 / rate;
            }
        }
    }

    /// Fill a slice with standard normals scaled by `std` (f32).
    pub fn fill_gaussian_f32(&mut self, out: &mut [f32], std: f32) {
        for v in out {
            *v = (self.gaussian() as f32) * std;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval_with_correct_mean() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / n as f64 - 0.5).abs() < 5e-3);
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(9);
        let n = 200_000;
        let (mut m1, mut m2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.gaussian();
            m1 += z;
            m2 += z * z;
        }
        m1 /= n as f64;
        m2 /= n as f64;
        assert!(m1.abs() < 0.01, "mean {m1}");
        assert!((m2 - 1.0).abs() < 0.02, "var {m2}");
    }

    #[test]
    fn gamma_moments_match_shape_rate() {
        // Γ(λ, ω): mean λ/ω, var λ/ω² — the thesis §5.2 parameterization.
        for &(shape, rate) in &[(0.5, 0.5), (1.0, 1.0), (2.0, 2.0), (10.0, 10.0)] {
            let mut r = Rng::new(11);
            let n = 200_000;
            let (mut m1, mut m2) = (0.0, 0.0);
            for _ in 0..n {
                let g = r.gamma(shape, rate);
                assert!(g >= 0.0);
                m1 += g;
                m2 += g * g;
            }
            m1 /= n as f64;
            m2 = m2 / n as f64 - m1 * m1;
            let mean = shape / rate;
            let var = shape / (rate * rate);
            assert!((m1 - mean).abs() < 0.05 * mean.max(0.2), "mean {m1} vs {mean}");
            assert!((m2 - var).abs() < 0.08 * var.max(0.2), "var {m2} vs {var}");
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = r.below(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn split_streams_are_independent_and_deterministic() {
        let mut root1 = Rng::new(99);
        let mut root2 = Rng::new(99);
        let mut a = root1.split(0);
        let mut b = root2.split(0);
        assert_eq!(a.next_u64(), b.next_u64());
        let mut c = Rng::new(99).split(1);
        assert_ne!(Rng::new(99).split(0).next_u64(), c.next_u64());
    }
}
