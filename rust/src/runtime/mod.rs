//! PJRT runtime: load the AOT artifacts (`make artifacts`) and execute
//! them from the rust hot path. Python never runs here.
//!
//! - [`artifacts`] — manifest parsing, parameter table, shared initial
//!   parameters (always available), plus HLO loading and compilation
//!   (`PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//!   `compile`) under the `pjrt` feature.
//! - [`session`] (`pjrt`) — `PjrtModel`: flat-buffer ⇄ literal packing
//!   and the `train_step` / `eval_step` / update-kernel execution paths.
//! - [`pjrt_oracle`] (`pjrt`) — `PjrtOracle`, the `GradOracle`
//!   implementation that plugs the AOT transformer into the same
//!   EASGD/DOWNPOUR/Tree drivers the sweeps use.
//!
//! The `pjrt` feature is off by default so the tier-1 build has zero
//! external dependencies; the vendored `xla` stub keeps
//! `--features pjrt` compiling offline (every call errors at runtime
//! until the real crate is swapped in — see rust/README.md).

pub mod artifacts;
#[cfg(feature = "pjrt")]
pub mod pjrt_oracle;
#[cfg(feature = "pjrt")]
pub mod session;

pub use artifacts::Artifacts;
#[cfg(feature = "pjrt")]
pub use pjrt_oracle::PjrtOracle;
#[cfg(feature = "pjrt")]
pub use session::PjrtModel;
