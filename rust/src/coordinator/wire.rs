//! The process backend's wire format: length-prefixed flat-θ frames
//! over TCP or Unix-domain sockets (`super::process`).
//!
//! One frame is a fixed little-endian header followed by an f32
//! payload:
//!
//! ```text
//! magic   u32   0x45545746 ("ETWF")
//! version u16   1
//! kind    u8    FrameKind discriminant
//! wid     u32   sender worker id (0 on master->worker frames)
//! clock   u64   sender's local clock (t_local / step count)
//! n       u32   payload length in f32 elements
//! payload n×f32
//! ```
//!
//! Hand-rolled on `std::io` — no serde, no new dependencies — because
//! the point of the process tier is that serialize/deserialize and
//! socket transfer are REAL measured costs: [`send_frame`] /
//! [`recv_frame`] time the encode/decode separately from the socket
//! write/read and accumulate both into a [`WireClock`], which the
//! process backend feeds into the run's comm-time breakdown
//! (`TimeBreakdown::serialize` / `TimeBreakdown::transfer`).
//!
//! Failures are loud by construction: a bad magic, an unknown version,
//! an unknown frame kind, or an oversized length prefix each produce a
//! descriptive error instead of a silent desync, and an EOF mid-frame
//! names how far the frame got.

use crate::error::Result;
use crate::sync::thread;
use std::io::{Read, Write};
use std::time::Instant;

pub const MAGIC: u32 = 0x4554_5746; // "ETWF"
pub const VERSION: u16 = 1;
/// Frame header bytes: magic + version + kind + wid + clock + n.
pub const HEADER_BYTES: usize = 4 + 2 + 1 + 4 + 8 + 4;
/// Refuse length prefixes above this many f32s (1 GiB of payload) —
/// a corrupt or misaligned stream fails at the header instead of
/// streaming garbage. Public so `fuzz_wire` can aim max-`n` claims
/// exactly at the edge. CI's fuzz lane compiles the guard OUT with
/// `--cfg wire_mutate_no_payload_cap` and requires the fuzzer to
/// notice (the cap-class mutations stop producing cap errors).
pub const MAX_PAYLOAD: u32 = 1 << 28;
/// Payload reads are chunked at this size so allocation tracks bytes
/// actually received: a lying length prefix costs at most one chunk
/// before the stream runs dry, never the claimed `n`.
pub const READ_CHUNK_BYTES: usize = 64 * 1024;

/// Frame discriminants of the master⇄worker protocol.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum FrameKind {
    /// Worker's first frame: announces `wid`, empty payload.
    Hello = 0,
    /// Master's reply to Hello: the shared init θ.
    Init = 1,
    /// Worker → master exchange payload (θ for the elastic methods,
    /// the accumulated update for the DOWNPOUR family).
    Push = 2,
    /// Master → worker exchange reply (the worker's next read of the
    /// center / its updated θ).
    Center = 3,
    /// Master → worker: horizon reached, finish up. Payload like
    /// `Center` so the worker's last exchange still applies.
    Stop = 4,
    /// Worker's final frame: `clock` = local steps taken, payload =
    /// measured [compute_s, comm_s, serialize_s, transfer_s].
    Done = 5,
    /// Worker → master: local divergence (non-finite loss / exploding
    /// θ). Empty payload.
    Diverged = 6,
}

impl FrameKind {
    /// Every kind, for exhaustive enumeration (the protocol table
    /// test) and fuzz mutation picks.
    pub const ALL: [FrameKind; 7] = [
        FrameKind::Hello,
        FrameKind::Init,
        FrameKind::Push,
        FrameKind::Center,
        FrameKind::Stop,
        FrameKind::Done,
        FrameKind::Diverged,
    ];

    fn from_u8(b: u8) -> Result<FrameKind> {
        Ok(match b {
            0 => FrameKind::Hello,
            1 => FrameKind::Init,
            2 => FrameKind::Push,
            3 => FrameKind::Center,
            4 => FrameKind::Stop,
            5 => FrameKind::Done,
            6 => FrameKind::Diverged,
            other => return Err(crate::err!("unknown wire frame kind {other}")),
        })
    }
}

/// One protocol frame.
#[derive(Clone, Debug, PartialEq)]
pub struct Frame {
    pub kind: FrameKind,
    pub wid: u32,
    pub clock: u64,
    pub payload: Vec<f32>,
}

impl Frame {
    pub fn new(kind: FrameKind, wid: u32, clock: u64, payload: Vec<f32>) -> Frame {
        Frame { kind, wid, clock, payload }
    }
}

/// Per-endpoint accumulator of measured wire costs.
#[derive(Clone, Copy, Debug, Default)]
pub struct WireClock {
    /// Nanoseconds spent encoding/decoding frames (f32 ⇄ bytes).
    pub serialize_ns: u64,
    /// Nanoseconds spent in socket write/flush/read calls.
    pub transfer_ns: u64,
    /// Frames sent + received.
    pub frames: u64,
    /// Payload bytes sent + received (header excluded: the interesting
    /// quantity is the θ message size the thesis' cost model prices).
    pub payload_bytes: u64,
}

impl WireClock {
    pub fn serialize_s(&self) -> f64 {
        self.serialize_ns as f64 * 1e-9
    }

    pub fn transfer_s(&self) -> f64 {
        self.transfer_ns as f64 * 1e-9
    }
}

/// Encode and write one frame; encode time lands in
/// `clock.serialize_ns`, the socket write in `clock.transfer_ns`.
pub fn send_frame<W: Write>(w: &mut W, frame: &Frame, clock: &mut WireClock) -> Result<()> {
    let t0 = Instant::now();
    let mut buf = Vec::with_capacity(HEADER_BYTES + frame.payload.len() * 4);
    buf.extend_from_slice(&MAGIC.to_le_bytes());
    buf.extend_from_slice(&VERSION.to_le_bytes());
    buf.push(frame.kind as u8);
    buf.extend_from_slice(&frame.wid.to_le_bytes());
    buf.extend_from_slice(&frame.clock.to_le_bytes());
    let n = u32::try_from(frame.payload.len()).map_err(|_| {
        crate::err!("frame payload of {} f32s overflows the u32 length field", frame.payload.len())
    })?;
    buf.extend_from_slice(&n.to_le_bytes());
    for &x in &frame.payload {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    clock.serialize_ns += t0.elapsed().as_nanos() as u64;

    let t1 = Instant::now();
    w.write_all(&buf)
        .map_err(|e| crate::err!("socket write failed ({:?} frame): {e}", frame.kind))?;
    w.flush()
        .map_err(|e| crate::err!("socket flush failed ({:?} frame): {e}", frame.kind))?;
    clock.transfer_ns += t1.elapsed().as_nanos() as u64;
    clock.frames += 1;
    clock.payload_bytes += (frame.payload.len() * 4) as u64;
    Ok(())
}

/// Read and decode one frame; the socket reads land in
/// `clock.transfer_ns`, the decode in `clock.serialize_ns`.
pub fn recv_frame<R: Read>(r: &mut R, clock: &mut WireClock) -> Result<Frame> {
    let mut header = [0u8; HEADER_BYTES];
    let t0 = Instant::now();
    r.read_exact(&mut header)
        .map_err(|e| crate::err!("socket closed mid-stream (reading frame header): {e}"))?;
    clock.transfer_ns += t0.elapsed().as_nanos() as u64;

    let t1 = Instant::now();
    let magic = u32::from_le_bytes(header[0..4].try_into().unwrap());
    if magic != MAGIC {
        return Err(crate::err!(
            "bad frame magic 0x{magic:08x} (expected 0x{MAGIC:08x}) — stream desynced or not a wire peer"
        ));
    }
    let version = u16::from_le_bytes(header[4..6].try_into().unwrap());
    if version != VERSION {
        return Err(crate::err!(
            "wire version mismatch: peer speaks v{version}, this binary speaks v{VERSION}"
        ));
    }
    let kind = FrameKind::from_u8(header[6])?;
    let wid = u32::from_le_bytes(header[7..11].try_into().unwrap());
    let fclock = u64::from_le_bytes(header[11..19].try_into().unwrap());
    let n = u32::from_le_bytes(header[19..23].try_into().unwrap());
    // The mutation build (`--cfg wire_mutate_no_payload_cap`) deletes
    // this guard; CI requires `fuzz_wire` to fail when it does.
    #[cfg(not(wire_mutate_no_payload_cap))]
    {
        if n > MAX_PAYLOAD {
            return Err(crate::err!(
                "frame length prefix {n} f32s exceeds the {MAX_PAYLOAD} cap — corrupt stream?"
            ));
        }
    }
    clock.serialize_ns += t1.elapsed().as_nanos() as u64;

    // Chunked read: allocation is bounded by bytes actually received
    // (plus at most one READ_CHUNK_BYTES chunk), so even a length
    // prefix lying about `n` cannot make this endpoint reserve the
    // claimed size up front.
    let want = n as usize * 4;
    let mut bytes: Vec<u8> = Vec::new();
    let t2 = Instant::now();
    while bytes.len() < want {
        let at = bytes.len();
        let take = (want - at).min(READ_CHUNK_BYTES);
        bytes.resize(at + take, 0);
        r.read_exact(&mut bytes[at..]).map_err(|e| {
            crate::err!(
                "socket closed mid-stream (reading {n}-f32 {kind:?} payload at byte {at}): {e}"
            )
        })?;
    }
    clock.transfer_ns += t2.elapsed().as_nanos() as u64;

    let t3 = Instant::now();
    let payload: Vec<f32> = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    clock.serialize_ns += t3.elapsed().as_nanos() as u64;
    clock.frames += 1;
    clock.payload_bytes += (n as usize * 4) as u64;
    Ok(Frame { kind, wid, clock: fclock, payload })
}

/// The transport address the master binds and workers dial, chosen by
/// the `transport=tcp|unix` knob. Round-trips through a CLI argument
/// (`addr=`) so the self-exec'd worker reconnects to the same endpoint.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WireAddr {
    /// `host:port`; port 0 means "bind ephemeral" (the master passes
    /// the actual bound address to the workers).
    Tcp(String),
    #[cfg(unix)]
    Unix(std::path::PathBuf),
}

impl WireAddr {
    /// The `addr=` argument value: `tcp:host:port` or `unix:/path`.
    pub fn to_arg(&self) -> String {
        match self {
            WireAddr::Tcp(hp) => format!("tcp:{hp}"),
            #[cfg(unix)]
            WireAddr::Unix(p) => format!("unix:{}", p.display()),
        }
    }

    pub fn parse(s: &str) -> Result<WireAddr> {
        if let Some(hp) = s.strip_prefix("tcp:") {
            Ok(WireAddr::Tcp(hp.to_string()))
        } else if let Some(path) = s.strip_prefix("unix:") {
            #[cfg(unix)]
            {
                Ok(WireAddr::Unix(std::path::PathBuf::from(path)))
            }
            #[cfg(not(unix))]
            {
                let _ = path;
                Err(crate::err!("unix-domain sockets are not available on this platform"))
            }
        } else {
            Err(crate::err!(
                "invalid wire address '{s}' (expected tcp:host:port or unix:/path)"
            ))
        }
    }
}

/// A connected stream of either transport.
pub enum WireStream {
    Tcp(std::net::TcpStream),
    #[cfg(unix)]
    Unix(std::os::unix::net::UnixStream),
}

impl Read for WireStream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            WireStream::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            WireStream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for WireStream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            WireStream::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            WireStream::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            WireStream::Tcp(s) => s.flush(),
            #[cfg(unix)]
            WireStream::Unix(s) => s.flush(),
        }
    }
}

impl WireStream {
    /// Dial the master, retrying briefly (the worker process can win
    /// the race against the master's accept loop, never its bind —
    /// the listener exists before the worker is spawned).
    pub fn connect(addr: &WireAddr) -> Result<WireStream> {
        let deadline = Instant::now() + std::time::Duration::from_secs(10);
        loop {
            let attempt = match addr {
                WireAddr::Tcp(hp) => std::net::TcpStream::connect(hp).map(WireStream::Tcp),
                #[cfg(unix)]
                WireAddr::Unix(p) => {
                    std::os::unix::net::UnixStream::connect(p).map(WireStream::Unix)
                }
            };
            match attempt {
                Ok(s) => {
                    if let WireStream::Tcp(t) = &s {
                        // θ frames are latency-bound round trips.
                        let _ = t.set_nodelay(true);
                    }
                    return Ok(s);
                }
                Err(e) if Instant::now() < deadline => {
                    let _ = e;
                    thread::sleep(std::time::Duration::from_millis(20));
                }
                Err(e) => {
                    return Err(crate::err!("cannot connect to master at {}: {e}", addr.to_arg()))
                }
            }
        }
    }
}

/// A bound listener of either transport.
pub enum WireListener {
    Tcp(std::net::TcpListener),
    #[cfg(unix)]
    Unix(std::os::unix::net::UnixListener),
}

impl WireListener {
    /// Bind `addr`; returns the listener and the ACTUAL address (TCP
    /// port 0 resolves to the ephemeral port the workers must dial).
    pub fn bind(addr: &WireAddr) -> Result<(WireListener, WireAddr)> {
        match addr {
            WireAddr::Tcp(hp) => {
                let l = std::net::TcpListener::bind(hp)
                    .map_err(|e| crate::err!("cannot bind tcp listener on {hp}: {e}"))?;
                let actual = l
                    .local_addr()
                    .map_err(|e| crate::err!("cannot resolve bound tcp address: {e}"))?;
                Ok((WireListener::Tcp(l), WireAddr::Tcp(actual.to_string())))
            }
            #[cfg(unix)]
            WireAddr::Unix(p) => {
                // A stale socket file from a killed run blocks bind.
                let _ = std::fs::remove_file(p);
                let l = std::os::unix::net::UnixListener::bind(p)
                    .map_err(|e| crate::err!("cannot bind unix listener at {}: {e}", p.display()))?;
                Ok((WireListener::Unix(l), WireAddr::Unix(p.clone())))
            }
        }
    }

    /// Accept one worker connection, or error after `timeout` —
    /// a worker that died before dialing must fail the run loudly, not
    /// hang the master's accept loop forever.
    pub fn accept_timeout(&self, timeout: std::time::Duration) -> Result<WireStream> {
        let deadline = Instant::now() + timeout;
        self.set_nonblocking(true)?;
        let out = loop {
            let attempt = match self {
                WireListener::Tcp(l) => l.accept().map(|(s, _)| WireStream::Tcp(s)),
                #[cfg(unix)]
                WireListener::Unix(l) => l.accept().map(|(s, _)| WireStream::Unix(s)),
            };
            match attempt {
                Ok(s) => break s,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        return Err(crate::err!(
                            "no worker connected within {:.0?} — did a worker process die on startup?",
                            timeout
                        ));
                    }
                    thread::sleep(std::time::Duration::from_millis(5));
                }
                Err(e) => return Err(crate::err!("accept failed: {e}")),
            }
        };
        self.set_nonblocking(false)?;
        if let WireStream::Tcp(t) = &out {
            let _ = t.set_nodelay(true);
        }
        Ok(out)
    }

    fn set_nonblocking(&self, nb: bool) -> Result<()> {
        match self {
            WireListener::Tcp(l) => l.set_nonblocking(nb),
            #[cfg(unix)]
            WireListener::Unix(l) => l.set_nonblocking(nb),
        }
        .map_err(|e| crate::err!("set_nonblocking({nb}) failed: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip_preserves_everything() {
        let f = Frame::new(FrameKind::Push, 3, 41, vec![1.0, -2.5, 0.0, f32::MIN_POSITIVE]);
        let mut buf = Vec::new();
        let mut ck = WireClock::default();
        send_frame(&mut buf, &f, &mut ck).unwrap();
        assert_eq!(buf.len(), HEADER_BYTES + 16);
        let g = recv_frame(&mut buf.as_slice(), &mut ck).unwrap();
        assert_eq!(f, g);
        assert_eq!(ck.frames, 2);
        assert_eq!(ck.payload_bytes, 32);
        assert!(ck.serialize_ns > 0);
    }

    #[test]
    fn empty_payload_frames_work() {
        let f = Frame::new(FrameKind::Hello, 7, 0, vec![]);
        let mut buf = Vec::new();
        let mut ck = WireClock::default();
        send_frame(&mut buf, &f, &mut ck).unwrap();
        let g = recv_frame(&mut buf.as_slice(), &mut ck).unwrap();
        assert_eq!(g.kind, FrameKind::Hello);
        assert_eq!(g.wid, 7);
        assert!(g.payload.is_empty());
    }

    #[test]
    fn bad_magic_is_a_descriptive_error() {
        let mut buf = vec![0xDEu8; HEADER_BYTES];
        let e = recv_frame(&mut buf.as_slice(), &mut WireClock::default()).unwrap_err();
        assert!(format!("{e}").contains("bad frame magic"), "{e}");
    }

    #[test]
    fn version_mismatch_is_a_descriptive_error() {
        let f = Frame::new(FrameKind::Init, 0, 0, vec![1.0]);
        let mut buf = Vec::new();
        send_frame(&mut buf, &f, &mut WireClock::default()).unwrap();
        buf[4] = 99; // stomp the version field
        let e = recv_frame(&mut buf.as_slice(), &mut WireClock::default()).unwrap_err();
        assert!(format!("{e}").contains("wire version mismatch"), "{e}");
    }

    #[test]
    fn unknown_kind_and_oversized_length_are_rejected() {
        let f = Frame::new(FrameKind::Init, 0, 0, vec![]);
        let mut buf = Vec::new();
        send_frame(&mut buf, &f, &mut WireClock::default()).unwrap();
        let mut bad_kind = buf.clone();
        bad_kind[6] = 42;
        let e = recv_frame(&mut bad_kind.as_slice(), &mut WireClock::default()).unwrap_err();
        assert!(format!("{e}").contains("unknown wire frame kind"), "{e}");
        let mut bad_len = buf;
        bad_len[19..23].copy_from_slice(&u32::MAX.to_le_bytes());
        let e = recv_frame(&mut bad_len.as_slice(), &mut WireClock::default()).unwrap_err();
        assert!(format!("{e}").contains("cap — corrupt stream"), "{e}");
    }

    #[test]
    fn truncated_stream_names_the_failure_point() {
        let f = Frame::new(FrameKind::Center, 1, 5, vec![1.0, 2.0, 3.0]);
        let mut buf = Vec::new();
        send_frame(&mut buf, &f, &mut WireClock::default()).unwrap();
        buf.truncate(HEADER_BYTES + 4); // header + 1 of 3 payload f32s
        let e = recv_frame(&mut buf.as_slice(), &mut WireClock::default()).unwrap_err();
        assert!(format!("{e}").contains("payload at byte"), "{e}");
        let mut short = vec![0u8; 3];
        short.copy_from_slice(&MAGIC.to_le_bytes()[..3]);
        let e = recv_frame(&mut short.as_slice(), &mut WireClock::default()).unwrap_err();
        assert!(format!("{e}").contains("reading frame header"), "{e}");
    }

    /// A sink that fails on write or flush, to pin the send-side
    /// error messages.
    struct FailIo {
        on_flush: bool,
    }

    impl Write for FailIo {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            if self.on_flush {
                Ok(buf.len())
            } else {
                Err(std::io::Error::other("wire down"))
            }
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Err(std::io::Error::other("wire down"))
        }
    }

    #[test]
    fn send_failures_name_the_phase_and_frame() {
        let f = Frame::new(FrameKind::Push, 2, 9, vec![1.0]);
        let mut ck = WireClock::default();
        let e = send_frame(&mut FailIo { on_flush: false }, &f, &mut ck).unwrap_err();
        assert!(format!("{e}").contains("socket write failed"), "{e}");
        assert!(format!("{e}").contains("Push"), "{e}");
        let e = send_frame(&mut FailIo { on_flush: true }, &f, &mut ck).unwrap_err();
        assert!(format!("{e}").contains("socket flush failed"), "{e}");
        assert_eq!(ck.frames, 0, "failed sends must not count as frames");
    }

    #[test]
    fn addr_arg_roundtrip() {
        let a = WireAddr::Tcp("127.0.0.1:4477".into());
        assert_eq!(WireAddr::parse(&a.to_arg()).unwrap(), a);
        #[cfg(unix)]
        {
            let u = WireAddr::Unix(std::path::PathBuf::from("/tmp/et.sock"));
            assert_eq!(WireAddr::parse(&u.to_arg()).unwrap(), u);
        }
        let e = WireAddr::parse("carrier-pigeon:coop").unwrap_err();
        assert!(format!("{e}").contains("invalid wire address"), "{e}");
    }

    #[test]
    #[cfg_attr(miri, ignore = "Miri's interpreter has no socket support")]
    fn bind_and_accept_failures_are_descriptive() {
        // An unresolvable host fails bind with the address in the message.
        let e = WireListener::bind(&WireAddr::Tcp("definitely.invalid.host.example:0".into()))
            .unwrap_err();
        assert!(format!("{e}").contains("cannot bind tcp listener"), "{e}");
        #[cfg(unix)]
        {
            let p = std::path::PathBuf::from("/nonexistent-dir-for-sure/et.sock");
            let e = WireListener::bind(&WireAddr::Unix(p)).unwrap_err();
            assert!(format!("{e}").contains("cannot bind unix listener"), "{e}");
        }
        // Nobody dials: the accept timeout names the suspicion.
        let (l, _) = WireListener::bind(&WireAddr::Tcp("127.0.0.1:0".into())).unwrap();
        let e = l.accept_timeout(std::time::Duration::from_millis(30)).unwrap_err();
        assert!(format!("{e}").contains("no worker connected within"), "{e}");
    }

    #[test]
    #[cfg_attr(miri, ignore = "Miri's interpreter has no socket support")]
    fn tcp_listener_roundtrip_one_frame() {
        let (l, actual) = WireListener::bind(&WireAddr::Tcp("127.0.0.1:0".into())).unwrap();
        let dial = actual.clone();
        let t = std::thread::spawn(move || {
            let mut s = WireStream::connect(&dial).unwrap();
            let mut ck = WireClock::default();
            send_frame(&mut s, &Frame::new(FrameKind::Hello, 9, 0, vec![]), &mut ck).unwrap();
            let reply = recv_frame(&mut s, &mut ck).unwrap();
            (reply, ck)
        });
        let mut conn = l.accept_timeout(std::time::Duration::from_secs(5)).unwrap();
        let mut ck = WireClock::default();
        let hello = recv_frame(&mut conn, &mut ck).unwrap();
        assert_eq!(hello.kind, FrameKind::Hello);
        assert_eq!(hello.wid, 9);
        send_frame(
            &mut conn,
            &Frame::new(FrameKind::Init, 0, 0, vec![0.5; 64]),
            &mut ck,
        )
        .unwrap();
        let (reply, worker_ck) = t.join().unwrap();
        assert_eq!(reply.payload, vec![0.5; 64]);
        assert!(worker_ck.transfer_ns > 0, "socket time must be measured");
    }
}
