"""L1 Pallas kernel: causal attention forward for the L2 transformer.

Tiling (DESIGN.md §3): the grid is (batch*heads, T/BQ). Each grid step
holds one q-tile of BQ rows plus the full K/V slab for that head in VMEM
(T is small in this model family; at T=128, Dh=64 the live set is
2*T*Dh + BQ*Dh + BQ*T ≈ 72 KiB f32 — comfortably inside a TPU core's
~16 MiB VMEM, leaving room for double-buffering). The q·kᵀ and p·v
contractions are MXU work on real hardware (bf16-in/f32-acc); here they
lower through interpret=True to plain HLO dots.

AD: interpret-mode pallas_call has no usable VJP, so the public
``attention`` wraps the kernel in jax.custom_vjp whose backward is the
pure-jnp oracle's VJP (kernels/ref.py) — numerically identical, checked
by pytest.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

BQ = 32  # q-tile rows per grid step


def _attn_kernel(scale: float, t: int, q_ref, k_ref, v_ref, o_ref):
    # q_ref: (1, BQ, Dh); k_ref/v_ref: (1, T, Dh); o_ref: (1, BQ, Dh)
    j = pl.program_id(1)
    q = q_ref[0]                       # (BQ, Dh)
    k = k_ref[0]                       # (T, Dh)
    v = v_ref[0]                       # (T, Dh)
    s = jnp.dot(q, k.T) * scale        # (BQ, T) — MXU contraction
    q_idx = j * BQ + jax.lax.iota(jnp.int32, BQ)
    k_idx = jax.lax.iota(jnp.int32, t)
    mask = q_idx[:, None] >= k_idx[None, :]
    s = jnp.where(mask, s, jnp.asarray(-1e30, s.dtype))
    # Numerically stable softmax along k.
    m = jnp.max(s, axis=1, keepdims=True)
    p = jnp.exp(s - m)
    p = p / jnp.sum(p, axis=1, keepdims=True)
    o_ref[0] = jnp.dot(p, v)           # (BQ, Dh) — MXU contraction


def _attention_fwd_pallas(q, k, v, scale):
    b, h, t, dh = q.shape
    assert t % BQ == 0, f"T={t} must be a multiple of BQ={BQ}"
    qm = q.reshape(b * h, t, dh)
    km = k.reshape(b * h, t, dh)
    vm = v.reshape(b * h, t, dh)
    grid = (b * h, t // BQ)
    out = pl.pallas_call(
        functools.partial(_attn_kernel, scale, t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, BQ, dh), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, t, dh), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, t, dh), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, BQ, dh), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, t, dh), q.dtype),
        interpret=True,
    )(qm, km, vm)
    return out.reshape(b, h, t, dh)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def attention(q, k, v, scale):
    """Causal attention with a Pallas forward and oracle-VJP backward."""
    return _attention_fwd_pallas(q, k, v, scale)


def _fwd(q, k, v, scale):
    return _attention_fwd_pallas(q, k, v, scale), (q, k, v)


def _bwd(scale, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q, k, v: ref.attention_ref(q, k, v, scale), q, k, v)
    return vjp(g)


attention.defvjp(_fwd, _bwd)
