//! Artifact loading: manifest.json + HLO text + shared init params.
//!
//! HLO *text* is the interchange format — xla_extension 0.5.1 rejects
//! jax≥0.5 serialized protos (64-bit instruction ids); the text parser
//! reassigns ids (see /opt/xla-example/README.md and DESIGN.md).

use crate::config::Json;
use crate::error::{Context, Result};
use crate::{bail, err};
use std::path::{Path, PathBuf};

/// One entry of the parameter table (the contract with
/// `python/compile/model.py::param_specs`).
#[derive(Clone, Debug)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
}

/// Model geometry from the manifest.
#[derive(Clone, Copy, Debug)]
pub struct ModelDims {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub seq_len: usize,
    pub batch: usize,
}

/// Parsed artifacts directory.
pub struct Artifacts {
    pub dir: PathBuf,
    pub preset: String,
    pub n_params: usize,
    pub params: Vec<ParamEntry>,
    pub dims: ModelDims,
}

impl Artifacts {
    /// The default location relative to the repo root.
    pub fn default_dir() -> PathBuf {
        PathBuf::from("artifacts")
    }

    pub fn load(dir: &Path) -> Result<Artifacts> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} — run `make artifacts`"))?;
        let j = Json::parse(&text).map_err(|e| err!("manifest parse: {e}"))?;

        let n_params = j
            .get("preset_params")
            .and_then(Json::as_usize)
            .ok_or_else(|| err!("manifest missing preset_params"))?;
        let preset = j
            .get("preset")
            .and_then(Json::as_str)
            .unwrap_or("unknown")
            .to_string();

        let mut params = Vec::new();
        let mut expect_off = 0usize;
        for e in j
            .get("params")
            .and_then(Json::as_arr)
            .ok_or_else(|| err!("manifest missing params"))?
        {
            let entry = ParamEntry {
                name: e
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| err!("param missing name"))?
                    .to_string(),
                shape: e
                    .get("shape")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| err!("param missing shape"))?
                    .iter()
                    .map(|d| d.as_usize().unwrap_or(0))
                    .collect(),
                offset: e.get("offset").and_then(Json::as_usize).unwrap_or(0),
                size: e.get("size").and_then(Json::as_usize).unwrap_or(0),
            };
            if entry.offset != expect_off {
                bail!("param table not contiguous at {}", entry.name);
            }
            if entry.shape.iter().product::<usize>() != entry.size {
                bail!("shape/size mismatch for {}", entry.name);
            }
            expect_off += entry.size;
            params.push(entry);
        }
        if expect_off != n_params {
            bail!("param table sums to {expect_off}, manifest says {n_params}");
        }

        let cfg = j
            .get("config")
            .ok_or_else(|| err!("manifest missing config"))?;
        let dim = |k: &str| -> Result<usize> {
            cfg.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| err!("config missing {k}"))
        };
        let dims = ModelDims {
            vocab: dim("vocab")?,
            d_model: dim("d_model")?,
            n_layers: dim("n_layers")?,
            n_heads: dim("n_heads")?,
            seq_len: dim("seq_len")?,
            batch: dim("batch")?,
        };

        Ok(Artifacts { dir: dir.to_path_buf(), preset, n_params, params, dims })
    }

    /// The shared random init (thesis §4.1: identical for master and
    /// every worker).
    pub fn init_params(&self) -> Result<Vec<f32>> {
        let path = self.dir.join("init_params.bin");
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {path:?}"))?;
        if bytes.len() != self.n_params * 4 {
            bail!(
                "init_params.bin is {} bytes, expected {}",
                bytes.len(),
                self.n_params * 4
            );
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Load + compile one HLO text artifact on the given client.
    #[cfg(feature = "pjrt")]
    pub fn compile(
        &self,
        client: &xla::PjRtClient,
        name: &str,
    ) -> Result<xla::PjRtLoadedExecutable> {
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let path_str = path
            .to_str()
            .ok_or_else(|| err!("non-utf8 path {path:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .map_err(|e| err!("parsing {path_str}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        client
            .compile(&comp)
            .map_err(|e| err!("compiling {name}: {e:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn repo_artifacts() -> Option<Artifacts> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Artifacts::load(&dir).ok()
    }

    #[test]
    fn manifest_loads_and_is_consistent() {
        let Some(a) = repo_artifacts() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        assert!(a.n_params > 0);
        assert_eq!(a.params[0].name, "tok_embed");
        assert_eq!(a.params[0].shape, vec![a.dims.vocab, a.dims.d_model]);
        let init = a.init_params().unwrap();
        assert_eq!(init.len(), a.n_params);
        assert!(init.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn missing_dir_errors_cleanly() {
        let err = match Artifacts::load(Path::new("/nonexistent/artifacts")) {
            Err(e) => e,
            Ok(_) => panic!("expected error"),
        };
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
