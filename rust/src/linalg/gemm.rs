//! Blocked f32 GEMM micro-kernels — the compute substrate of the
//! batched MLP oracle.
//!
//! The Chapter-4/6 sweeps and both real-thread backends spend their
//! wall clock inside `Mlp::grad_batch`; every matrix product there
//! lands on [`sgemm`] (accumulating `C += op(A)·op(B)` with transpose
//! flags) or on the fused [`sgemm_bias_act`] forward epilogue (bias
//! broadcast + optional ReLU applied while the accumulator tile is
//! still in registers). The kernels are register-blocked — an
//! [`MR`]×[`NR`] accumulator tile per iteration, streaming
//! contiguously along the output row — and never allocate: callers own
//! every buffer.
//!
//! **Kernel tiers:** the portable kernels below auto-vectorize; the
//! [`super::simd`] module adds explicit AVX2+FMA / NEON
//! implementations behind the off-by-default `simd` cargo feature.
//! Every execution routes through `simd`'s dispatch wrappers, which
//! collapse to the scalar kernels when the feature is off (or the tier
//! is `scalar`) — so this file stays the reference semantics.
//!
//! Layout convention: everything is row-major and contiguous (leading
//! dimension = column count), which is both how the model stores its
//! batch-major activation matrices and how a flat `theta` stores each
//! layer's `din × dout` weight block. Three storage-aware paths cover
//! the MLP's products without packing scratch:
//!
//! - `A·B` (forward): broadcast kernel, B streamed along rows;
//! - `Aᵀ·B` (weight gradient, sum over the batch): same broadcast
//!   kernel with swapped A strides — the broadcast load is scalar, so
//!   the strided access costs nothing in the vector lanes;
//! - `A·Bᵀ` (input gradient): both operands are walked along their
//!   contiguous k-axis, so each output is one vectorized dot product.
//!
//! **Hybrid parallelism:** every kernel takes an output *span* — rows
//! `[i0, i1)` × columns `[j0, j1)` — so a product can be split into
//! contiguous MR-aligned row panels (the default) or, when M is too
//! short to feed the helpers and N is wide, NR-aligned column panels
//! (see [`pool::plan_for`]), and dispatched on the per-worker
//! [`super::pool`]. Each output element is computed whole, by one
//! thread, in the serial inner-loop order — panel starts sit on tile
//! boundaries, so every element takes the same full-block or tail code
//! path it would serially, making the threaded result **bitwise
//! identical** to single-thread *within a kernel tier*. At
//! `threads = 1` (the default) dispatch runs the full span `[0, m) ×
//! [0, n)` inline on the caller: the exact pre-pool code path.
//!
//! Not to be confused with [`super::Matrix`], the f64 substrate of the
//! eigenvalue solver: that one optimizes for robustness on ≤ 20×20
//! stability matrices, this one for throughput on batch × dim panels.

use super::pool::{self, Split};
use super::simd;
use std::ptr::NonNull;

/// Register-tile rows of the broadcast kernels.
pub const MR: usize = 4;
/// Register-tile columns (f32 lanes) of the broadcast kernels.
pub const NR: usize = 16;

/// Which kernel a dispatched [`Job`] runs over its span.
#[derive(Clone, Copy)]
pub(crate) enum JobKind {
    /// Broadcast-form `C += op(A)·B` with `op(A)[i][p] = a[i*ars + p*acs]`.
    Broadcast { ars: usize, acs: usize },
    /// Dot-form `C += A·Bᵀ`.
    Dot,
    /// `C += Aᵀ·Bᵀ`.
    BothT,
    /// Fused overwrite `C = act(A·B + bias)`.
    BiasAct { relu: bool },
}

/// A GEMM flight plan: raw operand pointers plus the full problem
/// shape and the split axis. `Copy` so dispatch publishes it to
/// helpers by value — no allocation, no lifetime to thread through the
/// pool.
///
/// # Aliasing invariants (the whole safety story, in one place)
///
/// 1. **Lifetime**: a `Job` is built in [`dispatch`] from live slice
///    borrows (`a: &[f32]`, `b: &[f32]`, optional `bias: &[f32]`,
///    `c: &mut [f32]`) and is only executed between construction and
///    dispatch's return — [`pool::GemmPool::run`] blocks until every
///    helper has finished its panel, so the pointers never outlive the
///    borrows they were derived from.
/// 2. **Sizes**: the public entry points assert `a.len() == m·k`,
///    `b.len() == k·n`, `bias.len() == n`, `c.len() == m·n` before a
///    `Job` exists, so every in-range reconstruction in [`exec_span`]
///    stays inside the original allocations.
/// 3. **Disjoint writes**: concurrent executors receive spans from
///    [`pool::span_for`], which partitions `[0, m)` (row split) or
///    `[0, n)` (column split) — the `&mut` row segments each span
///    materializes through [`COut::row`] are pairwise disjoint across
///    spans, so no two live `&mut` ever overlap. `a`, `b`, and `bias`
///    are reconstructed only as shared `&[f32]`, which may alias
///    freely.
/// 4. **Provenance**: `bias` is `Option<NonNull<f32>>` — present iff
///    the job is `BiasAct` (checked at construction from a real slice,
///    never a dangling sentinel), so Miri's provenance tracking sees
///    either a valid derived pointer or no pointer at all.
#[derive(Clone, Copy)]
pub(crate) struct Job {
    kind: JobKind,
    split: Split,
    m: usize,
    n: usize,
    k: usize,
    a: *const f32,
    b: *const f32,
    /// `Some` iff `kind` is [`JobKind::BiasAct`]; points at the bias
    /// slice (length `n`) the job was constructed from.
    bias: Option<NonNull<f32>>,
    c: *mut f32,
}

// SAFETY: per the aliasing invariants above — the pointers describe
// caller-owned slices that outlive the dispatch (the dispatching
// thread blocks until all helpers finish), and each helper writes a
// disjoint span of `c`.
unsafe impl Send for Job {}

impl Job {
    /// Output rows (M) — what a row split partitions.
    pub(crate) fn rows(&self) -> usize {
        self.m
    }

    /// Output columns (N) — what a column split partitions.
    pub(crate) fn cols(&self) -> usize {
        self.n
    }

    /// The axis this job is split along.
    pub(crate) fn split(&self) -> Split {
        self.split
    }
}

/// Kernel-side view of the output matrix: base pointer + row stride.
/// Kernels address C exclusively through [`COut::row`], which is the
/// single place a `&mut` output segment is materialized — one accessor
/// serves both split modes (a column-split span's rows interleave with
/// its neighbors' in memory, so no contiguous `&mut` panel exists to
/// hand out).
pub(crate) struct COut {
    ptr: *mut f32,
    ldc: usize,
}

impl COut {
    /// `&mut C[i][j0..j1]` — row `i` (global index), columns `[j0, j1)`.
    #[inline(always)]
    pub(crate) fn row(&mut self, i: usize, j0: usize, j1: usize) -> &mut [f32] {
        debug_assert!(j0 <= j1 && j1 <= self.ldc);
        // SAFETY: Job invariants 2–3 — the pointer covers the live
        // `c.len() == m·n` borrow, `i*ldc + j1 <= m·n` for every row a
        // span owns, and spans own disjoint (row, column-range) sets,
        // so this is the only live &mut over these elements. Borrowing
        // &mut self serializes rows *within* one span's kernel call.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(i * self.ldc + j0), j1 - j0) }
    }
}

/// Run `job`'s kernel over its span `[s0, s1)` — row indices under a
/// row split, column indices under a column split. Span starts must be
/// tile-aligned (MR / NR) or equal to the end; callers obtain spans
/// from [`pool::span_for`], which guarantees this.
pub(crate) fn exec_span(job: &Job, s0: usize, s1: usize) {
    if s1 <= s0 {
        return;
    }
    let (m, n, k) = (job.m, job.n, job.k);
    let (i0, i1, j0, j1) = match job.split {
        Split::Rows => (s0, s1, 0, n),
        Split::Cols => (0, m, s0, s1),
    };
    // SAFETY: Job invariants 1–2 — the pointers cover a.len() == m*k,
    // b.len() == k*n live caller borrows, reconstructed shared-only.
    let a = unsafe { std::slice::from_raw_parts(job.a, m * k) };
    let b = unsafe { std::slice::from_raw_parts(job.b, k * n) };
    let mut c = COut { ptr: job.c, ldc: n };
    match job.kind {
        JobKind::Broadcast { ars, acs } => {
            simd::broadcast(i0, i1, j0, j1, n, k, [ars, acs], a, b, &mut c)
        }
        JobKind::Dot => simd::dot(i0, i1, j0, j1, k, a, b, &mut c),
        JobKind::BothT => simd::both_t(i0, i1, j0, j1, m, k, a, b, &mut c),
        JobKind::BiasAct { relu } => {
            let bias = job.bias.expect("BiasAct jobs always carry a bias pointer");
            // SAFETY: Job invariant 4 — a Some bias was derived from a
            // live &[f32] of len n at construction.
            let bias = unsafe { std::slice::from_raw_parts(bias.as_ptr(), n) };
            simd::bias_act(i0, i1, j0, j1, n, k, a, b, bias, relu, &mut c);
        }
    }
}

/// Route a product to the caller's thread (full span) or the
/// per-worker pool (MR-aligned row panels, or NR-aligned column panels
/// when M is short and N wide), per the configured `threads=` knob and
/// the work threshold.
#[allow(clippy::too_many_arguments)]
fn dispatch(
    kind: JobKind,
    m: usize,
    n: usize,
    k: usize,
    a: &[f32],
    b: &[f32],
    bias: Option<&[f32]>,
    c: &mut [f32],
) {
    debug_assert_eq!(
        bias.is_some(),
        matches!(kind, JobKind::BiasAct { .. }),
        "bias operand iff BiasAct"
    );
    let (t, split) = pool::plan_for(m, n, k);
    let job = Job {
        kind,
        split,
        m,
        n,
        k,
        a: a.as_ptr(),
        b: b.as_ptr(),
        // NonNull::from(slice).cast() keeps the slice's provenance and
        // can never smuggle in a null/dangling sentinel.
        bias: bias.map(|s| NonNull::from(s).cast::<f32>()),
        c: c.as_mut_ptr(),
    };
    if t <= 1 {
        // Serial plans are always Split::Rows: the full row span.
        exec_span(&job, 0, m);
    } else {
        pool::run(&job, t);
    }
}

/// `C(m×n) += op(A)·op(B)`, accumulating into `C`.
///
/// `op(A)` is `m×k` (stored `k×m` row-major when `ta`), `op(B)` is
/// `k×n` (stored `n×k` row-major when `tb`). All slices must be
/// exactly the implied size.
#[allow(clippy::too_many_arguments)]
pub fn sgemm(
    ta: bool,
    tb: bool,
    m: usize,
    n: usize,
    k: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
) {
    assert_eq!(a.len(), m * k, "A size");
    assert_eq!(b.len(), k * n, "B size");
    assert_eq!(c.len(), m * n, "C size");
    let kind = match (ta, tb) {
        // op(A)[i][p] = a[i*ars + p*acs]; broadcast loads are scalar,
        // so runtime strides cost nothing in the vector lanes.
        (false, false) => JobKind::Broadcast { ars: k, acs: 1 },
        (true, false) => JobKind::Broadcast { ars: 1, acs: m },
        (false, true) => JobKind::Dot,
        (true, true) => JobKind::BothT,
    };
    dispatch(kind, m, n, k, a, b, None, c);
}

/// Fused forward step: `C(m×n) = act(A(m×k)·B(k×n) + bias)`,
/// overwriting `C`. `bias` (length `n`) is broadcast over rows; the
/// activation is ReLU when `relu`, identity otherwise — applied in the
/// epilogue, before the accumulator tile is stored.
#[allow(clippy::too_many_arguments)]
pub fn sgemm_bias_act(
    m: usize,
    n: usize,
    k: usize,
    a: &[f32],
    b: &[f32],
    bias: &[f32],
    relu: bool,
    c: &mut [f32],
) {
    assert_eq!(a.len(), m * k, "A size");
    assert_eq!(b.len(), k * n, "B size");
    assert_eq!(bias.len(), n, "bias size");
    assert_eq!(c.len(), m * n, "C size");
    dispatch(JobKind::BiasAct { relu }, m, n, k, a, b, Some(bias), c);
}

/// `out[j] += Σ_i a[i][j]` over an `m×n` row-major panel — the bias
/// gradient's column reduction, batched. Stays serial: it is O(m·n)
/// with no k-axis to amortize a dispatch over.
pub fn col_sums_accum(m: usize, n: usize, a: &[f32], out: &mut [f32]) {
    assert_eq!(a.len(), m * n, "A size");
    assert_eq!(out.len(), n, "out size");
    for i in 0..m {
        let row = &a[i * n..(i + 1) * n];
        for (ov, &av) in out.iter_mut().zip(row) {
            *ov += av;
        }
    }
}

/// Lane-blocked dot product (8 independent partial sums so the
/// reduction auto-vectorizes).
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let head = x.len() / 8 * 8;
    let mut lanes = [0.0f32; 8];
    for (xc, yc) in x[..head].chunks_exact(8).zip(y[..head].chunks_exact(8)) {
        for (l, lane) in lanes.iter_mut().enumerate() {
            *lane += xc[l] * yc[l];
        }
    }
    let mut s: f32 = lanes.iter().sum();
    for (&xv, &yv) in x[head..].iter().zip(&y[head..]) {
        s += xv * yv;
    }
    s
}

/// Fused bias+activation kernel over rows `[i0, i1)` × columns
/// `[j0, j1)`; `c` addresses the full output through [`COut`], `a` is
/// the full `m×k` operand indexed by global row. The loop structure is
/// the pre-pool serial body with the row counter started at `i0` and
/// the column loops bounded by `[j0, j1)` — `j0` is NR-aligned, so
/// block starts (and therefore per-element code paths) match the
/// serial schedule exactly.
#[allow(clippy::too_many_arguments)]
pub(crate) fn kernel_bias_act(
    i0: usize,
    i1: usize,
    j0: usize,
    j1: usize,
    n: usize,
    k: usize,
    a: &[f32],
    b: &[f32],
    bias: &[f32],
    relu: bool,
    c: &mut COut,
) {
    let mut i = i0;
    while i + MR <= i1 {
        let mut j = j0;
        while j + NR <= j1 {
            let mut acc = [[0.0f32; NR]; MR];
            for accr in acc.iter_mut() {
                accr.copy_from_slice(&bias[j..j + NR]);
            }
            for p in 0..k {
                let brow = &b[p * n + j..p * n + j + NR];
                for (r, accr) in acc.iter_mut().enumerate() {
                    let arp = a[(i + r) * k + p];
                    for (av, &bv) in accr.iter_mut().zip(brow) {
                        *av += arp * bv;
                    }
                }
            }
            for (r, accr) in acc.iter().enumerate() {
                let crow = c.row(i + r, j, j + NR);
                for (cv, &av) in crow.iter_mut().zip(accr) {
                    *cv = if relu { av.max(0.0) } else { av };
                }
            }
            j += NR;
        }
        if j < j1 {
            for r in 0..MR {
                let row = i + r;
                let crow = c.row(row, j, j1);
                crow.copy_from_slice(&bias[j..j1]);
                for p in 0..k {
                    let arp = a[row * k + p];
                    let brow = &b[p * n + j..p * n + j1];
                    for (cv, &bv) in crow.iter_mut().zip(brow) {
                        *cv += arp * bv;
                    }
                }
                if relu {
                    for cv in crow.iter_mut() {
                        *cv = cv.max(0.0);
                    }
                }
            }
        }
        i += MR;
    }
    while i < i1 {
        let crow = c.row(i, j0, j1);
        crow.copy_from_slice(&bias[j0..j1]);
        for p in 0..k {
            let aip = a[i * k + p];
            let brow = &b[p * n + j0..p * n + j1];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += aip * bv;
            }
        }
        if relu {
            for cv in crow.iter_mut() {
                *cv = cv.max(0.0);
            }
        }
        i += 1;
    }
}

/// Broadcast-form kernel over rows `[i0, i1)` × columns `[j0, j1)`:
/// `C += op(A)·B` with `op(A)[i][p] = a[i*strides[0] + p*strides[1]]`
/// (global row index) and `B` stored `k×n` row-major. Covers the
/// no-transpose and A-transposed cases; the inner loop streams `B` and
/// `C` rows while `op(A)` supplies scalar broadcasts.
#[allow(clippy::too_many_arguments)]
pub(crate) fn kernel_broadcast(
    i0: usize,
    i1: usize,
    j0: usize,
    j1: usize,
    n: usize,
    k: usize,
    strides: [usize; 2],
    a: &[f32],
    b: &[f32],
    c: &mut COut,
) {
    let [ars, acs] = strides;
    let mut i = i0;
    while i + MR <= i1 {
        let mut j = j0;
        while j + NR <= j1 {
            let mut acc = [[0.0f32; NR]; MR];
            for p in 0..k {
                let brow = &b[p * n + j..p * n + j + NR];
                for (r, accr) in acc.iter_mut().enumerate() {
                    let arp = a[(i + r) * ars + p * acs];
                    for (av, &bv) in accr.iter_mut().zip(brow) {
                        *av += arp * bv;
                    }
                }
            }
            for (r, accr) in acc.iter().enumerate() {
                let crow = c.row(i + r, j, j + NR);
                for (cv, &av) in crow.iter_mut().zip(accr) {
                    *cv += av;
                }
            }
            j += NR;
        }
        if j < j1 {
            for p in 0..k {
                let brow = &b[p * n + j..p * n + j1];
                for r in 0..MR {
                    let arp = a[(i + r) * ars + p * acs];
                    let crow = c.row(i + r, j, j1);
                    for (cv, &bv) in crow.iter_mut().zip(brow) {
                        *cv += arp * bv;
                    }
                }
            }
        }
        i += MR;
    }
    while i < i1 {
        for p in 0..k {
            let aip = a[i * ars + p * acs];
            let brow = &b[p * n + j0..p * n + j1];
            let crow = c.row(i, j0, j1);
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += aip * bv;
            }
        }
        i += 1;
    }
}

/// Dot-form kernel over rows `[i0, i1)` × columns `[j0, j1)`:
/// `C += A·Bᵀ` with `A` stored `m×k` and `B` stored `n×k` — both
/// operands contiguous along `k`, so every output element is one
/// vectorized [`dot`].
#[allow(clippy::too_many_arguments)]
pub(crate) fn kernel_dot(
    i0: usize,
    i1: usize,
    j0: usize,
    j1: usize,
    k: usize,
    a: &[f32],
    b: &[f32],
    c: &mut COut,
) {
    for i in i0..i1 {
        let arow = &a[i * k..(i + 1) * k];
        let crow = c.row(i, j0, j1);
        for (j, cv) in (j0..j1).zip(crow.iter_mut()) {
            *cv += dot(arow, &b[j * k..(j + 1) * k]);
        }
    }
}

/// `C += Aᵀ·Bᵀ` over rows `[i0, i1)` × columns `[j0, j1)` — not on any
/// hot path (kept for completeness of the flag matrix); plain triple
/// loop. Needs the full `m` because `Aᵀ` is indexed `a[p*m + i]`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn kernel_both_t(
    i0: usize,
    i1: usize,
    j0: usize,
    j1: usize,
    m: usize,
    k: usize,
    a: &[f32],
    b: &[f32],
    c: &mut COut,
) {
    for i in i0..i1 {
        let crow = c.row(i, j0, j1);
        for (j, cv) in (j0..j1).zip(crow.iter_mut()) {
            let mut s = 0.0f32;
            for p in 0..k {
                s += a[p * m + i] * b[j * k + p];
            }
            *cv += s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn fill(rng: &mut Rng, len: usize) -> Vec<f32> {
        (0..len).map(|_| rng.normal(0.0, 1.0) as f32).collect()
    }

    fn naive(ta: bool, tb: bool, m: usize, n: usize, k: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0f64;
                for p in 0..k {
                    let av = if ta { a[p * m + i] } else { a[i * k + p] };
                    let bv = if tb { b[j * k + p] } else { b[p * n + j] };
                    s += av as f64 * bv as f64;
                }
                c[i * n + j] = s as f32;
            }
        }
        c
    }

    fn close(got: &[f32], want: &[f32]) {
        assert_eq!(got.len(), want.len());
        for (i, (g, w)) in got.iter().zip(want).enumerate() {
            assert!((g - w).abs() < 1e-3 * (1.0 + w.abs()), "elem {i}: {g} vs {w}");
        }
    }

    #[test]
    fn all_transpose_flags_match_naive_reference() {
        // Sizes chosen to hit the blocked body, the n-tail, the m-tail,
        // and the degenerate single-row/column cases. Miri interprets
        // every multiply-add, so it keeps the structural shapes and
        // drops the throughput-sized ones.
        let shapes: &[(usize, usize, usize)] = if cfg!(miri) {
            &[(1, 1, 1), (3, 5, 7), (4, 16, 8), (9, 33, 17)]
        } else {
            &[(1, 1, 1), (3, 5, 7), (4, 16, 8), (9, 33, 17), (128, 10, 32), (2, 64, 1)]
        };
        let mut rng = Rng::new(42);
        for &(m, n, k) in shapes {
            let a = fill(&mut rng, m * k);
            let b = fill(&mut rng, k * n);
            for ta in [false, true] {
                for tb in [false, true] {
                    let mut c = vec![0.0f32; m * n];
                    sgemm(ta, tb, m, n, k, &a, &b, &mut c);
                    close(&c, &naive(ta, tb, m, n, k, &a, &b));
                }
            }
        }
    }

    #[test]
    fn sgemm_accumulates_into_c() {
        let mut rng = Rng::new(7);
        let (m, n, k) = (5, 18, 6);
        let a = fill(&mut rng, m * k);
        let b = fill(&mut rng, k * n);
        let seed = fill(&mut rng, m * n);
        let mut c = seed.clone();
        sgemm(false, false, m, n, k, &a, &b, &mut c);
        let prod = naive(false, false, m, n, k, &a, &b);
        let want: Vec<f32> = seed.iter().zip(&prod).map(|(s, p)| s + p).collect();
        close(&c, &want);
    }

    #[test]
    fn fused_bias_act_matches_unfused() {
        let mut rng = Rng::new(9);
        let shapes: &[(usize, usize, usize)] = if cfg!(miri) {
            &[(1, 10, 32), (6, 16, 4), (7, 33, 13)]
        } else {
            &[(1, 10, 32), (6, 16, 4), (7, 33, 13), (128, 10, 64)]
        };
        for &(m, n, k) in shapes {
            let a = fill(&mut rng, m * k);
            let b = fill(&mut rng, k * n);
            let bias = fill(&mut rng, n);
            for relu in [false, true] {
                let mut c = vec![-1.0f32; m * n]; // overwritten, not accumulated
                sgemm_bias_act(m, n, k, &a, &b, &bias, relu, &mut c);
                let prod = naive(false, false, m, n, k, &a, &b);
                let want: Vec<f32> = prod
                    .iter()
                    .enumerate()
                    .map(|(idx, p)| {
                        let v = p + bias[idx % n];
                        if relu {
                            v.max(0.0)
                        } else {
                            v
                        }
                    })
                    .collect();
                close(&c, &want);
            }
        }
    }

    #[test]
    fn threaded_kernels_are_bitwise_identical_to_serial() {
        // Shapes stressing tile tails (67 = 16·4+3 rows), M < MR·c
        // (the wide-n shapes now take the NR-aligned *column* split),
        // single-tile M, and an empty product; all above and below the
        // parallel threshold. Under Miri only the first above-threshold
        // shape and the empty product run — that is the cross-thread
        // `Job` aliasing case Miri exists to vet, at interpretable
        // cost.
        let shapes: &[(usize, usize, usize)] = if cfg!(miri) {
            &[(67, 33, 40), (0, 64, 64)]
        } else {
            &[
                (67, 33, 40),
                (9, 1024, 8),
                (5, 2048, 16),
                (4, 4096, 32),
                (128, 100, 33),
                (256, 64, 64),
                (0, 64, 64),
            ]
        };
        let mut rng = Rng::new(1234);
        for &(m, n, k) in shapes {
            let a = fill(&mut rng, m * k);
            let b = fill(&mut rng, k * n);
            let bias = fill(&mut rng, n);
            let seed = fill(&mut rng, m * n);
            for ta in [false, true] {
                for tb in [false, true] {
                    pool::configure_threads(1);
                    let mut serial = seed.clone();
                    sgemm(ta, tb, m, n, k, &a, &b, &mut serial);
                    pool::configure_threads(4);
                    let mut threaded = seed.clone();
                    sgemm(ta, tb, m, n, k, &a, &b, &mut threaded);
                    assert!(
                        serial == threaded,
                        "sgemm ta={ta} tb={tb} m={m} n={n} k={k}: threaded != serial bitwise"
                    );
                }
            }
            for relu in [false, true] {
                pool::configure_threads(1);
                let mut serial = vec![-1.0f32; m * n];
                sgemm_bias_act(m, n, k, &a, &b, &bias, relu, &mut serial);
                pool::configure_threads(4);
                let mut threaded = vec![-1.0f32; m * n];
                sgemm_bias_act(m, n, k, &a, &b, &bias, relu, &mut threaded);
                assert!(
                    serial == threaded,
                    "sgemm_bias_act relu={relu} m={m} n={n} k={k}: threaded != serial bitwise"
                );
            }
        }
        pool::configure_threads(1);
    }

    #[test]
    fn col_sums_accumulate() {
        let a = [1.0f32, 2.0, 3.0, 10.0, 20.0, 30.0];
        let mut out = vec![1.0f32; 3];
        col_sums_accum(2, 3, &a, &mut out);
        assert_eq!(out, vec![12.0, 23.0, 34.0]);
    }

    #[test]
    fn dot_handles_tails() {
        for len in [0usize, 1, 7, 8, 9, 17, 64] {
            let x: Vec<f32> = (0..len).map(|i| i as f32).collect();
            let y: Vec<f32> = (0..len).map(|i| (i as f32) * 0.5).collect();
            let want: f32 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
            assert!((dot(&x, &y) - want).abs() < 1e-3 * (1.0 + want.abs()), "len {len}");
        }
    }

    #[test]
    fn zero_sized_dims_are_noops() {
        let mut c = vec![5.0f32; 6];
        sgemm(false, false, 2, 3, 0, &[], &[], &mut c);
        assert_eq!(c, vec![5.0; 6]);
        let mut empty: Vec<f32> = Vec::new();
        sgemm(false, false, 0, 3, 2, &[], &[0.0; 6], &mut empty);
    }
}
