//! Layer-3 coordinator: the thesis' distributed optimization methods.
//!
//! - [`oracle`] — the `GradOracle` abstraction (native MLP for sweeps;
//!   the PJRT transformer in `runtime` implements the same trait).
//! - [`method`] — every parallel method the thesis compares:
//!   EASGD / EAMSGD (Algorithms 1–2), DOWNPOUR (Alg. 3),
//!   MDOWNPOUR (Algs 4–5), ADOWNPOUR / MVADOWNPOUR, and async ADMM.
//! - [`driver`] — the asynchronous event-driven run loop over a
//!   simulated cluster: per-worker virtual clocks, communication
//!   period τ, jittered compute, Table-4.4 accounting.
//! - [`sequential`] — the p = 1 baselines: SGD, MSGD, ASGD, MVASGD.
//! - [`tree`] — EASGD Tree (Alg. 6): d-ary topology, fully-async
//!   messaging, the two communication schemes of §6.1.
//! - [`gauss_seidel`] — §6.2: the Gauss–Seidel reformulation unifying
//!   EASGD and DOWNPOUR, with its stability map.

pub mod driver;
pub mod gauss_seidel;
pub mod method;
pub mod oracle;
pub mod sequential;
pub mod tree;

pub use driver::{run_parallel, DriverConfig};
pub use method::Method;
pub use oracle::{EvalStats, GradOracle, MlpOracle};
pub use sequential::{run_sequential, SeqMethod};
pub use tree::{run_tree, TreeConfig, TreeScheme};
