//! EASGD **Tree**, virtual-time backend (thesis Chapter 6, Algorithm
//! 6): scaling elastic averaging to hundreds of workers with a d-ary
//! tree of nodes and a *fully asynchronous* message protocol — as the
//! [`super::executor::SimExecutor`] face of
//! [`super::topology::Topology::Tree`].
//!
//! * Leaf nodes run the shared master-decoupled local step
//!   ([`super::executor::local_step_decoupled`]): plain SGD under
//!   [`super::method::Method::Easgd`], Nesterov momentum under
//!   [`super::method::Method::Eamsgd`] — with the same learning-rate
//!   decay schedule as the star drivers.
//! * Interior nodes do NO gradient work (the thesis' final design):
//!   they absorb arriving child/parent parameters with the
//!   Gauss–Seidel moving-average rule x ← x + α(x_arrived − x), and
//!   push their own parameter up (τ_up) and down (τ_down) per the
//!   [`super::topology::TreeScheme`] table from
//!   [`super::topology::node_taus`].
//!
//! Messages carry full parameter snapshots with a one-way delivery
//! delay from the cost model (bottom-layer links take the intra-machine
//! discount); arrival processing happens at the receiving node's next
//! activation — exactly the "apply just-in-time, never during a
//! gradient update" rule of §6.1. The run is bitwise deterministic
//! given the seed; the real-thread face of the same topology is
//! [`super::tree_threaded`].

use super::executor::{local_step_decoupled, tree_alpha, DriverConfig, WorkerState};
use super::oracle::GradOracle;
use super::topology::{node_taus, TreeLayout, TreeSpec};
use crate::cluster::{CurvePoint, RunResult, TimeBreakdown};
use crate::error::Result;
use crate::model::flat;
use crate::rng::Rng;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

#[derive(PartialEq)]
enum EvKind {
    Activate(usize),
    Deliver { to: usize, payload_idx: usize },
}

#[derive(PartialEq)]
struct Ev(f64, u64, EvKind);
impl Eq for Ev {}
impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Ev {
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .0
            .partial_cmp(&self.0)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.1.cmp(&self.1))
    }
}

/// Run an EASGD Tree experiment in virtual time. `oracles[k]` serves
/// leaf k (node `first_leaf + k`); `oracles[0]` evaluates the ROOT node
/// — the thesis' tracked variable. `cfg.method` must be EASGD/EAMSGD
/// (its α is the per-arrival moving rate; EAMSGD's δ drives the leaf
/// Nesterov dynamics); `cfg.max_steps` caps total leaf gradient steps.
pub fn run_tree_sim<O: GradOracle>(
    oracles: &mut [O],
    cfg: &DriverConfig,
    spec: &TreeSpec,
) -> Result<RunResult> {
    let leaves = oracles.len();
    assert!(leaves >= 1);
    spec.validate()?;
    let alpha = tree_alpha(cfg.method)?;
    let layout = TreeLayout::dary(spec.degree, leaves);
    let init = oracles[0].init_params();

    let taus = node_taus(&layout, spec.scheme);

    // Interior nodes are bare parameter vectors; leaves carry the full
    // shared WorkerState (theta, momentum, local clock, RNG stream).
    let mut interior: Vec<Vec<f32>> = (0..layout.first_leaf).map(|_| init.clone()).collect();
    let mut root_rng = Rng::new(cfg.seed);
    let mut workers = WorkerState::family(&init, leaves, &mut root_rng);
    let mut time_rng = root_rng.split(0xABCD);

    let mut clocks = vec![0u64; layout.n_nodes];
    let mut inbox: Vec<Vec<usize>> = vec![Vec::new(); layout.n_nodes];
    let mut payloads: Vec<Vec<f32>> = Vec::new();
    let mut free_payloads: Vec<usize> = Vec::new();

    let mut heap: BinaryHeap<Ev> = BinaryHeap::new();
    let mut seq = 0u64;
    for i in 0..layout.n_nodes {
        heap.push(Ev(time_rng.uniform() * cfg.cost.t_grad, seq, EvKind::Activate(i)));
        seq += 1;
    }

    let mut result = RunResult::default();
    let mut breakdown = TimeBreakdown::default();
    let mut next_eval = 0.0f64;
    let mut total_steps = 0u64;
    let mut diverged = false;

    while let Some(Ev(now, _, kind)) = heap.pop() {
        if now > cfg.horizon || total_steps >= cfg.max_steps || diverged {
            break;
        }
        while now >= next_eval {
            // Root node — the tracked variable (a leaf only in the
            // degenerate single-node tree).
            let root_theta: &[f32] =
                if layout.first_leaf == 0 { &workers[0].theta } else { &interior[0] };
            let st = oracles[0].eval(root_theta);
            result.curve.push(CurvePoint {
                time: next_eval,
                train_loss: st.train_loss,
                test_loss: st.test_loss,
                test_error: st.test_error,
            });
            if !st.train_loss.is_finite() {
                diverged = true;
            }
            next_eval += cfg.eval_every;
        }

        match kind {
            EvKind::Deliver { to, payload_idx } => {
                inbox[to].push(payload_idx);
            }
            EvKind::Activate(i) => {
                // 1) absorb arrivals (Gauss–Seidel moving average) —
                //    just-in-time, never during a gradient update.
                let pending = std::mem::take(&mut inbox[i]);
                if !pending.is_empty() {
                    let theta = if i < layout.first_leaf {
                        &mut interior[i]
                    } else {
                        &mut workers[i - layout.first_leaf].theta
                    };
                    for pidx in pending {
                        flat::moving_average(theta, &payloads[pidx], alpha);
                        free_payloads.push(pidx);
                    }
                }
                // 2) leaf gradient step (interior nodes do no gradient
                //    work — thesis' final design).
                let mut dt;
                if layout.is_leaf(i) {
                    let k = i - layout.first_leaf;
                    let loss = local_step_decoupled(cfg, &mut workers[k], &mut oracles[k]);
                    if !loss.is_finite() {
                        diverged = true;
                    }
                    dt = cfg.cost.grad_time(&mut time_rng) + cfg.cost.t_data;
                    breakdown.compute += dt - cfg.cost.t_data;
                    breakdown.data += cfg.cost.t_data;
                    total_steps += 1;
                } else {
                    dt = cfg.cost.t_grad * spec.interior_activity;
                }
                clocks[i] += 1;
                let t = clocks[i];
                // 3) sends (non-blocking Isend).
                let (tau_up, tau_down) = taus[i];
                let mut send_to: Vec<usize> = Vec::new();
                if tau_up != u64::MAX && t % tau_up == 0 {
                    if let Some(par) = layout.parent[i] {
                        send_to.push(par);
                    }
                }
                if tau_down != u64::MAX && t % tau_down == 0 {
                    send_to.extend(layout.children[i].iter().copied());
                }
                let theta_now: &[f32] = if i < layout.first_leaf {
                    &interior[i]
                } else {
                    &workers[i - layout.first_leaf].theta
                };
                for dest in send_to {
                    // Intra-machine (bottom-layer) links are cheap.
                    let discount = if layout.is_leaf(dest) || layout.is_leaf(i) {
                        spec.intra_discount
                    } else {
                        1.0
                    };
                    let pidx = match free_payloads.pop() {
                        Some(idx) => {
                            payloads[idx].copy_from_slice(theta_now);
                            idx
                        }
                        None => {
                            payloads.push(theta_now.to_vec());
                            payloads.len() - 1
                        }
                    };
                    let delay = cfg.cost.one_way_time_scaled(discount);
                    breakdown.comm += delay;
                    heap.push(Ev(now + delay, seq, EvKind::Deliver { to: dest, payload_idx: pidx }));
                    seq += 1;
                    // Non-blocking: no dt added to the sender.
                }
                if flat::norm2(theta_now) > 1e8 {
                    diverged = true;
                }
                if dt <= 0.0 {
                    dt = 1e-9;
                }
                heap.push(Ev(now + dt, seq, EvKind::Activate(i)));
                seq += 1;
            }
        }
    }

    let root_theta: &[f32] =
        if layout.first_leaf == 0 { &workers[0].theta } else { &interior[0] };
    let st = oracles[0].eval(root_theta);
    result.curve.push(CurvePoint {
        time: cfg.horizon.min(next_eval),
        train_loss: st.train_loss,
        test_loss: st.test_loss,
        test_error: st.test_error,
    });
    result.breakdown = breakdown;
    result.total_steps = total_steps;
    result.diverged = diverged || !st.train_loss.is_finite();
    Ok(result)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::CostModel;
    use crate::coordinator::method::Method;
    use crate::coordinator::oracle::MlpOracle;
    use crate::coordinator::topology::TreeScheme;
    use crate::data::BlobDataset;
    use crate::model::MlpConfig;
    use std::sync::Arc;

    fn small_cost() -> CostModel {
        CostModel {
            t_grad: 1e-3,
            jitter: 0.1,
            t_data: 1e-4,
            latency: 1e-4,
            bandwidth: 1e9,
            param_bytes: 1000.0,
        }
    }

    fn tree_cfg(method: Method, eta: f32, horizon: f64, eval_every: f64, seed: u64) -> DriverConfig {
        DriverConfig {
            eta,
            method,
            cost: small_cost(),
            horizon,
            eval_every,
            seed,
            max_steps: u64::MAX / 2,
            lr_decay_gamma: 0.0,
        }
    }

    #[test]
    fn tree_trains_on_blobs_with_both_schemes() {
        let data = Arc::new(BlobDataset::generate(8, 4, 1024, 256, 0.8, 1));
        let mcfg = MlpConfig::new(&[8, 16, 4], 1e-4);
        for scheme in [
            TreeScheme::MultiScale { tau1: 2, tau2: 8 },
            TreeScheme::UpDown { tau_up: 2, tau_down: 8 },
        ] {
            let mut oracles = MlpOracle::family(data.clone(), &mcfg, 32, 16);
            let spec = TreeSpec::new(4, scheme);
            let cfg = tree_cfg(
                Method::Easgd { alpha: 0.9 / 5.0, tau: 1 },
                0.1,
                0.5,
                0.1,
                11,
            );
            let r = run_tree_sim(&mut oracles, &cfg, &spec).unwrap();
            assert!(!r.diverged, "{scheme:?} diverged");
            assert!(r.total_steps > 1000, "{scheme:?}: {} steps", r.total_steps);
            let first = r.curve.first().unwrap().train_loss;
            let last = r.curve.last().unwrap().train_loss;
            assert!(last < first - 0.1, "{scheme:?}: {first} -> {last}");
        }
    }

    #[test]
    fn tree_with_momentum_is_stable_at_reduced_eta() {
        let data = Arc::new(BlobDataset::generate(8, 4, 512, 128, 0.8, 2));
        let mcfg = MlpConfig::new(&[8, 16, 4], 1e-4);
        let mut oracles = MlpOracle::family(data, &mcfg, 32, 16);
        let spec = TreeSpec::new(4, TreeScheme::MultiScale { tau1: 1, tau2: 10 });
        // Thesis: momentum δ=0.9 ⇒ reduce η ×10.
        let cfg = tree_cfg(
            Method::Eamsgd { alpha: 0.9 / 5.0, tau: 1, delta: 0.9 },
            0.01,
            0.5,
            0.25,
            13,
        );
        let r = run_tree_sim(&mut oracles, &cfg, &spec).unwrap();
        assert!(!r.diverged);
        let first = r.curve.first().unwrap().train_loss;
        let last = r.curve.last().unwrap().train_loss;
        assert!(last < first, "{first} -> {last}");
    }

    #[test]
    fn tree_rejects_methods_without_a_tree_form() {
        let mut oracles =
            crate::coordinator::oracle::QuadraticOracle::family(8, 1.0, 0.0, 1.0, 0.0, 4);
        let spec = TreeSpec::new(2, TreeScheme::UpDown { tau_up: 1, tau_down: 4 });
        let cfg = tree_cfg(Method::Downpour { tau: 1 }, 0.1, 0.1, 0.1, 1);
        let e = run_tree_sim(&mut oracles, &cfg, &spec).unwrap_err();
        assert!(format!("{e}").contains("tree"), "{e}");
    }

    #[test]
    fn tree_respects_the_step_budget() {
        let mut oracles =
            crate::coordinator::oracle::QuadraticOracle::family(16, 1.0, 0.0, 1.0, 0.0, 4);
        let spec = TreeSpec::new(2, TreeScheme::UpDown { tau_up: 1, tau_down: 4 });
        let mut cfg = tree_cfg(Method::Easgd { alpha: 0.3, tau: 1 }, 0.1, 1e6, 1e6, 3);
        cfg.max_steps = 500;
        let r = run_tree_sim(&mut oracles, &cfg, &spec).unwrap();
        assert_eq!(r.total_steps, 500);
        assert!(!r.diverged);
    }
}
