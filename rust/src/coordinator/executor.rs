//! The `Executor` abstraction: one distributed-run contract, two
//! backends.
//!
//! A backend takes a family of [`GradOracle`]s (one per worker, index 0
//! doubling as the evaluator), a [`DriverConfig`], and produces a
//! [`RunResult`] with the center-variable curve:
//!
//! * [`SimExecutor`] — the virtual-time event simulator
//!   ([`super::driver::run_parallel`]): a min-heap interleaves workers
//!   by next-event time, communication/data costs come from the
//!   [`crate::cluster::CostModel`], and runs are bitwise deterministic
//!   given the seed. This is the figure-sweep substrate.
//! * [`ThreadExecutor`] — real `std::thread` workers
//!   ([`super::threaded::run_threaded`]): the center variable lives
//!   behind a sharded lock and exchanges execute concurrently against
//!   genuinely stale center reads. Time-valued config fields are *real*
//!   seconds here; runs are not bit-deterministic (the interleaving is
//!   the OS scheduler's), but the optimization-level outcomes match the
//!   simulator (see `tests/executor_equivalence.rs`).
//!
//! This module also owns the state shared by both backends: the
//! [`DriverConfig`], the per-worker [`WorkerState`], the virtual-time
//! master's [`MasterState`], the master-decoupled local gradient step,
//! and the evaluation-point recorder.

use super::method::Method;
use super::oracle::GradOracle;
use crate::cluster::{CostModel, CurvePoint, RunResult};
use crate::model::flat;
use crate::rng::Rng;

/// Driver configuration for one distributed run, shared by every
/// backend. `horizon` / `eval_every` are *virtual* seconds under
/// [`SimExecutor`] and *real* (wall-clock) seconds under
/// [`ThreadExecutor`]; `cost` is only consulted by the simulator.
#[derive(Clone, Debug)]
pub struct DriverConfig {
    pub eta: f32,
    pub method: Method,
    pub cost: CostModel,
    /// Time horizon (virtual seconds for Sim, real seconds for Thread).
    pub horizon: f64,
    /// Evaluation cadence (same time base as `horizon`).
    pub eval_every: f64,
    pub seed: u64,
    /// Safety cap on total local steps across workers.
    pub max_steps: u64,
    /// Learning-rate decay γ: η_t = η / (1 + γ·t_local)^0.5, driven by
    /// each worker's own clock (thesis Fig 4.13). 0 disables.
    pub lr_decay_gamma: f64,
}

impl DriverConfig {
    #[inline]
    pub(crate) fn eta_at(&self, t_local: u64) -> f32 {
        if self.lr_decay_gamma == 0.0 {
            self.eta
        } else {
            (self.eta as f64 / (1.0 + self.lr_decay_gamma * t_local as f64).sqrt()) as f32
        }
    }
}

/// Per-worker mutable state, identical across backends.
pub(crate) struct WorkerState {
    pub theta: Vec<f32>,
    pub v: Vec<f32>,
    pub grad: Vec<f32>,
    pub scratch: Vec<f32>,
    /// DOWNPOUR accumulated update; ADMM λ.
    pub aux: Vec<f32>,
    pub t_local: u64,
    pub rng: Rng,
}

impl WorkerState {
    /// Build the p-worker family: shared init (thesis §4.1), RNG
    /// streams split off `root` in worker order.
    pub fn family(init: &[f32], p: usize, root: &mut Rng) -> Vec<WorkerState> {
        let n = init.len();
        (0..p)
            .map(|i| WorkerState {
                theta: init.to_vec(),
                v: vec![0.0; n],
                grad: vec![0.0; n],
                scratch: vec![0.0; n],
                aux: vec![0.0; n],
                t_local: 0,
                rng: root.split(i as u64),
            })
            .collect()
    }
}

/// Master-side state of the virtual-time driver (center variable,
/// averaging sequences, master momentum, ADMM contributions). The
/// threaded backend keeps the equivalent state sharded behind locks
/// (`super::threaded::ShardedMaster`).
pub(crate) struct MasterState {
    pub center: Vec<f32>,
    /// Averaged center (ADOWNPOUR / MVADOWNPOUR).
    pub z: Option<Vec<f32>>,
    /// Master momentum (MDOWNPOUR).
    pub mv: Option<Vec<f32>>,
    /// ADMM: last (xⁱ − λⁱ) contribution per worker.
    pub contrib: Option<Vec<Vec<f32>>>,
    /// Master clock (# center updates) for the 1/t averaging rate.
    pub clock: u64,
}

impl MasterState {
    pub fn new(method: Method, init: &[f32], p: usize) -> MasterState {
        let n = init.len();
        MasterState {
            center: init.to_vec(),
            z: match method {
                Method::ADownpour { .. } | Method::MvaDownpour { .. } => Some(init.to_vec()),
                _ => None,
            },
            mv: match method {
                Method::MDownpour { .. } => Some(vec![0.0; n]),
                _ => None,
            },
            contrib: match method {
                Method::AdmmAsync { .. } => Some(vec![init.to_vec(); p]),
                _ => None,
            },
            clock: 0,
        }
    }

    /// The variable the thesis tracks: the averaged center when the
    /// method defines one, otherwise the center itself.
    pub fn eval_target(&self) -> &Vec<f32> {
        self.z.as_ref().unwrap_or(&self.center)
    }
}

/// One local gradient step for the master-decoupled methods (EASGD /
/// EAMSGD local dynamics, and the DOWNPOUR pull-push family's local
/// accumulation). Returns the batch loss and advances `t_local`.
///
/// MDOWNPOUR and async ADMM touch master state *inside* the local step
/// (master momentum push / prox toward the center) and therefore stay
/// inline in the virtual-time driver; [`thread_supported`] reports
/// which methods the threaded backend accepts.
pub(crate) fn local_step_decoupled<O: GradOracle>(
    cfg: &DriverConfig,
    w: &mut WorkerState,
    oracle: &mut O,
) -> f32 {
    let eta_t = cfg.eta_at(w.t_local);
    let loss = match cfg.method {
        Method::Eamsgd { delta, .. } => {
            // g at lookahead x + δv (Alg. 2), then v ← δv − ηg ; x ← x + v.
            for (s, (t, v)) in w.scratch.iter_mut().zip(w.theta.iter().zip(&w.v)) {
                *s = t + delta * v;
            }
            let loss = oracle.grad(&w.scratch, &mut w.rng, &mut w.grad);
            flat::nesterov_step(&mut w.theta, &mut w.v, &w.grad, eta_t, delta);
            loss
        }
        Method::MDownpour { .. } | Method::AdmmAsync { .. } => {
            unreachable!("master-coupled methods take the driver's inline step")
        }
        _ => {
            let loss = oracle.grad(&w.theta, &mut w.rng, &mut w.grad);
            flat::sgd_step(&mut w.theta, &w.grad, eta_t);
            if matches!(
                cfg.method,
                Method::Downpour { .. } | Method::ADownpour { .. } | Method::MvaDownpour { .. }
            ) {
                // Accumulate −ηg for the next push.
                for (a, g) in w.aux.iter_mut().zip(&w.grad) {
                    *a -= eta_t * g;
                }
            }
            loss
        }
    };
    w.t_local += 1;
    loss
}

/// Evaluate `theta` and append a curve point at `time`; returns false
/// when the train loss is non-finite (divergence).
pub(crate) fn eval_point<O: GradOracle>(
    oracle: &mut O,
    theta: &[f32],
    time: f64,
    curve: &mut Vec<CurvePoint>,
) -> bool {
    let st = oracle.eval(theta);
    curve.push(CurvePoint {
        time,
        train_loss: st.train_loss,
        test_loss: st.test_loss,
        test_error: st.test_error,
    });
    st.train_loss.is_finite()
}

/// Does the threaded backend implement this method? (MDOWNPOUR and
/// async ADMM interleave master updates into every local step; they are
/// defined on the virtual-time backend only.)
pub fn thread_supported(method: Method) -> bool {
    !matches!(method, Method::MDownpour { .. } | Method::AdmmAsync { .. })
}

/// A distributed-run backend.
///
/// The `Send` bound on the oracle is what real parallelism needs; the
/// simulator does not require it when called directly
/// ([`super::driver::run_parallel`] stays bound-free for the non-`Send`
/// PJRT oracle).
pub trait Executor {
    fn name(&self) -> &'static str;
    fn run<O: GradOracle + Send>(&self, oracles: &mut [O], cfg: &DriverConfig) -> RunResult;
}

/// Virtual-time event-driven backend (deterministic; the figure-sweep
/// substrate).
#[derive(Clone, Copy, Debug, Default)]
pub struct SimExecutor;

impl Executor for SimExecutor {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn run<O: GradOracle + Send>(&self, oracles: &mut [O], cfg: &DriverConfig) -> RunResult {
        super::driver::run_parallel(oracles, cfg)
    }
}

/// Real-thread backend: one `std::thread` per worker, sharded-lock
/// center.
#[derive(Clone, Copy, Debug)]
pub struct ThreadExecutor {
    /// Number of center shards (lock granularity). More shards ⇒ finer
    /// interleaving and less contention at small τ.
    pub shards: usize,
}

impl Default for ThreadExecutor {
    fn default() -> Self {
        ThreadExecutor { shards: 16 }
    }
}

impl Executor for ThreadExecutor {
    fn name(&self) -> &'static str {
        "thread"
    }

    fn run<O: GradOracle + Send>(&self, oracles: &mut [O], cfg: &DriverConfig) -> RunResult {
        super::threaded::run_threaded(oracles, cfg, self.shards)
    }
}

/// Backend selector for CLI / figure plumbing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    Sim,
    Thread,
}

impl Backend {
    pub fn parse(s: &str) -> Option<Backend> {
        match s {
            "sim" | "virtual" => Some(Backend::Sim),
            "thread" | "threads" | "threaded" => Some(Backend::Thread),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Sim => "sim",
            Backend::Thread => "thread",
        }
    }
}

/// Dispatch a run to the selected backend. Methods the threaded
/// backend does not implement fall back to the simulator (with a note
/// on stderr) so method sweeps keep working under `backend=thread` —
/// but beware that the fallback's curve is on VIRTUAL seconds while the
/// thread backend's is on real seconds; don't plot the two on one axis.
pub fn run_with_backend<O: GradOracle + Send>(
    backend: Backend,
    oracles: &mut [O],
    cfg: &DriverConfig,
) -> RunResult {
    match backend {
        Backend::Sim => SimExecutor.run(oracles, cfg),
        Backend::Thread => {
            if thread_supported(cfg.method) {
                ThreadExecutor::default().run(oracles, cfg)
            } else {
                eprintln!(
                    "note: {} is master-coupled; falling back to the sim backend \
                     (curve times are VIRTUAL seconds, not wall-clock)",
                    cfg.method.name()
                );
                SimExecutor.run(oracles, cfg)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_parse_roundtrip() {
        assert_eq!(Backend::parse("sim"), Some(Backend::Sim));
        assert_eq!(Backend::parse("thread"), Some(Backend::Thread));
        assert_eq!(Backend::parse("threaded"), Some(Backend::Thread));
        assert_eq!(Backend::parse("gpu"), None);
        assert_eq!(Backend::Sim.name(), "sim");
        assert_eq!(Backend::Thread.name(), "thread");
    }

    #[test]
    fn thread_support_matrix() {
        assert!(thread_supported(Method::easgd_default(4, 4)));
        assert!(thread_supported(Method::eamsgd_default(4, 4)));
        assert!(thread_supported(Method::Downpour { tau: 1 }));
        assert!(thread_supported(Method::ADownpour { tau: 1 }));
        assert!(thread_supported(Method::MvaDownpour { tau: 1, alpha: 0.001 }));
        assert!(!thread_supported(Method::MDownpour { delta: 0.9 }));
        assert!(!thread_supported(Method::AdmmAsync { rho: 1.0, tau: 4 }));
    }

    #[test]
    fn eta_decay_schedule() {
        let cfg = DriverConfig {
            eta: 0.1,
            method: Method::easgd_default(4, 4),
            cost: CostModel::cifar_like(100),
            horizon: 1.0,
            eval_every: 1.0,
            seed: 0,
            max_steps: 100,
            lr_decay_gamma: 1.0,
        };
        assert!((cfg.eta_at(0) - 0.1).abs() < 1e-9);
        assert!((cfg.eta_at(3) - 0.05).abs() < 1e-9); // 0.1/√4
    }

    #[test]
    fn master_state_allocates_per_method() {
        let init = vec![1.0f32; 8];
        let m = MasterState::new(Method::easgd_default(4, 4), &init, 4);
        assert!(m.z.is_none() && m.mv.is_none() && m.contrib.is_none());
        assert_eq!(m.eval_target(), &init);
        let m = MasterState::new(Method::ADownpour { tau: 1 }, &init, 4);
        assert!(m.z.is_some());
        let m = MasterState::new(Method::MDownpour { delta: 0.9 }, &init, 4);
        assert!(m.mv.is_some());
        let m = MasterState::new(Method::AdmmAsync { rho: 1.0, tau: 4 }, &init, 4);
        assert_eq!(m.contrib.as_ref().unwrap().len(), 4);
    }
}
