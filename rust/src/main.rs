//! `repro` — the elastic-train CLI.
//!
//! Subcommands:
//!   repro figure <id|all|list> [out-dir=out] [--full] [seed=N]
//!       Regenerate a thesis table/figure (DESIGN.md §5 maps ids).
//!   repro train [method=easgd|eamsgd|downpour|...] [p=4] [tau=10]
//!               [eta=0.05] [horizon=60] [cost=cifar|imagenet]
//!               [sharding=replicated|partitioned] [model=mlp|conv]
//!               [backend=sim|thread|process] [topology=star|tree] ...
//!       One distributed run on the native-MLP sweep workload; prints
//!       the tracked-variable curve. Every parallel method runs on
//!       the sim and thread backends (the thread backend serializes
//!       MDOWNPOUR and async ADMM through a master-actor thread); the
//!       process backend runs the master-decoupled star methods over
//!       real sockets with workers as separate OS processes. With
//!       topology=tree, p counts the LEAVES and
//!       degree=/scheme=/tau1=/tau2=/tau_up=/tau_down= shape the
//!       d-ary tree (thesis Ch. 6).
//!   repro train-pjrt [p=2] [steps=200] [eta=0.3] [tau=4]
//!       The end-to-end three-layer run: AOT transformer through PJRT.
//!   repro inspect
//!       Print the artifacts manifest summary.

use elastic_train::bail;
use elastic_train::config::{Args, ExperimentConfig};
use elastic_train::coordinator::{
    process_worker_main, run_process, run_sequential, run_with_backend_topology, Backend,
    ConvOracle, DriverConfig, Method, MlpOracle, OracleSpec, ProcessOpts, Topology, TreeScheme,
    TreeSpec,
};
use elastic_train::error::Result;
use elastic_train::figures::{self, FigOpts};
use elastic_train::model::ModelKind;
#[cfg(feature = "pjrt")]
use elastic_train::cluster::CostModel;
#[cfg(feature = "pjrt")]
use elastic_train::runtime::{PjrtModel, PjrtOracle};
#[cfg(feature = "pjrt")]
use std::rc::Rc;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env();
    // Hidden entry point: `repro --process-worker addr=... wid=...`.
    // The process backend self-execs this binary for each worker.
    if args.get("process-worker").is_some() {
        return process_worker_main(&args);
    }
    match args.positional.first().map(|s| s.as_str()) {
        Some("figure") => cmd_figure(&args),
        Some("train") => cmd_train(&args),
        Some("train-pjrt") => cmd_train_pjrt(&args),
        Some("inspect") => cmd_inspect(&args),
        _ => {
            // Generated from the knob registry: the help text, the
            // ExperimentConfig fields, and the forwarding lists are
            // all pinned to the same table (lint R5).
            eprint!("{}", elastic_train::config::registry::usage_text());
            Ok(())
        }
    }
}

fn cmd_figure(args: &Args) -> Result<()> {
    let id = args.positional.get(1).map(|s| s.as_str()).unwrap_or("list");
    if id == "list" {
        for f in figures::ALL_FIGURES {
            println!("{f}");
        }
        return Ok(());
    }
    let opts = FigOpts::from_args(args)?;
    figures::run(id, &opts)
}

/// Parse `topology=star|tree` plus the tree's `degree=`/`scheme=` keys.
fn topology_from_args(args: &Args) -> Result<Topology> {
    match args.get_str("topology", "star") {
        "star" => Ok(Topology::Star),
        "tree" => {
            let degree = args.get_usize("degree", 4)?;
            let scheme = match args.get_str("scheme", "multiscale") {
                "multiscale" | "1" => TreeScheme::MultiScale {
                    tau1: args.get_u32("tau1", 10)?,
                    tau2: args.get_u32("tau2", 100)?,
                },
                "updown" | "2" => TreeScheme::UpDown {
                    tau_up: args.get_u32("tau_up", 1)?,
                    tau_down: args.get_u32("tau_down", 10)?,
                },
                other => bail!("unknown scheme '{other}' (multiscale|updown)"),
            };
            Ok(Topology::Tree(TreeSpec::new(degree, scheme)))
        }
        other => bail!("unknown topology '{other}' (star|tree)"),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let mut cfg = ExperimentConfig::default();
    if let Some(path) = args.get("config") {
        cfg = ExperimentConfig::from_file(path)?;
    }
    cfg.apply_args(args)?;
    cfg.validate()?;

    // Kernel tier: resolved once, up front, before any GEMM dispatch or
    // speedup calibration — an unavailable tier (feature gate, arch,
    // CPU) is a clean CLI error naming the reason, never a silent
    // fallback to scalar.
    let tier = elastic_train::linalg::simd::configure(&cfg.simd)?;

    let data = elastic_train::figures::ch4::sweep_data(cfg.seed + 1);
    let mcfg = elastic_train::figures::ch4::sweep_mlp();
    let ccfg = elastic_train::figures::ch4::sweep_conv();

    let backend_str = args.get_str("backend", "sim");
    let backend = match Backend::parse(backend_str) {
        Some(b) => b,
        None => bail!("unknown backend '{backend_str}' (sim|thread|process)"),
    };

    let topo = topology_from_args(args)?;

    let sharding = match cfg.sharding_mode() {
        Some(s) => s,
        None => bail!("unknown sharding '{}' (replicated|partitioned)", cfg.sharding),
    };

    let model = match cfg.model_kind() {
        Some(m) => m,
        None => bail!("unknown model '{}' (mlp|conv)", cfg.model),
    };
    // The cost model's communication terms scale with the parameter
    // count of the model actually being trained.
    let cost = cfg.cost_model(match model {
        ModelKind::Mlp => mcfg.n_params(),
        ModelKind::Conv => ccfg.n_params(),
    });

    if let Some(mut m) = cfg.parallel_method()? {
        // Hybrid parallelism: p workers × `threads` GEMM helpers each.
        // The sim backend computes gradients on one thread regardless
        // of p (virtual time), so only the real backends multiply.
        let workers = if backend == Backend::Sim { 1 } else { cfg.p };
        let threads = elastic_train::linalg::pool::clamp_oversubscription(cfg.threads, workers);
        elastic_train::linalg::pool::configure_threads(threads);
        // Price the measured c-thread local-step speedup into the cost
        // model so virtual-time τ trade-offs match the real backends
        // (exact no-op at threads=1).
        let cost = cost.with_thread_speedup(elastic_train::linalg::pool::measured_speedup());
        // Tree runs use the thesis rate α = β/(d+1) — a node talks to
        // at most d+1 neighbors — instead of the star's β/p.
        if let Topology::Tree(spec) = &topo {
            let alpha = cfg.beta / (spec.degree as f32 + 1.0);
            m = match m {
                Method::Easgd { tau, .. } => Method::Easgd { alpha, tau },
                Method::Eamsgd { tau, delta, .. } => Method::Eamsgd { alpha, tau, delta },
                other => other, // gated with a descriptive error below
            };
        }
        println!(
            "train: {} p={} threads={} simd={} τ={} η={} horizon={}s ({} cost model, {} sharding, {} model, {} backend, {} topology)",
            m.name(),
            cfg.p,
            threads,
            tier.name(),
            cfg.tau,
            cfg.eta,
            cfg.horizon,
            cfg.cost_family,
            sharding.name(),
            model.name(),
            backend.name(),
            topo.name()
        );
        let dc = DriverConfig {
            eta: cfg.eta,
            method: m,
            cost,
            horizon: cfg.horizon,
            eval_every: cfg.eval_every,
            seed: cfg.seed,
            max_steps: u64::MAX / 2,
            lr_decay_gamma: cfg.extra_f32("gamma", 0.0)? as f64,
        };
        let r = if backend == Backend::Process {
            // Workers are separate OS processes: they rebuild the
            // oracle from a serializable spec instead of sharing ours.
            elastic_train::coordinator::check_supported(m, backend, &topo)?;
            let spec = OracleSpec::Sweep {
                model,
                sharding,
                batch: cfg.batch,
                seed: cfg.seed,
            };
            let mut opts = ProcessOpts::from_args(args)?;
            opts.threads = threads;
            // Forward the *resolved* tier, not the raw request: every
            // worker process then computes on exactly the tier this
            // master resolved (auto on a mixed fleet could diverge).
            opts.simd = tier.name().to_string();
            run_process(&spec, cfg.p, &dc, &opts)?
        } else {
            match model {
                ModelKind::Mlp => {
                    let mut oracles =
                        MlpOracle::family_sharded(data, &mcfg, cfg.batch, cfg.p, sharding);
                    run_with_backend_topology(backend, &mut oracles, &dc, &topo)?
                }
                ModelKind::Conv => {
                    let mut oracles =
                        ConvOracle::family_sharded(data, &ccfg, cfg.batch, cfg.p, sharding);
                    run_with_backend_topology(backend, &mut oracles, &dc, &topo)?
                }
            }
        };
        print_curve(&r);
    } else if let Some(m) = cfg.sequential_method()? {
        // Sequential runs have exactly one computing worker.
        elastic_train::linalg::pool::configure_threads(
            elastic_train::linalg::pool::clamp_oversubscription(cfg.threads, 1),
        );
        let cost = cost.with_thread_speedup(elastic_train::linalg::pool::measured_speedup());
        if topo != Topology::Star {
            bail!(
                "{} is a sequential (p=1) method; topology={} does not apply",
                m.name(),
                topo.name()
            );
        }
        println!(
            "train: {} (sequential) simd={} η={} horizon={}s ({} model)",
            m.name(),
            tier.name(),
            cfg.eta,
            cfg.horizon,
            model.name()
        );
        let r = match model {
            ModelKind::Mlp => {
                let mut oracle = MlpOracle::new_sharded(data, mcfg, cfg.batch, 40_000, sharding);
                run_sequential(&mut oracle, m, cfg.eta, &cost, cfg.horizon, cfg.eval_every, cfg.seed)
            }
            ModelKind::Conv => {
                let mut oracle = ConvOracle::new_sharded(data, ccfg, cfg.batch, 40_000, sharding);
                run_sequential(&mut oracle, m, cfg.eta, &cost, cfg.horizon, cfg.eval_every, cfg.seed)
            }
        };
        print_curve(&r);
    } else {
        bail!("unknown method '{}'", cfg.method);
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_train_pjrt(_args: &Args) -> Result<()> {
    bail!(
        "this binary was built without the `pjrt` feature; \
         rebuild with `cargo build --features pjrt` (see rust/README.md)"
    )
}

#[cfg(feature = "pjrt")]
fn cmd_train_pjrt(args: &Args) -> Result<()> {
    let p = args.get_usize("p", 2)?;
    let steps = args.get_u64("steps", 200)?;
    let eta = args.get_f32("eta", 0.3)?;
    let tau = args.get_u32("tau", 4)?;
    let delta = args.get_f32("delta", 0.0)?;
    let dir = std::path::PathBuf::from(args.get_str("artifacts", "artifacts"));

    let model = Rc::new(PjrtModel::load(&dir)?);
    println!(
        "train-pjrt: preset={} params={} p={p} τ={tau} η={eta} δ={delta} steps≈{steps}",
        model.artifacts.preset,
        model.n_params()
    );
    let mut oracles = PjrtOracle::family(model.clone(), 0.05, 4, 42, p);
    let method = if delta > 0.0 {
        elastic_train::coordinator::Method::Eamsgd { alpha: 0.9 / p as f32, tau, delta }
    } else {
        elastic_train::coordinator::Method::Easgd { alpha: 0.9 / p as f32, tau }
    };
    // Virtual time: ~1 ms per step ⇒ horizon sized to the step budget.
    let cost = CostModel {
        t_grad: 1e-3,
        jitter: 0.05,
        t_data: 1e-4,
        latency: 1e-4,
        bandwidth: 1e9,
        param_bytes: (model.n_params() * 4) as f64,
    };
    let dc = DriverConfig {
        eta,
        method,
        cost,
        horizon: steps as f64 * 2.4e-3 / p as f64,
        eval_every: steps as f64 * 2.4e-3 / p as f64 / 10.0,
        seed: args.get_u64("seed", 0)?,
        max_steps: steps,
        lr_decay_gamma: 0.0,
    };
    let r = elastic_train::coordinator::run_parallel(&mut oracles, &dc);
    print_curve(&r);
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let dir = std::path::PathBuf::from(args.get_str("artifacts", "artifacts"));
    let a = elastic_train::runtime::Artifacts::load(&dir)?;
    println!("preset:   {}", a.preset);
    println!(
        "params:   {} ({:.1} MB f32)",
        a.n_params,
        a.n_params as f64 * 4e-6
    );
    println!(
        "model:    vocab={} d_model={} layers={} heads={} seq={} batch={}",
        a.dims.vocab, a.dims.d_model, a.dims.n_layers, a.dims.n_heads,
        a.dims.seq_len, a.dims.batch
    );
    println!("tensors:  {}", a.params.len());
    for p in a.params.iter().take(6) {
        println!("  {:<16} {:?} @ {}", p.name, p.shape, p.offset);
    }
    if a.params.len() > 6 {
        println!("  … {} more", a.params.len() - 6);
    }
    Ok(())
}

fn print_curve(r: &elastic_train::cluster::RunResult) {
    println!("  time        train_loss  test_loss   test_err");
    for pt in &r.curve {
        println!(
            "  {:<10.2}  {:<10.4}  {:<10.4}  {:.4}",
            pt.time, pt.train_loss, pt.test_loss, pt.test_error
        );
    }
    println!(
        "steps={} rounds={} diverged={} best_test_err={:.4} | breakdown compute/data/comm = {:.1}/{:.1}/{:.1}s (serialize {:.3}s, transfer {:.3}s)",
        r.total_steps,
        r.rounds,
        r.diverged,
        r.best_test_error(),
        r.breakdown.compute,
        r.breakdown.data,
        r.breakdown.comm,
        r.breakdown.serialize,
        r.breakdown.transfer
    );
    if let Some(w) = &r.wire {
        println!(
            "wire: {} frames, {:.2} MB on the socket, mean staleness {:.2} rounds",
            w.frames,
            w.payload_bytes as f64 * 1e-6,
            w.mean_staleness
        );
    }
}
