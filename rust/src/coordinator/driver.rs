//! The asynchronous event-driven run loop — the virtual-time backend
//! ([`super::executor::SimExecutor`]) of the Executor abstraction.
//!
//! Each worker owns a virtual clock; a min-heap interleaves workers by
//! next-event time, so jittered compute produces genuine asynchrony
//! (staleness between a worker's view of the center and its current
//! value — exactly the effect the thesis studies). The master state
//! (center variable, averaging sequences, master momentum, ADMM
//! contributions) lives in [`MasterState`] and is touched only at
//! communication events. Shared state/config/step logic lives in
//! [`super::executor`]; the real-thread backend is
//! [`super::threaded`].
//!
//! Faithfulness notes:
//! * EASGD exchange follows Alg. 1 literally: the gradient of the
//!   exchange step is evaluated at the PRE-exchange snapshot `x`.
//! * DOWNPOUR follows Alg. 3: push accumulated gradients, pull the
//!   fresh center, reset.
//! * MDOWNPOUR follows Algs 4–5: stateless workers evaluate at the
//!   master's lookahead x̃ + δv.

use super::executor::{eval_point, local_step_decoupled, MasterState, WorkerState};
use super::method::Method;
use super::oracle::GradOracle;
use crate::cluster::{RunResult, TimeBreakdown};
use crate::model::flat;
use crate::rng::Rng;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

pub use super::executor::DriverConfig;

#[derive(PartialEq)]
struct Ev(f64, usize);
impl Eq for Ev {}
impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Ev {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap on time.
        other
            .0
            .partial_cmp(&self.0)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.1.cmp(&self.1))
    }
}

/// Run one asynchronous distributed experiment in virtual time.
/// `oracles[i]` is worker i's gradient computer; `oracles[0]` doubles
/// as the evaluator. Deliberately has no `Send` bound so the non-`Send`
/// PJRT oracle runs here; thread-parallel execution goes through
/// [`super::executor::ThreadExecutor`].
pub fn run_parallel<O: GradOracle>(oracles: &mut [O], cfg: &DriverConfig) -> RunResult {
    let p = oracles.len();
    assert!(p >= 1);
    let n = oracles[0].n_params();
    let init = oracles[0].init_params();
    let tau = cfg.method.tau().max(1) as u64;

    let mut root_rng = Rng::new(cfg.seed);
    let mut workers = WorkerState::family(&init, p, &mut root_rng);
    let mut master = MasterState::new(cfg.method, &init, p);

    let mut heap: BinaryHeap<Ev> = BinaryHeap::new();
    let mut time_rng = root_rng.split(0xC0FFEE);
    for i in 0..p {
        heap.push(Ev(cfg.cost.grad_time(&mut time_rng) * 0.1, i));
    }

    let mut result = RunResult::default();
    let mut breakdown = TimeBreakdown::default();
    let mut next_eval = 0.0f64;
    let mut total_steps = 0u64;
    let mut diverged = false;

    while let Some(Ev(now, wi)) = heap.pop() {
        if now > cfg.horizon || total_steps >= cfg.max_steps || diverged {
            break;
        }
        // Periodic center evaluation (uses the averaged center when the
        // method defines one — that's the variable the thesis tracks).
        while now >= next_eval {
            if !eval_point(&mut oracles[0], master.eval_target(), next_eval, &mut result.curve) {
                diverged = true;
            }
            next_eval += cfg.eval_every;
        }

        let mut dt = 0.0f64;
        let exchange_now = workers[wi].t_local % tau == 0;

        // ---- Communication phase -----------------------------------
        if exchange_now {
            dt += cfg.cost.exchange_time();
            breakdown.comm += cfg.cost.exchange_time();
            let w = &mut workers[wi];
            match cfg.method {
                Method::Easgd { alpha, .. } | Method::Eamsgd { alpha, .. } => {
                    // Alg. 1 steps a/b — symmetric elastic exchange.
                    flat::elastic_exchange(&mut w.theta, &mut master.center, alpha);
                    master.clock += 1;
                }
                Method::Downpour { .. }
                | Method::ADownpour { .. }
                | Method::MvaDownpour { .. } => {
                    // Alg. 3: push accumulated update, pull center.
                    flat::accumulate(&mut master.center, &w.aux);
                    w.theta.copy_from_slice(&master.center);
                    w.aux.iter_mut().for_each(|a| *a = 0.0);
                    master.clock += 1;
                    // Averaged-center variants.
                    match cfg.method {
                        Method::ADownpour { .. } => {
                            let a = 1.0 / (master.clock as f32);
                            let z = master
                                .z
                                .as_mut()
                                .expect("averaged methods allocate z at init");
                            flat::moving_average(z, &master.center, a);
                        }
                        Method::MvaDownpour { alpha, .. } => {
                            let z = master
                                .z
                                .as_mut()
                                .expect("averaged methods allocate z at init");
                            flat::moving_average(z, &master.center, alpha);
                        }
                        _ => {}
                    }
                }
                Method::MDownpour { delta } => {
                    // Worker reads the lookahead x̃ + δv (Alg. 4).
                    let mv = master.mv.as_ref().expect("MDOWNPOUR allocates mv at init");
                    for (t, (c, v)) in w.theta.iter_mut().zip(master.center.iter().zip(mv)) {
                        *t = c + delta * v;
                    }
                }
                Method::AdmmAsync { .. } => {
                    // Dual ascent: λⁱ ← λⁱ − (xⁱ − x̃); then master
                    // refreshes its stored contribution (xⁱ − λⁱ) and
                    // recomputes the center as the mean.
                    let contribs = master.contrib.as_mut().expect("ADMM allocates contrib at init");
                    for j in 0..n {
                        w.aux[j] -= w.theta[j] - master.center[j];
                        contribs[wi][j] = w.theta[j] - w.aux[j];
                    }
                    let inv = 1.0 / p as f32;
                    for j in 0..n {
                        let mut s = 0.0;
                        for c in contribs.iter() {
                            s += c[j];
                        }
                        master.center[j] = s * inv;
                    }
                    master.clock += 1;
                }
            }
        }

        // ---- Local gradient step -----------------------------------
        {
            let w = &mut workers[wi];
            let loss;
            match cfg.method {
                Method::AdmmAsync { rho, .. } => {
                    let eta_t = cfg.eta_at(w.t_local);
                    loss = oracles[wi].grad(&w.theta, &mut w.rng, &mut w.grad);
                    // Linearized prox step (Eq 3.53): λ is w.aux.
                    let d = 1.0 + eta_t * rho;
                    for j in 0..n {
                        w.theta[j] = (w.theta[j] - eta_t * w.grad[j]
                            + eta_t * rho * (w.aux[j] + master.center[j]))
                            / d;
                    }
                    w.t_local += 1;
                }
                Method::MDownpour { delta } => {
                    // Worker: gradient at x̃ + δv; master applies
                    // Nesterov (Alg. 5) immediately (async push).
                    let eta_t = cfg.eta_at(w.t_local);
                    loss = oracles[wi].grad(&w.theta, &mut w.rng, &mut w.grad);
                    let mv = master.mv.as_mut().expect("MDOWNPOUR allocates mv at init");
                    for j in 0..n {
                        mv[j] = delta * mv[j] - eta_t * w.grad[j];
                        master.center[j] += mv[j];
                    }
                    master.clock += 1;
                    w.t_local += 1;
                    dt += cfg.cost.exchange_time(); // per-step comm
                    breakdown.comm += cfg.cost.exchange_time();
                }
                _ => {
                    // EASGD / EAMSGD / DOWNPOUR-family: the shared
                    // master-decoupled step (also used by the threaded
                    // backend).
                    loss = local_step_decoupled(cfg, w, &mut oracles[wi]);
                }
            }
            if !loss.is_finite() || flat::norm2(&w.theta) > 1e8 {
                diverged = true;
            }
        }

        let step_t = cfg.cost.grad_time(&mut time_rng);
        dt += step_t + cfg.cost.t_data;
        breakdown.compute += step_t;
        breakdown.data += cfg.cost.t_data;
        total_steps += 1;
        heap.push(Ev(now + dt, wi));
    }

    // Final evaluation at the horizon.
    let finite = eval_point(
        &mut oracles[0],
        master.eval_target(),
        cfg.horizon.min(next_eval),
        &mut result.curve,
    );
    result.breakdown = breakdown;
    result.total_steps = total_steps;
    result.rounds = master.clock;
    result.diverged = diverged || !finite;
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::oracle::MlpOracle;
    use crate::data::BlobDataset;
    use crate::model::MlpConfig;
    use std::sync::Arc;

    fn setup(p: usize) -> Vec<MlpOracle> {
        let data = Arc::new(BlobDataset::generate(8, 4, 1024, 256, 0.8, 1));
        let cfg = MlpConfig::new(&[8, 16, 4], 1e-4);
        MlpOracle::family(data, &cfg, 32, p)
    }

    fn base_cfg(method: Method) -> DriverConfig {
        let cost = crate::cluster::CostModel {
            t_grad: 1e-3,
            jitter: 0.1,
            t_data: 1e-4,
            latency: 1e-4,
            bandwidth: 1e9,
            param_bytes: 1000.0,
        };
        DriverConfig {
            eta: 0.1,
            method,
            cost,
            horizon: 0.8,
            eval_every: 0.1,
            seed: 7,
            max_steps: 1_000_000,
            lr_decay_gamma: 0.0,
        }
    }

    #[test]
    fn easgd_trains_and_improves() {
        let mut oracles = setup(4);
        let cfg = base_cfg(Method::easgd_default(4, 4));
        let r = run_parallel(&mut oracles, &cfg);
        assert!(!r.diverged);
        assert!(r.total_steps > 500, "steps {}", r.total_steps);
        let first = r.curve.first().unwrap().train_loss;
        let last = r.curve.last().unwrap().train_loss;
        assert!(last < first - 0.2, "{first} -> {last}");
    }

    #[test]
    fn all_methods_run_without_divergence_at_moderate_eta() {
        for method in [
            Method::easgd_default(4, 4),
            Method::eamsgd_default(4, 4),
            Method::Downpour { tau: 1 },
            Method::MDownpour { delta: 0.9 },
            Method::ADownpour { tau: 1 },
            Method::MvaDownpour { tau: 1, alpha: 0.001 },
            Method::AdmmAsync { rho: 1.0, tau: 4 },
        ] {
            let mut oracles = setup(4);
            let mut cfg = base_cfg(method);
            cfg.eta = if matches!(method, Method::MDownpour { .. }) {
                0.003 // master momentum amplifies: thesis uses tiny lr
            } else {
                0.05
            };
            let r = run_parallel(&mut oracles, &cfg);
            assert!(!r.diverged, "{} diverged", method.name());
            let first = r.curve.first().unwrap().train_loss;
            let last = r.curve.last().unwrap().train_loss;
            assert!(
                last < first,
                "{}: {first} -> {last} did not improve",
                method.name()
            );
        }
    }

    #[test]
    fn downpour_unstable_at_large_tau_easgd_robust() {
        // The thesis' central empirical claim (Figs 4.1–4.4): DOWNPOUR
        // degrades/destabilizes as τ grows; EASGD stays healthy.
        let run = |method: Method, eta: f32| {
            let mut oracles = setup(4);
            let mut cfg = base_cfg(method);
            cfg.eta = eta;
            cfg.horizon = 1.0;
            run_parallel(&mut oracles, &cfg)
        };
        let e = run(Method::easgd_default(4, 64), 0.1);
        assert!(!e.diverged);
        let e_loss = e.curve.last().unwrap().train_loss;
        let d = run(Method::Downpour { tau: 64 }, 0.1);
        let d_loss = if d.diverged {
            f64::INFINITY
        } else {
            d.curve.last().unwrap().train_loss
        };
        assert!(
            e_loss < d_loss || d.diverged,
            "EASGD {e_loss} should beat DOWNPOUR {d_loss} at τ=64"
        );
    }

    #[test]
    fn more_workers_do_not_break_and_accumulate_more_steps() {
        let r4 = {
            let mut o = setup(4);
            run_parallel(&mut o, &base_cfg(Method::easgd_default(4, 4)))
        };
        let r8 = {
            let mut o = setup(8);
            run_parallel(&mut o, &base_cfg(Method::easgd_default(8, 4)))
        };
        assert!(!r8.diverged);
        assert!(r8.total_steps > (1.6 * r4.total_steps as f64) as u64);
    }

    #[test]
    fn breakdown_accounts_all_three_columns() {
        let mut oracles = setup(4);
        let cfg = base_cfg(Method::easgd_default(4, 2));
        let r = run_parallel(&mut oracles, &cfg);
        assert!(r.breakdown.compute > 0.0);
        assert!(r.breakdown.data > 0.0);
        assert!(r.breakdown.comm > 0.0);
        // τ=2 ⇒ roughly one exchange per two steps.
        let per_step_comm = r.breakdown.comm / r.total_steps as f64;
        assert!(per_step_comm < cfg.cost.exchange_time());
    }

    #[test]
    fn deterministic_given_seed() {
        let run = || {
            let mut o = setup(4);
            run_parallel(&mut o, &base_cfg(Method::easgd_default(4, 4)))
        };
        let (a, b) = (run(), run());
        assert_eq!(a.total_steps, b.total_steps);
        assert_eq!(a.curve.last().unwrap().train_loss, b.curve.last().unwrap().train_loss);
    }
}
