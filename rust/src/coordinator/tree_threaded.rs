//! EASGD **Tree**, real-thread backend: the
//! [`super::executor::ThreadExecutor`] face of
//! [`super::topology::Topology::Tree`].
//!
//! Where [`super::tree`] *models* the fully-asynchronous tree protocol
//! in virtual time, this backend *is* that protocol: every tree node is
//! an OS thread, and parameter snapshots travel over `mpsc` channels.
//!
//! * **Leaf workers** run the shared master-decoupled local step
//!   ([`super::executor::local_step_decoupled`]) on their own
//!   [`WorkerState`] — plain SGD under [`Method::Easgd`], Nesterov
//!   under [`Method::Eamsgd`] — and push a full parameter snapshot to
//!   their parent every τ_up steps.
//! * **Interior nodes** are message-absorbing actors (no gradient
//!   work, the thesis' final design): each activation — an arrival, or
//!   an idle tick — drains the inbox, folding every snapshot in with
//!   the Gauss–Seidel rule x ← x + α(x_arrived − x), then pushes its
//!   own snapshot up (τ_up) / down (τ_down) per the
//!   [`super::topology::node_taus`] table.
//!
//! The §6.1 delivery rule — "apply just-in-time, never during a
//! gradient update" — holds by construction: a leaf owns its parameter
//! vector, drains its inbox *before* each gradient step, and is never
//! written by another thread.
//!
//! Shutdown is a bottom-up flush: an exiting leaf sends one final
//! [`Msg::Flush`] snapshot up; an interior node waits (bounded) for a
//! flush from every child, absorbs them, and flushes up in turn — so
//! the root's last snapshot reflects the leaves' final parameters, not
//! whatever happened to be absorbed when the stop flag flipped.
//!
//! Semantics match [`super::threaded`]: `horizon` / `eval_every` are
//! REAL (wall-clock) seconds, the cost model is ignored (real compute
//! is the cost), `max_steps` caps total leaf steps, and runs are not
//! bit-deterministic. The root node — the thesis' tracked variable —
//! publishes timestamped snapshots at the eval cadence; they are scored
//! with `oracles[0]` after the threads join, so the evaluator never
//! contends with the run.
//!
//! [`Method::Easgd`]: super::method::Method::Easgd
//! [`Method::Eamsgd`]: super::method::Method::Eamsgd

use super::executor::{eval_point, local_step_decoupled, tree_alpha, DriverConfig, WorkerState};
use super::oracle::GradOracle;
use super::topology::{node_taus, TreeLayout, TreeSpec};
use crate::cluster::{RunResult, TimeBreakdown};
use crate::error::Result;
use super::threaded::lock_recover;
use crate::model::flat;
use crate::rng::Rng;
use crate::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use crate::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use crate::sync::{thread, Mutex, PoisonError};
use std::time::{Duration, Instant};

/// A parameter snapshot in flight.
enum Msg {
    /// Ordinary τ-cadence push.
    Snap(Vec<f32>),
    /// A child's final snapshot, sent exactly once as it exits (never
    /// sent downward, so every flush a node receives is from a child).
    Flush(Vec<f32>),
}

impl Msg {
    fn payload(&self) -> &[f32] {
        match self {
            Msg::Snap(p) | Msg::Flush(p) => p,
        }
    }
}

/// Idle-activation period of interior actors: how long an interior
/// node waits for an arrival before ticking anyway (the real-time
/// analog of the simulator's `interior_activity`).
const INTERIOR_TICK: Duration = Duration::from_micros(500);

/// How long an interior node waits for its children's flushes at
/// shutdown before giving up (children flush within microseconds unless
/// one of them panicked).
const FLUSH_DEADLINE: Duration = Duration::from_millis(250);

/// One node's end of the tree wiring.
struct NodeChans {
    rx: Receiver<Msg>,
    parent_tx: Option<Sender<Msg>>,
    children_tx: Vec<Sender<Msg>>,
    tau_up: u64,
    tau_down: u64,
}

/// Cross-thread run state.
struct Shared {
    stop: AtomicBool,
    /// Claimed leaf steps (global budget).
    steps: AtomicU64,
    diverged: AtomicBool,
    compute_ns: AtomicU64,
    comm_ns: AtomicU64,
}

/// The root's timestamped snapshot log (scored after the join).
struct RootSnaps {
    snaps: Mutex<Vec<(f64, Vec<f32>)>>,
    t0: Instant,
    cadence: f64,
}

impl RootSnaps {
    fn maybe_publish(&self, theta: &[f32], next_pub: &mut f64) {
        let el = self.t0.elapsed().as_secs_f64();
        if el >= *next_pub {
            lock_recover(&self.snaps).push((el, theta.to_vec()));
            while *next_pub <= el {
                *next_pub += self.cadence;
            }
        }
    }

    fn publish_final(&self, theta: &[f32]) {
        let el = self.t0.elapsed().as_secs_f64();
        lock_recover(&self.snaps).push((el, theta.to_vec()));
    }
}

fn leaf_loop<O: GradOracle>(
    cfg: &DriverConfig,
    alpha: f32,
    ch: NodeChans,
    w: &mut WorkerState,
    oracle: &mut O,
    sh: &Shared,
    root: Option<&RootSnaps>,
) {
    let mut next_pub = root.map_or(0.0, |r| r.cadence);
    let mut clock = 0u64;
    loop {
        if sh.stop.load(Ordering::Relaxed) {
            break;
        }
        // Absorb parent pushes just-in-time — before the gradient step,
        // never during it (§6.1 delivery rule).
        let t_comm = Instant::now();
        let mut absorbed = false;
        while let Ok(msg) = ch.rx.try_recv() {
            flat::moving_average(&mut w.theta, msg.payload(), alpha);
            absorbed = true;
        }
        if absorbed {
            sh.comm_ns
                .fetch_add(t_comm.elapsed().as_nanos() as u64, Ordering::Relaxed);
        }
        // Claim one step of the global leaf budget.
        let k = sh.steps.fetch_add(1, Ordering::Relaxed);
        if k >= cfg.max_steps {
            sh.steps.fetch_sub(1, Ordering::Relaxed);
            sh.stop.store(true, Ordering::Relaxed);
            break;
        }
        let t_grad = Instant::now();
        let loss = local_step_decoupled(cfg, w, oracle);
        sh.compute_ns
            .fetch_add(t_grad.elapsed().as_nanos() as u64, Ordering::Relaxed);
        clock += 1;
        if !loss.is_finite() || flat::norm2(&w.theta) > 1e8 {
            sh.diverged.store(true, Ordering::Relaxed);
            sh.stop.store(true, Ordering::Relaxed);
            break;
        }
        if ch.tau_up != u64::MAX && clock % ch.tau_up == 0 {
            if let Some(tx) = &ch.parent_tx {
                let t_send = Instant::now();
                let _ = tx.send(Msg::Snap(w.theta.clone()));
                sh.comm_ns
                    .fetch_add(t_send.elapsed().as_nanos() as u64, Ordering::Relaxed);
            }
        }
        if let Some(r) = root {
            // Single-node tree: the leaf doubles as the root.
            r.maybe_publish(&w.theta, &mut next_pub);
        }
    }
    if let Some(tx) = &ch.parent_tx {
        let _ = tx.send(Msg::Flush(w.theta.clone()));
    }
    if let Some(r) = root {
        r.publish_final(&w.theta);
    }
}

fn interior_loop(
    alpha: f32,
    ch: NodeChans,
    mut theta: Vec<f32>,
    sh: &Shared,
    root: Option<&RootSnaps>,
) {
    let mut next_pub = root.map_or(0.0, |r| r.cadence);
    let mut clock = 0u64;
    let mut flushed = 0usize;
    let absorb = |theta: &mut Vec<f32>, m: &Msg, flushed: &mut usize| {
        flat::moving_average(theta, m.payload(), alpha);
        if matches!(m, Msg::Flush(_)) {
            *flushed += 1;
        }
    };
    loop {
        if sh.stop.load(Ordering::Relaxed) {
            break;
        }
        // One activation: wake on the first arrival (or an idle tick),
        // then drain the inbox, absorbing each snapshot in arrival
        // order (Gauss–Seidel).
        match ch.rx.recv_timeout(INTERIOR_TICK) {
            Ok(msg) => {
                let t_comm = Instant::now();
                absorb(&mut theta, &msg, &mut flushed);
                while let Ok(m) = ch.rx.try_recv() {
                    absorb(&mut theta, &m, &mut flushed);
                }
                sh.comm_ns
                    .fetch_add(t_comm.elapsed().as_nanos() as u64, Ordering::Relaxed);
            }
            Err(RecvTimeoutError::Timeout) => {}
            // Cannot happen while the run holds the sender set; avoid a
            // busy spin if it ever does.
            Err(RecvTimeoutError::Disconnected) => thread::sleep(INTERIOR_TICK),
        }
        clock += 1;
        if ch.tau_up != u64::MAX && clock % ch.tau_up == 0 {
            if let Some(tx) = &ch.parent_tx {
                let _ = tx.send(Msg::Snap(theta.clone()));
            }
        }
        if ch.tau_down != u64::MAX && clock % ch.tau_down == 0 {
            for tx in &ch.children_tx {
                let _ = tx.send(Msg::Snap(theta.clone()));
            }
        }
        if let Some(r) = root {
            r.maybe_publish(&theta, &mut next_pub);
        }
    }
    // Bottom-up flush: absorb until every child has sent its final
    // snapshot (bounded wait), then pass the aggregate up. No gradient
    // runs anywhere anymore, so absorbing stays just-in-time.
    let deadline = Instant::now() + FLUSH_DEADLINE;
    while flushed < ch.children_tx.len() && Instant::now() < deadline {
        match ch.rx.recv_timeout(INTERIOR_TICK) {
            Ok(m) => absorb(&mut theta, &m, &mut flushed),
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    while let Ok(m) = ch.rx.try_recv() {
        absorb(&mut theta, &m, &mut flushed);
    }
    if let Some(tx) = &ch.parent_tx {
        let _ = tx.send(Msg::Flush(theta.clone()));
    }
    if let Some(r) = root {
        r.publish_final(&theta);
    }
}

/// Run one EASGD Tree experiment on real threads. `oracles[k]` is leaf
/// k's gradient computer; `oracles[0]` scores the root's snapshot log
/// after the join. `cfg.method` must be EASGD/EAMSGD (its α is the
/// per-arrival moving rate); `cfg.max_steps` caps total leaf steps and
/// `cfg.horizon` is a real-seconds wall.
pub fn run_tree_threaded<O: GradOracle + Send>(
    oracles: &mut [O],
    cfg: &DriverConfig,
    spec: &TreeSpec,
) -> Result<RunResult> {
    let leaves = oracles.len();
    assert!(leaves >= 1);
    spec.validate()?;
    let alpha = tree_alpha(cfg.method)?;
    let layout = TreeLayout::dary(spec.degree, leaves);
    let taus = node_taus(&layout, spec.scheme);
    let init = oracles[0].init_params();

    let mut root_rng = Rng::new(cfg.seed);
    let mut workers = WorkerState::family(&init, leaves, &mut root_rng);

    // One channel per node, wired along the tree edges. `txs` stays
    // alive until the threads join, so no receiver sees a disconnect
    // mid-run.
    let (txs, rxs): (Vec<Sender<Msg>>, Vec<Receiver<Msg>>) =
        (0..layout.n_nodes).map(|_| channel()).unzip();
    let mut chans: Vec<NodeChans> = Vec::with_capacity(layout.n_nodes);
    for (i, rx) in rxs.into_iter().enumerate() {
        chans.push(NodeChans {
            rx,
            parent_tx: layout.parent[i].map(|p| txs[p].clone()),
            children_tx: layout.children[i].iter().map(|&c| txs[c].clone()).collect(),
            tau_up: taus[i].0,
            tau_down: taus[i].1,
        });
    }

    let shared = Shared {
        stop: AtomicBool::new(false),
        steps: AtomicU64::new(0),
        diverged: AtomicBool::new(false),
        compute_ns: AtomicU64::new(0),
        comm_ns: AtomicU64::new(0),
    };
    let root_snaps = RootSnaps {
        snaps: Mutex::new(vec![(0.0, init.clone())]),
        t0: Instant::now(),
        cadence: cfg.eval_every.max(1e-3),
    };

    thread::scope(|s| {
        let mut leaf_handles = Vec::new();
        let mut interior_handles = Vec::new();
        let mut leaf_iter = workers.iter_mut().zip(oracles.iter_mut());
        for (i, ch) in chans.into_iter().enumerate() {
            let shared = &shared;
            let root = if i == 0 { Some(&root_snaps) } else { None };
            if i < layout.first_leaf {
                let theta = init.clone();
                interior_handles
                    .push(s.spawn(move || interior_loop(alpha, ch, theta, shared, root)));
            } else {
                let (w, o) = leaf_iter
                    .next()
                    .expect("TreeLayout mints exactly `leaves` leaf slots");
                leaf_handles.push(s.spawn(move || leaf_loop(cfg, alpha, ch, w, o, shared, root)));
            }
        }
        loop {
            let el = root_snaps.t0.elapsed().as_secs_f64();
            let leaves_done = leaf_handles.iter().all(|h| h.is_finished());
            if el > cfg.horizon || leaves_done {
                shared.stop.store(true, Ordering::Relaxed);
            }
            if leaves_done && interior_handles.iter().all(|h| h.is_finished()) {
                break;
            }
            thread::sleep(Duration::from_micros(200));
        }
        // Scope joins on exit; propagate worker panics eagerly.
        for h in leaf_handles.into_iter().chain(interior_handles) {
            if let Err(e) = h.join() {
                std::panic::resume_unwind(e);
            }
        }
    });
    drop(txs);

    let mut result = RunResult::default();
    let mut diverged = shared.diverged.load(Ordering::Relaxed);
    // Same recovery contract as lock_recover: all writers joined above,
    // and a panicking node already resumed its unwind, so a poisoned
    // flag here carries no information the join didn't.
    let snaps = root_snaps.snaps.into_inner().unwrap_or_else(PoisonError::into_inner);
    for (t, theta) in &snaps {
        if !eval_point(&mut oracles[0], theta, *t, &mut result.curve) {
            diverged = true;
        }
    }
    result.total_steps = shared.steps.load(Ordering::Relaxed);
    result.breakdown = TimeBreakdown {
        compute: shared.compute_ns.load(Ordering::Relaxed) as f64 * 1e-9,
        data: 0.0,
        comm: shared.comm_ns.load(Ordering::Relaxed) as f64 * 1e-9,
        serialize: 0.0,
        transfer: 0.0,
    };
    result.diverged = diverged;
    Ok(result)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::CostModel;
    use crate::coordinator::method::Method;
    use crate::coordinator::oracle::QuadraticOracle;
    use crate::coordinator::topology::TreeScheme;

    fn cfg(method: Method, max_steps: u64) -> DriverConfig {
        DriverConfig {
            eta: 0.1,
            method,
            cost: CostModel::cifar_like(100), // unused by this backend
            horizon: 30.0,                    // real-seconds safety net
            eval_every: 1e6,
            seed: 7,
            max_steps,
            lr_decay_gamma: 0.0,
        }
    }

    #[test]
    fn threaded_tree_converges_on_quadratic_with_both_schemes() {
        for scheme in [
            TreeScheme::MultiScale { tau1: 1, tau2: 4 },
            TreeScheme::UpDown { tau_up: 1, tau_down: 4 },
        ] {
            let mut oracles = QuadraticOracle::family(64, 1.0, 0.0, 1.0, 0.0, 4);
            let spec = TreeSpec::new(2, scheme);
            let c = cfg(Method::Easgd { alpha: 0.3, tau: 1 }, 20_000);
            let r = run_tree_threaded(&mut oracles, &c, &spec).unwrap();
            assert!(!r.diverged, "{scheme:?}");
            assert_eq!(r.total_steps, 20_000, "{scheme:?}");
            assert!(r.curve.len() >= 2, "{scheme:?}");
            let last = r.curve.last().unwrap().train_loss;
            assert!(last < 1e-4, "{scheme:?}: final root loss {last}");
        }
    }

    #[test]
    fn threaded_tree_respects_budget_and_accounts_time() {
        let mut oracles = QuadraticOracle::family(256, 1.0, 0.0, 1.0, 0.0, 8);
        let spec = TreeSpec::new(4, TreeScheme::UpDown { tau_up: 2, tau_down: 8 });
        let c = cfg(Method::Easgd { alpha: 0.9 / 5.0, tau: 1 }, 2000);
        let r = run_tree_threaded(&mut oracles, &c, &spec).unwrap();
        assert_eq!(r.total_steps, 2000);
        assert!(!r.diverged);
        assert!(r.breakdown.compute > 0.0);
    }

    #[test]
    fn single_leaf_tree_degenerates_to_local_sgd() {
        let mut oracles = QuadraticOracle::family(16, 2.0, 0.0, 1.0, 0.0, 1);
        let spec = TreeSpec::new(2, TreeScheme::UpDown { tau_up: 1, tau_down: 1 });
        let c = cfg(Method::Easgd { alpha: 0.3, tau: 1 }, 800);
        let r = run_tree_threaded(&mut oracles, &c, &spec).unwrap();
        assert!(!r.diverged);
        assert!(r.curve.last().unwrap().train_loss < 1e-3);
    }

    #[test]
    fn threaded_tree_rejects_methods_without_a_tree_form() {
        let mut oracles = QuadraticOracle::family(8, 1.0, 0.0, 1.0, 0.0, 2);
        let spec = TreeSpec::new(2, TreeScheme::UpDown { tau_up: 1, tau_down: 1 });
        let c = cfg(Method::MDownpour { delta: 0.9 }, 10);
        let e = run_tree_threaded(&mut oracles, &c, &spec).unwrap_err();
        assert!(format!("{e}").contains("tree"), "{e}");
    }
}
