//! PJRT runtime: load the AOT artifacts (`make artifacts`) and execute
//! them from the rust hot path. Python never runs here.
//!
//! - [`artifacts`] — manifest parsing, parameter table, HLO loading and
//!   compilation (`PjRtClient::cpu()` → `HloModuleProto::from_text_file`
//!   → `compile`), shared initial parameters.
//! - [`session`] — `PjrtModel`: flat-buffer ⇄ literal packing and the
//!   `train_step` / `eval_step` / update-kernel execution paths.
//! - [`pjrt_oracle`] — `PjrtOracle`, the `GradOracle` implementation
//!   that plugs the AOT transformer into the same EASGD/DOWNPOUR/Tree
//!   drivers the sweeps use.

pub mod artifacts;
pub mod pjrt_oracle;
pub mod session;

pub use artifacts::Artifacts;
pub use pjrt_oracle::PjrtOracle;
pub use session::PjrtModel;
