//! Golden stability-boundary tests: the closed-form EASGD/ADMM/MSGD
//! stability conditions in `sim::moments` / `sim::admm` must predict —
//! exactly at the boundary — what the `sim::quadratic` simulators and
//! the round-robin linear maps actually do, over a grid of (p, ρ, η).

use elastic_train::linalg::spectral_radius;
use elastic_train::rng::Rng;
use elastic_train::sim::{admm, moments, quadratic};

/// Bisect the η·h stability boundary of Lemma 3.1.1's (γ, φ) condition
/// at fixed (α, p), h = 1.
fn easgd_eta_boundary(alpha: f64, p: usize) -> Option<f64> {
    let (lo0, hi0) = (1e-6, 6.0);
    if !moments::easgd_stable(lo0, alpha, 1.0, p) || moments::easgd_stable(hi0, alpha, 1.0, p) {
        return None; // region empty or unbounded on this grid line
    }
    let (mut lo, mut hi) = (lo0, hi0);
    for _ in 0..80 {
        let mid = 0.5 * (lo + hi);
        if moments::easgd_stable(mid, alpha, 1.0, p) {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    Some(lo)
}

/// Noiseless synchronous EASGD (Eq 5.9, β = p·α) from x0 = 1: returns
/// the final |center|.
fn sync_easgd_final(eta: f64, alpha: f64, p: usize, t: usize) -> f64 {
    let m = quadratic::Quadratic { h: 1.0, sigma: 0.0 };
    let tr = quadratic::easgd_trajectory(
        m,
        eta,
        alpha,
        p as f64 * alpha,
        p,
        1.0,
        t,
        &mut Rng::new(1),
    );
    tr.last().unwrap().abs()
}

/// (1) The Lemma 3.1.1 boundary, empirically: for a grid of (p, α) the
/// bisected η* separates a contracting simulation (0.9·η*) from a
/// diverging one (1.1·η*), and `center_mse_infinite` flips to ∞ at the
/// same edge. (Noiseless + symmetric init, so the reduced system the
/// lemma analyzes is exactly what the simulator excites.)
#[test]
fn easgd_sync_boundary_matches_lemma_3_1_1() {
    let mut checked = 0;
    for &p in &[1usize, 2, 4, 8] {
        for &alpha in &[0.05f64, 0.15, 0.3] {
            let Some(eta_star) = easgd_eta_boundary(alpha, p) else {
                // e.g. p=8, α=0.3 ⇒ β=2.4 > 2: unstable for every η.
                assert!(
                    !moments::easgd_stable(0.1, alpha, 1.0, p),
                    "empty bracket must mean an empty stability region"
                );
                continue;
            };
            assert!(eta_star > 1.0 && eta_star < 2.0, "η*={eta_star} at p={p} α={alpha}");
            let below = sync_easgd_final(0.9 * eta_star, alpha, p, 4000);
            let above = sync_easgd_final(1.1 * eta_star, alpha, p, 4000);
            assert!(
                below < 1e-3,
                "p={p} α={alpha}: stable side |x|={below} at η={:.4}",
                0.9 * eta_star
            );
            assert!(
                above > 1e6 || !above.is_finite(),
                "p={p} α={alpha}: unstable side |x|={above} at η={:.4}",
                1.1 * eta_star
            );
            // The closed-form stationary MSE agrees with the flip.
            let model = moments::QuadraticModel { h: 1.0, sigma: 1.0, p };
            let beta = p as f64 * alpha;
            assert!(moments::center_mse_infinite(&model, 0.9 * eta_star, beta).is_finite());
            assert!(moments::center_mse_infinite(&model, 1.1 * eta_star, beta).is_infinite());
            checked += 1;
        }
    }
    assert!(checked >= 10, "grid degenerated: only {checked} cells checked");
}

/// (2a) Round-robin EASGD: the closed-form §3.3 condition
/// α ≤ (4 − 2η)/(4 − η) is EXACT at p = 1 — the spectral radius of the
/// composed map crosses 1 precisely at the predicted boundary.
#[test]
fn easgd_rr_spectral_radius_crosses_one_at_closed_form_boundary() {
    for &eta in &[0.3f64, 0.8, 1.5] {
        let a_star = (4.0 - 2.0 * eta) / (4.0 - eta);
        let sp_at = spectral_radius(&admm::easgd_round_robin_map(1, eta, a_star));
        let sp_below = spectral_radius(&admm::easgd_round_robin_map(1, eta, a_star * 0.999));
        let sp_above = spectral_radius(&admm::easgd_round_robin_map(1, eta, a_star * 1.001));
        assert!((sp_at - 1.0).abs() < 1e-7, "η={eta}: sp at boundary {sp_at}");
        assert!(sp_below < 1.0, "η={eta}: sp just inside {sp_below}");
        assert!(sp_above > 1.0, "η={eta}: sp just outside {sp_above}");
        assert!(admm::easgd_rr_stable(eta, a_star * 0.999));
        assert!(!admm::easgd_rr_stable(eta, a_star * 1.001));
    }
}

/// (2b) Round-robin ADMM over a (p, ρ, η) grid: sp(𝓕) < 1 ⟺ the
/// iterated trajectory's envelope decays; sp > 1 ⟺ it grows. Cells
/// within ~1e-3 of the unit circle are skipped (growth there needs far
/// more rounds than a unit test affords — the thesis' Fig 3.3 chaos is
/// exactly such a slow divergence).
#[test]
fn admm_spectral_radius_predicts_trajectory_envelope() {
    let mut asserted = 0;
    for &p in &[2usize, 3] {
        for &eta in &[0.001f64, 0.3] {
            for &rho in &[2.5f64, 6.0, 9.0] {
                let sp = admm::admm_spectral_radius(p, eta, rho);
                let tr = admm::admm_trajectory(p, eta, rho, 1.0, 20_000);
                let finite = tr.iter().all(|x| x.is_finite());
                let early = tr[..1000.min(tr.len())]
                    .iter()
                    .fold(0.0f64, |m, x| m.max(x.abs()));
                let late = tr[tr.len().saturating_sub(1000)..]
                    .iter()
                    .fold(0.0f64, |m, x| m.max(x.abs()));
                if sp < 0.9985 {
                    assert!(finite, "p={p} η={eta} ρ={rho}: sp={sp} but blow-up");
                    assert!(
                        late <= early,
                        "p={p} η={eta} ρ={rho}: sp={sp} but envelope grew {early} -> {late}"
                    );
                    asserted += 1;
                } else if sp > 1.0008 {
                    assert!(
                        !finite || late > 10.0 * early.max(1e-300),
                        "p={p} η={eta} ρ={rho}: sp={sp} but envelope did not grow \
                         ({early} -> {late})"
                    );
                    asserted += 1;
                } // else: borderline — skipped by design.
            }
        }
    }
    assert!(asserted >= 7, "grid degenerated: only {asserted} cells asserted");
}

/// (3) MSGD second moments: sp of the Eq 5.6 moment matrix < 1 ⟺ the
/// simulated second moment stays bounded, over an (η·h, δ) grid that
/// straddles the boundary several times.
#[test]
fn msgd_moment_matrix_sp_predicts_second_moment_divergence() {
    let mut asserted = 0;
    for &eta_h in &[0.2f64, 1.0, 1.9, 2.5, 3.5] {
        for &delta in &[0.0f64, 0.5, 0.9] {
            let sp = moments::sp(&moments::msgd_moment_matrix(eta_h, delta));
            if (sp - 1.0).abs() < 0.05 {
                continue; // borderline cells need asymptotic horizons
            }
            let m = quadratic::Quadratic { h: 1.0, sigma: 0.1 };
            let mut worst = 0.0f64;
            for rep in 0..4u64 {
                let tr = quadratic::msgd_trajectory(
                    m,
                    eta_h,
                    delta,
                    0.0,
                    3000,
                    &mut Rng::new(500 + rep),
                );
                let last = tr.last().unwrap().abs();
                worst = worst.max(if last.is_finite() { last } else { f64::INFINITY });
            }
            if sp < 1.0 {
                assert!(
                    worst < 1e3,
                    "η_h={eta_h} δ={delta}: sp={sp} (stable) but |x|={worst}"
                );
            } else {
                assert!(
                    worst > 1e6 || worst.is_infinite(),
                    "η_h={eta_h} δ={delta}: sp={sp} (unstable) but |x|={worst}"
                );
            }
            asserted += 1;
        }
    }
    assert!(asserted >= 12, "grid degenerated: only {asserted} cells asserted");
}

/// The MSGD stationary point of Eq 5.7 is achieved by the simulator
/// inside the stable region (a golden value, not just a boundary).
#[test]
fn msgd_stationary_moment_matches_eq_5_7_inside_region() {
    let (eta, delta, sigma) = (0.3f64, 0.4f64, 0.1f64);
    let (_, _, x2_units) = moments::msgd_asymptotic(eta, delta);
    let want = x2_units * eta * eta * sigma * sigma;
    let m = quadratic::Quadratic { h: 1.0, sigma };
    let got = quadratic::empirical_second_moment(
        |r| quadratic::msgd_trajectory(m, eta, delta, 0.0, 4000, &mut Rng::new(900 + r as u64)),
        40,
        500,
    );
    assert!(
        (got - want).abs() / want < 0.2,
        "stationary x²: sim {got} vs closed form {want}"
    );
}
