//! Real-parallelism star backend: one `std::thread` per worker, the
//! center variable behind a [`CenterBackend`]
//! ([`super::executor::ThreadExecutor`]).
//!
//! Where the virtual-time driver *models* asynchrony (per-worker
//! clocks, jittered costs), this backend *is* asynchronous: workers
//! free-run on OS threads and the exchanges of
//! [`super::method::Method`] execute concurrently against genuinely
//! stale center reads. How the center variable survives that
//! concurrency is the [`CenterBackend`] choice, made per method:
//!
//! * [`ShardedMaster`] — the master-DEcoupled methods (EASGD / EAMSGD,
//!   the DOWNPOUR pull-push family). The center is split into
//!   contiguous shards, each behind its own `Mutex`; an exchange locks
//!   one shard at a time, so two workers exchanging simultaneously
//!   interleave at shard granularity — the center a worker assembles
//!   is a mixture of before/after states, exactly the staleness regime
//!   the thesis argues EASGD tolerates (and Jin et al. 2016 argue must
//!   be validated on real concurrent workers).
//! * [`super::master_actor::ActorMaster`] — the master-COUPLED methods
//!   (MDOWNPOUR, async ADMM), whose master update belongs to every
//!   local step and cannot race shard-by-shard. A dedicated master
//!   thread owns the center and absorbs worker messages over `mpsc`
//!   channels with serialized Gauss–Seidel application — the same
//!   actor pattern [`super::tree_threaded`] uses for interior tree
//!   nodes.
//!
//! Semantics and differences from the simulator:
//! * `DriverConfig::horizon` / `eval_every` are REAL (wall-clock)
//!   seconds; `cost` is ignored (real compute is the cost).
//! * `RunResult::curve` times are real seconds; the breakdown's
//!   compute/comm columns are measured thread-seconds (data = 0).
//! * Runs are not bit-deterministic — the OS scheduler picks the
//!   interleaving — but optimization-level outcomes match the simulator
//!   (`tests/executor_equivalence.rs`).
//! * A worker performs NO communication round at `t_local == 0`: the
//!   round would be a no-op exchange (all-zero push, elastic average of
//!   identical init params) yet would advance the master clock by one
//!   per worker, skewing ADOWNPOUR's 1/t averaging schedule and
//!   polluting the comm-time breakdown.
//!
//! Evaluation: the main thread snapshots the (averaged) center at the
//! eval cadence while workers run, and scores the snapshots with
//! `oracles[0]` after the workers join — the evaluator never contends
//! with the workers.

use super::executor::{
    eval_point, local_step_decoupled, master_coupled, DriverConfig, WorkerState,
};
use super::method::Method;
use super::oracle::GradOracle;
use crate::cluster::{RunResult, TimeBreakdown};
use crate::error::Result;
use crate::model::flat;
use crate::rng::Rng;
use crate::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use crate::sync::{thread, Mutex, MutexGuard};
use std::ops::Range;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::{Duration, Instant};

/// Lock a mutex, recovering the guard from a poisoned lock. Poison
/// means some thread panicked while holding the guard — the panic
/// itself is surfaced as a descriptive run error by [`run_with_center`]
/// (and the center data, scalar writes of f32/u64, is never left
/// torn), so propagating the secondary `PoisonError` panic out of
/// every OTHER thread would only bury the real failure. Shared by the
/// sharded center, the master actor, and the process master.
pub(crate) fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Cross-thread run state (borrowed by every worker).
pub(crate) struct Shared {
    pub(crate) stop: AtomicBool,
    pub(crate) steps: AtomicU64,
    pub(crate) diverged: AtomicBool,
    pub(crate) compute_ns: AtomicU64,
    pub(crate) comm_ns: AtomicU64,
    /// First worker panic `(wid, message)` — the loud, descriptive
    /// account of a worker death that [`run_with_center`] turns into
    /// an `Err` instead of resuming the unwind into a mutex-poisoning
    /// cascade.
    pub(crate) failure: Mutex<Option<(usize, String)>>,
}

/// The center variable's concurrency backend for the star thread
/// executor: how worker threads read and update the shared center.
/// Chosen per method by [`run_threaded`] —
/// [`super::executor::master_coupled`] methods go through the
/// channel-serialized master actor, the rest through the sharded lock.
pub(crate) trait CenterBackend: Sync {
    /// Per-worker endpoint, moved into that worker's thread (channel
    /// ends for the actor; nothing for the sharded lock).
    type Port: Send;

    /// Mint the p worker endpoints. Called once, before spawning.
    fn take_ports(&mut self, p: usize) -> Vec<Self::Port>;

    /// Copy out the evaluation target (averaged center when defined).
    /// Callable from the main thread at any point during the run.
    fn snapshot(&self) -> Vec<f32>;

    /// Center-update rounds applied so far (the master clock).
    fn rounds(&self) -> u64;

    /// Blocking service loop for backends that need a master thread
    /// (the actor); returns once every worker port is dropped. The
    /// sharded lock needs no server.
    fn serve(&self) {}

    /// One worker iteration: the method's communication round (when
    /// due) plus one local gradient step. Returns the batch loss.
    fn step<O: GradOracle>(
        &self,
        cfg: &DriverConfig,
        port: &mut Self::Port,
        w: &mut WorkerState,
        oracle: &mut O,
        sh: &Shared,
    ) -> f32;
}

/// One lock-protected slice of master state.
struct Shard {
    center: Vec<f32>,
    /// Averaged center (ADOWNPOUR / MVADOWNPOUR), this shard's slice.
    z: Option<Vec<f32>>,
    /// Center updates applied to this shard (drives the 1/t rate).
    clock: u64,
}

/// The center variable behind a sharded lock — the [`CenterBackend`]
/// of the master-decoupled methods. Workers lock one shard at a time
/// in index order; the snapshot path does the same, so there is a
/// single global lock order and no deadlock.
pub(crate) struct ShardedMaster {
    shards: Vec<Mutex<Shard>>,
    bounds: Vec<Range<usize>>,
}

impl ShardedMaster {
    pub(crate) fn new(init: &[f32], n_shards: usize, averaged: bool) -> ShardedMaster {
        let n = init.len();
        let s = n_shards.clamp(1, n.max(1));
        let bounds: Vec<Range<usize>> =
            (0..s).map(|i| (i * n / s)..((i + 1) * n / s)).collect();
        let shards = bounds
            .iter()
            .map(|r| {
                Mutex::new(Shard {
                    center: init[r.clone()].to_vec(),
                    z: if averaged { Some(init[r.clone()].to_vec()) } else { None },
                    clock: 0,
                })
            })
            .collect();
        ShardedMaster { shards, bounds }
    }

    /// One communication round: walk the shards in order, performing
    /// the method's exchange on each slice under that shard's lock.
    fn exchange(&self, cfg: &DriverConfig, w: &mut WorkerState) {
        match cfg.method {
            Method::Easgd { alpha, .. } | Method::Eamsgd { alpha, .. } => {
                for (sh, r) in self.shards.iter().zip(&self.bounds) {
                    let mut sh = lock_recover(sh);
                    flat::elastic_exchange(&mut w.theta[r.clone()], &mut sh.center, alpha);
                    sh.clock += 1;
                }
            }
            Method::Downpour { .. } | Method::ADownpour { .. } | Method::MvaDownpour { .. } => {
                for (sh, r) in self.shards.iter().zip(&self.bounds) {
                    let mut guard = lock_recover(sh);
                    let sh = &mut *guard;
                    // Alg. 3 on this slice: push accumulated update, pull.
                    flat::accumulate(&mut sh.center, &w.aux[r.clone()]);
                    w.theta[r.clone()].copy_from_slice(&sh.center);
                    w.aux[r.clone()].iter_mut().for_each(|a| *a = 0.0);
                    sh.clock += 1;
                    // The averaged-center slice exists by construction
                    // for these two methods (`run_threaded` passes
                    // `averaged = true`); `expect` documents that
                    // invariant instead of an anonymous unwrap.
                    match cfg.method {
                        Method::ADownpour { .. } => {
                            let a = 1.0 / (sh.clock as f32);
                            let z = sh.z.as_mut().expect("averaged methods allocate z at init");
                            flat::moving_average(z, &sh.center, a);
                        }
                        Method::MvaDownpour { alpha, .. } => {
                            let z = sh.z.as_mut().expect("averaged methods allocate z at init");
                            flat::moving_average(z, &sh.center, alpha);
                        }
                        _ => {}
                    }
                }
            }
            Method::MDownpour { .. } | Method::AdmmAsync { .. } => {
                unreachable!("master-coupled methods run on the master actor")
            }
        }
    }
}

impl CenterBackend for ShardedMaster {
    type Port = ();

    fn take_ports(&mut self, p: usize) -> Vec<()> {
        vec![(); p]
    }

    fn snapshot(&self) -> Vec<f32> {
        let n = self.bounds.last().map(|r| r.end).unwrap_or(0);
        let mut out = Vec::with_capacity(n);
        for sh in &self.shards {
            let sh = lock_recover(sh);
            out.extend_from_slice(sh.z.as_deref().unwrap_or(&sh.center));
        }
        out
    }

    fn rounds(&self) -> u64 {
        // Every exchange walks every shard exactly once, so any one
        // shard's clock is the round count.
        self.shards.first().map_or(0, |sh| lock_recover(sh).clock)
    }

    fn step<O: GradOracle>(
        &self,
        cfg: &DriverConfig,
        _port: &mut (),
        w: &mut WorkerState,
        oracle: &mut O,
        sh: &Shared,
    ) -> f32 {
        let tau = cfg.method.tau().max(1) as u64;
        // No round at t_local == 0 — see the module docs.
        if w.t_local > 0 && w.t_local % tau == 0 {
            let t0 = Instant::now();
            self.exchange(cfg, w);
            sh.comm_ns
                .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        }
        let t0 = Instant::now();
        let loss = local_step_decoupled(cfg, w, oracle);
        sh.compute_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        loss
    }
}

fn worker_loop<O: GradOracle, C: CenterBackend>(
    cfg: &DriverConfig,
    wid: usize,
    center: &C,
    mut port: C::Port,
    w: &mut WorkerState,
    oracle: &mut O,
    sh: &Shared,
) {
    loop {
        if sh.stop.load(Ordering::Relaxed) {
            break;
        }
        // Claim one step of the global budget.
        let k = sh.steps.fetch_add(1, Ordering::Relaxed);
        if k >= cfg.max_steps {
            sh.steps.fetch_sub(1, Ordering::Relaxed);
            sh.stop.store(true, Ordering::Relaxed);
            break;
        }
        // A panicking oracle (or exchange) must not kill the run by
        // stealth: uncaught, the unwind would poison the center locks,
        // leave the stop flag unset — so the SURVIVING workers burn
        // the entire remaining step budget before anyone notices — and
        // finally resurface as an opaque resume_unwind. Catch it,
        // record who and why, stop everyone now.
        let stepped = catch_unwind(AssertUnwindSafe(|| {
            center.step(cfg, &mut port, w, oracle, sh)
        }));
        let loss = match stepped {
            Ok(loss) => loss,
            Err(payload) => {
                let msg = panic_message(&payload);
                let mut failure = lock_recover(&sh.failure);
                failure.get_or_insert((wid, msg));
                sh.stop.store(true, Ordering::Relaxed);
                // The claimed step never happened.
                sh.steps.fetch_sub(1, Ordering::Relaxed);
                break;
            }
        };
        if !loss.is_finite() || flat::norm2(&w.theta) > 1e8 {
            sh.diverged.store(true, Ordering::Relaxed);
            sh.stop.store(true, Ordering::Relaxed);
            break;
        }
    }
    // `port` drops here — for the actor backend this is the worker's
    // goodbye: once every port is gone the master's receive loop
    // disconnects and `serve` returns.
}

/// Extract a human-readable message from a panic payload (`&str` and
/// `String` cover what `panic!` produces).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "panic with a non-string payload".to_string()
    }
}

/// The shared star driver: spawn the backend's server (if any) and one
/// worker thread per oracle, snapshot the eval target at the cadence,
/// join, score.
pub(crate) fn run_with_center<O: GradOracle + Send, C: CenterBackend>(
    oracles: &mut [O],
    cfg: &DriverConfig,
    init: Vec<f32>,
    mut center: C,
) -> Result<RunResult> {
    let p = oracles.len();
    let mut root_rng = Rng::new(cfg.seed);
    let mut workers = WorkerState::family(&init, p, &mut root_rng);
    let ports = center.take_ports(p);
    let center = &center;

    let shared = Shared {
        stop: AtomicBool::new(false),
        steps: AtomicU64::new(0),
        diverged: AtomicBool::new(false),
        compute_ns: AtomicU64::new(0),
        comm_ns: AtomicU64::new(0),
        failure: Mutex::new(None),
    };

    // (real seconds, eval-target snapshot) pairs, scored after the join.
    let mut snaps: Vec<(f64, Vec<f32>)> = Vec::new();
    let t0 = Instant::now();
    let mut server_panicked = false;
    thread::scope(|s| {
        let server = s.spawn(move || center.serve());
        let handles: Vec<_> = workers
            .iter_mut()
            .zip(oracles.iter_mut())
            .zip(ports)
            .enumerate()
            .map(|(wid, ((w, o), port))| {
                let shared = &shared;
                s.spawn(move || worker_loop(cfg, wid, center, port, w, o, shared))
            })
            .collect();
        let cadence = cfg.eval_every.max(1e-3);
        let mut next_eval = 0.0f64;
        loop {
            let el = t0.elapsed().as_secs_f64();
            if el >= next_eval {
                snaps.push((el, center.snapshot()));
                next_eval += cadence;
            }
            if el > cfg.horizon {
                shared.stop.store(true, Ordering::Relaxed);
            }
            if handles.iter().all(|h| h.is_finished()) {
                break;
            }
            thread::sleep(Duration::from_micros(200));
        }
        // Workers join first (dropping their ports), then the server,
        // whose receive loop disconnects once the last port is gone.
        // worker_loop catches its own panics into `shared.failure`, so
        // a join error here cannot happen short of a harness bug; the
        // server's serve loop owns no oracle code but is recorded too.
        for h in handles {
            let _ = h.join();
        }
        if server.join().is_err() {
            server_panicked = true;
        }
    });
    if let Some((wid, msg)) = lock_recover(&shared.failure).take() {
        return Err(crate::err!(
            "worker {wid} died mid-run: {msg} (the run was stopped; the center state was \
             recovered, not trusted)"
        ));
    }
    if server_panicked {
        return Err(crate::err!("the center's master thread panicked mid-run"));
    }
    snaps.push((t0.elapsed().as_secs_f64(), center.snapshot()));

    let mut result = RunResult::default();
    let mut diverged = shared.diverged.load(Ordering::Relaxed);
    for (t, theta) in &snaps {
        if !eval_point(&mut oracles[0], theta, *t, &mut result.curve) {
            diverged = true;
        }
    }
    result.total_steps = shared.steps.load(Ordering::Relaxed);
    result.rounds = center.rounds();
    result.breakdown = TimeBreakdown {
        compute: shared.compute_ns.load(Ordering::Relaxed) as f64 * 1e-9,
        data: 0.0,
        comm: shared.comm_ns.load(Ordering::Relaxed) as f64 * 1e-9,
        serialize: 0.0,
        transfer: 0.0,
    };
    result.diverged = diverged;
    Ok(result)
}

/// Run one distributed experiment on real threads. `oracles[i]` is
/// worker i's gradient computer; `oracles[0]` doubles as the (post-run)
/// evaluator. `n_shards` is the center lock granularity for the
/// sharded backend (master-coupled methods serialize through the actor
/// instead and ignore it). A worker dying mid-run (a panicking oracle)
/// returns a descriptive `Err` naming the worker — promptly, without
/// letting the survivors burn the remaining step budget.
pub fn run_threaded<O: GradOracle + Send>(
    oracles: &mut [O],
    cfg: &DriverConfig,
    n_shards: usize,
) -> Result<RunResult> {
    let p = oracles.len();
    assert!(p >= 1);
    let init = oracles[0].init_params();
    if master_coupled(cfg.method) {
        let actor = super::master_actor::ActorMaster::new(cfg.method, &init, p);
        run_with_center(oracles, cfg, init, actor)
    } else {
        let averaged = matches!(
            cfg.method,
            Method::ADownpour { .. } | Method::MvaDownpour { .. }
        );
        let master = ShardedMaster::new(&init, n_shards, averaged);
        run_with_center(oracles, cfg, init, master)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::oracle::{MlpOracle, QuadraticOracle};
    use crate::data::BlobDataset;
    use crate::model::MlpConfig;
    use std::sync::Arc;

    fn cfg(method: Method, max_steps: u64) -> DriverConfig {
        DriverConfig {
            eta: 0.1,
            method,
            cost: crate::cluster::CostModel::cifar_like(100),
            horizon: 30.0, // real-seconds safety net; steps bound first
            eval_every: 1e6,
            seed: 7,
            max_steps,
            lr_decay_gamma: 0.0,
        }
    }

    #[test]
    fn threaded_easgd_reduces_mlp_loss() {
        let data = Arc::new(BlobDataset::generate(8, 4, 1024, 256, 0.8, 1));
        let mcfg = MlpConfig::new(&[8, 16, 4], 1e-4);
        let mut oracles = MlpOracle::family(data, &mcfg, 32, 4);
        let r = run_threaded(&mut oracles, &cfg(Method::easgd_default(4, 4), 2000), 8).unwrap();
        assert!(!r.diverged);
        assert_eq!(r.total_steps, 2000);
        let first = r.curve.first().unwrap().train_loss;
        let last = r.curve.last().unwrap().train_loss;
        assert!(last < first - 0.2, "{first} -> {last}");
    }

    #[test]
    fn threaded_respects_step_budget_and_counts() {
        let mut oracles = QuadraticOracle::family(64, 1.0, 0.0, 1.0, 0.0, 3);
        let r = run_threaded(&mut oracles, &cfg(Method::easgd_default(3, 2), 500), 4).unwrap();
        assert_eq!(r.total_steps, 500);
        assert!(!r.diverged);
        assert!(r.curve.len() >= 2); // initial + final snapshot
        assert!(r.breakdown.compute > 0.0);
    }

    #[test]
    fn threaded_downpour_family_runs() {
        for method in [
            Method::Downpour { tau: 4 },
            Method::ADownpour { tau: 4 },
            Method::MvaDownpour { tau: 4, alpha: 0.01 },
        ] {
            let mut oracles = QuadraticOracle::family(64, 1.0, 0.0, 1.0, 0.0, 2);
            let mut c = cfg(method, 2000);
            c.eta = 0.05;
            let r = run_threaded(&mut oracles, &c, 4).unwrap();
            assert!(!r.diverged, "{}", method.name());
            let last = r.curve.last().unwrap().train_loss;
            assert!(last < 0.1, "{}: final loss {last}", method.name());
        }
    }

    #[test]
    fn single_worker_single_shard_degenerate_cases() {
        let mut oracles = QuadraticOracle::family(7, 2.0, 0.0, 1.0, 0.0, 1);
        let mut c = cfg(Method::easgd_default(1, 1), 800);
        c.eta = 0.1;
        let r = run_threaded(&mut oracles, &c, 1).unwrap();
        assert!(!r.diverged);
        assert!(r.curve.last().unwrap().train_loss < 1e-3);
    }

    #[test]
    fn no_round_at_t_local_zero() {
        // One worker, τ=1, S steps: rounds happen at t_local = 1..S−1,
        // never at 0, so the master clock reads S−1 (it read S before
        // the fix — one spurious no-op round skewing the 1/t schedule).
        let mut oracles = QuadraticOracle::family(16, 1.0, 0.0, 1.0, 0.0, 1);
        let mut c = cfg(Method::ADownpour { tau: 1 }, 400);
        c.eta = 0.05;
        let r = run_threaded(&mut oracles, &c, 4).unwrap();
        assert!(!r.diverged);
        assert_eq!(r.total_steps, 400);
        assert_eq!(r.rounds, 399);
    }

    #[test]
    fn threaded_mdownpour_converges_on_quadratic() {
        let mut oracles = QuadraticOracle::family(32, 1.0, 0.0, 1.0, 0.0, 2);
        let mut c = cfg(Method::MDownpour { delta: 0.9 }, 4000);
        c.eta = 0.01;
        let r = run_threaded(&mut oracles, &c, 4).unwrap();
        assert!(!r.diverged);
        assert_eq!(r.total_steps, 4000);
        // Master momentum pushes the center all the way to the target.
        assert!(r.curve.last().unwrap().train_loss < 1e-4);
        // Every local step is one serialized master round (τ = 1).
        assert_eq!(r.rounds, 4000);
    }

    /// Regression coverage for the poison-recovery branches: a worker
    /// dying *while it holds a center shard lock* (the only way a lock
    /// becomes poisoned) must surface as the named "worker N died
    /// mid-run" error, promptly, with the survivors — including the
    /// main thread's snapshot cadence — recovering the poisoned shard
    /// through `lock_recover` instead of deadlocking or cascading.
    struct PoisonInjector {
        inner: ShardedMaster,
        victim: usize,
        after: u64,
    }

    impl CenterBackend for PoisonInjector {
        type Port = usize;

        fn take_ports(&mut self, p: usize) -> Vec<usize> {
            (0..p).collect()
        }

        fn snapshot(&self) -> Vec<f32> {
            self.inner.snapshot()
        }

        fn rounds(&self) -> u64 {
            self.inner.rounds()
        }

        fn step<O: GradOracle>(
            &self,
            cfg: &DriverConfig,
            port: &mut usize,
            w: &mut WorkerState,
            oracle: &mut O,
            sh: &Shared,
        ) -> f32 {
            if *port == self.victim && w.t_local >= self.after {
                let _guard = lock_recover(&self.inner.shards[0]);
                panic!("injected death while holding center shard 0");
            }
            self.inner.step(cfg, &mut (), w, oracle, sh)
        }
    }

    #[test]
    fn worker_dying_while_holding_a_shard_fails_loud_and_prompt() {
        let mut oracles = QuadraticOracle::family(64, 1.0, 0.0, 1.0, 0.0, 3);
        let init = oracles[0].init_params();
        let inner = ShardedMaster::new(&init, 4, false);
        let center = PoisonInjector {
            inner,
            victim: 1,
            after: 3,
        };
        // A step budget the survivors could not burn for minutes: the
        // promptness bound below proves the stop flag, not budget
        // exhaustion, ended the run.
        let mut c = cfg(Method::easgd_default(3, 1), u64::MAX / 2);
        c.eta = 0.05;
        let t0 = Instant::now();
        let err = run_with_center(&mut oracles, &c, init, center).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("worker 1 died mid-run"), "{msg}");
        assert!(msg.contains("injected death while holding center shard 0"), "{msg}");
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "survivors must stop promptly, not burn the step budget"
        );
    }

    #[test]
    fn poisoned_shard_is_recovered_by_snapshot_and_rounds() {
        let master = ShardedMaster::new(&[1.0f32; 8], 2, false);
        let died = catch_unwind(AssertUnwindSafe(|| {
            let _g = lock_recover(&master.shards[0]);
            panic!("poison shard 0");
        }));
        assert!(died.is_err());
        // Both read paths must recover the poisoned guard, not cascade.
        assert_eq!(master.snapshot(), vec![1.0f32; 8]);
        assert_eq!(master.rounds(), 0);
    }

    #[test]
    fn threaded_admm_converges_on_quadratic() {
        let mut oracles = QuadraticOracle::family(32, 1.0, 0.0, 1.0, 0.0, 2);
        let mut c = cfg(Method::AdmmAsync { rho: 1.0, tau: 4 }, 8000);
        c.eta = 0.05;
        let r = run_threaded(&mut oracles, &c, 4).unwrap();
        assert!(!r.diverged);
        assert_eq!(r.total_steps, 8000);
        assert!(r.curve.last().unwrap().train_loss < 1e-4);
        assert!(r.rounds > 0);
    }
}
