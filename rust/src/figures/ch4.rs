//! Chapter 4: the deep-learning experiments, reproduced on the
//! simulated cluster with the native-MLP oracle over synthetic
//! CIFAR-like data (DESIGN.md §2). Axes and claims mirror the thesis;
//! absolute numbers are substrate-specific.

use super::csv::Csv;
use super::FigOpts;
use crate::cluster::{CostModel, RunResult};
use crate::coordinator::{
    run_sequential, run_with_backend, Backend, ConvOracle, DriverConfig, Method, MlpOracle,
    SeqMethod,
};
use crate::csv_row;
use crate::data::{BlobDataset, Sharding};
use crate::error::Result;
use crate::model::{ConvNetConfig, MlpConfig, ModelKind};
use crate::sync::Arc;

pub fn sweep_data(seed: u64) -> Arc<BlobDataset> {
    Arc::new(BlobDataset::generate(32, 10, 4096, 512, 2.2, seed))
}

pub fn sweep_mlp() -> MlpConfig {
    MlpConfig::new(&[32, 64, 32, 10], 1e-4)
}

/// The `model=conv` sweep architecture: the 32-dim blob input read as a
/// 1×4×8 image through two 3×3 conv blocks (§4.1's conv-net shape on
/// the same data the MLP sweeps use).
pub fn sweep_conv() -> ConvNetConfig {
    ConvNetConfig::for_blob(32, 10, 1e-4)
}

pub struct Sweep {
    pub data: Arc<BlobDataset>,
    pub mcfg: MlpConfig,
    /// Conv architecture for `model=conv`, derived from the sweep
    /// dataset's dimension (callers swapping `data` should refresh it
    /// with [`ConvNetConfig::for_blob`]).
    pub ccfg: ConvNetConfig,
    pub horizon: f64,
    pub eval_every: f64,
    pub seed: u64,
    /// Executor backend every parallel run in this sweep goes through
    /// (sim = virtual time; thread = real workers, real seconds).
    pub backend: Backend,
    /// §4.1 prefetch sharding for every oracle family in this sweep
    /// (Replicated = CIFAR mode; Partitioned = ImageNet mode).
    pub sharding: Sharding,
    /// Gradient model every oracle family in this sweep runs
    /// (`model=mlp` historical stand-in; `model=conv` im2col conv net).
    pub model: ModelKind,
    /// GEMM threads per worker (the hybrid-parallelism knob): real
    /// backends run their local steps on this many threads; the sim
    /// backend prices the measured speedup into its cost model so the
    /// τ trade-off figures stay honest across backends.
    pub threads: usize,
    /// Kernel-tier knob (`simd=`), forwarded to spawned process-backend
    /// workers so every process in a run computes on the same tier.
    pub simd: String,
}

impl Sweep {
    pub fn new(opts: &FigOpts) -> Sweep {
        let data = sweep_data(opts.seed + 1);
        let ccfg = ConvNetConfig::for_blob(data.dim, data.classes, 1e-4);
        Sweep {
            data,
            mcfg: sweep_mlp(),
            ccfg,
            horizon: if opts.full { 240.0 } else { 45.0 },
            eval_every: if opts.full { 5.0 } else { 2.5 },
            seed: opts.seed,
            backend: opts.backend,
            sharding: Sharding::Replicated,
            model: opts.model,
            threads: opts.threads,
            simd: opts.simd.clone(),
        }
    }

    /// Parameter count of the selected sweep model (the cost model
    /// scales communication with it).
    pub fn n_params(&self) -> usize {
        match self.model {
            ModelKind::Mlp => self.mcfg.n_params(),
            ModelKind::Conv => self.ccfg.n_params(),
        }
    }

    pub fn cost(&self, family: &str) -> CostModel {
        let base = match family {
            "imagenet" => CostModel::imagenet_like(self.n_params()),
            _ => CostModel::cifar_like(self.n_params()),
        };
        if self.threads > 1 {
            base.with_thread_speedup(crate::linalg::pool::measured_speedup())
        } else {
            base
        }
    }

    pub fn run(&self, p: usize, method: Method, eta: f32, family: &str) -> Result<RunResult> {
        self.run_decay(p, method, eta, family, 0.0)
    }

    pub fn run_decay(
        &self,
        p: usize,
        method: Method,
        eta: f32,
        family: &str,
        gamma: f64,
    ) -> Result<RunResult> {
        let cfg = DriverConfig {
            eta,
            method,
            cost: self.cost(family),
            horizon: self.horizon,
            eval_every: self.eval_every,
            seed: self.seed + 77,
            max_steps: 40_000_000,
            lr_decay_gamma: gamma,
        };
        if self.backend == Backend::Process {
            // Workers are separate OS processes; they rebuild this
            // sweep's oracle from the serializable spec.
            let spec = crate::coordinator::OracleSpec::Sweep {
                model: self.model,
                sharding: self.sharding,
                batch: 32,
                seed: self.seed,
            };
            let opts = crate::coordinator::ProcessOpts {
                threads: self.threads,
                simd: self.simd.clone(),
                ..Default::default()
            };
            return crate::coordinator::run_process(&spec, p, &cfg, &opts);
        }
        match self.model {
            ModelKind::Mlp => {
                let mut oracles =
                    MlpOracle::family_sharded(self.data.clone(), &self.mcfg, 32, p, self.sharding);
                run_with_backend(self.backend, &mut oracles, &cfg)
            }
            ModelKind::Conv => {
                let mut oracles = ConvOracle::family_sharded(
                    self.data.clone(),
                    &self.ccfg,
                    32,
                    p,
                    self.sharding,
                );
                run_with_backend(self.backend, &mut oracles, &cfg)
            }
        }
    }

    pub fn run_seq(&self, m: SeqMethod, eta: f32, family: &str) -> RunResult {
        let cost = self.cost(family);
        match self.model {
            ModelKind::Mlp => {
                let mut o = MlpOracle::new(self.data.clone(), self.mcfg.clone(), 32, 40_000);
                run_sequential(&mut o, m, eta, &cost, self.horizon, self.eval_every, self.seed + 77)
            }
            ModelKind::Conv => {
                let mut o = ConvOracle::new_sharded(
                    self.data.clone(),
                    self.ccfg.clone(),
                    32,
                    40_000,
                    Sharding::Replicated,
                );
                run_sequential(&mut o, m, eta, &cost, self.horizon, self.eval_every, self.seed + 77)
            }
        }
    }
}

/// EAMSGD with the momentum rate calibrated to this oracle (δ=0.9; the
/// thesis uses 0.99 on CIFAR — see EXPERIMENTS.md §Calibration).
fn eamsgd(p: usize, tau: u32) -> Method {
    Method::Eamsgd { alpha: 0.9 / p as f32, tau, delta: 0.9 }
}

fn dump_curve(csv: &mut Csv, label: &str, tau: u32, p: usize, r: &RunResult) -> Result<()> {
    for pt in &r.curve {
        csv_row!(
            csv, label, tau, p, pt.time, pt.train_loss, pt.test_loss, pt.test_error
        )?;
    }
    Ok(())
}

/// Tables 4.1–4.3 — the learning-rate grids the thesis explored (echoed
/// so the harness documents the search spaces it samples from).
pub fn tab4_1(opts: &FigOpts) -> Result<()> {
    let mut csv = Csv::create(
        format!("{}/tab4_1_4_3.csv", opts.out_dir),
        &["table", "method", "etas"],
    )?;
    let rows: &[(&str, &str, &str)] = &[
        ("4.1", "EASGD", "0.05 0.01 0.005"),
        ("4.1", "EAMSGD", "0.01 0.005 0.001"),
        ("4.1", "DOWNPOUR/ADOWNPOUR/MVADOWNPOUR", "0.005 0.001 0.0005"),
        ("4.1", "MDOWNPOUR", "0.00005 0.00001 0.000005"),
        ("4.1", "SGD/ASGD/MVASGD", "0.05 0.01 0.005"),
        ("4.1", "MSGD", "0.001 0.0005 0.0001"),
        ("4.3", "EASGD(ImageNet)", "0.1"),
        ("4.3", "EAMSGD(ImageNet)", "0.001"),
        ("4.3", "DOWNPOUR(ImageNet)", "p4:0.02 p8:0.01"),
        ("4.3", "SGD/ASGD/MVASGD(ImageNet)", "0.05"),
        ("4.3", "MSGD(ImageNet)", "0.0005"),
    ];
    for (t, m, e) in rows {
        csv_row!(csv, t, m, e)?;
        println!("tab{t}: {m:<38} η ∈ {{{e}}}");
    }
    Ok(())
}

/// Figs 4.1–4.4 — all parallel methods vs. communication period
/// τ ∈ {1, 4, 16, 64} at p = 4.
pub fn fig4_tau_sweep(opts: &FigOpts) -> Result<()> {
    let sw = Sweep::new(opts);
    let p = 4;
    let mut csv = Csv::create(
        format!("{}/fig4_1_4_4.csv", opts.out_dir),
        &["method", "tau", "p", "time", "train_loss", "test_loss", "test_error"],
    )?;
    let mut easgd_best = vec![];
    let mut downpour_best = vec![];
    for &tau in &[1u32, 4, 16, 64] {
        let runs: Vec<(&str, RunResult)> = vec![
            ("EASGD", sw.run(p, Method::easgd_default(p, tau), 0.08, "cifar")?),
            ("EAMSGD", sw.run(p, eamsgd(p, tau), 0.016, "cifar")?),
            ("DOWNPOUR", sw.run(p, Method::Downpour { tau }, 0.05, "cifar")?),
            ("ADOWNPOUR", sw.run(p, Method::ADownpour { tau }, 0.05, "cifar")?),
            (
                "MVADOWNPOUR",
                sw.run(p, Method::MvaDownpour { tau, alpha: 0.001 }, 0.05, "cifar")?,
            ),
        ];
        for (name, r) in &runs {
            dump_curve(&mut csv, name, tau, p, r)?;
            let best = r.best_test_error();
            println!(
                "fig4.x τ={tau:<3} {name:<12} best test err {:.3}{}",
                best,
                if r.diverged { "  [DIVERGED]" } else { "" }
            );
            if *name == "EASGD" {
                easgd_best.push((tau, best, r.diverged));
            }
            if *name == "DOWNPOUR" {
                downpour_best.push((tau, best, r.diverged));
            }
        }
    }
    // MDOWNPOUR only defined at τ=1.
    let r = sw.run(p, Method::MDownpour { delta: 0.9 }, 0.002, "cifar")?;
    dump_curve(&mut csv, "MDOWNPOUR", 1, p, &r)?;
    println!("fig4.x τ=1   MDOWNPOUR    best test err {:.3}", r.best_test_error());

    let easgd_ok = easgd_best.iter().all(|(_, e, d)| !*d && *e < 0.7);
    let dp_degrades = {
        let small: f64 = downpour_best
            .iter()
            .filter(|(t, _, _)| *t <= 4)
            .map(|(_, e, d)| if *d { 1.0 } else { *e })
            .fold(f64::INFINITY, f64::min);
        let large: f64 = downpour_best
            .iter()
            .filter(|(t, _, _)| *t >= 16)
            .map(|(_, e, d)| if *d { 1.0 } else { *e })
            .fold(f64::INFINITY, f64::min);
        large > small + 0.01
    };
    println!(
        "fig4.1-4.4 shape: EASGD robust across τ: {} | DOWNPOUR degrades at τ≥16: {}",
        if easgd_ok { "HOLDS" } else { "VIOLATED" },
        if dp_degrades { "HOLDS" } else { "VIOLATED" }
    );
    Ok(())
}

/// Figs 4.5–4.7 — methods at their best τ vs. worker count p ∈ {4,8,16}.
pub fn fig4_p_sweep(opts: &FigOpts) -> Result<()> {
    let sw = Sweep::new(opts);
    let mut csv = Csv::create(
        format!("{}/fig4_5_4_7.csv", opts.out_dir),
        &["method", "tau", "p", "time", "train_loss", "test_loss", "test_error"],
    )?;
    let mut eamsgd_best = Vec::new();
    for &p in &[4usize, 8, 16] {
        let runs: Vec<(&str, u32, RunResult)> = vec![
            ("EASGD", 10, sw.run(p, Method::easgd_default(p, 10), 0.08, "cifar")?),
            ("EAMSGD", 10, sw.run(p, eamsgd(p, 10), 0.016, "cifar")?),
            ("DOWNPOUR", 1, sw.run(p, Method::Downpour { tau: 1 }, 0.03, "cifar")?),
            (
                "MDOWNPOUR",
                1,
                sw.run(p, Method::MDownpour { delta: 0.9 }, 0.002, "cifar")?,
            ),
        ];
        for (name, tau, r) in &runs {
            dump_curve(&mut csv, name, *tau, p, r)?;
            println!(
                "fig4.5-7 p={p:<3} {name:<10} best test err {:.3}{}",
                r.best_test_error(),
                if r.diverged { " [DIVERGED]" } else { "" }
            );
            if *name == "EAMSGD" {
                eamsgd_best.push(r.best_test_error());
            }
        }
    }
    // Sequential reference.
    let r = sw.run_seq(SeqMethod::Msgd { delta: 0.9 }, 0.01, "cifar");
    dump_curve(&mut csv, "MSGD", 0, 1, &r)?;
    println!("fig4.5-7 p=1   MSGD       best test err {:.3}", r.best_test_error());

    let improves = eamsgd_best.windows(2).all(|w| w[1] <= w[0] + 0.01);
    println!(
        "fig4.5-4.7 shape: EAMSGD best error non-increasing in p: {}",
        if improves { "HOLDS" } else { "VIOLATED" }
    );
    Ok(())
}

/// Figs 4.8–4.9 — the ImageNet-shaped cost model at p ∈ {4, 8}:
/// expensive steps, expensive messages (233 MB model).
pub fn fig4_imagenet(opts: &FigOpts) -> Result<()> {
    let mut sw = Sweep::new(opts);
    sw.horizon = if opts.full { 4000.0 } else { 900.0 };
    sw.eval_every = sw.horizon / 18.0;
    // The §4.1 ImageNet mode: each loader owns a distinct 1/k shard.
    sw.sharding = Sharding::Partitioned;
    let mut csv = Csv::create(
        format!("{}/fig4_8_4_9.csv", opts.out_dir),
        &["method", "tau", "p", "time", "train_loss", "test_loss", "test_error"],
    )?;
    for &p in &[4usize, 8] {
        let runs: Vec<(&str, u32, RunResult)> = vec![
            ("EASGD", 10, sw.run(p, Method::easgd_default(p, 10), 0.1, "imagenet")?),
            ("EAMSGD", 10, sw.run(p, eamsgd(p, 10), 0.016, "imagenet")?),
            ("DOWNPOUR", 1, sw.run(p, Method::Downpour { tau: 1 }, 0.05, "imagenet")?),
        ];
        for (name, tau, r) in &runs {
            dump_curve(&mut csv, name, *tau, p, r)?;
            println!(
                "fig4.8-9 p={p} {name:<10} best test err {:.3}",
                r.best_test_error()
            );
        }
        // EAMSGD should reach DOWNPOUR's best error faster (speedup ≈1.8
        // in the thesis).
        let thr = runs[2].2.best_test_error() * 1.02;
        let t_ea = runs[1].2.time_to_error(thr);
        let t_dp = runs[2].2.time_to_error(thr);
        if let (Some(a), Some(b)) = (t_ea, t_dp) {
            println!(
                "fig4.8-9 shape p={p}: EAMSGD reaches DOWNPOUR-best {:.2}x {} (thesis ≈1.8x)",
                b / a,
                if a <= b { "faster — HOLDS" } else { "slower — VIOLATED" }
            );
        }
    }
    Ok(())
}

/// Figs 4.10–4.11 — the sequential (p=1) comparison.
pub fn fig4_sequential(opts: &FigOpts) -> Result<()> {
    let sw = Sweep::new(opts);
    let mut csv = Csv::create(
        format!("{}/fig4_10_4_11.csv", opts.out_dir),
        &["method", "tau", "p", "time", "train_loss", "test_loss", "test_error"],
    )?;
    let runs: Vec<(&str, RunResult)> = vec![
        ("SGD", sw.run_seq(SeqMethod::Sgd, 0.08, "cifar")),
        ("MSGD", sw.run_seq(SeqMethod::Msgd { delta: 0.9 }, 0.01, "cifar")),
        ("ASGD", sw.run_seq(SeqMethod::Asgd, 0.08, "cifar")),
        ("MVASGD", sw.run_seq(SeqMethod::Mvasgd { alpha: 0.001 }, 0.08, "cifar")),
    ];
    for (name, r) in &runs {
        dump_curve(&mut csv, name, 0, 1, r)?;
        println!("fig4.10 {name:<8} best test err {:.3}", r.best_test_error());
    }
    let msgd = runs[1].1.best_test_error();
    let sgd = runs[0].1.best_test_error();
    println!(
        "fig4.10-4.11 shape: MSGD best ≤ SGD best: {}",
        if msgd <= sgd + 0.05 {
            "HOLDS"
        } else {
            "DIVERGES (momentum gains are model-specific; see EXPERIMENTS.md)"
        }
    );
    Ok(())
}

/// Fig 4.12 — learning-rate dependence of EASGD vs EAMSGD (p=16, τ=10):
/// larger η helps EAMSGD's test error, hurts EASGD's.
pub fn fig4_12_eta(opts: &FigOpts) -> Result<()> {
    let sw = Sweep::new(opts);
    let p = 16;
    let mut csv = Csv::create(
        format!("{}/fig4_12.csv", opts.out_dir),
        &["method", "eta", "time", "train_loss", "test_loss", "test_error"],
    )?;
    let etas = [0.12f32, 0.05, 0.02];
    let mut ea = Vec::new();
    let mut eam = Vec::new();
    for &eta in &etas {
        let r1 = sw.run(p, Method::easgd_default(p, 10), eta, "cifar")?;
        let r2 = sw.run(p, Method::eamsgd_default(p, 10), eta * 0.2, "cifar")?;
        for pt in &r1.curve {
            csv_row!(csv, "EASGD", eta, pt.time, pt.train_loss, pt.test_loss, pt.test_error)?;
        }
        for pt in &r2.curve {
            csv_row!(csv, "EAMSGD", eta * 0.2, pt.time, pt.train_loss, pt.test_loss, pt.test_error)?;
        }
        println!(
            "fig4.12 η={eta:<5}: EASGD best {:.3} | EAMSGD(η={:.3}) best {:.3}",
            r1.best_test_error(),
            eta * 0.2,
            r2.best_test_error()
        );
        ea.push(r1.best_test_error());
        let _ = &ea;
        eam.push(r2.best_test_error());
    }
    println!(
        "fig4.12 shape: EAMSGD prefers larger η: {}",
        if eam[0] <= eam[2] + 0.02 {
            "HOLDS"
        } else {
            "DIVERGES (regularization-by-fluctuation is a deep-net effect; \
             on this convex-ish oracle larger η only adds noise — \
             EXPERIMENTS.md §Deviations)"
        }
    );
    let _ = &ea;
    Ok(())
}

/// Fig 4.13 — communication period τ up to 1000 and learning-rate decay:
/// EASGD τ-insensitive; EAMSGD can trap at large τ, rescued by decay.
pub fn fig4_13_tau_decay(opts: &FigOpts) -> Result<()> {
    let sw = Sweep::new(opts);
    let p = 16;
    let taus: &[u32] = if opts.full { &[1, 10, 100, 1000] } else { &[1, 10, 100] };
    let mut csv = Csv::create(
        format!("{}/fig4_13.csv", opts.out_dir),
        &["method", "tau", "gamma", "time", "train_loss", "test_loss", "test_error"],
    )?;
    let mut easgd_range = (f64::INFINITY, f64::NEG_INFINITY);
    for &tau in taus {
        for &(gamma, glab) in &[(0.0f64, "0"), (1e-3, "1e-3")] {
            let r1 = sw.run_decay(p, Method::easgd_default(p, tau), 0.08, "cifar", gamma)?;
            let r2 = sw.run_decay(p, eamsgd(p, tau), 0.016, "cifar", gamma)?;
            for pt in &r1.curve {
                csv_row!(csv, "EASGD", tau, glab, pt.time, pt.train_loss, pt.test_loss, pt.test_error)?;
            }
            for pt in &r2.curve {
                csv_row!(csv, "EAMSGD", tau, glab, pt.time, pt.train_loss, pt.test_loss, pt.test_error)?;
            }
            println!(
                "fig4.13 τ={tau:<5} γ={glab:<5} EASGD {:.3} | EAMSGD {:.3}",
                r1.best_test_error(),
                r2.best_test_error()
            );
            if gamma == 0.0 {
                let b = r1.best_test_error();
                easgd_range.0 = easgd_range.0.min(b);
                easgd_range.1 = easgd_range.1.max(b);
            }
        }
    }
    println!(
        "fig4.13 shape: EASGD τ-insensitive (spread {:.3}): {}",
        easgd_range.1 - easgd_range.0,
        if easgd_range.1 - easgd_range.0 < 0.08 { "HOLDS" } else { "VIOLATED" }
    );
    Ok(())
}

/// Figs 4.14–4.15 — wall-clock time to reach fixed test-error levels vs
/// p; missing bars = never reached.
pub fn fig4_speedup(opts: &FigOpts) -> Result<()> {
    let sw = Sweep::new(opts);
    let mut results: Vec<(String, usize, RunResult)> = Vec::new();
    for &p in &[4usize, 8, 16] {
        results.push(("EASGD".into(), p, sw.run(p, Method::easgd_default(p, 10), 0.08, "cifar")?));
        results.push(("EAMSGD".into(), p, sw.run(p, eamsgd(p, 10), 0.016, "cifar")?));
        results.push((
            "DOWNPOUR".into(),
            p,
            sw.run(p, Method::Downpour { tau: 1 }, 0.03, "cifar")?,
        ));
        results.push((
            "MDOWNPOUR".into(),
            p,
            sw.run(p, Method::MDownpour { delta: 0.9 }, 0.002, "cifar")?,
        ));
    }
    let msgd = sw.run_seq(SeqMethod::Msgd { delta: 0.9 }, 0.01, "cifar");
    results.push(("MSGD".into(), 1, msgd));

    // Thresholds relative to the global best (the thesis' fixed CIFAR
    // percentages translated to this dataset's achievable range).
    let best = results
        .iter()
        .map(|(_, _, r)| r.best_test_error())
        .fold(f64::INFINITY, f64::min);
    let thresholds: Vec<f64> = [1.30, 1.20, 1.10, 1.05]
        .iter()
        .map(|f| best * f)
        .collect();

    let mut csv = Csv::create(
        format!("{}/fig4_14_4_15.csv", opts.out_dir),
        &["method", "p", "threshold", "time_or_nan"],
    )?;
    let mut eamsgd_wins = 0usize;
    let mut comparisons = 0usize;
    for &thr in &thresholds {
        println!("fig4.14 threshold test err ≤ {thr:.3}:");
        for (name, p, r) in &results {
            let t = r.time_to_error(thr);
            csv_row!(csv, name, p, thr, t.map(|x| x.to_string()).unwrap_or("nan".into()))?;
            match t {
                Some(t) => println!("    {name:<10} p={p:<3} t={t:>8.1}s"),
                None => println!("    {name:<10} p={p:<3} (never)"),
            }
        }
        // EAMSGD vs best comparator at p=16.
        let t_eam = results
            .iter()
            .find(|(n, p, _)| n == "EAMSGD" && *p == 16)
            .and_then(|(_, _, r)| r.time_to_error(thr));
        let t_best_other = results
            .iter()
            .filter(|(n, _, _)| n != "EAMSGD")
            .filter_map(|(_, _, r)| r.time_to_error(thr))
            .fold(f64::INFINITY, f64::min);
        if let Some(t) = t_eam {
            comparisons += 1;
            if t <= t_best_other {
                eamsgd_wins += 1;
            }
        }
    }
    println!(
        "fig4.14-4.15 shape: EAMSGD(p=16) fastest at {eamsgd_wins}/{comparisons} thresholds"
    );
    Ok(())
}

/// Table 4.4 — compute / data / parameter-communication breakdown for
/// DOWNPOUR (τ=1) and EASGD (τ=10) under both cost families.
pub fn tab4_4(opts: &FigOpts) -> Result<()> {
    let mut sw = Sweep::new(opts);
    sw.horizon = if opts.full { 120.0 } else { 30.0 };
    sw.eval_every = sw.horizon; // breakdown only
    let mut csv = Csv::create(
        format!("{}/tab4_4.csv", opts.out_dir),
        &["family", "method", "tau", "p", "compute", "data", "comm", "per_step_norm"],
    )?;
    for family in ["cifar", "imagenet"] {
        let mut iw = Sweep::new(opts);
        iw.horizon = if family == "imagenet" {
            if opts.full { 2400.0 } else { 600.0 }
        } else {
            sw.horizon
        };
        iw.eval_every = iw.horizon;
        if family == "imagenet" {
            iw.sharding = Sharding::Partitioned;
        }
        for &p in &[1usize, 4, 8, 16] {
            for (name, method, tau) in [
                ("DOWNPOUR", Method::Downpour { tau: 1 }, 1u32),
                ("EASGD", Method::easgd_default(p.max(1), 10), 10u32),
            ] {
                if p == 1 && tau == 10 {
                    continue; // thesis marks τ=10, p=1 as NA
                }
                let r = iw.run(p, method, 0.03, family)?;
                let steps = r.total_steps.max(1) as f64;
                // Normalize like the paper: per 400 (CIFAR) / 1024
                // (ImageNet) mini-batches PER WORKER.
                let unit = if family == "imagenet" { 1024.0 } else { 400.0 };
                let norm = unit * p as f64 / steps;
                let (c, d, m) = (
                    r.breakdown.compute * norm,
                    r.breakdown.data * norm,
                    r.breakdown.comm * norm,
                );
                csv_row!(csv, family, name, tau, p, c, d, m, norm)?;
                println!(
                    "tab4.4 [{family:<8}] {name:<9} τ={tau:<2} p={p:<3} compute/data/comm = {c:>7.1}/{d:>5.1}/{m:>6.1} s"
                );
            }
        }
    }
    println!("tab4.4 shape: comm large at τ=1, negligible at τ=10 (compare rows)");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opts(backend: Backend, model: ModelKind) -> FigOpts {
        FigOpts {
            out_dir: std::env::temp_dir()
                .join("et_fig_ch4")
                .to_string_lossy()
                .into_owned(),
            full: false,
            seed: 0,
            backend,
            model,
            threads: 1,
            simd: "auto".into(),
        }
    }

    #[test]
    fn quick_sequential_figure_runs() {
        tab4_1(&opts(Backend::Sim, ModelKind::Mlp)).unwrap();
    }

    /// The `model=conv` acceptance cell: one EASGD sweep cell runs
    /// end-to-end with the conv oracle on BOTH executor backends (sim
    /// virtual time, thread real seconds) and produces a finite,
    /// non-trivial curve.
    #[test]
    fn conv_sweep_cell_runs_on_both_backends() {
        for backend in [Backend::Sim, Backend::Thread] {
            let mut sw = Sweep::new(&opts(backend, ModelKind::Conv));
            // Keep the cell tiny: the thread backend's horizon is real
            // wall-clock seconds.
            sw.horizon = if backend == Backend::Thread { 0.4 } else { 6.0 };
            sw.eval_every = sw.horizon / 2.0;
            let r = sw
                .run(2, Method::easgd_default(2, 4), 0.02, "cifar")
                .unwrap_or_else(|e| panic!("{backend:?}: {e}"));
            assert!(!r.curve.is_empty(), "{backend:?}: no eval points");
            assert!(
                r.curve.iter().all(|pt| pt.train_loss.is_finite() && pt.test_loss.is_finite()),
                "{backend:?}: non-finite conv sweep stats"
            );
            assert!(r.total_steps > 0, "{backend:?}: no steps taken");
        }
    }

    /// The conv sweep's cost model scales with the conv net's parameter
    /// count, not the MLP's.
    #[test]
    fn sweep_n_params_follows_the_model_knob() {
        let mlp = Sweep::new(&opts(Backend::Sim, ModelKind::Mlp));
        let conv = Sweep::new(&opts(Backend::Sim, ModelKind::Conv));
        assert_eq!(mlp.n_params(), sweep_mlp().n_params());
        assert_eq!(conv.n_params(), sweep_conv().n_params());
        assert_ne!(mlp.n_params(), conv.n_params());
    }
}
