//! Row-major dense f64 matrix — small-matrix workhorse for the
//! stability/moment analyses. No BLAS; the figures sweep ≤20×20
//! matrices where naive loops are already memory-bound.

/// Dense row-major matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m.set(i, i, 1.0);
        }
        m
    }

    pub fn diag(d: &[f64]) -> Self {
        let mut m = Self::zeros(d.len(), d.len());
        for (i, &v) in d.iter().enumerate() {
            m.set(i, i, v);
        }
        m
    }

    pub fn from_rows(rows: &[&[f64]]) -> Self {
        let r = rows.len();
        let c = rows[0].len();
        let mut m = Self::zeros(r, c);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), c, "ragged rows");
            m.data[i * c..(i + 1) * c].copy_from_slice(row);
        }
        m
    }

    /// Build from a flat row-major slice.
    pub fn from_flat(rows: usize, cols: usize, flat: &[f64]) -> Self {
        assert_eq!(flat.len(), rows * cols);
        Self { rows, cols, data: flat.to_vec() }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    #[inline]
    pub fn add_at(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.cols + j] += v;
    }

    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "dim mismatch");
        let mut out = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self.get(i, k);
                if a == 0.0 {
                    continue;
                }
                for j in 0..other.cols {
                    out.data[i * other.cols + j] += a * other.get(k, j);
                }
            }
        }
        out
    }

    /// y = A x.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0; self.rows];
        for i in 0..self.rows {
            let row = &self.data[i * self.cols..(i + 1) * self.cols];
            y[i] = row.iter().zip(x).map(|(a, b)| a * b).sum();
        }
        y
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.set(j, i, self.get(i, j));
            }
        }
        out
    }

    pub fn scale(&self, s: f64) -> Matrix {
        let mut out = self.clone();
        for v in &mut out.data {
            *v *= s;
        }
        out
    }

    pub fn add(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let mut out = self.clone();
        for (v, w) in out.data.iter_mut().zip(&other.data) {
            *v += w;
        }
        out
    }

    /// Max-abs entry (used for divergence detection in map iteration).
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, v| m.max(v.abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matvec_matches_matmul_column() {
        let a = Matrix::from_rows(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]]);
        let x = [1.0, 0.5, -1.0];
        let y = a.matvec(&x);
        assert_eq!(y, vec![1.0 + 1.0 - 3.0, 4.0 + 2.5 - 6.0]);
    }

    #[test]
    fn transpose_involution() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0], &[5.0, 6.0]]);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    #[should_panic]
    fn matmul_dim_mismatch_panics() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(2, 3);
        let _ = a.matmul(&b);
    }
}
