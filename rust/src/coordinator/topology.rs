//! The `Topology` abstraction: how workers and the master (or the tree
//! of nodes) are wired together.
//!
//! The thesis studies two layouts:
//!
//! * [`Topology::Star`] — the flat master–worker star of Chapter 4:
//!   p workers exchange directly with one center variable.
//! * [`Topology::Tree`] — the d-ary EASGD tree of Chapter 6
//!   (Algorithm 6): leaves run local SGD/Nesterov, interior nodes do no
//!   gradient work and absorb arriving parameter snapshots with the
//!   Gauss–Seidel moving-average rule x ← x + α(x_arrived − x).
//!
//! Both layouts run on both [`super::executor::Executor`] backends
//! (virtual-time simulator / real threads); this module owns the pieces
//! the backends share: the tree wiring ([`TreeLayout`]), the §6.1
//! communication schemes ([`TreeScheme`]), the per-node (τ_up, τ_down)
//! table ([`node_taus`]) the schemes induce, and the spec validation
//! ([`TreeSpec::validate`]) every entry path runs.

use crate::error::Result;

/// The two §6.1 communication schemes.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TreeScheme {
    /// Scheme 1 (multi-scale): fast period τ₁ on the bottom layer
    /// (leaf ↔ leaf-parent), slow τ₂ between interior nodes.
    MultiScale { tau1: u32, tau2: u32 },
    /// Scheme 2 (fast-up/slow-down): every node pushes up every τ_up
    /// activations and down every τ_down.
    UpDown { tau_up: u32, tau_down: u32 },
}

impl TreeScheme {
    pub fn name(&self) -> &'static str {
        match self {
            TreeScheme::MultiScale { .. } => "multiscale",
            TreeScheme::UpDown { .. } => "updown",
        }
    }
}

/// Tree-specific run parameters (the rest of the configuration — η,
/// method, horizon, seed, cost model — lives in the shared
/// [`super::executor::DriverConfig`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TreeSpec {
    /// Fan-out d of the d-ary tree (≥ 2).
    pub degree: usize,
    pub scheme: TreeScheme,
    /// How often interior nodes activate, as a fraction of the leaf
    /// gradient-step time (virtual-time backend only).
    pub interior_activity: f64,
    /// Cost discount for bottom-layer (leaf ↔ leaf-parent) messages —
    /// they stay inside one machine in the thesis' deployment (§6.1),
    /// which is exactly what communication scheme 1 exploits
    /// (virtual-time backend only).
    pub intra_discount: f64,
}

impl TreeSpec {
    pub fn new(degree: usize, scheme: TreeScheme) -> TreeSpec {
        TreeSpec { degree, scheme, interior_activity: 0.25, intra_discount: 0.2 }
    }

    /// Thesis §6.1.2 defaults: d = 16, multi-scale τ₁ = 10 / τ₂ = 100.
    pub fn thesis_default() -> TreeSpec {
        TreeSpec::new(16, TreeScheme::MultiScale { tau1: 10, tau2: 100 })
    }

    /// Reject degenerate specs — fan-out < 2, zero communication
    /// periods (a zero τ would hit `t % 0` in the drivers) — with a
    /// descriptive error instead of a panic downstream. Run by every
    /// entry path: `check_supported` and both tree backends.
    pub fn validate(&self) -> Result<()> {
        if self.degree < 2 {
            return Err(crate::err!(
                "tree fan-out must be ≥ 2, got degree={}",
                self.degree
            ));
        }
        let (a, b, what) = match self.scheme {
            TreeScheme::MultiScale { tau1, tau2 } => (tau1, tau2, "tau1/tau2"),
            TreeScheme::UpDown { tau_up, tau_down } => (tau_up, tau_down, "tau_up/tau_down"),
        };
        if a == 0 || b == 0 {
            return Err(crate::err!(
                "tree communication periods must be ≥ 1, got {what}={a}/{b}"
            ));
        }
        Ok(())
    }
}

/// How a distributed run is wired. The worker/leaf count is implied by
/// the oracle family handed to the executor, so the topology itself
/// stays a small copyable value.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Topology {
    /// Flat master–worker star (Chapter 4).
    Star,
    /// d-ary EASGD tree (Chapter 6, Algorithm 6).
    Tree(TreeSpec),
}

impl Topology {
    pub fn name(&self) -> &'static str {
        match self {
            Topology::Star => "star",
            Topology::Tree(_) => "tree",
        }
    }
}

/// Static tree wiring: node 0 is the root; nodes are laid out level by
/// level; leaves are the last `leaves` nodes.
pub struct TreeLayout {
    pub parent: Vec<Option<usize>>,
    pub children: Vec<Vec<usize>>,
    pub n_nodes: usize,
    pub first_leaf: usize,
}

impl TreeLayout {
    /// Build the minimal d-ary tree with `leaves` leaf nodes: levels of
    /// size ⌈leaves/d^k⌉ from root down.
    pub fn dary(degree: usize, leaves: usize) -> TreeLayout {
        assert!(degree >= 2 && leaves >= 1);
        // Level sizes from the leaf level up. `sizes` and `offs` are
        // non-empty by construction, so `last()` always holds a value.
        let mut sizes = vec![leaves];
        while *sizes.last().expect("sizes starts non-empty") > 1 {
            let s = sizes.last().expect("sizes starts non-empty").div_ceil(degree);
            sizes.push(s);
        }
        sizes.reverse(); // root first
        let n_nodes: usize = sizes.iter().sum();
        let mut parent = vec![None; n_nodes];
        let mut children = vec![Vec::new(); n_nodes];
        // Offsets of each level.
        let mut offs = vec![0usize];
        for s in &sizes {
            offs.push(offs.last().expect("offs starts non-empty") + s);
        }
        for lvl in 1..sizes.len() {
            for j in 0..sizes[lvl] {
                let node = offs[lvl] + j;
                let par = offs[lvl - 1] + j / degree;
                parent[node] = Some(par);
                children[par].push(node);
            }
        }
        let first_leaf = n_nodes - leaves;
        TreeLayout { parent, children, n_nodes, first_leaf }
    }

    pub fn is_leaf(&self, i: usize) -> bool {
        i >= self.first_leaf
    }

    /// Is this node a parent of leaves (the "bottom layer" of scheme 1)?
    pub fn is_leaf_parent(&self, i: usize) -> bool {
        self.children[i].iter().any(|&c| self.is_leaf(c))
    }
}

/// Per-node (τ_up, τ_down) communication periods under a scheme;
/// `u64::MAX` means "never" (the root never pushes up, leaves never
/// push down). Shared by both tree backends so the sim and the thread
/// executor run the identical protocol.
pub fn node_taus(layout: &TreeLayout, scheme: TreeScheme) -> Vec<(u64, u64)> {
    (0..layout.n_nodes)
        .map(|i| match scheme {
            TreeScheme::MultiScale { tau1, tau2 } => {
                if layout.is_leaf(i) {
                    (tau1 as u64, u64::MAX)
                } else if layout.is_leaf_parent(i) {
                    (tau2 as u64, tau1 as u64)
                } else if layout.parent[i].is_none() {
                    (u64::MAX, tau2 as u64)
                } else {
                    (tau2 as u64, tau2 as u64)
                }
            }
            TreeScheme::UpDown { tau_up, tau_down } => {
                let up = if layout.parent[i].is_none() { u64::MAX } else { tau_up as u64 };
                let down = if layout.is_leaf(i) { u64::MAX } else { tau_down as u64 };
                (up, down)
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dary_topology_shapes() {
        let t = TreeLayout::dary(16, 256);
        // 256 leaves, 16 parents, 1 root.
        assert_eq!(t.n_nodes, 256 + 16 + 1);
        assert_eq!(t.first_leaf, 17);
        assert!(t.parent[0].is_none());
        assert_eq!(t.children[0].len(), 16);
        for i in 17..t.n_nodes {
            assert!(t.is_leaf(i));
            assert!(t.children[i].is_empty());
        }
        for i in 1..17 {
            assert_eq!(t.children[i].len(), 16);
            assert_eq!(t.parent[i], Some(0));
            assert!(t.is_leaf_parent(i));
        }
    }

    #[test]
    fn ragged_tree_still_connects_everyone() {
        let t = TreeLayout::dary(4, 10); // levels: 10, 3, 1
        assert_eq!(t.n_nodes, 14);
        for i in 1..t.n_nodes {
            assert!(t.parent[i].is_some());
        }
        let total_children: usize = t.children.iter().map(|c| c.len()).sum();
        assert_eq!(total_children, t.n_nodes - 1);
    }

    #[test]
    fn multiscale_taus_follow_the_layer_structure() {
        let layout = TreeLayout::dary(4, 16); // 1 root, 4 parents, 16 leaves
        let taus = node_taus(&layout, TreeScheme::MultiScale { tau1: 2, tau2: 8 });
        // Root: never up, slow down.
        assert_eq!(taus[0], (u64::MAX, 8));
        // Leaf parents: slow up, fast down.
        for i in 1..5 {
            assert_eq!(taus[i], (8, 2));
        }
        // Leaves: fast up, never down.
        for i in 5..21 {
            assert_eq!(taus[i], (2, u64::MAX));
        }
    }

    #[test]
    fn updown_taus_are_uniform_except_at_the_rim() {
        let layout = TreeLayout::dary(4, 16);
        let taus = node_taus(&layout, TreeScheme::UpDown { tau_up: 1, tau_down: 10 });
        assert_eq!(taus[0], (u64::MAX, 10));
        for i in 1..5 {
            assert_eq!(taus[i], (1, 10));
        }
        for i in 5..21 {
            assert_eq!(taus[i], (1, u64::MAX));
        }
    }

    #[test]
    fn single_node_tree_never_communicates() {
        let layout = TreeLayout::dary(2, 1);
        assert_eq!(layout.n_nodes, 1);
        assert_eq!(layout.first_leaf, 0);
        for scheme in [
            TreeScheme::MultiScale { tau1: 1, tau2: 2 },
            TreeScheme::UpDown { tau_up: 1, tau_down: 2 },
        ] {
            let taus = node_taus(&layout, scheme);
            // Root-and-leaf at once: up is MAX (no parent) under updown,
            // down is MAX (leaf); multiscale leaves the up period set but
            // the drivers guard on `parent.is_none()`.
            assert_eq!(taus[0].1, u64::MAX, "{scheme:?}");
        }
    }

    #[test]
    fn validate_rejects_degenerate_specs() {
        assert!(TreeSpec::new(4, TreeScheme::UpDown { tau_up: 1, tau_down: 10 })
            .validate()
            .is_ok());
        let e = TreeSpec::new(1, TreeScheme::UpDown { tau_up: 1, tau_down: 10 })
            .validate()
            .unwrap_err();
        assert!(format!("{e}").contains("fan-out"), "{e}");
        let e = TreeSpec::new(4, TreeScheme::UpDown { tau_up: 0, tau_down: 10 })
            .validate()
            .unwrap_err();
        assert!(format!("{e}").contains("periods"), "{e}");
        assert!(TreeSpec::new(4, TreeScheme::MultiScale { tau1: 10, tau2: 0 })
            .validate()
            .is_err());
    }

    #[test]
    fn names_and_defaults() {
        assert_eq!(Topology::Star.name(), "star");
        let spec = TreeSpec::thesis_default();
        assert_eq!(Topology::Tree(spec).name(), "tree");
        assert_eq!(spec.degree, 16);
        assert_eq!(spec.scheme.name(), "multiscale");
        assert_eq!(TreeScheme::UpDown { tau_up: 1, tau_down: 4 }.name(), "updown");
        assert!(spec.interior_activity > 0.0 && spec.intra_discount > 0.0);
    }
}
