"""Kernel vs oracle — the core L1 correctness signal (pytest + hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import easgd_update as KU
from compile.kernels import ref
from compile.kernels.attention import attention, BQ

SETTINGS = dict(max_examples=25, deadline=None)


def _vec(rng, n, scale=1.0):
    return jnp.asarray(rng.standard_normal(n).astype(np.float32) * scale)


@settings(**SETTINGS)
@given(n=st.integers(1, 5000), eta=st.floats(0.0, 1.0),
       delta=st.floats(-1.0, 1.0), seed=st.integers(0, 2**31 - 1))
def test_sgd_nesterov_matches_ref(n, eta, delta, seed):
    rng = np.random.default_rng(seed)
    x, v, g = _vec(rng, n), _vec(rng, n), _vec(rng, n)
    xk, vk = KU.sgd_nesterov_step(x, v, g, jnp.float32([eta]),
                                  jnp.float32([delta]))
    xr, vr = ref.sgd_nesterov_step_ref(x, v, g, np.float32(eta),
                                       np.float32(delta))
    np.testing.assert_allclose(xk, xr, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(vk, vr, rtol=1e-6, atol=1e-6)


@settings(**SETTINGS)
@given(n=st.integers(1, 5000), alpha=st.floats(-1.0, 1.0),
       seed=st.integers(0, 2**31 - 1))
def test_elastic_exchange_matches_ref(n, alpha, seed):
    rng = np.random.default_rng(seed)
    x, c = _vec(rng, n), _vec(rng, n)
    xk, ck = KU.elastic_exchange(x, c, jnp.float32([alpha]))
    xr, cr = ref.elastic_exchange_ref(x, c, np.float32(alpha))
    np.testing.assert_allclose(xk, xr, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(ck, cr, rtol=1e-6, atol=1e-6)


def test_elastic_exchange_is_symmetric():
    """The elastic force is equal and opposite: x+c is invariant (§3.3)."""
    rng = np.random.default_rng(0)
    x, c = _vec(rng, 4096), _vec(rng, 4096)
    xk, ck = KU.elastic_exchange(x, c, jnp.float32([0.3]))
    np.testing.assert_allclose(np.asarray(xk) + np.asarray(ck),
                               np.asarray(x) + np.asarray(c),
                               rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(n=st.integers(1, 4096), eta=st.floats(0.0, 0.5),
       alpha=st.floats(0.0, 1.0), delta=st.floats(0.0, 0.999),
       do=st.sampled_from([0.0, 1.0]), seed=st.integers(0, 2**31 - 1))
def test_fused_step_matches_ref(n, eta, alpha, delta, do, seed):
    rng = np.random.default_rng(seed)
    x, v, g, c = (_vec(rng, n) for _ in range(4))
    out_k = KU.easgd_fused_step(x, v, g, c, jnp.float32([eta]),
                                jnp.float32([alpha]), jnp.float32([delta]),
                                jnp.float32([do]))
    out_r = ref.easgd_fused_step_ref(x, v, g, c, np.float32(eta),
                                     np.float32(alpha), np.float32(delta),
                                     np.float32(do))
    for got, want in zip(out_k, out_r):
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_fused_step_no_exchange_is_pure_sgd():
    rng = np.random.default_rng(7)
    x, v, g, c = (_vec(rng, 2048) for _ in range(4))
    x2, v2, d = KU.easgd_fused_step(
        x, v, g, c, jnp.float32([0.1]), jnp.float32([0.5]),
        jnp.float32([0.0]), jnp.float32([0.0]))
    xs, vs = ref.sgd_nesterov_step_ref(x, v, g, np.float32(0.1),
                                       np.float32(0.0))
    np.testing.assert_allclose(x2, xs, rtol=1e-6)
    np.testing.assert_allclose(d, np.zeros(2048, np.float32))


@pytest.mark.parametrize("b,h,t,dh", [(1, 1, 32, 8), (2, 2, 64, 16),
                                      (1, 4, 96, 32), (2, 1, 128, 64)])
def test_attention_matches_ref(b, h, t, dh):
    rng = np.random.default_rng(b * 1000 + t)
    q, k, v = (jnp.asarray(rng.standard_normal((b, h, t, dh)),
                           dtype=jnp.float32) for _ in range(3))
    scale = 1.0 / np.sqrt(dh)
    out = attention(q, k, v, scale)
    want = ref.attention_ref(q, k, v, scale)
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)


def test_attention_is_causal():
    """Future-token perturbations must not change earlier outputs."""
    rng = np.random.default_rng(3)
    t, dh = 64, 16
    q = jnp.asarray(rng.standard_normal((1, 1, t, dh)), dtype=jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 1, t, dh)), dtype=jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 1, t, dh)), dtype=jnp.float32)
    out1 = attention(q, k, v, 0.25)
    k2 = k.at[0, 0, -1].add(100.0)
    v2 = v.at[0, 0, -1].add(100.0)
    out2 = attention(q, k2, v2, 0.25)
    np.testing.assert_allclose(out1[0, 0, : t - 1], out2[0, 0, : t - 1],
                               rtol=1e-6, atol=1e-6)


def test_attention_grad_matches_ref_grad():
    """custom_vjp backward must equal the oracle's gradient."""
    rng = np.random.default_rng(11)
    shape = (2, 2, BQ, 8)
    q, k, v = (jnp.asarray(rng.standard_normal(shape), dtype=jnp.float32)
               for _ in range(3))

    def f_kernel(q, k, v):
        return jnp.sum(jnp.sin(attention(q, k, v, 0.35)))

    def f_ref(q, k, v):
        return jnp.sum(jnp.sin(ref.attention_ref(q, k, v, 0.35)))

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)
