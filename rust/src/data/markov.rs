//! Synthetic next-token corpus with learnable structure: a random
//! order-2 Markov chain over the vocabulary with temperature-controlled
//! concentration. A transformer LM can reach substantially below the
//! unigram entropy on this data, so loss curves are meaningful
//! (DESIGN.md §2 substitution for CIFAR/ImageNet).

use crate::rng::Rng;

/// A sampled order-2 Markov language over `vocab` tokens.
pub struct MarkovCorpus {
    vocab: usize,
    /// Cumulative transition rows, indexed by (prev2 * vocab + prev1).
    cumrows: Vec<Vec<f64>>,
    rng: Rng,
}

impl MarkovCorpus {
    /// `concentration` < 1 makes rows peaky (low entropy ⇒ learnable);
    /// each row is a Dirichlet-like draw built from Gamma variates.
    pub fn new(vocab: usize, concentration: f64, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut cumrows = Vec::with_capacity(vocab * vocab);
        for _ in 0..vocab * vocab {
            let mut row: Vec<f64> = (0..vocab)
                .map(|_| rng.gamma(concentration, 1.0))
                .collect();
            let sum: f64 = row.iter().sum();
            let mut acc = 0.0;
            for v in &mut row {
                acc += *v / sum;
                *v = acc;
            }
            *row.last_mut().unwrap() = 1.0;
            cumrows.push(row);
        }
        Self { vocab, cumrows, rng: rng.split(1) }
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// Keep the language (transition table) but replace the sampling
    /// stream — used to give p workers distinct draws from the SAME
    /// distribution (thesis §1.2).
    pub fn reseeded(mut self, seed: u64) -> Self {
        self.rng = Rng::new(seed);
        self
    }

    fn next_token(&mut self, p2: usize, p1: usize) -> usize {
        let row = &self.cumrows[p2 * self.vocab + p1];
        let u = self.rng.uniform();
        // Binary search the cumulative row.
        match row.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) => (i + 1).min(self.vocab - 1),
            Err(i) => i.min(self.vocab - 1),
        }
    }

    /// Sample a token sequence of length `len`.
    pub fn sample(&mut self, len: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(len);
        let (mut p2, mut p1) = (
            self.rng.below(self.vocab),
            self.rng.below(self.vocab),
        );
        for _ in 0..len {
            let t = self.next_token(p2, p1);
            out.push(t as i32);
            p2 = p1;
            p1 = t;
        }
        out
    }

    /// (inputs, targets) batch for next-token prediction:
    /// batch-major flat i32 buffers of shape [b, t].
    pub fn batch(&mut self, b: usize, t: usize) -> (Vec<i32>, Vec<i32>) {
        let mut xs = Vec::with_capacity(b * t);
        let mut ys = Vec::with_capacity(b * t);
        for _ in 0..b {
            let seq = self.sample(t + 1);
            xs.extend_from_slice(&seq[..t]);
            ys.extend_from_slice(&seq[1..]);
        }
        (xs, ys)
    }

    /// Empirical conditional entropy (nats) of the chain — the
    /// achievable LM loss floor.
    pub fn conditional_entropy(&self) -> f64 {
        let mut h = 0.0;
        let rows = self.cumrows.len();
        for row in &self.cumrows {
            let mut prev = 0.0;
            for &c in row {
                let p = c - prev;
                prev = c;
                if p > 1e-15 {
                    h -= p * p.ln();
                }
            }
        }
        h / rows as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_in_range_and_batch_shapes() {
        let mut c = MarkovCorpus::new(16, 0.2, 1);
        let (x, y) = c.batch(4, 32);
        assert_eq!(x.len(), 128);
        assert_eq!(y.len(), 128);
        assert!(x.iter().chain(&y).all(|&t| (0..16).contains(&t)));
    }

    #[test]
    fn targets_are_shifted_inputs() {
        let mut c = MarkovCorpus::new(8, 0.3, 2);
        let (x, y) = c.batch(1, 16);
        // y[i] is the token after x[i]; so x[1..] == y[..15].
        assert_eq!(&x[1..], &y[..15]);
    }

    #[test]
    fn low_concentration_gives_low_entropy() {
        let peaky = MarkovCorpus::new(32, 0.05, 3).conditional_entropy();
        let flat = MarkovCorpus::new(32, 50.0, 3).conditional_entropy();
        let uniform = (32f64).ln();
        assert!(peaky < 0.5 * uniform, "peaky {peaky} vs uniform {uniform}");
        assert!(flat > 0.9 * uniform, "flat {flat} vs uniform {uniform}");
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = MarkovCorpus::new(16, 0.2, 7);
        let mut b = MarkovCorpus::new(16, 0.2, 7);
        assert_eq!(a.sample(64), b.sample(64));
    }

    #[test]
    fn chain_visits_most_tokens() {
        let mut c = MarkovCorpus::new(16, 0.5, 9);
        let seq = c.sample(4000);
        let mut seen = vec![false; 16];
        for &t in &seq {
            seen[t as usize] = true;
        }
        assert!(seen.iter().filter(|&&s| s).count() >= 12);
    }
}
