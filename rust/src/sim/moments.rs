//! Closed-form moment analysis from thesis Chapters 3 and 5.
//!
//! Everything here is an exact transcription of the thesis' formulas;
//! the simulators in [`super::quadratic`] / [`super::multiplicative`]
//! cross-validate them empirically (and the unit tests cross-validate
//! the two against each other).

use crate::linalg::{spectral_radius, Matrix};

/// Parameters of the 1-d quadratic additive-noise model (§3.1.1):
/// gradient h·x − b with i.i.d. N(0, σ²) noise, p workers.
#[derive(Clone, Copy, Debug)]
pub struct QuadraticModel {
    pub h: f64,
    pub sigma: f64,
    pub p: usize,
}

/// γ and φ of Lemma 3.1.1 — the two roots of
/// λ² − (2−a)λ + (1 − a + c²), a = ηh + (p+1)α, c² = ηhpα.
pub fn gamma_phi(eta: f64, alpha: f64, h: f64, p: usize) -> (f64, f64) {
    let a = eta * h + (p as f64 + 1.0) * alpha;
    let c2 = eta * h * p as f64 * alpha;
    let disc = (a * a - 4.0 * c2).max(0.0).sqrt();
    let gamma = 1.0 - (a - disc) / 2.0;
    let phi = 1.0 - (a + disc) / 2.0;
    (gamma, phi)
}

/// Stability condition Eq 3.4: −1 < φ ≤ γ < 1.
pub fn easgd_stable(eta: f64, alpha: f64, h: f64, p: usize) -> bool {
    let (gamma, phi) = gamma_phi(eta, alpha, h, p);
    phi > -1.0 && gamma < 1.0 && phi <= gamma
}

/// Lemma 3.1.1: (bias, variance) of the center variable at step t with
/// x̃₀ = x₀ⁱ = x0 for all workers.
pub fn center_bias_variance(
    m: &QuadraticModel,
    eta: f64,
    beta: f64,
    x0: f64,
    t: u32,
) -> (f64, f64) {
    let p = m.p as f64;
    let alpha = beta / p;
    let (gamma, phi) = gamma_phi(eta, alpha, m.h, m.p);
    // u0 = Σ_i (x0^i − x* − α/(1−pα−φ)(x̃0 − x*)); x* folded out (we work
    // in centered coordinates, x0 already means x0 − x*).
    let u0 = p * x0 * (1.0 - alpha / (1.0 - p * alpha - phi));
    let tf = t as f64;
    let (g_t, f_t) = (gamma.powf(tf), phi.powf(tf));
    let denom = gamma - phi;
    let bias = if denom.abs() < 1e-14 {
        g_t * x0 + tf * gamma.powf(tf - 1.0) * alpha * u0
    } else {
        g_t * x0 + (g_t - f_t) / denom * alpha * u0
    };

    let geo = |r: f64, tt: f64| -> f64 {
        // (r² − r^{2t}) / (1 − r²), guarded for |r| ≥ 1 (divergence).
        if r.abs() >= 1.0 {
            f64::INFINITY
        } else {
            (r * r - r.powf(2.0 * tt)) / (1.0 - r * r)
        }
    };
    let cross = if (gamma * phi).abs() >= 1.0 {
        f64::INFINITY
    } else {
        (gamma * phi - (gamma * phi).powf(tf)) / (1.0 - gamma * phi)
    };
    let var = (p * alpha * eta / denom.max(1e-300)).powi(2)
        * (geo(gamma, tf) + geo(phi, tf) - 2.0 * cross)
        * (m.sigma * m.sigma / p);
    (bias, var)
}

/// Lemma 3.1.1 at t → ∞ (stationary MSE of the center variable).
pub fn center_mse_infinite(m: &QuadraticModel, eta: f64, beta: f64) -> f64 {
    let p = m.p as f64;
    let alpha = beta / p;
    if !easgd_stable(eta, alpha, m.h, m.p) {
        return f64::INFINITY;
    }
    let (gamma, phi) = gamma_phi(eta, alpha, m.h, m.p);
    // Closed form from Corollary 3.1.1's derivation:
    // β²η²/((1−γ²)(1−φ²)) · (1+γφ)/(1−γφ) · σ²/p.
    (beta * eta).powi(2) / ((1.0 - gamma * gamma) * (1.0 - phi * phi))
        * (1.0 + gamma * phi)
        / (1.0 - gamma * phi)
        * m.sigma
        * m.sigma
        / p
}

/// Corollary 3.1.1: lim_{p→∞} lim_{t→∞} p·E[(x̃_t − x*)²].
pub fn center_mse_limit_p_infinity(h: f64, sigma: f64, eta: f64, beta: f64) -> f64 {
    let eh = eta * h;
    beta * eh / ((2.0 - beta) * (2.0 - eh))
        * (2.0 - beta - eh + beta * eh)
        / (beta + eh - beta * eh)
        * sigma
        * sigma
        / (h * h)
}

/// MSE at step t (bias² + variance), Fig 3.1's plotted quantity.
pub fn center_mse(m: &QuadraticModel, eta: f64, beta: f64, x0: f64, t: u32) -> f64 {
    let alpha = beta / m.p as f64;
    if !easgd_stable(eta, alpha, m.h, m.p) {
        return f64::INFINITY;
    }
    let (b, v) = center_bias_variance(m, eta, beta, x0, t);
    b * b + v
}

// ---------------------------------------------------------------------
// Chapter 5, additive noise.
// ---------------------------------------------------------------------

/// Eq 5.6 — MSGD second-moment matrix M over (E v², E vx, E x²).
/// δ_h = δ(1−ηh), η_h = ηh.
pub fn msgd_moment_matrix(eta_h: f64, delta: f64) -> Matrix {
    let dh = delta * (1.0 - eta_h);
    Matrix::from_rows(&[
        &[dh * dh, -2.0 * dh * eta_h, eta_h * eta_h],
        &[dh * dh, dh * (1.0 - 2.0 * eta_h), -eta_h * (1.0 - eta_h)],
        &[dh * dh, 2.0 * dh * (1.0 - eta_h), (1.0 - eta_h) * (1.0 - eta_h)],
    ])
}

/// Eq 5.7 — asymptotic second moments of MSGD (v²∞, vx∞, x²∞), each in
/// units of η²σ².
pub fn msgd_asymptotic(eta_h: f64, delta: f64) -> (f64, f64, f64) {
    let dh = delta * (1.0 - eta_h);
    let d = (1.0 - dh) * (2.0 * (1.0 + dh) - eta_h);
    (2.0 / d, 1.0 / d, (1.0 + dh) / (eta_h * d))
}

/// The optimal momentum of §5.1.2: δ_h* = (√η_h − 1)², giving the
/// fastest second-moment convergence for fixed η_h.
pub fn msgd_optimal_delta_h(eta_h: f64) -> f64 {
    (eta_h.sqrt() - 1.0).powi(2)
}

/// Eq 5.12 — EASGD reduced-system second-moment matrix over
/// (E y², E yx̃, E x̃²).
pub fn easgd_reduced_moment_matrix(eta_h: f64, alpha: f64, beta: f64) -> Matrix {
    let q = 1.0 - eta_h - alpha;
    Matrix::from_rows(&[
        &[q * q, 2.0 * alpha * q, alpha * alpha],
        &[q * beta, q * (1.0 - beta) + alpha * beta, alpha * (1.0 - beta)],
        &[beta * beta, 2.0 * beta * (1.0 - beta), (1.0 - beta) * (1.0 - beta)],
    ])
}

/// Eqs 5.13–5.14: asymptotic (y²∞, yx̃∞, x̃²∞) in units of η²σ²/p.
pub fn easgd_asymptotic(eta_h: f64, alpha: f64, beta: f64) -> (f64, f64, f64) {
    let denom = eta_h
        * ((2.0 - beta) * (2.0 - eta_h) - 2.0 * alpha)
        * (alpha + beta + eta_h * (1.0 - beta));
    let y2 = ((2.0 - beta) * (1.0 - beta) * eta_h + beta * (2.0 - alpha - beta)) / denom;
    let yx = beta * ((2.0 - beta) * (1.0 - eta_h) - alpha) / denom;
    let x2 = (-beta * (1.0 - beta) * eta_h + beta * (2.0 - alpha - beta)) / denom;
    (y2, yx, x2)
}

/// §5.1.3: the optimal moving rate of the *reduced* system,
/// α* = −(√β − √η_h)² (Eq 5.17) — zero or negative.
pub fn easgd_optimal_alpha_reduced(eta_h: f64, beta: f64) -> f64 {
    -(beta.sqrt() - eta_h.sqrt()).powi(2)
}

/// §5.1.3 (Eq 5.19 analysis): optimal α for the *original* drift matrix
/// M_p — 0 when β > η_h, else −(√β − √η_h)².
pub fn easgd_optimal_alpha_original(eta_h: f64, beta: f64) -> f64 {
    if beta > eta_h {
        0.0
    } else {
        -(beta.sqrt() - eta_h.sqrt()).powi(2)
    }
}

/// Eq 5.18 — EASGD first-order drift matrix M_p ((p+1)×(p+1)),
/// β' = β/p. Eigenvalues are p-independent for p > 1 (thesis).
pub fn easgd_drift_matrix(eta_h: f64, alpha: f64, beta: f64, p: usize) -> Matrix {
    let n = p + 1;
    let mut m = Matrix::zeros(n, n);
    let bp = beta / p as f64;
    for i in 0..p {
        m.set(i, i, 1.0 - alpha - eta_h);
        m.set(i, p, alpha);
        m.set(p, i, bp);
    }
    m.set(p, p, 1.0 - beta);
    m
}

/// Eq 5.19 — the three distinct eigenvalues of M_p (p > 1):
/// z₁ = 1−α−η_h and the roots of (1−β−z)(1−α−η_h−z) = αβ.
pub fn easgd_drift_eigs(eta_h: f64, alpha: f64, beta: f64) -> (f64, f64, f64) {
    let z1 = 1.0 - alpha - eta_h;
    let b = 0.5 * (2.0 - beta - eta_h - alpha);
    let c = (1.0 - eta_h) * (1.0 - beta) - alpha;
    let disc = b * b - c;
    if disc >= 0.0 {
        (z1, b - disc.sqrt(), b + disc.sqrt())
    } else {
        // complex pair: report common modulus with sign of real part.
        let m = c.abs().sqrt();
        (z1, m, m)
    }
}

/// Eq 5.20 — EAMSGD first-order drift matrix ((2p+1)×(2p+1)) over
/// (v¹, x¹, …, vᵖ, xᵖ, x̃). δ_h = δ(1−η_h).
pub fn eamsgd_drift_matrix(
    eta_h: f64,
    alpha: f64,
    beta: f64,
    delta: f64,
    p: usize,
) -> Matrix {
    let n = 2 * p + 1;
    let mut m = Matrix::zeros(n, n);
    let dh = delta * (1.0 - eta_h);
    let bp = beta / p as f64;
    for i in 0..p {
        let (vi, xi) = (2 * i, 2 * i + 1);
        m.set(vi, vi, dh);
        m.set(vi, xi, -eta_h);
        m.set(xi, vi, dh);
        m.set(xi, xi, 1.0 - eta_h - alpha);
        m.set(xi, n - 1, alpha);
        m.set(n - 1, xi, bp);
    }
    m.set(n - 1, n - 1, 1.0 - beta);
    m
}

// ---------------------------------------------------------------------
// Chapter 5, multiplicative noise (input u² ~ Γ(λ, ω)).
// ---------------------------------------------------------------------

/// Eq 5.26 — mini-batch SGD second-moment contraction rate
/// 1 − 2η λ/ω + η² λ(pλ+1)/(p ω²).
pub fn minibatch_sgd_rate(eta: f64, lambda: f64, omega: f64, p: usize) -> f64 {
    let pf = p as f64;
    1.0 - 2.0 * eta * lambda / omega
        + eta * eta * lambda * (pf * lambda + 1.0) / (pf * omega * omega)
}

/// Eq 5.27 — optimal learning rate η_p = ω / (λ + 1/p).
pub fn minibatch_optimal_eta(lambda: f64, omega: f64, p: usize) -> f64 {
    omega / (lambda + 1.0 / p as f64)
}

/// Γ(λ, ω) pdf (rate parameterization) — Fig 5.9.
pub fn gamma_pdf(x: f64, lambda: f64, omega: f64) -> f64 {
    if x <= 0.0 {
        return 0.0;
    }
    (lambda * omega.ln() + (lambda - 1.0) * x.ln() - omega * x - ln_gamma(lambda)).exp()
}

/// Lanczos log-gamma (g = 7, n = 9 coefficients).
pub fn ln_gamma(x: f64) -> f64 {
    const G: f64 = 7.0;
    const C: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_572e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // reflection
        return (std::f64::consts::PI / (std::f64::consts::PI * x).sin()).ln()
            - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = C[0];
    let t = x + G + 0.5;
    for (i, &c) in C.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// Eq 5.30 — MSGD multiplicative-noise second-moment matrix over
/// (E v², E x², E vx). u₁ = λ/ω, u₂ = λ(λ+1)/ω².
pub fn msgd_mult_moment_matrix(eta: f64, delta: f64, lambda: f64, omega: f64) -> Matrix {
    let u1 = lambda / omega;
    let u2 = lambda * (lambda + 1.0) / (omega * omega);
    let q = 1.0 - 2.0 * eta * u1 + eta * eta * u2; // E (1−ηξ)²
    let r = eta * (u1 - eta * u2); // E ηξ(1−ηξ)... sign folded below
    let d2q = delta * delta * q;
    Matrix::from_rows(&[
        &[d2q, eta * eta * u2, -2.0 * delta * r],
        &[d2q, q, 2.0 * delta * (1.0 - eta * u1) - 2.0 * delta * r],
        &[d2q, -eta * u1 + eta * eta * u2, delta * (1.0 - eta * u1) - 2.0 * delta * r],
    ])
}

/// Mini-batched input: Γ(pλ, pω) has the same mean and 1/p the variance.
pub fn msgd_mult_moment_matrix_minibatch(
    eta: f64,
    delta: f64,
    lambda: f64,
    omega: f64,
    p: usize,
) -> Matrix {
    let pf = p as f64;
    msgd_mult_moment_matrix(eta, delta, pf * lambda, pf * omega)
}

/// Eq 5.34 — EASGD multiplicative-noise second-moment matrix over
/// (a, b, c, d) = (E x̃², mean E (xⁱ)², mean E x̃xⁱ, mean E xⁱxʲ).
pub fn easgd_mult_moment_matrix(
    eta: f64,
    alpha: f64,
    beta: f64,
    lambda: f64,
    omega: f64,
    p: usize,
) -> Matrix {
    let u1 = lambda / omega;
    let u2 = lambda / (omega * omega); // Var ξ = λ/ω²
    let q = 1.0 - alpha - eta * u1; // E (1−α−ηξ)
    let q2 = q * q + eta * eta * u2; // E (1−α−ηξ)²
    let pf = p as f64;
    Matrix::from_rows(&[
        &[
            (1.0 - beta) * (1.0 - beta),
            0.0,
            2.0 * beta * (1.0 - beta),
            beta * beta,
        ],
        &[alpha * alpha, q2, 2.0 * alpha * q, 0.0],
        &[
            alpha * (1.0 - beta),
            0.0,
            (1.0 - beta) * q + alpha * beta,
            q * beta,
        ],
        &[
            alpha * alpha,
            eta * eta * u2 / pf,
            2.0 * alpha * q,
            q * q, // independent ξⁱ, ξʲ across workers: E ξⁱξʲ = u1²
        ],
    ])
}

/// §5.2.3 Case II: the p→∞ optimal moving rate α = 1 − √λ and the
/// stability edge η < ω/√λ.
pub fn easgd_mult_optimal_alpha(lambda: f64) -> f64 {
    1.0 - lambda.sqrt()
}

pub fn easgd_mult_stability_edge(lambda: f64, omega: f64) -> f64 {
    omega / lambda.sqrt()
}

/// Spectral radius helper used by every figure sweep.
pub fn sp(m: &Matrix) -> f64 {
    spectral_radius(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    const EPS: f64 = 1e-9;

    #[test]
    fn gamma_phi_are_roots_of_the_quadratic() {
        let (eta, alpha, h, p) = (0.1, 0.05, 1.0, 4usize);
        let a = eta * h + (p as f64 + 1.0) * alpha;
        let c2 = eta * h * p as f64 * alpha;
        let (g, f) = gamma_phi(eta, alpha, h, p);
        for z in [g, f] {
            let val = z * z - (2.0 - a) * z + (1.0 - a + c2);
            assert!(val.abs() < EPS, "root residual {val}");
        }
        assert!(f <= g);
    }

    #[test]
    fn mse_decreases_with_more_workers() {
        // The crux of Corollary 3.1.1: stationary MSE is O(1/p).
        let eta = 0.1;
        let beta = 0.5;
        let mut last = f64::INFINITY;
        for p in [1usize, 10, 100, 1000] {
            let m = QuadraticModel { h: 1.0, sigma: 10.0, p };
            let v = center_mse_infinite(&m, eta, beta);
            assert!(v < last, "p={p}: {v} !< {last}");
            last = v;
        }
    }

    #[test]
    fn mse_infinite_matches_corollary_at_large_p() {
        let (h, sigma, eta, beta) = (1.0, 10.0, 0.1, 0.5);
        let p = 100_000usize;
        let m = QuadraticModel { h, sigma, p };
        let lhs = p as f64 * center_mse_infinite(&m, eta, beta);
        let rhs = center_mse_limit_p_infinity(h, sigma, eta, beta);
        assert!((lhs - rhs).abs() / rhs < 1e-3, "{lhs} vs {rhs}");
    }

    #[test]
    fn unstable_settings_return_infinity() {
        let m = QuadraticModel { h: 1.0, sigma: 10.0, p: 1 };
        // η h = 3.9, β = 3.9 violates Eq 3.4.
        assert!(center_mse(&m, 3.9, 3.9, 1.0, 100).is_infinite());
    }

    #[test]
    fn msgd_asymptotic_solves_fixed_point() {
        let (eta_h, delta) = (0.3, 0.6);
        let m = msgd_moment_matrix(eta_h, delta);
        let (v2, vx, x2) = msgd_asymptotic(eta_h, delta);
        let w = m.matvec(&[v2, vx, x2]);
        // Fixed point: w + (1,1,1) (units of η²σ²) = state.
        assert!((w[0] + 1.0 - v2).abs() < 1e-9);
        assert!((w[1] + 1.0 - vx).abs() < 1e-9);
        assert!((w[2] + 1.0 - x2).abs() < 1e-9);
    }

    #[test]
    fn msgd_optimal_delta_minimizes_spectral_radius() {
        let eta_h = 0.25;
        let best_dh = msgd_optimal_delta_h(eta_h);
        let to_delta = |dh: f64| dh / (1.0 - eta_h);
        // At δ_h* the matrix has a defective triple eigenvalue δ_h*;
        // QR accuracy there degrades to ~ε^(1/3), so compare loosely.
        let sp_best = sp(&msgd_moment_matrix(eta_h, to_delta(best_dh)));
        assert!((sp_best - best_dh).abs() < 1e-3,
                "min value should be δ_h*={best_dh}, got {sp_best}");
        for dh in [-0.5, 0.0, 0.3, 0.8] {
            let s = sp(&msgd_moment_matrix(eta_h, to_delta(dh)));
            assert!(s >= sp_best - 1e-3, "δ_h={dh}: {s} < {sp_best}");
        }
    }

    #[test]
    fn momentum_increases_asymptotic_variance_in_0_1_region() {
        // §5.1.2: in η_h ∈ (0,1), δ_h ∈ (0,1), MSGD variance > SGD's.
        for &eta_h in &[0.1, 0.5, 0.9] {
            let (.., x2_sgd) = msgd_asymptotic(eta_h, 0.0);
            for &delta in &[0.3, 0.6, 0.9] {
                let dh = delta * (1.0 - eta_h);
                if dh <= 0.0 || dh >= 1.0 {
                    continue;
                }
                let (.., x2_m) = msgd_asymptotic(eta_h, delta);
                assert!(x2_m > x2_sgd, "η_h={eta_h} δ={delta}");
            }
        }
    }

    #[test]
    fn easgd_asymptotic_solves_fixed_point() {
        let (eta_h, alpha, beta) = (0.2, 0.1, 0.9);
        let m = easgd_reduced_moment_matrix(eta_h, alpha, beta);
        let st = easgd_asymptotic(eta_h, alpha, beta);
        let w = m.matvec(&[st.0, st.1, st.2]);
        // Forcing is (1, 0, 0) in units of η²σ²/p.
        assert!((w[0] + 1.0 - st.0).abs() < 1e-9, "{:?}", st);
        assert!((w[1] - st.1).abs() < 1e-9);
        assert!((w[2] - st.2).abs() < 1e-9);
    }

    #[test]
    fn center_variance_below_spatial_average_for_beta_below_one() {
        // §5.1.3: x̃²∞ < y²∞ iff 0 < β < 1, reversed for β > 1.
        let (y2, _, x2) = easgd_asymptotic(0.2, 0.05, 0.5);
        assert!(x2 < y2);
        let (y2b, _, x2b) = easgd_asymptotic(0.2, 0.05, 1.5);
        assert!(x2b > y2b);
    }

    #[test]
    fn drift_eigs_match_matrix_eigs_and_are_p_independent() {
        let (eta_h, alpha, beta) = (0.3, 0.15, 0.9);
        let (z1, z2, z3) = easgd_drift_eigs(eta_h, alpha, beta);
        for p in [2usize, 3, 8] {
            let m = easgd_drift_matrix(eta_h, alpha, beta, p);
            let mut mags: Vec<f64> = crate::linalg::eigenvalues(&m)
                .iter()
                .map(|z| z.abs())
                .collect();
            mags.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let mut want = vec![z1.abs(), z2.abs(), z3.abs()];
            want.sort_by(|a, b| b.partial_cmp(a).unwrap());
            // Largest magnitudes must agree (z1 has multiplicity p−1).
            assert!((mags[0] - want[0]).abs() < 1e-8, "p={p}");
        }
    }

    #[test]
    fn easgd_optimal_alpha_negative_when_beta_below_eta() {
        // §5.1.3: β < η_h ⇒ α* = −(√β−√η_h)² < 0; β > η_h ⇒ α* = 0.
        assert!(easgd_optimal_alpha_original(1.5, 0.9) < 0.0);
        assert_eq!(easgd_optimal_alpha_original(0.1, 0.9), 0.0);
        // And the optimum beats the elastic choice α = β/p on sp(M_p).
        let (eta_h, beta, p) = (1.5, 0.9, 4usize);
        let a_star = easgd_optimal_alpha_original(eta_h, beta);
        let sp_star = sp(&easgd_drift_matrix(eta_h, a_star, beta, p));
        let sp_elastic = sp(&easgd_drift_matrix(eta_h, beta / p as f64, beta, p));
        assert!(sp_star < sp_elastic, "{sp_star} vs {sp_elastic}");
    }

    #[test]
    fn minibatch_rate_monotone_in_p_and_saturates() {
        let (eta, l, w) = (0.3, 0.5, 0.5);
        let mut last = f64::INFINITY;
        for p in [1usize, 2, 4, 8, 1024] {
            let r = minibatch_sgd_rate(eta, l, w, p);
            assert!(r <= last + 1e-12);
            last = r;
        }
        let sat = (1.0 - eta * l / w).powi(2);
        assert!((last - sat).abs() < 1e-3);
    }

    #[test]
    fn minibatch_optimal_eta_minimizes_rate() {
        let (l, w, p) = (0.5, 0.5, 4usize);
        let e_star = minibatch_optimal_eta(l, w, p);
        let r_star = minibatch_sgd_rate(e_star, l, w, p);
        for de in [-0.1, -0.01, 0.01, 0.1] {
            assert!(minibatch_sgd_rate(e_star + de, l, w, p) >= r_star);
        }
    }

    #[test]
    fn gamma_pdf_integrates_to_one() {
        for &(l, w) in &[(0.5, 0.5), (1.0, 1.0), (2.0, 2.0)] {
            let mut s = 0.0;
            let dx = 1e-3;
            let mut x = dx / 2.0;
            while x < 60.0 {
                s += gamma_pdf(x, l, w) * dx;
                x += dx;
            }
            assert!((s - 1.0).abs() < 1e-2, "Γ({l},{w}) mass {s}");
        }
    }

    #[test]
    fn ln_gamma_known_values() {
        assert!((ln_gamma(1.0)).abs() < 1e-10);
        assert!((ln_gamma(2.0)).abs() < 1e-10);
        assert!((ln_gamma(5.0) - 24.0f64.ln()).abs() < 1e-9);
        assert!((ln_gamma(0.5) - 0.5 * std::f64::consts::PI.ln()).abs() < 1e-9);
    }

    #[test]
    fn mult_sgd_rate_is_sp_of_moment_matrix_at_delta_zero() {
        // With δ=0, the (x²) row of Eq 5.30 decouples: rate = q.
        let (eta, l, w) = (0.4, 1.0, 1.0);
        let m = msgd_mult_moment_matrix(eta, 0.0, l, w);
        let q = minibatch_sgd_rate(eta, l, w, 1);
        assert!((sp(&m) - q.abs()).abs() < 1e-8);
    }

    #[test]
    fn easgd_mult_momentless_optimum_beats_msgd_figures_claim() {
        // §5.2.3 Case I numbers: λ=ω=0.5 → sp≈0.5742 at p=6, η=0.3814
        // (vs MSGD 2/3). We verify our matrix reproduces ≈0.574.
        let m = easgd_mult_moment_matrix(0.3814, 0.9 / 6.0, 0.9, 0.5, 0.5, 6);
        let s = sp(&m);
        assert!((s - 0.5742).abs() < 0.02, "sp={s}");
        assert!(s < 2.0 / 3.0);
    }

    #[test]
    fn easgd_mult_stability_edge_formula() {
        assert!((easgd_mult_optimal_alpha(0.5) - (1.0 - 0.5f64.sqrt())).abs() < 1e-12);
        assert!((easgd_mult_stability_edge(0.5, 0.5) - 0.5 / 0.5f64.sqrt()).abs() < 1e-12);
    }
}
