//! EASGD Tree at scale (thesis Chapter 6): d-ary tree of workers with
//! fully-asynchronous parameter messaging, comparing the two §6.1
//! communication schemes on the synthetic CIFAR-like task — on either
//! executor backend.
//!
//!     cargo run --release --example tree_scale -- [leaves=64] [degree=8] \
//!         [eta=0.15] [delta=0] [horizon=25] [backend=sim|thread]
//!
//! Thesis scale is leaves=256 degree=16 (use those for the full run).
//! With backend=thread the horizon is REAL seconds (default 25 is a
//! long run — pass e.g. horizon=5) and every tree node is an OS thread.

use elastic_train::cluster::CostModel;
use elastic_train::config::Args;
use elastic_train::coordinator::{
    run_with_backend_topology, Backend, DriverConfig, Method, MlpOracle, Topology, TreeScheme,
    TreeSpec,
};
use elastic_train::data::BlobDataset;
use elastic_train::model::MlpConfig;
use std::sync::Arc;

fn main() -> elastic_train::error::Result<()> {
    let args = Args::from_env();
    let leaves = args.get_usize("leaves", 64)?;
    let degree = args.get_usize("degree", 8)?;
    let eta = args.get_f32("eta", 0.15)?;
    let delta = args.get_f32("delta", 0.0)?;
    let horizon = args.get_f64("horizon", 25.0)?;
    let backend_str = args.get_str("backend", "sim");
    let backend = Backend::parse(backend_str).unwrap_or_else(|| {
        eprintln!("error: unknown backend '{backend_str}' (sim|thread)");
        std::process::exit(2);
    });

    let data = Arc::new(BlobDataset::generate(32, 10, 4096, 512, 2.2, 1));
    let mcfg = MlpConfig::new(&[32, 64, 32, 10], 1e-4);
    let cost = CostModel::cifar_like(mcfg.n_params());
    let alpha = 0.9 / (degree as f32 + 1.0);
    let method = if delta > 0.0 {
        Method::Eamsgd { alpha, tau: 1, delta }
    } else {
        Method::Easgd { alpha, tau: 1 }
    };

    for (name, scheme) in [
        ("scheme-1 multi-scale (τ1=1, τ2=10)", TreeScheme::MultiScale { tau1: 1, tau2: 10 }),
        ("scheme-2 up/down    (τu=1, τd=10)", TreeScheme::UpDown { tau_up: 1, tau_down: 10 }),
    ] {
        let mut oracles = MlpOracle::family(data.clone(), &mcfg, 16, leaves);
        let topo = Topology::Tree(TreeSpec::new(degree, scheme));
        let cfg = DriverConfig {
            eta,
            method,
            cost,
            horizon,
            eval_every: horizon / 10.0,
            seed: args.get_u64("seed", 0)?,
            max_steps: u64::MAX / 2,
            lr_decay_gamma: 0.0,
        };
        let t0 = std::time::Instant::now();
        let r = match run_with_backend_topology(backend, &mut oracles, &cfg, &topo) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("error: {e}");
                std::process::exit(1);
            }
        };
        println!(
            "== {name}: p={leaves}, d={degree}, α=0.9/(d+1), η={eta}, δ={delta}, {} backend",
            backend.name()
        );
        println!("  t[s]    train_loss  test_err");
        for pt in &r.curve {
            println!("  {:<6.1}  {:<10.4}  {:.3}", pt.time, pt.train_loss, pt.test_error);
        }
        println!(
            "  {} leaf steps, {:.1}s wall, best test err {:.3}{}\n",
            r.total_steps,
            t0.elapsed().as_secs_f64(),
            r.best_test_error(),
            if r.diverged { "  [DIVERGED]" } else { "" }
        );
    }
    Ok(())
}
