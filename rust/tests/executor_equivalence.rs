//! Executor-equivalence suite: the virtual-time simulator and the
//! real-thread backend are different machines running the SAME
//! optimization — on a deterministic objective they must land in the
//! same place, the simulator must stay bitwise reproducible, and the
//! elastic fixed point must sit where the symmetric forces say it does.

use elastic_train::cluster::CostModel;
use elastic_train::coordinator::{
    run_process, DriverConfig, Executor, Method, MlpOracle, OracleSpec, ProcessOpts,
    QuadraticOracle, SimExecutor, ThreadExecutor,
};
use elastic_train::data::BlobDataset;
use elastic_train::model::{flat, MlpConfig};
use elastic_train::rng::Rng;
use std::sync::Arc;

fn fast_cost(n_params: usize) -> CostModel {
    CostModel {
        t_grad: 1e-3,
        jitter: 0.0, // synchronous: no compute jitter
        t_data: 0.0,
        latency: 1e-5,
        bandwidth: 1e12,
        param_bytes: (n_params * 4) as f64,
    }
}

/// (a) Synchronous EASGD (τ=1, jitter=0) on the quadratic objective:
/// both executors must reach the same loss within 1e-4. The quadratic
/// is deterministic and strongly convex, so every interleaving
/// contracts to the same fixed point (workers = center = target); the
/// tolerance absorbs f32 rounding along the two different paths.
#[test]
fn thread_matches_sim_on_quadratic_easgd() {
    let (n, p, steps) = (512usize, 4usize, 20_000u64);
    let method = Method::easgd_default(p, 1);

    let mut sim_oracles = QuadraticOracle::family(n, 1.0, 0.0, 1.0, 0.0, p);
    let sim_cfg = DriverConfig {
        eta: 0.1,
        method,
        cost: fast_cost(n),
        horizon: 1e6, // steps bound first
        eval_every: 1e6,
        seed: 11,
        max_steps: steps,
        lr_decay_gamma: 0.0,
    };
    let sim = SimExecutor.run(&mut sim_oracles, &sim_cfg).unwrap();

    let mut thr_oracles = QuadraticOracle::family(n, 1.0, 0.0, 1.0, 0.0, p);
    let thr_cfg = DriverConfig {
        horizon: 60.0, // REAL seconds safety net; steps bound first
        ..sim_cfg.clone()
    };
    let thr = ThreadExecutor::default().run(&mut thr_oracles, &thr_cfg).unwrap();

    assert!(!sim.diverged && !thr.diverged);
    assert_eq!(sim.total_steps, steps);
    assert_eq!(thr.total_steps, steps);
    let ls = sim.curve.last().unwrap().train_loss;
    let lt = thr.curve.last().unwrap().train_loss;
    // Both at the optimum (loss 0 for ½(θ−1)² from θ=0)...
    assert!(ls < 1e-6, "sim final loss {ls}");
    assert!(lt < 1e-6, "thread final loss {lt}");
    // ...and within the required tolerance of each other.
    assert!((ls - lt).abs() < 1e-4, "sim {ls} vs thread {lt}");
}

/// Hybrid parallelism pin: with `threads=2` GEMM helpers per worker,
/// ALL THREE backends (virtual-time sim, real threads, real processes
/// over sockets) still agree on the EASGD final loss. The threaded
/// kernels are bitwise-identical to serial by construction (MR-aligned
/// row panels, same accumulation order), so enabling the pool must not
/// move any backend; the process leg additionally exercises the
/// `threads=` forwarding through the worker CLI. The knob is
/// process-global, which is safe to flip here precisely BECAUSE of
/// that bitwise identity: concurrently running tests see identical
/// numerics either way.
#[test]
fn backends_agree_with_hybrid_threads_enabled() {
    elastic_train::linalg::pool::configure_threads(2);

    let (n, p, steps) = (512usize, 4usize, 8_000u64);
    let method = Method::easgd_default(p, 4);
    let sim_cfg = DriverConfig {
        eta: 0.1,
        method,
        cost: fast_cost(n),
        horizon: 1e6, // steps bound first
        eval_every: 1e6,
        seed: 43,
        max_steps: steps,
        lr_decay_gamma: 0.0,
    };
    let mk = || QuadraticOracle::family(n, 1.0, 0.0, 1.0, 0.0, p);
    let sim = SimExecutor.run(&mut mk(), &sim_cfg).unwrap();

    let thr_cfg = DriverConfig { horizon: 60.0, ..sim_cfg.clone() };
    let thr = ThreadExecutor::default().run(&mut mk(), &thr_cfg).unwrap();

    // Process leg: real worker processes, each told `threads=2` on its
    // command line (the same plumbing `repro train backend=process`
    // uses), rebuilding the oracle from the spec.
    let spec = OracleSpec::Quadratic { n, h: 1.0, x0: 0.0, target: 1.0, noise: 0.0 };
    let opts = ProcessOpts {
        exe: Some(std::path::PathBuf::from(env!("CARGO_BIN_EXE_repro"))),
        threads: 2,
        ..ProcessOpts::default()
    };
    let prc = run_process(&spec, p, &thr_cfg, &opts).unwrap();

    assert!(!sim.diverged && !thr.diverged && !prc.diverged);
    assert_eq!(sim.total_steps, steps);
    assert_eq!(thr.total_steps, steps);
    assert_eq!(prc.total_steps, steps);
    let ls = sim.curve.last().unwrap().train_loss;
    let lt = thr.curve.last().unwrap().train_loss;
    let lp = prc.curve.last().unwrap().train_loss;
    assert!(ls < 1e-6, "sim final loss {ls}");
    assert!(lt < 1e-6, "thread final loss {lt}");
    assert!(lp < 1e-6, "process final loss {lp}");
    assert!((ls - lt).abs() < 1e-4, "sim {ls} vs thread {lt}");
    assert!((ls - lp).abs() < 1e-4, "sim {ls} vs process {lp}");

    // Also run the REAL GEMM model through the thread backend with the
    // pool live: p worker threads each lazily build their own 2-helper
    // pool (thread-local), and the run must converge exactly as a
    // serial run would (bitwise-identical gradients).
    let data = Arc::new(BlobDataset::generate(32, 10, 1024, 128, 0.8, 7));
    let mcfg = MlpConfig::new(&[32, 64, 10], 1e-4);
    let mlp_cfg = DriverConfig {
        eta: 0.05,
        method: Method::easgd_default(p, 4),
        cost: fast_cost(mcfg.n_params()),
        horizon: 60.0,
        eval_every: 1e6,
        seed: 43,
        max_steps: 1_200,
        lr_decay_gamma: 0.0,
    };
    let mut oracles = MlpOracle::family(data, &mcfg, 128, p);
    let mlp = ThreadExecutor::default().run(&mut oracles, &mlp_cfg).unwrap();
    assert!(!mlp.diverged);
    assert_eq!(mlp.total_steps, 1_200);
    let lm = mlp.curve.last().unwrap().train_loss;
    assert!(lm.is_finite() && lm < 2.5, "threaded-GEMM MLP loss {lm}");

    elastic_train::linalg::pool::configure_threads(1);
}

/// Same equivalence on a *noisy* quadratic: the stationary center MSE
/// is interleaving-independent, so the two backends' final losses agree
/// to the noise floor (looser tolerance than the deterministic case).
#[test]
fn thread_matches_sim_on_noisy_quadratic_within_noise_floor() {
    let (n, p, steps) = (256usize, 4usize, 40_000u64);
    let method = Method::easgd_default(p, 1);
    let mk = || QuadraticOracle::family(n, 1.0, 0.0, 1.0, 0.05, p);

    let cfg = DriverConfig {
        eta: 0.1,
        method,
        cost: fast_cost(n),
        horizon: 1e6,
        eval_every: 1e6,
        seed: 17,
        max_steps: steps,
        lr_decay_gamma: 0.0,
    };
    let sim = SimExecutor.run(&mut mk(), &cfg).unwrap();
    let thr_cfg = DriverConfig { horizon: 60.0, ..cfg.clone() };
    let thr = ThreadExecutor::default().run(&mut mk(), &thr_cfg).unwrap();

    assert!(!sim.diverged && !thr.diverged);
    let ls = sim.curve.last().unwrap().train_loss;
    let lt = thr.curve.last().unwrap().train_loss;
    // Stationary loss ≈ ½·E(θ−1)² per coordinate: tiny but nonzero;
    // the two backends must agree on its scale.
    assert!(ls > 0.0 && lt > 0.0);
    assert!(ls < 1e-3 && lt < 1e-3, "sim {ls} thread {lt}");
}

/// Same sim ⇄ thread agreement for MDOWNPOUR — a master-COUPLED
/// method, which the thread backend runs through the master-actor
/// thread (serialized Gauss–Seidel application of every gradient
/// push). On the deterministic quadratic both machines must drive the
/// center to the optimum.
#[test]
fn thread_matches_sim_on_quadratic_mdownpour() {
    let (n, p, steps) = (128usize, 4usize, 20_000u64);
    let method = Method::MDownpour { delta: 0.9 };
    let mk = || QuadraticOracle::family(n, 1.0, 0.0, 1.0, 0.0, p);

    let cfg = DriverConfig {
        eta: 0.01, // master momentum amplifies: small lr (thesis §4.2)
        method,
        cost: fast_cost(n),
        horizon: 1e6,
        eval_every: 1e6,
        seed: 29,
        max_steps: steps,
        lr_decay_gamma: 0.0,
    };
    let sim = SimExecutor.run(&mut mk(), &cfg).unwrap();
    let thr_cfg = DriverConfig { horizon: 60.0, ..cfg.clone() };
    let thr = ThreadExecutor::default().run(&mut mk(), &thr_cfg).unwrap();

    assert!(!sim.diverged && !thr.diverged);
    assert_eq!(sim.total_steps, steps);
    assert_eq!(thr.total_steps, steps);
    // MDOWNPOUR is τ=1: every local step is one serialized master round.
    assert_eq!(thr.rounds, steps);
    let ls = sim.curve.last().unwrap().train_loss;
    let lt = thr.curve.last().unwrap().train_loss;
    assert!(ls < 1e-5, "sim final loss {ls}");
    assert!(lt < 1e-5, "thread final loss {lt}");
    assert!((ls - lt).abs() < 1e-4, "sim {ls} vs thread {lt}");
}

/// Same agreement for async ADMM: dual ascent + serialized consensus
/// mean at the master actor. The quadratic's ADMM fixed point is
/// exactly the optimum (λ = 0, center = target), so both backends
/// must land there.
#[test]
fn thread_matches_sim_on_quadratic_admm() {
    let (n, p, steps) = (128usize, 4usize, 24_000u64);
    let method = Method::AdmmAsync { rho: 1.0, tau: 4 };
    let mk = || QuadraticOracle::family(n, 1.0, 0.0, 1.0, 0.0, p);

    let cfg = DriverConfig {
        eta: 0.05,
        method,
        cost: fast_cost(n),
        horizon: 1e6,
        eval_every: 1e6,
        seed: 31,
        max_steps: steps,
        lr_decay_gamma: 0.0,
    };
    let sim = SimExecutor.run(&mut mk(), &cfg).unwrap();
    let thr_cfg = DriverConfig { horizon: 60.0, ..cfg.clone() };
    let thr = ThreadExecutor::default().run(&mut mk(), &thr_cfg).unwrap();

    assert!(!sim.diverged && !thr.diverged);
    assert_eq!(sim.total_steps, steps);
    assert_eq!(thr.total_steps, steps);
    assert!(thr.rounds > 0);
    let ls = sim.curve.last().unwrap().train_loss;
    let lt = thr.curve.last().unwrap().train_loss;
    assert!(ls < 1e-5, "sim final loss {ls}");
    assert!(lt < 1e-5, "thread final loss {lt}");
    assert!((ls - lt).abs() < 1e-4, "sim {ls} vs thread {lt}");
}

/// Regression for the `t_local == 0` fix: the thread backend performs
/// NO communication round before the first gradient step, so
/// ADOWNPOUR's 1/t master clock counts exactly the data-carrying
/// rounds. With one worker and τ=1 that is max_steps − 1 (it was
/// max_steps before the fix — one spurious no-op round); with p
/// workers each skips its own zeroth round.
#[test]
fn adownpour_thread_clock_has_no_spurious_zeroth_rounds() {
    let steps = 500u64;
    let cfg = DriverConfig {
        eta: 0.05,
        method: Method::ADownpour { tau: 1 },
        cost: fast_cost(64),
        horizon: 60.0,
        eval_every: 1e6,
        seed: 37,
        max_steps: steps,
        lr_decay_gamma: 0.0,
    };
    // p = 1: exact pin.
    let mut one = QuadraticOracle::family(64, 1.0, 0.0, 1.0, 0.0, 1);
    let r = ThreadExecutor::default().run(&mut one, &cfg).unwrap();
    assert!(!r.diverged);
    assert_eq!(r.total_steps, steps);
    assert_eq!(r.rounds, steps - 1);
    // p = 3: every worker that ran skips one round (a worker that the
    // scheduler never started before the budget ran out skips none).
    let p = 3u64;
    let mut fam = QuadraticOracle::family(64, 1.0, 0.0, 1.0, 0.0, p as usize);
    let r = ThreadExecutor::default().run(&mut fam, &cfg).unwrap();
    assert_eq!(r.total_steps, steps);
    assert!(
        r.rounds >= steps - p && r.rounds < steps,
        "rounds {} for {} steps, p={p}",
        r.rounds,
        steps
    );
}

/// (b) The simulator is bitwise deterministic: two runs with the same
/// seed produce identical step counts and identical curves (every
/// field, exact float equality).
#[test]
fn sim_executor_is_bitwise_deterministic() {
    let run = || {
        let data = Arc::new(BlobDataset::generate(8, 4, 1024, 256, 0.8, 1));
        let mcfg = MlpConfig::new(&[8, 16, 4], 1e-4);
        let mut oracles = MlpOracle::family(data, &mcfg, 32, 4);
        let cfg = DriverConfig {
            eta: 0.1,
            method: Method::easgd_default(4, 4),
            cost: CostModel {
                t_grad: 1e-3,
                jitter: 0.1,
                t_data: 1e-4,
                latency: 1e-4,
                bandwidth: 1e9,
                param_bytes: 1000.0,
            },
            horizon: 0.6,
            eval_every: 0.1,
            seed: 23,
            max_steps: 1_000_000,
            lr_decay_gamma: 0.0,
        };
        SimExecutor.run(&mut oracles, &cfg).unwrap()
    };
    let (a, b) = (run(), run());
    assert_eq!(a.total_steps, b.total_steps);
    assert_eq!(a.curve.len(), b.curve.len());
    for (pa, pb) in a.curve.iter().zip(&b.curve) {
        assert_eq!(pa.time, pb.time);
        assert_eq!(pa.train_loss, pb.train_loss);
        assert_eq!(pa.test_loss, pb.test_loss);
        assert_eq!(pa.test_error, pb.test_error);
    }
}

/// (c) Under symmetric elastic forces with zero gradient, the fixed
/// point of repeated worker↔center exchanges is consensus at the
/// conserved mean: center = worker average = Σ(x_i) + c over p+1.
#[test]
fn elastic_fixed_point_is_worker_average() {
    let (n, p) = (64usize, 5usize);
    let mut rng = Rng::new(41);
    let mut workers: Vec<Vec<f32>> = (0..p)
        .map(|_| {
            let mut v = vec![0.0f32; n];
            rng.fill_gaussian_f32(&mut v, 2.0);
            v
        })
        .collect();
    let mut center = vec![0.0f32; n];
    rng.fill_gaussian_f32(&mut center, 2.0);

    // Conserved quantity: per-coordinate sum over workers + center.
    let conserved: Vec<f64> = (0..n)
        .map(|j| workers.iter().map(|w| w[j] as f64).sum::<f64>() + center[j] as f64)
        .collect();

    for _ in 0..2000 {
        for w in &mut workers {
            flat::elastic_exchange(w, &mut center, 0.3);
        }
    }

    for j in 0..n {
        let mean = workers.iter().map(|w| w[j] as f64).sum::<f64>() / p as f64;
        let fixed = conserved[j] / (p as f64 + 1.0);
        // Consensus: every worker pinned to the center...
        for w in &workers {
            assert!((w[j] as f64 - center[j] as f64).abs() < 1e-5, "coord {j}");
        }
        // ...center equals the worker average...
        assert!((center[j] as f64 - mean).abs() < 1e-5, "coord {j}");
        // ...and both sit at the conserved symmetric-force fixed point.
        assert!(
            (center[j] as f64 - fixed).abs() < 1e-3,
            "coord {j}: center {} vs conserved mean {fixed}",
            center[j]
        );
    }
}

/// The executor trait objects report their backend names (backend
/// plumbing used by figures/CLI).
#[test]
fn executor_names() {
    assert_eq!(SimExecutor.name(), "sim");
    assert_eq!(ThreadExecutor::default().name(), "thread");
}
