//! Parity suite for the batched GEMM compute path: the batch-major
//! `Mlp::grad_batch` / `Mlp::eval_batch` pipeline must agree with the
//! summed per-sample path on random parameter vectors, eval statistics
//! must stay run-to-run deterministic, and the NaN-hardened argmax
//! must never panic.

use elastic_train::coordinator::{GradOracle, MlpOracle};
use elastic_train::data::BlobDataset;
use elastic_train::model::{Mlp, MlpConfig};
use elastic_train::rng::Rng;
use std::sync::Arc;

/// grad_batch == mean of per-sample grads, within 1e-4 relative, on
/// random thetas, awkward dims (register-tile tails included), and
/// batch sizes around the MR=4 tile edges.
#[test]
fn grad_batch_matches_summed_per_sample_grads() {
    let cfg = MlpConfig::new(&[11, 23, 14, 5], 1e-3);
    let mut mlp = Mlp::new(cfg);
    let mut rng = Rng::new(99);
    for &n in &[1usize, 2, 3, 4, 5, 8, 13, 37] {
        // Fresh random theta per batch size (not just the He init).
        let mut theta = mlp.init_params(&mut rng);
        for t in theta.iter_mut() {
            *t += rng.normal(0.0, 0.3) as f32;
        }
        let data: Vec<(Vec<f32>, usize)> = (0..n)
            .map(|_| {
                let x = (0..11).map(|_| rng.normal(0.0, 1.0) as f32).collect();
                (x, rng.below(5))
            })
            .collect();
        let mut gb = vec![0.0f32; theta.len()];
        let lb = mlp.batch_grad(&theta, &data, &mut gb);
        // Per-sample reference: accumulate, then take the mean (the
        // per-sample grad adds the l2 term each call, so the mean
        // carries it once — same as the batched path).
        let mut gs = vec![0.0f32; theta.len()];
        let mut ls = 0.0f32;
        for (x, y) in &data {
            ls += mlp.grad(&theta, x, *y, &mut gs);
        }
        let inv = 1.0 / n as f32;
        assert!(
            (lb - ls * inv).abs() < 1e-4 * (1.0 + lb.abs()),
            "n={n}: loss {lb} vs {}",
            ls * inv
        );
        for (i, (&b, &s)) in gb.iter().zip(&gs).enumerate() {
            let want = s * inv;
            assert!(
                (b - want).abs() < 1e-4 * (1.0 + want.abs()),
                "n={n} param {i}: batched {b} vs per-sample {want}"
            );
        }
    }
}

/// The batched eval produces identical stats run-to-run (the figure
/// sweeps rely on bit-deterministic curves given a seed).
#[test]
fn batched_eval_stats_are_deterministic() {
    let data = Arc::new(BlobDataset::generate(8, 4, 512, 200, 0.8, 1));
    let cfg = MlpConfig::new(&[8, 16, 4], 1e-4);
    let mut o = MlpOracle::new(data, cfg, 32, 7);
    let theta = o.init_params();
    let a = o.eval(&theta);
    let b = o.eval(&theta);
    assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
    assert_eq!(a.test_loss.to_bits(), b.test_loss.to_bits());
    assert_eq!(a.test_error.to_bits(), b.test_error.to_bits());
}

/// Eval loss must equal the per-sample loss path (l2 shared once per
/// theta vs recomputed per sample is the same number, cheaper).
#[test]
fn batched_eval_matches_per_sample_losses() {
    let data = Arc::new(BlobDataset::generate(8, 4, 300, 64, 0.8, 2));
    let cfg = MlpConfig::new(&[8, 16, 4], 1e-4);
    let mut o = MlpOracle::new(data.clone(), cfg.clone(), 32, 7);
    let theta = o.init_params();
    let stats = o.eval(&theta);
    let mut mlp = Mlp::new(cfg);
    let mut test_loss = 0.0f64;
    for (x, y) in &data.test {
        test_loss += mlp.loss(&theta, x, *y) as f64;
    }
    test_loss /= data.test.len() as f64;
    assert!(
        (stats.test_loss - test_loss).abs() < 1e-5 * (1.0 + test_loss.abs()),
        "batched {} vs per-sample {}",
        stats.test_loss,
        test_loss
    );
}

/// NaN logits must not panic anywhere on the eval path and the argmax
/// must degrade to class 0.
#[test]
fn nan_theta_does_not_panic_on_eval_path() {
    let data = Arc::new(BlobDataset::generate(8, 4, 64, 32, 0.8, 3));
    let cfg = MlpConfig::new(&[8, 16, 4], 1e-4);
    let mut mlp = Mlp::new(cfg.clone());
    let nan_theta = vec![f32::NAN; cfg.n_params()];
    let (x, _) = &data.train[0];
    assert_eq!(mlp.predict(&nan_theta, x), 0);
    // The oracle eval runs the same argmax over the whole test set.
    let mut o = MlpOracle::new(data, cfg, 32, 7);
    let stats = o.eval(&nan_theta);
    assert!(stats.test_error >= 0.0); // completed without panicking
}
