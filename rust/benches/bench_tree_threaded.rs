//! Real wall-clock throughput of the THREAD tree backend: leaf
//! steps/sec over leaves p ∈ {4, 8, 16} × fan-out d ∈ {2, 4} ×
//! up-period τ_u ∈ {1, 8} (scheme 2, τ_d = 8·τ_u), EASGD on the
//! deterministic quadratic oracle — the gradient is a pure n-element
//! stream, so the grid measures the executor (node threads + mpsc
//! snapshot traffic), not the model.
//!
//!     cargo bench --bench bench_tree_threaded            # full grid
//!     cargo bench --bench bench_tree_threaded -- --quick # smoke (CI)
//!
//! Expected shape: steps/sec grows with p while leaves ≤ cores and the
//! push period is long (τ_u = 8); at τ_u = 1 every leaf step clones and
//! ships a full snapshot, so the channel traffic eats the scaling —
//! the thesis' communication-period story measured on real threads.
//! The (d=4, τ_u=8) column prints a monotonicity verdict (5% slack;
//! oversubscribed p > cores legitimately plateaus).

use elastic_train::cluster::CostModel;
use elastic_train::coordinator::{
    run_tree_threaded, DriverConfig, Method, QuadraticOracle, TreeScheme, TreeSpec,
};
use elastic_train::figures::benchkit::{append_history, git_sha, unix_time};
use std::time::Instant;

/// Per-step gradient size: big enough that one step (~tens of µs)
/// dwarfs scheduling overhead, small enough for a quick grid.
const N_PARAMS: usize = 65_536;

fn steps_per_sec(leaves: usize, degree: usize, tau_up: u32, total_steps: u64) -> f64 {
    let mut oracles = QuadraticOracle::family(N_PARAMS, 1.0, 0.0, 1.0, 0.0, leaves);
    let spec = TreeSpec::new(
        degree,
        TreeScheme::UpDown { tau_up, tau_down: tau_up * 8 },
    );
    let cfg = DriverConfig {
        eta: 0.05,
        method: Method::Easgd { alpha: 0.9 / (degree as f32 + 1.0), tau: 1 },
        cost: CostModel::cifar_like(N_PARAMS), // unused by the thread backend
        horizon: 120.0,                        // real-seconds safety net
        eval_every: 1e6,                       // no mid-run snapshots
        seed: 9,
        max_steps: total_steps,
        lr_decay_gamma: 0.0,
    };
    let t0 = Instant::now();
    let r = run_tree_threaded(&mut oracles, &cfg, &spec).expect("supported combination");
    assert!(!r.diverged, "p={leaves} d={degree} τ_u={tau_up} diverged");
    assert_eq!(r.total_steps, total_steps);
    r.total_steps as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick" || a == "quick");
    let steps: u64 = if quick { 4_000 } else { 20_000 };
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    println!(
        "thread tree backend scaling: EASGD on quadratic(n={N_PARAMS}), {steps} leaf \
         steps/cell, {cores} cores\n"
    );
    println!(
        "{:>5} {:>3} {:>4} {:>14} {:>10}",
        "tau_u", "d", "p", "steps/sec", "vs p=4"
    );

    let mut rows: Vec<String> = Vec::new();
    let mut verdict_col: Vec<(usize, f64)> = Vec::new();
    for &tau_up in &[1u32, 8] {
        for &degree in &[2usize, 4] {
            let mut base = 0.0f64;
            for &leaves in &[4usize, 8, 16] {
                // Warm-up pass keeps first-touch page faults out of the cell.
                if leaves == 4 {
                    let _ = steps_per_sec(4, degree, tau_up, steps / 4);
                }
                let rate = steps_per_sec(leaves, degree, tau_up, steps);
                if leaves == 4 {
                    base = rate;
                }
                println!(
                    "{tau_up:>5} {degree:>3} {leaves:>4} {rate:>14.0} {:>9.2}x",
                    rate / base
                );
                rows.push(format!(
                    "      {{\"tau_up\": {tau_up}, \"degree\": {degree}, \"leaves\": {leaves}, \
                     \"steps_per_sec\": {rate:.1}}}"
                ));
                if tau_up == 8 && degree == 4 {
                    verdict_col.push((leaves, rate));
                }
            }
            println!();
        }
    }

    // Acceptance shape: at (d=4, τ_u=8) steps/sec is monotone
    // non-degrading from p=4 to p=16 while the machine has the cores
    // for it (5% slack for scheduler noise).
    let considered: Vec<&(usize, f64)> = verdict_col
        .iter()
        .filter(|(p, _)| *p <= cores.max(4))
        .collect();
    let monotone = considered.windows(2).all(|w| w[1].1 >= w[0].1 * 0.95);
    println!(
        "d=4 tau_u=8 scaling p=4->16: {} ({})",
        if monotone { "MONOTONE" } else { "NOT MONOTONE" },
        considered
            .iter()
            .map(|(p, r)| format!("p{p}={r:.0}"))
            .collect::<Vec<_>>()
            .join(" "),
    );
    if cores < 16 {
        println!(
            "(only {cores} cores visible — a p-leaf tree runs p+interior threads, so \
             scaling beyond p≈{cores} plateaus by design)"
        );
    }

    // Per-PR history, keyed by git SHA like BENCH_oracle.json.
    let entry = format!(
        "  {{\n    \"bench\": \"tree_threaded\",\n    \"sha\": \"{}\",\n    \"unix_time\": {},\n    \
         \"quick\": {},\n    \"cores\": {},\n    \"unit\": \"steps_per_sec\",\n    \
         \"results\": [\n{}\n    ]\n  }}",
        git_sha(),
        unix_time(),
        quick,
        cores,
        rows.join(",\n")
    );
    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_tree_threaded.json");
    append_history(out, &entry);
    println!("appended history entry to {out}");
}
