//! PCG64 (pcg_xsl_rr_128_64): 128-bit LCG state, xorshift-low + random
//! rotate output. Reference: O'Neill, "PCG: A Family of Simple Fast
//! Space-Efficient Statistically Good Algorithms for Random Number
//! Generation" (2014).

const MULT: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;
const INC: u128 = 0x5851_F42D_4C95_7F2D_1405_7B7E_F767_814F;

/// The raw generator; use [`super::Rng`] for distributions.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
}

impl Pcg64 {
    pub fn new(seed: u64) -> Self {
        // SplitMix-style state expansion so nearby seeds decorrelate.
        let mut s = Self {
            state: (seed as u128) ^ 0xCAFE_F00D_D15E_A5E5_u128 << 64,
        };
        s.state = s.state.wrapping_mul(MULT).wrapping_add(INC);
        s.next_u64();
        s.next_u64();
        s
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(MULT).wrapping_add(INC);
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_short_cycles() {
        let mut g = Pcg64::new(0);
        let first = g.next_u64();
        for _ in 0..10_000 {
            assert_ne!(g.next_u64(), 0);
        }
        // Extremely unlikely to revisit the first value in 10k steps.
        let mut g2 = Pcg64::new(0);
        g2.next_u64();
        let mut hits = 0;
        for _ in 0..10_000 {
            if g2.next_u64() == first {
                hits += 1;
            }
        }
        assert_eq!(hits, 0);
    }

    #[test]
    fn bit_balance() {
        let mut g = Pcg64::new(77);
        let mut ones = 0u64;
        let n = 10_000;
        for _ in 0..n {
            ones += g.next_u64().count_ones() as u64;
        }
        let frac = ones as f64 / (64.0 * n as f64);
        assert!((frac - 0.5).abs() < 0.01, "bit fraction {frac}");
    }
}
