//! General real eigenvalues: Householder Hessenberg reduction, then
//! complex single-shift (Wilkinson) QR with deflation via Givens
//! rotations. Exceptional ad-hoc shifts break the rare symmetric-stall
//! cycles (Jordan blocks, rotation-like matrices).
//!
//! Complexity per QR sweep is O(n²) on the Hessenberg form; the figure
//! sweeps call this on n ≤ ~20 so total cost is negligible next to the
//! number of grid points.

use super::complex::Complex;
use super::matrix::Matrix;

/// All eigenvalues of a real square matrix (with multiplicity).
pub fn eigenvalues(a: &Matrix) -> Vec<Complex> {
    assert_eq!(a.rows(), a.cols(), "eigenvalues need a square matrix");
    let n = a.rows();
    if n == 0 {
        return vec![];
    }
    if n == 1 {
        return vec![Complex::real(a.get(0, 0))];
    }
    let mut h = hessenberg(a);
    qr_hessenberg(&mut h)
}

/// max |λ| — the quantity every stability figure plots.
pub fn spectral_radius(a: &Matrix) -> f64 {
    eigenvalues(a).iter().fold(0.0f64, |m, z| m.max(z.abs()))
}

/// Householder reduction of a real matrix to (complex-stored) upper
/// Hessenberg form. Eigenvalues are preserved.
fn hessenberg(a: &Matrix) -> Vec<Vec<Complex>> {
    let n = a.rows();
    let mut h: Vec<Vec<f64>> = (0..n)
        .map(|i| (0..n).map(|j| a.get(i, j)).collect())
        .collect();

    for k in 0..n.saturating_sub(2) {
        // Householder vector annihilating h[k+2.., k].
        let mut alpha = 0.0f64;
        for i in k + 1..n {
            alpha += h[i][k] * h[i][k];
        }
        alpha = alpha.sqrt();
        if alpha == 0.0 {
            continue;
        }
        if h[k + 1][k] > 0.0 {
            alpha = -alpha;
        }
        let mut v = vec![0.0f64; n];
        v[k + 1] = h[k + 1][k] - alpha;
        for i in k + 2..n {
            v[i] = h[i][k];
        }
        let vtv: f64 = v.iter().map(|x| x * x).sum();
        if vtv == 0.0 {
            continue;
        }
        let beta = 2.0 / vtv;
        // H <- (I - beta v v^T) H
        for j in 0..n {
            let mut dot = 0.0;
            for i in k + 1..n {
                dot += v[i] * h[i][j];
            }
            let s = beta * dot;
            for i in k + 1..n {
                h[i][j] -= s * v[i];
            }
        }
        // H <- H (I - beta v v^T)
        for i in 0..n {
            let mut dot = 0.0;
            for j in k + 1..n {
                dot += h[i][j] * v[j];
            }
            let s = beta * dot;
            for j in k + 1..n {
                h[i][j] -= s * v[j];
            }
        }
        // Clean the column below the subdiagonal exactly.
        h[k + 1][k] = alpha;
        for i in k + 2..n {
            h[i][k] = 0.0;
        }
    }

    h.into_iter()
        .map(|row| row.into_iter().map(Complex::real).collect())
        .collect()
}

/// Shifted QR on a complex upper-Hessenberg matrix. Consumes `h`.
fn qr_hessenberg(h: &mut [Vec<Complex>]) -> Vec<Complex> {
    let n = h.len();
    let mut eigs = Vec::with_capacity(n);
    let mut hi = n; // active block is h[lo..hi]
    let mut iters_since_deflate = 0usize;

    while hi > 0 {
        // Find the active block: scan up for a negligible subdiagonal.
        let mut lo = hi - 1;
        while lo > 0 {
            let s = h[lo - 1][lo - 1].abs() + h[lo][lo].abs();
            let tiny = f64::EPSILON * s.max(f64::MIN_POSITIVE);
            if h[lo][lo - 1].abs() <= tiny {
                h[lo][lo - 1] = Complex::ZERO;
                break;
            }
            lo -= 1;
        }

        if lo == hi - 1 {
            // 1x1 block deflates directly.
            eigs.push(h[hi - 1][hi - 1]);
            hi -= 1;
            iters_since_deflate = 0;
            continue;
        }

        if iters_since_deflate > 0 && iters_since_deflate % 400 == 0 {
            // Should not happen with exceptional shifts, but never hang.
            // Take the diagonal as the best available estimate.
            for i in lo..hi {
                eigs.push(h[i][i]);
            }
            return eigs;
        }

        // Wilkinson shift from the trailing 2x2 of the active block.
        let a = h[hi - 2][hi - 2];
        let b = h[hi - 2][hi - 1];
        let c = h[hi - 1][hi - 2];
        let d = h[hi - 1][hi - 1];
        let tr = a + d;
        let det = a * d - b * c;
        let disc = (tr * tr - det * 4.0).sqrt();
        let l1 = (tr + disc) * 0.5;
        let l2 = (tr - disc) * 0.5;
        let mut shift = if (l1 - d).abs() < (l2 - d).abs() { l1 } else { l2 };
        if iters_since_deflate > 0 && iters_since_deflate % 12 == 0 {
            // Exceptional shift: perturb to break symmetric stalls.
            let mag = h[hi - 1][hi - 2].abs() + h[hi - 1][hi - 1].abs();
            shift = shift + Complex::new(0.75 * mag + 0.1, 0.31 * mag + 0.05);
        }

        // One implicit shifted QR sweep via Givens rotations on [lo, hi).
        for i in lo..hi {
            h[i][i] = h[i][i] - shift;
        }
        // QR factorize in place: rotations G_k zero the subdiagonal.
        let mut rot = Vec::with_capacity(hi - lo - 1);
        for k in lo..hi - 1 {
            let x = h[k][k];
            let y = h[k + 1][k];
            let r = (x.norm_sqr() + y.norm_sqr()).sqrt();
            if r == 0.0 {
                rot.push((Complex::ONE, Complex::ZERO));
                continue;
            }
            let cgiv = x * (1.0 / r);
            let sgiv = y * (1.0 / r);
            rot.push((cgiv, sgiv));
            // Apply G^H to rows k, k+1 (columns k..hi).
            for j in k..hi {
                let t1 = h[k][j];
                let t2 = h[k + 1][j];
                h[k][j] = cgiv.conj() * t1 + sgiv.conj() * t2;
                h[k + 1][j] = -sgiv * t1 + cgiv * t2;
            }
        }
        // RQ: apply the same rotations on the right (columns k, k+1).
        // Only rows lo..k+2 can be non-zero in those columns of R.
        for (k, (cgiv, sgiv)) in (lo..hi - 1).zip(rot) {
            for i in lo..(k + 2).min(hi) {
                let t1 = h[i][k];
                let t2 = h[i][k + 1];
                h[i][k] = t1 * cgiv + t2 * sgiv;
                h[i][k + 1] = -(t1 * sgiv.conj()) + t2 * cgiv.conj();
            }
        }
        for i in lo..hi {
            h[i][i] = h[i][i] + shift;
        }
        iters_since_deflate += 1;
    }

    eigs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_by_two_exact() {
        // [[0, -1], [1, 0]] -> ±i.
        let m = Matrix::from_rows(&[&[0.0, -1.0], &[1.0, 0.0]]);
        let e = eigenvalues(&m);
        assert_eq!(e.len(), 2);
        for z in e {
            assert!(z.re.abs() < 1e-12 && (z.im.abs() - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn upper_triangular_reads_diagonal() {
        let m = Matrix::from_rows(&[
            &[1.0, 5.0, -2.0],
            &[0.0, -4.0, 3.0],
            &[0.0, 0.0, 2.5],
        ]);
        let mut mags: Vec<f64> = eigenvalues(&m).iter().map(|z| z.abs()).collect();
        mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let want = [1.0, 2.5, 4.0];
        for (g, w) in mags.iter().zip(want) {
            assert!((g - w).abs() < 1e-9);
        }
    }

    #[test]
    fn spectral_radius_scaling() {
        let mut rng = crate::rng::Rng::new(21);
        let n = 6;
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                m.set(i, j, rng.normal(0.0, 1.0));
            }
        }
        let r1 = spectral_radius(&m);
        let r2 = spectral_radius(&m.scale(2.0));
        assert!((r2 - 2.0 * r1).abs() < 1e-8 * (1.0 + r1));
    }
}
